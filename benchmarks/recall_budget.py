"""Paper Fig. 2 / Figs. 9-20: Top-k-Recall vs CE-call budget for ADACUR
variants, ANNCUR and retrieve-and-rerank baselines, all budget-matched —
every method runs as a configuration of the unified Retriever engine
(``repro.core.engine``)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AdaCURConfig
from repro.core import retrieval
from repro.core.engine import AdaCURRetriever, ANNCURRetriever, RerankRetriever

from .common import Domain, emit, make_domain, timed

BUDGETS = (50, 100, 200, 500)
KS = (1, 10, 100)


def _de_candidates(dom: Domain, noise: float = 1.5, key=jax.random.PRNGKey(9)):
    """Stand-in first-stage retriever: exact scores + noise (a 'DE_BASE'
    whose retrieval quality is good but imperfect)."""
    noisy = dom.exact + noise * jax.random.normal(key, dom.exact.shape)
    _, order = jax.lax.top_k(noisy, dom.exact.shape[1])
    return order


def run(dom: Domain | None = None, quiet: bool = False, fused: bool = False):
    dom = dom or make_domain()
    score_fn = dom.ce.score_fn()
    de_order = _de_candidates(dom)
    key = jax.random.PRNGKey(1)
    rows = []
    for budget in BUDGETS:
        k_anchor = budget // 2
        base = dict(k_anchor=k_anchor, n_rounds=5, budget_ce=budget,
                    k_retrieve=100, loop_mode="fori", use_fused_topk=fused)
        methods = {}

        ret = AdaCURRetriever.from_index(dom.index, score_fn,
                                         AdaCURConfig(strategy="topk", **base))
        methods["adacur_topk"] = timed(lambda: ret.search(dom.test_q, key), warmup=1)

        ret_s = AdaCURRetriever.from_index(dom.index, score_fn,
                                           AdaCURConfig(strategy="softmax", **base))
        methods["adacur_softmax"] = timed(lambda: ret_s.search(dom.test_q, key), warmup=1)

        ns = dict(base, k_anchor=budget, split_budget=False)
        ret_ns = AdaCURRetriever.from_index(dom.index, score_fn,
                                            AdaCURConfig(strategy="topk", **ns))
        methods["adacur_topk_nosplit"] = timed(lambda: ret_ns.search(dom.test_q, key), warmup=1)

        # ADACUR seeded by the DE retriever (paper's ADACUR_{DE_BASE+TopK})
        first = de_order[:, : budget // 5]
        ret_de = AdaCURRetriever.from_index(
            dom.index, score_fn,
            AdaCURConfig(strategy="topk", first_round="retriever", **ns),
        )
        methods["adacur_de_topk_nosplit"] = timed(
            lambda: ret_de.search(dom.test_q, key, first_anchors=first), warmup=1
        )

        idx = dom.index.with_anchors(k_anchor=k_anchor, key=jax.random.PRNGKey(2))
        ret_a = ANNCURRetriever.from_index(idx, score_fn, budget, 100)
        methods["anncur"] = timed(lambda: ret_a.search(dom.test_q), warmup=1)

        idx_de = dom.index.with_anchors(anchor_pos=de_order[0, :k_anchor])
        ret_ade = ANNCURRetriever.from_index(idx_de, score_fn, budget, 100)
        methods["anncur_de"] = timed(lambda: ret_ade.search(dom.test_q), warmup=1)

        ret_rr = RerankRetriever.from_index(dom.index, score_fn, budget, 100)
        methods["de_rerank"] = timed(
            lambda: ret_rr.search(dom.test_q, candidate_idx=de_order), warmup=1
        )

        for name, (res, us) in methods.items():
            rep = retrieval.evaluate_result(name, res, dom.exact, ks=KS)
            derived = ";".join(f"recall@{k}={rep.recall[k]:.3f}" for k in KS)
            emit(f"recall_budget/{name}/B{budget}", us, derived)
            rows.append((name, budget, rep.recall))
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 2 / Figs. 9-20: Top-k-Recall vs CE-call budget for ADACUR
variants, ANNCUR and retrieve-and-rerank baselines, all budget-matched."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AdaCURConfig
from repro.core import adacur, anncur, retrieval

from .common import Domain, emit, make_domain, timed

BUDGETS = (50, 100, 200, 500)
KS = (1, 10, 100)


def _de_candidates(dom: Domain, noise: float = 1.5, key=jax.random.PRNGKey(9)):
    """Stand-in first-stage retriever: exact scores + noise (a 'DE_BASE'
    whose retrieval quality is good but imperfect)."""
    noisy = dom.exact + noise * jax.random.normal(key, dom.exact.shape)
    _, order = jax.lax.top_k(noisy, dom.exact.shape[1])
    return order


def run(dom: Domain | None = None, quiet: bool = False):
    dom = dom or make_domain()
    score_fn = dom.ce.score_fn()
    de_order = _de_candidates(dom)
    rows = []
    for budget in BUDGETS:
        k_anchor = budget // 2
        methods = {}

        cfg = AdaCURConfig(k_anchor=k_anchor, n_rounds=5, budget_ce=budget,
                           strategy="topk", k_retrieve=100)
        res, us = timed(
            lambda: adacur.adacur_search(score_fn, dom.r_anc, dom.test_q, cfg,
                                         jax.random.PRNGKey(1)))
        methods["adacur_topk"] = (res, us)

        cfg_s = AdaCURConfig(k_anchor=k_anchor, n_rounds=5, budget_ce=budget,
                             strategy="softmax", k_retrieve=100)
        res, us = timed(
            lambda: adacur.adacur_search(score_fn, dom.r_anc, dom.test_q, cfg_s,
                                         jax.random.PRNGKey(1)))
        methods["adacur_softmax"] = (res, us)

        cfg_ns = AdaCURConfig(k_anchor=budget, n_rounds=5, budget_ce=budget,
                              strategy="topk", split_budget=False, k_retrieve=100)
        res, us = timed(
            lambda: adacur.adacur_search(score_fn, dom.r_anc, dom.test_q, cfg_ns,
                                         jax.random.PRNGKey(1)))
        methods["adacur_topk_nosplit"] = (res, us)

        # ADACUR seeded by the DE retriever (paper's ADACUR_{DE_BASE+TopK})
        first = de_order[:, : budget // 5]
        cfg_de = AdaCURConfig(k_anchor=budget, n_rounds=5, budget_ce=budget,
                              strategy="topk", split_budget=False,
                              first_round="retriever", k_retrieve=100)
        res, us = timed(
            lambda: adacur.adacur_search(score_fn, dom.r_anc, dom.test_q, cfg_de,
                                         jax.random.PRNGKey(1), first_anchors=first))
        methods["adacur_de_topk_nosplit"] = (res, us)

        idx = anncur.build_index(dom.r_anc, k_anchor, key=jax.random.PRNGKey(2))
        res, us = timed(lambda: anncur.search(score_fn, idx, dom.test_q, budget, 100))
        methods["anncur"] = (res, us)

        idx_de = anncur.build_index(
            dom.r_anc, k_anchor, anchor_idx=de_order[0, :k_anchor])
        res, us = timed(lambda: anncur.search(score_fn, idx_de, dom.test_q, budget, 100))
        methods["anncur_de"] = (res, us)

        res, us = timed(
            lambda: retrieval.rerank_baseline(score_fn, de_order, dom.test_q, budget, 100))
        methods["de_rerank"] = (res, us)

        for name, (res, us) in methods.items():
            rep = retrieval.evaluate_result(name, res, dom.exact, ks=KS)
            derived = ";".join(f"recall@{k}={rep.recall[k]:.3f}" for k in KS)
            emit(f"recall_budget/{name}/B{budget}", us, derived)
            rows.append((name, budget, rep.recall))
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 5 (§3.2): oracle anchor-sampling strategies with access to
exact CE scores — TopK^O/SoftMax^O with (k_m, eps) sweeps, evaluated by
running ANNCUR-style CUR retrieval on the oracle-chosen anchors."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cur, retrieval, sampling
from repro.core.adacur import AdaCURResult

from .common import emit, make_domain, timed

K_I = 200
EPS_GRID = (0.0, 0.25, 0.5, 0.75, 0.9)


def _cur_retrieve_with_anchors(dom, anchor_idx, k_retrieve=100):
    """Retrieval quality when CUR uses a GIVEN per-query anchor set."""
    c_test = jnp.take_along_axis(dom.exact, anchor_idx, axis=1)
    s_hat = cur.approx_scores(dom.r_anc, c_test, anchor_idx, rcond=1e-4)
    b, n = s_hat.shape
    sel = jnp.zeros((b, n), bool).at[jnp.arange(b)[:, None], anchor_idx].set(True)
    masked = jnp.where(sel, -1e30, s_hat)
    _, rest = jax.lax.top_k(masked, k_retrieve)
    pool_idx = jnp.concatenate([anchor_idx, rest], axis=1)
    pool_scores = jnp.take_along_axis(dom.exact, pool_idx, axis=1)
    top_s, pos = jax.lax.top_k(pool_scores, k_retrieve)
    top_idx = jnp.take_along_axis(pool_idx, pos, axis=1)
    return AdaCURResult(anchor_idx, c_test, s_hat, top_idx, top_s, K_I)


def run(dom=None, quiet: bool = False):
    dom = dom or make_domain()
    key = jax.random.PRNGKey(0)
    results = {}

    # Fig 5a: mask-top-k effect (k_m = 0 vs k_m = k) at eps=0
    for k_m in (0, 10):
        for strat, fn in (("topk", sampling.oracle_topk), ("softmax", sampling.oracle_softmax)):
            anchors, us = timed(lambda: fn(key, dom.exact, K_I, k_m=k_m, eps=0.0))
            res = _cur_retrieve_with_anchors(dom, anchors)
            rep = retrieval.evaluate_result("o", res, dom.exact)
            derived = ";".join(f"recall@{k}={v:.3f}" for k, v in rep.recall.items())
            emit(f"oracle/{strat}/km{k_m}/eps0", us, derived)
            results[(strat, k_m, 0.0)] = rep.recall

    # Fig 5b/5c: eps sweep (fraction of random anchors for diversity)
    for strat, fn in (("topk", sampling.oracle_topk), ("softmax", sampling.oracle_softmax)):
        for eps in EPS_GRID:
            anchors, us = timed(lambda: fn(key, dom.exact, K_I, k_m=0, eps=eps))
            res = _cur_retrieve_with_anchors(dom, anchors)
            rep = retrieval.evaluate_result("o", res, dom.exact)
            derived = ";".join(f"recall@{k}={v:.3f}" for k, v in rep.recall.items())
            emit(f"oracle/{strat}/km0/eps{eps}", us, derived)
            results[(strat, 0, eps)] = rep.recall
    return results


if __name__ == "__main__":
    run()

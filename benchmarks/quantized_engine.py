"""Quantized-payload engine bench -> ``BENCH_quant.json``.

For each corpus size N this records, on the same synthetic CE domain and
the same seeds:

- **index bytes**: the R_anc payload footprint per payload dtype —
  fp32 vs the coded encodings (codes + per-tile scales).  int8 and
  fp8-e4m3 land at ~0.25x fp32; packed int4 (two codes per byte) at
  ~0.125x, under the 0.15 CI gate;
- **per-round latency** (staged kernel): the marginal adaptive-round cost
  of the fused engine ((t[n_rounds] - t[1]) / (n_rounds - 1), interleaved
  medians — the same protocol as BENCH_engine.json), per payload dtype.
  All paths use the engine's default ``fused_tile`` byte budget; a coded
  payload streams 4x (int8/fp8) or 8x (int4) the columns per tile in that
  budget (``_effective_tile``), which is where the byte reduction becomes
  wall-clock;
- **persistent vs staged round kernel**: the same marginal-per-round
  protocol under the *monitored* loop (``early_exit_tol`` armed, so every
  round also runs the provisional-top-k convergence probe).  The staged
  kernel streams the payload twice per monitored round (sample sweep +
  monitor sweep); the persistent kernel software-pipelines round r+1's
  sample into round r's monitor sweep — one payload pass per round.  Its
  rankings are BIT-identical to staged (asserted in tests), so no recall
  column: only the latency ratio.  Dequant is the work the fusion halves,
  so the win grows with payload coarseness (measured at N=100k on this
  host: int4 0.80x, int8 0.89x, fp32 0.97x, fp8 ~1.0x — fp8 decode is
  emulated casts on CPU); CI gates int4 <= 0.9 and fp32 <= 1.05 (a
  no-regression canary: with no dequant to halve, fusion only saves the
  second payload read);
- **recall@{1,10} parity**: retrieval quality per payload dtype against
  brute-force ground truth on identical seeds — quantizing R_anc perturbs
  the *approximation* that proposes candidates, never the exact CE scores
  that rank them.  int8 must not degrade recall@10 by 0.005 absolute at
  N=100k (asserted in CI; it currently *gains* — the rounding noise both
  regularizes the ill-conditioned pinv of correlated adaptive anchors
  (cf. ``pinv_rcond``) and adds the anchor diversity the paper's §3.2
  oracle study motivates).  The sub-int8 codes sit past that noise
  optimum and TRADE recall for bytes on this domain (measured at N=100k:
  fp8 ~ -0.09 @10 vs fp32; int4 ~ -0.4 vs int8 — per-(row,tile) blocked
  scales, NF4 codebooks and MSE-optimal clipping were all measured and
  recover at most ~0.1 of it, because top-k retrieval lives on the score
  *tails* that coarse grids flatten).  Their CI checks are calibrated
  regression canaries (bit-level corruption of packed codes or scales
  drives recall toward 0, far below the floors), not parity claims; the
  README table carries the measured trade-off.

  PYTHONPATH=src python -m benchmarks.quantized_engine [--fast|--full|--ci]

``--fast``: N=10k only.  ``--ci``: N in {10k, 100k}.  ``--full`` adds the
million-item point (fp32 R_anc alone is ~0.5 GB at k_q=128 — exactly the
payload the sub-int8 path is for: the int4 copy is ~64 MB).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdaCURConfig, replace
from repro.core import retrieval
from repro.core.engine import AdaCURRetriever, engine_slab_bytes
from repro.core.index import AnchorIndex
from repro.core.scorer import SyntheticScorer
from repro.data.synthetic import make_synthetic_ce
from repro.kernels.approx_topk import quant

from .common import emit

K_Q = 128
N_EVAL_Q = 100
PAYLOAD_TILE = 512
RECALL_SEEDS = (1, 2, 3)
CODED = ("int8", "int4", "fp8")


def _dtypes():
    return ["float32"] + [d for d in CODED if d != "fp8" or quant.fp8_supported()]


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def _ground_truth_topk(ce, eval_q, n_items: int, k: int, chunk: int = 16):
    """Brute-force top-k ids per eval query, computed in query chunks so the
    (Q, N) exact matrix never materializes at the million-item sizes."""
    item_ids = jnp.arange(n_items)
    fn = jax.jit(lambda q: jax.lax.top_k(ce.score_block(q, item_ids), k)[1])
    out = [fn(eval_q[lo: lo + chunk]) for lo in range(0, eval_q.shape[0], chunk)]
    return jnp.concatenate(out, axis=0)


def _time_marginal(rets: dict, queries, key, n_rounds: int, reps: int):
    """Interleaved medians of full vs single-round wall clock per tag ->
    (marginal per-round ms, full-call ms).  Interleaving the tags means
    load drift hits every path equally."""
    for ret in rets.values():           # compile all executables up front
        jax.block_until_ready(ret.search(queries, key))
        jax.block_until_ready(ret.search(queries, key, n_rounds=1))
    samples = {tag: {"full": [], "r1": []} for tag in rets}
    for _ in range(reps):
        for tag, ret in rets.items():
            t0 = time.perf_counter()
            jax.block_until_ready(ret.search(queries, key))
            samples[tag]["full"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(ret.search(queries, key, n_rounds=1))
            samples[tag]["r1"].append(time.perf_counter() - t0)
    per_round, call_ms = {}, {}
    for tag in rets:
        full = _median(samples[tag]["full"]) * 1e3
        r1 = _median(samples[tag]["r1"]) * 1e3
        call_ms[tag] = round(full, 3)
        per_round[tag] = round(max(full - r1, 0.0) / (n_rounds - 1), 3)
    return per_round, call_ms


def bench_size(
    n_items: int,
    batch: int = 256,
    budget: int = 200,
    n_rounds: int = 5,
    reps: int = 7,
) -> dict:
    dtypes = _dtypes()
    ce = make_synthetic_ce(
        jax.random.PRNGKey(0), n_queries=K_Q + N_EVAL_Q, n_items=n_items
    )
    r_anc = ce.full_matrix(jnp.arange(K_Q))
    index32 = AnchorIndex.from_r_anc(r_anc, anchor_query_ids=jnp.arange(K_Q))
    indexes = {"float32": index32}
    for dt in dtypes[1:]:
        indexes[dt] = index32.quantize(dt, tile=PAYLOAD_TILE)
    del r_anc
    score_fn = SyntheticScorer(ce)
    eval_q = jnp.arange(K_Q, K_Q + N_EVAL_Q)
    queries = jnp.tile(eval_q, -(-batch // N_EVAL_Q))[:batch]
    key = jax.random.PRNGKey(1)

    base = AdaCURConfig(
        k_anchor=budget // 2, n_rounds=n_rounds, budget_ce=budget,
        strategy="topk", k_retrieve=10, loop_mode="fori", use_fused_topk=True,
    )

    def cfg_for(dt, **kw):
        extra = {} if dt == "float32" else dict(
            payload_dtype=dt, payload_tile=PAYLOAD_TILE
        )
        return replace(base, **extra, **kw)

    # ---- staged dtype sweep (the historical protocol, now per dtype) ------
    rets = {
        dt: AdaCURRetriever.from_index(indexes[dt], score_fn, cfg_for(dt))
        for dt in dtypes
    }
    per_round, call_ms = _time_marginal(rets, queries, key, n_rounds, reps)
    per_round["ratio"] = {
        dt: round(per_round[dt] / max(per_round["float32"], 1e-9), 3)
        for dt in dtypes[1:]
    }

    # ---- persistent vs staged under the monitored loop --------------------
    # early_exit_tol arms the provisional-top-k probe every round; the
    # tiny tolerance means the loop only stops on EXACT top-k convergence,
    # and since the two kernels' rankings are bit-identical they always
    # run the same number of rounds — the ratio is pure kernel speed
    round_kernel = {}
    mon_rets = {}
    for dt in dtypes:
        for rk in ("staged", "persistent"):
            mon_rets[(dt, rk)] = AdaCURRetriever.from_index(
                indexes[dt], score_fn,
                cfg_for(dt, early_exit_tol=1e-6, round_kernel=rk),
            )
    mon_round, _ = _time_marginal(
        mon_rets, queries, key, n_rounds, max(reps - 2, 3)
    )
    for dt in dtypes:
        st, pe = mon_round[(dt, "staged")], mon_round[(dt, "persistent")]
        round_kernel[dt] = {
            "staged": st,
            "persistent": pe,
            "ratio": round(pe / max(st, 1e-9), 3),
        }

    # ---- recall parity on the same seeds (staged kernel; the persistent
    # kernel's rankings are bit-identical, so one sweep covers both) --------
    gt = _ground_truth_topk(ce, eval_q, n_items, 10)
    recall = {}
    for dt in dtypes:
        r1s, r10s = [], []
        for seed in RECALL_SEEDS:
            res = rets[dt].search(eval_q, jax.random.PRNGKey(seed))
            r1s.append(float(retrieval.topk_recall(res.topk_idx, gt[:, :1], 1)))
            r10s.append(float(retrieval.topk_recall(res.topk_idx, gt, 10)))
        recall[dt] = {
            "@1": round(float(np.mean(r1s)), 4),
            "@10": round(float(np.mean(r10s)), 4),
        }

    nbytes = {dt: int(indexes[dt].payload_nbytes) for dt in dtypes}
    entry = {
        "index_bytes": {
            **nbytes,
            "ratio": {
                dt: round(nbytes[dt] / nbytes["float32"], 4) for dt in dtypes[1:]
            },
        },
        "engine_slab_bytes": {
            dt: engine_slab_bytes(
                cfg_for(dt), batch, n_items, K_Q, payload=indexes[dt].r_anc
            )["total"]
            for dt in dtypes
        },
        "call_ms": call_ms,
        "per_round_ms": per_round,
        "round_kernel_per_round_ms": round_kernel,
        "recall": recall,
        "recall_delta_vs_fp32": {
            dt: {
                k: round(recall[dt][k] - recall["float32"][k], 4)
                for k in ("@1", "@10")
            }
            for dt in dtypes[1:]
        },
        "recall_delta_vs_int8": {
            dt: {
                k: round(recall[dt][k] - recall["int8"][k], 4)
                for k in ("@1", "@10")
            }
            for dt in dtypes[1:] if dt != "int8"
        },
        # kept for older BENCH readers: int8-vs-fp32 recall@10 delta
        "recall10_delta": round(recall["int8"]["@10"] - recall["float32"]["@10"], 4),
    }
    emit(
        f"quant/N{n_items}", per_round["int8"] * 1e3,
        f"int8_round_ratio={entry['per_round_ms']['ratio']['int8']};"
        f"int4_bytes_ratio={entry['index_bytes']['ratio'].get('int4')};"
        f"persistent_ratio_int4={round_kernel.get('int4', {}).get('ratio')};"
        f"recall10_delta={entry['recall10_delta']}",
    )
    return entry


def run(
    sizes=(10_000, 100_000),
    batch: int = 256,
    budget: int = 200,
    n_rounds: int = 5,
    json_path: str = "BENCH_quant.json",
):
    snapshot = {
        "batch": batch,
        "budget_ce": budget,
        "n_rounds": n_rounds,
        "k_q": K_Q,
        "payload_tile": PAYLOAD_TILE,
        "payload_dtypes": _dtypes(),
        "recall_seeds": list(RECALL_SEEDS),
        "n_eval_queries": N_EVAL_Q,
        "sizes": {},
    }
    for n in sorted(sizes):
        reps = 5 if n >= 1_000_000 else 7
        snapshot["sizes"][str(n)] = bench_size(
            n, batch=batch, budget=budget, n_rounds=n_rounds, reps=reps
        )
    at = snapshot["sizes"].get("100000")
    if at is not None:
        ratio = at["index_bytes"]["ratio"]
        d8 = at["recall_delta_vs_fp32"]["int8"]
        d4 = at["recall_delta_vs_int8"].get("int4", {})
        rk = at["round_kernel_per_round_ms"]
        snapshot["checks_at_100k"] = {
            "int8_bytes_ratio_le_0.3": ratio["int8"] <= 0.3,
            "int4_bytes_ratio_le_0.15": ratio.get("int4", 1.0) <= 0.15,
            "int8_per_round_ratio_le_0.9": at["per_round_ms"]["ratio"]["int8"] <= 0.9,
            # int8 must not LOSE recall beyond 0.005 absolute (it gains)
            "int8_recall10_degradation_lt_0.005": d8["@10"] > -0.005,
            # sub-int8 canary floor: measured int4 @10 is ~0.43 vs int8
            # ~0.93 on this domain (see docstring); packed-nibble or scale
            # corruption lands near 0, far below the floor
            "int4_recall10_canary_floor": d4.get("@10", 0.0) > -0.65,
            # the fusion halves DEQUANT, so the win scales with payload
            # coarseness (see docstring); gate the coded int4 win and pin
            # fp32 as a no-regression canary
            "int4_persistent_round_ratio_le_0.9": (
                rk.get("int4", {"ratio": 0.0})["ratio"] <= 0.9
            ),
            "fp32_persistent_round_no_regression": (
                rk["float32"]["ratio"] <= 1.05
            ),
        }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(snapshot, fh, indent=2)
        print(f"# wrote {json_path}")
    return snapshot


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="N=10k only")
    ap.add_argument("--ci", action="store_true", help="N in {10k, 100k}")
    ap.add_argument("--full", action="store_true",
                    help="add the 1M-item point (minutes on CPU)")
    ap.add_argument("--json", default="BENCH_quant.json")
    args = ap.parse_args()
    if args.fast:
        sizes = (10_000,)
    elif args.full:
        sizes = (10_000, 100_000, 1_000_000)
    elif args.ci:
        sizes = (10_000, 100_000)       # the CI gate reads sizes["100000"]
    else:
        sizes = (10_000, 100_000)
    run(sizes=sizes, json_path=args.json)

"""Quantized-payload engine bench -> ``BENCH_quant.json``.

For each corpus size N this records, on the same synthetic CE domain and
the same seeds:

- **index bytes**: the R_anc payload footprint, fp32 vs int8 (codes +
  per-tile scales; the int8 ratio lands at ~0.25), plus the engine's
  per-search state slabs;
- **per-round latency**: the marginal adaptive-round cost of the fused
  engine ((t[n_rounds] - t[1]) / (n_rounds - 1), interleaved medians —
  the same protocol as BENCH_engine.json), fp32 vs int8.  Both paths use
  the engine's default ``fused_tile`` byte budget; the int8 payload
  streams 4x the columns per tile in that budget (``_effective_tile``),
  which is where the ~4x byte reduction becomes wall-clock;
- **recall@{1,10} parity**: retrieval quality of the int8 engine against
  brute-force ground truth, next to the fp32 engine on identical seeds —
  quantizing R_anc perturbs the *approximation* that proposes candidates,
  never the exact CE scores that rank them, so recall@10 must not degrade
  by 0.005 absolute at N=100k (asserted in CI).  Empirically the int8
  engine retrieves *better* than fp32 on this domain (monotone in
  quantization coarseness: fp32 < bf16 < int8, fused == dense exactly for
  each payload): the rounding noise both regularizes the ill-conditioned
  pinv of correlated adaptive anchors (cf. ``pinv_rcond``) and adds the
  anchor diversity the paper's §3.2 oracle study motivates.

  PYTHONPATH=src python -m benchmarks.quantized_engine [--fast|--full|--ci]

``--fast``: N=10k only.  ``--ci``: N in {10k, 100k}.  ``--full`` adds the
million-item point (fp32 R_anc alone is ~0.5 GB at k_q=128 — exactly the
payload the quantized path is for).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdaCURConfig, replace
from repro.core import retrieval
from repro.core.engine import AdaCURRetriever, engine_slab_bytes
from repro.core.index import AnchorIndex
from repro.core.scorer import SyntheticScorer
from repro.data.synthetic import make_synthetic_ce

from .common import emit

K_Q = 128
N_EVAL_Q = 100
PAYLOAD_TILE = 512
RECALL_SEEDS = (1, 2, 3)


def _median(xs):
    return sorted(xs)[len(xs) // 2]


def _ground_truth_topk(ce, eval_q, n_items: int, k: int, chunk: int = 16):
    """Brute-force top-k ids per eval query, computed in query chunks so the
    (Q, N) exact matrix never materializes at the million-item sizes."""
    item_ids = jnp.arange(n_items)
    fn = jax.jit(lambda q: jax.lax.top_k(ce.score_block(q, item_ids), k)[1])
    out = [fn(eval_q[lo: lo + chunk]) for lo in range(0, eval_q.shape[0], chunk)]
    return jnp.concatenate(out, axis=0)


def bench_size(
    n_items: int,
    batch: int = 256,
    budget: int = 200,
    n_rounds: int = 5,
    reps: int = 7,
) -> dict:
    ce = make_synthetic_ce(
        jax.random.PRNGKey(0), n_queries=K_Q + N_EVAL_Q, n_items=n_items
    )
    r_anc = ce.full_matrix(jnp.arange(K_Q))
    index32 = AnchorIndex.from_r_anc(r_anc, anchor_query_ids=jnp.arange(K_Q))
    index8 = index32.quantize("int8", tile=PAYLOAD_TILE)
    del r_anc
    score_fn = SyntheticScorer(ce)
    eval_q = jnp.arange(K_Q, K_Q + N_EVAL_Q)
    queries = jnp.tile(eval_q, -(-batch // N_EVAL_Q))[:batch]
    key = jax.random.PRNGKey(1)

    base = AdaCURConfig(
        k_anchor=budget // 2, n_rounds=n_rounds, budget_ce=budget,
        strategy="topk", k_retrieve=10, loop_mode="fori", use_fused_topk=True,
    )
    paths = {
        "float32": (index32, base),
        "int8": (index8, replace(base, payload_dtype="int8",
                                 payload_tile=PAYLOAD_TILE)),
    }
    rets = {
        tag: AdaCURRetriever.from_index(idx, score_fn, cfg)
        for tag, (idx, cfg) in paths.items()
    }
    for ret in rets.values():           # compile both executables up front
        jax.block_until_ready(ret.search(queries, key))
        jax.block_until_ready(ret.search(queries, key, n_rounds=1))

    # interleave the two payloads so load drift hits both equally; the
    # marginal adaptive round isolates the per-round payload stream
    samples = {tag: {"full": [], "r1": []} for tag in rets}
    for _ in range(reps):
        for tag, ret in rets.items():
            t0 = time.perf_counter()
            jax.block_until_ready(ret.search(queries, key))
            samples[tag]["full"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(ret.search(queries, key, n_rounds=1))
            samples[tag]["r1"].append(time.perf_counter() - t0)

    per_round, call_ms = {}, {}
    for tag in rets:
        full = _median(samples[tag]["full"]) * 1e3
        r1 = _median(samples[tag]["r1"]) * 1e3
        call_ms[tag] = round(full, 3)
        per_round[tag] = round(max(full - r1, 0.0) / (n_rounds - 1), 3)

    # recall parity on the same seeds: exact-CE-ranked retrieval vs brute
    # force, pooled over RECALL_SEEDS x N_EVAL_Q queries per payload
    gt = _ground_truth_topk(ce, eval_q, n_items, 10)
    recall = {}
    for tag, ret in rets.items():
        r1s, r10s = [], []
        for seed in RECALL_SEEDS:
            res = ret.search(eval_q, jax.random.PRNGKey(seed))
            r1s.append(float(retrieval.topk_recall(res.topk_idx, gt[:, :1], 1)))
            r10s.append(float(retrieval.topk_recall(res.topk_idx, gt, 10)))
        recall[tag] = {
            "@1": round(float(np.mean(r1s)), 4),
            "@10": round(float(np.mean(r10s)), 4),
        }

    bytes32 = int(index32.payload_nbytes)
    bytes8 = int(index8.payload_nbytes)
    entry = {
        "index_bytes": {
            "float32": bytes32,
            "int8": bytes8,
            "ratio": round(bytes8 / bytes32, 4),
        },
        "engine_slab_bytes": engine_slab_bytes(base, batch, n_items, K_Q)["total"],
        "call_ms": call_ms,
        "per_round_ms": {
            **per_round,
            "ratio": round(per_round["int8"] / max(per_round["float32"], 1e-9), 3),
        },
        "recall": recall,
        "recall10_delta": round(
            recall["int8"]["@10"] - recall["float32"]["@10"], 4
        ),
    }
    emit(
        f"quant/N{n_items}", per_round["int8"] * 1e3,
        f"round_ratio={entry['per_round_ms']['ratio']};"
        f"bytes_ratio={entry['index_bytes']['ratio']};"
        f"recall10_delta={entry['recall10_delta']}",
    )
    return entry


def run(
    sizes=(10_000, 100_000),
    batch: int = 256,
    budget: int = 200,
    n_rounds: int = 5,
    json_path: str = "BENCH_quant.json",
):
    snapshot = {
        "batch": batch,
        "budget_ce": budget,
        "n_rounds": n_rounds,
        "k_q": K_Q,
        "payload_tile": PAYLOAD_TILE,
        "recall_seeds": list(RECALL_SEEDS),
        "n_eval_queries": N_EVAL_Q,
        "sizes": {},
    }
    for n in sorted(sizes):
        reps = 5 if n >= 1_000_000 else 7
        snapshot["sizes"][str(n)] = bench_size(
            n, batch=batch, budget=budget, n_rounds=n_rounds, reps=reps
        )
    at = snapshot["sizes"].get("100000")
    if at is not None:
        snapshot["checks_at_100k"] = {
            "index_bytes_ratio_le_0.3": at["index_bytes"]["ratio"] <= 0.3,
            "per_round_ratio_le_0.9": at["per_round_ms"]["ratio"] <= 0.9,
            # delta = int8 - fp32; the payload must not LOSE recall (it
            # currently gains some — see module docstring)
            "recall10_degradation_lt_0.005": at["recall10_delta"] > -0.005,
        }
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(snapshot, fh, indent=2)
        print(f"# wrote {json_path}")
    return snapshot


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="N=10k only")
    ap.add_argument("--ci", action="store_true", help="N in {10k, 100k}")
    ap.add_argument("--full", action="store_true",
                    help="add the 1M-item point (minutes on CPU)")
    ap.add_argument("--json", default="BENCH_quant.json")
    args = ap.parse_args()
    if args.fast:
        sizes = (10_000,)
    elif args.full:
        sizes = (10_000, 100_000, 1_000_000)
    elif args.ci:
        sizes = (10_000, 100_000)       # the CI gate reads sizes["100000"]
    else:
        sizes = (10_000, 100_000)
    run(sizes=sizes, json_path=args.json)

"""Paper Fig. 3: Top-k-Recall of ADACUR_TopK vs number of rounds
(N_r in {1,2,5,10,20}); N_r=1 reduces to ANNCUR (all anchors random)."""

from __future__ import annotations

import jax

from repro.configs.base import AdaCURConfig
from repro.core import retrieval
from repro.core.engine import AdaCURRetriever

from .common import emit, make_domain, timed

ROUNDS = (1, 2, 5, 10, 20)


def run(dom=None, budget: int = 200, quiet: bool = False):
    dom = dom or make_domain()
    score_fn = dom.ce.score_fn()
    out = {}
    for nr in ROUNDS:
        k_anchor = budget // 2
        k_anchor -= k_anchor % nr
        cfg = AdaCURConfig(k_anchor=k_anchor, n_rounds=nr, budget_ce=budget,
                           strategy="topk", k_retrieve=100, loop_mode="fori")
        ret = AdaCURRetriever.from_index(dom.index, score_fn, cfg)
        res, us = timed(lambda: ret.search(dom.test_q, jax.random.PRNGKey(1)))
        rep = retrieval.evaluate_result(f"rounds{nr}", res, dom.exact)
        derived = ";".join(f"recall@{k}={v:.3f}" for k, v in rep.recall.items())
        emit(f"rounds_sweep/Nr{nr}/B{budget}", us, derived)
        out[nr] = rep.recall
    return out


if __name__ == "__main__":
    run()

"""Paper Figs. 1/7/8: CUR approximation error by item rank band, for
ANNCUR (random anchors, 50 vs 200) vs ADACUR (adaptive anchors).

The paper's central observation: random anchors keep AVERAGE error low but
concentrate error exactly on the top-k items; adaptive anchors collapse
top-k error (anchors interpolate exactly) at a modest global-error cost."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import AdaCURConfig
from repro.core.engine import AdaCURRetriever, ANNCURRetriever

from .common import emit, make_domain, timed


def _band_errors(dom, s_hat):
    err = jnp.abs(s_hat - dom.exact)
    order = jnp.argsort(-dom.exact, axis=1)
    bands = {}
    for name, lo, hi in (("top10", 0, 10), ("top100", 10, 100), ("rest", 100, None)):
        idx = order[:, lo:hi]
        bands[name] = float(jnp.take_along_axis(err, idx, axis=1).mean())
    bands["all"] = float(err.mean())
    return bands


def run(dom=None, quiet: bool = False):
    dom = dom or make_domain()
    score_fn = dom.ce.score_fn()
    out = {}
    for k_i in (50, 200):
        idx = dom.index.with_anchors(k_anchor=k_i, key=jax.random.PRNGKey(2))
        ret_a = ANNCURRetriever.from_index(idx, score_fn, k_i, 100)
        res, us = timed(lambda: ret_a.search(dom.test_q))
        bands = _band_errors(dom, res.approx_scores)
        emit(f"approx_error/anncur_k{k_i}", us,
             ";".join(f"{k}={v:.4f}" for k, v in bands.items()))
        out[f"anncur_{k_i}"] = bands

        cfg = AdaCURConfig(k_anchor=k_i, n_rounds=5, budget_ce=k_i,
                           strategy="topk", split_budget=False, k_retrieve=100)
        ret = AdaCURRetriever.from_index(dom.index, score_fn, cfg)
        res, us = timed(lambda: ret.search(dom.test_q, jax.random.PRNGKey(3)))
        bands = _band_errors(dom, res.approx_scores)
        emit(f"approx_error/adacur_k{k_i}", us,
             ";".join(f"{k}={v:.4f}" for k, v in bands.items()))
        out[f"adacur_{k_i}"] = bands
    return out


if __name__ == "__main__":
    run()

"""SPMD engine scaling bench -> BENCH_sharded.json.

Measures the (data x items) `shard_map` engine (``engine.make_sharded_engine``)
against the single-device engine at 1/2/4/8 forced host devices:

- **strong scaling** (fixed N): per-round latency and — the acceptance
  metric — *per-shard device-buffer bytes* (the index payload slab actually
  resident on device 0, plus the engine's per-shard state slabs), which must
  shrink ~linearly in the item-shard count;
- **weak scaling** (fixed N per shard): per-shard bytes stay ~constant while
  the served corpus grows with the mesh;
- **exactness**: the sharded top-k must equal the single-device top-k
  BIT-FOR-BIT (ids and scores) — recall is identical by construction, and
  this bench asserts it on every configuration it runs;
- **device-resident real CE**: pairs/s per shard through the in-mesh
  transformer forward (DeviceCEScorer — the ``--mesh`` + ``real-ce``
  serving path), asserting measured CE calls == ce_call_plan on every
  timed execution.

jax locks the device count at backend init, so the aggregator re-executes
this file as a worker subprocess per device count
(``XLA_FLAGS=--xla_force_host_platform_device_count=<n>``) and merges the
workers' JSON.  Assertions (CI): per-shard payload bytes <= 1.1x the ideal
N/shards split at every device count, and sharded == single-device top-k
exactly everywhere.

Usage:  PYTHONPATH=src python -m benchmarks.sharded_engine [--ci]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

DEVICE_COUNTS = (1, 2, 4, 8)


def _worker(args) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src"))
    from benchmarks.common import timed
    from repro.configs.base import AdaCURConfig
    from repro.core.engine import engine_slab_bytes, make_engine, make_sharded_engine
    from repro.core.index import AnchorIndex
    from repro.data.synthetic import make_synthetic_ce

    n_dev = args.worker
    assert len(jax.devices()) == n_dev, (len(jax.devices()), n_dev)
    mesh = jax.make_mesh((1, n_dev), ("data", "items"))

    def bench_one(n_items: int) -> dict:
        ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=80,
                               n_items=n_items)
        r_anc = ce.full_matrix(jnp.arange(48))
        queries = jnp.arange(48, 48 + args.batch)
        score_fn = ce.score_fn()
        cfg = AdaCURConfig(
            k_anchor=32, n_rounds=args.rounds, budget_ce=64, k_retrieve=32,
            loop_mode="fori", use_fused_topk=True, fused_tile=1024,
        )
        index = AnchorIndex.from_r_anc(r_anc).shard(mesh)

        # actual per-device payload residency (shard 0 of each leaf)
        def shard0_bytes(x):
            return int(x.addressable_shards[0].data.nbytes)

        payload_shard = shard0_bytes(index.r_anc) + shard0_bytes(index.item_ids)
        slabs = engine_slab_bytes(
            cfg, args.batch, index.capacity, index.k_q,
            n_data_shards=1, n_item_shards=n_dev,
        )

        run_s = make_sharded_engine(score_fn, cfg, mesh)
        run_d = make_engine(score_fn, cfg)
        key = jax.random.PRNGKey(7)
        kw = dict(n_valid=index.n_valid, item_ids=index.item_ids)
        res_s, us_full = timed(
            run_s, index.r_anc, queries, key, n_iter=args.iters, warmup=1, **kw
        )
        _, us_r1 = timed(
            run_s, index.r_anc, queries, key, n_rounds=1,
            n_iter=args.iters, warmup=1, **kw,
        )
        res_d = run_d(r_anc, queries, key)

        # the acceptance bit: sharded == dense single-device, exactly
        idx_equal = bool(
            (np.asarray(res_s.topk_idx) == np.asarray(res_d.topk_idx)).all()
        )
        score_equal = bool(
            (np.asarray(res_s.topk_scores) == np.asarray(res_d.topk_scores)).all()
        )
        marginal_ms = (us_full - us_r1) / 1e3 / max(cfg.n_rounds - 1, 1)
        return {
            "n_items": n_items,
            "capacity": index.capacity,
            "payload_bytes_total": int(index.payload_nbytes),
            "payload_bytes_per_shard": payload_shard,
            "engine_slab_bytes_per_shard": slabs["total"],
            "device_buffer_bytes_per_shard": payload_shard + slabs["total"],
            "search_ms": us_full / 1e3,
            "per_round_ms": marginal_ms,
            "topk_idx_equal": idx_equal,
            "topk_scores_equal": score_equal,
        }

    def bench_real_ce() -> dict:
        """Device-resident CE stage: pairs/s through the in-mesh transformer
        forward (the --mesh + real-ce path), with measured == planned
        accounting across every timed execution."""
        from repro.configs.base import replace as cfg_replace
        from repro.configs.registry import CE_TINY
        from repro.core.engine import ce_call_plan
        from repro.core.scorer import DeviceCEScorer
        from repro.data.synthetic import make_zeshel_like
        from repro.models import cross_encoder

        n_items = 128 * n_dev          # one NOISE_BLOCK slab per item shard
        ds = make_zeshel_like(0, n_items=n_items, n_queries=48 + args.batch,
                              item_len=12, query_len=8)
        lm_cfg = cfg_replace(
            CE_TINY, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
            head_dim=16, d_ff=128, vocab_size=ds.vocab_size, dtype="float32",
            remat=False,
        )
        params, _ = cross_encoder.init_cross_encoder(
            jax.random.PRNGKey(0), lm_cfg
        )
        scorer = DeviceCEScorer(
            params, lm_cfg,
            query_token_fn=lambda q: np.asarray(ds.query_tokens)[q],
            item_tokens=ds.item_tokens, attn_impl="ref",
        )
        cfg = AdaCURConfig(k_anchor=16, n_rounds=args.rounds, budget_ce=32,
                           k_retrieve=16, loop_mode="fori")
        r_anc = jax.random.normal(jax.random.PRNGKey(1), (24, n_items))
        q_tok = scorer.tokenize_queries(jnp.arange(48, 48 + args.batch))
        run = make_sharded_engine(scorer, cfg, mesh)
        _, us = timed(run, r_anc, q_tok, jax.random.PRNGKey(7),
                      n_iter=args.iters, warmup=1)
        pairs = ce_call_plan(cfg) * args.batch
        pairs_per_s = pairs / (us / 1e6)
        return {
            "n_items": n_items,
            "pairs_per_search": pairs,
            "pairs_per_s": pairs_per_s,
            "pairs_per_s_per_shard": pairs_per_s / n_dev,
            # every timed execution (warmup included) counted exactly once,
            # item-shard pad rows excluded
            "measured_equals_planned": bool(
                scorer.stats.ce_calls == pairs * (args.iters + 1)
            ),
        }

    out = {
        "n_devices": n_dev,
        "fixed_n": bench_one(args.n_items),
        "weak_scaling": bench_one(args.n_per_shard * n_dev),
        "real_ce": bench_real_ce(),
    }
    print("BENCH_JSON " + json.dumps(out))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", type=int, default=None, help=argparse.SUPPRESS)
    ap.add_argument("--ci", action="store_true", help="small shapes for CI")
    ap.add_argument("--n-items", type=int, default=None,
                    help="fixed corpus size for the strong-scaling sweep")
    ap.add_argument("--n-per-shard", type=int, default=None,
                    help="per-shard corpus size for the weak-scaling sweep")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    if args.n_items is None:
        args.n_items = 16384 if args.ci else 65536
    if args.n_per_shard is None:
        args.n_per_shard = 4096 if args.ci else 16384

    if args.worker is not None:
        _worker(args)
        return

    per_dev = {}
    for n_dev in DEVICE_COUNTS:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        cmd = [sys.executable, "-m", "benchmarks.sharded_engine",
               "--worker", str(n_dev),
               "--n-items", str(args.n_items),
               "--n-per-shard", str(args.n_per_shard),
               "--batch", str(args.batch), "--rounds", str(args.rounds),
               "--iters", str(args.iters)]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        if proc.returncode != 0:
            sys.stderr.write(proc.stdout + "\n" + proc.stderr)
            raise SystemExit(f"worker for {n_dev} devices failed")
        line = [l for l in proc.stdout.splitlines() if l.startswith("BENCH_JSON ")]
        per_dev[str(n_dev)] = json.loads(line[-1][len("BENCH_JSON "):])
        f = per_dev[str(n_dev)]["fixed_n"]
        ce = per_dev[str(n_dev)]["real_ce"]
        print(f"devices={n_dev}: per-shard payload "
              f"{f['payload_bytes_per_shard']/1e6:.2f} MB "
              f"(ideal {f['payload_bytes_total']/n_dev/1e6:.2f}), "
              f"per-round {f['per_round_ms']:.1f} ms, "
              f"exact={f['topk_idx_equal'] and f['topk_scores_equal']}, "
              f"real-CE {ce['pairs_per_s_per_shard']:.0f} pairs/s/shard "
              f"(measured==planned: {ce['measured_equals_planned']})")

    snap = {
        "config": {"n_items": args.n_items, "n_per_shard": args.n_per_shard,
                   "batch": args.batch, "rounds": args.rounds},
        "devices": per_dev,
        "assertions": {},
    }

    # --- assertions: the acceptance criteria ------------------------------
    worst_ratio = 0.0
    all_exact = True
    ce_measured_ok = True
    ce_min_rate = float("inf")
    for n_dev, rec in per_dev.items():
        for sweep in ("fixed_n", "weak_scaling"):
            r = rec[sweep]
            ideal = r["payload_bytes_total"] / int(n_dev)
            worst_ratio = max(worst_ratio, r["payload_bytes_per_shard"] / ideal)
            all_exact = all_exact and r["topk_idx_equal"] and r["topk_scores_equal"]
        ce = rec["real_ce"]
        ce_measured_ok = ce_measured_ok and ce["measured_equals_planned"]
        ce_min_rate = min(ce_min_rate, ce["pairs_per_s_per_shard"])
    snap["assertions"] = {
        "per_shard_payload_over_ideal_max": worst_ratio,
        "sharded_equals_dense_exactly": all_exact,
        "real_ce_measured_equals_planned": ce_measured_ok,
        "real_ce_min_pairs_per_s_per_shard": ce_min_rate,
    }
    with open("BENCH_sharded.json", "w") as f:
        json.dump(snap, f, indent=1)
    print(json.dumps(snap["assertions"], indent=1))
    assert worst_ratio <= 1.1, (
        f"per-shard payload bytes {worst_ratio:.3f}x ideal N/shards split"
    )
    assert all_exact, "sharded engine diverged from the single-device engine"
    assert ce_measured_ok, (
        "device-resident CE measured calls diverged from ce_call_plan"
    )
    assert ce_min_rate > 0, "real-CE throughput not recorded"
    print("wrote BENCH_sharded.json")


if __name__ == "__main__":
    main()

"""Shared benchmark scaffolding: a ZESHEL-like synthetic domain with the
paper's experimental protocol (train/test query split, anchor queries =
train queries, budget-matched CE-call accounting)."""

from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.index import AnchorIndex
from repro.data.synthetic import make_synthetic_ce


@dataclass
class Domain:
    name: str
    ce: object
    index: AnchorIndex      # the offline artifact every retriever consumes
    test_q: jax.Array       # (B,) test query ids
    exact: jax.Array        # (B, N) ground-truth scores for the test split

    @property
    def r_anc(self) -> jax.Array:
        """The index's (k_q, N) score matrix (identity ids, no padding)."""
        return self.index.r_anc


def make_domain(
    name: str = "yugioh-like",
    n_items: int = 10000,
    n_train_q: int = 500,
    n_test_q: int = 100,
    seed: int = 0,
) -> Domain:
    """Mirrors the paper's setup: |I|≈10K (YuGiOh-scale), Q_train=500."""
    ce = make_synthetic_ce(
        jax.random.PRNGKey(seed), n_queries=n_train_q + n_test_q, n_items=n_items
    )
    m = ce.full_matrix(jnp.arange(n_train_q + n_test_q))
    return Domain(
        name=name,
        ce=ce,
        index=AnchorIndex.from_r_anc(
            m[:n_train_q], anchor_query_ids=jnp.arange(n_train_q)
        ),
        test_q=jnp.arange(n_train_q, n_train_q + n_test_q),
        exact=m[n_train_q:],
    )


def timed(fn, *args, n_iter: int = 1, warmup: int = 0, **kw):
    """(result, microseconds/call) with block_until_ready."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n_iter):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    us = (time.perf_counter() - t0) / n_iter * 1e6
    return out, us


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The harness CSV contract: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.1f},{derived}")

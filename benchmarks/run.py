"""Benchmark harness: one module per paper table/figure + kernel micro +
beyond-paper studies.  Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--fast]
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller domain")
    ap.add_argument("--n-items", default=None,
                    help="comma-separated corpus sizes: run ONLY the engine "
                         "scaling sweep (per-round latency + device-buffer "
                         "bytes per size -> BENCH_engine.json)")
    args = ap.parse_args()

    from . import (
        approx_error,
        common,
        epsilon_rounds,
        index_build,
        kernels_micro,
        latency_breakdown,
        oracle_sampling,
        pinv_incremental,
        quantized_engine,
        recall_budget,
        rounds_sweep,
        scorer_throughput,
    )

    if args.n_items:
        latency_breakdown.run_scaling(
            [int(s) for s in args.n_items.split(",")]
        )
        return

    if args.fast:
        dom = common.make_domain(n_items=2000, n_train_q=200, n_test_q=60)
    else:
        dom = common.make_domain()

    print("name,us_per_call,derived")
    suites = [
        ("recall_budget (paper Fig.2/9-20)", lambda: recall_budget.run(dom)),
        ("rounds_sweep (paper Fig.3)", lambda: rounds_sweep.run(dom)),
        ("oracle_sampling (paper Fig.5)", lambda: oracle_sampling.run(dom)),
        ("approx_error (paper Fig.1/7/8)", lambda: approx_error.run(dom)),
        ("latency_breakdown (paper Fig.4)", lambda: latency_breakdown.run(dom)),
        ("pinv_incremental (beyond-paper)", pinv_incremental.run),
        ("epsilon_rounds (beyond-paper)", lambda: epsilon_rounds.run(dom)),
        ("kernels_micro", kernels_micro.run),
        (
            "index_build (offline lifecycle)",
            (lambda: index_build.run(n_items=2000, k_q=64, block_rows=16))
            if args.fast else index_build.run,
        ),
        (
            "scorer_throughput (CE bucketing + score cache)",
            lambda: scorer_throughput.run(fast=args.fast),
        ),
        (
            "quantized_engine (int8 payload vs fp32)",
            lambda: quantized_engine.run(
                sizes=(10_000,) if args.fast else (10_000, 100_000)
            ),
        ),
    ]
    failed = 0
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failed += 1
            print(f"SUITE-FAILED,{name},", file=sys.stderr)
            traceback.print_exc()
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()

"""Scorer subsystem benchmark -> BENCH_scorer.json.

Three claims, measured rather than assumed:

- **real-CE throughput**: pairs/s through the bucketed micro-batching
  CrossEncoderScorer (flash-attention path, interpret-mode Pallas on CPU);
- **zero retraces**: after warmup, sweeping request shapes (batch, k) and
  serving-bucket batch sizes compiles nothing new — the static shape set
  absorbs every call;
- **cache effectiveness**: with the (query, item) score cache, a repeated
  query batch (batch >= 64) re-issues <= 50% of the cold CE calls — the
  acceptance bar; with a pinned trajectory it is exactly 0%.

CLI:  PYTHONPATH=src python -m benchmarks.scorer_throughput [--fast]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdaCURConfig, replace
from repro.configs.registry import CE_TINY
from repro.core import engine
from repro.core.scorer import CachingScorer, CrossEncoderScorer, TabulatedScorer
from repro.data.synthetic import make_synthetic_ce, make_zeshel_like
from repro.models import cross_encoder

from .common import emit, timed


def bench_cross_encoder(fast: bool) -> dict:
    """Bucketed real-CE scoring: throughput + the no-retrace sweep."""
    n_items = 200 if fast else 500
    ds = make_zeshel_like(0, n_items=n_items, n_queries=80, item_len=12,
                          query_len=8)
    lm_cfg = replace(
        CE_TINY, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=ds.vocab_size, dtype="float32", remat=False,
    )
    params, _ = cross_encoder.init_cross_encoder(jax.random.PRNGKey(0), lm_cfg)
    micro = 32 if fast else 64
    sc = CrossEncoderScorer(
        params, lm_cfg, ds.pair_tokens, micro_batch=micro,
        len_buckets=(32, 64), flash_block=(32, 32),
    )

    b, k = (64, 2) if fast else (64, 4)
    rng = np.random.default_rng(0)

    def call(bb, kk):
        q = rng.integers(0, 80, size=bb)
        idx = rng.integers(0, n_items, size=(bb, kk))
        return sc._host(q, idx)

    call(b, k)                                  # warmup: compiles the bucket
    n_warm = sc.n_traces
    _, us = timed(lambda: call(b, k), n_iter=2)
    pairs_per_s = b * k / (us / 1e6)
    emit(f"scorer/cross_encoder/B{b}xK{k}", us,
         f"pairs_per_s={pairs_per_s:.0f};micro_batch={micro}")

    # request-shape sweep: every (B, k) lands in the same compiled shapes
    for bb, kk in ((1, 1), (7, 5), (16, 3), (64, 2), (33, k)):
        call(bb, kk)
    retraces = sc.n_traces - n_warm
    emit("scorer/cross_encoder/shape_sweep_retraces", 0.0,
         f"retraces={retraces};traces_total={sc.n_traces}")
    return {
        "pairs_per_s": pairs_per_s,
        "micro_batch": micro,
        "len_buckets": list(sc.len_buckets),
        "traces_after_warmup": n_warm,
        "retraces_after_shape_sweep": retraces,
        "batch": b,
    }


def bench_cache(fast: bool) -> dict:
    """Cold vs repeat engine searches at serving batch size through the
    (query, item) score cache (tabulated inner model: measures the cache
    machinery, not the CE's FLOPs)."""
    n_items = 2000 if fast else 10000
    batch = 64
    n_q = 500 + batch
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=n_q, n_items=n_items)
    m = np.asarray(ce.full_matrix(jnp.arange(n_q)))
    cache = CachingScorer(TabulatedScorer(m))
    cfg = AdaCURConfig(
        k_anchor=50, n_rounds=5, budget_ce=100, k_retrieve=50, loop_mode="fori"
    )
    run = engine.make_engine(cache, cfg)
    r_anc = jnp.asarray(m[:500])
    q = jnp.arange(500, 500 + batch)
    key = jax.random.PRNGKey(3)

    _, cold_us = timed(lambda: run(r_anc, q, key))
    cold = cache.stats.ce_calls
    _, warm_us = timed(lambda: run(r_anc, q, key))
    repeat = cache.stats.ce_calls - cold
    ratio = repeat / cold if cold else 0.0
    emit(f"scorer/cache/cold_B{batch}", cold_us,
         f"ce_calls={cold};plan={engine.ce_call_plan(cfg) * batch}")
    emit(f"scorer/cache/repeat_B{batch}", warm_us,
         f"ce_calls={repeat};repeat_over_cold={ratio:.3f};"
         f"hits={cache.stats.cache_hits}")
    return {
        "batch": batch,
        "cold_ce_calls": cold,
        "repeat_ce_calls": repeat,
        "repeat_over_cold": ratio,
        "cache_hits": cache.stats.cache_hits,
        "cold_us": cold_us,
        "repeat_us": warm_us,
    }


def bench_tabulated(fast: bool) -> dict:
    n_items = 2000 if fast else 10000
    m = np.random.default_rng(0).normal(size=(256, n_items)).astype(np.float32)
    tab = TabulatedScorer(m)
    q = jnp.arange(64)
    idx = jnp.asarray(
        np.random.default_rng(1).integers(0, n_items, size=(64, 100))
    )
    _, us = timed(lambda: jax.block_until_ready(tab(q, idx)), n_iter=5, warmup=1)
    pairs_per_s = 6400 / (us / 1e6)
    emit("scorer/tabulated/B64xK100", us, f"pairs_per_s={pairs_per_s:.0f}")
    return {"pairs_per_s": pairs_per_s}


def run(fast: bool = False, json_path: str = "BENCH_scorer.json") -> dict:
    out = {
        "cross_encoder": bench_cross_encoder(fast),
        "cache": bench_cache(fast),
        "tabulated": bench_tabulated(fast),
    }
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast)


if __name__ == "__main__":
    main()

"""Budget-matched IR quality matrix -> ``BENCH_quality.json``.

One command, three claims, all measured:

- **quality**: recall@k / MRR@k / NDCG@k (CE-top-1 pseudo-qrels) and the
  paper's Top-k-Recall for every retrieval strategy the repo implements —
  ADACUR, ANNCUR, DE retrieve-and-rerank, and the multi-stage hybrids
  (DE / BM25 shortlist -> candidate-restricted ADACUR) — at the SAME
  exact-CE-call budget.  The CI gate asserts hybrid_de recall@1 >=
  rerank_de recall@1: spending the budget adaptively over a first-stage
  shortlist beats spending it all on one rerank pass;
- **accounting**: every method's CE spend is measured by its own
  TabulatedScorer and must equal the engine plan (budget_matched);
- **subset engine**: the candidate-subset search (gathered sub-index +
  ``pos_map``) is bit-identical to the masked full-corpus search over the
  candidate union, and sweeping *different candidate sets* through one
  HybridRetriever compiles exactly one executable (zero retraces).

CLI:  PYTHONPATH=src python -m benchmarks.quality_matrix [--fast]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdaCURConfig
from repro.core.candidates import (
    DualEncoderCandidates,
    HybridRetriever,
    candidate_eligibility,
)
from repro.core.engine import make_engine
from repro.core.index import AnchorIndex
from repro.core.scorer import TabulatedScorer
from repro.data.synthetic import lexical_signatures, make_synthetic_ce
from repro.eval.harness import quality_matrix

from .common import emit, timed


def bench_matrix(fast: bool, seed: int = 0) -> dict:
    n_items = 2000 if fast else 10000
    n_train, n_test = (200, 60) if fast else (500, 100)
    budget = 100 if fast else 200
    ce = make_synthetic_ce(
        jax.random.PRNGKey(seed), n_queries=n_train + n_test, n_items=n_items
    )
    m = np.asarray(ce.full_matrix(jnp.arange(n_train + n_test)))
    index = AnchorIndex.from_r_anc(
        m[:n_train], anchor_query_ids=jnp.arange(n_train)
    )
    test_q = jnp.arange(n_train, n_train + n_test)
    sig_seed = seed + 3
    reports = quality_matrix(
        ce, index, test_q, m, budget=budget, ks=(1, 10, 100),
        corpus_tokens=lexical_signatures(ce.i_emb, seed=sig_seed),
        query_tokens=lexical_signatures(ce.q_emb, seed=sig_seed),
        seed=seed,
    )
    for r in reports:
        emit(
            f"quality_matrix/{r.method}/B{budget}", r.wall_us_per_query,
            f"recall@1={r.ir['recall@1']:.3f};ndcg@10={r.ir['ndcg@10']:.3f};"
            f"topk_recall@100={r.topk_recall[100]:.3f};"
            f"measured={r.measured_ce};planned={r.planned_ce}",
        )
    return {
        "budget": budget,
        "n_items": n_items,
        "n_test": n_test,
        "methods": {r.method: r.to_json() for r in reports},
    }


def bench_subset_engine(fast: bool, seed: int = 0) -> dict:
    """Subset-vs-masked bit-parity + the zero-retrace sweep."""
    n_items = 1024 if fast else 4096
    n_q = 96
    batch = 8
    ce = make_synthetic_ce(
        jax.random.PRNGKey(seed + 10), n_queries=n_q, n_items=n_items
    )
    m = np.asarray(ce.full_matrix(jnp.arange(n_q)))
    k_q = 64
    r_anc = jnp.asarray(m[:k_q])
    cfg = AdaCURConfig(
        k_anchor=20, n_rounds=4, budget_ce=60, k_retrieve=20,
        strategy="topk", loop_mode="fori",
    )
    de = DualEncoderCandidates(ce.q_emb, ce.i_emb)
    scorer = TabulatedScorer(m)
    hyb = HybridRetriever(
        score_fn=scorer, generator=de, cfg=cfg, r_anc=r_anc,
        shortlist_k=96, mode="subset",
    )
    key = jax.random.PRNGKey(seed + 11)
    qids = jnp.arange(batch)
    res_sub, us_sub = timed(lambda: hyb.search(qids, key), warmup=1)

    # masked full-corpus reference: same engine config, candidate-union mask
    elig = candidate_eligibility(de(qids, 96), n_items, per_query=False)
    run = make_engine(TabulatedScorer(m), cfg)
    res_mask, us_mask = timed(
        lambda: run(hyb.r_anc, qids, key, eligible=elig), warmup=1
    )
    parity = bool(
        np.array_equal(np.asarray(res_sub.topk_idx), np.asarray(res_mask.topk_idx))
        and np.array_equal(
            np.asarray(res_sub.topk_scores), np.asarray(res_mask.topk_scores)
        )
    )

    # zero retraces across DIFFERENT candidate sets (query batches)
    traces = lambda: getattr(hyb._run, "_cache_size", lambda: -1)()
    warm = traces()
    for lo in range(0, n_q - batch, batch):
        jax.block_until_ready(
            hyb.search(jnp.arange(lo, lo + batch), jax.random.PRNGKey(lo))
        )
    retraces = traces() - warm

    jax.effects_barrier()
    before = scorer.stats.copy()
    jax.block_until_ready(hyb.search(qids, jax.random.PRNGKey(99)))
    jax.effects_barrier()
    measured = (scorer.stats - before).ce_calls // batch

    emit("quality_matrix/subset_engine", us_sub,
         f"parity={parity};retraces={retraces};measured={measured};"
         f"planned={hyb.ce_call_plan()};mask_us={us_mask:.0f}")
    return {
        "parity_vs_masked": parity,
        "retraces_across_candidate_sets": retraces,
        "measured_ce": measured,
        "planned_ce": hyb.ce_call_plan(),
        "subset_us_per_batch": us_sub,
        "masked_us_per_batch": us_mask,
    }


def run(fast: bool = False, json_path: str = "BENCH_quality.json") -> dict:
    out = {
        "matrix": bench_matrix(fast),
        "subset_engine": bench_subset_engine(fast),
    }
    with open(json_path, "w") as f:
        json.dump(out, f, indent=1)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default="BENCH_quality.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(fast=args.fast, json_path=args.json)


if __name__ == "__main__":
    main()

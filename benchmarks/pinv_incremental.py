"""Beyond-paper: incremental block pseudo-inverse vs full SVD recompute.

The paper recomputes pinv(R_anc[:, I_anc]) from scratch each round —
O(k_q·k_i²) and their Fig. 4 shows it dominating non-CE latency at high
round counts.  The bordering update is O(k_q·k_i·k_s) per round; this
benchmark measures speedup and max deviation across round counts."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import cur

from .common import emit, timed


def run(quiet: bool = False):
    key = jax.random.PRNGKey(0)
    out = {}
    for (k_q, k_i, n_rounds) in ((500, 200, 5), (500, 200, 20), (2000, 500, 10)):
        k_s = k_i // n_rounds
        a = jax.random.normal(key, (k_q, k_i))

        @jax.jit
        def full_rounds(a):
            ps = []
            for r in range(1, n_rounds + 1):
                ps.append(cur.pinv(a[:, : r * k_s]))
            return ps[-1]

        @jax.jit
        def inc_rounds(a):
            p = cur.incremental_pinv_init(a[:, :k_s])
            for r in range(1, n_rounds):
                p = cur.block_pinv_extend(
                    a[:, : r * k_s], p, a[:, r * k_s : (r + 1) * k_s]
                )
            return p

        # warmup=1: exclude trace+compile — the paper-relevant number is the
        # steady-state per-search cost
        p_full, us_full = timed(full_rounds, a, warmup=1)
        p_inc, us_inc = timed(inc_rounds, a, warmup=1)
        err = float(jnp.abs(p_full - p_inc).max())
        emit(
            f"pinv/kq{k_q}_ki{k_i}_Nr{n_rounds}", us_inc,
            f"full_us={us_full:.0f};speedup={us_full / us_inc:.2f}x;max_err={err:.1e}",
        )
        out[(k_q, k_i, n_rounds)] = (us_full, us_inc, err)
    return out


if __name__ == "__main__":
    run()

"""Kernel microbenchmarks: Pallas (interpret) vs jnp oracle on CPU.

Interpret-mode wall times are NOT TPU times — the derived column carries
the analytic HBM-traffic reduction each kernel buys on the TPU target,
which is what the roofline credits them for."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.approx_topk import quant
from repro.kernels.approx_topk.ops import approx_topk_op
from repro.kernels.approx_topk.persistent import persistent_round_op
from repro.kernels.approx_topk.ref import approx_topk_reference
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.embedding_bag.ref import embedding_bag_reference
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_reference

from .common import emit, timed


def run(quiet: bool = False):
    key = jax.random.PRNGKey(0)

    # flash attention: traffic reduction = O(L²) probs never hit HBM
    b, l, h, kv, hd = 1, 512, 4, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, l, h, hd))
    k = jax.random.normal(ks[1], (b, l, kv, hd))
    v = jax.random.normal(ks[2], (b, l, kv, hd))
    _, us_ref = timed(lambda: attention_reference(q, k, v, causal=True), warmup=1)
    _, us_pal = timed(lambda: flash_attention(q, k, v, causal=True, interpret=True), warmup=1)
    probs_bytes = b * h * l * l * 4
    io_bytes = (q.size + 2 * k.size + q.size) * 4
    emit("kernels/flash_attention_L512", us_pal,
         f"ref_us={us_ref:.0f};hbm_traffic_saved={probs_bytes / io_bytes:.1f}x_io")

    # approx_topk: traffic reduction = (B,N) scores never hit HBM
    bq, kq, n, kk = 8, 500, 100_000, 64
    e_q = jax.random.normal(ks[0], (bq, kq))
    r = jax.random.normal(ks[1], (kq, n))
    anchors = jnp.full((bq, 8), -1, jnp.int32)
    _, us_ref = timed(lambda: approx_topk_reference(e_q, r, anchors, kk), warmup=1)
    _, us_pal = timed(lambda: approx_topk_op(e_q, r, anchors, kk, tile=4096, interpret=True), warmup=1)
    scores_bytes = 2 * bq * n * 4                      # write + read back
    out_bytes = bq * (n // 4096) * kk * 8
    emit("kernels/approx_topk_N100k", us_pal,
         f"ref_us={us_ref:.0f};hbm_roundtrip_saved={scores_bytes / out_bytes:.1f}x")

    # persistent round: sample + provisional-monitor lists in ONE payload
    # sweep, per payload dtype.  Staged cost = two approx_topk sweeps (the
    # monitored-loop shape); traffic reduction = the second payload pass
    mask = jnp.zeros((bq, n), bool)
    for dt in ("float32", "int8", "int4") + (("fp8",) if quant.fp8_supported() else ()):
        payload = r if dt == "float32" else quant.quantize_ranc(r, 4096, code_dtype=dt)
        noise = jax.random.gumbel(ks[2], (bq, n))

        def staged():
            s = approx_topk_op(e_q, payload, anchors, kk, tile=4096,
                               interpret=True, noise=noise)
            p = approx_topk_op(e_q, payload, None, kk, tile=4096,
                               interpret=True, mask=mask)
            return s, p

        _, us_staged = timed(staged, warmup=1)
        _, us_per = timed(
            lambda: persistent_round_op(
                e_q, payload, k_sample=kk, k_prov=kk, anchors=anchors,
                noise=noise, prov_mask=mask, tile=4096, interpret=True,
            ),
            warmup=1,
        )
        pass_bytes = payload.nbytes
        emit(f"kernels/persistent_round_N100k_{dt}", us_per,
             f"staged2pass_us={us_staged:.0f};payload_pass_saved="
             f"{pass_bytes / 1e6:.1f}MB")

    # embedding bag: gathered rows never hit HBM
    rows, dim, bb, hh = 100_000, 128, 256, 8
    table = jax.random.normal(ks[2], (rows, dim))
    ids = jax.random.randint(ks[0], (bb, hh), 0, rows)
    _, us_ref = timed(lambda: embedding_bag_reference(table, ids), warmup=1)
    _, us_pal = timed(lambda: embedding_bag_op(table, ids, interpret=True), warmup=1)
    emit("kernels/embedding_bag_B256xH8", us_pal,
         f"ref_us={us_ref:.0f};gathered_rows_saved={hh}x_bag_width")
    return True


if __name__ == "__main__":
    run()

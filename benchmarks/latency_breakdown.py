"""Paper Fig. 4: inference latency vs number of rounds, broken into the
three stages — (1) exact CE scoring of anchors, (2) pseudo-inverse,
(3) approximate-score matmul — for both full-pinv (the paper's) and the
incremental-pinv (beyond-paper) variants; plus the static-shape engine
comparison (dense vs fused score->top-k sampling), which writes a
``BENCH_engine.json`` snapshot with compile time, per-round latency and a
jaxpr-verified count of (B, N) float intermediates per adaptive round."""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import AdaCURConfig, replace
from repro.core import cur, sampling
from repro.core.engine import (
    AdaCURRetriever,
    engine_slab_bytes,
    round_body_bn_intermediates,
)

from .common import emit, make_domain


def _run_staged(dom, budget: int, n_rounds: int, incremental: bool, n_iter: int = 1):
    """Instrumented re-implementation of the round loop with per-stage timers."""
    score_fn = dom.ce.score_fn()
    k_s = budget // n_rounds
    t_ce = t_pinv = t_mm = 0.0
    key = jax.random.PRNGKey(0)

    for _ in range(n_iter):
        b = dom.test_q.shape[0]
        n = dom.r_anc.shape[1]
        selected = jnp.zeros((b, n), bool)
        rows = jnp.arange(b)[:, None]
        anchor_idx = c_test = a_buf = p = e_q = None
        keys = jax.random.split(key, n_rounds)
        for r in range(n_rounds):
            if r == 0:
                idx_new = sampling.sample_random(keys[r], selected, k_s)
            else:
                t0 = time.perf_counter()
                s_hat = jax.block_until_ready(e_q @ dom.r_anc)
                t_mm += time.perf_counter() - t0
                idx_new = sampling.sample_topk(s_hat, selected, k_s)
            selected = selected.at[rows, idx_new].set(True)

            t0 = time.perf_counter()
            c_new = jax.block_until_ready(score_fn(dom.test_q, idx_new))
            t_ce += time.perf_counter() - t0

            cols_new = cur.gather_anchor_columns(dom.r_anc, idx_new)
            if anchor_idx is None:
                anchor_idx, c_test, a_buf = idx_new, c_new, cols_new
            else:
                anchor_idx = jnp.concatenate([anchor_idx, idx_new], 1)
                c_test = jnp.concatenate([c_test, c_new], 1)
                a_buf = jnp.concatenate([a_buf, cols_new], 2)

            t0 = time.perf_counter()
            if incremental:
                if p is None:
                    p = cur.incremental_pinv_init(a_buf)
                else:
                    p = jax.vmap(cur.block_pinv_extend)(
                        a_buf[..., : r * k_s], p, cols_new
                    )
            else:
                p = cur.pinv(a_buf, 1e-4)
            e_q = jnp.einsum("bk,bkq->bq", c_test, p)
            jax.block_until_ready(e_q)
            t_pinv += time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(e_q @ dom.r_anc)
        t_mm += time.perf_counter() - t0
    scale = 1e6 / n_iter
    return t_ce * scale, t_pinv * scale, t_mm * scale


def run(dom=None, budget: int = 200, quiet: bool = False):
    dom = dom or make_domain()
    out = {}
    for n_rounds in (1, 2, 5, 10, 20):
        for inc in (False, True):
            ce_us, pinv_us, mm_us = _run_staged(dom, budget, n_rounds, inc)
            total = ce_us + pinv_us + mm_us
            tag = "inc" if inc else "full"
            emit(
                f"latency/Nr{n_rounds}/{tag}", total,
                f"ce_us={ce_us:.0f};pinv_us={pinv_us:.0f};matmul_us={mm_us:.0f};"
                f"frac_pinv={pinv_us / total:.2f}",
            )
            out[(n_rounds, tag)] = (ce_us, pinv_us, mm_us)
    return out


def run_engine(
    dom=None,
    budget: int = 200,
    n_rounds: int = 5,
    batch: int = 256,
    json_path: str = "BENCH_engine.json",
    quiet: bool = False,
):
    """Static-shape engine: dense vs fused sampling at N=10k.

    For each path reports jit compile time, steady-state per-call and
    per-round latency, and the jaxpr-inspected number of (B, N) float
    intermediates in one adaptive round body (fused must be 0 — the score
    matrix never exists).  Snapshot lands in ``BENCH_engine.json``.

    ``batch`` defaults to a serving-sized 256: the fused path trades the
    (B, N) score-matrix traffic for streaming R_anc tiles, so its advantage
    on CPU appears once B is at least ~k_q (below that, the per-round R_anc
    tile copies outweigh the never-materialized scores; on the TPU kernel
    the tiles stream through VMEM and that copy never exists).
    """
    if n_rounds < 2:
        raise ValueError("marginal-round isolation needs n_rounds >= 2")
    dom = dom or make_domain()
    score_fn = dom.ce.score_fn()
    key = jax.random.PRNGKey(1)
    n_test = int(dom.test_q.shape[0])
    queries = jnp.tile(dom.test_q, -(-batch // n_test))[:batch]
    base = AdaCURConfig(
        k_anchor=budget // 2, n_rounds=n_rounds, budget_ce=budget,
        strategy="topk", k_retrieve=100, loop_mode="fori",
    )
    snapshot = {
        "n_items": int(dom.r_anc.shape[1]),
        "batch": batch,
        "budget_ce": budget,
        "n_rounds": n_rounds,
        # the engine's whole device working set: the index payload it
        # streams plus its preallocated per-search state slabs — tracked so
        # the memory story scales alongside the latency one
        "device_bytes": {
            "index_payload": int(dom.index.payload_nbytes),
            "index_payload_dtype": dom.index.payload_dtype,
            "engine_slabs": engine_slab_bytes(
                base, batch, int(dom.r_anc.shape[1]), int(dom.r_anc.shape[0])
            ),
        },
        "paths": {},
    }
    paths = {"dense": base, "fused": replace(base, use_fused_topk=True)}
    rets, compile_s = {}, {}
    for tag, cfg in paths.items():
        rets[tag] = AdaCURRetriever.from_index(dom.index, score_fn, cfg)
        t0 = time.perf_counter()
        jax.block_until_ready(rets[tag].search(queries, key))
        compile_s[tag] = time.perf_counter() - t0
    # Interleave the two paths so background load drift hits both equally;
    # medians are the serving-latency statistic under ambient load.  The
    # per-round cost is the MARGINAL adaptive round, isolated with the
    # engine's runtime round count — (t[n_rounds] - t[1]) / (n_rounds - 1)
    # strips round 0, the rerank and the retrieval tail, which are shared
    # by both paths (and needs no recompile: one executable serves both).
    jax.block_until_ready(rets["dense"].search(queries, key, n_rounds=1))
    jax.block_until_ready(rets["fused"].search(queries, key, n_rounds=1))
    samples = {tag: {"full": [], "r1": []} for tag in paths}
    for _ in range(7):
        for tag, ret in rets.items():
            t0 = time.perf_counter()
            jax.block_until_ready(ret.search(queries, key))
            samples[tag]["full"].append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            jax.block_until_ready(ret.search(queries, key, n_rounds=1))
            samples[tag]["r1"].append(time.perf_counter() - t0)

    def med(xs):
        return sorted(xs)[len(xs) // 2]

    for tag, cfg in paths.items():
        us = med(samples[tag]["full"]) * 1e6
        us_r1 = med(samples[tag]["r1"]) * 1e6
        bn = round_body_bn_intermediates(score_fn, dom.r_anc, queries, cfg)
        per_round_ms = max(us - us_r1, 0.0) / 1e3 / (n_rounds - 1)
        snapshot["paths"][tag] = {
            "compile_s": round(compile_s[tag], 4),
            "call_ms": round(us / 1e3, 3),
            "one_round_call_ms": round(us_r1 / 1e3, 3),
            "per_round_ms": round(per_round_ms, 3),
            "bn_float_intermediates_per_round": bn,
        }
        emit(
            f"engine/{tag}/Nr{n_rounds}", us,
            f"compile_s={compile_s[tag]:.2f};per_round_ms={per_round_ms:.2f};"
            f"bn_intermediates={bn}",
        )
    d, f = snapshot["paths"]["dense"], snapshot["paths"]["fused"]
    snapshot["fused_materializes_bn"] = f["bn_float_intermediates_per_round"] > 0
    snapshot["fused_vs_dense_round_ratio"] = round(
        f["per_round_ms"] / max(d["per_round_ms"], 1e-9), 3
    )
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(snapshot, fh, indent=2)
        if not quiet:
            print(f"# wrote {json_path}")
    return snapshot


def run_scaling(
    n_items_list,
    budget: int = 200,
    n_rounds: int = 5,
    batch: int = 256,
    json_path: str = "BENCH_engine.json",
):
    """``--n-items`` scaling sweep: the engine bench at each corpus size,
    recording per-round latency AND device-buffer bytes (index payload +
    engine slabs) per point — the memory axis of the scaling story.

    The base snapshot (smallest N) keeps the standard BENCH_engine.json
    schema; the remaining sizes land under ``"sweep"``.
    """
    sizes = sorted(int(n) for n in n_items_list)
    base_snap = None
    sweep = {}
    for n in sizes:
        dom = make_domain(n_items=n)
        snap = run_engine(
            dom, budget=budget, n_rounds=n_rounds, batch=batch, json_path=None
        )
        sweep[str(n)] = {
            "per_round_ms": {
                tag: snap["paths"][tag]["per_round_ms"] for tag in snap["paths"]
            },
            "device_bytes": snap["device_bytes"],
        }
        if base_snap is None:
            base_snap = snap
    base_snap["sweep"] = sweep
    if json_path:
        with open(json_path, "w") as fh:
            json.dump(base_snap, fh, indent=2)
        print(f"# wrote {json_path} ({len(sizes)}-point scaling sweep)")
    return base_snap


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine-only", action="store_true",
                    help="skip the Fig. 4 staged sweep, run only the engine bench")
    ap.add_argument("--n-items", default=None,
                    help="comma-separated corpus sizes: run the engine "
                         "scaling sweep instead (e.g. 10000,30000,100000)")
    ap.add_argument("--json", default="BENCH_engine.json")
    args = ap.parse_args()
    if args.n_items:
        run_scaling([int(s) for s in args.n_items.split(",")],
                    json_path=args.json)
    else:
        dom = make_domain()
        if not args.engine_only:
            run(dom)
        run_engine(dom, json_path=args.json)

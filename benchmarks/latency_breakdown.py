"""Paper Fig. 4: inference latency vs number of rounds, broken into the
three stages — (1) exact CE scoring of anchors, (2) pseudo-inverse,
(3) approximate-score matmul — for both full-pinv (the paper's) and the
incremental-pinv (beyond-paper) variants."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import cur, sampling

from .common import emit, make_domain


def _run_staged(dom, budget: int, n_rounds: int, incremental: bool, n_iter: int = 1):
    """Instrumented re-implementation of the round loop with per-stage timers."""
    score_fn = dom.ce.score_fn()
    k_s = budget // n_rounds
    t_ce = t_pinv = t_mm = 0.0
    key = jax.random.PRNGKey(0)

    for _ in range(n_iter):
        b = dom.test_q.shape[0]
        n = dom.r_anc.shape[1]
        selected = jnp.zeros((b, n), bool)
        rows = jnp.arange(b)[:, None]
        anchor_idx = c_test = a_buf = p = e_q = None
        keys = jax.random.split(key, n_rounds)
        for r in range(n_rounds):
            if r == 0:
                idx_new = sampling.sample_random(keys[r], selected, k_s)
            else:
                t0 = time.perf_counter()
                s_hat = jax.block_until_ready(e_q @ dom.r_anc)
                t_mm += time.perf_counter() - t0
                idx_new = sampling.sample_topk(s_hat, selected, k_s)
            selected = selected.at[rows, idx_new].set(True)

            t0 = time.perf_counter()
            c_new = jax.block_until_ready(score_fn(dom.test_q, idx_new))
            t_ce += time.perf_counter() - t0

            cols_new = cur.gather_anchor_columns(dom.r_anc, idx_new)
            if anchor_idx is None:
                anchor_idx, c_test, a_buf = idx_new, c_new, cols_new
            else:
                anchor_idx = jnp.concatenate([anchor_idx, idx_new], 1)
                c_test = jnp.concatenate([c_test, c_new], 1)
                a_buf = jnp.concatenate([a_buf, cols_new], 2)

            t0 = time.perf_counter()
            if incremental:
                if p is None:
                    p = cur.incremental_pinv_init(a_buf)
                else:
                    p = jax.vmap(cur.block_pinv_extend)(
                        a_buf[..., : r * k_s], p, cols_new
                    )
            else:
                p = cur.pinv(a_buf, 1e-4)
            e_q = jnp.einsum("bk,bkq->bq", c_test, p)
            jax.block_until_ready(e_q)
            t_pinv += time.perf_counter() - t0
        t0 = time.perf_counter()
        jax.block_until_ready(e_q @ dom.r_anc)
        t_mm += time.perf_counter() - t0
    scale = 1e6 / n_iter
    return t_ce * scale, t_pinv * scale, t_mm * scale


def run(dom=None, budget: int = 200, quiet: bool = False):
    dom = dom or make_domain()
    out = {}
    for n_rounds in (1, 2, 5, 10, 20):
        for inc in (False, True):
            ce_us, pinv_us, mm_us = _run_staged(dom, budget, n_rounds, inc)
            total = ce_us + pinv_us + mm_us
            tag = "inc" if inc else "full"
            emit(
                f"latency/Nr{n_rounds}/{tag}", total,
                f"ce_us={ce_us:.0f};pinv_us={pinv_us:.0f};matmul_us={mm_us:.0f};"
                f"frac_pinv={pinv_us / total:.2f}",
            )
            out[(n_rounds, tag)] = (ce_us, pinv_us, mm_us)
    return out


if __name__ == "__main__":
    run()

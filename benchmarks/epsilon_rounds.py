"""Beyond-paper: ε-greedy adaptive rounds.

The paper's §3.2 oracle study shows TopK anchor selection needs score
DIVERSITY (their ε-random oracle mix); their actual algorithm only gets it
implicitly from round-1 randomness + approximation error.  We make the mix
explicit: each adaptive round samples (1-ε)·k_s by TopK and ε·k_s uniformly
at random.  ε=0 is the paper's algorithm."""

from __future__ import annotations

import jax

from repro.configs.base import AdaCURConfig
from repro.core import retrieval
from repro.core.engine import AdaCURRetriever

from .common import emit, make_domain, timed

EPS = (0.0, 0.125, 0.25, 0.5)


def run(dom=None, budget: int = 200, quiet: bool = False):
    dom = dom or make_domain()
    score_fn = dom.ce.score_fn()
    out = {}
    for eps in EPS:
        cfg = AdaCURConfig(
            k_anchor=budget // 2, n_rounds=5, budget_ce=budget,
            strategy="topk", k_retrieve=100, round_epsilon=eps,
            loop_mode="fori",
        )
        ret = AdaCURRetriever.from_index(dom.index, score_fn, cfg)
        res, us = timed(lambda: ret.search(dom.test_q, jax.random.PRNGKey(1)))
        rep = retrieval.evaluate_result(f"eps{eps}", res, dom.exact)
        derived = ";".join(f"recall@{k}={v:.3f}" for k, v in rep.recall.items())
        emit(f"epsilon_rounds/eps{eps}/B{budget}", us, derived)
        out[eps] = rep.recall
    return out


if __name__ == "__main__":
    run()

"""AnchorIndex lifecycle benchmark -> ``BENCH_index.json``.

Measures the offline side of the system end to end:

- **build**: block-streamed R_anc scoring throughput (scores/s) through the
  resumable builder, plus the warm-resume time (pure block reload — what a
  preempted pod-scale job pays on restart);
- **latents / save / load**: ANNCUR precompute and persistence round-trip
  on the Checkpointer machinery, with a bit-parity check of
  save -> load -> topk against the in-memory index;
- **mutate**: add_items/remove_items wall time (capacity-padded, no
  retrace);
- **sharded-search parity**: ``shard(mesh)`` over all local devices must
  produce the identical top-k to the unsharded index (shard_map fused
  per-shard top-k + cross-shard merge).

    PYTHONPATH=src python -m benchmarks.index_build [--fast]
"""

from __future__ import annotations

import argparse
import json
import shutil
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.index import AnchorIndex

from .common import emit


def _timer():
    t0 = time.perf_counter()
    return lambda: time.perf_counter() - t0


def run(
    n_items: int = 10000,
    k_q: int = 200,
    block_rows: int = 64,
    capacity_headroom: int = 256,
    json_path: str = "BENCH_index.json",
    quiet: bool = False,
):
    from repro.data.synthetic import make_synthetic_ce

    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=k_q, n_items=n_items + capacity_headroom)
    capacity = n_items + capacity_headroom
    work = tempfile.mkdtemp(prefix="bench_index_")
    ck_dir, save_dir = f"{work}/build_ckpt", f"{work}/saved"
    snapshot = {"n_items": n_items, "k_q": k_q, "block_rows": block_rows,
                "capacity": capacity}
    try:
        # -- build (cold) + resume (warm) -----------------------------------
        t = _timer()
        index = AnchorIndex.build(
            ce.score_block, jnp.arange(k_q), jnp.arange(n_items),
            block_rows=block_rows, checkpoint_dir=ck_dir, capacity=capacity,
        )
        build_s = t()
        t = _timer()
        AnchorIndex.build(
            ce.score_block, jnp.arange(k_q), jnp.arange(n_items),
            block_rows=block_rows, checkpoint_dir=ck_dir, capacity=capacity,
        )
        resume_s = t()
        scores_per_s = k_q * n_items / build_s
        snapshot["build"] = {
            "build_s": round(build_s, 3),
            "resume_s": round(resume_s, 3),
            "scores_per_s": round(scores_per_s, 1),
        }
        emit("index_build/build", build_s * 1e6,
             f"scores_per_s={scores_per_s:.0f};resume_s={resume_s:.3f}")

        # -- latents + save/load round trip ---------------------------------
        t = _timer()
        index = index.with_latents(k_anchor=100, key=jax.random.PRNGKey(2))
        latents_s = t()
        t = _timer()
        index.save(save_dir)
        save_s = t()
        t = _timer()
        loaded = AnchorIndex.load(save_dir)
        load_s = t()
        e_q = jax.random.normal(jax.random.PRNGKey(3), (8, k_q))
        v0, i0 = jax.block_until_ready(index.topk(e_q, 100))
        v1, i1 = jax.block_until_ready(loaded.topk(e_q, 100))
        save_load_parity = bool(
            (np.asarray(i0) == np.asarray(i1)).all()
            and np.allclose(np.asarray(v0), np.asarray(v1))
        )
        snapshot["persistence"] = {
            "latents_s": round(latents_s, 3),
            "save_s": round(save_s, 3),
            "load_s": round(load_s, 3),
            "save_load_parity": save_load_parity,
        }
        emit("index_build/save", save_s * 1e6, f"load_s={load_s:.3f};parity={save_load_parity}")

        # -- mutation (padded capacity, no reshape) --------------------------
        new_ids = jnp.arange(n_items, n_items + capacity_headroom // 2)
        t = _timer()
        grown = index.add_items(new_ids, bulk_score_fn=ce.score_block)
        jax.block_until_ready(grown.r_anc)
        add_s = t()
        # removable = any valid items that are not ANNCUR anchors
        anchor_ids = np.asarray(grown.gather_item_ids(grown.anchor_item_pos))
        removable = np.setdiff1d(np.arange(n_items), anchor_ids)[:64]
        t = _timer()
        shrunk = grown.remove_items(jnp.asarray(removable))
        jax.block_until_ready(shrunk.r_anc)
        remove_s = t()
        snapshot["mutation"] = {
            "add_items_s": round(add_s, 4),
            "remove_items_s": round(remove_s, 4),
            "added": int(new_ids.shape[0]),
            "removed": 64,
        }
        emit("index_build/mutate", (add_s + remove_s) * 1e6,
             f"add_s={add_s:.4f};remove_s={remove_s:.4f}")

        # -- sharded-search parity over all local devices --------------------
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        sharded = index.shard(mesh)
        t = _timer()
        vs, is_ = jax.block_until_ready(sharded.topk(e_q, 100))
        shard_topk_s = t()
        cap = sharded.capacity
        # account for shard()'s divisibility re-pad: compare vs the same capacity
        ref = index if cap == index.capacity else index.with_capacity(cap)
        vr, ir = jax.block_until_ready(ref.topk(e_q, 100))
        sharded_parity = bool(
            (np.asarray(is_) == np.asarray(ir)).all()
            and np.allclose(np.asarray(vs), np.asarray(vr), rtol=1e-5, atol=1e-6)
        )
        snapshot["sharded"] = {
            "n_devices": jax.device_count(),
            "topk_s": round(shard_topk_s, 4),
            "sharded_search_parity": sharded_parity,
        }
        emit("index_build/sharded_topk", shard_topk_s * 1e6,
             f"devices={jax.device_count()};parity={sharded_parity}")
    finally:
        shutil.rmtree(work, ignore_errors=True)

    if json_path:
        with open(json_path, "w") as fh:
            json.dump(snapshot, fh, indent=2)
        if not quiet:
            print(f"# wrote {json_path}")
    return snapshot


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="smaller domain")
    ap.add_argument("--json", default="BENCH_index.json")
    args = ap.parse_args()
    if args.fast:
        run(n_items=2000, k_q=64, block_rows=16, json_path=args.json)
    else:
        run(json_path=args.json)

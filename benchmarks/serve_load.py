"""Serving-tier load benchmark under injected faults -> BENCH_serve.json.

Drives the replica router (``launch.router``) with Poisson arrivals from a
simulated user population, with and without deterministic faults
(``launch.faults.FaultPlan``), and records p50/p99 latency, QPS, and the
shed/degraded/retry/hedge/quarantine rates per scenario:

- **baseline**            — fault-free: the latency/QPS reference.
- **scorer_fault**        — replica 0's scorer raises on every callback:
                            error-quarantined, traffic retried to peers.
- **slow_replica**        — replica 0 stalls every batch: hedged re-dispatch
                            + straggler-watchdog quarantine keep tail
                            latency near fault-free.
- **swap_midflight**      — the live index is swapped (new external-id
                            namespace) while requests are in flight.
- **deadline_degraded**   — per-request budgets expire mid-search: the
                            anytime engine returns provisional top-k from
                            completed rounds, flagged degraded.

CI gates (asserted here AND against the JSON artifact in the workflow):

1. **no lost requests** under every scenario: each submitted request ends
   in exactly one terminal outcome (ok / degraded ok / error / rejected).
2. **hedging bounds the tail**: slow-replica p99 <= 2x fault-free p99
   (with a small absolute floor absorbing CI timer noise).
3. **degraded answers are prefix-consistent**: every degraded response
   equals bit-for-bit the answer of an explicit ``n_rounds =
   rounds_completed`` run with the same key — degradation truncates the
   search trajectory, it never invents a different one.

Usage:  PYTHONPATH=src python -m benchmarks.serve_load [--quick]
"""

from __future__ import annotations

import argparse
import json
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AdaCURConfig
from repro.core.engine import AdaCURRetriever, ce_call_plan
from repro.core.index import AnchorIndex
from repro.core.scorer import TabulatedScorer
from repro.launch.faults import (
    FaultPlan,
    FaultyScorer,
    ScorerFault,
    SleepFault,
    SwapFault,
)
from repro.launch.router import Router
from repro.launch.serve import AdaCURService, RetrievalRequest

N_QUERIES = 200
CFG = AdaCURConfig(
    k_anchor=8, n_rounds=4, budget_ce=24, k_retrieve=10, loop_mode="fori"
)
P99_FLOOR_MS = 20.0     # absolute floor for the hedging ratio denominator:
                        # below this, CI timer noise dominates real latency


def _matrix(n_items: int) -> np.ndarray:
    rng = np.random.default_rng(0)
    return rng.standard_normal((N_QUERIES, n_items)).astype(np.float32)


def _service(m, *, plan=None, replica=None, item_offset=0, n_items=None,
             max_batch=8, buckets=None, deterministic=False):
    n_items = m.shape[1] if n_items is None else n_items
    wide = m
    if item_offset:
        wide = np.zeros((m.shape[0], item_offset + n_items), dtype=np.float32)
        wide[:, item_offset:] = m[:, :n_items]
    scorer = TabulatedScorer(wide)
    if plan is not None:
        scorer = FaultyScorer(scorer, plan, replica=replica)
    index = AnchorIndex.from_r_anc(
        jnp.asarray(m[:64, :n_items]),
        item_ids=jnp.arange(item_offset, item_offset + n_items),
    )
    retriever = AdaCURRetriever.from_index(index, scorer, CFG, anytime=True)
    return AdaCURService(
        retriever=retriever, max_batch=max_batch, max_wait_s=60.0,
        batch_buckets=buckets or [2, 4, max_batch],
        deterministic=deterministic,
    )


def _warm(router) -> None:
    """Compile every batch bucket on every replica through the full service
    flush path (search + id gather) before any timing starts.  Goes through
    the flush error boundary, so warming a deliberately-faulty replica still
    populates its jit cache instead of crashing the benchmark."""
    for rep in router.replicas:
        svc = rep.service
        for b in svc.batch_buckets:
            with svc._lock:
                svc._pending.extend(
                    RetrievalRequest(query_id=i) for i in range(b)
                )
                svc.flush()


def _drive_poisson(router, n_requests, mean_interarrival_s, rng,
                   deadline_s=None):
    """Open-loop Poisson arrivals; returns (tickets, outcomes, wall_s)."""
    tickets = []
    t0 = time.monotonic()
    for _ in range(n_requests):
        tickets.append(router.submit(
            int(rng.integers(0, N_QUERIES)), deadline_s=deadline_s))
        time.sleep(float(rng.exponential(mean_interarrival_s)))
    outs = [router.result(t, timeout=120.0) for t in tickets]
    wall = time.monotonic() - t0
    return tickets, outs, wall


def _summarize(name, tickets, outs, wall, router) -> dict:
    lost = sum(o is None for o in outs)
    terminal = [o for o in outs if o is not None]
    lat_ms = [o.latency_s * 1e3 for o in terminal if o.status == "ok"]
    n = len(tickets)
    row = {
        "requests": n,
        "wall_s": round(wall, 3),
        "qps": round(n / wall, 1) if wall > 0 else None,
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 2) if lat_ms else None,
        "p99_ms": round(float(np.percentile(lat_ms, 99)), 2) if lat_ms else None,
        "ok": sum(o.status == "ok" for o in terminal),
        "degraded": sum(o.degraded for o in terminal),
        "errors": sum(o.status == "error" for o in terminal),
        "rejected": sum(o.status == "rejected" for o in terminal),
        "lost": lost,
        "hedges": router.stats["hedges"],
        "retries": router.stats["retries"],
        "quarantines": router.stats["quarantines"],
        "quarantined_replicas": list(router.quarantined),
    }
    assert lost == 0, f"{name}: {lost} requests lost (no terminal outcome)"
    assert row["ok"] + row["errors"] + row["rejected"] == n, row
    print(f"[{name}] " + " ".join(
        f"{k}={v}" for k, v in row.items() if k != "quarantined_replicas"
    ))
    return row


def run(quick: bool) -> dict:
    n_items = 500 if quick else 2000
    n_req = 120 if quick else 400
    interarrival = 0.008 if quick else 0.005
    m = _matrix(n_items)
    rng = np.random.default_rng(7)
    out = {"config": {
        "quick": quick, "n_items": n_items, "requests_per_scenario": n_req,
        "mean_interarrival_ms": interarrival * 1e3, "replicas": 2,
        "cfg": {"k_anchor": CFG.k_anchor, "n_rounds": CFG.n_rounds,
                "budget_ce": CFG.budget_ce, "k_retrieve": CFG.k_retrieve},
    }, "scenarios": {}}
    scn = out["scenarios"]

    # Heterogeneous batch buckets make healthy batch times multi-modal (a
    # bucket-8 batch is legitimately several x a bucket-2 batch), so
    # scenarios that are not exercising the straggler watchdog run it with
    # a threshold far above that spread — only slow_replica tightens it.
    LAX_WD = {"watchdog_threshold": 50.0, "watchdog_patience": 3}

    # ------------------------------------------------------------ baseline
    router = Router([_service(m), _service(m)], queue_limit=64, **LAX_WD)
    try:
        _warm(router)
        tickets, outs, wall = _drive_poisson(router, n_req, interarrival, rng)
        row = _summarize("baseline", tickets, outs, wall, router)
        assert row["quarantines"] == 0, "fault-free run must not quarantine"
        scn["baseline"] = row
    finally:
        router.close()
    p99_base_ms = scn["baseline"]["p99_ms"]
    hedge_after_s = max(0.02, p99_base_ms / 1e3)

    # -------------------------------------------------------- scorer_fault
    # replica 0's scorer raises on every callback until quarantine kicks in
    # (2 error batches at max_consecutive_errors=2 — 2000 calls is plenty)
    plan = FaultPlan(scorer_faults=[
        ScorerFault(call_k=k, replica=0) for k in range(1, 2000)
    ])
    router = Router(
        [_service(m, plan=plan, replica=0), _service(m, plan=plan, replica=1)],
        queue_limit=64, max_retries=2, max_consecutive_errors=2, plan=plan,
        **LAX_WD,
    )
    try:
        _warm(router)
        tickets, outs, wall = _drive_poisson(router, n_req, interarrival, rng)
        row = _summarize("scorer_fault", tickets, outs, wall, router)
        assert row["errors"] == 0, "retries should absorb a single bad replica"
        assert 0 in row["quarantined_replicas"]
        scn["scorer_fault"] = row
    finally:
        router.close()

    # -------------------------------------------------------- slow_replica
    stall_s = max(0.5, 20 * p99_base_ms / 1e3)
    plan = FaultPlan(sleep_faults=[SleepFault(replica=0, seconds=stall_s)])
    router = Router(
        [_service(m, plan=plan, replica=0), _service(m, plan=plan, replica=1)],
        queue_limit=64, hedge_after_s=hedge_after_s, plan=plan,
        watchdog_threshold=8.0, watchdog_patience=1,
    )
    try:
        _warm(router)
        # fleet baseline for the shared-deque watchdog: healthy batches sit
        # far under the flag level, the injected stall far over it
        router.replicas[1].watchdog.window.extend(
            [max(0.05, 2 * p99_base_ms / 1e3)] * 8
        )
        tickets, outs, wall = _drive_poisson(router, n_req, interarrival, rng)
        row = _summarize("slow_replica", tickets, outs, wall, router)
        assert row["quarantined_replicas"] == [0], (
            "watchdog must flag exactly the stalled replica", row)
        row["stall_s"] = round(stall_s, 3)
        row["hedge_after_ms"] = round(hedge_after_s * 1e3, 1)
        denom = max(p99_base_ms, P99_FLOOR_MS)
        row["p99_over_baseline"] = round(row["p99_ms"] / denom, 3)
        scn["slow_replica"] = row
    finally:
        router.close()

    # ------------------------------------------------------ swap_midflight
    new_index = AnchorIndex.from_r_anc(
        jnp.asarray(m[:64]), item_ids=jnp.arange(20000, 20000 + n_items)
    )
    plan = FaultPlan(swap_faults=[SwapFault(at_seq=n_req // 2)])
    services = [
        _service(m, item_offset=10000, n_items=n_items) for _ in range(2)
    ]
    for svc in services:
        wide = np.zeros((N_QUERIES, 20000 + n_items), dtype=np.float32)
        wide[:, 10000:10000 + n_items] = m
        wide[:, 20000:] = m
        svc._scorer.matrix = wide
    router = Router(services, queue_limit=64, plan=plan,
                    swap_index_fn=lambda: new_index, **LAX_WD)
    try:
        _warm(router)
        tickets, outs, wall = _drive_poisson(router, n_req, interarrival, rng)
        row = _summarize("swap_midflight", tickets, outs, wall, router)
        consistent = served_new = True
        seen_new = False
        for o in outs:
            if o.status != "ok":
                continue
            ids = o.response.item_ids
            old = ((ids >= 10000) & (ids < 20000)).all()
            new = (ids >= 20000).all()
            consistent = consistent and bool(old or new)
            seen_new = seen_new or bool(new)
        row["namespace_consistent"] = consistent
        row["swap_took_effect"] = seen_new
        assert consistent, "mixed-namespace response under mid-flight swap"
        assert seen_new
        scn["swap_midflight"] = row
    finally:
        router.close()

    # --------------------------------------------------- deadline_degraded
    # service-level, deterministic, bucket=1: each degraded response is
    # replayed as an explicit n_rounds=rounds_completed search on the same
    # key and must match bit-for-bit (the prefix-consistency gate)
    svc = _service(m, max_batch=1, buckets=[1], deterministic=True)
    jax.block_until_ready(svc.retriever.search(jnp.arange(1)).topk_idx)
    n_dead = 20 if quick else 50
    degraded = prefix_ok = 0
    lat_ms = []
    for _ in range(n_dead):
        qid = int(rng.integers(0, N_QUERIES))
        (r,) = svc.submit(RetrievalRequest(
            query_id=qid, deadline_t=time.monotonic())) or svc.flush()
        assert r.status == "ok"
        lat_ms.append(r.latency_s * 1e3)
        if not r.degraded:
            continue
        degraded += 1
        ref = svc.retriever.search(
            jnp.asarray([qid]), svc._key, n_rounds=r.rounds_completed
        )
        ref_ids = np.asarray(svc.index.gather_item_ids(ref.topk_idx))[0]
        if (np.array_equal(r.item_ids, ref_ids)
                and np.array_equal(r.scores, np.asarray(ref.topk_scores[0]))
                and r.measured_ce_calls == ce_call_plan(CFG, r.rounds_completed)):
            prefix_ok += 1
    row = {
        "requests": n_dead,
        "degraded": degraded,
        "prefix_consistent": degraded == prefix_ok,
        "p50_ms": round(float(np.percentile(lat_ms, 50)), 2),
        "rounds_budget": CFG.n_rounds,
    }
    print(f"[deadline_degraded] " + " ".join(f"{k}={v}" for k, v in row.items()))
    assert degraded > 0, "expired deadlines must degrade at least one search"
    assert row["prefix_consistent"], (degraded, prefix_ok)
    scn["deadline_degraded"] = row

    # ---------------------------------------------------------------- gates
    hedged_ratio = scn["slow_replica"]["p99_over_baseline"]
    out["gates"] = {
        "no_lost_requests": all(
            s.get("lost", 0) == 0 for s in scn.values()
        ),
        "hedged_p99_ratio": hedged_ratio,
        "hedged_p99_within_2x": hedged_ratio <= 2.0,
        "degraded_prefix_consistent": scn["deadline_degraded"][
            "prefix_consistent"],
    }
    assert out["gates"]["no_lost_requests"]
    assert out["gates"]["hedged_p99_within_2x"], scn["slow_replica"]
    assert out["gates"]["degraded_prefix_consistent"]
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run (smaller corpus, fewer arrivals)")
    args = ap.parse_args()
    logging.getLogger("jax._src.callback").setLevel(logging.CRITICAL)

    out = run(quick=args.quick)
    with open("BENCH_serve.json", "w") as f:
        json.dump(out, f, indent=2)
    print("wrote BENCH_serve.json")
    print(json.dumps(out["gates"], indent=2))


if __name__ == "__main__":
    main()

"""Checkpoint manager: keep-policy, resume, and failure-recovery loop.

Pod-scale runs die: preemptions, flaky hosts, link flaps.  The manager owns
the "what do we do about it" policy around the Checkpointer:

- ``maybe_save`` every N steps + keep-last-K garbage collection;
- ``latest`` / ``resume`` for cold restart (returns step 0 state when no
  checkpoint exists — one code path for fresh and resumed jobs);
- ``run_with_recovery`` drives a train loop and, on a step failure
  (simulating a lost host), restores the last checkpoint and continues —
  the integration test kills steps on purpose and asserts bit-exact resume.
"""

from __future__ import annotations

import logging
import time
from typing import Any, Callable, Optional, Tuple

from .checkpointer import Checkpointer

log = logging.getLogger(__name__)


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        save_every: int = 100,
        keep: int = 3,
        async_save: bool = True,
    ):
        self.ckpt = Checkpointer(directory, async_save=async_save)
        self.save_every = save_every
        self.keep = keep

    def maybe_save(self, step: int, state: Any, specs: Any = None) -> bool:
        if step % self.save_every != 0:
            return False
        self.ckpt.save(step, state, specs)
        self._gc()
        return True

    def _gc(self) -> None:
        import os, shutil

        steps = self.ckpt.available_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.ckpt.dir, f"step_{s}"), ignore_errors=True)

    def latest(self) -> Optional[int]:
        steps = self.ckpt.available_steps()
        return steps[-1] if steps else None

    def resume(self, like: Any, mesh=None) -> Tuple[int, Any]:
        """(start_step, state) — state is ``like`` itself when starting cold."""
        last = self.latest()
        if last is None:
            return 0, like
        self.ckpt.wait()
        return last, self.ckpt.restore(last, like, mesh)

    def run_with_recovery(
        self,
        step_fn: Callable[[int, Any], Any],
        state: Any,
        n_steps: int,
        specs: Any = None,
        mesh=None,
        max_restarts: int = 3,
    ) -> Any:
        """Drive a training loop; on exception, restore + retry (node-failure
        recovery).  ``step_fn(step, state) -> state``."""
        start, state = self.resume(state, mesh)
        restarts = 0
        step = start
        while step < n_steps:
            try:
                state = step_fn(step, state)
                step += 1
                self.maybe_save(step, state, specs)
            except Exception as e:  # noqa: BLE001 — any step failure
                restarts += 1
                if restarts > max_restarts:
                    raise
                log.warning("step %d failed (%s); restoring last checkpoint", step, e)
                self.ckpt.wait()
                step, state = self.resume(state, mesh)
        self.ckpt.wait()
        return state

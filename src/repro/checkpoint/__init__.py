from . import checkpointer, manager  # noqa: F401
from .checkpointer import Checkpointer  # noqa: F401
from .manager import CheckpointManager  # noqa: F401

"""Sharded, async, atomic checkpointing with elastic re-sharding.

Design (no orbax dependency — pure numpy + json):

- every array leaf is saved as one .npy per LOGICAL array (gathered from its
  shards on save; at real pod scale each host writes only its addressable
  shards — the layout below keeps one file per leaf so that path is a local
  change, not a format change);
- a manifest.json records the tree structure, dtypes, shapes and the
  PartitionSpec every leaf had at save time;
- saves are ASYNC (background thread) and ATOMIC (write to step_N.tmp,
  fsync, rename) — a preempted job never sees a torn checkpoint;
- restore RESHARDS onto whatever mesh the new job brings (elastic up/down):
  the manifest's specs are re-resolved against the new mesh, so a 16x16
  checkpoint restores onto 2x16x16 or 4x4 transparently.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

SEP = "/"


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


def _spec_to_json(spec: P):
    return [list(a) if isinstance(a, tuple) else a for a in spec]


def _spec_from_json(raw) -> P:
    return P(*[tuple(a) if isinstance(a, list) else a for a in raw])


class Checkpointer:
    """save(step, tree, specs) / restore(step, mesh) with async + atomic IO."""

    def __init__(self, directory: str, async_save: bool = True):
        self.dir = directory
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, specs: Any = None) -> None:
        """specs: optional matching tree of PartitionSpec (for elastic restore)."""
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        spec_map = {}
        if specs is not None:
            flat_specs, _ = _flatten_with_paths(specs)
            spec_map = {k: _spec_to_json(v) for k, v in flat_specs.items()}

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            flat, _ = _flatten_with_paths(host_tree)
            manifest = {"step": step, "leaves": {}}
            for key, leaf in flat.items():
                fn = key.replace(SEP, "__") + ".npy"
                # ml_dtypes leaves (bfloat16, float8_*) are custom numpy
                # dtypes (kind 'V') that np.save writes as raw void bytes —
                # the dtype would not survive np.load.  Write the byte view
                # instead; the manifest keeps the logical dtype/shape and
                # restore views the bytes back.
                to_disk = leaf.view(np.uint8) if leaf.dtype.kind == "V" else leaf
                np.save(os.path.join(tmp, fn), to_disk)
                manifest["leaves"][key] = {
                    "file": fn,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "spec": spec_map.get(key),
                }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)  # atomic commit

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------

    def available_steps(self):
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(steps)

    def restore(self, step: int, like: Any, mesh: Optional[Mesh] = None) -> Any:
        """Restore into the structure of ``like`` (a pytree of arrays or
        ShapeDtypeStructs).  With a mesh, each leaf is device_put with the
        spec recorded at save time re-resolved on the NEW mesh (elastic)."""
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        flat_like, treedef = _flatten_with_paths(like)
        leaves_out = {}
        for key, ref in flat_like.items():
            meta = manifest["leaves"][key]
            arr = np.load(os.path.join(path, meta["file"]))
            want = np.dtype(meta["dtype"])
            if want.kind == "V" and arr.dtype != want:
                arr = arr.view(want)  # byte view written by save (ml_dtypes)
            if tuple(arr.shape) != tuple(ref.shape):
                raise ValueError(
                    f"checkpoint leaf {key} shape {arr.shape} != expected {ref.shape}"
                )
            if mesh is not None and meta["spec"] is not None:
                spec = _spec_from_json(meta["spec"])
                # drop mesh axes the new mesh no longer has (elastic down)
                spec = P(*[
                    a if _axes_exist(mesh, a) else None for a in spec
                ])
                leaves_out[key] = jax.device_put(arr, NamedSharding(mesh, spec))
            else:
                leaves_out[key] = jax.numpy.asarray(arr, dtype=ref.dtype)
        ordered = [leaves_out[k] for k in flat_like]
        return jax.tree_util.tree_unflatten(treedef, ordered)


def _axes_exist(mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    axes_t = (axes,) if isinstance(axes, str) else axes
    return all(a in mesh.shape for a in axes_t)

"""Sequence-parallel flash-decode: KV cache sharded along SEQUENCE.

Why: decode_32k on qwen1.5-110b carries an 86 GB/batch-shard KV cache —
head-parallelism cannot shard it (kv_heads=8 < model=16), so the cache's
*sequence* axis is sharded over "model" (and over everything for the
batch=1 long_500k cell).  Each shard computes partial attention over its
local KV chunk plus a running max/denominator; shards combine with the
standard LSE-weighted psum (exactly FlashDecoding's split-K reduction,
mapped onto mesh axes).

One shard_map covers cache-update + attention so the new token's K/V are
written into the owning shard without any boundary resharding.
"""

from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..models import layers
from ..compat import shard_map


def _decode_core_body(
    q,            # (Bl, H, hd)      — local batch shard, all heads
    k_new,        # (Bl, KV, hd)
    v_new,        # (Bl, KV, hd)
    ck,           # (Bl, Sl, KV, hd) — local sequence shard of the cache
    cv,
    pos,          # ()  int32        — global write/attend position
    *,
    seq_axes: Tuple[str, ...],
    local_len: int,
):
    # which shard owns position `pos`?
    shard_id = jax.lax.axis_index(seq_axes)
    offset = shard_id * local_len
    local_pos = jnp.clip(pos - offset, 0, local_len - 1)
    mine = (pos >= offset) & (pos < offset + local_len)
    # masked write via a SLICE-level select: a full-cache jnp.where makes
    # XLA's CPU fusion pass materialize an f32 copy of the whole stacked
    # cache (12.6 GB/device on moonshot decode_32k); selecting on the
    # one-token payload is equivalent and byte-free.
    zero = (0, local_pos, 0, 0)
    sl = lambda c: jax.lax.dynamic_slice(
        c, zero, (c.shape[0], 1) + c.shape[2:]
    )
    ck = jax.lax.dynamic_update_slice(
        ck, jnp.where(mine, k_new[:, None], sl(ck)), zero
    )
    cv = jax.lax.dynamic_update_slice(
        cv, jnp.where(mine, v_new[:, None], sl(cv)), zero
    )

    num, den, m = layers.decode_attention_local(
        q, ck, cv, shard_offset=offset, kv_len=pos + 1
    )
    # LSE-weighted combine across sequence shards
    m_glob = jax.lax.pmax(m, seq_axes)
    scale = jnp.exp(m - m_glob)
    num = jax.lax.psum(num * scale[..., None], seq_axes)
    den = jax.lax.psum(den * scale, seq_axes)
    o = num / (den[..., None] + 1e-30)
    return o.astype(q.dtype), ck, cv


def make_decode_core(
    mesh: Mesh,
    batch_axes: Tuple[str, ...],
    seq_axes: Tuple[str, ...],
    seq_len: int,
):
    """Build the decode_core(q, k_new, v_new, ck, cv, pos) shard_map closure.

    batch_axes shard the cache/batch dim; seq_axes shard the cache sequence
    dim (psum'd in the combine).  Any mesh axis in neither set sees
    replicated compute (e.g. "model" when it TPs the surrounding matmuls).
    """
    n_seq_shards = 1
    for a in seq_axes:
        n_seq_shards *= mesh.shape[a]
    if seq_len % n_seq_shards:
        raise ValueError(f"seq_len={seq_len} not divisible by seq shards {n_seq_shards}")
    local_len = seq_len // n_seq_shards

    bspec = tuple(batch_axes) if batch_axes else None
    sspec = tuple(seq_axes)
    body = partial(_decode_core_body, seq_axes=sspec, local_len=local_len)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(bspec, None, None),          # q
            P(bspec, None, None),          # k_new
            P(bspec, None, None),          # v_new
            P(bspec, sspec, None, None),   # ck
            P(bspec, sspec, None, None),   # cv
            P(),                           # pos
        ),
        out_specs=(
            P(bspec, None, None),
            P(bspec, sspec, None, None),
            P(bspec, sspec, None, None),
        ),
        check_vma=False,
    )

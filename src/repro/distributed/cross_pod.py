"""Compressed cross-pod gradient reduction (distributed-optimization).

On a multi-pod mesh the "pod" axis rides data-center links an order slower
than intra-pod ICI, so the cross-pod grad reduce is the scaling wall.  This
module splits the reduction hierarchically:

    1. full-precision psum INSIDE each pod (fast ICI);
    2. int8-quantized (+ error feedback) psum ACROSS pods (slow DCI);

cutting cross-pod bytes 4x vs f32 (2x vs bf16) while the error-feedback
residual keeps the optimizer trajectory unbiased (tests assert convergence
parity; repro.distributed.compression has the value-level pieces).

Usage inside a train step on a ("pod", "data", "model") mesh:

    reduce_fn = make_hierarchical_grad_reduce(mesh, p_specs)
    grads, err = reduce_fn(grads, err)      # replaces the implicit DP psum
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from . import compression
from ..compat import shard_map


def make_hierarchical_grad_reduce(mesh: Mesh, grad_specs):
    """Build reduce(grads, err) -> (reduced grads, new err) for a multi-pod
    mesh.  Grads arrive pod-local (each pod's DP group already averaged by
    GSPMD); this performs the CROSS-POD mean with int8 payloads.

    Implemented with shard_map over the full mesh: each leaf keeps its
    at-rest sharding (in_specs = the grad PartitionSpecs with the "pod" axis
    REMOVED — pod-replicated values differ per pod pre-reduce)."""
    if "pod" not in mesh.shape:
        # single pod: nothing to do — identity keeps call sites uniform
        return lambda grads, err: (grads, err)

    def strip_pod(spec: P) -> P:
        return P(*[
            (tuple(a for a in ax if a != "pod") or None)
            if isinstance(ax, tuple) else (None if ax == "pod" else ax)
            for ax in spec
        ])

    local_specs = jax.tree.map(
        strip_pod, grad_specs, is_leaf=lambda x: isinstance(x, P)
    )

    n_pods = mesh.shape["pod"]

    def body(grads, err):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            # SHARED scale across pods (one pmax of a scalar) BEFORE
            # quantizing — mixing per-pod scales after the int sum would
            # bias the reconstruction (measured 23% accumulated error)
            scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), "pod") / 127.0 + 1e-12
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            # int8 payload crosses the pod link
            q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
            deq = q_sum.astype(jnp.float32) * scale / n_pods
            return deq.astype(g.dtype), g32 - q.astype(jnp.float32) * scale

        pairs = jax.tree.map(one, grads, err)
        out = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return out, new_err

    def reduce_fn(grads, err):
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(local_specs, local_specs),
            out_specs=(grad_specs, local_specs),
            check_vma=False,
        )(grads, err)

    return reduce_fn

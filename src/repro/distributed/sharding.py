"""Logical-axis sharding rules -> PartitionSpec trees.

Model inits return spec trees whose leaves are tuples of *logical* axis
names (("embed","heads","head_dim"), ...).  This module translates them to
``PartitionSpec``s for a concrete mesh:

- "data"  = combined DP/FSDP axis (params FSDP-shard their "embed"/"vocab"
  dims here; batches shard here and, multi-pod, on "pod" too);
- "model" = tensor/expert parallel axis;
- "pod"   = cross-pod data parallelism (never used for param FSDP — param
  all-gathers stay on intra-pod ICI, only grad reduction crosses pods).

Rules are divisibility-checked per tensor: a logical dim that does not
divide by its mesh axis (e.g. kv_heads=8 on model=16) falls back to
replication, and a mesh axis may appear only once per spec (first logical
dim wins; e.g. MoE (expert, embed, mlp) gives expert->model, embed->data,
mlp->replicated).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[str, Tuple[str, ...], None]

# logical axis -> preferred mesh axes (tried in order; tuples mean "shard
# this one dim over several mesh axes", e.g. huge embedding-table rows)
DEFAULT_RULES: Dict[str, Sequence[Axes]] = {
    # LM
    "vocab": ("model",),
    "embed": ("data",),            # FSDP
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (None,),
    "mlp": ("model",),
    "expert": ("model",),
    "layers": (None,),
    "seq": (None,),
    "unit": (None,),
    # recsys
    "table_rows": (("pod", "data", "model"), ("data", "model"), ("data",)),
    # retrieval (AnchorIndex): on a serving (data x items) mesh the item
    # axis lives on the dedicated "items" axis (the data axis shards the
    # query batch — see engine.make_sharded_engine); on training meshes it
    # spreads over the whole mesh as before
    "items": (
        ("items",),
        ("pod", "data", "model"), ("data", "model"), ("data",), ("model",),
    ),
    "anchor_q": (None,),
    "mlp_in": ("data",),
    "mlp_out": ("model",),
    "interest": (None,),
    # gnn
    "feat": (None,),
    "species": (None,),
    "ch": (None,),
    "ch_in": (None,),
    "rbf": (None,),
    "radial_out": (None,),
}


def _axis_size(mesh: Mesh, axes: Axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for(
    mesh: Mesh,
    logical: Tuple[str, ...],
    shape: Tuple[int, ...],
    rules: Optional[Dict[str, Sequence[Axes]]] = None,
) -> P:
    """Translate one logical-axes tuple into a PartitionSpec."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for name, dim in zip(logical, shape):
        chosen: Axes = None
        for cand in rules.get(name, (None,)):
            if cand is None:
                break
            cand_t = (cand,) if isinstance(cand, str) else cand
            if any(a not in mesh.shape for a in cand_t):
                continue
            if any(a in used for a in cand_t):
                continue
            if dim % _axis_size(mesh, cand_t) != 0:
                continue
            chosen = cand if isinstance(cand, str) else tuple(cand_t)
            used.update(cand_t)
            break
        out.append(chosen)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, str) for e in x)


def tree_specs(mesh: Mesh, params, logical_specs, rules=None):
    """Map a whole (params, logical-spec) tree to PartitionSpecs.

    The logical-spec tree leads the traversal (its leaves are tuples of
    axis-name strings, which are themselves pytrees, so it must be primary
    with an ``is_leaf`` that stops on them)."""
    return jax.tree.map(
        lambda s, p: spec_for(mesh, s, p.shape, rules),
        logical_specs,
        params,
        is_leaf=_is_axes,
    )


def tree_shardings(mesh: Mesh, params, logical_specs, rules=None):
    specs = tree_specs(mesh, params, logical_specs, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that shard the batch dimension (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh, extra_dims: int = 1) -> P:
    return P(batch_axes(mesh), *([None] * extra_dims))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())

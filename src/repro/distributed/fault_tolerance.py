"""Straggler mitigation + heartbeat monitoring (host-side control plane).

On a 1000-node job the slowest host sets the step time.  The watchdog
measures per-step wall time against a rolling deadline; persistent
stragglers trigger a policy decision (log + alert, skip the host's data
shard, or request an elastic down-scale — the latter two are simulated
here and exercised in tests).
"""

from __future__ import annotations

import collections
import time
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional


@dataclass
class StepStats:
    step: int
    seconds: float
    straggler: bool


class StragglerWatchdog:
    """Rolling-median deadline: a step slower than ``threshold`` x median is
    flagged; ``on_straggler`` fires after ``patience`` consecutive flags.

    ``baseline`` optionally shares the healthy-step deque across watchdog
    instances — the serving router gives each replica its own watchdog (its
    own consecutive-flag state and callback) over one *fleet-wide* baseline,
    so a replica that is slow from its very first batch is still flagged
    against its healthy peers' median rather than its own history.
    """

    def __init__(
        self,
        threshold: float = 2.0,
        window: int = 20,
        patience: int = 3,
        on_straggler: Optional[Callable[[StepStats], None]] = None,
        baseline: Optional[Deque[float]] = None,
    ):
        self.threshold = threshold
        self.window: Deque[float] = (
            baseline if baseline is not None
            else collections.deque(maxlen=window)
        )
        self.patience = patience
        self.on_straggler = on_straggler
        self.consecutive = 0
        self.history: List[StepStats] = []

    @staticmethod
    def shared_baseline(window: int = 20) -> Deque[float]:
        """A healthy-step deque to pass as ``baseline`` to a watchdog group."""
        return collections.deque(maxlen=window)

    def _median(self) -> float:
        if not self.window:
            return float("inf")
        s = sorted(self.window)
        return s[len(s) // 2]

    def observe(self, step: int, seconds: float) -> StepStats:
        med = self._median()
        straggler = len(self.window) >= 5 and seconds > self.threshold * med
        if straggler:
            self.consecutive += 1
        else:
            self.consecutive = 0
            self.window.append(seconds)   # only healthy steps update the baseline
        stats = StepStats(step, seconds, straggler)
        self.history.append(stats)
        if straggler and self.consecutive >= self.patience and self.on_straggler:
            self.on_straggler(stats)
            self.consecutive = 0
        return stats

    def timed(self, step: int, fn: Callable, *args, **kw):
        t0 = time.monotonic()
        out = fn(*args, **kw)
        self.observe(step, time.monotonic() - t0)
        return out


class HeartbeatMonitor:
    """Host liveness registry: hosts report heartbeats; hosts silent past
    ``timeout`` are declared dead and listed for the elastic controller."""

    def __init__(self, timeout: float = 60.0):
        self.timeout = timeout
        self.last_seen: Dict[str, float] = {}

    def beat(self, host: str, now: Optional[float] = None) -> None:
        self.last_seen[host] = now if now is not None else time.monotonic()

    def dead_hosts(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]

    def healthy_count(self, now: Optional[float] = None) -> int:
        return len(self.last_seen) - len(self.dead_hosts(now))


def elastic_plan(n_healthy: int, axis_candidates=((2, 16, 16), (16, 16), (8, 16), (8, 8), (4, 8), (4, 4), (2, 2), (1, 1))):
    """Largest mesh shape (from the supported ladder) that fits the surviving
    hosts — checkpoint restore re-shards onto it (repro.checkpoint)."""
    for shape in axis_candidates:
        n = 1
        for s in shape:
            n *= s
        if n <= n_healthy:
            return shape
    return (1,)

"""Gradient compression for cross-pod reduction (distributed-optimization).

At 512+ chips the cross-pod data-center links are ~10x slower than intra-pod
ICI, so the "pod" grad all-reduce is the scaling bottleneck.  Two standard
compressors, both with error feedback (the residual re-enters the next
step's gradient, preserving convergence — Karimireddy et al. 2019):

- int8 quantization: per-tensor absmax scale, 4x traffic cut vs f32;
- top-k sparsification: keep the largest |g| fraction per tensor.

These are pure value-transformations wrapped around the psum the step
function already performs, so they compose with any optimizer.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def int8_quantize(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_roundtrip_with_feedback(grads, error):
    """Returns (compressed-then-decompressed grads, new error residual).

    In the distributed step the int8 payload is what crosses the pod link;
    the residual (quantization error) is added back into the NEXT step's
    gradient so nothing is lost asymptotically."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = int8_quantize(g)
        deq = int8_dequantize(q, s)
        return deq, g - deq

    pairs = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_err


def topk_sparsify_with_feedback(grads, error, frac: float = 0.01):
    """Keep the top-|g| ``frac`` entries per tensor; rest feeds back."""

    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        kept = jnp.where(jnp.abs(g) >= thresh, g, 0.0)
        return kept, g - kept

    pairs = jax.tree.map(one, grads, error)
    kept = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return kept, new_err


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

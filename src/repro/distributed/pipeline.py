"""Pipeline parallelism over a mesh axis (GPipe-style microbatching).

For multi-pod topologies the "pod" axis can carry pipeline STAGES instead of
data parallelism: cross-pod links are an order slower than intra-pod ICI, so
sending one (microbatch, d_model) activation per microbatch beats
all-reducing every gradient.  This module implements the mechanics with
``shard_map`` + ``collective_permute``:

- layer stack is split into S contiguous stages, stage s owned by pipe rank s;
- microbatches stream with the standard GPipe schedule (S + M - 1 ticks);
- each tick every rank runs its stage on its current microbatch then
  ppermutes activations to the next rank.

Bubble fraction = (S-1)/(S+M-1); compose with grad accumulation for the
backward (the driver below is forward-only, used for serving and tested for
exact equivalence with the unpipelined forward).
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from ..compat import shard_map


def pipeline_forward(
    mesh: Mesh,
    stage_fn: Callable,      # stage_fn(stage_params, x) -> x
    pipe_axis: str,
    n_microbatches: int,
):
    """Build pipelined_fn(stage_params, x) with params/batch sharded on
    ``pipe_axis``: params (S, ...) one stage per rank; x (M*mb, ...) split
    into M microbatches that flow through the S stages."""
    n_stages = mesh.shape[pipe_axis]

    def body(params_local, x_local):
        # params_local: (1, ...) this rank's stage; x_local: (M*mb_local...)
        # GPipe over M microbatches + (S-1) drain ticks.
        rank = jax.lax.axis_index(pipe_axis)
        stage_params = jax.tree.map(lambda p: p[0], params_local)
        m = n_microbatches
        mb = x_local.shape[0] // m
        micro = x_local.reshape((m, mb) + x_local.shape[1:])

        n_ticks = m + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, out = carry          # buf: (mb, ...) activation held here
            # rank 0 injects microbatch t (if in range) — other ranks use buf
            inject = jnp.where(t < m, jnp.clip(t, 0, m - 1), 0)
            x_in = jnp.where(rank == 0, micro[inject], buf)
            y = stage_fn(stage_params, x_in)
            # the LAST stage's output for microbatch (t - (S-1)) is final
            done_idx = t - (n_stages - 1)
            is_done = (rank == n_stages - 1) & (done_idx >= 0) & (done_idx < m)
            out = jax.lax.cond(
                is_done,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None], (jnp.maximum(done_idx, 0),) + (0,) * y.ndim
                ),
                lambda o: o,
                out,
            )
            # rotate activations to the next stage
            buf = jax.lax.ppermute(y, pipe_axis, perm)
            return (buf, out), None

        buf0 = jnp.zeros_like(micro[0])
        out0 = jnp.zeros_like(micro)
        (_, out), _ = jax.lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
        # every rank holds only its writes; the last stage has the real data
        out = jax.lax.psum(out, pipe_axis) / 1.0  # ranks != last wrote zeros
        return out.reshape(x_local.shape)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(pipe_axis), P()),
        out_specs=P(),
        check_vma=False,
    )


def split_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (S, L/S, ...) stage-major split."""
    def rs(p):
        l = p.shape[0]
        return p.reshape((n_stages, l // n_stages) + p.shape[1:])

    return jax.tree.map(rs, stacked_params)

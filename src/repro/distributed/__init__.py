from . import compression, cross_pod, decode_attention, fault_tolerance, pipeline, sharding  # noqa: F401

"""Pure-JAX AdamW + schedules + gradient utilities (no optax dependency).

Optimizer state mirrors the param tree, so it inherits the params'
PartitionSpecs (FSDP/TP sharded moments for free).  Includes global-norm
clipping, gradient accumulation, and hooks for the compression wrappers in
``repro.distributed.compression``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def cosine_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init_adamw(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    cfg: AdamWConfig,
    params,
    grads,
    state: AdamWState,
    grad_transform: Optional[Callable] = None,
):
    """One AdamW step. ``grad_transform`` hooks compression/error-feedback."""
    if grad_transform is not None:
        grads = grad_transform(grads)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = cosine_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        )
        return new_p.astype(p.dtype), m, v

    flat = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step, new_mu, new_nu), {"grad_norm": gnorm, "lr": lr}


def accumulate_grads(loss_fn: Callable, params, microbatches, n_micro: int):
    """lax.scan gradient accumulation over leading-microbatch-stacked data."""

    def body(acc, mb):
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        acc_g, acc_l = acc
        return (
            jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc_g, grads),
            acc_l + loss,
        ), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (grads, loss), _ = jax.lax.scan(body, (zeros, 0.0), microbatches)
    scale = 1.0 / n_micro
    return jax.tree.map(lambda g: g * scale, grads), loss * scale

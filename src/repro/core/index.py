"""Offline R_anc indexing: score k_q anchor queries against ALL items.

This is the O(k_q·|I|·C_f) offline stage of both ANNCUR and ADACUR — an
embarrassingly parallel batch-inference job.  The builder:

- streams (query-block x item-block) chunks through any scorer,
- shards blocks over the mesh when one is installed,
- checkpoints finished row-blocks so a preempted job resumes where it left
  off (fault tolerance for the multi-day pod-scale indexing run).
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# bulk_score_fn(query_ids (Q,), item_ids (N,)) -> (Q, N) exact scores
BulkScoreFn = Callable[[jax.Array, jax.Array], jax.Array]


@dataclass
class IndexMeta:
    k_q: int
    n_items: int
    block_rows: int
    done_blocks: list


def build_r_anc(
    bulk_score_fn: BulkScoreFn,
    anchor_query_ids: jax.Array,
    item_ids: jax.Array,
    block_rows: int = 64,
    checkpoint_dir: Optional[str] = None,
) -> jax.Array:
    """Compute R_anc (k_q, N) in row blocks with optional resume.

    Each row block is one jit'd bulk scoring call; with a checkpoint dir the
    block results are persisted (.npy) plus a manifest, and finished blocks
    are skipped on restart.
    """
    k_q = int(anchor_query_ids.shape[0])
    n_items = int(item_ids.shape[0])
    n_blocks = (k_q + block_rows - 1) // block_rows

    done = set()
    manifest_path = None
    if checkpoint_dir is not None:
        os.makedirs(checkpoint_dir, exist_ok=True)
        manifest_path = os.path.join(checkpoint_dir, "manifest.json")
        if os.path.exists(manifest_path):
            with open(manifest_path) as f:
                meta = json.load(f)
            if meta["k_q"] == k_q and meta["n_items"] == n_items:
                done = set(meta["done_blocks"])

    rows = []
    for blk in range(n_blocks):
        lo, hi = blk * block_rows, min((blk + 1) * block_rows, k_q)
        blk_path = (
            os.path.join(checkpoint_dir, f"ranc_block_{blk:05d}.npy")
            if checkpoint_dir
            else None
        )
        if blk in done and blk_path and os.path.exists(blk_path):
            rows.append(jnp.asarray(np.load(blk_path)))
            continue
        block = bulk_score_fn(anchor_query_ids[lo:hi], item_ids)
        block = jax.block_until_ready(block)
        rows.append(block)
        if checkpoint_dir:
            np.save(blk_path, np.asarray(block))
            done.add(blk)
            tmp = manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(
                    {
                        "k_q": k_q,
                        "n_items": n_items,
                        "block_rows": block_rows,
                        "done_blocks": sorted(done),
                    },
                    f,
                )
            os.replace(tmp, manifest_path)  # atomic commit
    return jnp.concatenate(rows, axis=0)

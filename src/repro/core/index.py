"""The offline side of the system: the first-class :class:`AnchorIndex` artifact.

Every retriever in this codebase searches the same offline product — the
anchor-query/item score matrix ``R_anc`` plus whatever was precomputed from
it.  Following the paper's follow-up (Yadav et al., *Adaptive Retrieval and
Scalable Indexing*, arXiv 2405.03651) the index is a first-class artifact
with a full lifecycle, not a bare array:

- **build**: :meth:`AnchorIndex.build` streams (query-block x item) chunks
  through any bulk scorer — the O(k_q·|I|·C_f) offline stage is an
  embarrassingly parallel multi-day pod-scale job, so finished row blocks
  are checkpointed and a preempted build resumes where it left off;
- **save/load**: versioned persistence on the repo's
  :class:`repro.checkpoint.Checkpointer` (atomic commit, per-leaf .npy +
  manifest, elastic re-sharding on restore);
- **shard**: :meth:`AnchorIndex.shard` places the item axis over a mesh via
  ``distributed/sharding.py`` rules; :meth:`AnchorIndex.topk` then runs
  under ``shard_map`` — the engine's fused ``approx_topk`` per shard with a
  cross-shard top-k merge, so no shard ever materializes global scores;
- **mutate**: :meth:`add_items` / :meth:`remove_items` support dynamic
  corpora through *padded capacity* plus the engine's ``n_valid`` bound —
  array shapes never change, so corpus mutation never retraces the search.

Retrievers consume the artifact through ``Retriever.from_index`` (see
``core/engine.py``); the item axis of the index is addressed by *position*,
with ``item_ids`` mapping positions to external corpus ids (the engine
applies the map before every cross-encoder call).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..checkpoint.checkpointer import Checkpointer
from ..compat import shard_map
from ..distributed import sharding
from ..kernels.approx_topk import quant
from ..kernels.approx_topk.ops import approx_topk_op
from ..kernels.approx_topk.quant import QuantizedRanc
from . import cur

# bulk_score_fn(query_ids (Q,), item_ids (N,)) -> (Q, N) exact scores
BulkScoreFn = Callable[[jax.Array, jax.Array], jax.Array]

# v2 adds the quantized payload (r_codes/r_scales leaves + payload meta).
# v3 adds the optional corpus token table (item_tokens leaf) that makes the
# index self-contained for device-resident CE scoring (DeviceCEScorer under
# the SPMD engine).  v4 adds sub-int8 payload encodings (packed int4 /
# fp8-e4m3), recorded as ``payload.code_dtype`` (+ ``payload.n_cols`` for
# packed widths) in the meta.  Saves stamp the LOWEST version whose features
# they use — a plain fp32 index keeps the v1 on-disk layout byte-for-byte,
# an int8-quantized one without tokens stamps v2, and only int4/fp8 payloads
# stamp v4 — so older readers keep loading everything they can represent;
# this build reads all four.
INDEX_FORMAT_VERSION = 4
_READABLE_FORMAT_VERSIONS = (1, 2, 3, 4)
_META_FILE = "index_meta.json"
_CKPT_STEP = 0


def build_r_anc(
    bulk_score_fn: BulkScoreFn,
    anchor_query_ids: jax.Array,
    item_ids: jax.Array,
    block_rows: int = 64,
    checkpoint_dir: Optional[str] = None,
) -> jax.Array:
    """Compute R_anc (k_q, N) in row blocks with optional resume.

    Each row block is one jit'd bulk scoring call; with a checkpoint dir the
    block results are persisted (.npy) plus a manifest, and finished blocks
    are skipped on restart.  A manifest whose ``k_q``/``n_items``/
    ``block_rows`` — or whose anchor-query/item *id content* (fingerprinted)
    — does not match the current call is stale, so it is discarded (with its
    block files) rather than silently reused.  A changed *scorer* over
    identical ids is undetectable; use a fresh checkpoint_dir per model.
    """
    k_q = int(anchor_query_ids.shape[0])
    n_items = int(item_ids.shape[0])
    n_blocks = (k_q + block_rows - 1) // block_rows
    ids_fp = hashlib.sha256(
        np.asarray(anchor_query_ids).tobytes() + b"|" + np.asarray(item_ids).tobytes()
    ).hexdigest()[:16]

    done = set()
    manifest_path = None
    if checkpoint_dir is not None:
        os.makedirs(checkpoint_dir, exist_ok=True)
        manifest_path = os.path.join(checkpoint_dir, "manifest.json")
        if os.path.exists(manifest_path):
            with open(manifest_path) as f:
                meta = json.load(f)
            if (
                meta.get("k_q") == k_q
                and meta.get("n_items") == n_items
                and meta.get("block_rows") == block_rows
                and meta.get("ids_fingerprint") == ids_fp
            ):
                done = set(meta["done_blocks"])
            else:
                # stale manifest: blocks cover different rows or different ids
                clear_build_checkpoints(checkpoint_dir)

    rows = []
    for blk in range(n_blocks):
        lo, hi = blk * block_rows, min((blk + 1) * block_rows, k_q)
        blk_path = (
            os.path.join(checkpoint_dir, f"ranc_block_{blk:05d}.npy")
            if checkpoint_dir
            else None
        )
        if blk in done and blk_path and os.path.exists(blk_path):
            rows.append(jnp.asarray(np.load(blk_path)))
            continue
        block = bulk_score_fn(anchor_query_ids[lo:hi], item_ids)
        block = jax.block_until_ready(block)
        rows.append(block)
        if checkpoint_dir:
            np.save(blk_path, np.asarray(block))
            done.add(blk)
            tmp = manifest_path + ".tmp"
            with open(tmp, "w") as f:
                json.dump(
                    {
                        "k_q": k_q,
                        "n_items": n_items,
                        "block_rows": block_rows,
                        "ids_fingerprint": ids_fp,
                        "done_blocks": sorted(done),
                    },
                    f,
                )
            os.replace(tmp, manifest_path)  # atomic commit
    return jnp.concatenate(rows, axis=0)


def clear_build_checkpoints(checkpoint_dir: str) -> None:
    """Drop :func:`build_r_anc`'s row-block checkpoints + manifest — called
    on stale-manifest invalidation and after the built index has been
    committed via :meth:`AnchorIndex.save` (the blocks are superseded)."""
    for name in os.listdir(checkpoint_dir):
        if name.startswith("ranc_block_") and name.endswith(".npy"):
            os.remove(os.path.join(checkpoint_dir, name))
    manifest = os.path.join(checkpoint_dir, "manifest.json")
    if os.path.exists(manifest):
        os.remove(manifest)


def _pad_axis(x: jax.Array, axis: int, target: int, fill) -> jax.Array:
    n = x.shape[axis]
    if n == target:
        return x
    if n > target:
        raise ValueError(f"cannot shrink axis {axis} from {n} to {target}")
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, target - n)
    return jnp.pad(x, widths, constant_values=fill)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "r_anc", "anchor_query_ids", "item_ids", "n_valid",
        "anchor_item_pos", "u", "item_embeddings", "item_tokens",
    ),
    meta_fields=(),
)
@dataclass
class AnchorIndex:
    """The offline artifact every retriever consumes.

    The item axis is padded to ``capacity``; positions ``[0, n_valid)`` hold
    real items (column ``j`` of ``r_anc`` scores item ``item_ids[j]``) and
    the tail holds exact-zero columns with ``item_ids == -1``.  All methods
    are functional — they return a new ``AnchorIndex`` and never resize an
    array, so a retriever holding a mutated index never retraces.
    """

    # (k_q, capacity) anchor-query scores: an fp32/bf16 array, or a coded
    # QuantizedRanc payload (int8 / packed int4 / fp8 codes + per-item-tile
    # scales) after quantize()
    r_anc: Union[jax.Array, QuantizedRanc]
    anchor_query_ids: jax.Array      # (k_q,) int32 anchor query ids
    item_ids: jax.Array              # (capacity,) int32 external ids, -1 padding
    n_valid: jax.Array               # () int32 number of real items
    # optional precomputed ANNCUR latents (arXiv 2210.12579)
    anchor_item_pos: Optional[jax.Array] = None  # (k_i,) anchor item positions
    u: Optional[jax.Array] = None                # (k_i, k_q) pinv(R_anc[:, I_anc])
    item_embeddings: Optional[jax.Array] = None  # (k_i, capacity) = U @ R_anc
    # optional corpus token table for device-resident CE scoring: row j holds
    # the (valid-first, fixed-length) item tokens of position j — kept in
    # positional lockstep with r_anc through every mutation
    item_tokens: Optional[jax.Array] = None      # (capacity, item_len) int32

    # ---- shape/metadata accessors -----------------------------------------

    @property
    def k_q(self) -> int:
        return self.r_anc.shape[0]

    @property
    def capacity(self) -> int:
        return self.r_anc.shape[1]

    @property
    def payload_dtype(self) -> str:
        """Storage dtype of the R_anc payload:
        float32 | bfloat16 | int8 | int4 | fp8."""
        return quant.payload_dtype_of(self.r_anc)

    @property
    def payload_nbytes(self) -> int:
        """Device bytes of the R_anc payload (codes + scales when int8)."""
        return int(self.r_anc.nbytes)

    def _payload_leaf(self) -> jax.Array:
        """The array whose NamedSharding carries the item-axis placement."""
        return self.r_anc.codes if self._quantized else self.r_anc

    @property
    def _quantized(self) -> bool:
        return isinstance(self.r_anc, QuantizedRanc)

    # ---- payload dtype policy ---------------------------------------------

    def quantize(
        self, dtype: str = "int8", tile: int = quant.DEFAULT_TILE
    ) -> "AnchorIndex":
        """Re-encode the R_anc payload
        (``int8`` | ``int4`` | ``fp8`` | ``bfloat16`` | ``float32``).

        The coded dtypes store per-item-tile symmetric codes + fp32 scales
        (int8 ~4x smaller, packed int4 ~8x, fp8-e4m3 ~4x with wider dynamic
        range; the fused kernel dequantizes tile-by-tile in registers).
        ANNCUR latents, if present, stay fp32 — they are (k_i, capacity)
        with k_i ≪ k_q and are not the memory bottleneck.  Quantizing an
        already-coded index with a different tile or code dtype re-quantizes
        from the dequantized codes (documented lossy; keep one encoding per
        artifact).
        """
        if dtype not in quant.PAYLOAD_DTYPES:
            raise ValueError(
                f"unknown payload dtype '{dtype}' (one of {quant.PAYLOAD_DTYPES})"
            )
        cur_payload = self.r_anc
        if dtype == self.payload_dtype and (
            not self._quantized or cur_payload.tile == tile
        ):
            return self
        dense = (
            quant.dequantize(cur_payload) if self._quantized
            else jnp.asarray(cur_payload, jnp.float32)
        )
        if dtype in quant.CODE_DTYPES:
            new = quant.quantize_ranc(dense, tile, code_dtype=dtype)
        elif dtype == "bfloat16":
            new = dense.astype(jnp.bfloat16)
        else:
            new = dense
        return dataclasses.replace(self, r_anc=new)

    @property
    def n_items(self) -> int:
        """Concrete valid-item count (host-side; do not call under a trace)."""
        return int(self.n_valid)

    @property
    def has_latents(self) -> bool:
        return self.item_embeddings is not None

    def valid_mask(self) -> jax.Array:
        """(capacity,) bool — True on real item positions."""
        return jnp.arange(self.capacity, dtype=jnp.int32) < self.n_valid

    def gather_item_ids(self, pos: jax.Array) -> jax.Array:
        """Map engine positions (e.g. ``result.topk_idx``) to external ids."""
        return jnp.take(self.item_ids, pos, axis=0)

    # ---- construction ------------------------------------------------------

    @classmethod
    def from_r_anc(
        cls,
        r_anc: jax.Array,
        anchor_query_ids: Optional[jax.Array] = None,
        item_ids: Optional[jax.Array] = None,
        capacity: Optional[int] = None,
    ) -> "AnchorIndex":
        """Wrap a dense (k_q, N) score matrix, padding the item axis to
        ``capacity`` (defaults to N — no mutation headroom)."""
        k_q, n = r_anc.shape
        capacity = n if capacity is None else int(capacity)
        if capacity < n:
            raise ValueError(f"capacity={capacity} < n_items={n}")
        if anchor_query_ids is None:
            anchor_query_ids = jnp.arange(k_q, dtype=jnp.int32)
        if item_ids is None:
            item_ids = jnp.arange(n, dtype=jnp.int32)
        if item_ids.shape[0] != n:
            raise ValueError(f"item_ids {item_ids.shape} != n_items {n}")
        return cls(
            r_anc=_pad_axis(jnp.asarray(r_anc), 1, capacity, 0),
            anchor_query_ids=jnp.asarray(anchor_query_ids, jnp.int32),
            item_ids=_pad_axis(jnp.asarray(item_ids, jnp.int32), 0, capacity, -1),
            n_valid=jnp.asarray(n, jnp.int32),
        )

    @classmethod
    def build(
        cls,
        bulk_score_fn: BulkScoreFn,
        anchor_query_ids: jax.Array,
        item_ids: jax.Array,
        block_rows: int = 64,
        checkpoint_dir: Optional[str] = None,
        capacity: Optional[int] = None,
        payload_dtype: str = "float32",
        payload_tile: int = quant.DEFAULT_TILE,
    ) -> "AnchorIndex":
        """The offline indexing job: block-streamed, resumable R_anc build.

        With ``payload_dtype`` the finished artifact is emitted directly in
        the requested payload encoding (the fp32 row blocks themselves stay
        the resumable checkpoint unit — per-item-tile scales span all k_q
        rows, so quantization runs once over the assembled matrix)."""
        r_anc = build_r_anc(
            bulk_score_fn, anchor_query_ids, item_ids,
            block_rows=block_rows, checkpoint_dir=checkpoint_dir,
        )
        idx = cls.from_r_anc(
            r_anc, anchor_query_ids=anchor_query_ids, item_ids=item_ids,
            capacity=capacity,
        )
        return idx.quantize(payload_dtype, tile=payload_tile)

    def with_item_tokens(self, item_tokens) -> "AnchorIndex":
        """Attach the corpus token table (device-resident CE scoring).

        ``item_tokens`` is (n_valid, item_len) or (capacity, item_len) int32
        — row ``j`` tokenizes the item at *position* ``j`` (valid-first,
        fixed length, trailing pad).  The table is padded to capacity with
        pad rows (token 0) and from then on moves in positional lockstep
        with the payload through ``add_items``/``remove_items``/``shard``,
        so a :class:`~repro.core.scorer.DeviceCEScorer` can gather pair
        rows by engine position at any point in the index lifecycle."""
        item_tokens = jnp.asarray(item_tokens, jnp.int32)
        if item_tokens.ndim != 2:
            raise ValueError(f"item_tokens must be (n, item_len); got {item_tokens.shape}")
        n = item_tokens.shape[0]
        if n not in (self.n_items, self.capacity):
            raise ValueError(
                f"item_tokens rows ({n}) must cover the valid items "
                f"({self.n_items}) or the full capacity ({self.capacity})"
            )
        return dataclasses.replace(
            self, item_tokens=_pad_axis(item_tokens, 0, self.capacity, 0)
        )

    def with_capacity(self, capacity: int) -> "AnchorIndex":
        """Re-pad the item axis (must still hold all ``n_valid`` items).

        On a quantized payload only the padded tail changes, so every tile
        covering the valid prefix keeps bit-identical codes and scales."""
        n = self.n_items
        if capacity < n:
            raise ValueError(f"capacity={capacity} < n_valid={n}")
        if self._quantized:
            dense = _pad_axis(quant.dequantize(self.r_anc)[:, :n], 1, capacity, 0)
            r_anc = quant.requantize_preserving_prefix(self.r_anc, dense, n)
        else:
            r_anc = _pad_axis(self.r_anc[:, :n], 1, capacity, 0)
        emb = self.item_embeddings
        tok = self.item_tokens
        return dataclasses.replace(
            self,
            r_anc=r_anc,
            item_ids=_pad_axis(self.item_ids[:n], 0, capacity, -1),
            item_embeddings=(
                None if emb is None else _pad_axis(emb[:, :n], 1, capacity, 0)
            ),
            item_tokens=(
                None if tok is None else _pad_axis(tok[:n], 0, capacity, 0)
            ),
        )

    # ---- ANNCUR latents ----------------------------------------------------

    def with_anchors(
        self,
        k_anchor: Optional[int] = None,
        key: Optional[jax.Array] = None,
        anchor_pos: Optional[jax.Array] = None,
    ) -> "AnchorIndex":
        """Fix the ANNCUR anchor item *positions* (uniform over the valid
        prefix unless given) without computing latents — all the engine's
        ``ANNCURRetriever.from_index`` needs."""
        if anchor_pos is None:
            if key is None or k_anchor is None:
                raise ValueError("need (k_anchor, key) or explicit anchor_pos")
            anchor_pos = jax.random.choice(
                key, self.n_items, shape=(k_anchor,), replace=False
            )
        return dataclasses.replace(
            self, anchor_item_pos=jnp.asarray(anchor_pos, jnp.int32),
            u=None, item_embeddings=None,
        )

    def with_latents(
        self,
        k_anchor: Optional[int] = None,
        key: Optional[jax.Array] = None,
        anchor_pos: Optional[jax.Array] = None,
        rcond: float = 1e-6,
    ) -> "AnchorIndex":
        """:meth:`with_anchors` plus the precomputed ANNCUR pieces:
        ``U = pinv(R_anc[:, I_anc])`` and the latent item embeddings
        ``E_I = U @ R_anc`` (what :meth:`topk` searches over)."""
        idx = self.with_anchors(k_anchor=k_anchor, key=key, anchor_pos=anchor_pos)
        anchor_cols = quant.take_columns(idx.r_anc, idx.anchor_item_pos)
        u = cur.pinv(anchor_cols, rcond)                         # (k_i, k_q)
        return dataclasses.replace(
            idx, u=u, item_embeddings=quant.matmul(u, idx.r_anc)
        )

    def query_embedding(self, c_anchor: jax.Array) -> jax.Array:
        """(B, k_i) exact anchor scores -> (B, k_q) latent query embedding."""
        if self.u is None:
            raise ValueError("index has no latents; call with_latents() first")
        return c_anchor @ self.u

    # ---- dynamic corpus (padded capacity + n_valid, shapes never change) ---

    def add_items(
        self,
        new_item_ids: jax.Array,
        cols: Optional[jax.Array] = None,
        bulk_score_fn: Optional[BulkScoreFn] = None,
        new_tokens: Optional[jax.Array] = None,
    ) -> "AnchorIndex":
        """Append items into the padded tail.  ``cols`` is the (k_q, n_new)
        exact score block (computed via ``bulk_score_fn`` when omitted);
        latent item embeddings extend incrementally (``U`` is unchanged —
        the anchor columns are untouched).  An index carrying a token table
        requires ``new_tokens`` (n_new, item_len) so the table stays aligned
        with the payload.  Host-side offline op."""
        new_item_ids = jnp.asarray(new_item_ids, jnp.int32)
        n_new = int(new_item_ids.shape[0])
        n0 = self.n_items
        if n0 + n_new > self.capacity:
            raise ValueError(
                f"add_items overflows capacity {self.capacity} "
                f"({n0} + {n_new}); rebuild via with_capacity() first"
            )
        new_host = np.asarray(new_item_ids)
        if (new_host < 0).any():
            raise ValueError("add_items: item ids must be >= 0 (-1 is the padding sentinel)")
        if np.unique(new_host).size != n_new:
            raise ValueError("add_items: duplicate item ids in the new batch")
        if np.intersect1d(new_host, np.asarray(self.item_ids[: n0])).size:
            raise ValueError("add_items: some item ids already in the index")
        if cols is None:
            if bulk_score_fn is None:
                raise ValueError("need cols or bulk_score_fn")
            cols = bulk_score_fn(self.anchor_query_ids, new_item_ids)
        cols = jnp.asarray(cols, jnp.float32)
        if cols.shape != (self.k_q, n_new):
            raise ValueError(f"cols {cols.shape} != ({self.k_q}, {n_new})")
        tok = self.item_tokens
        if tok is not None:
            if new_tokens is None:
                raise ValueError(
                    "this index carries a token table (with_item_tokens); "
                    "add_items needs new_tokens (n_new, item_len) to keep it "
                    "position-aligned with the payload"
                )
            new_tokens = jnp.asarray(new_tokens, jnp.int32)
            if new_tokens.shape != (n_new, tok.shape[1]):
                raise ValueError(
                    f"new_tokens {new_tokens.shape} != ({n_new}, {tok.shape[1]})"
                )
            tok = jax.lax.dynamic_update_slice(tok, new_tokens, (n0, 0))
        elif new_tokens is not None:
            raise ValueError(
                "new_tokens given but the index carries no token table; "
                "attach one first (with_item_tokens)"
            )
        if self._quantized:
            # re-quantize only the tiles the new column range touches
            r_anc = quant.update_columns(self.r_anc, cols, n0)
        else:
            r_anc = jax.lax.dynamic_update_slice(
                self.r_anc, cols.astype(self.r_anc.dtype), (0, n0)
            )
        emb = self.item_embeddings
        return dataclasses.replace(
            self,
            r_anc=r_anc,
            item_ids=jax.lax.dynamic_update_slice(self.item_ids, new_item_ids, (n0,)),
            n_valid=jnp.asarray(n0 + n_new, jnp.int32),
            item_embeddings=(
                None if emb is None
                else jax.lax.dynamic_update_slice(
                    emb, (self.u @ cols).astype(emb.dtype), (0, n0)
                )
            ),
            item_tokens=tok,
        )

    def remove_items(self, remove_item_ids: jax.Array) -> "AnchorIndex":
        """Drop items by external id via *stable compaction*: surviving
        columns keep their relative order (so a removal is bit-identical to a
        from-scratch rebuild over the survivors), freed slots join the padded
        tail, and shapes never change.  On a quantized payload only the
        tiles from the first removed column onward re-quantize — the prefix
        keeps bit-identical codes and scales.  Host-side offline op."""
        cap = self.capacity
        rm = self.valid_mask() & jnp.isin(
            self.item_ids, jnp.asarray(remove_item_ids, jnp.int32)
        )
        if self.anchor_item_pos is not None and bool(rm[self.anchor_item_pos].any()):
            raise ValueError(
                "remove_items would drop an ANNCUR anchor item; rebuild the "
                "latents (with_latents) with a surviving anchor set first"
            )
        perm = jnp.argsort(rm.astype(jnp.int32), stable=True)  # survivors first, in order
        n1 = self.n_items - int(rm.sum())
        keep = jnp.arange(cap, dtype=jnp.int32) < n1
        if self._quantized:
            dense = quant.dequantize(self.r_anc)
            dense = jnp.where(keep[None, :], dense[:, perm], 0)
            # columns before the first removed position survive in place
            first_rm = int(jnp.argmax(rm)) if n1 < self.n_items else cap
            r_anc = quant.requantize_preserving_prefix(self.r_anc, dense, first_rm)
        else:
            r_anc = jnp.where(keep[None, :], self.r_anc[:, perm], 0)
        emb = self.item_embeddings
        tok = self.item_tokens
        new = dataclasses.replace(
            self,
            r_anc=r_anc,
            item_ids=jnp.where(keep, self.item_ids[perm], -1),
            n_valid=jnp.asarray(n1, jnp.int32),
            item_embeddings=(
                None if emb is None else jnp.where(keep[None, :], emb[:, perm], 0)
            ),
            item_tokens=(
                None if tok is None else jnp.where(keep[:, None], tok[perm], 0)
            ),
        )
        if self.anchor_item_pos is not None:
            inv = jnp.argsort(perm)                  # old position -> new
            new = dataclasses.replace(
                new, anchor_item_pos=inv[self.anchor_item_pos].astype(jnp.int32)
            )
        return new

    # ---- persistence (versioned, on the Checkpointer machinery) ------------

    def _tree(self) -> dict:
        t = {
            "anchor_query_ids": self.anchor_query_ids,
            "item_ids": self.item_ids,
            "n_valid": self.n_valid,
        }
        if self._quantized:
            t["r_codes"] = self.r_anc.codes
            t["r_scales"] = self.r_anc.scales
        else:
            t["r_anc"] = self.r_anc
        if self.anchor_item_pos is not None:
            t["anchor_item_pos"] = self.anchor_item_pos
        if self.has_latents:
            t.update(u=self.u, item_embeddings=self.item_embeddings)
        if self.item_tokens is not None:
            t["item_tokens"] = self.item_tokens
        return t

    def save(self, path: str) -> None:
        """Persist atomically under ``path`` (Checkpointer layout: one .npy
        per leaf + manifest with each leaf's save-time PartitionSpec, so a
        pod-scale index restores elastically onto any mesh)."""
        tree = self._tree()

        def leaf_spec(x, default: P) -> P:
            # record the ACTUAL placement of a sharded leaf; unsharded
            # leaves get the canonical default so a later load(mesh) still
            # distributes the item axis
            sh = getattr(x, "sharding", None)
            if isinstance(sh, NamedSharding) and sh.mesh.size > 1:
                return sh.spec
            return default

        defaults = {
            "r_anc": P(None, "data"),
            "r_codes": P(None, "data"),        # co-sharded with r_scales:
            "r_scales": P("data"),             # items axis == tiles axis
            "anchor_query_ids": P(),
            "item_ids": P("data"),
            "n_valid": P(),
            "anchor_item_pos": P(),
            "u": P(),
            "item_embeddings": P(None, "data"),
            "item_tokens": P("data", None),
        }
        specs = {k: leaf_spec(v, defaults[k]) for k, v in tree.items()}
        ck = Checkpointer(path, async_save=False)
        ck.save(_CKPT_STEP, tree, specs)
        # stamp the lowest version whose on-disk features this index uses
        if self._quantized and self.r_anc.code_dtype != "int8":
            version = 4          # sub-int8 codes: packed int4 / fp8-e4m3
        elif self.item_tokens is not None:
            version = 3
        elif self._quantized:
            version = 2
        else:
            version = 1
        payload_meta = {
            "dtype": self.payload_dtype,
            "tile": self.r_anc.tile if self._quantized else None,
        }
        if self._quantized:
            payload_meta["code_dtype"] = self.r_anc.code_dtype
            payload_meta["n_cols"] = self.r_anc.n_cols
        meta = {
            "format_version": version,
            "k_q": self.k_q,
            "capacity": self.capacity,
            "n_items": self.n_items,
            "dtype": str(self.r_anc.dtype),
            "has_latents": self.has_latents,
            "payload": payload_meta,
        }
        tmp = os.path.join(path, _META_FILE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(meta, f)
        os.replace(tmp, os.path.join(path, _META_FILE))

    @classmethod
    def load(cls, path: str, mesh: Optional[Mesh] = None) -> "AnchorIndex":
        """Load a saved index; with a mesh, leaves are device_put with their
        save-time specs re-resolved on the new mesh (elastic restore)."""
        meta_path = os.path.join(path, _META_FILE)
        if not os.path.exists(meta_path):
            raise FileNotFoundError(f"no AnchorIndex at {path!r} ({_META_FILE} missing)")
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("format_version") not in _READABLE_FORMAT_VERSIONS:
            raise ValueError(
                f"unsupported AnchorIndex format version {meta.get('format_version')} "
                f"(this build reads versions {_READABLE_FORMAT_VERSIONS})"
            )
        with open(os.path.join(path, f"step_{_CKPT_STEP}", "manifest.json")) as f:
            manifest = json.load(f)
        like = {
            k: jax.ShapeDtypeStruct(tuple(v["shape"]), np.dtype(v["dtype"]))
            for k, v in manifest["leaves"].items()
        }
        tree = Checkpointer(path, async_save=False).restore(_CKPT_STEP, like, mesh=mesh)
        if "r_codes" in tree:
            payload = meta.get("payload") or {}
            # v2/v3 meta predates sub-int8 codes: default to the int8 layout
            tree["r_anc"] = QuantizedRanc(
                codes=tree.pop("r_codes"),
                scales=tree.pop("r_scales"),
                tile=int(payload.get("tile") or quant.DEFAULT_TILE),
                code_dtype=str(payload.get("code_dtype") or "int8"),
                n_cols=int(payload.get("n_cols", -1)),
            )
        return cls(**tree)

    # ---- sharding + sharded search -----------------------------------------

    def shard(self, mesh: Mesh, rules=None) -> "AnchorIndex":
        """Place the item axis over ``mesh`` (capacity is re-padded to a
        shardable multiple if needed).  The placement lives in the arrays'
        own ``NamedSharding`` — it survives mutation (`add_items` etc.) and
        pytree ops — and :meth:`topk` / the retrievers' SPMD engine read it
        back to search under ``shard_map``.  A quantized payload co-shards
        codes and scales, and capacity aligns to
        ``n_item_shards * lcm(tile, NOISE_BLOCK)`` so every shard owns whole
        quantization tiles (with their scales) AND whole blocks of the
        engine's canonical noise field (the bit-parity requirement of
        ``sampling.blocked_gumbel``)."""
        from .sampling import NOISE_BLOCK

        idx = self
        grain = math.lcm(idx.r_anc.tile if idx._quantized else 1, NOISE_BLOCK)
        # learn which mesh axes the rules give the item axis (probe with a
        # capacity every axis divides), then align only to THOSE shards —
        # on a (data x items) mesh the data axis must not inflate the pad
        probe = sharding.spec_for(
            mesh, ("anchor_q", "items"), (idx.k_q, mesh.size * grain), rules
        )
        item_axes = probe[1] if len(probe) > 1 else None
        if item_axes is None:
            raise ValueError(
                f"item axis not shardable over mesh {dict(mesh.shape)}"
            )
        axes = (item_axes,) if isinstance(item_axes, str) else tuple(item_axes)
        n_item_shards = 1
        for a in axes:
            n_item_shards *= mesh.shape[a]
        unit = n_item_shards * grain
        if idx.capacity % unit:
            idx = idx.with_capacity(-(-idx.capacity // unit) * unit)

        def put(x, s):
            return jax.device_put(x, NamedSharding(mesh, s))

        if idx._quantized:
            r_anc = QuantizedRanc(
                codes=put(idx.r_anc.codes, P(None, axes)),
                scales=put(idx.r_anc.scales, P(axes)),
                tile=idx.r_anc.tile,
                code_dtype=idx.r_anc.code_dtype,
                n_cols=idx.r_anc.n_cols,
            )
        else:
            r_anc = put(idx.r_anc, P(None, axes))
        emb = idx.item_embeddings
        tok = idx.item_tokens
        out = dataclasses.replace(
            idx,
            r_anc=r_anc,
            anchor_query_ids=put(idx.anchor_query_ids, P()),
            item_ids=put(idx.item_ids, P(axes)),
            n_valid=put(idx.n_valid, P()),
            item_embeddings=None if emb is None else put(emb, P(None, axes)),
            item_tokens=None if tok is None else put(tok, P(axes, None)),
        )
        if idx.anchor_item_pos is not None:
            out = dataclasses.replace(
                out,
                anchor_item_pos=put(idx.anchor_item_pos, P()),
                u=put(idx.u, P()),
            )
        return out

    def _item_sharding(self) -> Tuple[Optional[Mesh], Optional[Tuple[str, ...]]]:
        """(mesh, item axes) read back from ``r_anc``'s NamedSharding, or
        (None, None) when the item axis is unsharded/replicated."""
        sh = getattr(self._payload_leaf(), "sharding", None)
        if not isinstance(sh, NamedSharding) or sh.mesh.size == 1:
            return None, None
        spec = sh.spec
        item_axes = spec[1] if len(spec) > 1 else None
        if item_axes is None:
            return None, None
        axes = (item_axes,) if isinstance(item_axes, str) else tuple(item_axes)
        return sh.mesh, axes

    def topk(
        self,
        e_q: jax.Array,
        k: int,
        *,
        mesh: Optional[Mesh] = None,
        item_axes: Optional[Tuple[str, ...]] = None,
        tile: int = 512,
        interpret: bool = True,
    ) -> Tuple[jax.Array, jax.Array]:
        """Top-k of ``e_q @ R_anc`` over *valid* items -> (vals, positions).

        On a sharded index (``shard(mesh)``, ``load(path, mesh)``, or
        explicit mesh/item_axes) each shard runs the fused ``approx_topk``
        over its local item slab — global (B, N) scores are never
        materialized anywhere — and the per-shard candidates are merged with
        an all-gather + top-k (the cross-shard merge is over n_shards·k
        entries, ≪ N).  The placement is detected from ``r_anc``'s
        ``NamedSharding``, so mutated/replaced indices keep their path.
        """
        if mesh is None and item_axes is None:
            mesh, item_axes = self._item_sharding()
        invalid = ~self.valid_mask()
        b = e_q.shape[0]
        if mesh is None:
            mask = jnp.broadcast_to(invalid[None, :], (b, self.capacity))
            return approx_topk_op(
                e_q, self.r_anc, None, k, tile=tile, interpret=interpret, mask=mask
            )
        axes = item_axes
        if axes is None:
            raise ValueError("sharded topk needs item_axes alongside mesh")
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        n_local = self.capacity // n_shards
        if k > n_local:
            raise ValueError(f"k={k} > per-shard items {n_local}")
        quantized = self._quantized
        tile_q = self.r_anc.tile if quantized else 0
        cdt = self.r_anc.code_dtype if quantized else "int8"

        def body(eq, r_local, scales_local, inv_local):
            shard_id = jnp.int32(0)
            for a in axes:
                shard_id = shard_id * jax.lax.psum(1, a) + jax.lax.axis_index(a)
            if quantized:
                # codes + scales arrive co-sharded: the local slab is a
                # self-contained payload over this shard's whole tiles
                # (shard widths are whole even tiles, so the packed width
                # sentinel n_cols=-1 resolves correctly per shard)
                r_local = QuantizedRanc(r_local, scales_local, tile_q, cdt)
            mask = jnp.broadcast_to(inv_local[None, :], (eq.shape[0], n_local))
            v, i = approx_topk_op(
                eq, r_local, None, k, tile=min(tile, n_local),
                interpret=interpret, mask=mask,
            )
            gi = i + shard_id * n_local
            vg = jax.lax.all_gather(v, axes, axis=1, tiled=True)   # (B, S*k)
            ig = jax.lax.all_gather(gi, axes, axis=1, tiled=True)
            vt, pos = jax.lax.top_k(vg, k)
            return vt, jnp.take_along_axis(ig, pos, axis=1)

        if quantized:
            payload_args = (self.r_anc.codes, self.r_anc.scales)
        else:
            payload_args = (self.r_anc, jnp.zeros((n_shards,), jnp.float32))
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(P(), P(None, axes), P(axes), P(axes)),
            out_specs=(P(), P()),
            check_vma=False,
        )
        return fn(e_q, *payload_args, invalid)

    def engine_search(self, score_fn, query, cfg, key=None, **kw):
        """One-shot FULL multi-round search over this index — the engine
        twin of :meth:`topk`.  On a sharded index (``shard(mesh)`` /
        ``load(path, mesh)``) the whole round loop runs as one SPMD program
        under ``shard_map`` (``engine.make_sharded_engine``), bit-identical
        to the single-device engine; otherwise it is the plain compiled
        engine.  For repeated queries hold a
        ``Retriever.from_index``-built retriever instead — this constructs
        one per call."""
        from .engine import AdaCURRetriever

        ret = AdaCURRetriever.from_index(self, score_fn, cfg)
        return ret.search(query, key, **kw)

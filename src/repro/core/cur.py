"""CUR decomposition primitives for ANNCUR/ADACUR.

The paper (Alg. 2) approximates all-item scores for a test query as

    S_hat = C_test @ pinv(R_anc[:, I_anc]) @ R_anc

with ``R_anc ∈ R^{k_q x N}`` the offline anchor-query/all-item score matrix,
``I_anc`` the anchor-item column subset and ``C_test ∈ R^{k_i}`` the exact CE
scores of the test query against the anchor items.

This module provides:

- ``approx_scores``       — the faithful Alg. 2 (batched over queries);
- ``query_embedding``     — the beyond-paper ``e_q = C_test @ U`` factoring
  (one rank-k_q GEMM against R_anc instead of two large GEMMs per round);
- ``pinv`` / ``block_pinv_extend`` — full and *incremental* Moore-Penrose
  pseudo-inverse.  The paper recomputes the pinv from scratch every round,
  O(k_q·k_i²); the incremental bordering update is O(k_q·k_i·k_s) per round
  and is validated against the full pinv in tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def pinv(a: jax.Array, rcond: float = 1e-6) -> jax.Array:
    """Moore-Penrose pseudo-inverse (SVD-based, batched over leading dims)."""
    return jnp.linalg.pinv(a, rtol=rcond)


def gather_anchor_columns(
    r_anc: jax.Array, anchor_idx: jax.Array, via_onehot: bool = False
) -> jax.Array:
    """R_anc[:, I_anc] for a batch of per-query anchor sets.

    Args:
      r_anc: (k_q, N) anchor-query/item scores.
      anchor_idx: (B, k) int32 anchor item ids.
      via_onehot: express the gather as a one-hot matmul.  Under SPMD with
        R_anc column-sharded, a plain gather makes XLA REPLICATE the 2 GB
        table per device; the matmul contracts the sharded axis shard-local
        and psums the (B, k_q, k) result instead.

    Returns:
      (B, k_q, k) per-query anchor column subsets.
    """
    if via_onehot:
        n = r_anc.shape[1]
        onehot = (
            anchor_idx[:, None, :] == jnp.arange(n)[None, :, None]
        ).astype(r_anc.dtype)                                # (B, N, k)
        return jnp.einsum("qn,bnk->bqk", r_anc, onehot)
    # take along the item axis; result (B, k_q, k)
    return jnp.swapaxes(r_anc.T[anchor_idx], 1, 2)


def query_embedding(
    r_anc_cols: jax.Array, c_test: jax.Array, rcond: float = 1e-6
) -> jax.Array:
    """e_q = C_test @ pinv(R_anc[:, I_anc])  — (B, k_q).

    ``S_hat = e_q @ R_anc`` then reconstructs Alg. 2 line 7 with a single
    (B,k_q)x(k_q,N) GEMM.
    """
    u = pinv(r_anc_cols, rcond)  # (B, k, k_q)
    return jnp.einsum("bk,bkq->bq", c_test, u)


def approx_scores(
    r_anc: jax.Array,
    c_test: jax.Array,
    anchor_idx: jax.Array,
    rcond: float = 1e-6,
) -> jax.Array:
    """Faithful Algorithm 2: approximate scores of ALL items for each query.

    Args:
      r_anc: (k_q, N).
      c_test: (B, k) exact CE scores of each query against its anchors.
      anchor_idx: (B, k) anchor item ids.

    Returns:
      (B, N) approximate scores.
    """
    cols = gather_anchor_columns(r_anc, anchor_idx)      # (B, k_q, k)
    e_q = query_embedding(cols, c_test, rcond)           # (B, k_q)
    return e_q @ r_anc                                   # (B, N)


# ---------------------------------------------------------------------------
# Incremental (bordered) pseudo-inverse  — beyond-paper optimization #1
# ---------------------------------------------------------------------------


def _bordered_blocks(
    a: jax.Array, p: jax.Array, b: jax.Array, ridge: float
) -> tuple:
    """Shared core of the bordering update: (D, K) for M = [A | B].

    D = P @ B, C = B - A @ D, and K is pinv(C) blended with the Greville
    fallback ``K = (I + DᵀD)⁻¹ Dᵀ P`` per-column when the residual C is
    (numerically) rank-deficient — new columns inside span(A)."""
    d = p @ b                                      # (n, s)
    c = b - a @ d                                  # (m, s)
    # full-column-rank branch: K1 = (CᵀC + ridge I)⁻¹ Cᵀ
    gram = c.T @ c
    s = gram.shape[-1]
    eye = jnp.eye(s, dtype=gram.dtype)
    scale = jnp.trace(gram) / s + 1.0
    k1 = jnp.linalg.solve(gram + ridge * scale * eye, c.T)
    # rank-deficient branch: K2 = (I + DᵀD)⁻¹ Dᵀ P
    k2 = jnp.linalg.solve(eye + d.T @ d, d.T @ p)
    # DUPLICATE new columns (coarse payload grids — int4 especially — make
    # exact column collisions likely) leave gram singular with a large
    # trace, and the ridge underflows against the fp32 rounding of the
    # diagonal add, so LU turns the whole solve non-finite.  Each c column
    # has a healthy norm there, so the w-blend below would keep the NaNs;
    # fall back to the (always finite) Greville branch instead.  Finite
    # solves pass through untouched, so healthy updates keep their exact
    # bits.
    k1 = jnp.where(jnp.isfinite(k1), k1, k2)
    # per-column blend: column j uses branch 1 iff ‖c_j‖² is non-negligible
    # relative to ‖b_j‖².
    c_norm = jnp.sum(c * c, axis=0)
    b_norm = jnp.sum(b * b, axis=0) + 1e-30
    w = (c_norm > 1e-10 * b_norm).astype(k1.dtype)[:, None]
    return d, w * k1 + (1.0 - w) * k2


def block_pinv_extend(
    a: jax.Array,
    p: jax.Array,
    b: jax.Array,
    ridge: float = 1e-8,
) -> jax.Array:
    """Extend ``P = pinv(A)`` to ``pinv([A | B])`` via the bordering identity.

    For M = [A B] with A (m,n), P = A⁺ (n,m), B (m,s):

        D = P @ B                     (n,s)
        C = B - A @ D                 (m,s)  residual of B off col-space(A)
        K = pinv(C)                   (s,m)  [full-col-rank fast path below]
        M⁺ = [ P - D @ K ]
             [     K     ]

    When C is (numerically) rank-deficient — the new columns lie in the span
    of the old — the Greville fallback ``K = (I + DᵀD)⁻¹ Dᵀ P`` applies; we
    blend the two branches per-column on a residual-magnitude test so the
    update stays jit-friendly (no data-dependent control flow).

    Anchor matrices here are tall (k_q anchor queries ≫ k_i anchor items), so
    the full-column-rank branch is the hot path; the ridge keeps the small
    (s,s) solves well-posed.
    """
    d, k = _bordered_blocks(a, p, b, ridge)
    top = p - d @ k
    return jnp.concatenate([top, k], axis=0)


def block_pinv_extend_static(
    a_full: jax.Array,
    p_full: jax.Array,
    b: jax.Array,
    start,
    ridge: float = 1e-8,
) -> jax.Array:
    """Shape-invariant bordering update over *preallocated* buffers.

    ``a_full`` (m, K) holds the anchor columns filled so far in columns
    [0, start) with exact zeros beyond; ``p_full`` (K, m) holds their pinv in
    rows [0, start) with exact zeros beyond.  The new block ``b`` (m, s) is
    incorporated by writing its K-rows into [start, start+s) — the same math
    as :func:`block_pinv_extend` (the zero padding contributes exact zeros to
    every contraction), but with static shapes so the multi-round engine's
    loop body is trace-invariant and ``start`` may be a traced index.
    """
    d, k = _bordered_blocks(a_full, p_full, b, ridge)
    top = p_full - d @ k        # rows >= start stay exactly zero (p, d zero)
    return jax.lax.dynamic_update_slice(top, k, (start, 0))


def incremental_pinv_init(a0: jax.Array, rcond: float = 1e-6) -> jax.Array:
    """pinv of the first anchor block (computed once, full SVD)."""
    return pinv(a0, rcond)


def cur_reconstruction(
    r_anc: jax.Array, anchor_idx: jax.Array, rows: jax.Array, rcond: float = 1e-6
) -> jax.Array:
    """Full CUR reconstruction M̃ = C U R of arbitrary score rows.

    Used by the ANNCUR offline index and by approximation-error benchmarks:
    ``rows`` is (B, k) exact scores of B queries on the anchor columns, the
    return is the (B, N) approximation of their full score rows.
    """
    return approx_scores(r_anc, rows, anchor_idx, rcond)

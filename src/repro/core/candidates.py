"""First-stage candidate generation + candidate-subset hybrid retrieval.

Production k-NN with cross-encoders is multi-stage: a cheap first stage
proposes a shortlist and the expensive CE decides (cf. multi-stage dense
retrieval, arXiv 2108.11480).  This module supplies both halves on top of
the engine:

- :class:`CandidateGenerator` providers — a dual-encoder dot-product top-k
  over corpus embeddings through the fused ``approx_topk`` kernel
  (:class:`DualEncoderCandidates`), a BM25 sparse-lexical provider running
  host-side behind ``jax.pure_callback`` with *runtime* accounting, the
  same idiom as ``TabulatedScorer`` (:class:`BM25Candidates`), and an
  oracle provider for tests (:class:`OracleCandidates`);
- candidate-subset search — :func:`union_candidates` unions a batch's
  shortlists into a sorted, padded position vector *inside the trace*, the
  payload columns at those positions are gathered into a compact sub-index
  (:func:`quant.subset_columns` — coded payloads (int8 / packed int4 / fp8)
  keep their code bytes and carry per-column source-tile scales, so no
  re-quantization), and the engine
  runs over the sub-index with ``pos_map`` remapping every noise draw to
  the original corpus coordinates.  The subset search is **bit-identical**
  to the same engine search over the full corpus with an ``eligible``
  candidate mask (asserted across loop modes x payload dtypes by
  ``tests/test_candidates.py``), and because the union/gather/search
  pipeline is one jitted program over value operands, queries with
  different candidate sets never retrace;
- :class:`HybridRetriever` — first stage -> ADACUR over the candidates,
  behind the same :class:`~repro.core.engine.Retriever` protocol as the
  other methods.  ``mode='subset'`` streams only the shortlist's columns
  per round (the perf path); ``mode='mask'`` restricts each query to its
  own candidates over the full (possibly mesh-sharded) corpus via the
  engine's ``eligible`` operand (the quality/SPMD path).

Budget accounting is untouched by the first stage: candidate generation
spends zero CE calls, and the engine still scores exactly
:func:`~repro.core.engine.ce_call_plan` pairs per query — measured ==
planned holds verbatim under a first stage (property suite + CI gate).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig
from ..kernels.approx_topk import quant
from ..kernels.approx_topk.ops import approx_topk_op
from .adacur import AdaCURResult, ScoreFn
from .engine import _IndexBacked, ce_call_plan, engine_search


@dataclass
class GeneratorStats:
    """Measured first-stage accounting (host-side for host providers)."""

    requests: int = 0        # generator invocations observed
    candidates: int = 0      # candidate slots returned

    def copy(self) -> "GeneratorStats":
        return dataclasses.replace(self)

    def __sub__(self, other: "GeneratorStats") -> "GeneratorStats":
        return GeneratorStats(
            requests=self.requests - other.requests,
            candidates=self.candidates - other.candidates,
        )


@runtime_checkable
class CandidateGenerator(Protocol):
    """First-stage provider: query batch -> (B, k) candidate positions.

    Returned positions index the *corpus axis* (engine positions, not
    external ids), are ordered by descending first-stage score, and must
    lie in ``[0, n_valid)`` of the index being searched.
    """

    stats: GeneratorStats

    def __call__(self, query, k: int) -> jax.Array: ...


@dataclass
class DualEncoderCandidates:
    """Dual-encoder dot-product shortlist via the fused approx_topk kernel.

    ``i_emb`` (N, d) corpus embeddings are held transposed as a (d, N)
    "payload" so the kernel streams item tiles exactly like an anchor
    payload — no (B, N) score matrix is ever formed.  Deterministic: exact
    dot-product ties break by ascending item position (kernel contract).
    Pure-traced (fuses into a jitted pipeline), so stats are counted at
    trace time like :class:`~repro.core.scorer.SyntheticScorer`'s.
    """

    q_emb: jax.Array                    # (n_queries, d) query embeddings
    i_emb: jax.Array                    # (N, d) corpus item embeddings
    n_valid: Optional[int] = None       # static valid-prefix bound
    tile: int = 1024
    interpret: bool = True
    stats: GeneratorStats = field(default_factory=GeneratorStats)

    def __post_init__(self):
        self._i_emb_t = jnp.asarray(self.i_emb, jnp.float32).T   # (d, N)
        self._q_emb = jnp.asarray(self.q_emb, jnp.float32)

    def reset_stats(self) -> None:
        self.stats = GeneratorStats()

    def __call__(self, query, k: int) -> jax.Array:
        qids = jnp.asarray(query)
        self.stats.requests += 1
        self.stats.candidates += int(qids.shape[0]) * k
        e = jnp.take(self._q_emb, qids, axis=0)
        _, idx = approx_topk_op(
            e, self._i_emb_t, None, k, tile=self.tile,
            interpret=self.interpret, n_valid=self.n_valid,
        )
        return idx


class BM25Candidates:
    """BM25 sparse-lexical shortlist, host-side behind ``pure_callback``.

    The corpus statistics (term frequencies, document lengths, idf) are
    folded at construction into one (N, V) weight matrix ``W`` with
    ``W[d, t] = idf[t] * tf[d, t] * (k1 + 1) / (tf[d, t] + k1 * (1 - b +
    b * dl[d] / avgdl))`` — Robertson/Sparck-Jones BM25 — so scoring a
    query is one ``qtf @ W.T`` contraction over its term counts.  Ties
    break by ascending document position (stable argsort), matching the
    engine's tie-break convention.

    Like :class:`~repro.core.scorer.TabulatedScorer`, the callback counts
    at *runtime*: every jitted pipeline invocation increments the stats,
    so first-stage work is measured, not assumed.  The callback is
    numpy-only and therefore safe under the SPMD engine's host-callback
    constraint.
    """

    def __init__(
        self,
        corpus_tokens,
        query_tokens,
        k1: float = 1.5,
        b: float = 0.75,
        pad_id: int = 0,
        n_valid: Optional[int] = None,
    ):
        corpus_tokens = np.asarray(corpus_tokens)
        self.query_tokens = np.asarray(query_tokens)
        self.pad_id = pad_id
        self.stats = GeneratorStats()
        n_docs = corpus_tokens.shape[0]
        self.n_valid = n_docs if n_valid is None else int(n_valid)
        vocab = int(max(corpus_tokens.max(), self.query_tokens.max())) + 1
        self.vocab = vocab

        tf = np.zeros((n_docs, vocab), np.float32)
        np.add.at(
            tf,
            (np.repeat(np.arange(n_docs), corpus_tokens.shape[1]),
             corpus_tokens.ravel()),
            1.0,
        )
        tf[:, pad_id] = 0.0
        dl = tf.sum(axis=1)
        avgdl = max(float(dl[: self.n_valid].mean()), 1e-9)
        df = (tf[: self.n_valid] > 0).sum(axis=0).astype(np.float32)
        idf = np.log(1.0 + (self.n_valid - df + 0.5) / (df + 0.5))
        denom = tf + k1 * (1.0 - b + b * dl[:, None] / avgdl)
        self._w = np.where(tf > 0, idf[None, :] * tf * (k1 + 1.0) / denom, 0.0)
        self._w = self._w.astype(np.float32)

    def reset_stats(self) -> None:
        self.stats = GeneratorStats()

    def _host(self, qids: np.ndarray, k: int) -> np.ndarray:
        qids = np.asarray(qids)
        self.stats.requests += 1
        self.stats.candidates += int(qids.size) * k
        toks = self.query_tokens[qids]                          # (B, L)
        qtf = np.zeros((qids.size, self.vocab), np.float32)
        np.add.at(
            qtf,
            (np.repeat(np.arange(qids.size), toks.shape[1]), toks.ravel()),
            1.0,
        )
        qtf[:, self.pad_id] = 0.0
        scores = qtf @ self._w.T                                # (B, N)
        scores[:, self.n_valid:] = -np.inf
        order = np.argsort(-scores, axis=1, kind="stable")[:, :k]
        return order.astype(np.int32)

    def __call__(self, query, k: int) -> jax.Array:
        qids = jnp.asarray(query)
        return jax.pure_callback(
            lambda q: self._host(q, k),
            jax.ShapeDtypeStruct((qids.shape[0], k), jnp.int32),
            qids,
        )


@dataclass
class OracleCandidates:
    """Candidates from the exact CE score matrix — the testing upper bound.

    A first stage with perfect recall@k: isolates the engine's contribution
    to hybrid quality from the generator's (and gives invariant tests a
    deterministic, trivially checkable candidate set).
    """

    exact_scores: jax.Array             # (n_queries, N)
    n_valid: Optional[int] = None
    stats: GeneratorStats = field(default_factory=GeneratorStats)

    def reset_stats(self) -> None:
        self.stats = GeneratorStats()

    def __call__(self, query, k: int) -> jax.Array:
        qids = jnp.asarray(query)
        self.stats.requests += 1
        self.stats.candidates += int(qids.shape[0]) * k
        s = jnp.take(jnp.asarray(self.exact_scores), qids, axis=0)
        if self.n_valid is not None and self.n_valid < s.shape[1]:
            s = jnp.where(
                jnp.arange(s.shape[1]) < self.n_valid, s, -jnp.inf
            )
        return jax.lax.top_k(s, k)[1]


# ---------------------------------------------------------------------------
# Candidate-subset machinery
# ---------------------------------------------------------------------------


def union_candidates(cand: jax.Array, capacity: int, n_corpus: int):
    """Sorted union of a batch's candidate positions, padded to ``capacity``.

    Runs inside the trace (``jnp.unique`` with a static size), so varying
    candidate sets never retrace.  Returns ``(pos, valid, n_sub)``: ``pos``
    (capacity,) int32 ascending with padded slots clamped to position 0
    (their ``valid`` is False — :func:`quant.subset_columns` zeroes them),
    and ``n_sub`` the traced union size.  Entries >= ``n_corpus`` are
    treated as padding.  If the true union exceeds ``capacity`` the largest
    positions are dropped — size the capacity to ``B * shortlist_k`` (as
    :class:`HybridRetriever` does) and that never happens.
    """
    u = jnp.unique(
        jnp.asarray(cand, jnp.int32).ravel(), size=capacity,
        fill_value=n_corpus,
    )
    n_sub = jnp.sum(u < n_corpus).astype(jnp.int32)
    valid = jnp.arange(capacity, dtype=jnp.int32) < n_sub
    pos = jnp.where(valid, u, 0).astype(jnp.int32)
    return pos, valid, n_sub


def candidate_eligibility(
    cand: jax.Array, n_items: int, per_query: bool = True
) -> jax.Array:
    """Scatter (B, M) candidate positions into the engine's ``eligible``
    mask — (B, N) when ``per_query`` (each row restricted to its own
    shortlist), else the (N,) batch union.  Out-of-range positions drop."""
    b, _ = cand.shape
    cand = jnp.asarray(cand, jnp.int32)
    if per_query:
        rows = jnp.arange(b, dtype=jnp.int32)[:, None]
        base = jnp.zeros((b, n_items), bool)
        return base.at[rows, cand].set(True, mode="drop")
    return jnp.zeros(n_items, bool).at[cand.ravel()].set(True, mode="drop")


@dataclass
class HybridRetriever(_IndexBacked):
    """First-stage shortlist -> ADACUR over the candidates, one jit.

    ``mode='subset'`` (default): the batch's shortlists are unioned and
    their payload columns gathered into a compact padded sub-index; the
    multi-round engine then streams C = O(B * shortlist_k) columns per
    round instead of N, with ``pos_map`` keeping every noise draw on the
    original corpus coordinates (bit-identical to the masked full-corpus
    search).  Single-device only.

    ``mode='mask'``: each query is restricted to its *own* shortlist via
    the engine's per-query ``eligible`` mask over the full corpus — no
    payload gather, works under the SPMD sharded engine, and typically
    higher quality (row i never spends budget on row j's candidates).

    Either way the engine's CE budget accounting is exact:
    :meth:`ce_call_plan` is the engine's plan verbatim (the first stage is
    CE-free), and ``shortlist_k`` must cover it so sampling never runs out
    of eligible items.
    """

    score_fn: ScoreFn
    generator: CandidateGenerator
    cfg: AdaCURConfig
    r_anc: Optional[jax.Array] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    shortlist_k: int = 0
    subset_capacity: Optional[int] = None
    mode: str = "subset"
    jit: bool = True
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        if self.mode not in ("subset", "mask"):
            raise ValueError(f"unknown mode '{self.mode}' (subset|mask)")
        if self.shortlist_k < self.cfg.budget_ce:
            raise ValueError(
                f"shortlist_k={self.shortlist_k} < budget_ce="
                f"{self.cfg.budget_ce}: every query must propose at least "
                f"budget_ce candidates or the engine would sample "
                f"ineligible items"
            )
        self._apply_payload_policy(self.cfg)
        if self.r_anc is not None:
            # pre-apply the payload policy so subset gathers slice the SAME
            # payload a full-corpus search would stream (bit-parity)
            self.r_anc = quant.as_payload(
                self.r_anc, self.cfg.payload_dtype, self.cfg.payload_tile
            )
        sharded = False
        if self.index is not None:
            sharded = self.index._item_sharding()[0] is not None
        if self.mode == "subset":
            if sharded:
                raise ValueError(
                    "mode='subset' is single-device (pos_map); use "
                    "mode='mask' over a sharded index"
                )
            self._run = self._make_subset_run()
        else:
            self._run = self._build_engine(self.cfg, jit_compile=self.jit)

    def ce_call_plan(self, rounds: Optional[int] = None) -> int:
        """Planned CE calls per query — the engine plan, first stage free."""
        return ce_call_plan(self.cfg, rounds)

    def _operands(self):
        """(payload, item_ids (capacity,), n_valid traced int32)."""
        if self.index is not None:
            return (
                self.index.r_anc,
                self.index.item_ids,
                jnp.asarray(self.index.n_valid, jnp.int32),
            )
        n = self.r_anc.shape[1]
        return (
            self.r_anc,
            jnp.arange(n, dtype=jnp.int32),
            jnp.asarray(n, jnp.int32),
        )

    def _capacity(self, b: int) -> int:
        full = (
            self.index.capacity if self.index is not None
            else self.r_anc.shape[1]
        )
        if self.subset_capacity is not None:
            return min(self.subset_capacity, full)
        want = max(b * self.shortlist_k, self.cfg.budget_ce, self.cfg.k_retrieve)
        return min(-(-want // 128) * 128, full)

    def _make_subset_run(self):
        cfg, score_fn = self.cfg, self.score_fn

        def run(r_anc, item_ids, n_valid, query, cand, key, n_rounds,
                capacity: int):
            n_full = r_anc.shape[1]
            # positions outside the valid prefix become padding
            cand = jnp.where(cand < n_valid, cand, n_full)
            pos, valid, n_sub = union_candidates(cand, capacity, n_full)
            sub = quant.subset_columns(r_anc, pos, valid)
            sub_ids = jnp.where(valid, jnp.take(item_ids, pos), -1)
            res = engine_search(
                score_fn, sub, query, cfg, key, n_valid_items=n_sub,
                n_rounds=n_rounds, return_scores=False, item_ids=sub_ids,
                pos_map=pos,
            )
            # results leave in full-corpus positions, like every retriever
            return dataclasses.replace(
                res,
                anchor_idx=jnp.where(
                    res.anchor_idx >= 0, pos[res.anchor_idx], -1
                ),
                topk_idx=pos[res.topk_idx],
            )

        if self.jit:
            run = jax.jit(run, static_argnames=("capacity",))
        return run

    def search(self, query, key=None, n_rounds=None, **_ignored) -> AdaCURResult:
        key = jax.random.PRNGKey(0) if key is None else key
        cand = self.generator(query, self.shortlist_k)
        if self.cfg.loop_mode == "fori":
            n_rounds = jnp.asarray(
                self.cfg.n_rounds if n_rounds is None else n_rounds, jnp.int32
            )
        elif n_rounds is not None:
            raise ValueError("runtime n_rounds override requires loop_mode='fori'")
        if self.mode == "subset":
            r_anc, item_ids, n_valid = self._operands()
            b = jax.tree_util.tree_leaves(query)[0].shape[0]
            return self._run(
                r_anc, item_ids, n_valid, query, cand, key, n_rounds,
                capacity=self._capacity(b),
            )
        r_anc, kw = self._search_operands()
        n_items = r_anc.shape[1]
        eligible = candidate_eligibility(cand, n_items, per_query=True)
        return self._run(
            r_anc, query, key, n_rounds=n_rounds, eligible=eligible, **kw
        )

"""Static-shape multi-round ADACUR engine + the unified Retriever API.

The seed implementation (``core/adacur.py``, kept as the executable spec and
parity oracle) grows every buffer with ``jnp.concatenate``: each round body
has a different trace shape, so changing ``n_rounds`` recompiles the whole
search and nothing can run under ``lax.fori_loop`` — exactly the non-CE
overhead the paper's Fig. 4 warns about.  This module is the production
path:

- **preallocated slabs**: the anchor-id (B, k_i), exact-score (B, k_i),
  anchor-column (B, k_q, k_i) and incremental-pinv (B, k_i, k_q) buffers are
  allocated once at their final size and round r fills slab
  ``[r·k_s, (r+1)·k_s)`` with ``lax.dynamic_update_slice``.  Unfilled pinv
  rows / anchor columns are exact zeros, which contribute exact zeros to
  every contraction, so the padded math equals the growing-shape math;
- **shape-invariant round body**: runs unrolled (``loop_mode='unrolled'``,
  the seed behavior, any score_fn), under ``lax.fori_loop`` with the round
  count as a *runtime operand* (``loop_mode='fori'`` — per-query-batch round
  counts without retracing, cf. arXiv 2405.03651), or under
  ``lax.while_loop`` with an early-exit tolerance (anytime ADACUR: stop when
  the round-over-round provisional top-k set stabilizes);
- **fused score->sample** (``use_fused_topk``): per-round anchor sampling
  and the final split-budget rerank selection go through the Pallas
  ``approx_topk_op`` so the (B, N) approximate score matrix is never
  materialized — TopK sampling needs no (B, N) intermediate at all, SoftMax
  passes Gumbel noise as a kernel input (Kool et al. 2019);
- **one code path for every method**: :class:`AdaCURRetriever` (the paper),
  :class:`ANNCURRetriever` (fixed anchors = one engine round, arXiv
  2210.12579) and :class:`RerankRetriever` (retrieve-and-rerank = one
  retriever-seeded round with no budget split) are thin configurations of
  :func:`engine_search` behind the common :class:`Retriever` protocol.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Callable, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig, replace
from ..kernels.approx_topk import quant
from ..kernels.approx_topk.ops import approx_topk_op
from . import cur, sampling
from .adacur import AdaCURResult, ScoreFn


def ce_call_plan(cfg: AdaCURConfig, rounds: Optional[int] = None) -> int:
    """Exact CE calls per query for a run executing ``rounds`` rounds.

    Each executed round scores its k_s fresh anchors, plus the split-budget
    rerank (``budget_ce - k_anchor``) once at the end.  This is the single
    source of truth for budget accounting: ``AdaCURResult.ce_calls`` is this
    plan at the full round count, and a counting
    :class:`~repro.core.scorer.Scorer`'s *measured* ``stats.ce_calls`` must
    equal ``ce_call_plan(cfg, rounds_done) * batch`` — asserted per engine
    mode by the property-based invariant suite.
    """
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    k_s = k_i // cfg.n_rounds
    r = cfg.n_rounds if rounds is None else rounds
    if not 1 <= r <= cfg.n_rounds:
        raise ValueError(f"rounds={r} outside [1, {cfg.n_rounds}]")
    k_r = cfg.budget_ce - k_i if cfg.split_budget else 0
    return k_s * r + k_r


class EngineState(NamedTuple):
    """Loop-invariant-shaped state threaded through the round body."""

    anchor_idx: jax.Array    # (B, k_i) int32, -1 in unfilled slots
    c_test: jax.Array        # (B, k_i) exact CE scores, 0 in unfilled slots
    a_buf: jax.Array         # (B, k_q, k_i) anchor columns, 0 beyond filled
    p: jax.Array             # (B, k_i, k_q) incremental pinv, 0 beyond filled
    e_q: jax.Array           # (B, k_q) latent query embedding
    selected: jax.Array      # (B, N) bool mask of already-selected items


def _effective_tile(cfg: AdaCURConfig, r_anc) -> int:
    """Item-tile width of the fused kernel for this payload.

    On the CPU scan backend (``fused_interpret``) the binding constraint is
    the payload tile's L2 residency, so ``cfg.fused_tile`` acts as a
    per-tile *byte* budget expressed in fp32 columns: a quantized payload
    streams proportionally more columns in the same footprint (x4 int8,
    x2 bf16) — which is where the ~4x fewer bytes per round turn into
    wall-clock on CPU.  The compiled TPU kernel keeps the configured column
    count: its VMEM budget is dominated by the (B, T) fp32 score (and
    noise/mask) blocks, which do NOT shrink with the payload dtype —
    widening T 4x there would blow VMEM; the int8 win on TPU is the 4x
    smaller HBM stream per (unchanged) tile."""
    if not cfg.fused_interpret:
        return cfg.fused_tile
    dtype = quant.payload_dtype_of(r_anc)
    if dtype == "int8":
        return cfg.fused_tile * 4
    if dtype == "bfloat16":
        return cfg.fused_tile * 2
    return cfg.fused_tile


def _fused_suppress(
    cfg: AdaCURConfig, state: EngineState, force_mask: bool = False
) -> dict:
    """How the fused op suppresses already-selected items, per backend.

    On TPU (compiled kernel) the (B, k_i) anchor-id list stays resident in
    VMEM and is compared per tile — no (B, N) traffic.  On the CPU scan
    backend the engine's existing (B, N) bool ``selected`` mask is streamed
    tile-by-tile instead: O(B·T) per tile beats the O(B·T·A) id compare.
    ``force_mask`` routes through the mask even on TPU — required when the
    valid-item bound is a *runtime* value (dynamic corpora), because the
    anchor-id compare cannot see the invalid padded tail."""
    if cfg.fused_interpret or force_mask:
        return dict(anchors=None, mask=state.selected)
    return dict(anchors=state.anchor_idx, mask=None)


def _sample_round(
    cfg: AdaCURConfig,
    key: jax.Array,
    state: EngineState,
    r_anc: jax.Array,
    k_eff: int,
    n_valid: Optional[int],
    force_mask: bool = False,
) -> jax.Array:
    """One adaptive round's anchor pick (Alg. 3) — dense or fused.

    ``r_anc`` is any payload type (fp32/bf16 array or int8 QuantizedRanc);
    both branches dequantize per column, the dense one via
    :func:`quant.matmul`, the fused one inside the kernel tiles."""
    if not cfg.use_fused_topk:
        s_hat = quant.matmul(state.e_q, r_anc)
        return sampling.sample(
            cfg.strategy, key, s_hat, state.selected, k_eff, cfg.softmax_temp
        )
    if cfg.strategy == "random":
        return sampling.sample_random(key, state.selected, k_eff)
    suppress = _fused_suppress(cfg, state, force_mask)
    if cfg.strategy == "softmax":
        # temp folds into e_q (scores/temp == (e_q/temp) @ R_anc); Gumbel
        # noise enters the kernel as an input, S_hat stays in VMEM.
        b, n = state.selected.shape
        g = jax.random.gumbel(key, (b, n), dtype=jnp.float32)
        e_q = state.e_q / jnp.asarray(cfg.softmax_temp, state.e_q.dtype)
        _, idx = approx_topk_op(
            e_q, r_anc, k=k_eff, tile=_effective_tile(cfg, r_anc),
            interpret=cfg.fused_interpret, noise=g, n_valid=n_valid,
            **suppress,
        )
        return idx
    # topk: temp > 0 is order-preserving, no noise needed
    _, idx = approx_topk_op(
        state.e_q, r_anc, k=k_eff, tile=_effective_tile(cfg, r_anc),
        interpret=cfg.fused_interpret, n_valid=n_valid, **suppress,
    )
    return idx


def _make_round_body(
    score_fn: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    keys: jax.Array,
    k_s: int,
    n_valid: Optional[int],
    force_mask: bool = False,
) -> Callable[[jax.Array, EngineState], EngineState]:
    """The shape-invariant adaptive round body (rounds 1..n_rounds-1).

    ``r`` may be a python int (unrolled) or a traced int32 (fori/while)."""
    n_rand = int(round(cfg.round_epsilon * k_s))

    def body(r, state: EngineState) -> EngineState:
        key_r = keys[r]
        b = state.selected.shape[0]
        row_ids = jnp.arange(b)[:, None]
        idx_new = _sample_round(
            cfg, key_r, state, r_anc, k_s - n_rand, n_valid, force_mask
        )
        if n_rand:
            # ε-greedy diversity mix (beyond-paper; see AdaCURConfig)
            sel_tmp = state.selected.at[row_ids, idx_new].set(True)
            k_eps = jax.random.fold_in(key_r, 1)
            idx_rand = sampling.sample_random(k_eps, sel_tmp, n_rand)
            idx_new = jnp.concatenate([idx_new, idx_rand], axis=1)
        selected = state.selected.at[row_ids, idx_new].set(True)
        start = r * k_s

        # exact CE scores for the new slab (Alg. 1 line 15)
        c_new = score_fn(query, idx_new)                       # (B, k_s)
        cols_new = quant.gather_columns(
            r_anc, idx_new, via_onehot=cfg.distributed_gather
        )                                                      # (B, k_q, k_s)

        anchor_idx = jax.lax.dynamic_update_slice(
            state.anchor_idx, idx_new, (0, start)
        )
        c_test = jax.lax.dynamic_update_slice(state.c_test, c_new, (0, start))

        # APPROXSCORES state update (Alg. 2) over the padded buffers
        if cfg.incremental_pinv:
            p = jax.vmap(cur.block_pinv_extend_static, in_axes=(0, 0, 0, None))(
                state.a_buf, state.p, cols_new, start
            )
            a_buf = jax.lax.dynamic_update_slice(
                state.a_buf, cols_new, (0, 0, start)
            )
        else:
            a_buf = jax.lax.dynamic_update_slice(
                state.a_buf, cols_new, (0, 0, start)
            )
            p = cur.pinv(a_buf, cfg.pinv_rcond)     # zero cols -> zero rows
        e_q = jnp.einsum("bk,bkq->bq", c_test, p)
        return EngineState(anchor_idx, c_test, a_buf, p, e_q, selected)

    return body


def _provisional_topk(cfg: AdaCURConfig, e_q, r_anc, m: int, n_valid, invalid=None):
    """Top-m candidate ids of S_hat (unmasked) — the early-exit monitor.

    ``invalid`` is the (N,) runtime invalid-column mask of a dynamic corpus
    (padded capacity); it replaces the static ``n_valid`` bound."""
    if cfg.use_fused_topk:
        mask = (
            None if invalid is None
            else jnp.broadcast_to(invalid[None, :], (e_q.shape[0], r_anc.shape[1]))
        )
        _, idx = approx_topk_op(
            e_q, r_anc, None, m, tile=_effective_tile(cfg, r_anc),
            interpret=cfg.fused_interpret, n_valid=n_valid, mask=mask,
        )
        return idx
    s_hat = quant.matmul(e_q, r_anc)
    if n_valid is not None and n_valid < s_hat.shape[1]:
        s_hat = jnp.where(jnp.arange(s_hat.shape[1]) < n_valid, s_hat, sampling.NEG_INF)
    if invalid is not None:
        s_hat = jnp.where(invalid[None, :], sampling.NEG_INF, s_hat)
    _, idx = jax.lax.top_k(s_hat, m)
    return idx


def _pad_short_ranking(top_idx: jax.Array, top_s: jax.Array):
    """Keep under-filled rankings well-formed for callers.

    When a runtime ``n_rounds`` override or early exit leaves fewer filled
    candidates than ``k_retrieve``, trailing top-k slots would otherwise
    carry the -1 id sentinel with NEG_INF scores all the way to service
    responses.  Repeat the row-best candidate instead (top_k sorts
    descending, so position 0 is always a valid, exact-scored item)."""
    ok = top_s > 0.5 * sampling.NEG_INF
    return (
        jnp.where(ok, top_idx, top_idx[:, :1]),
        jnp.where(ok, top_s, top_s[:, :1]),
    )


def engine_search(
    score_fn: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    key: jax.Array,
    first_anchors: Optional[jax.Array] = None,
    batch: Optional[int] = None,
    n_valid_items=None,
    n_rounds=None,
    return_scores: Optional[bool] = None,
    item_ids: Optional[jax.Array] = None,
) -> AdaCURResult:
    """Run Algorithm 1 (+ retrieval) through the static-shape round engine.

    Mirrors :func:`repro.core.adacur.adacur_search` (same RNG stream, same
    budget accounting) with three extensions:

    - ``n_rounds``: runtime round-count override (``loop_mode='fori'`` only;
      may be a traced int32 ≤ ``cfg.n_rounds``).  Slabs beyond the executed
      rounds stay empty and are masked out of the final ranking, so one
      compiled executable serves every round count.
    - early exit: with ``cfg.early_exit_tol > 0`` the loop stops once the
      batch-mean overlap of consecutive provisional top-``k_retrieve`` sets
      reaches ``1 - tol``; ``AdaCURResult.rounds_done`` reports the count.
    - ``return_scores``: the (B, N) ``approx_scores`` field is only
      materialized on request (defaults to the dense path's behavior; the
      fused path defaults to ``None`` so no (B, N) buffer ever exists).

    Two further extensions serve the :class:`~repro.core.index.AnchorIndex`
    lifecycle: ``n_valid_items`` may be a *traced* int32 (dynamic corpora —
    growing/shrinking the valid prefix of a padded index never retraces),
    and ``item_ids`` (N,) maps engine positions to external corpus ids
    before every ``score_fn`` call.

    ``r_anc`` may be an fp32/bf16 array or an int8
    :class:`~repro.kernels.approx_topk.quant.QuantizedRanc` payload;
    ``cfg.payload_dtype`` converts a plain array up to the configured
    payload inside the trace (an AnchorIndex-backed retriever pre-quantizes
    instead — see ``Retriever.from_index``).
    """
    r_anc = quant.as_payload(r_anc, cfg.payload_dtype, cfg.payload_tile)
    k_q, n_items = r_anc.shape
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    r_max = cfg.n_rounds
    if k_i % r_max != 0:
        raise ValueError(f"k_i={k_i} not divisible by n_rounds={r_max}")
    k_s = k_i // r_max
    if return_scores is None:
        return_scores = not cfg.use_fused_topk
    n_valid = None
    invalid = None                        # (N,) runtime invalid-column mask
    if n_valid_items is not None:
        if isinstance(n_valid_items, (int, np.integer)):
            if n_valid_items < n_items:
                n_valid = int(n_valid_items)
        else:
            nv = jnp.minimum(jnp.asarray(n_valid_items, jnp.int32), n_items)
            invalid = jnp.arange(n_items, dtype=jnp.int32) >= nv
    dyn_valid = invalid is not None
    if cfg.loop_mode == "unrolled" and n_rounds is not None:
        raise ValueError("runtime n_rounds override requires loop_mode='fori'")
    if item_ids is not None:
        _raw_score_fn = score_fn

        def score_fn(q, idx, _f=_raw_score_fn, _ids=item_ids):
            return _f(q, jnp.take(_ids, idx, axis=0))

    if first_anchors is not None:
        b = first_anchors.shape[0]
        if first_anchors.shape[1] != k_s:
            raise ValueError(
                f"first_anchors must provide k_s={k_s} items, got {first_anchors.shape}"
            )
    elif batch is not None:
        b = batch
    else:
        b = jax.tree_util.tree_leaves(query)[0].shape[0]

    rows = jnp.arange(b)[:, None]
    selected = jnp.zeros((b, n_items), dtype=bool)
    if n_valid is not None:
        selected = selected | (jnp.arange(n_items) >= n_valid)
    if invalid is not None:
        selected = selected | invalid[None, :]

    # same RNG stream as the seed path: keys[r] drives round r
    keys = jax.random.split(key, r_max + 1)

    # --- round 0 (static): random or retriever-seeded first anchors --------
    if first_anchors is not None and cfg.first_round == "retriever":
        idx0 = first_anchors
    else:
        idx0 = sampling.sample_random(keys[0], selected, k_s)
    selected = selected.at[rows, idx0].set(True)
    c0 = score_fn(query, idx0)                                 # (B, k_s)
    cols0 = quant.gather_columns(
        r_anc, idx0, via_onehot=cfg.distributed_gather
    )

    dtype = c0.dtype
    anchor_idx = jnp.full((b, k_i), -1, jnp.int32)
    anchor_idx = anchor_idx.at[:, :k_s].set(idx0.astype(jnp.int32))
    c_test = jnp.zeros((b, k_i), dtype).at[:, :k_s].set(c0)
    a_buf = jnp.zeros((b, k_q, k_i), cols0.dtype).at[:, :, :k_s].set(cols0)

    # rerank-only configurations (one retriever round, no budget split) never
    # read S_hat: skip the pinv/e_q machinery entirely.
    needs_scores = cfg.split_budget or return_scores or r_max > 1
    if needs_scores:
        p = jnp.zeros((b, k_i, k_q), dtype)
        p0 = (
            cur.incremental_pinv_init(cols0, cfg.pinv_rcond)
            if cfg.incremental_pinv
            else cur.pinv(cols0, cfg.pinv_rcond)
        )
        p = p.at[:, :k_s, :].set(p0)
        e_q = jnp.einsum("bk,bkq->bq", c_test, p)
    else:
        p = jnp.zeros((b, k_i, k_q), dtype)
        e_q = jnp.zeros((b, k_q), dtype)
    state = EngineState(anchor_idx, c_test, a_buf, p, e_q, selected)

    body = _make_round_body(
        score_fn, r_anc, query, cfg, keys, k_s, n_valid, force_mask=dyn_valid
    )

    # --- rounds 1..n_rounds-1 ----------------------------------------------
    if cfg.loop_mode == "unrolled":
        for r in range(1, r_max):
            state = body(r, state)
        rounds_done = jnp.asarray(r_max, jnp.int32)
    else:
        r_dyn = jnp.asarray(r_max if n_rounds is None else n_rounds, jnp.int32)
        r_dyn = jnp.clip(r_dyn, 1, r_max)
        if cfg.early_exit_tol > 0.0:
            m = min(cfg.k_retrieve, n_items)
            prev = _provisional_topk(cfg, state.e_q, r_anc, m, n_valid, invalid)

            def cond(carry):
                r, frac, _, _ = carry
                return (r < r_dyn) & (frac < 1.0 - cfg.early_exit_tol)

            def while_body(carry):
                r, _, st, prev_top = carry
                st = body(r, st)
                cur_top = _provisional_topk(cfg, st.e_q, r_anc, m, n_valid, invalid)
                hit = (cur_top[:, :, None] == prev_top[:, None, :]).any(-1)
                return r + 1, hit.mean(), st, cur_top

            rounds_done, _, state, _ = jax.lax.while_loop(
                cond, while_body, (jnp.int32(1), jnp.float32(0.0), state, prev)
            )
        else:
            state = jax.lax.fori_loop(1, r_dyn, body, state)
            rounds_done = r_dyn

    anchor_idx, c_test = state.anchor_idx, state.c_test
    n_filled = rounds_done * k_s
    valid_slot = jnp.arange(k_i) < n_filled                    # (k_i,)
    anchor_logits = jnp.where(valid_slot[None, :], c_test, sampling.NEG_INF)
    s_hat = quant.matmul(state.e_q, r_anc) if return_scores else None

    # --- retrieval ---------------------------------------------------------
    if not cfg.split_budget:
        # ADACUR^No-Split: rank the anchors by their exact CE scores (free).
        k = min(cfg.k_retrieve, k_i)
        top_s, top_pos = jax.lax.top_k(anchor_logits, k)
        top_idx = jnp.take_along_axis(anchor_idx, top_pos, axis=1)
        top_idx, top_s = _pad_short_ranking(top_idx, top_s)
        return AdaCURResult(
            anchor_idx, c_test, s_hat, top_idx, top_s, ce_call_plan(cfg),
            rounds_done,
        )

    # ADACUR (split): spend the remaining budget on fresh exact CE calls for
    # the top approximate-scoring non-anchor items.
    k_r = cfg.budget_ce - k_i
    if cfg.use_fused_topk:
        _, rerank_idx = approx_topk_op(
            state.e_q, r_anc, k=k_r, tile=_effective_tile(cfg, r_anc),
            interpret=cfg.fused_interpret, n_valid=n_valid,
            **_fused_suppress(cfg, state, dyn_valid),
        )
    else:
        full = s_hat if s_hat is not None else quant.matmul(state.e_q, r_anc)
        masked = jnp.where(state.selected, sampling.NEG_INF, full)
        _, rerank_idx = jax.lax.top_k(masked, k_r)             # (B, k_r)
    rerank_scores = score_fn(query, rerank_idx)                # k_r CE calls
    pool_idx = jnp.concatenate([anchor_idx, rerank_idx], axis=1)
    pool_scores = jnp.concatenate([anchor_logits, rerank_scores], axis=1)
    k = min(cfg.k_retrieve, pool_idx.shape[1])
    top_s, top_pos = jax.lax.top_k(pool_scores, k)
    top_idx = jnp.take_along_axis(pool_idx, top_pos, axis=1)
    top_idx, top_s = _pad_short_ranking(top_idx, top_s)
    return AdaCURResult(
        anchor_idx, c_test, s_hat, top_idx, top_s, ce_call_plan(cfg),
        rounds_done,
    )


def make_engine(
    score_fn: ScoreFn,
    cfg: AdaCURConfig,
    n_valid_items=None,
    return_scores: Optional[bool] = None,
    jit_compile: bool = True,
):
    """jit-compiled engine closure over a concrete scorer + config.

    In ``fori`` mode the returned callable takes an optional runtime
    ``n_rounds`` (any value in [1, cfg.n_rounds]) *without retracing* — the
    round count is a traced operand of one compiled executable.  ``n_valid``
    and ``item_ids`` are likewise traced operands (AnchorIndex dynamic
    corpora: mutation changes their *values*, never the trace).

    ``jit_compile=False`` runs the engine eagerly (``loop_mode='unrolled'``
    only) so non-traceable scorers — numpy tokenizers, external CE services —
    still go through the one engine code path.
    """
    if not jit_compile and cfg.loop_mode != "unrolled":
        raise ValueError("jit_compile=False requires loop_mode='unrolled'")

    def _run(r_anc, query, key, n_rounds, first_anchors=None, batch=None,
             n_valid=None, item_ids=None):
        return engine_search(
            score_fn, r_anc, query, cfg, key,
            first_anchors=first_anchors, batch=batch,
            n_valid_items=n_valid if n_valid is not None else n_valid_items,
            n_rounds=n_rounds, return_scores=return_scores, item_ids=item_ids,
        )

    if jit_compile:
        _run = partial(jax.jit, static_argnames=("batch",))(_run)

    def run(r_anc, query, key, first_anchors=None, batch=None, n_rounds=None,
            n_valid=None, item_ids=None):
        if cfg.loop_mode == "fori":
            n_rounds = jnp.asarray(
                cfg.n_rounds if n_rounds is None else n_rounds, jnp.int32
            )
        elif n_rounds is not None:
            raise ValueError("runtime n_rounds override requires loop_mode='fori'")
        if n_valid is not None:
            n_valid = jnp.asarray(n_valid, jnp.int32)
        return _run(r_anc, query, key, n_rounds, first_anchors, batch,
                    n_valid, item_ids)

    return run


# ---------------------------------------------------------------------------
# Unified Retriever API — ADACUR / ANNCUR / retrieve-and-rerank as
# configurations of the one engine code path.
# ---------------------------------------------------------------------------


@runtime_checkable
class Retriever(Protocol):
    """Anything that answers a k-NN query batch under a CE-call budget."""

    def search(self, query, key: Optional[jax.Array] = None, **kw) -> AdaCURResult:
        ...


class _IndexBacked:
    """Shared plumbing for retrievers that consume an AnchorIndex.

    The index's arrays (``r_anc``, ``n_valid``, ``item_ids``) enter the
    compiled engine as *traced operands* read from ``self.index`` at every
    search, so swapping in a mutated index (``retriever.index = new_index``)
    changes values only — shapes are capacity-constant and nothing retraces.

    The runtime ``n_valid`` bound is only passed when the index is (or was
    constructed) padded: an unpadded index keeps the engine's static path,
    whose fused TPU sampling suppresses via the compact anchor-id list
    instead of a (B, N) mask.  Removing items from an unpadded index flips
    it to the dynamic path (one retrace, then stable).

    ``cfg.payload_dtype`` is applied to the index ONCE at construction
    (:meth:`_apply_payload_policy`): the engine then receives an already
    bf16/int8 payload operand and never re-converts per call.  An index that
    is already quantized is authoritative and passes through unchanged.
    """

    def _apply_payload_policy(self, cfg: AdaCURConfig) -> None:
        idx = getattr(self, "index", None)
        if idx is None or cfg.payload_dtype == "float32":
            return
        if idx.payload_dtype in (cfg.payload_dtype, "int8"):
            # already compliant — or already quantized, which is
            # authoritative (mirrors quant.as_payload: the policy converts
            # payloads UP, it never dequantizes an int8 artifact)
            return
        self.index = idx.quantize(cfg.payload_dtype, tile=cfg.payload_tile)

    def _search_operands(self):
        if self.index is None:
            return self.r_anc, {}
        kw = dict(item_ids=self.index.item_ids)
        if not getattr(self, "_dynamic_valid", False):
            # the padded? device->host sync runs once per index object, not
            # per search; once dynamic, the trace stays dynamic forever
            if getattr(self, "_seen_index", None) is not self.index:
                self._seen_index = self.index
                self._dynamic_valid = self.index.capacity > self.index.n_items
        if self._dynamic_valid:
            kw["n_valid"] = self.index.n_valid
        return self.index.r_anc, kw


@dataclass
class AdaCURRetriever(_IndexBacked):
    """The paper's method (Alg. 1) on the static-shape engine."""

    score_fn: ScoreFn
    r_anc: Optional[jax.Array]
    cfg: AdaCURConfig
    n_valid_items: Optional[int] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    jit: bool = True
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        self._apply_payload_policy(self.cfg)
        self._run = make_engine(
            self.score_fn, self.cfg, self.n_valid_items, jit_compile=self.jit
        )

    @classmethod
    def from_index(cls, index, score_fn: ScoreFn, cfg: AdaCURConfig,
                   jit: bool = True) -> "AdaCURRetriever":
        """Bind the engine to an :class:`~repro.core.index.AnchorIndex`:
        ``score_fn`` receives *external item ids* (the engine maps positions
        through ``index.item_ids``), padded capacity is masked through the
        runtime ``n_valid`` bound, and index mutation never retraces."""
        return cls(score_fn, None, cfg, index=index, jit=jit)

    def search(self, query, key=None, first_anchors=None, batch=None,
               n_rounds=None, **_ignored):
        key = jax.random.PRNGKey(0) if key is None else key
        r_anc, kw = self._search_operands()
        return self._run(
            r_anc, query, key, first_anchors=first_anchors, batch=batch,
            n_rounds=n_rounds, **kw,
        )


@dataclass
class ANNCURRetriever(_IndexBacked):
    """Fixed-anchor one-round special case (Yadav et al. 2022).

    The offline index is just the anchor id set; ``search`` is one
    retriever-seeded engine round followed by the split-budget rerank — the
    identical code path ADACUR uses, at ``n_rounds=1``.  With
    ``budget_ce == k_anchor`` there is no rerank budget left and the final
    ranking is the free exact-score ranking of the anchors themselves
    (the engine's no-split configuration).
    """

    score_fn: ScoreFn
    r_anc: Optional[jax.Array]
    anchor_idx: Optional[jax.Array]      # (k_i,) fixed anchor item positions
    budget_ce: int = 0
    k_retrieve: int = 100
    pinv_rcond: float = 1e-6
    base_cfg: Optional[AdaCURConfig] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    jit: bool = True
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.anchor_idx is None:
            if self.index is None or self.index.anchor_item_pos is None:
                raise ValueError(
                    "need anchor_idx or an AnchorIndex with anchors "
                    "(index.with_anchors() / with_latents())"
                )
            k_i = int(self.index.anchor_item_pos.shape[0])
        else:
            k_i = int(self.anchor_idx.shape[0])
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        if self.budget_ce < k_i:
            raise ValueError(f"budget_ce={self.budget_ce} < k_anchor={k_i}")
        base = self.base_cfg or AdaCURConfig()
        split = self.budget_ce > k_i
        self.cfg = replace(
            base, k_anchor=k_i, n_rounds=1, budget_ce=self.budget_ce,
            split_budget=split, first_round="retriever",
            k_retrieve=self.k_retrieve, pinv_rcond=self.pinv_rcond,
            round_epsilon=0.0, early_exit_tol=0.0,
        )
        self._apply_payload_policy(self.cfg)
        self._run = make_engine(self.score_fn, self.cfg, jit_compile=self.jit)

    @classmethod
    def from_index(cls, index, score_fn: ScoreFn, budget_ce: int,
                   k_retrieve: int = 100, pinv_rcond: float = 1e-6,
                   base_cfg: Optional[AdaCURConfig] = None,
                   jit: bool = True) -> "ANNCURRetriever":
        """ANNCUR over an :class:`~repro.core.index.AnchorIndex` that carries
        latents; anchors are read from the index at every search, so a
        mutated index (whose anchor positions may have been compacted) is
        picked up without retracing."""
        return cls(score_fn, None, None, budget_ce, k_retrieve, pinv_rcond,
                   base_cfg, index=index, jit=jit)

    def search(self, query, key=None, **kw):
        key = jax.random.PRNGKey(0) if key is None else key
        anchors = (
            self.index.anchor_item_pos
            if self.anchor_idx is None else self.anchor_idx
        )
        b = jax.tree_util.tree_leaves(query)[0].shape[0]
        first = jnp.broadcast_to(
            anchors[None, :].astype(jnp.int32), (b, anchors.shape[0])
        )
        r_anc, opkw = self._search_operands()
        return self._run(r_anc, query, key, first_anchors=first, **opkw)


@dataclass
class RerankRetriever(_IndexBacked):
    """Retrieve-and-rerank baseline: one retriever-seeded round, no split.

    Every candidate is exact-CE scored (they *are* the anchors) and the
    final ranking is the free top-k over those scores — i.e.
    ``retrieval.rerank_baseline`` expressed as an engine configuration.
    """

    score_fn: ScoreFn
    r_anc: Optional[jax.Array]
    budget_ce: int = 0
    k_retrieve: int = 100
    base_cfg: Optional[AdaCURConfig] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    jit: bool = True
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        base = self.base_cfg or AdaCURConfig()
        self.cfg = replace(
            base, k_anchor=self.budget_ce, n_rounds=1,
            budget_ce=self.budget_ce, split_budget=False,
            first_round="retriever", k_retrieve=self.k_retrieve,
            round_epsilon=0.0, early_exit_tol=0.0,
        )
        self._apply_payload_policy(self.cfg)
        # pure rerank never reads S_hat: skip the pinv/e_q machinery
        self._run = make_engine(
            self.score_fn, self.cfg, return_scores=False, jit_compile=self.jit
        )

    @classmethod
    def from_index(cls, index, score_fn: ScoreFn, budget_ce: int,
                   k_retrieve: int = 100,
                   base_cfg: Optional[AdaCURConfig] = None,
                   jit: bool = True) -> "RerankRetriever":
        return cls(score_fn, None, budget_ce, k_retrieve, base_cfg,
                   index=index, jit=jit)

    def search(self, query, key=None, candidate_idx=None, **kw):
        if candidate_idx is None:
            raise ValueError("RerankRetriever.search needs candidate_idx (B, >=budget)")
        key = jax.random.PRNGKey(0) if key is None else key
        first = candidate_idx[:, : self.budget_ce].astype(jnp.int32)
        r_anc, opkw = self._search_operands()
        return self._run(r_anc, query, key, first_anchors=first, **opkw)


# ---------------------------------------------------------------------------
# Introspection: prove the fused path never materializes (B, N) scores.
# ---------------------------------------------------------------------------


def _iter_sub_jaxprs(params: dict):
    """Jaxprs nested in an eqn's params (scan/while/cond/pallas bodies).

    Duck-typed walk instead of jax.core.jaxprs_in_params — that helper is
    private and has moved across JAX releases."""
    for val in params.values():
        for item in val if isinstance(val, (tuple, list)) else (val,):
            j = getattr(item, "jaxpr", item)   # ClosedJaxpr -> Jaxpr
            if hasattr(j, "eqns"):
                yield j


def _count_bn_floats(jaxpr, b: int, n: int) -> int:
    """Recursively count eqn outputs with float aval of shape (b, n)."""
    count = 0
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if (
                aval is not None
                and getattr(aval, "shape", None) == (b, n)
                and jnp.issubdtype(aval.dtype, jnp.floating)
            ):
                count += 1
        for sub in _iter_sub_jaxprs(eqn.params):
            count += _count_bn_floats(sub, b, n)
    return count


def round_body_bn_intermediates(
    score_fn: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    batch: Optional[int] = None,
) -> int:
    """Number of (B, N) float intermediates in ONE adaptive round body.

    Dense sampling scores every item each round (>= 1); the fused-kernel
    TopK path must report 0 — the per-round claim behind the Fig. 4
    latency argument, checked by jaxpr inspection rather than trust.
    """
    r_anc = quant.as_payload(r_anc, cfg.payload_dtype, cfg.payload_tile)
    k_q, n_items = r_anc.shape
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    k_s = k_i // cfg.n_rounds
    b = batch or jax.tree_util.tree_leaves(query)[0].shape[0]
    keys = jax.random.split(jax.random.PRNGKey(0), cfg.n_rounds + 1)
    body = _make_round_body(score_fn, r_anc, query, cfg, keys, k_s, None)
    dtype = jnp.float32
    state = EngineState(
        anchor_idx=jnp.zeros((b, k_i), jnp.int32),
        c_test=jnp.zeros((b, k_i), dtype),
        a_buf=jnp.zeros((b, k_q, k_i), dtype),
        p=jnp.zeros((b, k_i, k_q), dtype),
        e_q=jnp.zeros((b, k_q), dtype),
        selected=jnp.zeros((b, n_items), bool),
    )
    closed = jax.make_jaxpr(lambda st: body(jnp.int32(1), st))(state)
    return _count_bn_floats(closed.jaxpr, b, n_items)


def engine_slab_bytes(
    cfg: AdaCURConfig, batch: int, n_items: int, k_q: int
) -> dict:
    """Device bytes of the engine's preallocated per-search state slabs.

    The engine's whole working set is these six buffers (plus the payload it
    streams); reporting them next to the index payload in BENCH_engine.json
    tracks the memory story alongside latency as N scales.
    """
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    slabs = {
        "anchor_idx": batch * k_i * 4,
        "c_test": batch * k_i * 4,
        "a_buf": batch * k_q * k_i * 4,
        "p": batch * k_i * k_q * 4,
        "e_q": batch * k_q * 4,
        "selected_mask": batch * n_items * 1,
    }
    slabs["total"] = sum(slabs.values())
    return slabs

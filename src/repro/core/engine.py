"""Static-shape multi-round ADACUR engine + the unified Retriever API.

The seed implementation (``core/adacur.py``, kept as the executable spec and
parity oracle) grows every buffer with ``jnp.concatenate``: each round body
has a different trace shape, so changing ``n_rounds`` recompiles the whole
search and nothing can run under ``lax.fori_loop`` — exactly the non-CE
overhead the paper's Fig. 4 warns about.  This module is the production
path:

- **preallocated slabs**: the anchor-id (B, k_i), exact-score (B, k_i),
  anchor-column (B, k_q, k_i) and incremental-pinv (B, k_i, k_q) buffers are
  allocated once at their final size and round r fills slab
  ``[r·k_s, (r+1)·k_s)`` with ``lax.dynamic_update_slice``.  Unfilled pinv
  rows / anchor columns are exact zeros, which contribute exact zeros to
  every contraction, so the padded math equals the growing-shape math;
- **shape-invariant round body**: runs unrolled (``loop_mode='unrolled'``,
  the seed behavior, any score_fn), under ``lax.fori_loop`` with the round
  count as a *runtime operand* (``loop_mode='fori'`` — per-query-batch round
  counts without retracing, cf. arXiv 2405.03651), or under
  ``lax.while_loop`` with an early-exit tolerance (anytime ADACUR: stop when
  the round-over-round provisional top-k set stabilizes);
- **fused score->sample** (``use_fused_topk``): per-round anchor sampling
  and the final split-budget rerank selection go through the Pallas
  ``approx_topk_op`` so the (B, N) approximate score matrix is never
  materialized — TopK sampling needs no (B, N) intermediate at all, SoftMax
  passes Gumbel noise as a kernel input (Kool et al. 2019);
- **one code path for every method**: :class:`AdaCURRetriever` (the paper),
  :class:`ANNCURRetriever` (fixed anchors = one engine round, arXiv
  2210.12579) and :class:`RerankRetriever` (retrieve-and-rerank = one
  retriever-seeded round with no budget split) are thin configurations of
  :func:`engine_search` behind the common :class:`Retriever` protocol;
- **one SPMD program over a (data x items) mesh**: the whole engine — slab
  state, sampling, CE scoring, incremental pinv, rerank — is written as a
  *per-shard math core* in local item coordinates plus a thin *collective
  layer* (:class:`ShardCtx` + the ``_merge_topk``/``_gather_cols``/
  ``_score_once`` helpers below).  :func:`make_sharded_engine` runs that
  core under ``shard_map``: the item axis shards the payload and the
  per-shard slab columns, the data axis shards the query batch, and the
  small pinv/e_q state replicates.  A single-device search is the same core
  on a trivial one-shard context, so the sharded engine is **bit-identical**
  to the single-device engine by construction (see the collective layer's
  docstrings for the three contracts that make this true).
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, NamedTuple, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map
from ..configs.base import AdaCURConfig, replace
from ..kernels.approx_topk import quant
from ..kernels.approx_topk.ops import approx_topk_op
from ..kernels.approx_topk.persistent import persistent_round_op
from ..kernels.approx_topk.quant import QuantizedRanc
from . import cur, sampling
from .adacur import AdaCURResult, ScoreFn


def ce_call_plan(cfg: AdaCURConfig, rounds: Optional[int] = None) -> int:
    """Exact CE calls per query for a run executing ``rounds`` rounds.

    Each executed round scores its k_s fresh anchors, plus the split-budget
    rerank (``budget_ce - k_anchor``) once at the end.  This is the single
    source of truth for budget accounting: ``AdaCURResult.ce_calls`` is this
    plan at the full round count, and a counting
    :class:`~repro.core.scorer.Scorer`'s *measured* ``stats.ce_calls`` must
    equal ``ce_call_plan(cfg, rounds_done) * batch`` — asserted per engine
    mode by the property-based invariant suite.
    """
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    k_s = k_i // cfg.n_rounds
    r = cfg.n_rounds if rounds is None else rounds
    if not 1 <= r <= cfg.n_rounds:
        raise ValueError(f"rounds={r} outside [1, {cfg.n_rounds}]")
    k_r = cfg.budget_ce - k_i if cfg.split_budget else 0
    return k_s * r + k_r


class AnytimeDeadline:
    """Host-side wall-clock deadline the engine's round loop polls.

    The anytime-serving contract: every round boundary of the multi-round
    search is a valid (if coarser) answer, so a search that runs out of
    latency budget should *return the provisional top-k from the rounds it
    completed* instead of nothing.  This object is the host<->trace bridge:
    the serving layer ``arm()``s it with an absolute ``time.monotonic()``
    deadline before a search and the engine's ``lax.while_loop`` cond polls
    :meth:`expired` through a numpy-only ``pure_callback`` (no nested device
    compute — the same mesh-legality class as ``TabulatedScorer``) once per
    round.  Round 0 always runs (it executes before the loop), so an
    already-expired deadline still yields a 1-round answer; the split-budget
    rerank still spends its ``budget_ce - k_anchor`` calls on whatever
    provisional estimate exists, keeping every response exact-CE ranked.

    ``fired`` records whether the deadline actually cut the loop short —
    ``arm()`` resets it, ``disarm()`` leaves it readable, so the serving
    layer can flag the response ``degraded`` after the (blocking) search.

    Single-device only: under the SPMD engine each shard would poll its own
    wall clock, shards could disagree on the iteration count, and the next
    collective would deadlock.  ``make_engine(anytime=True)`` is the one
    construction path; ``make_sharded_engine`` has no such parameter, and
    the serving tier's unit of redundancy is the *replica*, not the shard.
    """

    def __init__(self):
        self.deadline_t = float("inf")
        self.fired = False

    def arm(self, deadline_t: float) -> None:
        self.deadline_t = float(deadline_t)
        self.fired = False

    def disarm(self) -> None:
        """Stop cutting rounds; ``fired`` stays readable for the caller."""
        self.deadline_t = float("inf")

    def _expired_host(self, r) -> np.bool_:
        if time.monotonic() >= self.deadline_t:
            self.fired = True
            return np.bool_(True)
        return np.bool_(False)

    def expired(self, r: jax.Array) -> jax.Array:
        """Traced poll; ``r`` rides along as an operand so each loop
        iteration's callback is distinct (CSE-proof) and runs in order."""
        return jax.pure_callback(
            self._expired_host, jax.ShapeDtypeStruct((), jnp.bool_), r
        )


class EngineState(NamedTuple):
    """Loop-invariant-shaped state threaded through the round body.

    Under the SPMD engine, ``selected`` is the only item-axis buffer — it is
    *local* (B_local, N_local); everything else is small, indexed by global
    item ids, and replicated across item shards."""

    anchor_idx: jax.Array    # (B, k_i) int32, -1 in unfilled slots
    c_test: jax.Array        # (B, k_i) exact CE scores, 0 in unfilled slots
    a_buf: jax.Array         # (B, k_q, k_i) anchor columns, 0 beyond filled
    p: jax.Array             # (B, k_i, k_q) incremental pinv, 0 beyond filled
    e_q: jax.Array           # (B, k_q) latent query embedding
    selected: jax.Array      # (B, N) bool mask of already-selected items


# ---------------------------------------------------------------------------
# The collective layer: ShardCtx + the cross-shard primitives.
#
# The engine's math core runs in LOCAL item coordinates — every per-item
# buffer it touches is this shard's slab.  The helpers below are the only
# places shard boundaries exist.  Three contracts make the sharded program
# bit-identical to the single-device one:
#
# 1. **per-column scores are shard-invariant**: every sampling score is an
#    independent fp32 contraction over one payload column (+ the blocked
#    noise field, a pure function of global (row, item) coordinates — see
#    ``sampling.blocked_gumbel``), so a column scores to the same bits no
#    matter which shard computes it;
# 2. **deterministic global-id tie-break**: per-shard candidate lists break
#    exact score ties by ascending item id (the fused kernel contract), and
#    the cross-shard merge concatenates shard blocks in ascending shard
#    order before an index-stable ``lax.top_k`` — equal values therefore
#    resolve to the ascending *global* id, exactly like a single shard;
# 3. **every contribution has one owner**: anchor-column gathers and CE
#    scores are computed by exactly one shard and ``psum``-broadcast; the
#    other shards contribute exact zeros, and ``x + 0.0`` is exact in fp.
# ---------------------------------------------------------------------------


class ShardCtx(NamedTuple):
    """This program instance's place on the (data x items) mesh.

    ``item_axes is None`` is the trivial single-shard context: every
    collective helper short-circuits to plain local math, which *is* the
    single-device engine."""

    item_axes: Optional[Tuple[str, ...]]  # mesh axes sharding the item axis
    data_axes: Tuple[str, ...]            # mesh axes sharding the query batch
    n_local: int                          # item columns owned by this shard
    n_item_shards: int
    item_shard: Any                       # () int32 shard index (0 unsharded)
    row_offset: Any                       # global row of local batch row 0
    col_map: Any = None                   # (N_local,) global item position of
                                          # each local column (None = identity)


def _local_ctx(n_items: int, col_map=None) -> ShardCtx:
    return ShardCtx(None, (), n_items, 1, 0, 0, col_map)


def _axes_index(axes: Tuple[str, ...]) -> jax.Array:
    """Mixed-radix shard index over ``axes`` (major-to-minor in given order,
    matching ``lax.all_gather``'s tiled concatenation order)."""
    i = jnp.int32(0)
    for a in axes:
        i = i * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return i


def _item_offset(ctx: ShardCtx):
    """Global position of this shard's column 0."""
    return ctx.item_shard * ctx.n_local


def _psum_items(ctx: ShardCtx, x: jax.Array) -> jax.Array:
    return jax.lax.psum(x, ctx.item_axes) if ctx.item_axes else x


def _noise(ctx: ShardCtx, key: jax.Array, rows: int) -> jax.Array:
    """This context's (rows, N_local) rectangle of the canonical noise field.

    A candidate-subset context (``col_map`` set) holds columns gathered from
    scattered corpus positions; it evaluates the field at those *global*
    coordinates (:func:`sampling.gumbel_at`), so every draw matches the bits
    a masked full-corpus search would have seen at the same columns — the
    subset-vs-masked bit-parity contract."""
    if ctx.col_map is not None:
        return sampling.gumbel_at(key, rows, ctx.col_map, ctx.row_offset)
    return sampling.blocked_gumbel(
        key, rows, ctx.n_local, ctx.row_offset, _item_offset(ctx)
    )


def _merge_topk(ctx: ShardCtx, vals: jax.Array, gidx: jax.Array, k: int):
    """Per-shard (B, k) candidates -> global (B, k) top-k, replicated.

    The documented tie-break contract for cross-shard merges: each shard's
    list is value-sorted with exact ties in ascending global id (the fused
    kernel / ``lax.top_k`` index-stability), shard blocks concatenate in
    ascending shard order (= ascending global id ranges), and the final
    ``lax.top_k`` is index-stable over that buffer — so exact score ties
    resolve to the ascending global item id, identically to one shard
    ranking all N columns."""
    if ctx.item_axes is None:
        return vals, gidx
    vg = jax.lax.all_gather(vals, ctx.item_axes, axis=1, tiled=True)
    ig = jax.lax.all_gather(gidx, ctx.item_axes, axis=1, tiled=True)
    v, pos = jax.lax.top_k(vg, k)
    return v, jnp.take_along_axis(ig, pos, axis=1)


def _local_topk_merge(ctx: ShardCtx, logits: jax.Array, k: int) -> jax.Array:
    """top-k of a local (B, N_local) score slab -> global ids."""
    v, i = jax.lax.top_k(logits, k)
    if ctx.item_axes is None:
        return i
    _, gi = _merge_topk(ctx, v, i.astype(jnp.int32) + _item_offset(ctx), k)
    return gi


def _sample_random_ctx(
    ctx: ShardCtx, key: jax.Array, selected: jax.Array, k: int
) -> jax.Array:
    """Uniform w/o replacement over unselected items (global ids) — the
    shard-decomposed twin of ``sampling.sample_random`` (same noise field,
    same masked-Gumbel formula, so the single-shard case is bit-equal)."""
    b, n_local = selected.shape
    g = _noise(ctx, key, b)
    logits = jnp.where(selected, sampling.NEG_INF, 0.0) + g
    return _local_topk_merge(ctx, logits, k)


def _mark_selected(ctx: ShardCtx, selected: jax.Array, gidx: jax.Array) -> jax.Array:
    """Set the (global-id) picks in the local selected mask; ids owned by
    other shards drop out of range (negative locals must be sent PAST the
    slab, not left to Python-wrap onto someone else's column)."""
    rows = jnp.arange(selected.shape[0])[:, None]
    local = gidx - _item_offset(ctx)
    n_local = selected.shape[1]
    local = jnp.where((local >= 0) & (local < n_local), local, n_local)
    return selected.at[rows, local].set(True, mode="drop")


def _gather_cols(
    ctx: ShardCtx, r_anc, gidx: jax.Array, via_onehot: bool = False
) -> jax.Array:
    """R_anc[:, gidx] -> (B, k_q, k) fp32: the global->shard column gather.

    Each shard dequantizes/gathers exactly the columns it owns and the
    results are psum-broadcast (one owner per column, exact zeros
    elsewhere)."""
    if ctx.item_axes is None:
        return quant.gather_columns(r_anc, gidx, via_onehot=via_onehot)
    local = gidx - _item_offset(ctx)
    owned = (local >= 0) & (local < ctx.n_local)
    cols = quant.gather_columns(
        r_anc, jnp.clip(local, 0, ctx.n_local - 1), via_onehot=via_onehot
    )
    return _psum_items(ctx, jnp.where(owned[:, None, :], cols, 0.0))


def _map_item_ids(ctx: ShardCtx, item_ids: jax.Array, gidx: jax.Array) -> jax.Array:
    """Engine positions -> external corpus ids through the sharded id map."""
    if ctx.item_axes is None:
        return jnp.take(item_ids, gidx, axis=0)
    local = gidx - _item_offset(ctx)
    owned = (local >= 0) & (local < ctx.n_local)
    v = jnp.take(item_ids, jnp.clip(local, 0, ctx.n_local - 1), axis=0)
    return _psum_items(ctx, jnp.where(owned, v, 0))


def _score_once(
    ctx: ShardCtx, score_fn: ScoreFn, query, ids: jax.Array, dtype
) -> jax.Array:
    """Exact-CE score a (B, k) id batch EXACTLY ONCE across the system.

    Item shard 0 of each data shard runs the scorer (host callbacks fire on
    that shard only — ``lax.cond`` branches execute per shard at runtime,
    so a counting scorer's measured calls stay equal to the plan); the
    result psum-broadcasts to the item shards that contributed zeros."""
    if ctx.item_axes is None:
        return score_fn(query, ids)
    c = jax.lax.cond(
        ctx.item_shard == 0,
        lambda q, i: score_fn(q, i).astype(dtype),
        lambda q, i: jnp.zeros(i.shape, dtype),
        query, ids,
    )
    return _psum_items(ctx, c)


def _gather_token_rows(ctx: ShardCtx, table: jax.Array, gidx: jax.Array) -> jax.Array:
    """Corpus token rows for GLOBAL item positions -> (..., Li) int32.

    The token-table analogue of :func:`_map_item_ids`: each item shard
    gathers the rows it owns from its local (N_local, Li) slab, zeros
    elsewhere, one psum broadcast."""
    if ctx.item_axes is None:
        return jnp.take(table, gidx, axis=0)
    local = gidx - _item_offset(ctx)
    owned = (local >= 0) & (local < ctx.n_local)
    rows = jnp.take(table, jnp.clip(local, 0, ctx.n_local - 1), axis=0)
    return _psum_items(ctx, jnp.where(owned[..., None], rows, 0))


def _device_ce_score(
    ctx: ShardCtx, scorer, q_tokens, gidx: jax.Array, item_tokens: jax.Array
) -> jax.Array:
    """Device-resident CE scoring of a (B, k) position batch, in-trace.

    Replaces the shard-0 host-callback path for scorers with
    ``device_resident=True`` (:class:`~repro.core.scorer.DeviceCEScorer`):
    gather the selected items' token rows, assemble ``[CLS] q [SEP] i
    [SEP]`` pairs, and run the CE transformer forward inside the caller's
    trace — under the mesh the flattened pair batch is split across the
    *item* shards (each scores an equal contiguous chunk, all_gather
    reassembles), so the CE FLOPs parallelize over the whole mesh while
    every pair is still scored exactly once system-wide.  Measured
    accounting rides a numpy-only callback on item shard 0 (mesh-legal: no
    nested device launch), with the item-shard pad rows excluded.
    """
    rows = _gather_token_rows(ctx, item_tokens, gidx)          # (B, k, Li)
    pairs = scorer.build_pairs(q_tokens, rows)                 # (B, k, Lb)
    b, k, lb = pairs.shape
    n = b * k
    flat = pairs.reshape(n, lb)
    if ctx.item_axes is None:
        scores = scorer.forward(flat)
        dummy = scorer.count(gidx, 0)
    else:
        n_pad = -n % ctx.n_item_shards
        if n_pad:
            flat = jnp.concatenate(
                [flat, jnp.full((n_pad, lb), scorer.pad_id, flat.dtype)], axis=0
            )
        chunk = (n + n_pad) // ctx.n_item_shards
        local = jax.lax.dynamic_slice_in_dim(flat, ctx.item_shard * chunk, chunk, 0)
        s = scorer.forward(local).astype(jnp.float32)
        scores = jax.lax.all_gather(s, ctx.item_axes, axis=0, tiled=True)[:n]
        # one counting callback per data shard; gidx as operand keeps the
        # per-round calls distinct (CSE-proof), the consumed 0.0 keeps it live
        dummy = jax.lax.cond(
            ctx.item_shard == 0,
            lambda g: scorer.count(g, n_pad),
            lambda g: jnp.float32(0.0),
            gidx,
        )
    return scores.reshape(b, k).astype(jnp.float32) + 0.0 * dummy


def _global_frac(ctx: ShardCtx, hit: jax.Array) -> jax.Array:
    """Batch-mean of a boolean (B_local, m) statistic over the GLOBAL batch
    (the early-exit monitor must stop every shard on the same round).
    All partial sums are exact integers in fp32, so the sharded mean is
    bit-equal to the single-device one."""
    if not ctx.data_axes:
        return hit.mean()
    total = jax.lax.psum(jnp.sum(hit.astype(jnp.float32)), ctx.data_axes)
    n_rows = jax.lax.psum(jnp.int32(1), ctx.data_axes) * hit.size
    return total / n_rows.astype(jnp.float32)


def _effective_tile(cfg: AdaCURConfig, r_anc) -> int:
    """Item-tile width of the fused kernel for this payload.

    On the CPU scan backend (``fused_interpret``) the binding constraint is
    the payload tile's L2 residency, so ``cfg.fused_tile`` acts as a
    per-tile *byte* budget expressed in fp32 columns: a quantized payload
    streams proportionally more columns in the same footprint (x4 int8,
    x2 bf16) — which is where the ~4x fewer bytes per round turn into
    wall-clock on CPU.  The compiled TPU kernel keeps the configured column
    count: its VMEM budget is dominated by the (B, T) fp32 score (and
    noise/mask) blocks, which do NOT shrink with the payload dtype —
    widening T 4x there would blow VMEM; the int8 win on TPU is the 4x
    smaller HBM stream per (unchanged) tile."""
    if not cfg.fused_interpret:
        return cfg.fused_tile
    dtype = quant.payload_dtype_of(r_anc)
    if dtype == "int4":
        return cfg.fused_tile * 8
    if dtype in ("int8", "fp8"):
        return cfg.fused_tile * 4
    if dtype == "bfloat16":
        return cfg.fused_tile * 2
    return cfg.fused_tile


def _fused_suppress(
    cfg: AdaCURConfig, state: EngineState, force_mask: bool = False
) -> dict:
    """How the fused op suppresses already-selected items, per backend.

    On TPU (compiled kernel) the (B, k_i) anchor-id list stays resident in
    VMEM and is compared per tile — no (B, N) traffic.  On the CPU scan
    backend the engine's existing (B, N) bool ``selected`` mask is streamed
    tile-by-tile instead: O(B·T) per tile beats the O(B·T·A) id compare.
    ``force_mask`` routes through the mask even on TPU — required when the
    valid-item bound is a *runtime* value (dynamic corpora), because the
    anchor-id compare cannot see the invalid padded tail."""
    if cfg.fused_interpret or force_mask:
        return dict(anchors=None, mask=state.selected)
    return dict(anchors=state.anchor_idx, mask=None)


def _bcast_mask(invalid, b: int, n: int):
    """Normalize an (N,) / (1, N) / (B, N) invalid mask to (B, N)."""
    if invalid is None:
        return None
    inv = invalid if invalid.ndim == 2 else invalid[None, :]
    return jnp.broadcast_to(inv, (b, n))


def _sample_round(
    cfg: AdaCURConfig,
    key: jax.Array,
    state: EngineState,
    r_anc: jax.Array,
    k_eff: int,
    n_valid: Optional[int],
    ctx: ShardCtx,
    force_mask: bool = False,
    monitor: Optional[tuple] = None,
):
    """One adaptive round's anchor pick (Alg. 3) — dense or fused, over this
    shard's payload slab; returns GLOBAL item ids.

    ``r_anc`` is any payload type (fp32/bf16 array or quantized
    int8/int4/fp8 QuantizedRanc); both branches dequantize per column, the
    dense one via :func:`quant.matmul`, the fused one inside the kernel
    tiles.  On a sharded context the per-shard candidates go through the
    tie-break merge (:func:`_merge_topk`).

    ``monitor=(m, invalid)`` additionally returns the provisional top-m ids
    of the *current* ``state.e_q`` estimate (the early-exit monitor) as a
    second value.  Under ``cfg.round_kernel='persistent'`` both lists come
    out of ONE persistent payload sweep (:func:`persistent_round_op`)
    whenever the sample and provisional branches share the estimate GEMM —
    ``topk`` strategy, or ``softmax`` at temperature 1.0 (``e_q / 1.0`` is
    bitwise ``e_q``, so the folded-temperature sample operand equals the
    provisional one); otherwise the monitor falls back to a separate
    :func:`_provisional_topk` pass with identical results.
    """
    sharded = ctx.item_axes is not None
    b, n_local = state.selected.shape
    remapped = ctx.col_map is not None
    persistent = cfg.use_fused_topk and cfg.round_kernel == "persistent"

    def with_monitor(gidx):
        if monitor is None:
            return gidx
        m, invalid = monitor
        return gidx, _provisional_topk(
            cfg, state.e_q, r_anc, m, n_valid, invalid, ctx
        )

    if cfg.strategy == "random" and (sharded or remapped or cfg.use_fused_topk):
        return with_monitor(_sample_random_ctx(ctx, key, state.selected, k_eff))
    if not cfg.use_fused_topk:
        s_hat = quant.matmul(state.e_q, r_anc)
        if not sharded and not remapped:
            return with_monitor(sampling.sample(
                cfg.strategy, key, s_hat, state.selected, k_eff, cfg.softmax_temp
            ))
        logits = sampling._masked_logits(s_hat, state.selected, cfg.softmax_temp)
        if cfg.strategy == "softmax":
            logits = logits + _noise(ctx, key, b)
        return with_monitor(_local_topk_merge(ctx, logits, k_eff))
    suppress = _fused_suppress(cfg, state, force_mask or sharded)
    tile = _effective_tile(cfg, r_anc)
    nv = None if sharded else n_valid
    if persistent:
        kw = dict(
            k_sample=k_eff, tile=tile, interpret=cfg.fused_interpret,
            n_valid=nv, **suppress,
        )
        e_q = state.e_q
        if cfg.strategy == "softmax":
            # temp folds into e_q (scores/temp == (e_q/temp) @ R_anc), as on
            # the staged path.  The Gumbel field is generated INSIDE the
            # sweep from its (key, global row/col) coordinates — the (B, N)
            # noise matrix never exists — except on a remapped candidate
            # subset, whose scattered coordinates need the gathered field.
            e_q = e_q / jnp.asarray(cfg.softmax_temp, e_q.dtype)
            if remapped:
                kw["noise"] = _noise(ctx, key, b)
            else:
                kw.update(
                    noise_key=key, row_offset=ctx.row_offset,
                    col_offset=_item_offset(ctx),
                )
        fuse_prov = monitor is not None and (
            cfg.strategy == "topk" or cfg.softmax_temp == 1.0
        )
        if fuse_prov:
            m, invalid = monitor
            (v, idx), (pv, pidx) = persistent_round_op(
                e_q, r_anc, k_prov=m,
                prov_mask=_bcast_mask(invalid, b, n_local), **kw,
            )
            if not sharded:
                return idx, pidx
            _, gidx = _merge_topk(ctx, v, idx + _item_offset(ctx), k_eff)
            _, pgidx = _merge_topk(ctx, pv, pidx + _item_offset(ctx), m)
            return gidx, pgidx
        (v, idx), _ = persistent_round_op(e_q, r_anc, **kw)
    elif cfg.strategy == "softmax":
        # temp folds into e_q (scores/temp == (e_q/temp) @ R_anc); Gumbel
        # noise enters the kernel as an input, S_hat stays in VMEM.
        g = _noise(ctx, key, b)
        e_q = state.e_q / jnp.asarray(cfg.softmax_temp, state.e_q.dtype)
        v, idx = approx_topk_op(
            e_q, r_anc, k=k_eff, tile=tile,
            interpret=cfg.fused_interpret, noise=g, n_valid=nv, **suppress,
        )
    else:
        # topk: temp > 0 is order-preserving, no noise needed
        v, idx = approx_topk_op(
            state.e_q, r_anc, k=k_eff, tile=tile,
            interpret=cfg.fused_interpret, n_valid=nv, **suppress,
        )
    if not sharded:
        return with_monitor(idx)
    _, gidx = _merge_topk(ctx, v, idx + _item_offset(ctx), k_eff)
    return with_monitor(gidx)


def _make_round_steps(
    scored: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    keys: jax.Array,
    k_s: int,
    n_valid: Optional[int],
    ctx: ShardCtx,
    force_mask: bool = False,
):
    """The shape-invariant adaptive round, split into its two stages.

    ``sample(r, state, monitor=None)`` picks round r's fresh anchors from
    the current estimate (and optionally the provisional monitor top-k, in
    the same persistent sweep — see :func:`_sample_round`);
    ``apply(r, state, idx_new)`` is everything downstream of the pick — the
    ε diversity mix, CE scoring, slab updates and the pinv/e_q refresh.
    ``body = apply ∘ sample`` is the staged round body; the persistent
    monitored loop software-pipelines the stages instead (round r+1's
    ``sample`` rides round r's monitor sweep), which is legal because
    ``sample`` only reads state that ``apply`` finalized: the composition
    order changes, the computed values do not.

    ``r`` may be a python int (unrolled) or a traced int32 (fori/while).
    ``scored`` is the engine's score-once wrapper (id-mapped, one CE call
    per pair system-wide); all item ids in play are global."""
    n_rand = int(round(cfg.round_epsilon * k_s))

    def sample(r, state: EngineState, monitor=None):
        return _sample_round(
            cfg, keys[r], state, r_anc, k_s - n_rand, n_valid, ctx,
            force_mask, monitor=monitor,
        )

    def apply(r, state: EngineState, idx_new) -> EngineState:
        key_r = keys[r]
        if n_rand:
            # ε-greedy diversity mix (beyond-paper; see AdaCURConfig)
            sel_tmp = _mark_selected(ctx, state.selected, idx_new)
            k_eps = jax.random.fold_in(key_r, 1)
            idx_rand = _sample_random_ctx(ctx, k_eps, sel_tmp, n_rand)
            idx_new = jnp.concatenate([idx_new, idx_rand], axis=1)
        selected = _mark_selected(ctx, state.selected, idx_new)
        start = r * k_s

        # exact CE scores for the new slab (Alg. 1 line 15)
        c_new = scored(query, idx_new)                         # (B, k_s)
        cols_new = _gather_cols(
            ctx, r_anc, idx_new, via_onehot=cfg.distributed_gather
        )                                                      # (B, k_q, k_s)

        anchor_idx = jax.lax.dynamic_update_slice(
            state.anchor_idx, idx_new, (0, start)
        )
        c_test = jax.lax.dynamic_update_slice(state.c_test, c_new, (0, start))

        # APPROXSCORES state update (Alg. 2) over the padded buffers
        if cfg.incremental_pinv:
            p = jax.vmap(cur.block_pinv_extend_static, in_axes=(0, 0, 0, None))(
                state.a_buf, state.p, cols_new, start
            )
            a_buf = jax.lax.dynamic_update_slice(
                state.a_buf, cols_new, (0, 0, start)
            )
        else:
            a_buf = jax.lax.dynamic_update_slice(
                state.a_buf, cols_new, (0, 0, start)
            )
            p = cur.pinv(a_buf, cfg.pinv_rcond)     # zero cols -> zero rows
        e_q = jnp.einsum("bk,bkq->bq", c_test, p)
        return EngineState(anchor_idx, c_test, a_buf, p, e_q, selected)

    def body(r, state: EngineState) -> EngineState:
        return apply(r, state, sample(r, state))

    return sample, apply, body


def _make_round_body(
    scored: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    keys: jax.Array,
    k_s: int,
    n_valid: Optional[int],
    ctx: ShardCtx,
    force_mask: bool = False,
) -> Callable[[jax.Array, EngineState], EngineState]:
    """The staged round body — ``apply ∘ sample`` (see _make_round_steps)."""
    return _make_round_steps(
        scored, r_anc, query, cfg, keys, k_s, n_valid, ctx, force_mask
    )[2]


def _provisional_topk(
    cfg: AdaCURConfig, e_q, r_anc, m: int, n_valid, invalid=None,
    ctx: Optional[ShardCtx] = None,
):
    """Top-m candidate ids of S_hat (unmasked) — the early-exit monitor.

    ``invalid`` is the runtime invalid-column mask of a dynamic corpus
    (padded capacity) — (N_local,), or (B, N_local) when a per-query
    eligibility restriction is in play; it replaces the static ``n_valid``
    bound.  Returns global ids (merged on a sharded context)."""
    ctx = ctx or _local_ctx(r_anc.shape[1])
    sharded = ctx.item_axes is not None
    if invalid is not None and invalid.ndim == 1:
        invalid = invalid[None, :]
    if cfg.use_fused_topk:
        mask = (
            None if invalid is None
            else jnp.broadcast_to(invalid, (e_q.shape[0], r_anc.shape[1]))
        )
        v, idx = approx_topk_op(
            e_q, r_anc, None, m, tile=_effective_tile(cfg, r_anc),
            interpret=cfg.fused_interpret,
            n_valid=None if sharded else n_valid, mask=mask,
        )
        if not sharded:
            return idx
        return _merge_topk(ctx, v, idx + _item_offset(ctx), m)[1]
    s_hat = quant.matmul(e_q, r_anc)
    if n_valid is not None and not sharded and n_valid < s_hat.shape[1]:
        s_hat = jnp.where(jnp.arange(s_hat.shape[1]) < n_valid, s_hat, sampling.NEG_INF)
    if invalid is not None:
        s_hat = jnp.where(invalid, sampling.NEG_INF, s_hat)
    return _local_topk_merge(ctx, s_hat, m)


def _pad_short_ranking(top_idx: jax.Array, top_s: jax.Array):
    """Keep under-filled rankings well-formed for callers.

    When a runtime ``n_rounds`` override or early exit leaves fewer filled
    candidates than ``k_retrieve``, trailing top-k slots would otherwise
    carry the -1 id sentinel with NEG_INF scores all the way to service
    responses.  Repeat the row-best candidate instead (top_k sorts
    descending, so position 0 is always a valid, exact-scored item)."""
    ok = top_s > 0.5 * sampling.NEG_INF
    return (
        jnp.where(ok, top_idx, top_idx[:, :1]),
        jnp.where(ok, top_s, top_s[:, :1]),
    )


def engine_search(
    score_fn: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    key: jax.Array,
    first_anchors: Optional[jax.Array] = None,
    batch: Optional[int] = None,
    n_valid_items=None,
    n_rounds=None,
    return_scores: Optional[bool] = None,
    item_ids: Optional[jax.Array] = None,
    eligible: Optional[jax.Array] = None,
    pos_map: Optional[jax.Array] = None,
    item_tokens: Optional[jax.Array] = None,
    deadline: Optional[AnytimeDeadline] = None,
    _ctx: Optional[ShardCtx] = None,
) -> AdaCURResult:
    """Run Algorithm 1 (+ retrieval) through the static-shape round engine.

    Mirrors :func:`repro.core.adacur.adacur_search` (same RNG stream, same
    budget accounting) with three extensions:

    - ``n_rounds``: runtime round-count override (``loop_mode='fori'`` only;
      may be a traced int32 ≤ ``cfg.n_rounds``).  Slabs beyond the executed
      rounds stay empty and are masked out of the final ranking, so one
      compiled executable serves every round count.
    - early exit: with ``cfg.early_exit_tol > 0`` the loop stops once the
      batch-mean overlap of consecutive provisional top-``k_retrieve`` sets
      reaches ``1 - tol``; ``AdaCURResult.rounds_done`` reports the count.
    - ``return_scores``: the (B, N) ``approx_scores`` field is only
      materialized on request (defaults to the dense path's behavior; the
      fused path defaults to ``None`` so no (B, N) buffer ever exists).

    Two further extensions serve the :class:`~repro.core.index.AnchorIndex`
    lifecycle: ``n_valid_items`` may be a *traced* int32 (dynamic corpora —
    growing/shrinking the valid prefix of a padded index never retraces),
    and ``item_ids`` (N,) maps engine positions to external corpus ids
    before every ``score_fn`` call.

    ``r_anc`` may be an fp32/bf16 array or an int8
    :class:`~repro.kernels.approx_topk.quant.QuantizedRanc` payload;
    ``cfg.payload_dtype`` converts a plain array up to the configured
    payload inside the trace (an AnchorIndex-backed retriever pre-quantizes
    instead — see ``Retriever.from_index``).

    Multi-stage retrieval (``core/candidates.py``) adds two runtime
    operands.  ``eligible`` — (N,) or per-query (B, N) bool — restricts the
    search to a candidate set over the full corpus: ineligible items are
    never sampled, never reranked, and are excluded from the early-exit
    monitor, while CE accounting is untouched (:func:`ce_call_plan` holds
    verbatim — the first stage spends no CE calls and every round still
    scores exactly k_s items, so callers must supply at least
    ``budget_ce`` eligible items per row).  ``pos_map`` — (N,) int32,
    ascending — declares the engine's columns to be a *candidate subset*
    gathered from those global corpus positions (see
    :func:`quant.subset_columns`): all noise draws then evaluate the
    canonical field at the mapped coordinates, which makes the subset
    search bit-identical to an ``eligible``-masked full-corpus search
    (ascending order preserves the ascending-id tie-break contract).
    Result indices stay in engine-local (subset) coordinates; callers remap
    through ``pos_map`` (as :class:`HybridRetriever` does).

    Device-resident scorers (``score_fn.device_resident``, e.g.
    :class:`~repro.core.scorer.DeviceCEScorer`) score *in-trace* instead of
    through a host callback: ``query`` is then the (B, Lq) query token
    batch and ``item_tokens`` the (N, Li) corpus token table
    (position-indexed, like the payload — ``item_ids`` never applies), from
    which pair rows are gathered and the CE forward runs inside the engine
    program (:func:`_device_ce_score`).  Defaults to the scorer's own
    ``item_tokens`` table when the operand is omitted.

    ``deadline`` (an :class:`AnytimeDeadline`) makes the search *anytime*:
    the round loop additionally polls the armed wall-clock deadline and
    exits early when it expires, returning the provisional top-k built from
    the rounds completed so far (``rounds_done`` reports the count and the
    unfilled slabs are masked out of the ranking exactly as under a runtime
    ``n_rounds`` override).  Requires ``loop_mode='fori'`` and is rejected
    under a shard context (per-shard clocks would disagree on the iteration
    count and deadlock the collectives).

    ``_ctx`` is the shard context when this call is the per-shard body of
    the SPMD engine (:func:`make_sharded_engine`); ``r_anc``/``item_ids``
    are then this shard's LOCAL slabs and ``query`` the local batch rows,
    while ``n_valid_items`` stays the GLOBAL valid count.
    """
    r_anc = quant.as_payload(r_anc, cfg.payload_dtype, cfg.payload_tile)
    k_q, n_items = r_anc.shape
    if pos_map is not None and _ctx is not None:
        raise ValueError(
            "pos_map (candidate-subset search) is single-shard only; under "
            "a mesh use the eligible mask over the sharded full corpus"
        )
    ctx = _ctx or _local_ctx(n_items, pos_map)
    sharded = ctx.item_axes is not None
    n_global = n_items * ctx.n_item_shards
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    r_max = cfg.n_rounds
    if k_i % r_max != 0:
        raise ValueError(f"k_i={k_i} not divisible by n_rounds={r_max}")
    k_s = k_i // r_max
    if return_scores is None:
        return_scores = not cfg.use_fused_topk and not sharded
    if sharded and return_scores:
        raise ValueError(
            "return_scores is unavailable under the sharded engine: the "
            "(B, N) approximate score matrix is exactly what sharding "
            "refuses to materialize"
        )
    n_valid = None
    invalid = None                        # (N_local,) runtime invalid mask
    if sharded:
        # the sharded engine is always on the dynamic-mask path: validity is
        # a local column mask derived from the (replicated) global bound
        nv = jnp.minimum(
            jnp.asarray(
                n_global if n_valid_items is None else n_valid_items, jnp.int32
            ),
            n_global,
        )
        local_pos = _item_offset(ctx) + jnp.arange(n_items, dtype=jnp.int32)
        invalid = local_pos >= nv
    elif n_valid_items is not None:
        if isinstance(n_valid_items, (int, np.integer)):
            if n_valid_items < n_items:
                n_valid = int(n_valid_items)
        else:
            nv = jnp.minimum(jnp.asarray(n_valid_items, jnp.int32), n_items)
            invalid = jnp.arange(n_items, dtype=jnp.int32) >= nv
    if eligible is not None:
        eligible = jnp.asarray(eligible, bool)
        if eligible.ndim == 1:
            eligible = eligible[None, :]
    # the early-exit monitor's invalid mask: padded tail + ineligible items
    mon_invalid = invalid
    if eligible is not None:
        mon_invalid = (
            ~eligible if invalid is None else (~eligible | invalid[None, :])
        )
    dyn_valid = invalid is not None or eligible is not None
    if cfg.loop_mode == "unrolled" and n_rounds is not None:
        raise ValueError("runtime n_rounds override requires loop_mode='fori'")
    if deadline is not None:
        if cfg.loop_mode != "fori":
            raise ValueError(
                "an anytime deadline needs the shape-invariant round loop: "
                "use loop_mode='fori'"
            )
        if _ctx is not None:
            raise ValueError(
                "anytime deadlines are single-device only: per-shard clocks "
                "would disagree on the round count and deadlock the SPMD "
                "program's collectives — the serving tier's unit of "
                "redundancy is the replica, not the shard"
            )

    if first_anchors is not None:
        b = first_anchors.shape[0]
        if first_anchors.shape[1] != k_s:
            raise ValueError(
                f"first_anchors must provide k_s={k_s} items, got {first_anchors.shape}"
            )
    elif batch is not None:
        b = batch
    else:
        b = jax.tree_util.tree_leaves(query)[0].shape[0]

    # the score-once wrapper: positions -> external ids -> exactly one CE
    # call per pair system-wide (item shard 0 scores, psum broadcasts) —
    # or, for device-resident scorers, positions -> token rows -> the CE
    # forward in-trace, split across the item shards
    if getattr(score_fn, "device_resident", False):
        if item_tokens is None:
            item_tokens = getattr(score_fn, "item_tokens", None)
        if item_tokens is None:
            raise ValueError(
                "a device-resident scorer needs the corpus token table: pass "
                "item_tokens= (carried by AnchorIndex.with_item_tokens) or "
                "construct the scorer with one"
            )
        if item_tokens.shape[0] != n_items:
            raise ValueError(
                f"item_tokens rows ({item_tokens.shape[0]}) must match the "
                f"payload's item capacity ({n_items}); the token table is "
                f"position-indexed alongside r_anc"
            )

        def scored(q, gidx, _tok=item_tokens):
            return _device_ce_score(ctx, score_fn, q, gidx, _tok)
    elif sharded:
        score_dtype = jax.eval_shape(
            lambda q, i: score_fn(q, i),
            query, jax.ShapeDtypeStruct((b, k_s), jnp.int32),
        ).dtype

        def scored(q, gidx):
            ids = gidx if item_ids is None else _map_item_ids(ctx, item_ids, gidx)
            return _score_once(ctx, score_fn, q, ids, score_dtype)
    elif item_ids is not None:
        def scored(q, gidx, _f=score_fn, _ids=item_ids):
            return _f(q, jnp.take(_ids, gidx, axis=0))
    else:
        scored = score_fn

    selected = jnp.zeros((b, n_items), dtype=bool)
    if n_valid is not None:
        selected = selected | (jnp.arange(n_items) >= n_valid)
    if invalid is not None:
        selected = selected | invalid[None, :]
    if eligible is not None:
        selected = selected | ~eligible

    # same RNG stream as the seed path: keys[r] drives round r
    keys = jax.random.split(key, r_max + 1)

    # --- round 0 (static): random or retriever-seeded first anchors --------
    if first_anchors is not None and cfg.first_round == "retriever":
        idx0 = first_anchors
    else:
        idx0 = _sample_random_ctx(ctx, keys[0], selected, k_s)
    selected = _mark_selected(ctx, selected, idx0)
    c0 = scored(query, idx0)                                   # (B, k_s)
    cols0 = _gather_cols(ctx, r_anc, idx0, via_onehot=cfg.distributed_gather)

    dtype = c0.dtype
    anchor_idx = jnp.full((b, k_i), -1, jnp.int32)
    anchor_idx = anchor_idx.at[:, :k_s].set(idx0.astype(jnp.int32))
    c_test = jnp.zeros((b, k_i), dtype).at[:, :k_s].set(c0)
    a_buf = jnp.zeros((b, k_q, k_i), cols0.dtype).at[:, :, :k_s].set(cols0)

    # rerank-only configurations (one retriever round, no budget split) never
    # read S_hat: skip the pinv/e_q machinery entirely.
    needs_scores = cfg.split_budget or return_scores or r_max > 1
    if needs_scores:
        p = jnp.zeros((b, k_i, k_q), dtype)
        p0 = (
            cur.incremental_pinv_init(cols0, cfg.pinv_rcond)
            if cfg.incremental_pinv
            else cur.pinv(cols0, cfg.pinv_rcond)
        )
        p = p.at[:, :k_s, :].set(p0)
        e_q = jnp.einsum("bk,bkq->bq", c_test, p)
    else:
        p = jnp.zeros((b, k_i, k_q), dtype)
        e_q = jnp.zeros((b, k_q), dtype)
    state = EngineState(anchor_idx, c_test, a_buf, p, e_q, selected)

    sample_step, apply_step, body = _make_round_steps(
        scored, r_anc, query, cfg, keys, k_s, n_valid, ctx, force_mask=dyn_valid
    )

    # --- rounds 1..n_rounds-1 ----------------------------------------------
    if cfg.loop_mode == "unrolled":
        for r in range(1, r_max):
            state = body(r, state)
        rounds_done = jnp.asarray(r_max, jnp.int32)
    else:
        r_dyn = jnp.asarray(r_max if n_rounds is None else n_rounds, jnp.int32)
        r_dyn = jnp.clip(r_dyn, 1, r_max)
        if cfg.early_exit_tol > 0.0 and cfg.round_kernel == "persistent":
            # software-pipelined monitored loop: round r+1's anchor sample
            # and round r's provisional monitor ride ONE persistent payload
            # sweep.  Legal because the sample at round r+1 reads exactly
            # the state apply(r) finalized — the same (e_q, selected, key)
            # the staged loop would hand it one iteration later — so every
            # value (and rounds_done) is bit-identical to the staged loop;
            # only the number of payload passes halves.
            m = min(cfg.k_retrieve, n_global)
            pending, prev = sample_step(1, state, monitor=(m, mon_invalid))

            def cond(carry):
                r, frac, _, _, _ = carry
                go = (r < r_dyn) & (frac < 1.0 - cfg.early_exit_tol)
                if deadline is not None:
                    go = go & ~deadline.expired(r)
                return go

            def while_body(carry):
                r, _, st, prev_top, pend = carry
                st = apply_step(r, st, pend)
                pend_next, cur_top = sample_step(
                    r + 1, st, monitor=(m, mon_invalid)
                )
                hit = (cur_top[:, :, None] == prev_top[:, None, :]).any(-1)
                return r + 1, _global_frac(ctx, hit), st, cur_top, pend_next

            rounds_done, _, state, _, _ = jax.lax.while_loop(
                cond, while_body,
                (jnp.int32(1), jnp.float32(0.0), state, prev, pending),
            )
        elif cfg.early_exit_tol > 0.0:
            m = min(cfg.k_retrieve, n_global)
            prev = _provisional_topk(
                cfg, state.e_q, r_anc, m, n_valid, mon_invalid, ctx
            )

            def cond(carry):
                r, frac, _, _ = carry
                go = (r < r_dyn) & (frac < 1.0 - cfg.early_exit_tol)
                if deadline is not None:
                    go = go & ~deadline.expired(r)
                return go

            def while_body(carry):
                r, _, st, prev_top = carry
                st = body(r, st)
                cur_top = _provisional_topk(
                    cfg, st.e_q, r_anc, m, n_valid, mon_invalid, ctx
                )
                hit = (cur_top[:, :, None] == prev_top[:, None, :]).any(-1)
                return r + 1, _global_frac(ctx, hit), st, cur_top

            rounds_done, _, state, _ = jax.lax.while_loop(
                cond, while_body, (jnp.int32(1), jnp.float32(0.0), state, prev)
            )
        elif deadline is not None:
            # anytime loop: same math as the fori path, but the cond also
            # polls the armed wall-clock deadline — a mid-search expiry exits
            # at the next round boundary with the provisional state so far
            def cond(carry):
                r, _ = carry
                return (r < r_dyn) & ~deadline.expired(r)

            def while_body(carry):
                r, st = carry
                return r + 1, body(r, st)

            rounds_done, state = jax.lax.while_loop(
                cond, while_body, (jnp.int32(1), state)
            )
        else:
            state = jax.lax.fori_loop(1, r_dyn, body, state)
            rounds_done = r_dyn

    anchor_idx, c_test = state.anchor_idx, state.c_test
    n_filled = rounds_done * k_s
    valid_slot = jnp.arange(k_i) < n_filled                    # (k_i,)
    anchor_logits = jnp.where(valid_slot[None, :], c_test, sampling.NEG_INF)
    s_hat = quant.matmul(state.e_q, r_anc) if return_scores else None

    # --- retrieval ---------------------------------------------------------
    if not cfg.split_budget:
        # ADACUR^No-Split: rank the anchors by their exact CE scores (free).
        k = min(cfg.k_retrieve, k_i)
        top_s, top_pos = jax.lax.top_k(anchor_logits, k)
        top_idx = jnp.take_along_axis(anchor_idx, top_pos, axis=1)
        top_idx, top_s = _pad_short_ranking(top_idx, top_s)
        return AdaCURResult(
            anchor_idx, c_test, s_hat, top_idx, top_s, ce_call_plan(cfg),
            rounds_done,
        )

    # ADACUR (split): spend the remaining budget on fresh exact CE calls for
    # the top approximate-scoring non-anchor items.
    k_r = cfg.budget_ce - k_i
    if cfg.use_fused_topk:
        v_r, rerank_idx = approx_topk_op(
            state.e_q, r_anc, k=k_r, tile=_effective_tile(cfg, r_anc),
            interpret=cfg.fused_interpret,
            n_valid=None if sharded else n_valid,
            **_fused_suppress(cfg, state, dyn_valid or sharded),
        )
        if sharded:
            _, rerank_idx = _merge_topk(
                ctx, v_r, rerank_idx + _item_offset(ctx), k_r
            )
    else:
        full = s_hat if s_hat is not None else quant.matmul(state.e_q, r_anc)
        masked = jnp.where(state.selected, sampling.NEG_INF, full)
        rerank_idx = _local_topk_merge(ctx, masked, k_r)       # (B, k_r)
    rerank_scores = scored(query, rerank_idx)                  # k_r CE calls
    pool_idx = jnp.concatenate([anchor_idx, rerank_idx], axis=1)
    pool_scores = jnp.concatenate([anchor_logits, rerank_scores], axis=1)
    k = min(cfg.k_retrieve, pool_idx.shape[1])
    top_s, top_pos = jax.lax.top_k(pool_scores, k)
    top_idx = jnp.take_along_axis(pool_idx, top_pos, axis=1)
    top_idx, top_s = _pad_short_ranking(top_idx, top_s)
    return AdaCURResult(
        anchor_idx, c_test, s_hat, top_idx, top_s, ce_call_plan(cfg),
        rounds_done,
    )


def make_engine(
    score_fn: ScoreFn,
    cfg: AdaCURConfig,
    n_valid_items=None,
    return_scores: Optional[bool] = None,
    jit_compile: bool = True,
    anytime: bool = False,
):
    """jit-compiled engine closure over a concrete scorer + config.

    In ``fori`` mode the returned callable takes an optional runtime
    ``n_rounds`` (any value in [1, cfg.n_rounds]) *without retracing* — the
    round count is a traced operand of one compiled executable.  ``n_valid``
    and ``item_ids`` are likewise traced operands (AnchorIndex dynamic
    corpora: mutation changes their *values*, never the trace).

    ``jit_compile=False`` runs the engine eagerly (``loop_mode='unrolled'``
    only) so non-traceable scorers — numpy tokenizers, external CE services —
    still go through the one engine code path.

    ``anytime=True`` (``fori`` mode only) threads an :class:`AnytimeDeadline`
    through the round loop and exposes it as ``run.deadline``: arm it with
    an absolute ``time.monotonic()`` deadline before a search and the loop
    exits at the first round boundary past it, returning the provisional
    top-k of the rounds completed (``rounds_done`` + ``deadline.fired``
    tell the serving layer to flag the response degraded).  Costs one
    numpy-only host callback per executed round, so it is opt-in.
    """
    if not jit_compile and cfg.loop_mode != "unrolled":
        raise ValueError("jit_compile=False requires loop_mode='unrolled'")
    deadline = None
    if anytime:
        if cfg.loop_mode != "fori":
            raise ValueError("anytime=True requires loop_mode='fori' (the "
                             "deadline cuts a runtime round loop)")
        deadline = AnytimeDeadline()

    def _run(r_anc, query, key, n_rounds, first_anchors=None, batch=None,
             n_valid=None, item_ids=None, eligible=None, pos_map=None,
             item_tokens=None):
        return engine_search(
            score_fn, r_anc, query, cfg, key,
            first_anchors=first_anchors, batch=batch,
            n_valid_items=n_valid if n_valid is not None else n_valid_items,
            n_rounds=n_rounds, return_scores=return_scores, item_ids=item_ids,
            eligible=eligible, pos_map=pos_map, item_tokens=item_tokens,
            deadline=deadline,
        )

    if jit_compile:
        _run = partial(jax.jit, static_argnames=("batch",))(_run)

    def run(r_anc, query, key, first_anchors=None, batch=None, n_rounds=None,
            n_valid=None, item_ids=None, eligible=None, pos_map=None,
            item_tokens=None):
        if cfg.loop_mode == "fori":
            n_rounds = jnp.asarray(
                cfg.n_rounds if n_rounds is None else n_rounds, jnp.int32
            )
        elif n_rounds is not None:
            raise ValueError("runtime n_rounds override requires loop_mode='fori'")
        if n_valid is not None:
            n_valid = jnp.asarray(n_valid, jnp.int32)
        return _run(r_anc, query, key, n_rounds, first_anchors, batch,
                    n_valid, item_ids, eligible, pos_map, item_tokens)

    run.deadline = deadline
    return run


def _payload_specs(r_anc, item_axes: Tuple[str, ...]):
    """shard_map in_spec tree for the payload operand: codes column-sharded,
    per-tile scales co-sharded on the same axes.

    The spec tree must carry the operand's static meta (tile, code_dtype,
    n_cols) verbatim or the pytree structures mismatch.  Packed int4 shards
    cleanly because shard slabs are even (whole even tiles), so the packed
    byte axis divides exactly and the ``n_cols=-1`` "2x the packed width"
    sentinel stays correct per shard."""
    if isinstance(r_anc, QuantizedRanc):
        return QuantizedRanc(
            codes=P(None, item_axes), scales=P(item_axes), tile=r_anc.tile,
            code_dtype=r_anc.code_dtype, n_cols=r_anc.n_cols,
        )
    return P(None, item_axes)


def make_sharded_engine(
    score_fn: ScoreFn,
    cfg: AdaCURConfig,
    mesh: Mesh,
    *,
    item_axes: Tuple[str, ...] = ("items",),
    data_axes: Optional[Tuple[str, ...]] = None,
    n_valid_items=None,
    jit_compile: bool = True,
):
    """The SPMD engine: one ``shard_map`` program over a (data x items) mesh.

    The returned callable has :func:`make_engine`'s signature.  Inside, the
    whole multi-round search — estimate, fused score->sample, CE scoring,
    incremental pinv / e_q update, provisional top-k, rerank — is the
    per-shard math core of :func:`engine_search` on a live :class:`ShardCtx`:

    - ``item_axes`` shard the payload (fp32 columns, or int8 codes with
      their co-sharded per-tile scales), the per-shard ``selected`` slab and
      the ``item_ids`` map; per-round candidates cross shards only as
      (B, k) lists through the documented tie-break merge;
    - ``data_axes`` (default: every mesh axis not in ``item_axes`` named
      ``pod``/``data``) shard the query batch; the blocked noise field keys
      off global row ids, so the data split never changes a trajectory;
    - the pinv/e_q state replicates — it is O(B·k_i·k_q), mesh-independent.

    Results are **bit-identical** to the single-device engine for every
    loop mode and payload dtype (the collective-layer contracts; asserted
    by ``tests/test_multidevice.py``).  ``n_rounds``, ``n_valid`` and the
    index's ``item_ids`` are traced operands of the one compiled program:
    runtime round counts and corpus mutation never retrace.

    Constraints checked here: the global batch divides over ``data_axes``;
    the capacity divides over ``item_axes`` into ``NOISE_BLOCK``-aligned
    slabs holding whole payload tiles (``AnchorIndex.shard`` guarantees
    this); and every per-shard candidate list (``k_s``, the rerank budget,
    ``k_retrieve``) fits in one shard's slab.

    Scorer constraint: the real cross-encoder runs as a *device-resident
    stage* of this program — pass a scorer with ``device_resident=True``
    (:class:`~repro.core.scorer.DeviceCEScorer`) plus the corpus token
    table (``item_tokens=``, carried by ``AnchorIndex.with_item_tokens``),
    and each round's pair assembly + transformer forward happen in-trace,
    split across the item shards, with no host round-trip.  Host-callback
    scorers remain acceptable when the callback is NUMPY-ONLY —
    ``TabulatedScorer`` (and ``CachingScorer`` over it) fire on item shard
    0 and psum-broadcast, which is exactly right for matrix lookups and
    tests.  What is *rejected* (at construction, via the scorer's
    ``nested_device_callback`` capability flag) is a host callback that
    launches nested device compute — ``CrossEncoderScorer``'s jitted
    forward deadlocks a single-process multi-device runtime, the nested
    launch contending with shards parked at the score-broadcast psum.
    """
    if not jit_compile:
        raise ValueError("the sharded engine is a compiled SPMD program; "
                         "jit_compile=False is only available unsharded")
    if getattr(score_fn, "nested_device_callback", False):
        raise ValueError(
            "this scorer's host callback launches nested device compute (a "
            "jitted CE forward) and would deadlock the SPMD program's psum "
            "rendezvous; under a mesh run the real CE device-resident "
            "(DeviceCEScorer + an index token table) — numpy-only callback "
            "scorers (TabulatedScorer, CachingScorer over it) stay supported"
        )
    item_axes = (item_axes,) if isinstance(item_axes, str) else tuple(item_axes)
    if data_axes is None:
        data_axes = tuple(
            a for a in mesh.axis_names
            if a not in item_axes and a in ("pod", "data")
        )
    data_axes = tuple(data_axes)
    n_item_shards = math.prod(mesh.shape[a] for a in item_axes)
    n_data_shards = math.prod(mesh.shape[a] for a in data_axes) if data_axes else 1
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    k_s = k_i // cfg.n_rounds
    k_r = cfg.budget_ce - k_i if cfg.split_budget else 0

    data_spec = P(data_axes) if data_axes else P()

    def _validate(r_anc, b_global):
        capacity = r_anc.shape[1]
        if capacity % n_item_shards:
            raise ValueError(
                f"capacity {capacity} not divisible over {n_item_shards} item "
                f"shards (AnchorIndex.shard aligns this)"
            )
        n_local = capacity // n_item_shards
        if n_item_shards > 1 and n_local % sampling.NOISE_BLOCK:
            raise ValueError(
                f"per-shard slab {n_local} must hold whole NOISE_BLOCK="
                f"{sampling.NOISE_BLOCK} noise blocks"
            )
        if isinstance(r_anc, QuantizedRanc) and n_local % r_anc.tile:
            raise ValueError(
                f"per-shard slab {n_local} must hold whole payload tiles "
                f"({r_anc.tile})"
            )
        need = max(k_s, k_r, min(cfg.k_retrieve, capacity))
        if need > n_local:
            raise ValueError(
                f"per-shard candidate list ({need}) exceeds the per-shard "
                f"slab ({n_local}); use fewer item shards"
            )
        if b_global % n_data_shards:
            raise ValueError(
                f"batch {b_global} not divisible over {n_data_shards} data shards"
            )
        return n_local

    def core(r_anc, query, key, n_rounds, n_valid, item_ids, first_anchors,
             eligible, item_tokens):
        n_local = r_anc.shape[1]
        b_local = jax.tree_util.tree_leaves(query)[0].shape[0]
        ctx = ShardCtx(
            item_axes=item_axes,
            data_axes=data_axes,
            n_local=n_local,
            n_item_shards=n_item_shards,
            item_shard=_axes_index(item_axes),
            row_offset=_axes_index(data_axes) * b_local if data_axes else 0,
        )
        res = engine_search(
            score_fn, r_anc, query, cfg, key,
            first_anchors=first_anchors,
            n_valid_items=n_valid, n_rounds=n_rounds,
            return_scores=False, item_ids=item_ids, eligible=eligible,
            item_tokens=item_tokens, _ctx=ctx,
        )
        return (res.anchor_idx, res.anchor_scores, res.topk_idx,
                res.topk_scores, res.rounds_done)

    compiled = {}          # (has_first, query treedef/ranks) -> jitted fn

    def run(r_anc, query, key, first_anchors=None, batch=None, n_rounds=None,
            n_valid=None, item_ids=None, eligible=None, pos_map=None,
            item_tokens=None):
        if pos_map is not None:
            raise ValueError(
                "pos_map (candidate-subset search) is single-shard only; "
                "pass eligible= to restrict a sharded search"
            )
        if cfg.loop_mode == "fori":
            n_rounds = jnp.asarray(
                cfg.n_rounds if n_rounds is None else n_rounds, jnp.int32
            )
        elif n_rounds is not None:
            raise ValueError("runtime n_rounds override requires loop_mode='fori'")
        if batch is not None:
            raise ValueError(
                "the sharded engine derives the batch from the query leaves "
                "(or first_anchors); the batch= override would leave the "
                "query un-shardable over the data axes — pass batched "
                "query operands instead"
            )
        r_anc = quant.as_payload(r_anc, cfg.payload_dtype, cfg.payload_tile)
        b = (
            first_anchors.shape[0] if first_anchors is not None
            else jax.tree_util.tree_leaves(query)[0].shape[0]
        )
        _validate(r_anc, b)
        capacity = r_anc.shape[1]
        if n_valid is None:
            n_valid = capacity if n_valid_items is None else n_valid_items
        n_valid = jnp.asarray(n_valid, jnp.int32)
        if item_ids is None:
            item_ids = jnp.arange(capacity, dtype=jnp.int32)
        if getattr(score_fn, "device_resident", False):
            if item_tokens is None:
                item_tokens = getattr(score_fn, "item_tokens", None)
            if item_tokens is None:
                raise ValueError(
                    "a device-resident scorer needs the corpus token table "
                    "under the mesh: pass item_tokens= (carried by "
                    "AnchorIndex.with_item_tokens) or construct the scorer "
                    "with one"
                )
            item_tokens = jnp.asarray(item_tokens, jnp.int32)
            if item_tokens.shape[0] != capacity:
                raise ValueError(
                    f"item_tokens rows ({item_tokens.shape[0]}) must match "
                    f"the payload capacity ({capacity}); the token table is "
                    f"position-aligned with r_anc (AnchorIndex keeps them in "
                    f"lockstep through mutation)"
                )
        else:
            item_tokens = None
        query_specs = jax.tree.map(
            lambda leaf: P(data_axes, *([None] * (jnp.ndim(leaf) - 1)))
            if data_axes else P(),
            query,
        )
        if eligible is not None:
            eligible = jnp.asarray(eligible, bool)
        sig = (
            first_anchors is not None,
            jax.tree_util.tree_structure(query),
            tuple(jnp.ndim(l) for l in jax.tree_util.tree_leaves(query)),
            quant.payload_dtype_of(r_anc),
            None if eligible is None else eligible.ndim,
            item_tokens is not None,
        )
        if sig not in compiled:
            if eligible is None:
                eligible_spec = None
            elif eligible.ndim == 1:
                eligible_spec = P(item_axes)
            else:
                eligible_spec = P(data_axes if data_axes else None, item_axes)
            in_specs = (
                _payload_specs(r_anc, item_axes),     # r_anc
                query_specs,                          # query
                P(),                                  # key
                P() if cfg.loop_mode == "fori" else None,  # n_rounds
                P(),                                  # n_valid
                P(item_axes),                         # item_ids
                data_spec if first_anchors is not None else None,
                eligible_spec,                        # eligible
                P(item_axes, None) if item_tokens is not None else None,
            )
            out_specs = (data_spec, data_spec, data_spec, data_spec, P())

            live_specs = tuple(s for s in in_specs if s is not None)

            def entry(r_anc, query, key, n_rounds, n_valid, item_ids,
                      first_anchors, eligible, item_tokens):
                args = (r_anc, query, key, n_rounds, n_valid, item_ids,
                        first_anchors, eligible, item_tokens)
                live = tuple(a for a, s in zip(args, in_specs) if s is not None)

                def body(*live_args):
                    it = iter(live_args)
                    full = tuple(
                        next(it) if s is not None else None for s in in_specs
                    )
                    return core(*full)

                return shard_map(
                    body, mesh=mesh, in_specs=live_specs,
                    out_specs=out_specs, check_vma=False,
                )(*live)

            compiled[sig] = jax.jit(entry, static_argnums=())
        anchor_idx, c_test, top_idx, top_s, rounds_done = compiled[sig](
            r_anc, query, key, n_rounds, n_valid, item_ids, first_anchors,
            eligible, item_tokens,
        )
        return AdaCURResult(
            anchor_idx, c_test, None, top_idx, top_s,
            ce_call_plan(cfg), rounds_done,
        )

    return run


# ---------------------------------------------------------------------------
# Unified Retriever API — ADACUR / ANNCUR / retrieve-and-rerank as
# configurations of the one engine code path.
# ---------------------------------------------------------------------------


@runtime_checkable
class Retriever(Protocol):
    """Anything that answers a k-NN query batch under a CE-call budget."""

    def search(self, query, key: Optional[jax.Array] = None, **kw) -> AdaCURResult:
        ...


class _IndexBacked:
    """Shared plumbing for retrievers that consume an AnchorIndex.

    The index's arrays (``r_anc``, ``n_valid``, ``item_ids``) enter the
    compiled engine as *traced operands* read from ``self.index`` at every
    search, so swapping in a mutated index (``retriever.index = new_index``)
    changes values only — shapes are capacity-constant and nothing retraces.

    The runtime ``n_valid`` bound is only passed when the index is (or was
    constructed) padded: an unpadded index keeps the engine's static path,
    whose fused TPU sampling suppresses via the compact anchor-id list
    instead of a (B, N) mask.  Removing items from an unpadded index flips
    it to the dynamic path (one retrace, then stable).

    ``cfg.payload_dtype`` is applied to the index ONCE at construction
    (:meth:`_apply_payload_policy`): the engine then receives an already
    bf16/int8 payload operand and never re-converts per call.  An index that
    is already quantized is authoritative and passes through unchanged.

    An index whose item axis is placed over a mesh (``AnchorIndex.shard`` /
    ``load(path, mesh)``) makes the retriever bind the **SPMD engine**
    (:func:`make_sharded_engine`) instead: the full multi-round search runs
    as one ``shard_map`` program with the payload item-sharded and the query
    batch sharded over the mesh's ``data``/``pod`` axes, bit-identical to
    the single-device engine.
    """

    def _build_engine(self, cfg: AdaCURConfig, n_valid_items=None,
                      return_scores: Optional[bool] = None,
                      jit_compile: bool = True,
                      anytime: bool = False) -> Callable:
        """make_engine or make_sharded_engine, by the index's placement."""
        idx = getattr(self, "index", None)
        mesh = axes = None
        if idx is not None:
            mesh, axes = idx._item_sharding()
        if mesh is None:
            self._sharded = False
            return make_engine(
                self.score_fn, cfg, n_valid_items,
                return_scores=return_scores, jit_compile=jit_compile,
                anytime=anytime,
            )
        if anytime:
            raise ValueError(
                "anytime deadlines are single-device only: a sharded engine "
                "polling per-shard clocks would diverge across shards and "
                "deadlock the SPMD collectives"
            )
        self._sharded = True
        return make_sharded_engine(
            self.score_fn, cfg, mesh, item_axes=axes,
            n_valid_items=n_valid_items, jit_compile=jit_compile,
        )

    def _apply_payload_policy(self, cfg: AdaCURConfig) -> None:
        idx = getattr(self, "index", None)
        if idx is None or cfg.payload_dtype == "float32":
            return
        if (idx.payload_dtype == cfg.payload_dtype
                or idx.payload_dtype in quant.CODE_DTYPES):
            # already compliant — or already quantized (int8/int4/fp8),
            # which is authoritative (mirrors quant.as_payload: the policy
            # converts payloads UP, it never requantizes a coded artifact)
            return
        mesh, _ = idx._item_sharding()
        new = idx.quantize(cfg.payload_dtype, tile=cfg.payload_tile)
        if mesh is not None:
            # re-place the converted payload: quantization is a reshaping
            # computation whose output placement XLA chooses freely
            new = new.shard(mesh)
        self.index = new

    def _prep_query(self, query):
        """Device-resident scorers take token operands: map a (B,) query-id
        batch through the scorer's host tokenizer (once, before the round
        loop); every other scorer passes the query through untouched."""
        tok = getattr(self.score_fn, "tokenize_queries", None)
        return query if tok is None else tok(query)

    def _search_operands(self):
        if self.index is None:
            return self.r_anc, {}
        kw = dict(item_ids=self.index.item_ids)
        if not getattr(self, "_dynamic_valid", False):
            # the padded? device->host sync runs once per index object, not
            # per search; once dynamic, the trace stays dynamic forever
            if getattr(self, "_seen_index", None) is not self.index:
                self._seen_index = self.index
                self._dynamic_valid = self.index.capacity > self.index.n_items
        if self._dynamic_valid:
            kw["n_valid"] = self.index.n_valid
        if (getattr(self.score_fn, "device_resident", False)
                and getattr(self.index, "item_tokens", None) is not None):
            # the index's table is authoritative: position-aligned with the
            # payload through every mutation (the scorer's own copy is not)
            kw["item_tokens"] = self.index.item_tokens
        return self.index.r_anc, kw


@dataclass
class AdaCURRetriever(_IndexBacked):
    """The paper's method (Alg. 1) on the static-shape engine."""

    score_fn: ScoreFn
    r_anc: Optional[jax.Array]
    cfg: AdaCURConfig
    n_valid_items: Optional[int] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    jit: bool = True
    anytime: bool = False
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        self._apply_payload_policy(self.cfg)
        self._run = self._build_engine(
            self.cfg, self.n_valid_items, jit_compile=self.jit,
            anytime=self.anytime,
        )
        self.deadline = getattr(self._run, "deadline", None)

    @classmethod
    def from_index(cls, index, score_fn: ScoreFn, cfg: AdaCURConfig,
                   jit: bool = True, anytime: bool = False) -> "AdaCURRetriever":
        """Bind the engine to an :class:`~repro.core.index.AnchorIndex`:
        ``score_fn`` receives *external item ids* (the engine maps positions
        through ``index.item_ids``), padded capacity is masked through the
        runtime ``n_valid`` bound, and index mutation never retraces."""
        return cls(score_fn, None, cfg, index=index, jit=jit, anytime=anytime)

    def search(self, query, key=None, first_anchors=None, batch=None,
               n_rounds=None, deadline_t=None, **_ignored):
        key = jax.random.PRNGKey(0) if key is None else key
        query = self._prep_query(query)
        r_anc, kw = self._search_operands()
        if deadline_t is None:
            return self._run(
                r_anc, query, key, first_anchors=first_anchors, batch=batch,
                n_rounds=n_rounds, **kw,
            )
        if self.deadline is None:
            raise ValueError("deadline_t= requires anytime=True at construction")
        # arm -> run -> *block* -> disarm: the dispatch is async, so the
        # deadline must stay armed until the round loop has actually executed;
        # ``deadline.fired`` then tells the caller whether the answer is a
        # provisional (degraded) top-k of ``rounds_done`` rounds.
        self.deadline.arm(deadline_t)
        try:
            res = self._run(
                r_anc, query, key, first_anchors=first_anchors, batch=batch,
                n_rounds=n_rounds, **kw,
            )
            jax.block_until_ready(res.topk_idx)
            return res
        finally:
            self.deadline.disarm()


@dataclass
class ANNCURRetriever(_IndexBacked):
    """Fixed-anchor one-round special case (Yadav et al. 2022).

    The offline index is just the anchor id set; ``search`` is one
    retriever-seeded engine round followed by the split-budget rerank — the
    identical code path ADACUR uses, at ``n_rounds=1``.  With
    ``budget_ce == k_anchor`` there is no rerank budget left and the final
    ranking is the free exact-score ranking of the anchors themselves
    (the engine's no-split configuration).
    """

    score_fn: ScoreFn
    r_anc: Optional[jax.Array]
    anchor_idx: Optional[jax.Array]      # (k_i,) fixed anchor item positions
    budget_ce: int = 0
    k_retrieve: int = 100
    pinv_rcond: float = 1e-6
    base_cfg: Optional[AdaCURConfig] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    jit: bool = True
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.anchor_idx is None:
            if self.index is None or self.index.anchor_item_pos is None:
                raise ValueError(
                    "need anchor_idx or an AnchorIndex with anchors "
                    "(index.with_anchors() / with_latents())"
                )
            k_i = int(self.index.anchor_item_pos.shape[0])
        else:
            k_i = int(self.anchor_idx.shape[0])
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        if self.budget_ce < k_i:
            raise ValueError(f"budget_ce={self.budget_ce} < k_anchor={k_i}")
        base = self.base_cfg or AdaCURConfig()
        split = self.budget_ce > k_i
        self.cfg = replace(
            base, k_anchor=k_i, n_rounds=1, budget_ce=self.budget_ce,
            split_budget=split, first_round="retriever",
            k_retrieve=self.k_retrieve, pinv_rcond=self.pinv_rcond,
            round_epsilon=0.0, early_exit_tol=0.0,
        )
        self._apply_payload_policy(self.cfg)
        self._run = self._build_engine(self.cfg, jit_compile=self.jit)

    @classmethod
    def from_index(cls, index, score_fn: ScoreFn, budget_ce: int,
                   k_retrieve: int = 100, pinv_rcond: float = 1e-6,
                   base_cfg: Optional[AdaCURConfig] = None,
                   jit: bool = True) -> "ANNCURRetriever":
        """ANNCUR over an :class:`~repro.core.index.AnchorIndex` that carries
        latents; anchors are read from the index at every search, so a
        mutated index (whose anchor positions may have been compacted) is
        picked up without retracing."""
        return cls(score_fn, None, None, budget_ce, k_retrieve, pinv_rcond,
                   base_cfg, index=index, jit=jit)

    def search(self, query, key=None, **kw):
        key = jax.random.PRNGKey(0) if key is None else key
        query = self._prep_query(query)
        anchors = (
            self.index.anchor_item_pos
            if self.anchor_idx is None else self.anchor_idx
        )
        b = jax.tree_util.tree_leaves(query)[0].shape[0]
        first = jnp.broadcast_to(
            anchors[None, :].astype(jnp.int32), (b, anchors.shape[0])
        )
        r_anc, opkw = self._search_operands()
        return self._run(r_anc, query, key, first_anchors=first, **opkw)


@dataclass
class RerankRetriever(_IndexBacked):
    """Retrieve-and-rerank baseline: one retriever-seeded round, no split.

    Every candidate is exact-CE scored (they *are* the anchors) and the
    final ranking is the free top-k over those scores — i.e.
    ``retrieval.rerank_baseline`` expressed as an engine configuration.
    """

    score_fn: ScoreFn
    r_anc: Optional[jax.Array]
    budget_ce: int = 0
    k_retrieve: int = 100
    base_cfg: Optional[AdaCURConfig] = None
    index: Optional[object] = None       # repro.core.index.AnchorIndex
    jit: bool = True
    _run: Callable = field(init=False, repr=False)

    def __post_init__(self):
        if self.r_anc is None and self.index is None:
            raise ValueError("need r_anc or an AnchorIndex")
        base = self.base_cfg or AdaCURConfig()
        self.cfg = replace(
            base, k_anchor=self.budget_ce, n_rounds=1,
            budget_ce=self.budget_ce, split_budget=False,
            first_round="retriever", k_retrieve=self.k_retrieve,
            round_epsilon=0.0, early_exit_tol=0.0,
        )
        self._apply_payload_policy(self.cfg)
        # pure rerank never reads S_hat: skip the pinv/e_q machinery
        self._run = self._build_engine(
            self.cfg, return_scores=False, jit_compile=self.jit
        )

    @classmethod
    def from_index(cls, index, score_fn: ScoreFn, budget_ce: int,
                   k_retrieve: int = 100,
                   base_cfg: Optional[AdaCURConfig] = None,
                   jit: bool = True) -> "RerankRetriever":
        return cls(score_fn, None, budget_ce, k_retrieve, base_cfg,
                   index=index, jit=jit)

    def search(self, query, key=None, candidate_idx=None, **kw):
        if candidate_idx is None:
            raise ValueError("RerankRetriever.search needs candidate_idx (B, >=budget)")
        key = jax.random.PRNGKey(0) if key is None else key
        query = self._prep_query(query)
        first = candidate_idx[:, : self.budget_ce].astype(jnp.int32)
        r_anc, opkw = self._search_operands()
        return self._run(r_anc, query, key, first_anchors=first, **opkw)


# ---------------------------------------------------------------------------
# Introspection: prove the fused path never materializes (B, N) scores.
# ---------------------------------------------------------------------------


def _iter_sub_jaxprs(params: dict):
    """Jaxprs nested in an eqn's params (scan/while/cond/pallas bodies).

    Duck-typed walk instead of jax.core.jaxprs_in_params — that helper is
    private and has moved across JAX releases."""
    for val in params.values():
        for item in val if isinstance(val, (tuple, list)) else (val,):
            j = getattr(item, "jaxpr", item)   # ClosedJaxpr -> Jaxpr
            if hasattr(j, "eqns"):
                yield j


def _count_bn_floats(jaxpr, b: int, n: int) -> int:
    """Recursively count eqn outputs with float aval of shape (b, n)."""
    count = 0
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            aval = getattr(v, "aval", None)
            if (
                aval is not None
                and getattr(aval, "shape", None) == (b, n)
                and jnp.issubdtype(aval.dtype, jnp.floating)
            ):
                count += 1
        for sub in _iter_sub_jaxprs(eqn.params):
            count += _count_bn_floats(sub, b, n)
    return count


def round_body_bn_intermediates(
    score_fn: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    batch: Optional[int] = None,
) -> int:
    """Number of (B, N) float intermediates in ONE adaptive round body.

    Dense sampling scores every item each round (>= 1); the fused-kernel
    TopK path must report 0 — the per-round claim behind the Fig. 4
    latency argument, checked by jaxpr inspection rather than trust.
    """
    r_anc = quant.as_payload(r_anc, cfg.payload_dtype, cfg.payload_tile)
    k_q, n_items = r_anc.shape
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    k_s = k_i // cfg.n_rounds
    b = batch or jax.tree_util.tree_leaves(query)[0].shape[0]
    keys = jax.random.split(jax.random.PRNGKey(0), cfg.n_rounds + 1)
    body = _make_round_body(
        score_fn, r_anc, query, cfg, keys, k_s, None, _local_ctx(n_items)
    )
    dtype = jnp.float32
    state = EngineState(
        anchor_idx=jnp.zeros((b, k_i), jnp.int32),
        c_test=jnp.zeros((b, k_i), dtype),
        a_buf=jnp.zeros((b, k_q, k_i), dtype),
        p=jnp.zeros((b, k_i, k_q), dtype),
        e_q=jnp.zeros((b, k_q), dtype),
        selected=jnp.zeros((b, n_items), bool),
    )
    closed = jax.make_jaxpr(lambda st: body(jnp.int32(1), st))(state)
    return _count_bn_floats(closed.jaxpr, b, n_items)


def engine_slab_bytes(
    cfg: AdaCURConfig, batch: int, n_items: int, k_q: int,
    n_data_shards: int = 1, n_item_shards: int = 1,
    payload=None,
) -> dict:
    """Device bytes of the engine's preallocated per-search state slabs —
    PER SHARD when a (data x items) decomposition is given.

    The engine's whole working set is these six buffers plus the payload it
    streams; reporting them next to the index payload in BENCH_engine.json /
    BENCH_sharded.json tracks the memory story alongside latency as N and
    the mesh scale.  Under the SPMD engine the batch dimension divides over
    ``n_data_shards`` everywhere, and the item axis — which only the
    ``selected`` mask carries — further divides over ``n_item_shards``; the
    pinv/e_q state replicates across item shards by design.

    ``payload``, when given, adds a ``"payload"`` entry with the REAL
    per-shard byte footprint of the streamed operand — either a concrete
    payload (fp32/bf16 array or QuantizedRanc, measured via ``nbytes`` so
    packed int4 columns count 0.5 bytes/row, not element counts) or a
    payload dtype string, sized analytically from ``(k_q, n_items)`` plus
    the per-tile scale vector for coded dtypes.
    """
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    b = batch // n_data_shards
    slabs = {
        "anchor_idx": b * k_i * 4,
        "c_test": b * k_i * 4,
        "a_buf": b * k_q * k_i * 4,
        "p": b * k_i * k_q * 4,
        "e_q": b * k_q * 4,
        "selected_mask": b * (n_items // n_item_shards) * 1,
    }
    if payload is not None:
        if isinstance(payload, str):
            nb = quant.payload_nbytes(payload, k_q, n_items, cfg.payload_tile)
        else:
            nb = int(payload.nbytes)
        slabs["payload"] = nb // n_item_shards
    slabs["total"] = sum(slabs.values())
    return slabs

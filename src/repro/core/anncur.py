"""ANNCUR baseline (Yadav et al. 2022) — fixed anchor items, one round.

Offline: choose ``k_i`` anchor items (uniformly at random, or from a
retriever), precompute latent item embeddings ``E_I = U @ R_anc`` with
``U = pinv(R_anc[:, I_anc])``.  Online: the latent query embedding is the
vector of exact CE scores against the anchors, and approximate scores are a
single (B,k_i)x(k_i,N) GEMM — followed by retrieve-and-rerank under the same
CE-call budget accounting as ADACUR.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from . import cur, sampling
from .adacur import AdaCURResult, ScoreFn


@dataclass
class ANNCURIndex:
    anchor_idx: jax.Array     # (k_i,) fixed anchor item ids
    item_embeddings: jax.Array  # (k_i, N) = U @ R_anc


def build_index(
    r_anc: jax.Array,
    k_anchor: int,
    key: Optional[jax.Array] = None,
    anchor_idx: Optional[jax.Array] = None,
    rcond: float = 1e-6,
) -> ANNCURIndex:
    """Offline indexing: anchors uniform-at-random unless explicitly given."""
    _, n_items = r_anc.shape
    if anchor_idx is None:
        if key is None:
            raise ValueError("need key or explicit anchor_idx")
        anchor_idx = jax.random.choice(
            key, n_items, shape=(k_anchor,), replace=False
        )
    u = cur.pinv(r_anc[:, anchor_idx], rcond)      # (k_i, k_q)
    return ANNCURIndex(anchor_idx, u @ r_anc)      # (k_i, N)


def search(
    score_fn: ScoreFn,
    index: ANNCURIndex,
    query,
    budget_ce: int,
    k_retrieve: int,
) -> AdaCURResult:
    """Retrieve-and-rerank with ANNCUR under a CE-call budget.

    ``k_i`` CE calls produce the query embedding; the remaining
    ``budget_ce - k_i`` calls re-rank the top approximate-scoring non-anchor
    items (anchors re-rank for free, same accounting as ADACUR).
    """
    k_i = index.anchor_idx.shape[0]
    if budget_ce < k_i:
        raise ValueError(f"budget_ce={budget_ce} < k_anchor={k_i}")
    b = jax.tree_util.tree_leaves(query)[0].shape[0]
    anchor_idx = jnp.broadcast_to(index.anchor_idx[None, :], (b, k_i))
    e_q = score_fn(query, anchor_idx)              # (B, k_i) exact CE scores
    s_hat = e_q @ index.item_embeddings            # (B, N)

    n_items = s_hat.shape[1]
    selected = jnp.zeros((b, n_items), dtype=bool)
    selected = selected.at[jnp.arange(b)[:, None], anchor_idx].set(True)

    k_r = budget_ce - k_i
    if k_r > 0:
        masked = jnp.where(selected, sampling.NEG_INF, s_hat)
        _, rerank_idx = jax.lax.top_k(masked, k_r)
        rerank_scores = score_fn(query, rerank_idx)
        pool_idx = jnp.concatenate([anchor_idx, rerank_idx], axis=1)
        pool_scores = jnp.concatenate([e_q, rerank_scores], axis=1)
    else:
        pool_idx, pool_scores = anchor_idx, e_q
    k = min(k_retrieve, pool_idx.shape[1])
    top_s, top_pos = jax.lax.top_k(pool_scores, k)
    top_idx = jnp.take_along_axis(pool_idx, top_pos, axis=1)
    return AdaCURResult(anchor_idx, e_q, s_hat, top_idx, top_s, budget_ce)

"""ANNCUR baseline (Yadav et al. 2022) — DEPRECATED thin view.

ANNCUR's offline product (fixed anchor items, ``U = pinv(R_anc[:, I_anc])``,
latent item embeddings ``E_I = U @ R_anc``) now lives inside the first-class
:class:`repro.core.index.AnchorIndex` artifact
(``AnchorIndex.with_latents``), and its online search is one configuration
of the unified engine (:class:`repro.core.engine.ANNCURRetriever` — a single
retriever-seeded round plus the split-budget rerank).  This module keeps the
historical entry points alive as deprecated shims:

- :func:`build_index` builds an ``AnchorIndex`` with latents and returns the
  legacy :class:`ANNCURIndex` view over it;
- :func:`search` delegates to ``ANNCURRetriever`` (identical budget
  accounting; parity is asserted in ``tests/test_engine.py``).

New code should use ``AnchorIndex`` + ``ANNCURRetriever.from_index``.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Optional

import jax

from .adacur import AdaCURResult, ScoreFn


@dataclass
class ANNCURIndex:
    """Deprecated view of an :class:`~repro.core.index.AnchorIndex` carrying
    ANNCUR latents.  ``anchor_idx``/``item_embeddings`` alias the parent's
    ``anchor_item_pos``/``item_embeddings`` arrays."""

    anchor_idx: jax.Array        # (k_i,) fixed anchor item positions
    item_embeddings: jax.Array   # (k_i, N) = U @ R_anc
    parent: Optional[object] = None   # the owning AnchorIndex

    @classmethod
    def from_anchor_index(cls, index) -> "ANNCURIndex":
        if index.anchor_item_pos is None:
            raise ValueError("AnchorIndex has no latents; call with_latents()")
        return cls(index.anchor_item_pos, index.item_embeddings, parent=index)


def build_index(
    r_anc: jax.Array,
    k_anchor: int,
    key: Optional[jax.Array] = None,
    anchor_idx: Optional[jax.Array] = None,
    rcond: float = 1e-6,
) -> ANNCURIndex:
    """Deprecated: use ``AnchorIndex.from_r_anc(...).with_latents(...)``."""
    warnings.warn(
        "anncur.build_index is deprecated; use AnchorIndex.with_latents()",
        DeprecationWarning, stacklevel=2,
    )
    from .index import AnchorIndex

    if anchor_idx is None and key is None:
        raise ValueError("need key or explicit anchor_idx")
    index = AnchorIndex.from_r_anc(r_anc).with_latents(
        k_anchor=k_anchor, key=key, anchor_pos=anchor_idx, rcond=rcond
    )
    return ANNCURIndex.from_anchor_index(index)


def search(
    score_fn: ScoreFn,
    index,
    query,
    budget_ce: int,
    k_retrieve: int,
) -> AdaCURResult:
    """Deprecated: delegates to the engine's :class:`ANNCURRetriever`.

    ``k_i`` CE calls produce the query embedding; the remaining
    ``budget_ce - k_i`` calls re-rank the top approximate-scoring non-anchor
    items (anchors re-rank for free, same accounting as ADACUR).
    """
    warnings.warn(
        "anncur.search is deprecated; use ANNCURRetriever.from_index()",
        DeprecationWarning, stacklevel=2,
    )
    from .engine import ANNCURRetriever

    parent = index.parent if isinstance(index, ANNCURIndex) else index
    if parent is None:
        raise ValueError(
            "legacy ANNCURIndex without a parent AnchorIndex; construct via "
            "anncur.build_index or use ANNCURRetriever directly"
        )
    ret = ANNCURRetriever.from_index(
        parent, score_fn, budget_ce=budget_ce, k_retrieve=k_retrieve
    )
    return ret.search(query)

"""Cross-encoder scorer subsystem: the third pillar next to the engine
(online) and the AnchorIndex (offline).

Everything the engine scores goes through a :class:`Scorer` — a ScoreFn
with *measured* CE-call accounting.  Three production providers:

- :class:`SyntheticScorer` — the closed-form synthetic CE, pure-traced
  (fuses into the jitted engine; the seed behavior);
- :class:`TabulatedScorer` — exact-matrix lookup routed through
  ``jax.pure_callback``, so every call is counted *at runtime* even inside
  ``lax.fori_loop``/``while_loop`` bodies.  The engine's per-round budget
  becomes measured, not assumed: tests assert measured == planned
  (:func:`repro.core.engine.ce_call_plan`);
- :class:`CrossEncoderScorer` — the real transformer CE
  (``models/cross_encoder.py``).  Host-side pair tokenization, token-length
  bucketing and micro-batch padding to a *small static shape set* (repeated
  calls never retrace), scored through the Pallas flash-attention kernel
  whose per-example SMEM valid-length masks make one padded bucket serve
  every pair length;
- :class:`DeviceCEScorer` — the same transformer CE as a *device-resident*
  stage of the engine's program.  The corpus token table lives on device
  (carried by ``AnchorIndex.item_tokens``), queries are tokenized on the
  host once per batch, and pair assembly + the CE forward happen *in-trace*
  — so the SPMD ``shard_map`` engine runs the real CE with no host
  callback, no nested jit, and no psum rendezvous to deadlock.

Capability flags the engine keys on (duck-typed, no imports needed):

- ``device_resident`` — the scorer scores token operands in-trace and needs
  the corpus token table (``item_tokens``) instead of item *ids*;
- ``nested_device_callback`` — the scorer's host callback launches device
  compute (a nested jit).  Safe single-device; **rejected** by
  ``make_sharded_engine`` because it deadlocks a single-process
  multi-device runtime.  Numpy-only callbacks (TabulatedScorer,
  CachingScorer over one) stay mesh-legal.

Layered on top, :class:`CachingScorer` adds a (query_id, item_id) score
cache: scores computed for one request's anchors are exactly the R_anc
rows future requests reconstruct from, so popular pairs are scored once
process-wide (cf. the test-time index-growth direction of arXiv 2405.03651).

Every host-backed scorer rides ``jax.pure_callback``: the engine stays one
jit-compiled executable in every loop mode while tokenization, caching and
accounting run host-side (each callback fires exactly once per executed
round — verified by the property suite).
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Protocol, Tuple, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import LMConfig


@dataclass
class ScorerStats:
    """Measured CE-call accounting (host-side, survives jit)."""

    requests: int = 0        # score() invocations observed
    pairs: int = 0           # (query, item) pairs requested
    ce_calls: int = 0        # pairs actually scored by the underlying model
    cache_hits: int = 0      # pairs served from the score cache
    cache_size: int = 0      # resident cached pairs
    batch_pad: int = 0       # padded filler rows scored for static shapes

    def copy(self) -> "ScorerStats":
        return dataclasses.replace(self)

    def __sub__(self, other: "ScorerStats") -> "ScorerStats":
        """Per-window delta (cache_size stays absolute)."""
        return ScorerStats(
            requests=self.requests - other.requests,
            pairs=self.pairs - other.pairs,
            ce_calls=self.ce_calls - other.ce_calls,
            cache_hits=self.cache_hits - other.cache_hits,
            cache_size=self.cache_size,
            batch_pad=self.batch_pad - other.batch_pad,
        )


@runtime_checkable
class Scorer(Protocol):
    """A ScoreFn with measured accounting: callable as score_fn(query, idx)."""

    stats: ScorerStats

    def __call__(self, query, item_idx) -> jax.Array: ...

    def reset_stats(self) -> None: ...


def scorer_stats(score_fn) -> Optional[ScorerStats]:
    """The live stats of a ScoreFn if it is a Scorer, else None."""
    s = getattr(score_fn, "stats", None)
    return s if isinstance(s, ScorerStats) else None


def bucket_for(length: int, len_buckets: Tuple[int, ...], what: str = "pair") -> int:
    """Smallest bucket >= length, validated *eagerly* with an actionable error.

    Called at tokenization/enqueue time (host scorers) and at trace time
    (device scorers) so an oversized pair fails as a plain ``ValueError``
    where it was caused — never as an opaque XLA runtime error surfacing
    from inside ``jax.pure_callback``.
    """
    for b in len_buckets:
        if b >= length:
            return b
    raise ValueError(
        f"{what} length {length} exceeds the largest length bucket "
        f"{max(len_buckets)} (len_buckets={tuple(len_buckets)}); extend "
        f"len_buckets to cover it, or shorten the query/item token budget "
        f"so tokenized pairs fit an existing bucket"
    )


# ---------------------------------------------------------------------------
# pure-traced provider
# ---------------------------------------------------------------------------


@dataclass
class SyntheticScorer:
    """Closed-form synthetic CE as a Scorer — pure-traced, zero overhead.

    The scoring math inlines into the engine's jit trace (the seed
    behavior), so per-call accounting cannot be observed at runtime; only
    ``requests``/``pairs`` seen at *trace* time are recorded.  Wrap in
    :class:`TabulatedScorer`/:class:`CachingScorer` when measurement
    matters more than fusion.
    """

    ce: object                    # repro.data.synthetic.SyntheticCE
    stats: ScorerStats = field(default_factory=ScorerStats)

    def __call__(self, query, item_idx) -> jax.Array:
        self.stats.requests += 1
        self.stats.pairs += int(np.prod(item_idx.shape))
        return self.ce.score_pairs(query, item_idx)

    def reset_stats(self) -> None:
        self.stats = ScorerStats()


# ---------------------------------------------------------------------------
# host-backed providers (jax.pure_callback)
# ---------------------------------------------------------------------------


class _HostScorer:
    """Base: route scoring through a host callback with runtime accounting.

    ``record_pairs=True`` keeps a per-call log of (query_ids, item_idx)
    numpy copies — the dedup/suppression invariant suite reconstructs every
    search's scored-pair multiset from it.
    """

    def __init__(self, record_pairs: bool = False):
        self.stats = ScorerStats()
        self.record_pairs = record_pairs
        self.call_log: List[Tuple[np.ndarray, np.ndarray]] = []

    def reset_stats(self) -> None:
        self.stats = ScorerStats()
        self.call_log = []

    def _host(self, qids: np.ndarray, idx: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def _host_entry(self, qids, idx):
        qids = np.asarray(qids)
        idx = np.asarray(idx)
        self.stats.requests += 1
        self.stats.pairs += int(idx.size)
        if self.record_pairs:
            self.call_log.append((qids.copy(), idx.copy()))
        return np.asarray(self._host(qids, idx), dtype=np.float32)

    def __call__(self, query, item_idx) -> jax.Array:
        return jax.pure_callback(
            self._host_entry,
            jax.ShapeDtypeStruct(item_idx.shape, jnp.float32),
            query, item_idx,
        )


class TabulatedScorer(_HostScorer):
    """Exact-matrix lookup: ``score(q, i) = matrix[q, i]``.

    The reference scorer for tests and benchmarks: free to evaluate, exact,
    and *counting* — every scored pair increments ``stats.ce_calls`` at
    runtime, inside any engine loop mode.
    """

    def __init__(self, matrix, record_pairs: bool = False):
        super().__init__(record_pairs)
        self.matrix = np.asarray(matrix, dtype=np.float32)

    def _host(self, qids, idx):
        self.stats.ce_calls += int(idx.size)
        return self.matrix[qids[:, None], idx]


class CrossEncoderScorer(_HostScorer):
    """The real transformer CE on the engine's hot path.

    Host side: ``pair_fn(query_ids (B,), item_idx (B, k)) -> (B, k, L)``
    int32 pair tokens ([CLS] q [SEP] i [SEP], trailing ``pad_id`` padding).
    Pairs are flattened, padded to the smallest length bucket, and scored
    in fixed ``micro_batch``-row chunks, so the jitted compute sees only
    ``len(len_buckets)`` static shapes — ``n_traces`` proves repeated calls
    never retrace.  Attention runs through the Pallas flash kernel with
    per-example SMEM valid lengths (``attn_impl='flash'``).

    Pair lengths are validated *eagerly*: at construction the ``pair_fn``
    is probed with a one-pair dummy call, so a pair that overflows the
    largest length bucket raises an actionable ``ValueError`` immediately
    instead of an opaque XLA error from inside ``jax.pure_callback`` on
    the first search (set ``probe_pair_len=False`` for pair_fns that
    cannot tokenize id 0; lengths are then validated per enqueue).
    """

    # host callback launches a nested jit (the CE forward): deadlocks the
    # SPMD mesh (see make_sharded_engine) and, on a single-core host, the
    # async CPU client's one execute thread — run with
    # ``jax_cpu_enable_async_dispatch=False`` there, or use DeviceCEScorer
    nested_device_callback = True

    def __init__(
        self,
        params,
        cfg: LMConfig,
        pair_fn: Callable[[np.ndarray, np.ndarray], np.ndarray],
        pad_id: int = 0,
        micro_batch: int = 64,
        len_buckets: Tuple[int, ...] = (32, 64, 128, 256, 512),
        attn_impl: str = "flash",
        flash_block: Tuple[int, int] = (128, 128),
        flash_interpret: bool = True,
        record_pairs: bool = False,
        probe_pair_len: bool = True,
    ):
        super().__init__(record_pairs)
        from ..models import cross_encoder

        self.params = params
        self.cfg = cfg
        self.pair_fn = pair_fn
        self.pad_id = pad_id
        self.micro_batch = micro_batch
        self.len_buckets = tuple(sorted(len_buckets))
        self._n_traces = 0

        if probe_pair_len:
            try:
                probe = np.asarray(
                    pair_fn(np.zeros(1, np.int64), np.zeros((1, 1), np.int64))
                )
            except Exception:
                probe = None     # pair_fn rejects the dummy ids; validate per call
            if probe is not None:
                bucket_for(int(probe.shape[-1]), self.len_buckets)

        def scored(tokens):
            self._n_traces += 1          # trace-time side effect
            return cross_encoder.score_tokens(
                params, tokens, cfg, pad_id=pad_id, attn_impl=attn_impl,
                flash_block=flash_block, flash_interpret=flash_interpret,
            )

        self._jit_scored = jax.jit(scored)

    @property
    def n_traces(self) -> int:
        """Distinct (micro_batch, bucket) shapes compiled so far."""
        return self._n_traces

    def _bucket(self, length: int) -> int:
        return bucket_for(length, self.len_buckets)

    def _host(self, qids, idx):
        b, k = idx.shape
        tokens = np.asarray(self.pair_fn(qids, idx), dtype=np.int32)  # (B,k,L)
        n, length = b * k, tokens.shape[-1]
        bucket = self._bucket(length)
        n_pad = -n % self.micro_batch
        flat = np.full((n + n_pad, bucket), self.pad_id, dtype=np.int32)
        flat[:n, :length] = tokens.reshape(n, length)
        self.stats.ce_calls += n
        self.stats.batch_pad += n_pad
        out = np.empty(n + n_pad, dtype=np.float32)
        for lo in range(0, n + n_pad, self.micro_batch):
            chunk = jnp.asarray(flat[lo : lo + self.micro_batch])
            out[lo : lo + self.micro_batch] = np.asarray(self._jit_scored(chunk))
        return out[:n].reshape(b, k)


class DeviceCEScorer:
    """The real transformer CE as a *device-resident* stage of the engine.

    Where :class:`CrossEncoderScorer` tokenizes and launches the CE from a
    host callback (illegal under the SPMD mesh — the nested jit deadlocks
    against shards parked at the score-broadcast psum), this provider keeps
    the corpus token table on device, assembles ``[CLS] q [SEP] i [SEP]``
    pair rows *in-trace* and runs the transformer forward (flash-attention
    path with per-example valid-length masks) inside the engine's one
    compiled program.  Under ``make_sharded_engine`` the flattened pair
    batch is additionally split across the *item* shards, so the whole
    mesh shares the CE FLOPs and every pair is scored exactly once
    system-wide.

    The query operand the engine sees is the ``(B, query_len)`` int32 token
    batch from :meth:`tokenize_queries` (host-side, once per request batch,
    before the round loop).  The corpus table is either carried by the
    scorer (``item_tokens=``) or — the production path — by the index
    (``AnchorIndex.with_item_tokens``), position-aligned with the payload
    through every mutation.

    Accounting stays *measured*: a numpy-only counting callback (no device
    compute, mesh-legal) observes each executed scoring round, so
    ``stats.ce_calls`` equals :func:`repro.core.engine.ce_call_plan` at
    runtime and item-shard pad rows are excluded by construction.
    """

    device_resident = True

    def __init__(
        self,
        params,
        cfg: LMConfig,
        query_token_fn: Callable[[np.ndarray], np.ndarray],
        item_tokens=None,
        pad_id: int = 0,
        cls_id: int = 1,
        sep_id: int = 2,
        len_buckets: Tuple[int, ...] = (32, 64, 128, 256, 512),
        attn_impl: str = "flash",
        flash_block: Tuple[int, int] = (128, 128),
        flash_interpret: bool = True,
        record_pairs: bool = False,
    ):
        self.params = params
        self.cfg = cfg
        self.query_token_fn = query_token_fn
        self.item_tokens = (
            None if item_tokens is None else jnp.asarray(item_tokens, jnp.int32)
        )
        self.pad_id = pad_id
        self.cls_id = cls_id
        self.sep_id = sep_id
        self.len_buckets = tuple(sorted(len_buckets))
        self.attn_impl = attn_impl
        self.flash_block = flash_block
        self.flash_interpret = flash_interpret
        self.record_pairs = record_pairs
        self.stats = ScorerStats()
        self.call_log: List[np.ndarray] = []
        self._n_traces = 0

    # -- host side: once per request batch, before the round loop ----------

    def tokenize_queries(self, query) -> jax.Array:
        """Query ids -> (B, query_len) int32 token rows (the engine operand).

        Pair lengths are validated *here* (eagerly, with an actionable
        message) whenever the scorer carries its own token table; when the
        table rides on the index instead, :meth:`build_pairs` re-validates
        at trace time — still a plain ``ValueError``, never an XLA error.
        """
        qids = np.asarray(jax.device_get(query))
        toks = np.asarray(self.query_token_fn(qids), dtype=np.int32)
        if toks.ndim != 2 or toks.shape[0] != qids.shape[0]:
            raise ValueError(
                f"query_token_fn must map (B,) ids to (B, query_len) tokens; "
                f"got {toks.shape} for B={qids.shape[0]}"
            )
        if self.item_tokens is not None:
            bucket_for(
                toks.shape[1] + int(self.item_tokens.shape[1]) + 3,
                self.len_buckets,
            )
        return jnp.asarray(toks)

    # -- device side: traced into the engine program -----------------------

    def build_pairs(self, q_tokens, item_rows) -> jax.Array:
        """(B, Lq) x (B, k, Li) -> (B, k, bucket) padded pair token rows."""
        from ..models import cross_encoder

        lq, li = int(q_tokens.shape[-1]), int(item_rows.shape[-1])
        bucket = bucket_for(lq + li + 3, self.len_buckets)
        return cross_encoder.build_pair_tokens(
            q_tokens, item_rows, pad_to=bucket,
            cls_id=self.cls_id, sep_id=self.sep_id, pad_id=self.pad_id,
        )

    def forward(self, flat_tokens) -> jax.Array:
        """(M, bucket) pair rows -> (M,) CE scores, in the caller's trace."""
        from ..models import cross_encoder

        self._n_traces += 1              # trace-time side effect
        return cross_encoder.score_tokens(
            self.params, flat_tokens, self.cfg, pad_id=self.pad_id,
            attn_impl=self.attn_impl, flash_block=self.flash_block,
            flash_interpret=self.flash_interpret,
        )

    # -- measured accounting (numpy-only callback: mesh-legal) -------------

    def _count_host(self, idx, n_pad):
        idx = np.asarray(idx)
        self.stats.requests += 1
        self.stats.pairs += int(idx.size)
        self.stats.ce_calls += int(idx.size)
        self.stats.batch_pad += int(n_pad)
        if self.record_pairs:
            self.call_log.append(idx.copy())
        return np.float32(0.0)

    def count(self, item_idx, n_pad) -> jax.Array:
        """Record one executed scoring round; returns a 0.0 the caller must
        consume (``scores + 0.0 * count(...)``) so DCE cannot drop it."""
        return jax.pure_callback(
            self._count_host,
            jax.ShapeDtypeStruct((), jnp.float32),
            item_idx, jnp.asarray(n_pad, jnp.int32),
        )

    @property
    def n_traces(self) -> int:
        """CE forwards traced so far (stable across runtime n_rounds/n_valid)."""
        return self._n_traces

    def reset_stats(self) -> None:
        self.stats = ScorerStats()
        self.call_log = []

    def __call__(self, query_tokens, item_idx) -> jax.Array:
        """Plain ScoreFn over the scorer-carried table (single device)."""
        if self.item_tokens is None:
            raise ValueError(
                "DeviceCEScorer needs a corpus token table to score directly: "
                "construct it with item_tokens=, or search through an index "
                "that carries one (AnchorIndex.with_item_tokens)"
            )
        from .engine import _device_ce_score, _local_ctx

        ctx = _local_ctx(int(self.item_tokens.shape[0]))
        return _device_ce_score(ctx, self, query_tokens, item_idx, self.item_tokens)


class CachingScorer(_HostScorer):
    """(query_id, item_id) score cache over any host-backed Scorer.

    CE scores are query-conditioned, so the unit of reuse is the *pair*:
    repeat queries (and coalesced batches sharing pairs) hit the cache and
    skip the inner model entirely.  Within one call, duplicate pairs are
    scored once.  ``stats.ce_calls`` counts only inner-model pairs —
    measured accounting for the serving layer; ``capacity`` bounds
    residency with LRU eviction.

    Cache keys are the ids the engine passes to score_fn — external corpus
    ids when searching through ``AnchorIndex.item_ids``, so entries stay
    valid across index mutation/compaction.
    """

    def __init__(self, inner: _HostScorer, capacity: int = 1_000_000,
                 record_pairs: bool = False):
        super().__init__(record_pairs)
        if not isinstance(inner, _HostScorer):
            raise TypeError(
                "CachingScorer caches host-backed scorers (TabulatedScorer / "
                "CrossEncoderScorer); pure-traced scorers fuse into the jit "
                "trace and cannot be intercepted"
            )
        self.inner = inner
        self.capacity = capacity
        self._cache: "OrderedDict[int, float]" = OrderedDict()

    @property
    def nested_device_callback(self) -> bool:
        """Mesh legality follows the wrapped scorer (cache adds no device work)."""
        return bool(getattr(self.inner, "nested_device_callback", False))

    def reset_stats(self, clear_cache: bool = False) -> None:
        super().reset_stats()
        self.inner.reset_stats()
        if clear_cache:
            self._cache.clear()

    def _host(self, qids, idx):
        b, k = idx.shape
        keys = (qids.astype(np.int64)[:, None] << 32) | idx.astype(np.int64)
        flat_keys = keys.reshape(-1)
        out = np.empty(b * k, dtype=np.float32)

        miss_keys: List[int] = []
        miss_pos: dict = {}          # key -> every flat position needing it
        for pos, key in enumerate(flat_keys.tolist()):
            hit = self._cache.get(key)
            if hit is not None:
                out[pos] = hit
                self._cache.move_to_end(key)
                self.stats.cache_hits += 1
            else:
                positions = miss_pos.get(key)
                if positions is None:
                    miss_pos[key] = [pos]
                    miss_keys.append(key)
                else:
                    positions.append(pos)

        if miss_keys:
            mk = np.asarray(miss_keys, dtype=np.int64)
            q_m = (mk >> 32).astype(qids.dtype)
            i_m = (mk & 0xFFFFFFFF).astype(idx.dtype)
            scores = np.asarray(
                self.inner._host_entry(q_m, i_m[:, None]), dtype=np.float32
            ).reshape(-1)
            self.stats.ce_calls += len(miss_keys)
            for key, s in zip(miss_keys, scores.tolist()):
                self._cache[key] = s
                if len(self._cache) > self.capacity:
                    self._cache.popitem(last=False)
                # duplicates within the call are scored once, filled everywhere
                for pos in miss_pos[key]:
                    out[pos] = s
        self.stats.cache_size = len(self._cache)
        return out.reshape(b, k)

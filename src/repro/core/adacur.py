"""ADACUR reference implementation — Algorithm 1 as an executable spec.

This module is the *faithful, growing-shape* transcription of the paper's
adaptive multi-round anchor selection for CUR-based k-NN search with
cross-encoders.  It is one of two layers:

- **this file** (``core/adacur.py``): buffers grow by ``jnp.concatenate``
  every round, so each round body has its own trace shape.  Simple to read
  and audit against the paper's pseudo-code; works with any score_fn
  (including non-traceable numpy-backed scorers); used by the tests as the
  parity oracle.
- **the engine** (``core/engine.py``): the production hot path.  Identical
  math over *preallocated* static-shape slabs filled with
  ``lax.dynamic_update_slice``, so the round body is shape-invariant and can
  run unrolled, under ``lax.fori_loop`` with a runtime round count, or with
  an early-exit tolerance — plus fused Pallas score->top-k sampling that
  never materializes the (B, N) approximate score matrix.  New call sites
  should use the engine's ``Retriever`` API (``AdaCURRetriever`` et al.).

Differences from the paper's single-query pseudo-code, all behaviour-
preserving (validated in tests/benchmarks against the faithful path):

- **batched**: B test queries run the round loop together, each with its own
  anchor set (the paper scores one query at a time);
- **unrolled rounds**: ``n_rounds`` is static, so the loop unrolls inside one
  jit trace with exact (growing) shapes — no padding, no masking error;
- **incremental pinv** (optional, default on): the paper recomputes
  ``pinv(R_anc[:, I_anc])`` from scratch each round (their Fig. 4 shows this
  dominating non-CE latency at high round counts); we extend the previous
  pseudo-inverse with the bordering identity, O(k_q·k_i·k_s) per round;
- **e_q factoring**: scores are reconstructed as ``(C_test @ U) @ R_anc`` so
  each round performs ONE rank-k_q GEMM against R_anc.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import AdaCURConfig
from . import cur, sampling

# score_fn(query_pytree, item_idx (B,k)) -> (B,k) exact CE scores
ScoreFn = Callable[..., jax.Array]


@partial(
    jax.tree_util.register_dataclass,
    data_fields=(
        "anchor_idx", "anchor_scores", "approx_scores", "topk_idx",
        "topk_scores", "rounds_done",
    ),
    meta_fields=("ce_calls",),
)
@dataclass
class AdaCURResult:
    """Everything Algorithm 1 returns, plus the final retrieval."""

    anchor_idx: jax.Array        # (B, k_i)   anchor item ids, in sampling order
    anchor_scores: jax.Array     # (B, k_i)   exact CE scores of the anchors
    approx_scores: jax.Array     # (B, N)     Ŝ after the final round (engine:
                                 #            None when not materialized)
    topk_idx: jax.Array          # (B, k)     retrieved item ids (exact-CE ranked)
    topk_scores: jax.Array       # (B, k)     their exact CE scores
    ce_calls: int                # total exact CE calls per query (upper bound
                                 #            under the engine's early exit)
    rounds_done: Optional[jax.Array] = None  # () int32 rounds executed (engine)


def _approx_from_state(e_q: jax.Array, r_anc: jax.Array) -> jax.Array:
    return e_q @ r_anc


def adacur_search(
    score_fn: ScoreFn,
    r_anc: jax.Array,
    query,
    cfg: AdaCURConfig,
    key: jax.Array,
    first_anchors: Optional[jax.Array] = None,
    batch: Optional[int] = None,
    n_valid_items: Optional[int] = None,
) -> AdaCURResult:
    """Run Algorithm 1 (+ retrieval/re-ranking) for a batch of queries.

    Args:
      score_fn: exact cross-encoder scores for (query, item-id) pairs.
      r_anc: (k_q, N) offline anchor-query/all-item score matrix.
      query: batched query pytree handed to ``score_fn`` untouched.
      cfg: AdaCURConfig (budget, rounds, strategy, split policy).
      key: PRNG key.
      first_anchors: optional (B, k_s) retriever-chosen first round
        (paper's ADACUR_{DE_BASE}/ADACUR_{TF-IDF} variants).
      batch: batch size (inferred from ``first_anchors`` if given).
      n_valid_items: real item count when R_anc's column axis is padded to a
        shardable multiple (pod meshes); padded ids are never sampled.

    Returns: AdaCURResult.
    """
    k_q, n_items = r_anc.shape
    k_i = cfg.budget_ce if not cfg.split_budget else cfg.k_anchor
    if k_i % cfg.n_rounds != 0:
        raise ValueError(f"k_i={k_i} not divisible by n_rounds={cfg.n_rounds}")
    k_s = k_i // cfg.n_rounds

    if first_anchors is not None:
        b = first_anchors.shape[0]
        if first_anchors.shape[1] != k_s:
            raise ValueError(
                f"first_anchors must provide k_s={k_s} items, got {first_anchors.shape}"
            )
    elif batch is not None:
        b = batch
    else:
        b = jax.tree_util.tree_leaves(query)[0].shape[0]

    rows = jnp.arange(b)[:, None]
    selected = jnp.zeros((b, n_items), dtype=bool)
    if n_valid_items is not None and n_valid_items < n_items:
        selected = selected | (jnp.arange(n_items) >= n_valid_items)
    anchor_idx = None       # (B, r*k_s)
    c_test = None           # (B, r*k_s)
    a_buf = None            # (B, k_q, r*k_s)
    p = None                # (B, r*k_s, k_q) incremental pinv
    e_q = None

    keys = jax.random.split(key, cfg.n_rounds + 1)
    for r in range(cfg.n_rounds):
        # --- SAMPLEANCHORS (Alg. 3) ---------------------------------------
        if r == 0:
            if first_anchors is not None and cfg.first_round == "retriever":
                idx_new = first_anchors
            else:
                idx_new = sampling.sample_random(keys[r], selected, k_s)
        else:
            s_hat = _approx_from_state(e_q, r_anc)
            n_rand = int(round(cfg.round_epsilon * k_s))
            idx_new = sampling.sample(
                cfg.strategy, keys[r], s_hat, selected, k_s - n_rand,
                cfg.softmax_temp,
            )
            if n_rand:
                # ε-greedy diversity mix (beyond-paper; see AdaCURConfig)
                sel_tmp = selected.at[rows, idx_new].set(True)
                k_eps = jax.random.fold_in(keys[r], 1)
                idx_rand = sampling.sample_random(k_eps, sel_tmp, n_rand)
                idx_new = jnp.concatenate([idx_new, idx_rand], axis=1)
        selected = selected.at[rows, idx_new].set(True)

        # --- exact CE scores for the new anchors (Alg. 1 line 15) ----------
        c_new = score_fn(query, idx_new)                       # (B, k_s)
        cols_new = cur.gather_anchor_columns(
            r_anc, idx_new, via_onehot=cfg.distributed_gather
        )                                                      # (B, k_q, k_s)

        if anchor_idx is None:
            anchor_idx, c_test, a_buf = idx_new, c_new, cols_new
        else:
            anchor_idx = jnp.concatenate([anchor_idx, idx_new], axis=1)
            c_test = jnp.concatenate([c_test, c_new], axis=1)
            a_buf = jnp.concatenate([a_buf, cols_new], axis=2)

        # --- APPROXSCORES state update (Alg. 2) -----------------------------
        if cfg.incremental_pinv:
            if p is None:
                p = cur.incremental_pinv_init(a_buf, cfg.pinv_rcond)
            else:
                p = jax.vmap(cur.block_pinv_extend)(
                    a_buf[..., : r * k_s], p, cols_new
                )
        else:
            p = cur.pinv(a_buf, cfg.pinv_rcond)                # (B, rk_s, k_q)
        e_q = jnp.einsum("bk,bkq->bq", c_test, p)              # (B, k_q)

    s_hat = _approx_from_state(e_q, r_anc)                     # final Ŝ (line 16)

    # --- retrieval ---------------------------------------------------------
    if not cfg.split_budget:
        # ADACUR^No-Split: rank the anchors by their exact CE scores (free).
        k = min(cfg.k_retrieve, k_i)
        top_s, top_pos = jax.lax.top_k(c_test, k)
        top_idx = jnp.take_along_axis(anchor_idx, top_pos, axis=1)
        return AdaCURResult(anchor_idx, c_test, s_hat, top_idx, top_s, k_i)

    # ADACUR (split): spend the remaining budget on fresh exact CE calls for
    # the top approximate-scoring non-anchor items; anchors join the final
    # ranking for free (their exact scores are already in C_test).
    k_r = cfg.budget_ce - k_i
    masked = jnp.where(selected, sampling.NEG_INF, s_hat)
    _, rerank_idx = jax.lax.top_k(masked, k_r)                 # (B, k_r)
    rerank_scores = score_fn(query, rerank_idx)                # k_r CE calls
    pool_idx = jnp.concatenate([anchor_idx, rerank_idx], axis=1)
    pool_scores = jnp.concatenate([c_test, rerank_scores], axis=1)
    k = min(cfg.k_retrieve, pool_idx.shape[1])
    top_s, top_pos = jax.lax.top_k(pool_scores, k)
    top_idx = jnp.take_along_axis(pool_idx, top_pos, axis=1)
    return AdaCURResult(anchor_idx, c_test, s_hat, top_idx, top_s, cfg.budget_ce)


def make_jitted_search(score_fn: ScoreFn, cfg: AdaCURConfig):
    """jit-compiled ADACUR closure over a concrete scorer + config."""

    @partial(jax.jit, static_argnames=("batch",))
    def run(r_anc, query, key, first_anchors=None, batch=None):
        return adacur_search(
            score_fn, r_anc, query, cfg, key,
            first_anchors=first_anchors, batch=batch,
        )

    return run

"""Anchor-item sampling strategies (paper Algorithm 3 + §3.2 oracles).

All strategies operate on a batch of queries; masking of already-selected
anchors is done with an explicit (B, N) boolean mask so the whole multi-round
loop stays jit-compatible.  SoftMax sampling without replacement uses the
Gumbel-top-k trick (Kool et al. 2019) — top-k over ``logits + Gumbel noise``
is an exact sample without replacement from the softmax distribution.

**The blocked noise field.**  Every random draw in the engine (uniform
round-0 / Random-strategy sampling, SoftMax Gumbel perturbations, the
ε-greedy fill) reads from one canonical pseudo-random field over (query row,
item) coordinates, generated per ``NOISE_BLOCK``-item block:

    noise[i, j] = gumbel(fold_in(fold_in(key, row_id[i]), j // NOISE_BLOCK))
                      [j % NOISE_BLOCK]

The field is a pure function of (key, global row id, global item id) —
independent of the batch slab or item slab it is evaluated on.  That is what
makes the SPMD engine (``core/engine.py``) bit-identical to the single-device
engine: a shard of a (data x items) mesh evaluates exactly the noise
rectangle it owns by passing its global row/column offsets, rather than
drawing from a differently-shaped array.  Shard boundaries must therefore
align to ``NOISE_BLOCK`` columns (``AnchorIndex.shard`` pads capacity
accordingly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# item-axis block size of the canonical noise field; item shards must own a
# whole number of blocks (see AnchorIndex.shard's capacity alignment)
NOISE_BLOCK = 128


def blocked_gumbel(
    key: jax.Array,
    rows: int,
    n: int,
    row_offset=0,
    col_offset=0,
) -> jax.Array:
    """(rows, n) Gumbel noise — the canonical field's rectangle starting at
    global coordinates (``row_offset``, ``col_offset``).

    ``col_offset`` must be a multiple of ``NOISE_BLOCK`` (offsets are shard
    origins, which the index aligns); ``row_offset``/``col_offset`` may be
    traced int32 (the SPMD engine derives them from mesh axis indices).
    """
    nb = -(-n // NOISE_BLOCK)
    row_ids = jnp.asarray(row_offset, jnp.int32) + jnp.arange(rows, dtype=jnp.int32)
    blk_ids = (
        jnp.asarray(col_offset, jnp.int32) // NOISE_BLOCK
        + jnp.arange(nb, dtype=jnp.int32)
    )
    row_keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(row_ids)
    g = jax.vmap(
        lambda rk: jax.vmap(
            lambda b: jax.random.gumbel(
                jax.random.fold_in(rk, b), (NOISE_BLOCK,), dtype=jnp.float32
            )
        )(blk_ids)
    )(row_keys)                                   # (rows, nb, NOISE_BLOCK)
    return g.reshape(rows, nb * NOISE_BLOCK)[:, :n]


def gumbel_at(
    key: jax.Array,
    rows: int,
    col_pos: jax.Array,
    row_offset=0,
) -> jax.Array:
    """(rows, len(col_pos)) noise — the canonical field at *scattered* item
    coordinates.

    ``col_pos`` (C,) int32 holds global item positions, not necessarily
    contiguous or block-aligned: entry (i, j) is the field value at global
    coordinates (row_offset + i, col_pos[j]), bit-equal to the corresponding
    entry of :func:`blocked_gumbel`.  This is what makes a candidate-subset
    search (columns gathered into a compact sub-index) bit-identical to the
    same search masked over the full corpus: the sub-index evaluates the
    noise the full index would have seen at those columns.

    Cost is one NOISE_BLOCK draw per (row, column) — the field is only
    addressable per block — so this is O(rows * C * NOISE_BLOCK) generated
    bits, intended for shortlist-sized C, not the full corpus.
    """
    col_pos = jnp.asarray(col_pos, jnp.int32)
    row_ids = jnp.asarray(row_offset, jnp.int32) + jnp.arange(rows, dtype=jnp.int32)
    blk_ids = col_pos // NOISE_BLOCK
    offsets = col_pos % NOISE_BLOCK
    row_keys = jax.vmap(lambda r: jax.random.fold_in(key, r))(row_ids)

    def _one(rk):
        def _col(b, o):
            blk = jax.random.gumbel(
                jax.random.fold_in(rk, b), (NOISE_BLOCK,), dtype=jnp.float32
            )
            return blk[o]

        return jax.vmap(_col)(blk_ids, offsets)

    return jax.vmap(_one)(row_keys)


def _masked_logits(scores: jax.Array, selected: jax.Array, temp: float) -> jax.Array:
    """SoftMax(S) with already-selected items masked out (Alg. 3 lines 7-8)."""
    logits = scores / jnp.asarray(temp, scores.dtype)
    return jnp.where(selected, NEG_INF, logits)


def sample_topk(
    scores: jax.Array, selected: jax.Array, k: int, temp: float = 1.0
) -> jax.Array:
    """TopK strategy: greedily pick the k highest-scoring unselected items."""
    logits = _masked_logits(scores, selected, temp)
    _, idx = jax.lax.top_k(logits, k)
    return idx


def sample_softmax(
    key: jax.Array, scores: jax.Array, selected: jax.Array, k: int,
    temp: float = 1.0,
) -> jax.Array:
    """SoftMax strategy: sample k items w/o replacement ∝ softmax(scores).

    The Gumbel perturbation is the canonical field's (0, 0) rectangle; a
    sharded engine shard evaluates the same field at its own offsets via
    :func:`blocked_gumbel` directly (see ``engine._sample_round``)."""
    logits = _masked_logits(scores, selected, temp)
    g = blocked_gumbel(key, logits.shape[0], logits.shape[1]).astype(logits.dtype)
    _, idx = jax.lax.top_k(logits + g, k)
    return idx


def sample_random(
    key: jax.Array, selected: jax.Array, k: int
) -> jax.Array:
    """Random strategy: uniform w/o replacement over unselected items."""
    logits = jnp.where(selected, NEG_INF, 0.0)
    g = blocked_gumbel(key, logits.shape[0], logits.shape[1]).astype(logits.dtype)
    _, idx = jax.lax.top_k(logits + g, k)
    return idx


def sample(
    strategy: str,
    key: jax.Array,
    scores: jax.Array,
    selected: jax.Array,
    k: int,
    temp: float = 1.0,
) -> jax.Array:
    """Dispatch on the paper's three strategies (Algorithm 3)."""
    if strategy == "topk":
        return sample_topk(scores, selected, k, temp)
    if strategy == "softmax":
        return sample_softmax(key, scores, selected, k, temp)
    if strategy == "random":
        return sample_random(key, selected, k)
    raise ValueError(f"unknown sampling strategy '{strategy}'")


# ---------------------------------------------------------------------------
# Oracle strategies (paper §3.2) — have access to EXACT CE scores of all
# items; used to analyse why adaptive anchor selection works.
# ---------------------------------------------------------------------------


def oracle_topk(
    key: jax.Array,
    exact_scores: jax.Array,
    k_i: int,
    k_m: int = 0,
    eps: float = 0.0,
) -> jax.Array:
    """TopK^O_{k_m,eps}: mask top-k_m items, take the next (1-eps)·k_i items
    greedily, fill the remaining eps·k_i uniformly at random."""
    b, n = exact_scores.shape
    n_greedy = int(round((1.0 - eps) * k_i))
    n_rand = k_i - n_greedy
    order = jnp.argsort(-exact_scores, axis=-1)          # (B, N) descending
    greedy = order[:, k_m : k_m + n_greedy]
    if n_rand == 0:
        return greedy
    sel = jnp.zeros((b, n), dtype=bool)
    rows = jnp.arange(b)[:, None]
    sel = sel.at[rows, order[:, : k_m + n_greedy]].set(True)
    rand = sample_random(key, sel, n_rand)
    return jnp.concatenate([greedy, rand], axis=-1)


def oracle_softmax(
    key: jax.Array,
    exact_scores: jax.Array,
    k_i: int,
    k_m: int = 0,
    eps: float = 0.0,
    temp: float = 1.0,
) -> jax.Array:
    """SoftMax^O_{k_m,eps}: mask top-k_m, sample (1-eps)·k_i by softmax of the
    exact scores, fill eps·k_i uniformly at random."""
    b, n = exact_scores.shape
    n_soft = int(round((1.0 - eps) * k_i))
    n_rand = k_i - n_soft
    order = jnp.argsort(-exact_scores, axis=-1)
    rows = jnp.arange(b)[:, None]
    sel = jnp.zeros((b, n), dtype=bool)
    if k_m > 0:
        sel = sel.at[rows, order[:, :k_m]].set(True)
    k_soft, k_rand = jax.random.split(key)
    soft = sample_softmax(k_soft, exact_scores, sel, n_soft, temp)
    if n_rand == 0:
        return soft
    sel = sel.at[rows, soft].set(True)
    rand = sample_random(k_rand, sel, n_rand)
    return jnp.concatenate([soft, rand], axis=-1)

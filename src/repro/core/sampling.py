"""Anchor-item sampling strategies (paper Algorithm 3 + §3.2 oracles).

All strategies operate on a batch of queries; masking of already-selected
anchors is done with an explicit (B, N) boolean mask so the whole multi-round
loop stays jit-compatible.  SoftMax sampling without replacement uses the
Gumbel-top-k trick (Kool et al. 2019) — top-k over ``logits + Gumbel noise``
is an exact sample without replacement from the softmax distribution.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _masked_logits(scores: jax.Array, selected: jax.Array, temp: float) -> jax.Array:
    """SoftMax(S) with already-selected items masked out (Alg. 3 lines 7-8)."""
    logits = scores / jnp.asarray(temp, scores.dtype)
    return jnp.where(selected, NEG_INF, logits)


def sample_topk(
    scores: jax.Array, selected: jax.Array, k: int, temp: float = 1.0
) -> jax.Array:
    """TopK strategy: greedily pick the k highest-scoring unselected items."""
    logits = _masked_logits(scores, selected, temp)
    _, idx = jax.lax.top_k(logits, k)
    return idx


def sample_softmax(
    key: jax.Array, scores: jax.Array, selected: jax.Array, k: int, temp: float = 1.0
) -> jax.Array:
    """SoftMax strategy: sample k items w/o replacement ∝ softmax(scores)."""
    logits = _masked_logits(scores, selected, temp)
    g = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    _, idx = jax.lax.top_k(logits + g, k)
    return idx


def sample_random(
    key: jax.Array, selected: jax.Array, k: int
) -> jax.Array:
    """Random strategy: uniform w/o replacement over unselected items."""
    logits = jnp.where(selected, NEG_INF, 0.0)
    g = jax.random.gumbel(key, logits.shape, dtype=logits.dtype)
    _, idx = jax.lax.top_k(logits + g, k)
    return idx


def sample(
    strategy: str,
    key: jax.Array,
    scores: jax.Array,
    selected: jax.Array,
    k: int,
    temp: float = 1.0,
) -> jax.Array:
    """Dispatch on the paper's three strategies (Algorithm 3)."""
    if strategy == "topk":
        return sample_topk(scores, selected, k, temp)
    if strategy == "softmax":
        return sample_softmax(key, scores, selected, k, temp)
    if strategy == "random":
        return sample_random(key, selected, k)
    raise ValueError(f"unknown sampling strategy '{strategy}'")


# ---------------------------------------------------------------------------
# Oracle strategies (paper §3.2) — have access to EXACT CE scores of all
# items; used to analyse why adaptive anchor selection works.
# ---------------------------------------------------------------------------


def oracle_topk(
    key: jax.Array,
    exact_scores: jax.Array,
    k_i: int,
    k_m: int = 0,
    eps: float = 0.0,
) -> jax.Array:
    """TopK^O_{k_m,eps}: mask top-k_m items, take the next (1-eps)·k_i items
    greedily, fill the remaining eps·k_i uniformly at random."""
    b, n = exact_scores.shape
    n_greedy = int(round((1.0 - eps) * k_i))
    n_rand = k_i - n_greedy
    order = jnp.argsort(-exact_scores, axis=-1)          # (B, N) descending
    greedy = order[:, k_m : k_m + n_greedy]
    if n_rand == 0:
        return greedy
    sel = jnp.zeros((b, n), dtype=bool)
    rows = jnp.arange(b)[:, None]
    sel = sel.at[rows, order[:, : k_m + n_greedy]].set(True)
    rand = sample_random(key, sel, n_rand)
    return jnp.concatenate([greedy, rand], axis=-1)


def oracle_softmax(
    key: jax.Array,
    exact_scores: jax.Array,
    k_i: int,
    k_m: int = 0,
    eps: float = 0.0,
    temp: float = 1.0,
) -> jax.Array:
    """SoftMax^O_{k_m,eps}: mask top-k_m, sample (1-eps)·k_i by softmax of the
    exact scores, fill eps·k_i uniformly at random."""
    b, n = exact_scores.shape
    n_soft = int(round((1.0 - eps) * k_i))
    n_rand = k_i - n_soft
    order = jnp.argsort(-exact_scores, axis=-1)
    rows = jnp.arange(b)[:, None]
    sel = jnp.zeros((b, n), dtype=bool)
    if k_m > 0:
        sel = sel.at[rows, order[:, :k_m]].set(True)
    k_soft, k_rand = jax.random.split(key)
    soft = sample_softmax(k_soft, exact_scores, sel, n_soft, temp)
    if n_rand == 0:
        return soft
    sel = sel.at[rows, soft].set(True)
    rand = sample_random(k_rand, sel, n_rand)
    return jnp.concatenate([soft, rand], axis=-1)

"""Budget-matched retrieval evaluation: baselines, re-ranking, recall.

Implements the paper's evaluation protocol (§3): every method is given the
same test-time budget of exact CE calls.  Retrieve-and-rerank baselines
(dual-encoder / TF-IDF) spend the whole budget re-ranking their own top
candidates; ANNCUR/ADACUR split it between anchors and re-ranking.

The metric implementations live in :mod:`repro.eval.metrics` (one
implementation serves this module, the IR harness and the benchmarks);
``topk_recall`` / ``RecallReport`` / ``evaluate_result`` / ``exact_topk``
are re-exported here for backward compatibility.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..eval.metrics import (  # noqa: F401  (re-exported compat surface)
    RecallReport,
    evaluate_result,
    exact_topk,
    topk_recall,
)
from .adacur import AdaCURResult, ScoreFn


def rerank_baseline(
    score_fn: ScoreFn,
    candidate_idx: jax.Array,
    query,
    budget_ce: int,
    k_retrieve: int,
) -> AdaCURResult:
    """Retrieve-and-rerank: exact-CE-score the top ``budget_ce`` candidates
    of any first-stage retriever (DE, TF-IDF, MIND, ...)."""
    cand = candidate_idx[:, :budget_ce]
    scores = score_fn(query, cand)
    k = min(k_retrieve, cand.shape[1])
    top_s, top_pos = jax.lax.top_k(scores, k)
    top_idx = jnp.take_along_axis(cand, top_pos, axis=1)
    return AdaCURResult(cand, scores, scores, top_idx, top_s, budget_ce)

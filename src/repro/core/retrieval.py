"""Budget-matched retrieval evaluation: baselines, re-ranking, recall.

Implements the paper's evaluation protocol (§3): every method is given the
same test-time budget of exact CE calls.  Retrieve-and-rerank baselines
(dual-encoder / TF-IDF) spend the whole budget re-ranking their own top
candidates; ANNCUR/ADACUR split it between anchors and re-ranking.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .adacur import AdaCURResult, ScoreFn


def rerank_baseline(
    score_fn: ScoreFn,
    candidate_idx: jax.Array,
    query,
    budget_ce: int,
    k_retrieve: int,
) -> AdaCURResult:
    """Retrieve-and-rerank: exact-CE-score the top ``budget_ce`` candidates
    of any first-stage retriever (DE, TF-IDF, MIND, ...)."""
    cand = candidate_idx[:, :budget_ce]
    scores = score_fn(query, cand)
    k = min(k_retrieve, cand.shape[1])
    top_s, top_pos = jax.lax.top_k(scores, k)
    top_idx = jnp.take_along_axis(cand, top_pos, axis=1)
    return AdaCURResult(cand, scores, scores, top_idx, top_s, budget_ce)


def exact_topk(exact_scores: jax.Array, k: int):
    """Ground-truth top-k under the cross-encoder (for recall eval)."""
    return jax.lax.top_k(exact_scores, k)


def topk_recall(retrieved_idx: jax.Array, gt_idx: jax.Array, k: int) -> jax.Array:
    """Top-k-Recall: |retrieved ∩ gt_topk| / k, averaged over the batch.

    ``retrieved_idx`` may contain more than k entries (paper convention:
    recall of the ground-truth top-k within the method's returned set).
    """
    hits = (retrieved_idx[:, :, None] == gt_idx[:, None, :k]).any(axis=1)
    return hits.mean()


@dataclass
class RecallReport:
    method: str
    budget_ce: int
    recall: dict  # k -> float


def evaluate_result(
    method: str,
    result: AdaCURResult,
    exact_scores: jax.Array,
    ks=(1, 10, 100),
) -> RecallReport:
    out = {}
    for k in ks:
        _, gt = exact_topk(exact_scores, k)
        out[k] = float(topk_recall(result.topk_idx, gt, k))
    return RecallReport(method, result.ce_calls, out)

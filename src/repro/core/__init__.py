"""Core library: the paper's contribution (ADACUR) and its substrate.

- ``cur``       CUR decomposition, pseudo-inverse (full + incremental)
- ``sampling``  anchor sampling strategies (TopK/SoftMax/Random + oracles)
- ``adacur``    Algorithm 1 reference implementation (growing shapes)
- ``engine``    static-shape round engine + unified Retriever API (hot path)
- ``anncur``    fixed-anchor baseline (Yadav et al. 2022)
- ``retrieval`` budget-matched retrieve-and-rerank + recall metrics
- ``index``     offline R_anc builder (resumable, shardable)
"""

from . import adacur, anncur, cur, engine, index, retrieval, sampling  # noqa: F401
from .adacur import AdaCURResult, adacur_search, make_jitted_search  # noqa: F401
from .anncur import ANNCURIndex, build_index  # noqa: F401
from .engine import (  # noqa: F401
    AdaCURRetriever,
    ANNCURRetriever,
    RerankRetriever,
    Retriever,
    engine_search,
    make_engine,
)

"""Core library: the paper's contribution (ADACUR) and its substrate.

- ``cur``       CUR decomposition, pseudo-inverse (full + incremental)
- ``sampling``  anchor sampling strategies (TopK/SoftMax/Random + oracles)
- ``adacur``    Algorithm 1 reference implementation (growing shapes)
- ``engine``    static-shape round engine + unified Retriever API (hot path),
                single-device and SPMD ((data x items) mesh via shard_map)
- ``candidates`` first-stage candidate generation (dual-encoder / BM25 /
                oracle) + candidate-subset hybrid retrieval
- ``retrieval`` budget-matched retrieve-and-rerank + recall metrics
                (implementations in ``repro.eval.metrics``)
- ``index``     the AnchorIndex offline artifact (build/save/load/shard/mutate)
- ``scorer``    the Scorer subsystem (synthetic/tabulated/real CE + cache)

ANNCUR lives inside this API: the offline product is
``AnchorIndex.with_latents`` and the online search is
``ANNCURRetriever.from_index`` (the legacy ``anncur`` shim module was
removed after its deprecation cycle).
"""

from . import adacur, candidates, cur, engine, index, retrieval, sampling, scorer  # noqa: F401
from .adacur import AdaCURResult, adacur_search, make_jitted_search  # noqa: F401
from .candidates import (  # noqa: F401
    BM25Candidates,
    CandidateGenerator,
    DualEncoderCandidates,
    GeneratorStats,
    HybridRetriever,
    OracleCandidates,
    candidate_eligibility,
    union_candidates,
)
from .engine import (  # noqa: F401
    AdaCURRetriever,
    ANNCURRetriever,
    RerankRetriever,
    Retriever,
    ce_call_plan,
    engine_search,
    make_engine,
    make_sharded_engine,
)
from .index import AnchorIndex, build_r_anc  # noqa: F401
from .scorer import (  # noqa: F401
    CachingScorer,
    CrossEncoderScorer,
    Scorer,
    ScorerStats,
    SyntheticScorer,
    TabulatedScorer,
    scorer_stats,
)

"""Deterministic fault injection for the serving tier.

Every failure mode the router tier must survive — a scorer raising out of
its host callback mid-round, a replica stalling past its latency budget, a
live index swap racing in-flight requests — is expressed as a declarative
:class:`FaultPlan` so chaos tests and the load benchmark reproduce the
exact same failure at the exact same point on every run.  Nothing here is
probabilistic: faults key off *counters* (the k-th scorer callback, the
n-th admitted request), never clocks or RNG.

The injection points live where the real failures would:

- :class:`FaultyScorer` wraps any host-backed Scorer and raises
  :class:`FaultInjectedError` from inside the ``pure_callback`` on the
  scheduled call — the engine then surfaces ``XlaRuntimeError`` exactly as
  a crashed production cross-encoder would.
- ``FaultPlan.sleep_s`` is consulted by each replica worker before serving
  a batch: a matching :class:`SleepFault` stalls that replica, which is
  what drives the router's hedging and the straggler watchdog.
- ``FaultPlan.swap_due`` fires at an admission sequence number, telling the
  driver to ``swap_index`` while requests are in flight.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class FaultInjectedError(RuntimeError):
    """Raised by :class:`FaultyScorer` on a scheduled call."""


@dataclass(frozen=True)
class ScorerFault:
    """Raise out of the host scorer's k-th callback (1-based, per replica
    counter).  ``replica=None`` matches any replica's counter."""

    call_k: int
    replica: Optional[int] = None


@dataclass(frozen=True)
class SleepFault:
    """Stall ``replica`` for ``seconds`` before it serves a batch.

    ``request_seq=None`` makes the replica *persistently* slow (the
    single-slow-replica scenario the hedging gate measures); a concrete
    sequence number stalls only the batch containing that admitted request.
    """

    replica: int
    seconds: float
    request_seq: Optional[int] = None


@dataclass(frozen=True)
class SwapFault:
    """Swap the live index once ``at_seq`` requests have been admitted."""

    at_seq: int


class FaultPlan:
    """The full deterministic failure schedule of one run.

    Consulted by :class:`FaultyScorer` (scorer faults), the router's
    replica workers (sleep faults), and the admission path (swap faults —
    one-shot: each fires exactly once, at the first admission count at or
    past its ``at_seq``).
    """

    def __init__(
        self,
        scorer_faults: Sequence[ScorerFault] = (),
        sleep_faults: Sequence[SleepFault] = (),
        swap_faults: Sequence[SwapFault] = (),
    ):
        self.scorer_faults = list(scorer_faults)
        self.sleep_faults = list(sleep_faults)
        self.swap_faults = sorted(swap_faults, key=lambda f: f.at_seq)
        self._swaps_fired: List[SwapFault] = []

    def scorer_should_raise(self, call_k: int, replica: Optional[int]) -> bool:
        return any(
            f.call_k == call_k and (f.replica is None or f.replica == replica)
            for f in self.scorer_faults
        )

    def sleep_s(self, replica: int, request_seqs: Sequence[int]) -> float:
        """Stall duration before ``replica`` serves the batch holding the
        given admitted sequence numbers (0.0 = no fault)."""
        seqs = set(request_seqs)
        hit = [
            f.seconds
            for f in self.sleep_faults
            if f.replica == replica
            and (f.request_seq is None or f.request_seq in seqs)
        ]
        return max(hit, default=0.0)

    def swap_due(self, admitted: int) -> bool:
        """One-shot: True the first time the admission count reaches a
        scheduled swap."""
        if self.swap_faults and admitted >= self.swap_faults[0].at_seq:
            self._swaps_fired.append(self.swap_faults.pop(0))
            return True
        return False


class FaultyScorer:
    """Wrap a host-backed Scorer; raise on the plan's scheduled calls.

    Scoring, stats, and the pair log all stay on the *inner* scorer (the
    wrapper adds a call counter only), so measured-CE accounting and the
    exactly-once pair invariants read identically with or without the
    wrapper.  The raise happens inside the ``pure_callback`` — the engine
    sees the same ``XlaRuntimeError`` a production scorer crash produces,
    and :meth:`AdaCURService.flush`'s error boundary must contain it.
    """

    def __init__(self, inner, plan: Optional[FaultPlan] = None,
                 replica: Optional[int] = None):
        self.inner = inner
        self.plan = plan
        self.replica = replica
        self.calls = 0

    @property
    def stats(self):
        return self.inner.stats

    @property
    def call_log(self):
        return self.inner.call_log

    def reset_stats(self) -> None:
        self.inner.reset_stats()

    def _host_entry(self, qids, idx):
        self.calls += 1
        if self.plan is not None and self.plan.scorer_should_raise(
            self.calls, self.replica
        ):
            raise FaultInjectedError(
                f"injected scorer fault: call {self.calls} on replica "
                f"{self.replica}"
            )
        return np.asarray(self.inner._host_entry(qids, idx), dtype=np.float32)

    def __call__(self, query, item_idx) -> jax.Array:
        return jax.pure_callback(
            self._host_entry,
            jax.ShapeDtypeStruct(item_idx.shape, jnp.float32),
            query, item_idx,
        )

"""ADACUR retrieval service: batched request serving over a CE scorer.

The production serving loop the paper's technique plugs into:

- an offline :class:`repro.core.index.AnchorIndex` artifact (built by the
  resumable block-streaming job, persisted/loaded from disk, mutable at
  runtime via ``add_items``/``remove_items`` without retracing);
- any :class:`repro.core.engine.Retriever` behind the unified search API —
  the default is :class:`AdaCURRetriever` on the static-shape round engine
  (``loop_mode='fori'``), so per-batch round-count overrides do not retrace;
- request batching: queries accumulate to a batch or a deadline.  Batches
  fire from ``submit`` when full/overdue AND from ``poll`` — an idle queue
  with one straggler request is flushed by the event loop's periodic
  ``poll`` even if no further request ever arrives;
- per-request k-NN results with exact CE scores.

CLI:  PYTHONPATH=src python -m repro.launch.serve --requests 64 \
          --retriever {adacur,anncur,rerank} [--index-path DIR]
"""

from __future__ import annotations

import argparse
import os
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig
from ..core.engine import (
    AdaCURRetriever,
    ANNCURRetriever,
    RerankRetriever,
    Retriever,
)
from ..core.index import AnchorIndex, clear_build_checkpoints


@dataclass
class RetrievalRequest:
    query_id: int
    arrival_t: float = field(default_factory=time.monotonic)


@dataclass
class RetrievalResponse:
    query_id: int
    item_ids: np.ndarray
    scores: np.ndarray
    latency_s: float
    ce_calls: int


class AdaCURService:
    """Batched retrieval over an AnchorIndex via any Retriever.

    The offline side always enters through the :class:`AnchorIndex`
    artifact: pass one directly (or an on-disk index path), or pass a bare
    ``r_anc`` score matrix and the service wraps it.  Swap in a mutated
    index between batches with :meth:`swap_index` — capacity-padded shapes
    mean the compiled search is reused as-is.
    """

    def __init__(
        self,
        score_fn: Optional[Callable] = None,
        r_anc: Optional[jax.Array] = None,
        cfg: Optional[AdaCURConfig] = None,
        max_batch: int = 32,
        max_wait_s: float = 0.01,
        seed: int = 0,
        retriever: Optional[Retriever] = None,
        index: Optional[Union[AnchorIndex, str, os.PathLike]] = None,
        candidate_fn: Optional[Callable] = None,
    ):
        if index is not None and not isinstance(index, AnchorIndex):
            index = AnchorIndex.load(os.fspath(index))
        if retriever is None:
            if index is None:
                if score_fn is None or r_anc is None or cfg is None:
                    raise ValueError(
                        "need an index (AnchorIndex or path), (score_fn, r_anc, "
                        "cfg), or a retriever"
                    )
                index = AnchorIndex.from_r_anc(r_anc)
            if score_fn is None or cfg is None:
                raise ValueError("need score_fn and cfg to build the retriever")
            retriever = AdaCURRetriever.from_index(index, score_fn, cfg)
        elif index is None:
            index = getattr(retriever, "index", None)
        self.retriever = retriever
        self.index = index
        self.candidate_fn = candidate_fn    # qids (B,) -> (B, M) first-stage order
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._key = jax.random.PRNGKey(seed)
        self._pending: List[RetrievalRequest] = []

    def swap_index(self, index: AnchorIndex) -> None:
        """Serve a mutated (add/remove) index from the next batch on.  The
        index's capacity-constant shapes mean no recompilation happens."""
        if getattr(self.retriever, "index", None) is None:
            raise ValueError(
                "swap_index needs an index-backed retriever (Retriever."
                "from_index); this retriever was built on a bare r_anc and "
                "would keep searching the old scores"
            )
        self.index = index
        self.retriever.index = index

    def _due(self) -> bool:
        if not self._pending:
            return False
        oldest = self._pending[0].arrival_t
        return (
            len(self._pending) >= self.max_batch
            or time.monotonic() - oldest >= self.max_wait_s
        )

    def submit(self, req: RetrievalRequest) -> Optional[List[RetrievalResponse]]:
        """Queue a request; returns responses when a batch fires."""
        self._pending.append(req)
        return self.flush() if self._due() else None

    def poll(self) -> List[RetrievalResponse]:
        """Deadline check for stragglers: flush if the oldest queued request
        has waited past ``max_wait_s``.  Call from the serving event loop —
        without this, a lone queued request was only served when *another*
        request happened to arrive."""
        return self.flush() if self._due() else []

    def flush(self) -> List[RetrievalResponse]:
        if not self._pending:
            return []
        batch, self._pending = self._pending[: self.max_batch], self._pending[self.max_batch :]
        qids = jnp.asarray([r.query_id for r in batch])
        self._key, sub = jax.random.split(self._key)
        kw = {}
        if self.candidate_fn is not None:
            kw["candidate_idx"] = self.candidate_fn(qids)
        res = self.retriever.search(qids, sub, **kw)
        res = jax.block_until_ready(res)
        # single source of truth: an index-backed retriever may have been
        # mutated directly (retriever.index = ...), so map positions through
        # ITS index, not a possibly-stale service copy
        idx = getattr(self.retriever, "index", None)
        if idx is None:
            idx = self.index
        item_ids = (
            np.asarray(idx.gather_item_ids(res.topk_idx))
            if idx is not None else np.asarray(res.topk_idx)
        )
        out = []
        for i, r in enumerate(batch):
            out.append(
                RetrievalResponse(
                    query_id=r.query_id,
                    item_ids=item_ids[i],
                    scores=np.asarray(res.topk_scores[i]),
                    latency_s=time.monotonic() - r.arrival_t,
                    ce_calls=res.ce_calls,
                )
            )
        return out


def make_retriever(
    kind: str,
    index: AnchorIndex,
    score_fn: Callable,
    cfg: AdaCURConfig,
    anchor_key: Optional[jax.Array] = None,
) -> Retriever:
    """CLI retriever factory: every method consumes the same AnchorIndex."""
    if kind == "adacur":
        return AdaCURRetriever.from_index(index, score_fn, cfg)
    if kind == "anncur":
        if index.anchor_item_pos is None:
            index = index.with_anchors(
                k_anchor=cfg.k_anchor,
                key=anchor_key if anchor_key is not None else jax.random.PRNGKey(2),
            )
        return ANNCURRetriever.from_index(
            index, score_fn, budget_ce=cfg.budget_ce, k_retrieve=cfg.k_retrieve
        )
    if kind == "rerank":
        return RerankRetriever.from_index(
            index, score_fn, budget_ce=cfg.budget_ce, k_retrieve=cfg.k_retrieve
        )
    raise ValueError(f"unknown retriever '{kind}' (adacur|anncur|rerank)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n-items", type=int, default=10000)
    ap.add_argument("--budget", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--fused", action="store_true",
                    help="fused Pallas score->top-k sampling")
    ap.add_argument("--retriever", choices=("adacur", "anncur", "rerank"),
                    default="adacur", help="search method over the index")
    ap.add_argument("--index-path", default=None,
                    help="AnchorIndex directory: loaded when present, else "
                         "built once and saved there")
    args = ap.parse_args()

    from ..data.synthetic import make_synthetic_ce

    index = None
    if args.index_path and os.path.exists(
        os.path.join(args.index_path, "index_meta.json")
    ):
        print(f"loading AnchorIndex from {args.index_path}...")
        index = AnchorIndex.load(args.index_path)
        if index.n_items != args.n_items:
            print(f"  index holds {index.n_items} items; overriding "
                  f"--n-items {args.n_items} to match")
            args.n_items = index.n_items

    print(f"building synthetic CE domain (|I|={args.n_items})...")
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=600, n_items=args.n_items)

    if index is None:
        print("building AnchorIndex (block-streamed, resumable)...")
        index = AnchorIndex.build(
            ce.score_block, jnp.arange(500), jnp.arange(args.n_items),
            block_rows=128, checkpoint_dir=args.index_path,
        )
        if args.index_path:
            index.save(args.index_path)
            # the committed artifact supersedes the row-block checkpoints
            clear_build_checkpoints(args.index_path)
            print(f"saved AnchorIndex to {args.index_path}")

    cfg = AdaCURConfig(
        k_anchor=args.budget // 2, n_rounds=args.rounds, budget_ce=args.budget,
        strategy="topk", k_retrieve=100, loop_mode="fori",
        use_fused_topk=args.fused,
    )
    retriever = make_retriever(args.retriever, index, ce.score_fn(), cfg)
    candidate_fn = None
    if args.retriever == "rerank":
        # stand-in first-stage retriever: dual-encoder dot-product order
        def candidate_fn(qids):
            scores = ce.q_emb[qids] @ ce.i_emb.T
            _, order = jax.lax.top_k(scores, cfg.budget_ce)
            return order

    svc = AdaCURService(
        retriever=retriever, max_batch=args.batch, candidate_fn=candidate_fn
    )

    served = []
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        qid = int(rng.integers(500, 600))
        served += svc.submit(RetrievalRequest(query_id=qid)) or []
        served += svc.poll()   # the event loop's deadline sweep
    served += svc.flush()
    lat = np.array([r.latency_s for r in served])
    print(
        f"[{args.retriever}] served {len(served)} requests | "
        f"p50={np.percentile(lat, 50)*1e3:.1f}ms "
        f"p99={np.percentile(lat, 99)*1e3:.1f}ms | "
        f"{cfg.budget_ce} CE calls/request (vs {args.n_items} brute force = "
        f"{args.n_items / cfg.budget_ce:.0f}x fewer)"
    )


if __name__ == "__main__":
    main()

"""ADACUR retrieval service: batched request serving over a CE scorer.

The production serving loop the paper's technique plugs into:

- an offline ``R_anc`` index (built by repro.core.index, checkpointed);
- a scorer backend (tiny trained CE transformer, synthetic CE, or any
  recsys joint scorer) behind the common score_fn interface;
- request batching: queries accumulate to a batch (or a deadline) and run
  one jit'd multi-round ADACUR search together;
- per-request k-NN results with exact CE scores.

CLI:  PYTHONPATH=src python -m repro.launch.serve --arch ce-tiny --requests 64
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig
from ..core import adacur


@dataclass
class RetrievalRequest:
    query_id: int
    arrival_t: float = field(default_factory=time.monotonic)


@dataclass
class RetrievalResponse:
    query_id: int
    item_ids: np.ndarray
    scores: np.ndarray
    latency_s: float
    ce_calls: int


class AdaCURService:
    """Batched ADACUR retrieval over a fixed item corpus."""

    def __init__(
        self,
        score_fn: Callable,
        r_anc: jax.Array,
        cfg: AdaCURConfig,
        max_batch: int = 32,
        max_wait_s: float = 0.01,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.r_anc = r_anc
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._key = jax.random.PRNGKey(seed)
        self._search = adacur.make_jitted_search(score_fn, cfg)
        self._pending: List[RetrievalRequest] = []

    def submit(self, req: RetrievalRequest) -> Optional[List[RetrievalResponse]]:
        """Queue a request; returns responses when a batch fires."""
        self._pending.append(req)
        oldest = self._pending[0].arrival_t
        if (
            len(self._pending) >= self.max_batch
            or time.monotonic() - oldest >= self.max_wait_s
        ):
            return self.flush()
        return None

    def flush(self) -> List[RetrievalResponse]:
        if not self._pending:
            return []
        batch, self._pending = self._pending[: self.max_batch], self._pending[self.max_batch :]
        qids = jnp.asarray([r.query_id for r in batch])
        self._key, sub = jax.random.split(self._key)
        t0 = time.monotonic()
        res = self._search(self.r_anc, qids, sub)
        res = jax.block_until_ready(res)
        dt = time.monotonic() - t0
        out = []
        for i, r in enumerate(batch):
            out.append(
                RetrievalResponse(
                    query_id=r.query_id,
                    item_ids=np.asarray(res.topk_idx[i]),
                    scores=np.asarray(res.topk_scores[i]),
                    latency_s=time.monotonic() - r.arrival_t,
                    ce_calls=res.ce_calls,
                )
            )
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n-items", type=int, default=10000)
    ap.add_argument("--budget", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--batch", type=int, default=16)
    args = ap.parse_args()

    from ..data.synthetic import make_synthetic_ce

    print(f"building synthetic CE domain (|I|={args.n_items}) + R_anc index...")
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=600, n_items=args.n_items)
    r_anc = ce.full_matrix(jnp.arange(500))

    cfg = AdaCURConfig(
        k_anchor=args.budget // 2, n_rounds=args.rounds, budget_ce=args.budget,
        strategy="topk", k_retrieve=100,
    )
    svc = AdaCURService(ce.score_fn(), r_anc, cfg, max_batch=args.batch)

    lat = []
    done = 0
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        qid = int(rng.integers(500, 600))
        resp = svc.submit(RetrievalRequest(query_id=qid))
        if resp:
            done += len(resp)
            lat += [r.latency_s for r in resp]
    for r in svc.flush():
        done += 1
        lat.append(r.latency_s)
    lat = np.array(lat)
    print(
        f"served {done} requests | p50={np.percentile(lat, 50)*1e3:.1f}ms "
        f"p99={np.percentile(lat, 99)*1e3:.1f}ms | "
        f"{cfg.budget_ce} CE calls/request (vs {args.n_items} brute force = "
        f"{args.n_items / cfg.budget_ce:.0f}x fewer)"
    )


if __name__ == "__main__":
    main()

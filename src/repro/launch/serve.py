"""ADACUR retrieval service: batched request serving over a CE scorer.

The production serving loop the paper's technique plugs into:

- an offline :class:`repro.core.index.AnchorIndex` artifact (built by the
  resumable block-streaming job, persisted/loaded from disk, mutable at
  runtime via ``add_items``/``remove_items`` without retracing);
- any :class:`repro.core.engine.Retriever` behind the unified search API —
  the default is :class:`AdaCURRetriever` on the static-shape round engine
  (``loop_mode='fori'``), so per-batch round-count overrides do not retrace;
- continuous micro-batching: queries accumulate to a batch or a deadline.
  Batches fire from ``submit`` when full/overdue AND from ``poll`` — an
  idle queue with one straggler request is flushed by the event loop's
  periodic ``poll`` even if no further request ever arrives.  Every fired
  batch is padded up to one of a small set of static *batch buckets*
  (partial fills repeat the last row; padded rows are computed and
  discarded, exactly like the engine's ``n_valid`` item padding), so a
  deadline straggler reuses a compiled executable instead of retracing at
  its odd batch size;
- scorer-measured accounting: when the retriever's score_fn is a
  :class:`repro.core.scorer.Scorer` (e.g. ``CachingScorer`` around a
  ``CrossEncoderScorer``), responses carry the *measured* CE calls and
  cache hits of their batch window — the budget is observed, not assumed;
- per-request k-NN results with exact CE scores.

CLI:  PYTHONPATH=src python -m repro.launch.serve --requests 64 \
          --retriever {adacur,anncur,rerank} [--first-stage {none,de,bm25}] \
          [--index-path DIR] [--scorer {synthetic,real-ce}] [--cache] \
          [--payload-dtype {float32,bfloat16,int8,int4,fp8}] \
          [--round-kernel {staged,persistent}] [--mesh DATAxITEMS]

``--first-stage de|bm25`` serves the multi-stage hybrid: a dual-encoder or
BM25 generator proposes a per-query shortlist and the ADACUR search is
restricted to those candidates via the engine's ``eligible`` mask (the
generator runs outside the compiled search, so it composes with ``--mesh``
— candidates are computed once per batch, host- or device-side, and the
sharded engine only sees a boolean operand).

``--mesh 2x4`` serves over a (data x items) mesh: the index payload shards
over 8 devices' "items" axis, request batches data-parallel over "data", and
the FULL multi-round engine runs as one shard_map program (bit-identical to
single-device serving).  The device count must match — on a CPU host export
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` first.  ``--mesh``
composes with every scorer: synthetic/tabulated/cached ones run as before,
and ``--scorer real-ce`` serves through the *device-resident* CE stage —
the corpus token table rides on the index (``AnchorIndex.with_item_tokens``)
and the transformer forward runs inside the shard_map program, split across
the item shards (see ``engine.make_sharded_engine``).  The one exclusion is
``--cache`` under a real-CE mesh: the pair cache intercepts host callbacks,
and the device-resident CE never leaves the device.
"""

from __future__ import annotations

import argparse
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig
from ..core.engine import (
    AdaCURRetriever,
    ANNCURRetriever,
    RerankRetriever,
    Retriever,
)
from ..core.index import AnchorIndex, clear_build_checkpoints
from ..core.scorer import ScorerStats, scorer_stats


@dataclass
class RetrievalRequest:
    query_id: int
    arrival_t: float = field(default_factory=time.monotonic)
    deadline_t: Optional[float] = None   # absolute time.monotonic() budget;
                                         # past it the search returns the
                                         # provisional top-k (degraded=True)


@dataclass
class RetrievalResponse:
    query_id: int
    item_ids: Optional[np.ndarray] = None      # None on status="error"
    scores: Optional[np.ndarray] = None
    latency_s: float = 0.0
    ce_calls: int = 0                          # planned budget (upper bound)
    measured_ce_calls: Optional[int] = None    # scorer-measured, per batch row
    cache_hits: Optional[int] = None           # pairs served from cache (batch)
    status: str = "ok"                         # "ok" | "error"
    degraded: bool = False                     # deadline cut the round loop;
                                               # results are the anytime top-k
    rounds_completed: Optional[int] = None     # rounds actually executed
    error: Optional[str] = None                # failure detail (status="error")


class AdaCURService:
    """Batched retrieval over an AnchorIndex via any Retriever.

    The offline side always enters through the :class:`AnchorIndex`
    artifact: pass one directly (or an on-disk index path), or pass a bare
    ``r_anc`` score matrix and the service wraps it.  Swap in a mutated
    index between batches with :meth:`swap_index` — capacity-padded shapes
    mean the compiled search is reused as-is.

    ``batch_buckets`` are the static batch sizes the engine compiles for:
    every flush pads its requests up to the smallest bucket that fits
    (repeating the last row) and slices the padding off the responses.
    Padded rows never reach a response; note the engine's batched RNG
    draws depend on the batch shape, so a padded flush is the same search
    under a different (equally arbitrary) seed realization rather than a
    bit-identical rerun of the unpadded one.  Defaults to
    quarter/half/full of ``max_batch``.
    """

    def __init__(
        self,
        score_fn: Optional[Callable] = None,
        r_anc: Optional[jax.Array] = None,
        cfg: Optional[AdaCURConfig] = None,
        max_batch: int = 32,
        max_wait_s: float = 0.01,
        seed: int = 0,
        retriever: Optional[Retriever] = None,
        index: Optional[Union[AnchorIndex, str, os.PathLike]] = None,
        candidate_fn: Optional[Callable] = None,
        batch_buckets: Optional[List[int]] = None,
        deterministic: bool = False,
    ):
        if index is not None and not isinstance(index, AnchorIndex):
            index = AnchorIndex.load(os.fspath(index))
        if retriever is None:
            if index is None:
                if score_fn is None or r_anc is None or cfg is None:
                    raise ValueError(
                        "need an index (AnchorIndex or path), (score_fn, r_anc, "
                        "cfg), or a retriever"
                    )
                index = AnchorIndex.from_r_anc(r_anc)
            if score_fn is None or cfg is None:
                raise ValueError("need score_fn and cfg to build the retriever")
            retriever = AdaCURRetriever.from_index(index, score_fn, cfg)
        elif index is None:
            index = getattr(retriever, "index", None)
        self.retriever = retriever
        self.index = index
        self.candidate_fn = candidate_fn    # qids (B,) -> (B, M) first-stage order
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        if batch_buckets is None:
            batch_buckets = {max(1, max_batch // 4), max(1, max_batch // 2),
                             max_batch}
        self.batch_buckets = sorted(set(int(b) for b in batch_buckets))
        if self.batch_buckets[-1] != max_batch:
            raise ValueError(
                f"largest bucket {self.batch_buckets[-1]} must equal "
                f"max_batch={max_batch}"
            )
        # measured accounting source: the retriever's scorer, if it is one
        self._scorer = getattr(retriever, "score_fn", None)
        # deterministic: every flush reuses the seed key, so a query's search
        # trajectory is a function of (batch row, query_id) only.  With the
        # noise-free "topk" strategy, repeat queries then re-request exactly
        # the pairs already in a CachingScorer — what makes the cross-request
        # score cache effective (at the cost of per-flush anchor diversity).
        self.deterministic = deterministic
        self._key = jax.random.PRNGKey(seed)
        self._pending: List[RetrievalRequest] = []
        # one lock over queue + index mutation + flush: submit()/poll() from
        # request threads may race swap_index() from a control thread, and a
        # batch must be popped, searched, and answered under the index that
        # admitted it (reentrant: swap_index drains via flush)
        self._lock = threading.RLock()

    @property
    def scorer_stats(self) -> Optional[ScorerStats]:
        """Live measured stats of the underlying Scorer (None for bare fns)."""
        return scorer_stats(self._scorer) if self._scorer is not None else None

    def swap_index(self, index: AnchorIndex) -> List[RetrievalResponse]:
        """Serve a mutated (add/remove) index from the next batch on.  The
        index's capacity-constant shapes mean no recompilation happens.

        Requests already queued were admitted under the live index, so they
        are flushed against it *before* the swap (their responses are
        returned) — a swap racing queued requests can never serve a request
        with ids from an index it was not admitted under."""
        if getattr(self.retriever, "index", None) is None:
            raise ValueError(
                "swap_index needs an index-backed retriever (Retriever."
                "from_index); this retriever was built on a bare r_anc and "
                "would keep searching the old scores"
            )
        with self._lock:
            drained: List[RetrievalResponse] = []
            while self._pending:
                drained += self.flush()
            self.index = index
            self.retriever.index = index
            return drained

    def _due(self) -> bool:
        if not self._pending:
            return False
        oldest = self._pending[0].arrival_t
        return (
            len(self._pending) >= self.max_batch
            or time.monotonic() - oldest >= self.max_wait_s
        )

    def submit(self, req: RetrievalRequest) -> Optional[List[RetrievalResponse]]:
        """Queue a request; returns responses when a batch fires."""
        with self._lock:
            self._pending.append(req)
            return self.flush() if self._due() else None

    def poll(self) -> List[RetrievalResponse]:
        """Deadline check for stragglers: flush if the oldest queued request
        has waited past ``max_wait_s``.  Call from the serving event loop —
        without this, a lone queued request was only served when *another*
        request happened to arrive."""
        with self._lock:
            return self.flush() if self._due() else []

    def _bucket(self, n: int) -> int:
        for b in self.batch_buckets:
            if b >= n:
                return b
        return self.batch_buckets[-1]

    def flush(self) -> List[RetrievalResponse]:
        with self._lock:
            if not self._pending:
                return []
            batch, self._pending = self._pending[: self.max_batch], self._pending[self.max_batch :]
            try:
                return self._flush_batch(batch)
            except Exception as e:  # noqa: BLE001 — the flush boundary
                # A scorer exception (pure_callback -> XlaRuntimeError, or a
                # host scorer raising eagerly) fails exactly this batch: each
                # popped request gets a terminal error response, the rest of
                # the queue and the service loop keep running.
                msg = f"{type(e).__name__}: {e}"
                try:
                    # drain the poisoned effects token of the failed callback
                    # so it does not resurface at the next barrier/atexit
                    jax.effects_barrier()
                except Exception:  # noqa: BLE001
                    pass
                now = time.monotonic()
                return [
                    RetrievalResponse(
                        query_id=r.query_id,
                        latency_s=now - r.arrival_t,
                        status="error",
                        error=msg,
                    )
                    for r in batch
                ]

    def _flush_batch(self, batch: List[RetrievalRequest]) -> List[RetrievalResponse]:
        n_valid = len(batch)
        bucket = self._bucket(n_valid)
        # partial fill: pad to the static bucket by repeating the last row;
        # the padding is sliced off before responses are built
        raw = [r.query_id for r in batch] + [batch[-1].query_id] * (bucket - n_valid)
        qids = jnp.asarray(raw)
        if self.deterministic:
            sub = self._key
        else:
            self._key, sub = jax.random.split(self._key)
        kw = {}
        if self.candidate_fn is not None:
            kw["candidate_idx"] = self.candidate_fn(qids)
        # anytime serving: an armed deadline is batch-global (one round loop
        # serves all rows), so the tightest request deadline governs
        holder = getattr(self.retriever, "deadline", None)
        budgets = [r.deadline_t for r in batch if r.deadline_t is not None]
        if budgets and holder is not None:
            kw["deadline_t"] = min(budgets)
        before = self.scorer_stats
        before = before.copy() if before is not None else None
        res = self.retriever.search(qids, sub, **kw)
        res = jax.block_until_ready(res)
        degraded = bool(holder.fired) if "deadline_t" in kw else False
        rounds = res.rounds_done
        rounds = int(np.asarray(rounds)) if rounds is not None else None
        measured = cache_hits = None
        if before is not None:
            delta = self.scorer_stats - before
            # amortized over the REAL requests: padded filler rows are a
            # cost of serving them, so their calls are not averaged away
            measured = delta.ce_calls // n_valid
            cache_hits = delta.cache_hits
        # single source of truth: an index-backed retriever may have been
        # mutated directly (retriever.index = ...), so map positions through
        # ITS index, not a possibly-stale service copy
        idx = getattr(self.retriever, "index", None)
        if idx is None:
            idx = self.index
        item_ids = (
            np.asarray(idx.gather_item_ids(res.topk_idx))
            if idx is not None else np.asarray(res.topk_idx)
        )
        out = []
        for i, r in enumerate(batch):
            out.append(
                RetrievalResponse(
                    query_id=r.query_id,
                    item_ids=item_ids[i],
                    scores=np.asarray(res.topk_scores[i]),
                    latency_s=time.monotonic() - r.arrival_t,
                    ce_calls=res.ce_calls,
                    measured_ce_calls=measured,
                    cache_hits=cache_hits,
                    degraded=degraded,
                    rounds_completed=rounds,
                )
            )
        return out


def make_retriever(
    kind: str,
    index: AnchorIndex,
    score_fn: Callable,
    cfg: AdaCURConfig,
    anchor_key: Optional[jax.Array] = None,
    anytime: bool = False,
) -> Retriever:
    """CLI retriever factory: every method consumes the same AnchorIndex."""
    if kind == "adacur":
        return AdaCURRetriever.from_index(index, score_fn, cfg, anytime=anytime)
    if kind == "anncur":
        if index.anchor_item_pos is None:
            index = index.with_anchors(
                k_anchor=cfg.k_anchor,
                key=anchor_key if anchor_key is not None else jax.random.PRNGKey(2),
            )
        return ANNCURRetriever.from_index(
            index, score_fn, budget_ce=cfg.budget_ce, k_retrieve=cfg.k_retrieve
        )
    if kind == "rerank":
        return RerankRetriever.from_index(
            index, score_fn, budget_ce=cfg.budget_ce, k_retrieve=cfg.k_retrieve
        )
    raise ValueError(f"unknown retriever '{kind}' (adacur|anncur|rerank)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n-items", type=int, default=10000)
    ap.add_argument("--budget", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--fused", action="store_true",
                    help="fused Pallas score->top-k sampling")
    ap.add_argument("--retriever", choices=("adacur", "anncur", "rerank"),
                    default="adacur", help="search method over the index")
    ap.add_argument("--first-stage", choices=("none", "de", "bm25"),
                    default="none",
                    help="multi-stage hybrid retrieval: a dual-encoder or "
                         "BM25 first stage proposes a per-query shortlist "
                         "and ADACUR spends the CE budget only on those "
                         "candidates (engine 'eligible' mask; composes "
                         "with --mesh). Requires --retriever adacur")
    ap.add_argument("--index-path", default=None,
                    help="AnchorIndex directory: loaded when present, else "
                         "built once and saved there")
    ap.add_argument("--scorer", choices=("synthetic", "real-ce"),
                    default="synthetic",
                    help="real-ce: a transformer CrossEncoderScorer over a "
                         "ZESHEL-like corpus (bucketed micro-batching through "
                         "the flash-attention path)")
    ap.add_argument("--cache", action="store_true",
                    help="wrap the scorer in a (query, item) score cache")
    ap.add_argument("--payload-dtype",
                    choices=("float32", "bfloat16", "int8", "int4", "fp8"),
                    default="float32",
                    help="storage/streaming dtype of the R_anc payload: the "
                         "coded dtypes store per-tile codes+scales with fused "
                         "dequant in the kernel (int8/fp8 ~4x smaller index, "
                         "packed int4 ~8x)")
    ap.add_argument("--round-kernel", choices=("staged", "persistent"),
                    default="staged",
                    help="persistent: one fused payload sweep per round "
                         "(estimate + Gumbel top-k + provisional monitor in "
                         "a single pass; requires --fused). Bit-identical "
                         "rankings to staged")
    ap.add_argument("--mesh", default=None, metavar="DATAxITEMS",
                    help="serve over a (data x items) mesh, e.g. 2x4: the "
                         "items axis shards the index payload, the data axis "
                         "shards request batches; the full engine runs as one "
                         "shard_map program (device count must match)")
    args = ap.parse_args()

    from ..data.synthetic import make_synthetic_ce

    if (args.scorer == "real-ce" and not args.mesh
            and len(os.sched_getaffinity(0)) < 2):
        # single-core host: the async CPU client has one execute thread, so
        # the host CE callback's nested jit would self-block (the
        # single-device twin of the mesh deadlock). Must be set before the
        # first jax computation instantiates the client.
        jax.config.update("jax_cpu_enable_async_dispatch", False)

    if args.scorer == "real-ce":
        # with --mesh the CE runs device-resident inside the shard_map
        # program (DeviceCEScorer + the index token table); capability
        # detection lives in make_sharded_engine, which rejects any scorer
        # whose host callback would launch nested device compute
        _serve_real_ce(args)
        return

    index = None
    if args.index_path and os.path.exists(
        os.path.join(args.index_path, "index_meta.json")
    ):
        print(f"loading AnchorIndex from {args.index_path}...")
        index = AnchorIndex.load(args.index_path)
        if index.n_items != args.n_items:
            print(f"  index holds {index.n_items} items; overriding "
                  f"--n-items {args.n_items} to match")
            args.n_items = index.n_items

    print(f"building synthetic CE domain (|I|={args.n_items})...")
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=600, n_items=args.n_items)

    if index is None:
        print("building AnchorIndex (block-streamed, resumable)...")
        index = AnchorIndex.build(
            ce.score_block, jnp.arange(500), jnp.arange(args.n_items),
            block_rows=128, checkpoint_dir=args.index_path,
        )
        if args.index_path:
            index.save(args.index_path)
            # the committed artifact supersedes the row-block checkpoints
            clear_build_checkpoints(args.index_path)
            print(f"saved AnchorIndex to {args.index_path}")

    cfg = AdaCURConfig(
        k_anchor=args.budget // 2, n_rounds=args.rounds, budget_ce=args.budget,
        strategy="topk", k_retrieve=100, loop_mode="fori",
        use_fused_topk=args.fused, payload_dtype=args.payload_dtype,
        round_kernel=args.round_kernel,
    )
    if args.payload_dtype != "float32":
        fp32_bytes = index.payload_nbytes
        index = index.quantize(args.payload_dtype, tile=cfg.payload_tile)
        print(f"payload {args.payload_dtype}: {index.payload_nbytes / 1e6:.1f} MB "
              f"(fp32 would be {fp32_bytes / 1e6:.1f} MB)")
    if args.mesh:
        index = _shard_for_serving(index, args)
    from ..core.scorer import CachingScorer, SyntheticScorer, TabulatedScorer

    if args.cache:
        # caching requires a host-backed scorer; tabulate the synthetic CE
        m = ce.full_matrix(jnp.arange(600))
        score_fn = CachingScorer(TabulatedScorer(np.asarray(m)))
    else:
        score_fn = SyntheticScorer(ce)
    if args.first_stage != "none":
        if args.retriever != "adacur":
            raise SystemExit(
                "--first-stage composes the hybrid on top of ADACUR; use "
                "--retriever adacur (rerank already IS a first-stage method)"
            )
        from ..core.candidates import (
            BM25Candidates, DualEncoderCandidates, HybridRetriever,
        )

        if args.first_stage == "de":
            generator = DualEncoderCandidates(
                ce.q_emb, ce.i_emb, n_valid=index.n_items
            )
        else:
            from ..data.synthetic import lexical_signatures

            generator = BM25Candidates(
                lexical_signatures(ce.i_emb, seed=3),
                lexical_signatures(ce.q_emb, seed=3),
                n_valid=index.n_items,
            )
        shortlist = min(4 * cfg.budget_ce, index.n_items)
        retriever = HybridRetriever(
            score_fn=score_fn, generator=generator, cfg=cfg, index=index,
            shortlist_k=shortlist, mode="mask",
        )
        print(f"first stage: {args.first_stage} shortlist_k={shortlist} "
              f"(CE budget restricted to each query's candidates)")
    else:
        retriever = make_retriever(args.retriever, index, score_fn, cfg)
    candidate_fn = None
    if args.retriever == "rerank":
        # stand-in first-stage retriever: dual-encoder dot-product order
        def candidate_fn(qids):
            scores = ce.q_emb[qids] @ ce.i_emb.T
            _, order = jax.lax.top_k(scores, cfg.budget_ce)
            return order

    svc = AdaCURService(
        retriever=retriever, max_batch=args.batch, candidate_fn=candidate_fn
    )
    _drive(svc, args, cfg, brute_n=args.n_items)


def _shard_for_serving(index: AnchorIndex, args) -> AnchorIndex:
    """``--mesh DxI`` -> place the index over a (data x items) mesh; the
    retriever then auto-binds the SPMD engine (engine.make_sharded_engine)."""
    from .mesh import make_serving_mesh

    try:
        d, i = (int(x) for x in args.mesh.lower().split("x"))
    except ValueError as e:
        raise SystemExit(f"--mesh must be DATAxITEMS (e.g. 2x4): {e}")
    n_dev = len(jax.devices())
    if d * i != n_dev:
        raise SystemExit(
            f"--mesh {args.mesh} needs {d * i} devices but jax sees {n_dev}; "
            "on CPU export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{d * i}"
        )
    if args.batch % (4 * d):
        raise SystemExit(
            f"--batch {args.batch} must divide into the service's batch "
            f"buckets over {d} data shards (make it a multiple of {4 * d})"
        )
    mesh = make_serving_mesh(d, i)
    print(f"sharding index over mesh {dict(mesh.shape)} "
          f"(payload per item-shard ~{index.payload_nbytes // i / 1e6:.1f} MB)")
    return index.shard(mesh)


def _drive(svc: AdaCURService, args, cfg: AdaCURConfig,
           qid_range=(500, 600), label: Optional[str] = None,
           brute_n: Optional[int] = None) -> None:
    served = []
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        qid = int(rng.integers(*qid_range))
        served += svc.submit(RetrievalRequest(query_id=qid)) or []
        served += svc.poll()   # the event loop's deadline sweep
    served += svc.flush()
    lat = np.array([r.latency_s for r in served])
    ratio = (
        f" | {cfg.budget_ce} CE calls/request (vs {brute_n} brute force = "
        f"{brute_n / cfg.budget_ce:.0f}x fewer)"
        if brute_n else ""
    )
    print(
        f"[{label or args.retriever}] served {len(served)} requests | "
        f"p50={np.percentile(lat, 50)*1e3:.1f}ms "
        f"p99={np.percentile(lat, 99)*1e3:.1f}ms{ratio}"
    )
    stats = svc.scorer_stats
    if stats is not None:
        print(
            f"measured: {stats.ce_calls} CE calls, {stats.cache_hits} cache "
            f"hits ({stats.cache_size} resident pairs)"
        )


def _serve_real_ce(args) -> None:
    """End-to-end serving with the REAL transformer cross-encoder: offline
    index built by the bulk CE path, online scoring through the bucketed
    flash-attention CrossEncoderScorer (+ optional pair cache) — or, under
    ``--mesh``, through the device-resident DeviceCEScorer stage of the
    SPMD engine (the index carries the corpus token table)."""
    from ..configs.base import replace as cfg_replace
    from ..configs.registry import CE_TINY
    from ..core.scorer import CachingScorer, CrossEncoderScorer, DeviceCEScorer
    from ..data.synthetic import make_zeshel_like
    from ..models import cross_encoder

    if args.mesh and args.cache:
        raise SystemExit(
            "--cache intercepts host-callback scorers; under --mesh the real "
            "CE scores device-resident inside the shard_map program and its "
            "pairs never cross the host boundary — drop --cache"
        )

    n_items = min(args.n_items, 500)       # CE-scored corpus: keep CPU-friendly
    n_anchor_q, n_serve_q = 100, 100
    print(f"building ZESHEL-like corpus (|I|={n_items}) + tiny transformer CE...")
    ds = make_zeshel_like(0, n_items=n_items, n_queries=n_anchor_q + n_serve_q,
                          item_len=24, query_len=16)
    lm_cfg = cfg_replace(
        CE_TINY, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=ds.vocab_size, dtype="float32", remat=False,
    )
    params, _ = cross_encoder.init_cross_encoder(jax.random.PRNGKey(0), lm_cfg)
    scorer = CrossEncoderScorer(
        params, lm_cfg, ds.pair_tokens, micro_batch=64, flash_block=(64, 64)
    )
    if args.cache:
        scorer = CachingScorer(scorer)

    print("building AnchorIndex from the CE itself (block-streamed)...")

    def bulk(q_ids, item_ids):
        q = np.asarray(q_ids)
        items = np.tile(np.asarray(item_ids), (len(q), 1))
        inner = scorer.inner if args.cache else scorer
        return jnp.asarray(inner._host(q, items))

    index = AnchorIndex.build(
        bulk, jnp.arange(n_anchor_q), jnp.arange(n_items), block_rows=32,
    )
    scorer.reset_stats()      # offline-build calls are not serving cost
    cfg = AdaCURConfig(
        k_anchor=args.budget // 2, n_rounds=args.rounds, budget_ce=args.budget,
        strategy="topk", k_retrieve=50, loop_mode="fori",
        use_fused_topk=args.fused, payload_dtype=args.payload_dtype,
        round_kernel=args.round_kernel,
    )
    if args.mesh:
        # device-resident CE: the token table rides on the index (sharded
        # over the items axis with the payload) and the serving scorer
        # assembles + scores pairs inside the SPMD program
        serve_scorer = DeviceCEScorer(
            params, lm_cfg,
            query_token_fn=lambda q: np.asarray(ds.query_tokens)[q],
            flash_block=(64, 64),
        )
        index = index.with_item_tokens(ds.item_tokens)
        index = _shard_for_serving(index, args)
    else:
        serve_scorer = scorer
    retriever = make_retriever(args.retriever, index, serve_scorer, cfg)
    svc = AdaCURService(retriever=retriever, max_batch=args.batch)
    _drive(svc, args, cfg,
           qid_range=(n_anchor_q, n_anchor_q + n_serve_q),
           label=f"real-ce/{args.retriever}" + ("/mesh" if args.mesh else ""))
    if args.mesh:
        print(f"device-resident CE: {serve_scorer.n_traces} in-trace forwards "
              f"compiled (stable across batches); "
              f"{serve_scorer.stats.batch_pad} item-shard pad rows excluded "
              f"from {serve_scorer.stats.ce_calls} measured CE calls")
    else:
        inner = scorer.inner if args.cache else scorer
        print(f"compiled CE shapes: {inner.n_traces} (static buckets — no "
              f"retraces); {inner.stats.batch_pad} padded micro-batch rows")


if __name__ == "__main__":
    main()

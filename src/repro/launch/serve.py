"""ADACUR retrieval service: batched request serving over a CE scorer.

The production serving loop the paper's technique plugs into:

- an offline ``R_anc`` index (built by repro.core.index, checkpointed);
- any :class:`repro.core.engine.Retriever` behind the unified search API —
  the default is :class:`AdaCURRetriever` on the static-shape round engine
  (``loop_mode='fori'``), so per-batch round-count overrides do not retrace;
- request batching: queries accumulate to a batch or a deadline.  Batches
  fire from ``submit`` when full/overdue AND from ``poll`` — an idle queue
  with one straggler request is flushed by the event loop's periodic
  ``poll`` even if no further request ever arrives;
- per-request k-NN results with exact CE scores.

CLI:  PYTHONPATH=src python -m repro.launch.serve --requests 64
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig
from ..core.engine import AdaCURRetriever, Retriever


@dataclass
class RetrievalRequest:
    query_id: int
    arrival_t: float = field(default_factory=time.monotonic)


@dataclass
class RetrievalResponse:
    query_id: int
    item_ids: np.ndarray
    scores: np.ndarray
    latency_s: float
    ce_calls: int


class AdaCURService:
    """Batched retrieval over a fixed item corpus via any Retriever."""

    def __init__(
        self,
        score_fn: Optional[Callable] = None,
        r_anc: Optional[jax.Array] = None,
        cfg: Optional[AdaCURConfig] = None,
        max_batch: int = 32,
        max_wait_s: float = 0.01,
        seed: int = 0,
        retriever: Optional[Retriever] = None,
    ):
        if retriever is None:
            if score_fn is None or r_anc is None or cfg is None:
                raise ValueError("need (score_fn, r_anc, cfg) or a retriever")
            retriever = AdaCURRetriever(score_fn, r_anc, cfg)
        self.retriever = retriever
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self._key = jax.random.PRNGKey(seed)
        self._pending: List[RetrievalRequest] = []

    def _due(self) -> bool:
        if not self._pending:
            return False
        oldest = self._pending[0].arrival_t
        return (
            len(self._pending) >= self.max_batch
            or time.monotonic() - oldest >= self.max_wait_s
        )

    def submit(self, req: RetrievalRequest) -> Optional[List[RetrievalResponse]]:
        """Queue a request; returns responses when a batch fires."""
        self._pending.append(req)
        return self.flush() if self._due() else None

    def poll(self) -> List[RetrievalResponse]:
        """Deadline check for stragglers: flush if the oldest queued request
        has waited past ``max_wait_s``.  Call from the serving event loop —
        without this, a lone queued request was only served when *another*
        request happened to arrive."""
        return self.flush() if self._due() else []

    def flush(self) -> List[RetrievalResponse]:
        if not self._pending:
            return []
        batch, self._pending = self._pending[: self.max_batch], self._pending[self.max_batch :]
        qids = jnp.asarray([r.query_id for r in batch])
        self._key, sub = jax.random.split(self._key)
        res = self.retriever.search(qids, sub)
        res = jax.block_until_ready(res)
        out = []
        for i, r in enumerate(batch):
            out.append(
                RetrievalResponse(
                    query_id=r.query_id,
                    item_ids=np.asarray(res.topk_idx[i]),
                    scores=np.asarray(res.topk_scores[i]),
                    latency_s=time.monotonic() - r.arrival_t,
                    ce_calls=res.ce_calls,
                )
            )
        return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--n-items", type=int, default=10000)
    ap.add_argument("--budget", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--fused", action="store_true",
                    help="fused Pallas score->top-k sampling")
    args = ap.parse_args()

    from ..data.synthetic import make_synthetic_ce

    print(f"building synthetic CE domain (|I|={args.n_items}) + R_anc index...")
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=600, n_items=args.n_items)
    r_anc = ce.full_matrix(jnp.arange(500))

    cfg = AdaCURConfig(
        k_anchor=args.budget // 2, n_rounds=args.rounds, budget_ce=args.budget,
        strategy="topk", k_retrieve=100, loop_mode="fori",
        use_fused_topk=args.fused,
    )
    svc = AdaCURService(ce.score_fn(), r_anc, cfg, max_batch=args.batch)

    served = []
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        qid = int(rng.integers(500, 600))
        served += svc.submit(RetrievalRequest(query_id=qid)) or []
        served += svc.poll()   # the event loop's deadline sweep
    served += svc.flush()
    lat = np.array([r.latency_s for r in served])
    print(
        f"served {len(served)} requests | p50={np.percentile(lat, 50)*1e3:.1f}ms "
        f"p99={np.percentile(lat, 99)*1e3:.1f}ms | "
        f"{cfg.budget_ce} CE calls/request (vs {args.n_items} brute force = "
        f"{args.n_items / cfg.budget_ce:.0f}x fewer)"
    )


if __name__ == "__main__":
    main()

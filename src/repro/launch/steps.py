"""Step builders: one (jit-able fn, input specs, shardings) per dry-run cell.

Every assigned (architecture x shape) pair maps to exactly one entry here:

  LM      train_4k      -> train_step   (loss+grad+AdamW, FSDP+TP, remat+scan)
          prefill_32k   -> serve_prefill (flash path, returns KV cache)
          decode_32k    -> serve_decode  (sequence-parallel KV, LSE combine)
          long_500k     -> serve_decode  (cache sharded over ALL axes, batch=1)
  GNN     full_*/minibatch/molecule -> train_step (segment_sum MP, edge-sharded)
  RecSys  train_batch   -> train_step   (row-sharded tables)
          serve_p99/bulk-> serve_step   (forward scoring)
          retrieval_cand-> ADACUR retrieval step (the paper's technique at
                           1M-item scale) — MIND uses its native DE retrieval

Params are never materialized for the dry-run: ``abstract_state`` trees come
from jax.eval_shape and carry NamedShardings from the logical-axis rules.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs import registry
from ..configs.base import AdaCURConfig, GNNConfig, LMConfig, RecSysConfig
from ..configs.shapes import GraphShape, LMShape, RecSysShape
from ..core import adacur
from ..distributed import decode_attention, sharding
from ..models import moe as moe_lib, transformer
from ..models.gnn import nequip
from ..models.recsys import bert4rec, bst, dlrm, mind
from ..training import optimizer
from ..compat import shard_map

F32, I32, BF16 = jnp.float32, jnp.int32, jnp.bfloat16


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run needs for one cell."""

    name: str
    step: Callable
    abstract_args: Tuple          # positional ShapeDtypeStructs
    in_shardings: Tuple
    out_shardings: Any            # None -> GSPMD-propagated
    model_flops: float            # analytic 6·N·D (or family equivalent)
    notes: str = ""
    donate: Tuple[int, ...] = ()  # donated args (train state, decode cache)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _even(mesh: Mesh, dim: int, axes) -> Any:
    """axes if dim divides evenly over them, else replicated."""
    if axes is None:
        return None
    axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
    size = 1
    for a in axes_t:
        size *= mesh.shape[a]
    return axes if dim % size == 0 else None


def _shardify(mesh, tree_specs):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _abstract_params(init_fn, mesh, rules=None):
    """(abstract params, PartitionSpec tree) without materializing.

    init_fn returns (params, logical-axis specs); the specs are static
    strings, so they are smuggled out of the eval_shape trace via a box."""
    spec_box = {}

    def only_params():
        params, logical = init_fn()
        spec_box["s"] = logical
        return params

    a_params = jax.eval_shape(only_params)
    specs = sharding.tree_specs(mesh, a_params, spec_box["s"], rules)
    return a_params, specs


# ===========================================================================
# LM family
# ===========================================================================


def _chunked_nll(params, h, targets, cfg: LMConfig, mesh: Mesh, chunk: int = 512):
    """Cross-entropy over sequence chunks with vocab-sharded logits.

    The full (B, L, V) logits never materialize: each chunk is checkpointed
    (backward recomputes its logits from h) and the model axis stays on the
    VOCAB dim inside the loss region — measured 10 GB/device of f32 logits
    on qwen1.5-110b otherwise."""
    bp = sharding.batch_axes(mesh)
    b, l, d = h.shape
    chunk = min(chunk, l)
    n = l // chunk
    # loss-region layout: d_model on the model axis (contracted by the head)
    h = jax.lax.with_sharding_constraint(h, P(bp, None, _even(mesh, d, "model")))
    hs = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(b, n, chunk), 1, 0)

    @jax.checkpoint
    def one(hc, tc):
        logits = transformer.lm_logits(params, hc, cfg)
        pv = logits.shape[-1]
        logits = jax.lax.with_sharding_constraint(
            logits, P(bp, None, _even(mesh, pv, "model"))
        )
        logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
        return -jnp.take_along_axis(logp, tc[..., None], axis=-1).sum()

    def body(carry, xs):
        hc, tc = xs
        return carry + one(hc, tc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), F32), (hs, ts))
    return total / (b * l)


def _lm_loss_fn(cfg: LMConfig, moe_fn, mesh: Mesh, act_spec=None, attn_spec=None):
    def loss_fn(params, batch):
        h, aux = transformer.encode(
            params, batch["tokens"], cfg, moe_fn=moe_fn,
            act_spec=act_spec, attn_spec=attn_spec,
        )
        loss = _chunked_nll(params, h, batch["targets"], cfg, mesh)
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_coef * aux
        return loss

    return loss_fn


def build_lm_train(arch_id: str, cfg: LMConfig, shape: LMShape, mesh: Mesh) -> StepBundle:
    bp = sharding.batch_axes(mesh)
    n_tok_local = shape.global_batch * shape.seq_len
    for a in bp:
        n_tok_local //= mesh.shape[a]
    moe_fn = (
        moe_lib.make_moe_fn(
            mesh, cfg.moe, bp,
            # reduce-scatter the MoE combine straight into the seq-sharded
            # residual layout (perf iteration, EXPERIMENTS.md §Perf)
            scatter_tokens=n_tok_local % mesh.shape["model"] == 0,
        )
        if cfg.moe is not None else None
    )
    opt_cfg = optimizer.AdamWConfig()
    # Megatron sequence sharding of the residual stream (see _encode_layer);
    # attention internals shard by heads instead.
    act_spec = P(bp, _even(mesh, shape.seq_len, "model"), None)
    attn_spec = P(bp, None, _even(mesh, cfg.n_heads, "model"), None)
    loss_fn = _lm_loss_fn(cfg, moe_fn, mesh, act_spec, attn_spec)
    init = lambda: transformer.init_lm(jax.random.PRNGKey(0), cfg)
    # models under ~2B params skip FSDP (TP-only): the per-step param
    # all-gathers outweigh the modest per-device replication cost
    rules = None
    if cfg.n_params() < 2e9:
        rules = dict(sharding.DEFAULT_RULES)
        rules["embed"] = (None,)
    a_params, p_specs = _abstract_params(init, mesh, rules)
    # gradient accumulation for the largest models: token-proportional
    # activation temps (remat carries, attention chunks) scale 1/n_micro
    n_micro = 4 if cfg.n_params() > 4e10 else 1

    def step(params, opt_state, batch):
        if n_micro > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
                batch,
            )
            grads, loss = optimizer.accumulate_grads(
                lambda p, m: loss_fn(p, m), params, mb, n_micro
            )
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # pin gradient layouts to the param shardings — otherwise the embed
        # scatter-add grad materializes the FULL table per device
        grads = jax.lax.with_sharding_constraint(grads, p_specs)
        params, opt_state, metrics = optimizer.adamw_update(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, {"loss": loss, **metrics}
    a_opt = jax.eval_shape(optimizer.init_adamw, a_params)
    o_specs = optimizer.AdamWState(P(), p_specs, jax.tree.map(lambda s: s, p_specs))
    b, l = shape.global_batch, shape.seq_len
    batch_sds = {"tokens": _sds((b, l), I32), "targets": _sds((b, l), I32)}
    batch_spec = {"tokens": P(bp, None), "targets": P(bp, None)}
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=step,
        abstract_args=(a_params, a_opt, batch_sds),
        in_shardings=tuple(
            _shardify(mesh, s) for s in (p_specs, o_specs, batch_spec)
        ),
        out_shardings=(
            _shardify(mesh, p_specs), _shardify(mesh, o_specs), None
        ),
        model_flops=6.0 * cfg.n_active_params() * b * l,
        donate=(0, 1),
    )


def build_lm_prefill(arch_id: str, cfg: LMConfig, shape: LMShape, mesh: Mesh) -> StepBundle:
    bp = sharding.batch_axes(mesh)
    moe_fn = (
        moe_lib.make_moe_fn(mesh, cfg.moe, bp) if cfg.moe is not None else None
    )

    act_spec = P(_even(mesh, shape.global_batch, bp), _even(mesh, shape.seq_len, "model"), None)

    def step(params, tokens):
        h, _, (prefix_kv, scan_kv) = transformer.encode(
            params, tokens, cfg, moe_fn=moe_fn, return_kv=True, act_spec=act_spec
        )
        last = transformer.lm_logits(params, h[:, -1:, :], cfg)[:, 0]
        cache = {"k": scan_kv[0], "v": scan_kv[1]}
        if prefix_kv:
            cache["prefix"] = [{"k": k, "v": v} for (k, v) in prefix_kv]
        return last, cache

    init = lambda: transformer.init_lm(jax.random.PRNGKey(0), cfg)
    a_params, p_specs = _abstract_params(init, mesh)
    b, l = shape.global_batch, shape.seq_len
    tokens = _sds((b, l), I32)
    # cache out: seq sharded over model (matches the decode layout)
    kv_spec = P(None, _even(mesh, b, bp), None, _even(mesh, l, "model"), None)
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=step,
        abstract_args=(a_params, tokens),
        in_shardings=(
            _shardify(mesh, p_specs),
            NamedSharding(mesh, P(_even(mesh, b, bp), None)),
        ),
        out_shardings=None,
        model_flops=2.0 * cfg.n_active_params() * b * l,
    )


def build_lm_decode(arch_id: str, cfg: LMConfig, shape: LMShape, mesh: Mesh) -> StepBundle:
    b, s = shape.global_batch, shape.seq_len
    bp = sharding.batch_axes(mesh)
    if shape.name == "long_500k":
        batch_axes: Tuple[str, ...] = ()
        seq_axes = bp + ("model",)        # cache sharded over EVERYTHING
    else:
        batch_axes = tuple(a for a in bp if b % mesh.shape[a] == 0)
        seq_axes = ("model",)
    decode_core = decode_attention.make_decode_core(mesh, batch_axes, seq_axes, s)
    moe_fn = (
        moe_lib.make_moe_fn(mesh, cfg.moe, batch_axes) if cfg.moe is not None else None
    )

    def step(params, cache, token, pos):
        return transformer.decode_step(
            params, cache, token, pos, cfg, moe_fn=moe_fn, decode_core=decode_core
        )

    init = lambda: transformer.init_lm(jax.random.PRNGKey(0), cfg)
    a_params, p_specs = _abstract_params(init, mesh)
    a_cache = jax.eval_shape(lambda: transformer.init_cache(cfg, b, s))
    bspec = tuple(batch_axes) if batch_axes else None
    sspec = tuple(seq_axes)
    stacked = P(None, bspec, sspec, None, None)
    prefix = P(bspec, sspec, None, None)
    cache_spec = jax.tree.map(lambda _: prefix, a_cache)
    cache_spec["k"] = stacked
    cache_spec["v"] = stacked
    token = _sds((b,), I32)
    pos = _sds((), I32)
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=step,
        abstract_args=(a_params, a_cache, token, pos),
        in_shardings=(
            _shardify(mesh, p_specs),
            _shardify(mesh, cache_spec),
            NamedSharding(mesh, P(bspec)),
            NamedSharding(mesh, P()),
        ),
        out_shardings=(None, _shardify(mesh, cache_spec)),
        model_flops=2.0 * cfg.n_active_params() * b,
        notes=f"seq-parallel KV over {seq_axes}",
        donate=(1,),
    )


# ===========================================================================
# GNN family (NequIP)
# ===========================================================================


def _gnn_batch(cfg: GNNConfig, shape: GraphShape, mesh: Mesh,
               receiver_partitioned: bool = False):
    """(abstract batch, shardings, n_graphs).

    ``receiver_partitioned``: edges sharded on the SAME axis as nodes (the
    graph-partitioning contract the sharded interact requires)."""
    all_axes = tuple(mesh.axis_names)
    if shape.kind == "molecule":
        g = shape.batch_graphs
        n = g * shape.n_nodes
        e = g * shape.n_edges
        n_graphs = g
    elif shape.kind == "minibatch":
        # padded fanout subgraph (1024 seeds, fanout 15-10) — static shapes
        n = e = 196608
        n_graphs = 1
    else:
        n, e = shape.n_nodes, shape.n_edges
        n_graphs = 1
    # pad node/edge buffers to a shardable multiple (jraph-style): real graph
    # sizes (e.g. ogb 61,859,140 edges) divide over no mesh axis, which
    # otherwise forces full replication — 2.2 TB/device of edge messages.
    n = (n + 511) // 512 * 512
    e = (e + 511) // 512 * 512
    node_ax = _even(mesh, n, ("data",))
    edge_ax = (
        _even(mesh, e, ("data",)) if receiver_partitioned
        else _even(mesh, e, all_axes)
    )
    batch = {
        "positions": _sds((n, 3), F32),
        "node_attr": _sds((n, shape.d_feat), F32) if shape.d_feat else _sds((n,), I32),
        "senders": _sds((e,), I32),
        "receivers": _sds((e,), I32),
        "edge_mask": _sds((e,), F32),
        "node_mask": _sds((n,), F32),
        "energy": _sds((n_graphs,), F32),
    }
    if shape.kind == "molecule":
        batch["graph_ids"] = _sds((n,), I32)
    specs = {
        "positions": P(node_ax, None),
        "node_attr": P(node_ax, None) if shape.d_feat else P(node_ax),
        "senders": P(edge_ax),
        "receivers": P(edge_ax),
        "edge_mask": P(edge_ax),
        "node_mask": P(node_ax),
        "energy": P(_even(mesh, n_graphs, sharding.batch_axes(mesh))),
    }
    if shape.kind == "molecule":
        specs["graph_ids"] = P(node_ax)
    return batch, specs, n_graphs


def build_gnn_train(arch_id: str, cfg: GNNConfig, shape: GraphShape, mesh: Mesh) -> StepBundle:
    opt_cfg = optimizer.AdamWConfig(lr=1e-3)
    n_graphs_holder = {}
    # pod-scale graphs: receiver-partitioned edges + shard_map interact so
    # the scatter-add never leaves the node shard (see nequip module docs)
    big = shape.n_nodes > 100_000
    interact_fn = nequip.make_sharded_interact(mesh, "data") if big else None

    def loss_fn(params, batch):
        # remat=False deliberately: with channel-TP interact the saved
        # gathered tables are small, and remat's backward RE-gathers cost
        # 3 GB of extra all-gather traffic (235 -> 175 ms collective term;
        # EXPERIMENTS.md §Perf)
        return nequip.energy_mse_loss(
            params, cfg, batch, n_graphs=n_graphs_holder["n"],
            interact_fn=interact_fn, remat=False,
        )

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = optimizer.adamw_update(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, {"loss": loss, **metrics}

    init = lambda: nequip.init_nequip(jax.random.PRNGKey(0), cfg, d_feat=shape.d_feat)
    a_params, p_specs = _abstract_params(init, mesh)   # tiny -> replicated
    a_opt = jax.eval_shape(optimizer.init_adamw, a_params)
    o_specs = optimizer.AdamWState(P(), p_specs, jax.tree.map(lambda s: s, p_specs))
    batch_sds, batch_spec, n_graphs = _gnn_batch(
        cfg, shape, mesh, receiver_partitioned=big
    )
    n_graphs_holder["n"] = n_graphs
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=step,
        abstract_args=(a_params, a_opt, batch_sds),
        in_shardings=tuple(
            _shardify(mesh, s) for s in (p_specs, o_specs, batch_spec)
        ),
        out_shardings=(
            _shardify(mesh, p_specs), _shardify(mesh, o_specs), None
        ),
        # per-edge TP message cost dominates: ~(paths * irrep_dim * h) MACs/edge
        model_flops=2.0 * batch_sds["senders"].shape[0] * 11 * 9 * cfg.d_hidden
        * cfg.n_layers,
        notes=f"{shape.kind}, segment_sum message passing",
        donate=(0, 1),
    )


# ===========================================================================
# RecSys family
# ===========================================================================


def _recsys_init(arch_id: str, cfg: RecSysConfig):
    key = jax.random.PRNGKey(0)
    if cfg.kind == "dlrm":
        return lambda: dlrm.init_dlrm(key, cfg)
    if cfg.kind == "bst":
        return lambda: bst.init_bst(key, cfg)
    if cfg.kind == "bert4rec":
        return lambda: bert4rec.init_bert4rec(key, cfg)
    if cfg.kind == "mind":
        return lambda: mind.init_mind(key, cfg)
    raise KeyError(cfg.kind)


def _recsys_inputs(cfg: RecSysConfig, batch: int, mesh: Mesh, train: bool):
    bp = sharding.batch_axes(mesh)
    bax = _even(mesh, batch, bp)
    if cfg.kind == "dlrm":
        sds = {
            "dense": _sds((batch, cfg.n_dense), F32),
            "sparse": _sds((batch, cfg.n_sparse), I32),
        }
        spec = {"dense": P(bax, None), "sparse": P(bax, None)}
    else:
        sds = {"history": _sds((batch, cfg.seq_len), I32)}
        spec = {"history": P(bax, None)}
        if cfg.kind in ("bst",):
            sds["target"] = _sds((batch,), I32)
            spec["target"] = P(bax)
        if cfg.kind == "bert4rec" and not train:
            sds["target"] = _sds((batch,), I32)
            spec["target"] = P(bax)
    if train:
        if cfg.kind in ("dlrm", "bst"):
            sds["labels"] = _sds((batch,), F32)
            spec["labels"] = P(bax)
        if cfg.kind == "bert4rec":
            sds["target"] = _sds((batch,), I32)
            spec["target"] = P(bax)
        if cfg.kind == "mind":
            sds["target"] = _sds((batch,), I32)
            spec["target"] = P(bax)
            sds["neg_ids"] = _sds((batch, 64), I32)
            spec["neg_ids"] = P(bax, None)
    return sds, spec


def _recsys_loss(cfg: RecSysConfig):
    if cfg.kind == "dlrm":
        return lambda p, b: dlrm.bce_loss(p, b["dense"], b["sparse"], b["labels"], cfg)
    if cfg.kind == "bst":
        return lambda p, b: bst.bce_loss(p, b["history"], b["target"], b["labels"], cfg)
    if cfg.kind == "bert4rec":
        return lambda p, b: bert4rec.mlm_loss(p, b["history"], b["target"], cfg)
    if cfg.kind == "mind":
        return lambda p, b: mind.sampled_softmax_loss(
            p, b["history"], b["target"], b["neg_ids"], cfg
        )
    raise KeyError(cfg.kind)


def _recsys_forward(cfg: RecSysConfig, mesh: Optional[Mesh] = None):
    if cfg.kind == "dlrm":
        return lambda p, b: dlrm.forward(p, b["dense"], b["sparse"], cfg)
    if cfg.kind == "bst":
        return lambda p, b: bst.forward(p, b["history"], b["target"], cfg)
    if cfg.kind == "bert4rec":
        return lambda p, b: bert4rec.score_candidates(
            p, b["history"], b["target"][:, None], cfg
        )[:, 0]
    if cfg.kind == "mind":
        if mesh is None:
            return lambda p, b: mind.retrieve(p, b["history"], 100, cfg)
        # XLA's TopK partitioner all-gathers batch-sharded operands (a
        # 17 GB/device buffer at serve_bulk scale) — run the whole tiled
        # retrieval under shard_map so every top_k is shard-local; the only
        # resharding is one broadcast of the (256 MB) item table.
        bspec = sharding.batch_axes(mesh)

        def fwd(p, b):
            pspec = jax.tree.map(lambda _: P(), p)
            return shard_map(
                lambda pl, h: mind.retrieve(pl, h, 100, cfg),
                mesh=mesh,
                in_specs=(pspec, P(bspec, None)),
                out_specs=(P(bspec, None), P(bspec, None)),
                check_vma=False,
            )(p, b["history"])

        return fwd
    raise KeyError(cfg.kind)


def _recsys_flops(cfg: RecSysConfig, batch: int) -> float:
    if cfg.kind == "dlrm":
        mlp = sum(a * b for a, b in zip(cfg.bot_mlp[:-1], cfg.bot_mlp[1:]))
        n = cfg.n_sparse + 1
        mlp += (n * (n - 1) // 2 + cfg.bot_mlp[-1]) * cfg.top_mlp[1]
        mlp += sum(a * b for a, b in zip(cfg.top_mlp[1:-1], cfg.top_mlp[2:]))
        inter = n * n * cfg.embed_dim
        return 2.0 * batch * (mlp + inter)
    d, L = cfg.embed_dim, cfg.seq_len
    attn = cfg.n_blocks * (4 * L * d * d + 2 * L * L * d)
    ffn = cfg.n_blocks * 2 * L * d * (cfg.mlp_dims[0] if cfg.mlp_dims else 4 * d)
    head = sum(
        a * b
        for a, b in zip(
            (d * (L + 1),) + tuple(cfg.mlp_dims), tuple(cfg.mlp_dims) + (1,)
        )
    ) if cfg.kind == "bst" else d * d
    return 2.0 * batch * (attn + ffn + head)


def build_recsys_train(arch_id: str, cfg: RecSysConfig, shape: RecSysShape, mesh: Mesh) -> StepBundle:
    opt_cfg = optimizer.AdamWConfig(lr=1e-3, weight_decay=0.0)
    loss_fn = _recsys_loss(cfg)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = optimizer.adamw_update(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, {"loss": loss, **metrics}

    a_params, p_specs = _abstract_params(_recsys_init(arch_id, cfg), mesh)
    a_opt = jax.eval_shape(optimizer.init_adamw, a_params)
    o_specs = optimizer.AdamWState(P(), p_specs, jax.tree.map(lambda s: s, p_specs))
    sds, spec, = _recsys_inputs(cfg, shape.batch, mesh, train=True)
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=step,
        abstract_args=(a_params, a_opt, sds),
        in_shardings=tuple(_shardify(mesh, s) for s in (p_specs, o_specs, spec)),
        out_shardings=(_shardify(mesh, p_specs), _shardify(mesh, o_specs), None),
        model_flops=3.0 * _recsys_flops(cfg, shape.batch),  # fwd+bwd ≈ 3x fwd
        donate=(0, 1),
    )


def build_recsys_serve(arch_id: str, cfg: RecSysConfig, shape: RecSysShape, mesh: Mesh) -> StepBundle:
    fwd = _recsys_forward(cfg, mesh)

    def step(params, batch):
        return fwd(params, batch)

    a_params, p_specs = _abstract_params(_recsys_init(arch_id, cfg), mesh)
    sds, spec = _recsys_inputs(cfg, shape.batch, mesh, train=False)
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=step,
        abstract_args=(a_params, sds),
        in_shardings=(_shardify(mesh, p_specs), _shardify(mesh, spec)),
        out_shardings=None,
        model_flops=_recsys_flops(cfg, shape.batch),
    )


def build_recsys_retrieval(arch_id: str, cfg: RecSysConfig, shape: RecSysShape, mesh: Mesh) -> StepBundle:
    """The paper's technique at scale: ADACUR over 1M candidates.

    MIND (dual-encoder) uses its native all-item GEMM retrieval instead —
    DESIGN.md §4.1 — and doubles as ADACUR's first-round retriever."""
    n_cand = shape.n_candidates
    b = shape.batch
    k_q = 500
    all_axes = tuple(mesh.axis_names)
    # pad the candidate axis to a shardable multiple: 1M columns divide over
    # no mesh axis, which otherwise REPLICATES the 2 GB R_anc on every chip
    # (measured 22.4 GB of per-device HBM reads per search)
    n_pad = (n_cand + 511) // 512 * 512
    item_ax = _even(mesh, n_pad, all_axes)

    a_params, p_specs = _abstract_params(_recsys_init(arch_id, cfg), mesh)

    if cfg.kind == "mind":
        def step(params, batch):
            return mind.retrieve(params, batch["history"], 100, cfg)

        sds = {"history": _sds((b, cfg.seq_len), I32)}
        spec = {"history": P(None, None)}
        return StepBundle(
            name=f"{arch_id}:{shape.name}",
            step=step,
            abstract_args=(a_params, sds),
            in_shardings=(_shardify(mesh, p_specs), _shardify(mesh, spec)),
            out_shardings=None,
            model_flops=2.0 * b * cfg.n_interests * cfg.embed_dim * n_cand,
            notes="dual-encoder brute retrieval (ADACUR first-round source)",
        )

    # perf note (EXPERIMENTS.md §Perf): distributed_gather=True (one-hot
    # matmul column gather) was tried and REFUTED here — after padding the
    # candidate axis, XLA's gather partitioning already avoids replicating
    # R_anc, and the one-hot path only added flops + all-gather traffic.
    acfg = AdaCURConfig(
        k_anchor=250, n_rounds=5, budget_ce=500, strategy="topk",
        split_budget=True, k_retrieve=100,
    )

    def make_step():
        def step(params, batch, key):
            if cfg.kind == "dlrm":
                def sf(q, idx):
                    return dlrm.score_candidates(params, q["dense"], q["sparse"], idx, cfg)
                query = {"dense": batch["dense"], "sparse": batch["sparse"]}
            elif cfg.kind == "bst":
                def sf(q, idx):
                    return bst.score_candidates(params, q["history"], idx, cfg)
                query = {"history": batch["history"]}
            else:  # bert4rec
                def sf(q, idx):
                    return bert4rec.score_candidates(params, q["history"], idx, cfg)
                query = {"history": batch["history"]}
            res = adacur.adacur_search(
                sf, batch["r_anc"], query, acfg, key, batch=b,
                n_valid_items=n_cand,
            )
            return res.topk_idx, res.topk_scores

        return step

    sds, spec = _recsys_inputs(cfg, b, mesh, train=False)
    sds.pop("target", None)
    spec.pop("target", None)
    sds["r_anc"] = _sds((k_q, n_pad), F32)
    spec["r_anc"] = P(None, item_ax)
    key = _sds((2,), jnp.uint32)
    return StepBundle(
        name=f"{arch_id}:{shape.name}",
        step=make_step(),
        abstract_args=(a_params, sds, key),
        in_shardings=(
            _shardify(mesh, p_specs), _shardify(mesh, spec),
            NamedSharding(mesh, P(None)),
        ),
        out_shardings=None,
        # dominant: 5 rounds of e_q @ R_anc (B,k_q)x(k_q,N) + 500 CE calls
        model_flops=2.0 * b * k_q * n_cand * acfg.n_rounds
        + _recsys_flops(cfg, acfg.budget_ce),
        notes="ADACUR multi-round retrieval (paper technique at 1M scale)",
    )


def build_lm_adacur_serve(
    arch_id: str, cfg: LMConfig, mesh: Mesh,
    n_items: int = 1_000_000, batch: int = 8,
    item_len: int = 48, query_len: int = 16, k_q: int = 500,
) -> StepBundle:
    """The paper's FULL pipeline on a pod: multi-round ADACUR retrieval where
    the exact scorer is a transformer CROSS-ENCODER from the model zoo.

    Per round, the engine's k_s exact calls become one batched CE prefill of
    (B·k_s) [CLS] query [SEP] item [SEP] sequences through the TP-sharded
    backbone; the item corpus (token table) and R_anc are row/column-sharded
    over the whole mesh.  Extra dry-run target (beyond the 40 assigned
    cells): ``--cell <lm-arch>:adacur_serve``.
    """
    from ..models import cross_encoder

    bp = sharding.batch_axes(mesh)
    all_axes = tuple(mesh.axis_names)
    n_pad = (n_items + 511) // 512 * 512
    item_ax = _even(mesh, n_pad, all_axes)
    pair_len = query_len + item_len + 3
    moe_fn = (
        moe_lib.make_moe_fn(mesh, cfg.moe, ()) if cfg.moe is not None else None
    )
    acfg = AdaCURConfig(
        k_anchor=250, n_rounds=5, budget_ce=500, strategy="topk",
        split_budget=True, k_retrieve=100,
    )

    def step(params, batch_in, key):
        corpus = batch_in["corpus_tokens"]        # (N_pad, item_len)
        queries = batch_in["query_tokens"]        # (B, query_len)

        def score_fn(q_tokens, item_idx):         # (B, Lq) x (B, K) -> (B, K)
            b, k = item_idx.shape
            items = jnp.take(corpus, item_idx.reshape(-1), axis=0)  # (B*K, Li)
            q_rep = jnp.repeat(q_tokens, k, axis=0)                  # (B*K, Lq)
            cls = jnp.full((b * k, 1), 1, jnp.int32)
            sep = jnp.full((b * k, 1), 2, jnp.int32)
            pairs = jnp.concatenate([cls, q_rep, sep, items, sep], axis=1)
            return cross_encoder.score_tokens(
                params, pairs, cfg, moe_fn=moe_fn
            ).reshape(b, k)

        res = adacur.adacur_search(
            score_fn, batch_in["r_anc"], queries, acfg, key,
            batch=batch, n_valid_items=n_items,
        )
        return res.topk_idx, res.topk_scores

    init = lambda: cross_encoder.init_cross_encoder(jax.random.PRNGKey(0), cfg)
    a_params, p_specs = _abstract_params(init, mesh)
    sds = {
        "corpus_tokens": _sds((n_pad, item_len), I32),
        "query_tokens": _sds((batch, query_len), I32),
        "r_anc": _sds((k_q, n_pad), F32),
    }
    spec = {
        "corpus_tokens": P(item_ax, None),
        "query_tokens": P(None, None),
        "r_anc": P(None, item_ax),
    }
    key = _sds((2,), jnp.uint32)
    # CE cost dominates: budget_ce prefill passes per query
    ce_flops = 2.0 * cfg.n_active_params() * batch * acfg.budget_ce * pair_len
    return StepBundle(
        name=f"{arch_id}:adacur_serve",
        step=step,
        abstract_args=(a_params, sds, key),
        in_shardings=(
            _shardify(mesh, p_specs), _shardify(mesh, spec),
            NamedSharding(mesh, P(None)),
        ),
        out_shardings=None,
        model_flops=ce_flops + 2.0 * batch * k_q * n_pad * acfg.n_rounds,
        notes="paper pipeline w/ transformer CE scorer (extra cell)",
    )


# ===========================================================================
# dispatcher
# ===========================================================================


def build_cell(arch_id: str, shape_name: str, mesh: Mesh) -> StepBundle:
    entry = registry.get(arch_id)
    if entry.family == "lm" and shape_name == "adacur_serve":
        return build_lm_adacur_serve(arch_id, entry.config, mesh)
    shape = registry.shapes_for(arch_id)[shape_name]
    if entry.family == "lm":
        cfg = entry.config
        if shape.kind == "train":
            return build_lm_train(arch_id, cfg, shape, mesh)
        if shape.kind == "prefill":
            return build_lm_prefill(arch_id, cfg, shape, mesh)
        return build_lm_decode(arch_id, cfg, shape, mesh)
    if entry.family == "gnn":
        return build_gnn_train(arch_id, entry.config, shape, mesh)
    # recsys
    cfg = entry.config
    if shape.kind == "train":
        return build_recsys_train(arch_id, cfg, shape, mesh)
    if shape.kind == "serve":
        return build_recsys_serve(arch_id, cfg, shape, mesh)
    return build_recsys_retrieval(arch_id, cfg, shape, mesh)

"""Fault-tolerant replica router over :class:`AdaCURService` replicas.

The tier above the single-process service loop: N replicas (one worker
thread each, typically over device slices or forced-host-device subsets),
fronted by a router that owns the request lifecycle end to end.  The
design contract is **zero lost requests**: every admitted request gets
exactly one terminal outcome — results, degraded results, a per-request
error, or an explicit rejection — no matter which combination of scorer
crashes, stalled replicas, and mid-flight index swaps occurs.

- **Admission control**: the router bounds total in-flight work
  (``queue_limit``); past it, requests are shed *immediately* with a
  ``REJECTED`` outcome instead of queueing into a latency collapse.
- **Deadlines, anytime**: a per-request ``deadline_s`` budget rides into
  the replica's :class:`AdaCURService` and from there into the engine's
  round loop — a budget that expires mid-search yields the provisional
  top-k of the rounds completed, flagged ``degraded`` (every ADACUR round
  boundary is a valid, if coarser, answer).
- **Hedging**: a dispatch that exceeds ``hedge_after_s`` without resolving
  is *re-dispatched* to a second replica; the first terminal response wins
  (CAS on the ticket) and the loser is dropped, so a hedged pair yields
  exactly one response.
- **Retry/backoff**: a per-request error outcome (scorer exception) is
  retried on a different replica up to ``max_retries`` times with linear
  backoff before the error goes terminal.
- **Health + quarantine**: each replica runs a
  :class:`~repro.distributed.fault_tolerance.StragglerWatchdog` over a
  *shared* fleet-wide baseline (a replica slow from its first batch is
  still flagged against its peers' median); ``patience`` consecutive
  straggler batches — or ``max_consecutive_errors`` all-error batches —
  quarantine the replica and drain its queue to healthy peers.

Deterministic failure schedules come from :class:`~repro.launch.faults.
FaultPlan` (scorer raise on call k / replica sleeps / swap at admission n),
so the chaos suite and ``benchmarks/serve_load.py`` reproduce each failure
mode exactly.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

from ..distributed.fault_tolerance import StragglerWatchdog
from .faults import FaultPlan
from .serve import AdaCURService, RetrievalRequest, RetrievalResponse

OK = "ok"
ERROR = "error"
REJECTED = "rejected"

_POISON = None  # queue sentinel for worker shutdown


@dataclass
class RouterResponse:
    """The single terminal outcome of one routed request."""

    seq: int
    query_id: int
    status: str                              # "ok" | "error" | "rejected"
    response: Optional[RetrievalResponse] = None
    replica: Optional[int] = None            # replica whose answer won
    attempts: int = 0                        # dispatches issued (0 = rejected)
    hedged: bool = False                     # a hedge dispatch was issued
    retried: bool = False                    # at least one retry was issued
    latency_s: float = 0.0

    @property
    def degraded(self) -> bool:
        return bool(self.response is not None and self.response.degraded)


class Ticket:
    """One admitted request's lifecycle.

    A ticket may be dispatched to several replicas (hedge, retry, drain);
    :meth:`resolve` is a compare-and-set — the first terminal outcome wins
    and every later one returns ``False`` and is dropped.  That single
    primitive is what makes hedged duplicate suppression and the
    zero-lost-requests contract hold.
    """

    def __init__(self, seq: int, query_id: int,
                 deadline_t: Optional[float], submit_t: float):
        self.seq = seq
        self.query_id = query_id
        self.deadline_t = deadline_t
        self.submit_t = submit_t
        self.done = threading.Event()
        self.outcome: Optional[RouterResponse] = None
        self.lock = threading.Lock()
        self.replicas_tried: List[int] = []
        self.dispatch_t: float = submit_t
        self.hedged = False
        self.failures = 0
        self.retry_at: Optional[float] = None   # backoff schedule (monitor)

    @property
    def resolved(self) -> bool:
        return self.done.is_set()

    def resolve(self, status: str, response: Optional[RetrievalResponse] = None,
                replica: Optional[int] = None) -> bool:
        with self.lock:
            if self.done.is_set():
                return False
            self.outcome = RouterResponse(
                seq=self.seq, query_id=self.query_id, status=status,
                response=response, replica=replica,
                attempts=len(self.replicas_tried),
                hedged=self.hedged, retried=self.failures > 0,
                latency_s=time.monotonic() - self.submit_t,
            )
            self.done.set()
            return True


class Replica:
    """One service + worker thread + health state behind the router."""

    def __init__(self, rid: int, service: AdaCURService):
        self.rid = rid
        self.service = service
        self.q: "queue.Queue" = queue.Queue()
        self.healthy = True
        self.step = 0
        self.consecutive_errors = 0
        self.served = 0
        self.watchdog: Optional[StragglerWatchdog] = None
        self.thread: Optional[threading.Thread] = None


class Router:
    """Admission control + dispatch + hedging + quarantine over N replicas.

    ``services`` should be independent :class:`AdaCURService` instances
    (their own retrievers/scorers — replicas must not share mutable scorer
    state).  For anytime deadlines the retrievers must be built with
    ``anytime=True``; the router passes each request's budget through
    regardless and non-anytime replicas simply serve the full search.
    """

    def __init__(
        self,
        services: Sequence[AdaCURService],
        queue_limit: int = 64,
        hedge_after_s: Optional[float] = None,
        max_retries: int = 1,
        retry_backoff_s: float = 0.01,
        max_consecutive_errors: int = 3,
        plan: Optional[FaultPlan] = None,
        swap_index_fn: Optional[Callable[[], object]] = None,
        watchdog_threshold: float = 3.0,
        watchdog_window: int = 40,
        watchdog_patience: int = 2,
        monitor_interval_s: float = 0.002,
    ):
        if not services:
            raise ValueError("need at least one replica service")
        self.queue_limit = queue_limit
        self.hedge_after_s = hedge_after_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.max_consecutive_errors = max_consecutive_errors
        self.plan = plan
        self.swap_index_fn = swap_index_fn
        self.monitor_interval_s = monitor_interval_s

        self._lock = threading.Lock()
        self._seq = 0
        self._admitted = 0
        self._live: Dict[int, Ticket] = {}
        self._running = True
        self.stats: Dict[str, int] = {
            "submitted": 0, "admitted": 0, "rejected": 0, "ok": 0,
            "errors": 0, "degraded": 0, "hedges": 0, "retries": 0,
            "quarantines": 0, "swaps": 0,
        }
        self.quarantined: List[int] = []

        baseline = StragglerWatchdog.shared_baseline(watchdog_window)
        self.replicas: List[Replica] = []
        for rid, svc in enumerate(services):
            rep = Replica(rid, svc)
            rep.watchdog = StragglerWatchdog(
                threshold=watchdog_threshold, window=watchdog_window,
                patience=watchdog_patience,
                on_straggler=(
                    lambda st, rep=rep: self._quarantine(
                        rep, f"straggler: {st.seconds:.3f}s")
                ),
                baseline=baseline,
            )
            rep.thread = threading.Thread(
                target=self._worker, args=(rep,),
                name=f"replica-{rid}", daemon=True,
            )
            self.replicas.append(rep)
        for rep in self.replicas:
            rep.thread.start()
        self._monitor_thread = threading.Thread(
            target=self._monitor, name="router-monitor", daemon=True
        )
        self._monitor_thread.start()

    # ------------------------------------------------------------------ API

    def submit(self, query_id: int, deadline_s: Optional[float] = None) -> Ticket:
        """Admit (or shed) a request; returns its ticket immediately.

        ``deadline_s`` is a relative latency budget: past it the engine
        returns the anytime provisional top-k (``degraded``) rather than
        nothing.  A full router (``queue_limit`` tickets in flight)
        resolves the ticket ``REJECTED`` on the spot — load shedding is an
        explicit response, never a silent drop.
        """
        now = time.monotonic()
        deadline_t = now + deadline_s if deadline_s is not None else None
        with self._lock:
            seq = self._seq
            self._seq += 1
            tk = Ticket(seq, query_id, deadline_t, now)
            self.stats["submitted"] += 1
            if not self._running or len(self._live) >= self.queue_limit:
                self.stats["rejected"] += 1
                tk.resolve(REJECTED)
                return tk
            self._live[seq] = tk
            self._admitted += 1
            self.stats["admitted"] += 1
            swap = (self.plan is not None and self.swap_index_fn is not None
                    and self.plan.swap_due(self._admitted))
        if swap:
            self.swap_index(self.swap_index_fn())
        self._dispatch(tk)
        return tk

    def result(self, ticket: Ticket,
               timeout: Optional[float] = None) -> Optional[RouterResponse]:
        """Block for the ticket's terminal outcome (None only on timeout)."""
        ticket.done.wait(timeout)
        return ticket.outcome

    def swap_index(self, index) -> None:
        """Swap the live index on every replica (mid-flight safe: each
        service drains its admitted-but-queued requests against the old
        index under its own lock before switching)."""
        with self._lock:
            self.stats["swaps"] += 1
            reps = list(self.replicas)
        for rep in reps:
            rep.service.swap_index(index)

    def drain(self, timeout: float = 30.0) -> bool:
        """Wait until no tickets are in flight (True) or timeout (False)."""
        t_end = time.monotonic() + timeout
        while time.monotonic() < t_end:
            with self._lock:
                if not self._live:
                    return True
            time.sleep(0.002)
        with self._lock:
            return not self._live

    def close(self, timeout: float = 10.0) -> None:
        """Stop workers; any still-unresolved ticket is resolved as an
        error — even shutdown may not lose a request."""
        self._running = False
        for rep in self.replicas:
            rep.q.put(_POISON)
        for rep in self.replicas:
            if rep.thread is not None:
                rep.thread.join(timeout)
        self._monitor_thread.join(timeout)
        with self._lock:
            leftovers = list(self._live.values())
        for tk in leftovers:
            if tk.resolve(ERROR, RetrievalResponse(
                    query_id=tk.query_id, status="error",
                    error="router shutdown"), None):
                self._finish(tk)

    # ------------------------------------------------------- dispatch plane

    def _pick(self, exclude: Sequence[int]) -> Optional[Replica]:
        with self._lock:
            candidates = [
                r for r in self.replicas
                if r.healthy and r.rid not in exclude
            ]
            if not candidates:
                return None
            return min(candidates, key=lambda r: r.q.qsize())

    def _dispatch(self, tk: Ticket, exclude: Sequence[int] = (),
                  hedge: bool = False, retry: bool = False) -> None:
        rep = self._pick(exclude)
        if rep is None:
            # everyone we wanted to avoid is all there is: any healthy
            # replica beats a lost request
            rep = self._pick(())
        if rep is None:
            if tk.resolve(ERROR, RetrievalResponse(
                    query_id=tk.query_id, status="error",
                    error="no healthy replicas"), None):
                self._finish(tk)
            return
        with tk.lock:
            if tk.done.is_set():
                return
            tk.replicas_tried.append(rep.rid)
            tk.dispatch_t = time.monotonic()
        with self._lock:
            if hedge:
                self.stats["hedges"] += 1
            if retry:
                self.stats["retries"] += 1
        rep.q.put(tk)

    def _finish(self, tk: Ticket) -> None:
        with self._lock:
            self._live.pop(tk.seq, None)
            out = tk.outcome
            if out is None:
                return
            if out.status == OK:
                self.stats["ok"] += 1
                if out.degraded:
                    self.stats["degraded"] += 1
            elif out.status == ERROR:
                self.stats["errors"] += 1

    def _attempt_failed(self, tk: Ticket, response: RetrievalResponse,
                        rid: int) -> None:
        with tk.lock:
            if tk.done.is_set():
                return
            tk.failures += 1
            terminal = tk.failures > self.max_retries
            if not terminal:
                tk.retry_at = (
                    time.monotonic() + self.retry_backoff_s * tk.failures
                )
        if terminal and tk.resolve(ERROR, response, rid):
            self._finish(tk)

    def _quarantine(self, rep: Replica, reason: str) -> None:
        with self._lock:
            if not rep.healthy:
                return
            rep.healthy = False
            self.stats["quarantines"] += 1
            self.quarantined.append(rep.rid)
        # drain its queue to healthy peers — nothing waits on a dead replica
        while True:
            try:
                tk = rep.q.get_nowait()
            except queue.Empty:
                break
            if tk is _POISON:
                rep.q.put(_POISON)
                break
            if not tk.resolved:
                self._dispatch(tk, exclude=[rep.rid])

    # --------------------------------------------------------- worker plane

    def _coalesce(self, rep: Replica, first: Ticket) -> List[Ticket]:
        batch = [first]
        while len(batch) < rep.service.max_batch:
            try:
                tk = rep.q.get_nowait()
            except queue.Empty:
                break
            if tk is _POISON:
                rep.q.put(_POISON)
                break
            batch.append(tk)
        return batch

    def _worker(self, rep: Replica) -> None:
        while self._running:
            try:
                first = rep.q.get(timeout=0.02)
            except queue.Empty:
                continue
            if first is _POISON:
                break
            # duplicate suppression at the cheapest point: a ticket that
            # already resolved elsewhere (hedge winner) is dropped before
            # any CE pair is scored for it
            live = [t for t in self._coalesce(rep, first) if not t.resolved]
            if not live:
                continue
            t0 = time.monotonic()   # before any stall: the watchdog's
            # observation must include whatever is slowing this replica
            if self.plan is not None:
                stall = self.plan.sleep_s(rep.rid, [t.seq for t in live])
                if stall > 0:
                    time.sleep(stall)
            try:
                responses: List[RetrievalResponse] = []
                for tk in live:
                    fired = rep.service.submit(RetrievalRequest(
                        query_id=tk.query_id, deadline_t=tk.deadline_t))
                    if fired:
                        responses += fired
                responses += rep.service.flush()
                while len(responses) < len(live):
                    more = rep.service.flush()
                    if not more:
                        break
                    responses += more
            except Exception as e:  # noqa: BLE001 — replica must survive
                responses = [RetrievalResponse(
                    query_id=tk.query_id, status="error",
                    error=f"{type(e).__name__}: {e}") for tk in live]
            dt = time.monotonic() - t0
            rep.step += 1
            rep.served += len(live)
            if rep.watchdog is not None:
                rep.watchdog.observe(rep.step, dt)
            all_err = bool(responses) and all(
                r.status == "error" for r in responses
            )
            rep.consecutive_errors = rep.consecutive_errors + 1 if all_err else 0
            for tk, resp in zip(live, responses):
                if resp.status == "error":
                    self._attempt_failed(tk, resp, rep.rid)
                elif tk.resolve(OK, resp, rep.rid):
                    self._finish(tk)
            for tk in live[len(responses):]:
                # a response went missing (service invariant breach): still
                # terminal — never leave a ticket hanging
                self._attempt_failed(tk, RetrievalResponse(
                    query_id=tk.query_id, status="error",
                    error="replica returned no response"), rep.rid)
            if (rep.healthy
                    and rep.consecutive_errors >= self.max_consecutive_errors):
                self._quarantine(
                    rep, f"{rep.consecutive_errors} consecutive error batches"
                )

    # -------------------------------------------------------------- monitor

    def _monitor(self) -> None:
        while self._running:
            now = time.monotonic()
            with self._lock:
                live = list(self._live.values())
            for tk in live:
                if tk.resolved:
                    continue
                with tk.lock:
                    due_retry = tk.retry_at is not None and now >= tk.retry_at
                    if due_retry:
                        tk.retry_at = None
                    due_hedge = (
                        not due_retry
                        and self.hedge_after_s is not None
                        and not tk.hedged
                        and tk.retry_at is None
                        and now - tk.dispatch_t >= self.hedge_after_s
                    )
                    if due_hedge:
                        tk.hedged = True
                if due_retry:
                    self._dispatch(tk, exclude=tk.replicas_tried, retry=True)
                elif due_hedge:
                    self._dispatch(tk, exclude=tk.replicas_tried, hedge=True)
            time.sleep(self.monitor_interval_s)

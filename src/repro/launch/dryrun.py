import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile EVERY (arch x shape) cell on the
production mesh, record memory/cost analysis + collective bytes.

MUST be run as a standalone process (the XLA flag above has to land before
jax initializes its backend — hence the import-order violation).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --cell qwen3-8b:train_4k \
      [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from ..configs import registry
from . import mesh as mesh_lib, steps as steps_lib

COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "s64": 8, "u64": 8, "f64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1,
    "u1": 1, "s1": 1, "c64": 8, "c128": 16,
}


def _shape_bytes(text: str) -> int:
    """Sum byte sizes of every typed shape literal in an HLO line segment."""
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the compiled HLO.

    Collective lines look like:  %x = bf16[...]{...} all-gather(...), ...
    — the result shape is the post-collective (gathered) size, a reasonable
    proxy for link traffic per op (all-reduce moves ~2x in a ring; the
    roofline applies op-specific factors downstream)."""
    out: dict = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        kind = m.group(1)
        # bytes of the result shape(s): left of the op name
        lhs = line.split(m.group(1))[0]
        b = _shape_bytes(lhs)
        if b:
            d = out.setdefault(kind, {"count": 0, "bytes": 0})
            d["count"] += 1
            d["bytes"] += b
    return out


def run_cell(arch_id: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    bundle = steps_lib.build_cell(arch_id, shape_name, mesh)
    with mesh:
        jitted = jax.jit(
            bundle.step,
            in_shardings=bundle.in_shardings,
            out_shardings=bundle.out_shardings,
            donate_argnums=bundle.donate,
        )
        lowered = jitted.lower(*bundle.abstract_args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_d = {}
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            mem_d[k] = getattr(mem, k, None)
    cost_d = {}
    if cost:
        for k in ("flops", "bytes accessed", "transcendentals", "utilization operand 0 {}"):
            if k in cost:
                cost_d[k] = cost[k]
        cost_d = {k: v for k, v in cost.items() if isinstance(v, (int, float))}

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec = {
        "cell": f"{arch_id}:{shape_name}",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": int(n_chips),
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": mem_d,
        "cost": {k: cost_d[k] for k in ("flops", "bytes accessed") if k in cost_d},
        "collectives": coll,
        "model_flops": bundle.model_flops,
        "notes": bundle.notes,
        "hlo_bytes": len(hlo),
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch_id}__{shape_name}__{'mp' if multi_pod else 'sp'}".replace("/", "_")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", help="arch:shape, e.g. qwen3-8b:train_4k")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = (
        registry.cells()
        if args.all
        else [tuple(args.cell.split(":", 1))]
    )
    n_fail = 0
    for arch_id, shape_name in cells:
        try:
            rec = run_cell(arch_id, shape_name, args.multi_pod, args.out)
            mem = rec["memory"].get("temp_size_in_bytes")
            print(
                f"OK   {rec['cell']:42s} mesh={rec['mesh']} "
                f"compile={rec['compile_s']}s temp={mem} "
                f"flops={rec['cost'].get('flops')}",
                flush=True,
            )
        except Exception as e:
            n_fail += 1
            tag = f"{arch_id}__{shape_name}__{'mp' if args.multi_pod else 'sp'}"
            os.makedirs(args.out, exist_ok=True)
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(
                    {"cell": f"{arch_id}:{shape_name}", "ok": False,
                     "error": f"{type(e).__name__}: {e}"},
                    f, indent=1,
                )
            print(f"FAIL {arch_id}:{shape_name}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

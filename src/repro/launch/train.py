"""Training driver: any registry arch, any mesh, full fault-tolerance loop.

Wires together the whole substrate: config registry -> model init (sharded)
-> data pipeline (ShardedBatcher + Prefetcher) -> jit'd train step (FSDP/TP,
remat, grad accumulation) -> AdamW -> CheckpointManager (async, atomic,
keep-K, crash recovery) -> StragglerWatchdog.

CLI (CPU-sized example; the same code drives the pod meshes):
  PYTHONPATH=src python -m repro.launch.train --arch ce-tiny --steps 50
"""

from __future__ import annotations

import argparse
import logging
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import CheckpointManager
from ..configs import registry
from ..data.loader import Prefetcher, ShardedBatcher
from ..distributed.fault_tolerance import StragglerWatchdog
from ..models import transformer
from ..training import optimizer

log = logging.getLogger("repro.train")


def make_lm_train_step(cfg, opt_cfg):
    def loss_fn(params, batch):
        h, aux = transformer.encode(params, batch["tokens"], cfg)
        logits = transformer.lm_logits(params, h[:, :-1], cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, batch["tokens"][:, 1:, None], axis=-1)
        loss = nll.mean()
        if cfg.moe is not None:
            loss = loss + cfg.moe.aux_loss_coef * aux
        return loss

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, metrics = optimizer.adamw_update(
            opt_cfg, params, grads, opt_state
        )
        return params, opt_state, {"loss": loss, **metrics}

    return step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ce-tiny")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    entry = registry.get(args.arch)
    if entry.family != "lm":
        raise SystemExit("train.py drives LM archs; see steps.py for the rest")
    cfg = registry.smoke_config(args.arch) if args.smoke or args.arch == "ce-tiny" else entry.config
    if args.arch == "ce-tiny":
        cfg = registry.CE_TINY

    params, specs = transformer.init_lm(jax.random.PRNGKey(0), cfg)
    opt_cfg = optimizer.AdamWConfig(lr=3e-4, total_steps=args.steps)
    opt_state = optimizer.init_adamw(params)
    step_fn = make_lm_train_step(cfg, opt_cfg)

    # synthetic token stream via the deterministic sharded batcher
    n_docs = 4096
    rng = np.random.default_rng(0)
    docs = rng.integers(4, cfg.vocab_size, size=(n_docs, args.seq)).astype(np.int32)
    batcher = ShardedBatcher(n_docs, args.batch, seed=0)
    prefetch = Prefetcher(
        lambda s: {"tokens": jnp.asarray(docs[batcher.batch_indices(s)])}, depth=2
    )

    mgr = CheckpointManager(args.ckpt_dir, save_every=args.save_every, keep=2)
    watchdog = StragglerWatchdog(
        on_straggler=lambda st: log.warning("straggler: step %d %.2fs", st.step, st.seconds)
    )

    start, state = mgr.resume({"params": params, "opt": opt_state})
    params, opt_state = state["params"], state["opt"]
    if start:
        log.info("resumed from checkpoint at step %d", start)

    t_start = time.time()
    for step, batch in prefetch:
        if step < start:
            continue
        if step >= args.steps:
            break
        t0 = time.monotonic()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        watchdog.observe(step, time.monotonic() - t0)
        mgr.maybe_save(step + 1, {"params": params, "opt": opt_state})
        if step % 10 == 0 or step == args.steps - 1:
            log.info(
                "step %d loss %.4f gnorm %.3f lr %.2e",
                step, float(metrics["loss"]), float(metrics["grad_norm"]),
                float(metrics["lr"]),
            )
    prefetch.close()
    mgr.ckpt.wait()
    log.info("done: %d steps in %.1fs", args.steps - start, time.time() - t_start)


if __name__ == "__main__":
    main()

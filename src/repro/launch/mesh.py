"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — jax locks the device count on first backend
init, and the 512-device placeholder flag must be set before that
(launch/dryrun.py sets it as its very first lines).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod.

    Axes: ("data", "model") single pod, ("pod", "data", "model") multi-pod.
    "data" = DP/FSDP, "model" = TP/EP/sequence-parallel-KV, "pod" = cross-pod
    DP (grad-reduce only crosses pods — see repro.distributed.sharding).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any (shape, axes) — checkpoint restore re-shards
    between meshes built here (see repro.checkpoint)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_serving_mesh(data: int, items: int):
    """The retrieval-serving mesh: ``data`` shards the query batch (data-
    parallel request rows), ``items`` shards the AnchorIndex payload and the
    engine's per-shard item slabs (see ``repro.core.engine``'s SPMD engine).
    ``data * items`` must equal the visible device count."""
    return jax.make_mesh((data, items), ("data", "items"))

"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch x shape) cell, derive the three roofline terms on TPU v5e:

    compute term    = FLOPs        / (chips x 197e12 FLOP/s bf16)
    memory term     = HLO bytes    / (chips x 819e9  B/s HBM)
    collective term = link bytes   / (chips x 50e9   B/s ICI per link)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes (PER-PARTITION on
this backend) and the HLO collective parse from dryrun.py for link bytes.

Two documented corrections:
- XLA's CPU cost model counts while-loop BODIES ONCE (scan over layers,
  grad-accumulation loops, edge-chunk scans) — compiled FLOPs therefore
  undercount looped work.  We report BOTH the raw HLO term and the
  analytic-model term (MODEL_FLOPS = 6·N·D dense / 6·N_active·D MoE),
  and use max(hlo, analytic) for the bottleneck call.
- collective 'bytes' are result-shape sums; all-reduce is costed at 2x
  (ring reduce-scatter + all-gather), all-to-all at 1x, all-gather /
  reduce-scatter at 1x, collective-permute at 1x.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun [--mesh sp]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s / chip
LINK_BW = 50e9              # B/s / link
COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class Roofline:
    cell: str
    mesh: str
    chips: int
    t_compute_hlo: float
    t_compute_model: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    hlo_flops: float
    useful_frac: float
    temp_bytes: int
    notes: str

    @property
    def step_time(self) -> float:
        return max(self.t_compute_model, self.t_memory, self.t_collective)

    @property
    def roofline_frac(self) -> float:
        """Fraction of step time spent at the compute roof (the score)."""
        return self.t_compute_model / max(self.step_time, 1e-30)


def analyze_record(rec: dict) -> Roofline:
    chips = rec["n_chips"]
    hlo_flops = float(rec["cost"].get("flops", 0.0))          # per partition
    hlo_bytes = float(rec["cost"].get("bytes accessed", 0.0)) # per partition
    model_flops = float(rec["model_flops"]) / chips           # per chip
    coll_bytes = sum(
        v["bytes"] * COLL_FACTOR.get(k, 1.0)
        for k, v in rec.get("collectives", {}).items()
    )  # summed over the program; per-device link traffic
    t_c_hlo = hlo_flops / PEAK_FLOPS
    t_c_model = model_flops / PEAK_FLOPS
    t_m = hlo_bytes / HBM_BW
    t_coll = coll_bytes / LINK_BW
    t_c = max(t_c_hlo, t_c_model)
    terms = {"compute": t_c, "memory": t_m, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    return Roofline(
        cell=rec["cell"],
        mesh=rec["mesh"],
        chips=chips,
        t_compute_hlo=t_c_hlo,
        t_compute_model=t_c_model,
        t_memory=t_m,
        t_collective=t_coll,
        bottleneck=bottleneck,
        model_flops=float(rec["model_flops"]),
        hlo_flops=hlo_flops * chips,
        useful_frac=min(1.0, model_flops / hlo_flops) if hlo_flops else 0.0,
        temp_bytes=rec["memory"].get("temp_size_in_bytes", 0),
        notes=rec.get("notes", ""),
    )


def load_all(directory: str, mesh_tag: str = "sp"):
    out = []
    for path in sorted(glob.glob(os.path.join(directory, f"*__{mesh_tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("ok"):
            out.append(analyze_record(rec))
    return out


def print_table(rows):
    hdr = (f"{'cell':<42} {'comp(ms)':>9} {'mem(ms)':>8} {'coll(ms)':>9} "
           f"{'bound':>10} {'roof%':>6} {'temp(GB)':>9}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(
            f"{r.cell:<42} {r.t_compute_model*1e3:>9.3f} {r.t_memory*1e3:>8.3f} "
            f"{r.t_collective*1e3:>9.3f} {r.bottleneck:>10} "
            f"{r.roofline_frac*100:>5.1f}% {r.temp_bytes/1e9:>9.2f}"
        )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="sp", choices=("sp", "mp"))
    ap.add_argument("--json-out", default="")
    args = ap.parse_args()
    rows = load_all(args.dir, args.mesh)
    print_table(rows)
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ for r in rows], f, indent=1)
    worst = sorted(rows, key=lambda r: r.roofline_frac)[:3]
    print("\nworst roofline fraction (hillclimb candidates):")
    for r in worst:
        print(f"  {r.cell}: {r.roofline_frac*100:.1f}% ({r.bottleneck}-bound)")


if __name__ == "__main__":
    main()

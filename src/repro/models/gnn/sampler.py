"""Neighbor sampling for minibatch GNN training (GraphSAGE-style fanout).

Host-side (numpy, outside jit): builds a CSR adjacency once, then per batch
samples a fanout-bounded k-hop subgraph and pads it to static shapes so the
jit'd train step never recompiles.  This is the real sampler the
``minibatch_lg`` shape requires (232K nodes / 114M edges, fanout 15-10).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray      # (n_nodes+1,)
    indices: np.ndarray     # (n_edges,)
    n_nodes: int

    @staticmethod
    def from_edge_index(senders: np.ndarray, receivers: np.ndarray, n_nodes: int) -> "CSRGraph":
        order = np.argsort(senders, kind="stable")
        s, r = senders[order], receivers[order]
        counts = np.bincount(s, minlength=n_nodes)
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSRGraph(indptr, r.astype(np.int32), n_nodes)

    def sample_neighbors(self, nodes: np.ndarray, fanout: int, rng: np.random.Generator):
        """Uniform fanout sampling: returns (senders, receivers) edge lists."""
        src, dst = [], []
        for v in nodes:
            lo, hi = self.indptr[v], self.indptr[v + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = min(fanout, deg)
            picks = rng.choice(deg, size=take, replace=False) + lo
            nbrs = self.indices[picks]
            src.append(nbrs)
            dst.append(np.full(take, v, dtype=np.int32))
        if not src:
            return np.zeros(0, np.int32), np.zeros(0, np.int32)
        return np.concatenate(src), np.concatenate(dst)


@dataclass
class SampledSubgraph:
    """Padded, statically-shaped subgraph batch for the jit'd step."""

    node_ids: np.ndarray      # (max_nodes,) original ids (padded with 0)
    node_mask: np.ndarray     # (max_nodes,) bool
    senders: np.ndarray       # (max_edges,) local ids
    receivers: np.ndarray     # (max_edges,)
    edge_mask: np.ndarray     # (max_edges,) bool
    seed_mask: np.ndarray     # (max_nodes,) True for the loss-bearing seeds


def sample_subgraph(
    graph: CSRGraph,
    seed_nodes: np.ndarray,
    fanouts: Tuple[int, ...],
    max_nodes: int,
    max_edges: int,
    rng: np.random.Generator,
) -> SampledSubgraph:
    """k-hop fanout sampling + relabel + pad to (max_nodes, max_edges)."""
    frontier = seed_nodes.astype(np.int64)
    all_src = []
    all_dst = []
    seen = set(frontier.tolist())
    for f in fanouts:
        src, dst = graph.sample_neighbors(frontier, f, rng)
        all_src.append(src)
        all_dst.append(dst)
        new = np.unique(src)
        frontier = np.array([v for v in new if v not in seen], dtype=np.int64)
        seen.update(frontier.tolist())
        if frontier.size == 0:
            break
    src = np.concatenate(all_src) if all_src else np.zeros(0, np.int32)
    dst = np.concatenate(all_dst) if all_dst else np.zeros(0, np.int32)

    node_ids = np.unique(np.concatenate([seed_nodes, src, dst]))
    if node_ids.size > max_nodes:        # truncate (keep seeds first)
        others = np.setdiff1d(node_ids, seed_nodes, assume_unique=False)
        node_ids = np.concatenate([seed_nodes, others])[:max_nodes]
    relabel = {v: i for i, v in enumerate(node_ids.tolist())}
    keep = np.array(
        [s in relabel and d in relabel for s, d in zip(src.tolist(), dst.tolist())],
        dtype=bool,
    )
    src, dst = src[keep][:max_edges], dst[keep][:max_edges]
    loc_s = np.array([relabel[v] for v in src.tolist()], dtype=np.int32)
    loc_d = np.array([relabel[v] for v in dst.tolist()], dtype=np.int32)

    n, e = node_ids.size, loc_s.size
    out = SampledSubgraph(
        node_ids=np.zeros(max_nodes, np.int32),
        node_mask=np.zeros(max_nodes, bool),
        senders=np.zeros(max_edges, np.int32),
        receivers=np.zeros(max_edges, np.int32),
        edge_mask=np.zeros(max_edges, bool),
        seed_mask=np.zeros(max_nodes, bool),
    )
    out.node_ids[:n] = node_ids
    out.node_mask[:n] = True
    out.senders[:e] = loc_s
    out.receivers[:e] = loc_d
    out.edge_mask[:e] = True
    out.seed_mask[: seed_nodes.size] = True
    return out


def random_graph(n_nodes: int, n_edges: int, seed: int = 0):
    """Synthetic power-law-ish graph for tests/benchmarks."""
    rng = np.random.default_rng(seed)
    # preferential-attachment-flavoured degree skew
    weights = rng.pareto(2.0, n_nodes) + 1.0
    weights /= weights.sum()
    senders = rng.choice(n_nodes, size=n_edges, p=weights).astype(np.int32)
    receivers = rng.integers(0, n_nodes, size=n_edges).astype(np.int32)
    return senders, receivers

from . import nequip, sampler  # noqa: F401

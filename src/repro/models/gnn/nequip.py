"""NequIP: E(3)-equivariant interatomic potential  [arXiv:2101.03164].

Implementation notes (hardware adaptation, DESIGN.md):

- Features are irreps l=0..2 with channel multiplicity ``d_hidden``, stored
  in Cartesian form: scalars (N,h), vectors (N,h,3), symmetric-traceless
  rank-2 tensors (N,h,3,3).  The Cartesian form makes every tensor-product
  path an elementary einsum — dot, cross, symmetric outer, matrix-vector —
  which maps directly onto the TPU MXU instead of irregular CG contractions.
- Message passing is ``jax.ops.segment_sum`` over an edge index (JAX is
  BCOO-only — scatter-based message passing IS part of this system).
- Radial dependence: Bessel basis (n_rbf) with a polynomial cutoff envelope;
  per-path per-channel radial weights from a small MLP, as in the paper.
- Equivariance is property-tested: rotations of the input positions rotate
  vector features, leave energies invariant (tests/test_gnn.py).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ...configs.base import GNNConfig
from .. import layers
from ...compat import shard_map

# tensor-product paths computed in each interaction block
_PATHS = (
    "ss", "vv_s",            # -> scalars
    "sv", "vs", "vv_v", "tv_v", "vt_v",   # -> vectors
    "st", "vv_t", "ts", "tt_t",           # -> tensors
)


def _sym_traceless(m):
    m = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(m, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=m.dtype)
    return m - tr * eye / 3.0


def bessel_basis(r: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Radial Bessel basis with smooth polynomial cutoff envelope (paper)."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[..., None] / cutoff) / r[..., None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    # p=6 polynomial envelope: 1 - 28x^6 + 48x^7 - 21x^8 (C^2-smooth at cutoff)
    env = 1.0 - 28.0 * x**6 + 48.0 * x**7 - 21.0 * x**8
    return basis * env[..., None]


def _radial_mlp_init(key, n_rbf: int, n_out: int, hidden: int = 16):
    k1, k2 = jax.random.split(key)
    return {
        "w1": layers.dense_init(k1, (n_rbf, hidden), ("rbf", "mlp")),
        "w2": layers.dense_init(k2, (hidden, n_out), ("mlp", "radial_out")),
    }


def _radial_mlp(p, rbf):
    return jax.nn.silu(rbf @ p["w1"]) @ p["w2"]


def _layer_init(key, cfg: GNNConfig):
    h = cfg.d_hidden
    ks = jax.random.split(key, 8)
    n_weights = len(_PATHS) * h       # one radial weight per path per channel
    lin = {
        # post-aggregation linear mixing per irrep (channel mixing only —
        # equivariance forbids mixing across irrep components)
        "w_s": layers.dense_init(ks[0], (2 * h, h), ("ch_in", "ch")),
        "w_v": layers.dense_init(ks[1], (2 * h, h), ("ch_in", "ch")),
        "w_t": layers.dense_init(ks[2], (2 * h, h), ("ch_in", "ch")),
        # gates: scalars produced to gate vector/tensor channels
        "w_gate": layers.dense_init(ks[3], (2 * h, 2 * h), ("ch_in", "ch")),
    }
    radial = _radial_mlp_init(ks[4], cfg.n_rbf, n_weights)
    p, s = layers.split_tree({"lin": lin, "radial": radial})
    return p, s


def init_nequip(key, cfg: GNNConfig, d_feat: int = 0):
    """d_feat>0: raw node features projected in; else species embedding."""
    h = cfg.d_hidden
    ks = jax.random.split(key, cfg.n_layers + 3)
    params: Dict = {}
    specs: Dict = {}
    if d_feat > 0:
        params["embed"], specs["embed"] = layers.dense_init(
            ks[0], (d_feat, h), ("feat", "ch")
        )
    else:
        params["embed"], specs["embed"] = layers.dense_init(
            ks[0], (cfg.n_species, h), ("species", "ch"), scale=1.0
        )
    lp = [_layer_init(ks[1 + i], cfg) for i in range(cfg.n_layers)]
    params["layers"] = [p for p, _ in lp]
    specs["layers"] = [s for _, s in lp]
    params["readout1"], specs["readout1"] = layers.dense_init(
        ks[-2], (h, h), ("ch_in", "ch")
    )
    params["readout2"], specs["readout2"] = layers.dense_init(
        ks[-1], (h, 1), ("ch_in", "unit")
    )
    return params, specs


def _edge_geometry(positions, senders, receivers, cfg: GNNConfig):
    rel = positions[receivers] - positions[senders]          # (E, 3)
    r = jnp.sqrt(jnp.sum(rel * rel, axis=-1) + 1e-12)
    rhat = rel / r[:, None]
    rbf = bessel_basis(r, cfg.n_rbf, cfg.cutoff)             # (E, n_rbf)
    # Y1 = rhat ; Y2 = sym-traceless(rhat rhat^T)
    y2 = _sym_traceless(rhat[:, :, None] * rhat[:, None, :])
    return rhat, y2, rbf


def _interact(lp, feats, senders, receivers, rhat, y2, rbf, n_nodes: int, h: int):
    """One interaction block: TP messages -> segment_sum -> linear + gate."""
    s, v, t = feats["s"], feats["v"], feats["t"]
    w = _radial_mlp(lp["radial"], rbf).reshape(-1, len(_PATHS), h)  # (E, P, h)
    wp = {name: w[:, i] for i, name in enumerate(_PATHS)}

    se, ve, te = s[senders], v[senders], t[senders]          # sender feats
    y1 = rhat[:, None, :]                                    # (E, 1, 3)
    y2e = y2[:, None, :, :]                                  # (E, 1, 3, 3)

    # --- scalar messages ---------------------------------------------------
    m_s = wp["ss"] * se                                           # s ⊗ Y0 -> s
    m_s += wp["vv_s"] * jnp.einsum("ehc,ec->eh", ve, rhat)        # v ⊗ Y1 -> s
    # --- vector messages ---------------------------------------------------
    m_v = wp["sv"][..., None] * (se[..., None] * y1)              # s ⊗ Y1 -> v
    m_v += wp["vs"][..., None] * ve                               # v ⊗ Y0 -> v
    m_v += wp["vv_v"][..., None] * jnp.cross(ve, jnp.broadcast_to(y1, ve.shape))
    m_v += wp["tv_v"][..., None] * jnp.einsum("ehij,ej->ehi", te, rhat)
    m_v += wp["vt_v"][..., None] * jnp.einsum("eij,ehj->ehi", y2, ve)
    # --- tensor messages ---------------------------------------------------
    m_t = wp["st"][..., None, None] * (se[..., None, None] * y2e)
    m_t += wp["ts"][..., None, None] * te                         # t ⊗ Y0 -> t
    outer = _sym_traceless(ve[..., :, None] * jnp.broadcast_to(y1, ve.shape)[..., None, :])
    m_t += wp["vv_t"][..., None, None] * outer                    # v ⊗ Y1 -> t
    anti = _sym_traceless(jnp.einsum("ehij,ejk->ehik", te, y2))
    m_t += wp["tt_t"][..., None, None] * anti                     # t ⊗ Y2 -> t

    agg_s = jax.ops.segment_sum(m_s, receivers, num_segments=n_nodes)
    agg_v = jax.ops.segment_sum(m_v, receivers, num_segments=n_nodes)
    agg_t = jax.ops.segment_sum(m_t, receivers, num_segments=n_nodes)

    # self-interaction: concat(old, aggregated) -> channel-mix per irrep
    cs = jnp.concatenate([s, agg_s], axis=-1)                     # (N, 2h)
    cv = jnp.concatenate([v, agg_v], axis=1)                      # (N, 2h, 3)
    ct = jnp.concatenate([t, agg_t], axis=1)                      # (N, 2h, 3, 3)
    new_s = cs @ lp["lin"]["w_s"]
    new_v = jnp.einsum("ehi,hc->eci", cv, lp["lin"]["w_v"])
    new_t = jnp.einsum("ehij,hc->ecij", ct, lp["lin"]["w_t"])
    gates = jax.nn.sigmoid(cs @ lp["lin"]["w_gate"])              # (N, 2h)
    g_v, g_t = gates[:, :new_v.shape[1]], gates[:, new_v.shape[1]:]
    return {
        "s": s + jax.nn.silu(new_s),
        "v": v + g_v[..., None] * new_v,
        "t": t + g_t[..., None, None] * new_t,
    }


def make_sharded_interact(mesh, node_axis: str = "data",
                          channel_axis: Optional[str] = "model"):
    """Receiver-partitioned, channel-TP message passing (pod-scale graphs).

    Two-axis decomposition of one interaction block:

    - ``node_axis``: edges are partitioned by RECEIVER shard (the standard
      graph-partitioning contract), so every scatter-add is shard-local;
      the only node-axis collective is one all_gather of sender features.
      Without this, XLA's scatter partitioner replicates the (N, h, 9)
      feature tensors — 83.7 GB/device on ogb_products.
    - ``channel_axis``: the irrep channel (multiplicity) dim is tensor-
      parallel — every equivariant tensor-product path is channelwise, so
      each model shard gathers/computes only its h/tp channels; only the
      channel-MIXING linears contract across shards (one psum_scatter each).
      This divides the gathered sender table (the dominant resident after
      edge chunking) by the model-axis size.

    Returns interact(lp, feats, senders, receivers, rhat, y2, rbf, n, h)
    with feats sharded (node_axis, channel_axis, ...), edges on node_axis.
    """
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape[channel_axis] if channel_axis else 1

    def body(lp, feats, senders, receivers, rhat, y2, rbf):
        n_local, h_local = feats["s"].shape
        h_full = h_local * tp
        offset = jax.lax.axis_index(node_axis) * n_local
        crank = jax.lax.axis_index(channel_axis) if channel_axis else 0
        # sender features: gather full node table for MY channels only
        full = jax.tree.map(
            lambda x: jax.lax.all_gather(x, node_axis, axis=0, tiled=True), feats
        )
        local_recv = receivers - offset   # receiver-partitioned: in [0, n_local)

        def mix(cs_local, w_full, out_dim):
            """Channel-TP linear: rows of w for my channels, psum_scatter out."""
            if channel_axis is None:
                return cs_local @ w_full
            w_top = jax.lax.dynamic_slice_in_dim(w_full, crank * h_local, h_local, 0)
            w_bot = jax.lax.dynamic_slice_in_dim(
                w_full, h_full + crank * h_local, h_local, 0
            )
            partial = cs_local @ jnp.concatenate([w_top, w_bot], axis=0)
            return jax.lax.psum_scatter(
                partial, channel_axis, scatter_dimension=1, tiled=True
            )

        def radial_slice(rb):
            w = _radial_mlp(lp["radial"], rb).reshape(-1, len(_PATHS), h_full)
            if channel_axis is None:
                return w
            return jax.lax.dynamic_slice_in_dim(w, crank * h_local, h_local, 2)

        return _interact_inner_tp(
            lp, feats, full, senders, local_recv, rhat, y2, rbf,
            n_local, radial_slice, mix,
        )

    def interact(lp, feats, senders, receivers, rhat, y2, rbf, n, h):
        ch = channel_axis
        e_spec = P(node_axis)
        f_specs = {
            "s": P(node_axis, ch),
            "v": P(node_axis, ch, None),
            "t": P(node_axis, ch, None, None),
        }
        lp_spec = jax.tree.map(lambda _: P(), lp)
        return shard_map(
            body,
            mesh=mesh,
            in_specs=(lp_spec, f_specs, e_spec, e_spec, P(node_axis, None),
                      P(node_axis, None, None), P(node_axis, None)),
            out_specs=f_specs,
            check_vma=False,
        )(lp, feats, senders, receivers, rhat, y2, rbf)

    return interact


def _interact_inner_tp(lp, feats, full_feats, senders, receivers, rhat, y2,
                       rbf, n_nodes, radial_slice, mix,
                       edge_chunk: int = 262144):
    """Edge-blocked interact with pluggable radial-weight slicing and
    channel-mixing (the channel-TP hooks from make_sharded_interact)."""
    s, v, t = feats["s"], feats["v"], feats["t"]
    ne = senders.shape[0]

    def messages(sd, rh, y2c, rb):
        wfull = radial_slice(rb)                            # (E, P, h_local)
        wp = {name: wfull[:, i] for i, name in enumerate(_PATHS)}
        se, ve, te = full_feats["s"][sd], full_feats["v"][sd], full_feats["t"][sd]
        y1 = rh[:, None, :]
        y2e = y2c[:, None, :, :]
        m_s = wp["ss"] * se + wp["vv_s"] * jnp.einsum("ehc,ec->eh", ve, rh)
        m_v = wp["sv"][..., None] * (se[..., None] * y1)
        m_v += wp["vs"][..., None] * ve
        m_v += wp["vv_v"][..., None] * jnp.cross(ve, jnp.broadcast_to(y1, ve.shape))
        m_v += wp["tv_v"][..., None] * jnp.einsum("ehij,ej->ehi", te, rh)
        m_v += wp["vt_v"][..., None] * jnp.einsum("eij,ehj->ehi", y2c, ve)
        m_t = wp["st"][..., None, None] * (se[..., None, None] * y2e)
        m_t += wp["ts"][..., None, None] * te
        outer = _sym_traceless(
            ve[..., :, None] * jnp.broadcast_to(y1, ve.shape)[..., None, :]
        )
        m_t += wp["vv_t"][..., None, None] * outer
        m_t += wp["tt_t"][..., None, None] * _sym_traceless(
            jnp.einsum("ehij,ejk->ehik", te, y2c)
        )
        return m_s, m_v, m_t

    if ne > edge_chunk:
        n_chunks = -(-ne // edge_chunk)
        pad = n_chunks * edge_chunk - ne
        if pad:
            zpad = lambda x: jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0
            )
            senders, receivers = zpad(senders), zpad(receivers)
            rhat, y2, rbf = zpad(rhat), zpad(y2), zpad(rbf)
        rs = lambda x: x.reshape((n_chunks, edge_chunk) + x.shape[1:])
        xs = (rs(senders), rs(receivers), rs(rhat), rs(y2), rs(rbf))

        @jax.checkpoint
        def chunk_body(carry, x):
            a_s, a_v, a_t = carry
            sd, rc, rh, y2c, rb = x
            m_s, m_v, m_t = messages(sd, rh, y2c, rb)
            a_s = a_s + jax.ops.segment_sum(m_s, rc, num_segments=n_nodes)
            a_v = a_v + jax.ops.segment_sum(m_v, rc, num_segments=n_nodes)
            a_t = a_t + jax.ops.segment_sum(m_t, rc, num_segments=n_nodes)
            return (a_s, a_v, a_t), None

        init = (jnp.zeros_like(s), jnp.zeros_like(v), jnp.zeros_like(t))
        (agg_s, agg_v, agg_t), _ = jax.lax.scan(chunk_body, init, xs)
    else:
        m_s, m_v, m_t = messages(senders, rhat, y2, rbf)
        agg_s = jax.ops.segment_sum(m_s, receivers, num_segments=n_nodes)
        agg_v = jax.ops.segment_sum(m_v, receivers, num_segments=n_nodes)
        agg_t = jax.ops.segment_sum(m_t, receivers, num_segments=n_nodes)

    cs = jnp.concatenate([s, agg_s], axis=-1)
    cv = jnp.concatenate([v, agg_v], axis=1)
    ct = jnp.concatenate([t, agg_t], axis=1)
    new_s = mix(cs, lp["lin"]["w_s"], None)
    new_v = jnp.moveaxis(mix(jnp.moveaxis(cv, 1, -1).reshape(n_nodes * 3, -1),
                             lp["lin"]["w_v"], None).reshape(n_nodes, 3, -1), -1, 1)
    new_t = jnp.moveaxis(mix(jnp.moveaxis(ct, 1, -1).reshape(n_nodes * 9, -1),
                             lp["lin"]["w_t"], None).reshape(n_nodes, 3, 3, -1), -1, 1)
    # gate halves mixed separately: psum_scatter hands each shard a
    # CONTIGUOUS output slice, so the [v-gates | t-gates] layout must be
    # scattered per half to land on the right channel block
    h_full_out = lp["lin"]["w_gate"].shape[1] // 2
    g_v = jax.nn.sigmoid(mix(cs, lp["lin"]["w_gate"][:, :h_full_out], None))
    g_t = jax.nn.sigmoid(mix(cs, lp["lin"]["w_gate"][:, h_full_out:], None))
    return {
        "s": s + jax.nn.silu(new_s),
        "v": v + g_v[..., None] * new_v,
        "t": t + g_t[..., None, None] * new_t,
    }


def forward(
    params,
    cfg: GNNConfig,
    positions: jax.Array,        # (N, 3)
    node_attr: jax.Array,        # (N,) species int OR (N, d_feat) float
    senders: jax.Array,          # (E,)
    receivers: jax.Array,        # (E,)
    edge_mask: Optional[jax.Array] = None,   # (E,) padding mask
    node_mask: Optional[jax.Array] = None,   # (N,) padding mask
    graph_ids: Optional[jax.Array] = None,   # (N,) for batched small graphs
    n_graphs: int = 1,
    feat_spec=None,                          # PartitionSpec for (N, ...) feats
    remat: bool = False,                     # checkpoint each interaction block
    interact_fn=None,                        # e.g. make_sharded_interact(mesh)
) -> jax.Array:
    """Per-graph potential energies (n_graphs,)."""
    n_nodes = positions.shape[0]
    h = cfg.d_hidden
    if node_attr.ndim == 1:
        s = jnp.take(params["embed"], node_attr % params["embed"].shape[0], axis=0)
    else:
        s = node_attr @ params["embed"]
    feats = {
        "s": s,
        "v": jnp.zeros((n_nodes, h, 3), s.dtype),
        "t": jnp.zeros((n_nodes, h, 3, 3), s.dtype),
    }

    def _constrain(f):
        if feat_spec is None:
            return f
        import jax.sharding as shd
        # feat_spec is the (possibly multi-axis) sharding of the NODE dim
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x, shd.PartitionSpec(feat_spec, *((None,) * (x.ndim - 1)))
            ),
            f,
        )

    feats = _constrain(feats)
    rhat, y2, rbf = _edge_geometry(positions, senders, receivers, cfg)
    if edge_mask is not None:
        rbf = rbf * edge_mask[:, None]
    block = interact_fn if interact_fn is not None else _interact
    if remat:
        block = jax.checkpoint(block, static_argnums=(7, 8))
    for lp in params["layers"]:
        feats = _constrain(
            block(lp, feats, senders, receivers, rhat, y2, rbf, n_nodes, h)
        )
    node_e = jax.nn.silu(feats["s"] @ params["readout1"]) @ params["readout2"]
    node_e = node_e[:, 0]
    if node_mask is not None:
        node_e = node_e * node_mask
    if graph_ids is None:
        return jnp.sum(node_e, keepdims=True)
    return jax.ops.segment_sum(node_e, graph_ids, num_segments=n_graphs)


def energy_and_forces(params, cfg: GNNConfig, positions, node_attr, senders, receivers, **kw):
    """Forces = -dE/dpositions (autodiff through the whole network)."""
    def e_total(pos):
        return forward(params, cfg, pos, node_attr, senders, receivers, **kw).sum()

    e, grad = jax.value_and_grad(e_total)(positions)
    return e, -grad


def energy_mse_loss(params, cfg: GNNConfig, batch, n_graphs: int = 1,
                    feat_spec=None, remat: bool = False,
                    interact_fn=None) -> jax.Array:
    """MSE on per-graph energies. ``n_graphs`` is static (segment count)."""
    e = forward(
        params, cfg,
        batch["positions"], batch["node_attr"],
        batch["senders"], batch["receivers"],
        edge_mask=batch.get("edge_mask"),
        node_mask=batch.get("node_mask"),
        graph_ids=batch.get("graph_ids"),
        n_graphs=n_graphs,
        feat_spec=feat_spec,
        remat=remat,
        interact_fn=interact_fn,
    )
    target = batch["energy"]
    return jnp.mean((e - target) ** 2)

"""Cross-encoder scorer: f(q, i) = head(T(concat(q, [SEP], i))).

The CE jointly encodes the query-item token sequence (bidirectionally, as
entity-linking CEs do) and reads a scalar score off the [CLS] position.
This is the paper's f_theta; any LM backbone from the model zoo can serve.
The bulk-scoring entry points below are what the ADACUR engine and the
offline R_anc indexer call.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from . import layers, transformer


def init_cross_encoder(key, cfg: LMConfig):
    k_lm, k_head = jax.random.split(key)
    params, specs = transformer.init_lm(k_lm, cfg)
    params["score_head"], specs["score_head"] = layers.dense_init(
        k_head, (cfg.d_model, 1), ("embed", "unit"), scale=0.02
    )
    return params, specs


def score_tokens(
    params,
    pair_tokens: jax.Array,          # (B, L) int32, [CLS] q [SEP] i [SEP]
    cfg: LMConfig,
    pad_id: int = 0,
    moe_fn=None,
    attn_impl: str = "ref",          # "flash": Pallas kernel, SMEM varlen mask
    flash_block=(128, 128),
    flash_interpret: bool = True,
) -> jax.Array:
    """Exact CE score for a batch of already-concatenated pairs -> (B,).

    Pair tokens are valid-first with trailing ``pad_id`` padding (what
    ``ZeshelLikeDataset.pair_tokens`` + bucket padding produce), so the
    flash path can mask per-example lengths instead of a (B, L) key mask.
    """
    kv_mask = pair_tokens != pad_id
    h, _ = transformer.encode(
        params, pair_tokens, cfg, kv_mask=kv_mask, moe_fn=moe_fn,
        attn_impl=attn_impl, flash_block=flash_block,
        flash_interpret=flash_interpret,
    )
    cls = h[:, 0, :].astype(jnp.float32)
    return (cls @ params["score_head"].astype(jnp.float32))[:, 0]


def build_pair_tokens(
    query_tokens: jax.Array,         # (B, Lq) int32, no internal padding
    item_tokens: jax.Array,          # (B, K, Li) int32, no internal padding
    *,
    pad_to: int,                     # bucket length >= Lq + Li + 3
    cls_id: int = 1,
    sep_id: int = 2,
    pad_id: int = 0,
) -> jax.Array:
    """In-trace pair assembly: ``[CLS] q [SEP] i [SEP]`` -> (B, K, pad_to).

    The traced counterpart of a host-side ``pair_fn``: device-resident
    scorers gather corpus token rows on device and concatenate them here,
    inside the engine's compiled program.  Inputs are valid-first fixed
    length, so the output keeps the trailing-padding contract
    :func:`score_tokens` relies on for per-example length masking.
    """
    b, lq = query_tokens.shape
    _, k, li = item_tokens.shape
    length = lq + li + 3
    if pad_to < length:
        raise ValueError(f"pad_to={pad_to} cannot hold a pair of length {length}")
    q = jnp.broadcast_to(query_tokens[:, None, :], (b, k, lq)).astype(jnp.int32)
    fill = lambda tok, n: jnp.full((b, k, n), tok, jnp.int32)
    return jnp.concatenate(
        [
            fill(cls_id, 1), q, fill(sep_id, 1),
            item_tokens.astype(jnp.int32), fill(sep_id, 1),
            fill(pad_id, pad_to - length),
        ],
        axis=-1,
    )


def score_pairs(
    params,
    pair_tokens: jax.Array,          # (B, K, L) — K items per query
    cfg: LMConfig,
    pad_id: int = 0,
    moe_fn=None,
    attn_impl: str = "ref",
    flash_block=(128, 128),
    flash_interpret: bool = True,
) -> jax.Array:
    """(B, K) scores: flattens the item axis into the CE batch."""
    b, k, l = pair_tokens.shape
    flat = score_tokens(
        params, pair_tokens.reshape(b * k, l), cfg, pad_id, moe_fn,
        attn_impl=attn_impl, flash_block=flash_block,
        flash_interpret=flash_interpret,
    )
    return flat.reshape(b, k)


def ranking_loss(
    params,
    pair_tokens: jax.Array,          # (B, K, L) — item 0 is the gold item
    cfg: LMConfig,
    pad_id: int = 0,
) -> jax.Array:
    """In-batch softmax ranking loss used by the end-to-end CE trainer."""
    scores = score_pairs(params, pair_tokens, cfg, pad_id)      # (B, K)
    logp = jax.nn.log_softmax(scores, axis=-1)
    return -logp[:, 0].mean()

"""Decoder/encoder transformer LM: init, encode (train/prefill), decode.

Pure-functional, MaxText-style:

- layer parameters are STACKED (leading ``layers`` axis) and iterated with
  ``lax.scan`` — keeps the HLO size O(1) in depth (essential for 80-layer
  dry-run compiles) and composes with ``jax.checkpoint`` remat;
- every init returns (params, specs) where specs carry logical axis names
  (``embed``/``heads``/``kv_heads``/``mlp``/``vocab``/``expert``/``layers``)
  mapped to mesh axes by ``repro.distributed.sharding``;
- MoE layers run expert-parallel via shard_map when ``ep_axis`` is given
  (see ``repro.models.moe``); dense-prefix layers (Moonlight's first dense
  block) are unrolled separately from the scanned homogeneous stack;
- decode keeps a per-layer KV cache; the attention core is pluggable so the
  distributed sequence-parallel flash-decode (``repro.distributed``) can be
  swapped in for the local reference.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import LMConfig
from . import layers, moe as moe_lib


def _dtype(cfg: LMConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def padded_vocab(cfg: LMConfig) -> int:
    """Vocab rows padded to a shardable multiple (512 covers every mesh axis
    combination used here); padded logits are masked in lm_logits.  Standard
    TPU practice — pjit rejects uneven input shardings."""
    return (cfg.vocab_size + 511) // 512 * 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _norm_init(cfg: LMConfig, d: int):
    if cfg.norm == "layernorm":
        return {
            "w": layers.ones_init((d,), ("embed",)),
            "b": layers.zeros_init((d,), ("embed",)),
        }
    return {"w": layers.ones_init((d,), ("embed",))}


def _apply_norm(cfg: LMConfig, p, x):
    if cfg.norm == "layernorm":
        return layers.layernorm(x, p["w"], p["b"])
    return layers.rmsnorm(x, p["w"], cfg.rms_eps)


def _layer_init(key, cfg: LMConfig, use_moe: bool):
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    dt = _dtype(cfg)
    attn: Dict[str, Any] = {
        "wq": layers.dense_init(ks[0], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim"), dtype=dt),
        "wk": layers.dense_init(ks[1], (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wv": layers.dense_init(ks[2], (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim"), dtype=dt),
        "wo": layers.dense_init(ks[3], (cfg.n_heads, hd, d), ("heads", "head_dim", "embed"), dtype=dt),
    }
    if cfg.qkv_bias:
        attn["bq"] = layers.zeros_init((cfg.n_heads, hd), ("heads", "head_dim"), dtype=dt)
        attn["bk"] = layers.zeros_init((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), dtype=dt)
        attn["bv"] = layers.zeros_init((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"), dtype=dt)
    if cfg.qk_norm:
        attn["q_norm"] = layers.ones_init((hd,), ("head_dim",))
        attn["k_norm"] = layers.ones_init((hd,), ("head_dim",))
    out = {
        "attn": attn,
        "ln1": _norm_init(cfg, d),
        "ln2": _norm_init(cfg, d),
    }
    if use_moe:
        out["moe"] = moe_lib.moe_init(ks[4], d, cfg.moe, dtype=dt)
    else:
        d_ff = cfg.d_ff if cfg.moe is None else (cfg.moe.d_ff_dense or cfg.d_ff)
        out["mlp"] = layers.mlp_init(ks[4], d, d_ff, cfg.act, dtype=dt)
        if cfg.mlp_bias:
            out["mlp"]["bu"] = layers.zeros_init((d_ff,), ("mlp",), dtype=dt)
            out["mlp"]["bd"] = layers.zeros_init((d,), ("embed",), dtype=dt)
    return layers.split_tree(out)


def init_lm(key, cfg: LMConfig):
    """Returns (params, specs) with stacked scanned layers."""
    dt = _dtype(cfg)
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    n_prefix = cfg.moe.first_k_dense if cfg.moe is not None else 0
    n_scan = cfg.n_layers - n_prefix

    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}
    params["embed"], specs["embed"] = layers.dense_init(
        k_emb, (padded_vocab(cfg), cfg.d_model), ("vocab", "embed"), scale=0.02, dtype=dt
    )
    # dense-prefix layers (unrolled)
    if n_prefix:
        pk = jax.random.split(k_layers, n_prefix + 1)
        prefix = [_layer_init(pk[i], cfg, use_moe=False) for i in range(n_prefix)]
        params["prefix"] = [p for p, _ in prefix]
        specs["prefix"] = [s for _, s in prefix]
        k_layers = pk[-1]
    # scanned homogeneous stack
    scan_keys = jax.random.split(k_layers, n_scan)
    use_moe = cfg.moe is not None
    params["layers"] = jax.vmap(lambda k: _layer_init(k, cfg, use_moe)[0])(scan_keys)
    one_spec = _layer_init(scan_keys[0], cfg, use_moe)[1]
    specs["layers"] = jax.tree.map(
        lambda s: ("layers",) + s, one_spec, is_leaf=lambda s: isinstance(s, tuple)
    )
    params["final_norm"], specs["final_norm"] = layers.split_tree(
        {"n": _norm_init(cfg, cfg.d_model)}
    )
    params["final_norm"] = params["final_norm"]["n"]
    specs["final_norm"] = specs["final_norm"]["n"]
    if not cfg.tie_embeddings:
        params["lm_head"], specs["lm_head"] = layers.dense_init(
            k_head, (cfg.d_model, padded_vocab(cfg)), ("embed", "vocab"), scale=0.02, dtype=dt
        )
    return params, specs


# ---------------------------------------------------------------------------
# attention sub-block shared by encode/decode
# ---------------------------------------------------------------------------


def _project_qkv(cfg: LMConfig, attn, x, positions):
    q = jnp.einsum("...d,dhk->...hk", x, attn["wq"])
    k = jnp.einsum("...d,dhk->...hk", x, attn["wk"])
    v = jnp.einsum("...d,dhk->...hk", x, attn["wv"])
    if cfg.qkv_bias:
        q, k, v = q + attn["bq"], k + attn["bk"], v + attn["bv"]
    if cfg.qk_norm:
        q = layers.rmsnorm(q, attn["q_norm"], cfg.rms_eps)
        k = layers.rmsnorm(k, attn["k_norm"], cfg.rms_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mlp_block(cfg: LMConfig, layer_params, x2d, moe_fn):
    if "moe" in layer_params:
        fn = moe_fn if moe_fn is not None else (
            lambda p, x: moe_lib.moe_apply_local(p, x, cfg.moe)
        )
        return fn(layer_params["moe"], x2d)
    p = layer_params["mlp"]
    if cfg.mlp_bias:
        h = jax.nn.gelu(x2d @ p["wu"] + p["bu"], approximate=True)
        return h @ p["wd"] + p["bd"], jnp.zeros((), jnp.float32)
    return layers.mlp_apply(p, x2d, cfg.act), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# encode: training / prefill forward pass
# ---------------------------------------------------------------------------


def _constrain(h, act_spec):
    if act_spec is not None:
        h = jax.lax.with_sharding_constraint(h, act_spec)
    return h


def _encode_layer(cfg: LMConfig, moe_fn, attn_fn, act_spec, attn_spec, h, layer_params, positions, kv_mask):
    b, l, d = h.shape
    x = _apply_norm(cfg, layer_params["ln1"], h)
    q, k, v = _project_qkv(cfg, layer_params["attn"], x, positions)
    # inside the attention block activations shard by HEADS (Megatron TP);
    # the residual stream outside shards by sequence — GSPMD inserts the
    # boundary all-to-alls.
    q = _constrain(q, attn_spec)
    attn_out = attn_fn(q, k, v, kv_mask)
    attn_out = _constrain(attn_out, attn_spec)
    h = h + jnp.einsum("...hk,hkd->...d", attn_out, layer_params["attn"]["wo"])
    x2 = _apply_norm(cfg, layer_params["ln2"], h).reshape(b * l, d)
    ffn, aux = _mlp_block(cfg, layer_params, x2, moe_fn)
    # Megatron-style sequence sharding of the residual stream between layers:
    # the remat-saved per-layer carry shrinks by the model-axis size (86 GB ->
    # 5.4 GB/device on qwen1.5-110b train_4k); attention re-gathers KV only.
    return _constrain(h + ffn.reshape(b, l, d), act_spec), (k, v, aux)


def encode(
    params,
    tokens: jax.Array,                 # (B, L) int32
    cfg: LMConfig,
    *,
    positions: Optional[jax.Array] = None,
    kv_mask: Optional[jax.Array] = None,   # (B, L) valid-token mask
    moe_fn: Optional[Callable] = None,      # sharded MoE closure (repro.models.moe)
    q_chunk: int = 1024,
    return_kv: bool = False,
    act_spec=None,                          # PartitionSpec for the residual stream
    attn_spec=None,                         # PartitionSpec for (B, L, H, hd)
    attn_impl: str = "ref",                 # "ref" | "flash" (Pallas kernel)
    flash_block: Tuple[int, int] = (128, 128),
    flash_interpret: bool = True,           # interpret-mode Pallas (CPU)
):
    """Full forward pass. Returns (hidden (B,L,d), aux_loss[, kv caches]).

    ``attn_impl='flash'`` routes the attention core through the Pallas
    flash-attention kernel.  ``kv_mask`` must then describe *trailing*
    padding only (valid tokens first) — it is collapsed to a per-example
    valid length that rides in SMEM; the CE pair tokenizer produces exactly
    this layout.
    """
    b, l = tokens.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(l)[None, :], (b, l))
    h = _constrain(params["embed"][tokens].astype(_dtype(cfg)), act_spec)

    if attn_impl == "flash":
        from ..kernels.flash_attention.kernel import flash_attention

        def attn_fn(q, k, v, mask):
            return flash_attention(
                q, k, v, causal=cfg.causal,
                block_q=flash_block[0], block_k=flash_block[1],
                interpret=flash_interpret,
                kv_lens=None if mask is None else mask.sum(-1).astype(jnp.int32),
            )
    elif attn_impl == "ref":
        def attn_fn(q, k, v, mask):
            return layers.attention_ref(
                q, k, v, causal=cfg.causal, q_chunk=q_chunk, kv_mask=mask
            )
    else:
        raise ValueError(f"unknown attn_impl '{attn_impl}' (ref|flash)")

    layer_fn = partial(_encode_layer, cfg, moe_fn, attn_fn, act_spec, attn_spec)
    if cfg.remat:
        layer_fn = jax.checkpoint(layer_fn, static_argnums=())

    aux_total = jnp.zeros((), jnp.float32)
    kvs = []
    for p in params.get("prefix", []):
        h, (k, v, aux) = layer_fn(h, p, positions, kv_mask)
        aux_total += aux
        if return_kv:
            kvs.append((k, v))

    def scan_body(carry, lp):
        h, aux_sum = carry
        h, (k, v, aux) = layer_fn(h, lp, positions, kv_mask)
        return (h, aux_sum + aux), (k, v) if return_kv else None

    (h, aux_total), scan_kv = jax.lax.scan(
        scan_body, (h, aux_total), params["layers"]
    )
    h = _apply_norm(cfg, params["final_norm"], h)
    if return_kv:
        return h, aux_total, (kvs, scan_kv)
    return h, aux_total


def lm_logits(params, hidden, cfg: LMConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("...d,dv->...v", hidden, head)
    pv = padded_vocab(cfg)
    if pv != cfg.vocab_size:   # suppress the padded vocab rows
        mask = jnp.arange(pv) < cfg.vocab_size
        logits = jnp.where(mask, logits, jnp.asarray(-1e30, logits.dtype))
    return logits


# ---------------------------------------------------------------------------
# decode: KV-cached single-token step
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    """KV cache pytree: stacked (n_scan, B, S, n_kv, hd) + prefix list."""
    dt = dtype or _dtype(cfg)
    hd = cfg.resolved_head_dim
    n_prefix = cfg.moe.first_k_dense if cfg.moe is not None else 0
    n_scan = cfg.n_layers - n_prefix
    shape = (batch, max_len, cfg.n_kv_heads, hd)
    cache = {
        "k": jnp.zeros((n_scan,) + shape, dt),
        "v": jnp.zeros((n_scan,) + shape, dt),
    }
    if n_prefix:
        cache["prefix"] = [
            {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)}
            for _ in range(n_prefix)
        ]
    return cache


def _local_decode_core(q, k_new, v_new, ck, cv, pos):
    """Single-shard decode core: write new KV at ``pos``, attend over cache."""
    ck = jax.lax.dynamic_update_slice(ck, k_new[:, None], (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v_new[:, None], (0, pos, 0, 0))
    num, den, m = layers.decode_attention_local(
        q, ck, cv, shard_offset=jnp.zeros((), jnp.int32), kv_len=pos + 1
    )
    return (num / (den[..., None] + 1e-30)).astype(q.dtype), ck, cv


def _decode_layer(cfg, moe_fn, decode_core, h, lp, ck, cv, pos):
    """One decode layer. h: (B, d); ck/cv: (B, S, KV, hd).

    ``decode_core`` is pluggable: the local reference above, or the
    sequence-parallel shard_map core from repro.distributed."""
    x = _apply_norm(cfg, lp["ln1"], h)
    q, k, v = _project_qkv(cfg, lp["attn"], x[:, None, :], pos[None, None])
    o, ck, cv = decode_core(q[:, 0], k[:, 0], v[:, 0], ck, cv, pos)  # (B,H,hd)
    h = h + jnp.einsum("bhk,hkd->bd", o, lp["attn"]["wo"])
    x2 = _apply_norm(cfg, lp["ln2"], h)
    ffn, _ = _mlp_block(cfg, lp, x2, moe_fn)
    return h + ffn, ck, cv


def decode_step(
    params,
    cache,
    token: jax.Array,      # (B,) int32 — the newest token
    pos: jax.Array,        # () int32 — its position
    cfg: LMConfig,
    *,
    moe_fn: Optional[Callable] = None,
    decode_core: Callable = _local_decode_core,
):
    """One autoregressive step: returns (logits (B, V), updated cache)."""
    h = params["embed"][token].astype(_dtype(cfg))
    layer = partial(_decode_layer, cfg, moe_fn, decode_core)

    new_cache = dict(cache)
    if "prefix" in cache:
        new_prefix = []
        for lp, c in zip(params["prefix"], cache["prefix"]):
            h, ck, cv = layer(h, lp, c["k"], c["v"], pos)
            new_prefix.append({"k": ck, "v": cv})
        new_cache["prefix"] = new_prefix

    def scan_body(h, xs):
        lp, ck, cv = xs
        h, ck, cv = layer(h, lp, ck, cv, pos)
        return h, (ck, cv)

    h, (ks, vs) = jax.lax.scan(scan_body, h, (params["layers"], cache["k"], cache["v"]))
    new_cache["k"], new_cache["v"] = ks, vs
    h = _apply_norm(cfg, params["final_norm"], h)
    return lm_logits(params, h, cfg), new_cache

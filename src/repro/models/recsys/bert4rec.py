"""BERT4Rec  [arXiv:1904.06690]: bidirectional transformer over the
interaction sequence.

Two scoring modes:
- ``user_logits``: the standard masked-position prediction (factorized
  output layer tied to the item table) — the cheap retriever;
- ``score_candidates``: candidate-conditioned joint scoring — the candidate
  replaces the [MASK] slot and a coherence head reads a scalar off the
  sequence, one full transformer pass per (user, item) pair.  This is the
  cross-encoder-class re-ranker mode ADACUR accelerates (DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecSysConfig
from .. import layers

MASK_SLOT = 0  # candidate/[MASK] occupies the final position


def init_bert4rec(key, cfg: RecSysConfig):
    d = cfg.embed_dim
    ks = jax.random.split(key, 4 + cfg.n_blocks)
    params = {}
    specs = {}
    # row 0 of the item table doubles as the [MASK] embedding
    n_rows = (cfg.n_items + 1 + 511) // 512 * 512   # pad to shardable multiple
    params["item_emb"], specs["item_emb"] = layers.dense_init(
        ks[0], (n_rows, d), ("table_rows", "embed"), scale=0.05
    )
    params["pos_emb"], specs["pos_emb"] = layers.dense_init(
        ks[1], (cfg.seq_len + 1, d), ("seq", "embed"), scale=0.05
    )
    blocks = []
    bspecs = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + i], 6)
        hd = d // cfg.n_heads
        blk = {
            "wq": layers.dense_init(kb[0], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
            "wk": layers.dense_init(kb[1], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
            "wv": layers.dense_init(kb[2], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
            "wo": layers.dense_init(kb[3], (cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
            "ln1": layers.ones_init((d,), ("embed",)),
            "ln1b": layers.zeros_init((d,), ("embed",)),
            "ffn_w1": layers.dense_init(kb[4], (d, cfg.mlp_dims[0]), ("embed", "mlp")),
            "ffn_b1": layers.zeros_init((cfg.mlp_dims[0],), ("mlp",)),
            "ffn_w2": layers.dense_init(kb[5], (cfg.mlp_dims[0], d), ("mlp", "embed")),
            "ffn_b2": layers.zeros_init((d,), ("embed",)),
            "ln2": layers.ones_init((d,), ("embed",)),
            "ln2b": layers.zeros_init((d,), ("embed",)),
        }
        p, s = layers.split_tree(blk)
        blocks.append(p)
        bspecs.append(s)
    params["blocks"] = blocks
    specs["blocks"] = bspecs
    params["score_head"], specs["score_head"] = layers.dense_init(
        ks[-1], (d, 1), ("embed", "unit"), scale=0.02
    )
    return params, specs


def _block(blk, x):
    q = jnp.einsum("bld,dhk->blhk", x, blk["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, blk["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, blk["wv"])
    o = layers.attention_ref(q, k, v, causal=False)
    x = layers.layernorm(x + jnp.einsum("blhk,hkd->bld", o, blk["wo"]), blk["ln1"], blk["ln1b"])
    h = jax.nn.gelu(x @ blk["ffn_w1"] + blk["ffn_b1"]) @ blk["ffn_w2"] + blk["ffn_b2"]
    return layers.layernorm(x + h, blk["ln2"], blk["ln2b"])


def _encode(params, seq: jax.Array):
    """seq (B, L+1) item ids (0 = [MASK]) -> hidden (B, L+1, d)."""
    x = jnp.take(params["item_emb"], seq, axis=0) + params["pos_emb"][None]
    for blk in params["blocks"]:
        x = _block(blk, x)
    return x


def user_logits(params, history: jax.Array, cfg: RecSysConfig):
    """Standard BERT4Rec: [MASK] appended, logits = h_mask @ item_emb^T."""
    b = history.shape[0]
    seq = jnp.concatenate(
        [history, jnp.zeros((b, 1), history.dtype)], axis=1
    )
    h = _encode(params, seq)[:, -1, :]                  # masked position
    logits = h @ params["item_emb"][1:].T               # skip [MASK] row
    pad_mask = jnp.arange(logits.shape[-1]) < cfg.n_items  # hide pad rows
    return jnp.where(pad_mask, logits, -1e30)


def score_candidates(params, history: jax.Array, cand: jax.Array, cfg: RecSysConfig):
    """Joint mode: candidate fills the [MASK] slot; scalar coherence score.

    history (B, L), cand (B, K) -> (B, K); K full transformer passes/query.
    """
    b, k = cand.shape
    hist_r = jnp.repeat(history, k, axis=0)             # (B*K, L)
    seq = jnp.concatenate([hist_r, cand.reshape(-1, 1) + 1], axis=1)
    h = _encode(params, seq)
    pooled = h.mean(axis=1)
    return (pooled @ params["score_head"])[:, 0].reshape(b, k)


def mlm_loss(params, history: jax.Array, target: jax.Array, cfg: RecSysConfig,
             n_neg: int = 512, key=None):
    """Masked-item prediction with SAMPLED softmax — full softmax over the
    1M-item vocabulary would materialize (B, N) logits (262 GB at the
    train_batch shape); uniform negative sampling is standard BERT4Rec
    practice at catalog scale."""
    b = history.shape[0]
    if key is None:
        key = jax.random.PRNGKey(0)
    seq = jnp.concatenate([history, jnp.zeros((b, 1), history.dtype)], axis=1)
    h = _encode(params, seq)[:, -1, :]                     # (B, d)
    neg = jax.random.randint(key, (b, n_neg), 0, cfg.n_items)
    e_pos = jnp.take(params["item_emb"], target + 1, axis=0)
    e_neg = jnp.take(params["item_emb"], neg + 1, axis=0)
    pos = jnp.einsum("bd,bd->b", h, e_pos)
    negs = jnp.einsum("bd,bmd->bm", h, e_neg)
    logits = jnp.concatenate([pos[:, None], negs], axis=1)
    return -jax.nn.log_softmax(logits, axis=-1)[:, 0].mean()

from . import bert4rec, bst, dlrm, embedding, mind  # noqa: F401

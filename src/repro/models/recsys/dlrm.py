"""DLRM (MLPerf config): bottom MLP -> embedding lookups -> dot interaction
-> top MLP  [arXiv:1906.00091].

The (dense-features, sparse-ids) pair is a joint scorer: the dot-interaction
mixes query-side and item-side features non-factorizably, making DLRM a
cross-encoder-class model for ADACUR (DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ...configs.base import RecSysConfig
from .. import layers
from . import embedding


def _mlp_init(key, dims, prefix, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    params = {}
    specs = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        params[f"{prefix}{i}_w"], specs[f"{prefix}{i}_w"] = layers.dense_init(
            keys[i], (din, dout), ("mlp_in", "mlp_out"), dtype=dtype
        )
        params[f"{prefix}{i}_b"], specs[f"{prefix}{i}_b"] = layers.zeros_init(
            (dout,), ("mlp_out",), dtype=dtype
        )
    return params, specs


def _mlp_apply(params, prefix, x, n, final_act=False):
    for i in range(n):
        x = x @ params[f"{prefix}{i}_w"] + params[f"{prefix}{i}_b"]
        if i < n - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def init_dlrm(key, cfg: RecSysConfig):
    kb, kt, ke = jax.random.split(key, 3)
    params: Dict = {}
    specs: Dict = {}
    params["bot"], specs["bot"] = _mlp_init(kb, cfg.bot_mlp, "b")
    n_int = cfg.n_sparse + 1
    d_inter = n_int * (n_int - 1) // 2 + cfg.bot_mlp[-1]
    top_dims = (d_inter,) + tuple(cfg.top_mlp[1:])
    params["top"], specs["top"] = _mlp_init(kt, top_dims, "t")
    params["tables"], specs["tables"] = embedding.init_tables(
        ke, cfg.table_sizes, cfg.embed_dim
    )
    return params, specs


def forward(params, dense: jax.Array, sparse_ids: jax.Array, cfg: RecSysConfig):
    """dense (B, 13) float, sparse_ids (B, 26) int -> (B,) logit."""
    bot = _mlp_apply(params["bot"], "b", dense, len(cfg.bot_mlp) - 1, final_act=True)
    emb = embedding.lookup_all_tables(params["tables"], sparse_ids)   # (B, F, D)
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)            # (B, F+1, D)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)                   # (B, F+1, F+1)
    n = feats.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    flat = inter[:, iu, ju]                                            # (B, n(n-1)/2)
    x = jnp.concatenate([bot, flat], axis=1)
    return _mlp_apply(params["top"], "t", x, len(cfg.top_mlp) - 1)[:, 0]


def bce_loss(params, dense, sparse_ids, labels, cfg: RecSysConfig):
    logits = forward(params, dense, sparse_ids, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def score_candidates(params, dense: jax.Array, sparse_ids: jax.Array,
                     cand_sparse: jax.Array, cfg: RecSysConfig):
    """ADACUR bulk scorer: one query context vs K candidate items.

    The candidate item occupies sparse field 0 (the 'item id' table in the
    MLPerf layout); the query context supplies dense + remaining fields.

    dense (B, 13); sparse_ids (B, 26); cand_sparse (B, K) -> (B, K).
    """
    b, k = cand_sparse.shape
    dense_r = jnp.repeat(dense, k, axis=0)
    sparse_r = jnp.repeat(sparse_ids, k, axis=0)
    sparse_r = sparse_r.at[:, 0].set(cand_sparse.reshape(-1))
    return forward(params, dense_r, sparse_r, cfg).reshape(b, k)

"""Behavior Sequence Transformer  [arXiv:1905.06874].

The target item is appended to the user behaviour sequence BEFORE the
transformer block, so each (user, item) score is a joint forward pass —
a genuine cross-encoder-class scorer (ADACUR target, DESIGN.md §4).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecSysConfig
from .. import layers
from . import embedding as emb_lib


def init_bst(key, cfg: RecSysConfig):
    d = cfg.embed_dim
    ks = jax.random.split(key, 10)
    params = {}
    specs = {}
    n_rows = (cfg.n_items + 511) // 512 * 512   # pad to shardable multiple
    params["item_emb"], specs["item_emb"] = layers.dense_init(
        ks[0], (n_rows, d), ("table_rows", "embed"), scale=0.05
    )
    params["pos_emb"], specs["pos_emb"] = layers.dense_init(
        ks[1], (cfg.seq_len + 1, d), ("seq", "embed"), scale=0.05
    )
    # one post-LN transformer block (paper: n_blocks=1)
    blocks = []
    bspecs = []
    for i in range(cfg.n_blocks):
        kb = jax.random.split(ks[2 + i], 6)
        hd = d // cfg.n_heads
        blk = {
            "wq": layers.dense_init(kb[0], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
            "wk": layers.dense_init(kb[1], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
            "wv": layers.dense_init(kb[2], (d, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
            "wo": layers.dense_init(kb[3], (cfg.n_heads, hd, d), ("heads", "head_dim", "embed")),
            "ln1": layers.ones_init((d,), ("embed",)),
            "ln1b": layers.zeros_init((d,), ("embed",)),
            "ffn_w1": layers.dense_init(kb[4], (d, 4 * d), ("embed", "mlp")),
            "ffn_w2": layers.dense_init(kb[5], (4 * d, d), ("mlp", "embed")),
            "ln2": layers.ones_init((d,), ("embed",)),
            "ln2b": layers.zeros_init((d,), ("embed",)),
        }
        p, s = layers.split_tree(blk)
        blocks.append(p)
        bspecs.append(s)
    params["blocks"] = blocks
    specs["blocks"] = bspecs
    mlp_dims = (d * (cfg.seq_len + 1),) + tuple(cfg.mlp_dims) + (1,)
    mkeys = jax.random.split(ks[9], len(mlp_dims))
    for i, (din, dout) in enumerate(zip(mlp_dims[:-1], mlp_dims[1:])):
        params[f"mlp{i}_w"], specs[f"mlp{i}_w"] = layers.dense_init(
            mkeys[i], (din, dout), ("mlp_in", "mlp_out")
        )
        params[f"mlp{i}_b"], specs[f"mlp{i}_b"] = layers.zeros_init((dout,), ("mlp_out",))
    return params, specs


def _block(blk, x):
    b, l, d = x.shape
    q = jnp.einsum("bld,dhk->blhk", x, blk["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, blk["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, blk["wv"])
    o = layers.attention_ref(q, k, v, causal=False)
    x = layers.layernorm(x + jnp.einsum("blhk,hkd->bld", o, blk["wo"]), blk["ln1"], blk["ln1b"])
    h = jax.nn.leaky_relu(x @ blk["ffn_w1"]) @ blk["ffn_w2"]
    return layers.layernorm(x + h, blk["ln2"], blk["ln2b"])


def forward(params, history: jax.Array, target: jax.Array, cfg: RecSysConfig):
    """history (B, L) item ids, target (B,) item id -> (B,) logit."""
    seq = jnp.concatenate([history, target[:, None]], axis=1)      # (B, L+1)
    x = jnp.take(params["item_emb"], seq, axis=0) + params["pos_emb"][None]
    for blk in params["blocks"]:
        x = _block(blk, x)
    flat = x.reshape(x.shape[0], -1)
    n_mlp = len(cfg.mlp_dims) + 1
    for i in range(n_mlp):
        flat = flat @ params[f"mlp{i}_w"] + params[f"mlp{i}_b"]
        if i < n_mlp - 1:
            flat = jax.nn.leaky_relu(flat)
    return flat[:, 0]


def bce_loss(params, history, target, labels, cfg: RecSysConfig):
    logits = forward(params, history, target, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def score_candidates(params, history: jax.Array, cand: jax.Array, cfg: RecSysConfig):
    """ADACUR bulk scorer: history (B, L) x cand (B, K) -> (B, K) scores —
    one joint transformer pass per (user, item) pair, like a CE."""
    b, k = cand.shape
    hist_r = jnp.repeat(history, k, axis=0)
    return forward(params, hist_r, cand.reshape(-1), cfg).reshape(b, k)

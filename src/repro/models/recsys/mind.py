"""MIND: Multi-Interest Network with Dynamic routing  [arXiv:1904.08030].

Capsule (B2I dynamic routing) user encoder producing n_interests interest
vectors; item score = max_j <v_j, e_item>.  This is a dual-encoder: all-item
scores are a handful of GEMMs, so the model serves as the FIRST-ROUND anchor
retriever for ADACUR (the paper's DE_BASE role) rather than as a CE target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ...configs.base import RecSysConfig
from .. import layers


def init_mind(key, cfg: RecSysConfig):
    d = cfg.embed_dim
    ks = jax.random.split(key, 4)
    params = {}
    specs = {}
    n_rows = (cfg.n_items + 511) // 512 * 512   # pad to shardable multiple
    params["item_emb"], specs["item_emb"] = layers.dense_init(
        ks[0], (n_rows, d), ("table_rows", "embed"), scale=0.05
    )
    params["bilinear"], specs["bilinear"] = layers.dense_init(
        ks[1], (d, d), ("embed", "embed_out")
    )
    # fixed (non-trainable in paper; trainable here) routing logit init
    params["b_init"], specs["b_init"] = layers.dense_init(
        ks[2], (cfg.n_interests, cfg.seq_len), ("interest", "seq"), scale=1.0
    )
    params["proj"], specs["proj"] = layers.dense_init(
        ks[3], (d, d), ("embed", "embed_out")
    )
    return params, specs


def _squash(z):
    n2 = jnp.sum(z * z, axis=-1, keepdims=True)
    return (n2 / (1.0 + n2)) * z / jnp.sqrt(n2 + 1e-9)


def interest_vectors(params, history: jax.Array, cfg: RecSysConfig,
                     batch_spec=None):
    """B2I dynamic routing: history (B, L) -> (B, K, d) interest capsules."""
    e = jnp.take(params["item_emb"], history, axis=0)       # (B, L, d)
    if batch_spec is not None:
        # keep the (B, L, d) behaviour embeddings batch-sharded: the gather
        # from the row-sharded table otherwise replicates them (x5 buffers
        # at serve_bulk scale)
        import jax.sharding as shd
        e = jax.lax.with_sharding_constraint(
            e, shd.PartitionSpec(batch_spec, None, None)
        )
    u = e @ params["bilinear"]                              # (B, L, d)
    b_logit = jnp.broadcast_to(
        params["b_init"][None], (history.shape[0],) + params["b_init"].shape
    )                                                       # (B, K, L)
    v = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(b_logit, axis=1)                 # over capsules
        z = jnp.einsum("bkl,bld->bkd", w, u)
        v = _squash(z)
        b_logit = b_logit + jnp.einsum("bkd,bld->bkl", v, u)
    v = jax.nn.relu(v @ params["proj"]) + v
    return v


def score_all_items(params, history: jax.Array, cfg: RecSysConfig):
    """(B, N) retrieval scores: max over interests of dot products."""
    v = interest_vectors(params, history, cfg)              # (B, K, d)
    scores = jnp.einsum("bkd,nd->bkn", v, params["item_emb"]).max(axis=1)
    pad_mask = jnp.arange(scores.shape[-1]) < cfg.n_items   # hide pad rows
    return jnp.where(pad_mask, scores, -1e30)


def retrieve(params, history: jax.Array, k: int, cfg: RecSysConfig,
             item_tile: int = 16384, batch_spec=None):
    """Streaming tiled retrieval with a RUNNING top-k carry.

    At serve_bulk scale (B=262144, N=1M) the naive GEMM+top_k is a 1 TB
    temp, and even stacked per-tile top-ks are tens of GB — so the item
    tiles stream through a lax.scan whose carry is just the (B, k) running
    winners (same schedule as the approx_topk Pallas kernel)."""
    v = interest_vectors(params, history, cfg, batch_spec)  # (B, K, d)
    table = params["item_emb"]
    n_rows = table.shape[0]
    item_tile = min(item_tile, n_rows)
    n_tiles = max(1, n_rows // item_tile)
    tiles = table[: n_tiles * item_tile].reshape(n_tiles, -1, table.shape[1])
    b = history.shape[0]
    k = min(k, item_tile)

    def _bconstrain(x):
        if batch_spec is None:
            return x
        import jax.sharding as shd
        return jax.lax.with_sharding_constraint(
            x, shd.PartitionSpec(batch_spec, *((None,) * (x.ndim - 1)))
        )

    def tile_step(carry, t):
        best_v, best_i = carry
        tile, offset = t
        # constrain the (B, tile) scores batch-sharded — the fresh top-k
        # carry otherwise seeds replicated propagation (a 17 GB/device
        # buffer at serve_bulk scale)
        s = _bconstrain(jnp.einsum("bkd,nd->bkn", v, tile).max(axis=1))
        gid = offset + jnp.arange(s.shape[1])
        s = jnp.where(gid < cfg.n_items, s, -1e30)          # hide pad rows
        tv, ti = jax.lax.top_k(s, k)
        merged_v = jnp.concatenate([best_v, tv], axis=1)
        merged_i = jnp.concatenate([best_i, offset + ti], axis=1)
        best_v, pos = jax.lax.top_k(merged_v, k)
        return (_bconstrain(best_v),
                _bconstrain(jnp.take_along_axis(merged_i, pos, axis=1))), None

    init = (_bconstrain(jnp.full((b, k), -jnp.inf)),
            _bconstrain(jnp.zeros((b, k), jnp.int32)))
    offsets = jnp.arange(n_tiles) * item_tile
    (best_v, best_i), _ = jax.lax.scan(tile_step, init, (tiles, offsets))
    return best_v, best_i


def sampled_softmax_loss(params, history, target, neg_ids, cfg: RecSysConfig, pow_p: float = 2.0):
    """Label-aware attention + sampled softmax (paper's training loss)."""
    v = interest_vectors(params, history, cfg)              # (B, K, d)
    e_t = jnp.take(params["item_emb"], target, axis=0)      # (B, d)
    att = jax.nn.softmax(
        pow_p * jnp.einsum("bkd,bd->bk", v, e_t), axis=-1
    )
    u = jnp.einsum("bk,bkd->bd", att, v)                    # (B, d)
    e_neg = jnp.take(params["item_emb"], neg_ids, axis=0)   # (B, M, d)
    pos = jnp.einsum("bd,bd->b", u, e_t)
    neg = jnp.einsum("bd,bmd->bm", u, e_neg)
    logits = jnp.concatenate([pos[:, None], neg], axis=1)
    return -jax.nn.log_softmax(logits, axis=-1)[:, 0].mean()

"""EmbeddingBag and sharded embedding tables.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — the bag op here is
built from ``jnp.take`` + ``jax.ops.segment_sum`` (this IS part of the
system, per the assignment).  At pod scale the tables are row-sharded over
the whole mesh (DESIGN.md §5); the Pallas fast path for the bag gather lives
in ``repro.kernels.embedding_bag`` and is validated against this reference.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .. import layers


def embedding_bag(
    table: jax.Array,         # (rows, dim)
    indices: jax.Array,       # (n_lookups,) int32 row ids
    segment_ids: jax.Array,   # (n_lookups,) int32 bag ids, sorted or not
    n_bags: int,
    mode: str = "sum",
    weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Gather-and-reduce: out[b] = reduce_{j: seg[j]==b} table[idx[j]]."""
    emb = jnp.take(table, indices, axis=0)
    if weights is not None:
        emb = emb * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(emb, segment_ids, num_segments=n_bags)
        c = jax.ops.segment_sum(jnp.ones_like(segment_ids, emb.dtype), segment_ids, n_bags)
        return s / jnp.maximum(c, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(emb, segment_ids, num_segments=n_bags)
    raise ValueError(f"unknown mode {mode}")


def multihot_bag(
    table: jax.Array,         # (rows, dim)
    hot_ids: jax.Array,       # (B, H) int32 — H lookups per example
    mode: str = "sum",
) -> jax.Array:
    """Fixed-width multi-hot bag: (B, H) ids -> (B, dim)."""
    emb = jnp.take(table, hot_ids, axis=0)          # (B, H, dim)
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        return emb.mean(axis=1)
    if mode == "max":
        return emb.max(axis=1)
    raise ValueError(f"unknown mode {mode}")


def init_tables(key, table_sizes: Sequence[int], dim: int, dtype=jnp.float32,
                pad_to: int = 512):
    """One row-sharded table per sparse field; logical axes (table_rows, embed).

    Rows are padded to a shardable multiple (pjit rejects uneven input
    shardings); ids mod by the padded size, i.e. the pad rows just widen the
    hash space — standard row-sharded-table practice."""
    keys = jax.random.split(key, len(table_sizes))
    params, specs = [], []
    for k, rows in zip(keys, table_sizes):
        rows = (rows + pad_to - 1) // pad_to * pad_to
        p, s = layers.dense_init(k, (rows, dim), ("table_rows", "embed"),
                                 scale=1.0 / jnp.sqrt(dim), dtype=dtype)
        params.append(p)
        specs.append(s)
    return params, specs


def lookup_all_tables(tables, sparse_ids: jax.Array) -> jax.Array:
    """DLRM-style per-field single-hot lookup: ids (B, F) -> (B, F, dim).

    Ids are modded per table (the quotient-remainder hashing trick every
    production DLRM applies — raw Criteo ids exceed table cardinalities)."""
    outs = [
        jnp.take(t, sparse_ids[:, f] % t.shape[0], axis=0)
        for f, t in enumerate(tables)
    ]
    return jnp.stack(outs, axis=1)

"""Transformer building blocks: norms, RoPE, GQA attention, MLPs.

Pure-functional JAX: parameters are nested dicts, every init function
returns (params, spec) where ``spec`` mirrors the param tree with logical
axis-name tuples used by ``repro.distributed.sharding`` to build
PartitionSpecs.  The attention here is the jnp reference path (memory-safe
chunked softmax); the Pallas flash kernel in ``repro.kernels`` is the TPU
fast path and is validated against this implementation.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# init helpers: every param carries a logical-axes spec
# ---------------------------------------------------------------------------


def dense_init(key, shape, axes, scale=None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / jnp.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale, axes


def zeros_init(shape, axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype), axes


def ones_init(shape, axes, dtype=jnp.float32):
    return jnp.ones(shape, dtype), axes


def split_tree(params_and_specs):
    """{(name: (param, spec))} -> (params tree, specs tree)."""
    params = {k: (v[0] if isinstance(v, tuple) else split_tree(v)[0]) for k, v in params_and_specs.items()}
    specs = {k: (v[1] if isinstance(v, tuple) else split_tree(v)[1]) for k, v in params_and_specs.items()}
    return params, specs


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    # Sum-of-squares via a single dot with f32 ACCUMULATION (bf16 inputs):
    # one HLO op, so XLA cannot loop-hoist a full f32 copy of the stacked
    # remat-saved activations out of the backward scan — that hoisted
    # convert measured 10.7 GB/device on qwen1.5-110b train_4k.
    ss = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)
    var = ss[..., None] / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.maximum(
        jnp.mean(jnp.square(xf), axis=-1, keepdims=True) - jnp.square(mu), 0.0
    )
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return (x - mu.astype(x.dtype)) * inv * w.astype(x.dtype) + b.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,seq,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., : hd // 2], x[..., hd // 2 :]
    rotated = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (jnp reference path, chunked for long sequences)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def repeat_kv(k: jax.Array, q_per_kv: int) -> jax.Array:
    """(B, L, n_kv, hd) -> (B, L, n_kv*q_per_kv, hd) by head repetition."""
    if q_per_kv == 1:
        return k
    b, l, n_kv, hd = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, l, n_kv, q_per_kv, hd)
    ).reshape(b, l, n_kv * q_per_kv, hd)


def attention_ref(
    q: jax.Array,                 # (B, Lq, n_heads, hd)
    k: jax.Array,                 # (B, Lk, n_kv, hd)
    v: jax.Array,                 # (B, Lk, n_kv, hd)
    causal: bool,
    q_offset: int | jax.Array = 0,   # absolute position of q[0] (decode/chunks)
    kv_len: Optional[jax.Array] = None,   # valid KV length (cache masking)
    kv_mask: Optional[jax.Array] = None,  # (B, Lk) per-token validity mask
    q_chunk: int = 1024,
) -> jax.Array:
    """Exact attention, computed in query chunks to bound peak memory.

    Memory per chunk is (B, heads, q_chunk, Lk) — the full (Lq, Lk) logit
    matrix is never materialized.  GQA handled by repeating KV heads.
    """
    b, lq, n_heads, hd = q.shape
    q_per_kv = n_heads // k.shape[2]
    k = repeat_kv(k, q_per_kv)
    v = repeat_kv(v, q_per_kv)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    lk = k.shape[1]
    kv_pos = jnp.arange(lk)

    def chunk_attn(q_chunk_arr, chunk_start):
        # q_chunk_arr: (B, C, H, hd)
        logits = jnp.einsum(
            "bchd,blhd->bhcl", q_chunk_arr.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        mask = jnp.ones((q_chunk_arr.shape[1], lk), dtype=bool)
        if causal:
            q_pos = q_offset + chunk_start + jnp.arange(q_chunk_arr.shape[1])
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if kv_len is not None:
            mask &= kv_pos[None, :] < kv_len
        mask = mask[None, None]                      # (1, 1, C, Lk)
        if kv_mask is not None:
            mask = mask & kv_mask[:, None, None, :]  # (B, 1, C, Lk)
        logits = jnp.where(mask, logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        # PV matmul in the activation dtype: halves the saved-probs footprint
        # and matches TPU bf16-MXU practice (softmax itself stays f32)
        return jnp.einsum("bhcl,blhd->bchd", probs.astype(v.dtype), v)

    if lq <= q_chunk:
        out = chunk_attn(q, 0)
    else:
        n_chunks = (lq + q_chunk - 1) // q_chunk
        pad = n_chunks * q_chunk - lq
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qs = qp.reshape(b, n_chunks, q_chunk, n_heads, hd).transpose(1, 0, 2, 3, 4)
        starts = jnp.arange(n_chunks) * q_chunk
        # checkpoint each chunk: otherwise the backward of the chunk loop
        # saves every chunk's f32 probs — (chunks, B, H, C, Lk) stacked
        chunk_fn = jax.checkpoint(chunk_attn)
        outs = jax.lax.map(lambda args: chunk_fn(*args), (qs, starts))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * q_chunk, n_heads, hd)
        out = out[:, :lq]
    return out.astype(q.dtype)


def decode_attention_local(
    q: jax.Array,        # (B, n_heads, hd) — single new token
    k_shard: jax.Array,  # (B, Lc, n_kv, hd) — local KV chunk
    v_shard: jax.Array,
    shard_offset: jax.Array,   # absolute position of k_shard[0]
    kv_len: jax.Array,         # global number of valid cache entries
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Partial flash-decode over one KV shard.

    Returns (numerator (B,H,hd), denominator (B,H), running max (B,H)); the
    distributed combiner merges shards with the standard LSE-weighted sum.
    """
    b, lc, n_kv, hd = k_shard.shape
    n_heads = q.shape[1]
    q_per_kv = n_heads // n_kv
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = q.reshape(b, n_kv, q_per_kv, hd)
    # bf16 x bf16 dots with f32 ACCUMULATION: .astype(f32) on the cache
    # materializes a full-precision copy of the local KV shard per layer
    # (2x cache bytes — 12.8 GB/device on moonshot decode_32k)
    logits = jnp.einsum(
        "bkgh,blkh->bkgl", qg, k_shard, preferred_element_type=jnp.float32
    ) * scale
    pos = shard_offset + jnp.arange(lc)
    valid = pos[None, None, None, :] < kv_len
    logits = jnp.where(valid, logits, NEG_INF)
    m = jnp.max(logits, axis=-1)                                  # (B,kv,g)
    p = jnp.exp(logits - m[..., None])
    p = jnp.where(valid, p, 0.0)
    num = jnp.einsum(
        "bkgl,blkh->bkgh", p.astype(v_shard.dtype), v_shard,
        preferred_element_type=jnp.float32,
    )
    den = jnp.sum(p, axis=-1)
    return (
        num.reshape(b, n_heads, hd),
        den.reshape(b, n_heads),
        m.reshape(b, n_heads),
    )


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_apply(params, x, act: str):
    if act == "swiglu":
        g = x @ params["wg"]
        u = x @ params["wu"]
        return (jax.nn.silu(g) * u) @ params["wd"]
    # gelu
    h = jax.nn.gelu(x @ params["wu"], approximate=True)
    return h @ params["wd"]


def mlp_init(key, d_model, d_ff, act: str, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    out = {}
    if act == "swiglu":
        out["wg"] = dense_init(ks[0], (d_model, d_ff), ("embed", "mlp"), dtype=dtype)
    out["wu"] = dense_init(ks[1], (d_model, d_ff), ("embed", "mlp"), dtype=dtype)
    out["wd"] = dense_init(ks[2], (d_ff, d_model), ("mlp", "embed"), dtype=dtype)
    return out

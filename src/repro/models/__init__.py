from . import cross_encoder, layers, moe, transformer  # noqa: F401

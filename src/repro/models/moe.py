"""Mixture-of-experts FFN with top-k routing.

Two execution paths sharing the same parameters:

- ``moe_apply_local``: single-device reference (smoke tests, CPU experiments).
  Sort-based dispatch: assignments sorted by expert, scattered into fixed
  per-expert capacity buffers (static shapes, drop-on-overflow), batched
  expert GEMMs, weighted scatter-add combine.

- ``moe_apply_ep``: expert-parallel shard_map path.  Activations are
  replicated over the "model" axis (as in Megatron TP blocks), each model
  shard owns E/ep experts and processes the tokens routed to *its* experts
  only — dispatch needs no collective at all; the combine is one psum over
  "model", the same collective a dense TP FFN needs.  Per-chip buffers are
  (E_local, C, d) with C = T_local·top_k/E·capacity_factor.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import MoEConfig
from . import layers
from ..compat import shard_map


def moe_init(key, d_model: int, cfg: MoEConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    params = {
        "router": layers.dense_init(ks[0], (d_model, cfg.n_experts), ("embed", "expert"), dtype=jnp.float32),
        "wg": layers.dense_init(ks[1], (cfg.n_experts, d_model, cfg.d_expert), ("expert", "embed", "mlp"), dtype=dtype),
        "wu": layers.dense_init(ks[2], (cfg.n_experts, d_model, cfg.d_expert), ("expert", "embed", "mlp"), dtype=dtype),
        "wd": layers.dense_init(ks[3], (cfg.n_experts, cfg.d_expert, d_model), ("expert", "mlp", "embed"), dtype=dtype),
    }
    if cfg.n_shared_experts:
        params["shared"] = layers.mlp_init(
            ks[4], d_model, cfg.d_expert * cfg.n_shared_experts, "swiglu", dtype
        )
    return params


def _route(params, x, cfg: MoEConfig):
    """Top-k routing with normalized combine weights + aux load-balance loss."""
    logits = x.astype(jnp.float32) @ params["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, cfg.top_k)              # (T, k)
    top_p = top_p / (jnp.sum(top_p, axis=-1, keepdims=True) + 1e-9)
    # GShard aux loss: E * mean_e(frac_tokens_e * mean_prob_e)
    t = x.shape[0]
    one_hot = jax.nn.one_hot(top_e[:, 0], cfg.n_experts)        # primary expert
    frac = one_hot.mean(axis=0)
    aux = cfg.n_experts * jnp.sum(frac * probs.mean(axis=0))
    return top_e, top_p, aux


def _capacity(t: int, cfg: MoEConfig, factor: float = 1.25) -> int:
    c = int(t * cfg.top_k / cfg.n_experts * factor) + 1
    return max(4, (c + 3) // 4 * 4)


def _expert_ffn(wg, wu, wd, xin):
    """Batched SwiGLU over (E, C, d) with (E, d, f)/(E, f, d) weights."""
    g = jnp.einsum("ecd,edf->ecf", xin, wg)
    u = jnp.einsum("ecd,edf->ecf", xin, wu)
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)


def _dispatch_compute_combine(
    x, top_e, top_p, wg, wu, wd, n_experts: int, expert_offset, capacity: int
):
    """Sort-based dispatch for the expert block [offset, offset+E_block).

    Static shapes throughout; overflow beyond ``capacity`` is dropped (the
    standard GShard capacity policy).
    """
    t, d = x.shape
    k = top_e.shape[1]
    e_block = wg.shape[0]
    n_slots = e_block * capacity
    e_flat = top_e.reshape(-1) - expert_offset                   # (T*k,)
    tok_flat = jnp.repeat(jnp.arange(t), k)
    w_flat = top_p.reshape(-1)
    mine = (e_flat >= 0) & (e_flat < e_block)
    # sort assignments by (expert, arrival) — stable so token order persists
    sort_key = jnp.where(mine, e_flat, e_block)                  # foreign last
    order = jnp.argsort(sort_key, stable=True)
    e_sorted = sort_key[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]
    # position of each assignment within its expert group
    counts = jax.ops.segment_sum(
        jnp.ones_like(e_sorted), e_sorted, num_segments=e_block + 1
    )
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[e_sorted]
    keep = (e_sorted < e_block) & (pos < capacity)
    slot = jnp.where(keep, e_sorted * capacity + pos, n_slots)
    # slot -> (token, weight) maps, built with small int/f32 scatters; the
    # big (T·k, d) per-ASSIGNMENT gather/scatter of the naive formulation
    # (~13x larger than the capacity buffers under EP) never materializes.
    slot_tok = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(
        tok_sorted.astype(jnp.int32), mode="drop"
    )[:n_slots]
    slot_w = jnp.zeros((n_slots + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, w_sorted, 0.0), mode="drop"
    )[:n_slots]
    xin = jnp.take(x, slot_tok, axis=0)          # (E_block·C, d); empty slots
    out_buf = _expert_ffn(wg, wu, wd, xin.reshape(e_block, capacity, d))
    out_flat = out_buf.reshape(n_slots, d) * slot_w.astype(x.dtype)[:, None]
    return jax.ops.segment_sum(out_flat, slot_tok, num_segments=t)


def moe_apply_local(params, x, cfg: MoEConfig, capacity_factor: float = None):
    """Reference single-shard MoE. x: (T, d) -> (T, d), aux loss."""
    t, d = x.shape
    top_e, top_p, aux = _route(params, x, cfg)
    cap = _capacity(t, cfg, capacity_factor or cfg.capacity_factor)
    y = _dispatch_compute_combine(
        x, top_e, top_p, params["wg"], params["wu"], params["wd"],
        cfg.n_experts, 0, cap,
    )
    if "shared" in params:
        y = y + layers.mlp_apply(params["shared"], x, "swiglu")
    return y, aux


def make_moe_fn(mesh, cfg: MoEConfig, batch_axes, ep_axis: str = "model",
                capacity_factor: float = None, scatter_tokens: bool = False):
    """Sharded-MoE closure for transformer._mlp_block: experts live on
    ``ep_axis``, tokens shard on ``batch_axes`` and replicate over ep_axis
    (dispatch needs NO collective).

    ``scatter_tokens``: combine with psum_scatter instead of psum — the
    output lands TOKEN-SHARDED over ep_axis, which (a) halves the combine's
    link bytes (reduce-scatter vs ring all-reduce) and (b) is exactly the
    sequence-sharded residual layout the surrounding layers use, removing a
    reshard.  The shared experts then also run once per token instead of
    ep-times redundantly.  Requires tokens divisible by the ep size (train
    shapes; decode keeps the plain psum)."""
    from jax.sharding import PartitionSpec as P

    bspec = tuple(batch_axes) if batch_axes else None
    all_axes = tuple(mesh.axis_names)

    def body(p, x_local):
        y, aux = moe_apply_ep(
            p, x_local, cfg, ep_axis, capacity_factor,
            scatter_tokens=scatter_tokens,
        )
        aux = jax.lax.pmean(aux, all_axes)   # fully replicated scalar
        return y, aux

    out0 = (
        (tuple(batch_axes) + (ep_axis,)) if scatter_tokens
        else bspec
    )

    def moe_fn(params, x):
        in_specs = (
            {
                k: (P(ep_axis, None, None) if k in ("wg", "wu", "wd")
                    else jax.tree.map(lambda _: P(), v) if isinstance(v, dict)
                    else P())
                for k, v in params.items()
            },
            P(bspec, None),
        )
        out_specs = (P(out0, None), P())
        return shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )(params, x)

    return moe_fn


def moe_apply_ep(params, x, cfg: MoEConfig, ep_axis: str,
                 capacity_factor: float = None, scatter_tokens: bool = False):
    """Expert-parallel body — call inside shard_map with experts sharded on
    ``ep_axis`` and x replicated over it.  One psum (or psum_scatter, see
    make_moe_fn) over ep_axis total."""
    t, d = x.shape
    e_local = params["wg"].shape[0]
    rank = jax.lax.axis_index(ep_axis)
    my = rank * e_local
    top_e, top_p, aux = _route(params, x, cfg)
    cap = _capacity(t, cfg, capacity_factor or cfg.capacity_factor)
    y = _dispatch_compute_combine(
        x, top_e, top_p, params["wg"], params["wu"], params["wd"],
        cfg.n_experts, my, cap,
    )
    if scatter_tokens:
        y = jax.lax.psum_scatter(y, ep_axis, scatter_dimension=0, tiled=True)
        if "shared" in params:
            chunk = y.shape[0]
            x_loc = jax.lax.dynamic_slice_in_dim(x, rank * chunk, chunk, 0)
            y = y + layers.mlp_apply(params["shared"], x_loc, "swiglu")
    else:
        y = jax.lax.psum(y, ep_axis)
        if "shared" in params:
            y = y + layers.mlp_apply(params["shared"], x, "swiglu")
    aux = jax.lax.pmean(aux, ep_axis)
    return y, aux

"""Pure-jnp oracle for the embedding-bag kernel (take + reduce)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag_reference(table: jax.Array, hot_ids: jax.Array, mode: str = "sum"):
    emb = jnp.take(table, hot_ids, axis=0)      # (B, H, dim)
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        return emb.mean(axis=1)
    raise ValueError(mode)

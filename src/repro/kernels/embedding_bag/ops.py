"""jit'd public wrapper for the embedding-bag kernel."""

from __future__ import annotations

from functools import partial

import jax

from .kernel import embedding_bag


@partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag_op(table, hot_ids, *, mode: str = "sum", interpret: bool = True):
    return embedding_bag(table, hot_ids, mode=mode, interpret=interpret)

"""EmbeddingBag Pallas TPU kernel (scalar-prefetch gather + reduce).

The recsys hot path: out[b] = sum_h table[ids[b, h]].  JAX has no native
EmbeddingBag; the jnp reference (``repro.models.recsys.embedding``) does
take + segment_sum which round-trips the (B·H, dim) gathered rows through
HBM.  Here the bag ids are *scalar-prefetched* so the BlockSpec index_map
can steer the table DMA directly: grid (B, H), each step DMAs exactly one
table row HBM->VMEM and accumulates into the bag's output block — the
gathered rows never materialize.

This is the canonical TPU embedding-gather pattern (PrefetchScalarGridSpec);
rows arrive via the same double-buffered pipeline as any other BlockSpec
stream, so consecutive row fetches overlap with the adds.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _bag_kernel(ids_ref, table_row_ref, out_ref, acc_ref, *, n_hot: int, mode: str):
    h = pl.program_id(1)

    @pl.when(h == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # f32 accumulation regardless of table dtype (bf16 tables lose ~2^-8
    # per add otherwise; the accumulator lives in VMEM scratch)
    acc_ref[...] += table_row_ref[...].astype(jnp.float32)

    @pl.when(h == n_hot - 1)
    def _finalize():
        acc = acc_ref[...]
        if mode == "mean":
            acc = acc / n_hot
        out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag(
    table: jax.Array,     # (rows, dim)
    hot_ids: jax.Array,   # (B, H) int32
    *,
    mode: str = "sum",
    interpret: bool = False,
) -> jax.Array:
    """Fixed-width multi-hot bag lookup -> (B, dim)."""
    b, n_hot = hot_ids.shape
    rows, dim = table.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, n_hot),
        in_specs=[
            # one table row per step, steered by the prefetched ids
            pl.BlockSpec((1, dim), lambda bi, hi, ids: (ids[bi, hi], 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda bi, hi, ids: (bi, 0)),
        scratch_shapes=[pltpu.VMEM((1, dim), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_bag_kernel, n_hot=n_hot, mode=mode),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), table.dtype),
        interpret=interpret,
    )(hot_ids, table)

"""Flash attention Pallas TPU kernel (blocked online-softmax, GQA-aware).

TPU adaptation of the FlashAttention-2 schedule: the KV axis is the
innermost *sequential* grid dimension, so the (m, l, acc) running state
lives in VMEM scratch across KV steps; Q/K/V tiles stream HBM->VMEM once.
Block sizes default to 128/128 to align with the MXU 128x128 systolic array
and the (8,128) VREG lane layout.

Layout: q (BH, Lq, hd), k/v (BKV, Lk, hd) with BH = batch*n_heads and
BKV = batch*n_kv_heads; GQA is handled by the K/V index_map folding the
query-head index onto its KV group — no KV duplication in HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    *refs,                        # [lens_ref,] q, k, v, o, m, l, acc
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    kv_len: int,
    q_offset: int,
    has_lens: bool = False,
):
    if has_lens:
        # (1, 1) SMEM per-(batch*head) valid KV length — variable-length
        # sequences packed into one padded bucket (cross-encoder scoring)
        lens_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                        # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                        # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    run = True
    if causal:
        # whole block above the diagonal -> no work (cheap static skip is not
        # possible: grid is dense; mask handles it, @pl.when saves the GEMM)
        run = (ki * block_k) <= (q_offset + qi * block_q + block_q - 1)

    @pl.when(run if causal else True)
    def _body():
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                            # (bq, bk)
        kv_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        limit = jnp.minimum(kv_len, lens_ref[0, 0]) if has_lens else kv_len
        mask = kv_pos < limit
        if causal:
            q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask &= kv_pos <= q_pos
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / (l_scr[...][:, None] + 1e-30)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,           # (B, Lq, H, hd)
    k: jax.Array,           # (B, Lk, KV, hd)
    v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
    kv_lens: jax.Array | None = None,   # (B,) int32 valid KV length / example
) -> jax.Array:
    """pallas_call wrapper; returns (B, Lq, H, hd).

    ``kv_lens`` masks each example's trailing padding (keys at positions >=
    kv_lens[b] never contribute): how variable-length query-item pairs are
    scored through one static padded bucket shape without retracing.  The
    lengths ride in SMEM per (batch*head) grid row — no (B, Lk) mask in HBM.
    """
    b, lq, h, hd = q.shape
    _, lk, n_kv, _ = k.shape
    q_per_kv = h // n_kv
    scale = 1.0 / (hd ** 0.5)
    q_offset = lk - lq          # right-aligned causal convention (decode chunks)

    block_q = min(block_q, lq)
    block_k = min(block_k, lk)
    lq_pad = pl.cdiv(lq, block_q) * block_q
    lk_pad = pl.cdiv(lk, block_k) * block_k
    if lq_pad != lq:
        q = jnp.pad(q, ((0, 0), (0, lq_pad - lq), (0, 0), (0, 0)))
    if lk_pad != lk:
        k = jnp.pad(k, ((0, 0), (0, lk_pad - lk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, lk_pad - lk), (0, 0), (0, 0)))

    # (B, L, H, hd) -> (B*H, L, hd) head-major layout
    qh = q.transpose(0, 2, 1, 3).reshape(b * h, lq_pad, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(b * n_kv, lk_pad, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(b * n_kv, lk_pad, hd)

    grid = (b * h, lq_pad // block_q, lk_pad // block_k)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # fold query head onto its KV group: bh = bi*H + hi
        bi = bh // h
        hi = bh % h
        return (bi * n_kv + hi // q_per_kv, ki, 0)

    in_specs = [
        pl.BlockSpec((1, block_q, hd), q_map),
        pl.BlockSpec((1, block_k, hd), kv_map),
        pl.BlockSpec((1, block_k, hd), kv_map),
    ]
    operands = [qh, kh, vh]
    if kv_lens is not None:
        lens_bh = jnp.repeat(kv_lens.astype(jnp.int32), h)[:, None]  # (B*H, 1)
        in_specs.insert(
            0,
            pl.BlockSpec(
                (1, 1), lambda bh, qi, ki: (bh, 0), memory_space=pltpu.SMEM
            ),
        )
        operands.insert(0, lens_bh)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            scale=scale, causal=causal,
            block_q=block_q, block_k=block_k, kv_len=lk, q_offset=q_offset,
            has_lens=kv_lens is not None,
        ),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, block_q, hd), q_map),
        out_shape=jax.ShapeDtypeStruct((b * h, lq_pad, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
    out = out.reshape(b, h, lq_pad, hd).transpose(0, 2, 1, 3)
    return out[:, :lq]

"""jit'd public wrapper for the flash attention kernel.

``interpret=True`` executes the kernel body on CPU (how this container
validates it); on a real TPU the same call lowers to Mosaic.
"""

from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_op(
    q, k, v, *, causal=True, block_q=128, block_k=128, interpret=True, kv_lens=None
):
    return flash_attention(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k,
        interpret=interpret, kv_lens=kv_lens,
    )

"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(
    q: jax.Array,           # (B, Lq, H, hd)
    k: jax.Array,           # (B, Lk, KV, hd)
    v: jax.Array,
    causal: bool = True,
) -> jax.Array:
    b, lq, h, hd = q.shape
    _, lk, n_kv, _ = k.shape
    rep = h // n_kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (hd ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((lq, lk), dtype=bool), k=lk - lq)
        s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)

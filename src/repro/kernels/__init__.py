"""Pallas TPU kernels for the perf-critical compute layers.

- flash_attention: CE prefill attention (blocked online softmax, GQA)
- approx_topk:     fused ADACUR approx-score GEMM + masked top-k
- embedding_bag:   scalar-prefetch gather+reduce for recsys tables

Each package: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper),
ref.py (pure-jnp oracle).  Validated in interpret mode on CPU; Mosaic is
the TPU target.
"""

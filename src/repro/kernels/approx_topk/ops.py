"""Public fused approx-score->top-k op: tiled score+select + tiny merge.

Two interchangeable backends with identical semantics and identical memory
behavior (no (B, N) float score matrix is ever formed):

- ``pallas``: the TPU kernel in kernel.py — GEMM + mask + per-tile top-k in
  VMEM (``interpret=True`` runs the same kernel under the Pallas
  interpreter, useful for debugging the kernel itself);
- ``scan``: a lax.scan over item tiles in plain XLA — each step computes a
  (B, tile) score slab, masks it, and keeps its top-k.  This is the fast
  CPU path (the Pallas interpreter emulates the grid sequentially with
  per-step dispatch overhead; the scan compiles to one tight XLA loop) and
  doubles as an executable spec of the kernel.

``impl='auto'`` picks ``scan`` when ``interpret`` is requested (CPU
emulation) and the real kernel otherwise.

**Quantized payloads.**  ``r_anc`` may be a
:class:`~repro.kernels.approx_topk.quant.QuantizedRanc` (int8, packed
int4, or fp8 codes + per-item-tile fp32 scales).  Both backends then run a
fused dequant-matmul front end: each grid step loads a code tile, widens it
in registers (sign-extending nibbles for int4), contracts with ``e_q`` in
fp32 accumulation, and applies the per-column scale to the (B, T) GEMM
output — on TPU that is 4-8x fewer HBM bytes per step, and the fp32 R_anc
never exists anywhere.

**Deterministic tie-breaking.**  Exact score ties break by ascending item
index, in both backends: per tile the selection is index-stable
(``lax.top_k`` prefers the lower index; the kernel's iterative argmax takes
the first occurrence), and the cross-tile merge flattens tiles in ascending
order so ``lax.top_k`` over the flat buffer again prefers the lower global
id.  Fused and dense rankings are therefore bit-equal whenever their scores
are (asserted by the kernel parity tests), not merely set-equal.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import NEG_INF, approx_topk_tiles, pad_to_tile
from .quant import QuantizedRanc, unpack_int4


def _scan_topk_tiles(e_q, r_anc, anchors, k, tile, noise, mask, n_valid,
                     scales=None, pack=1, n_cols=None):
    """lax.scan tiled reference with kernel-identical tie-breaks.

    ``tile`` is rebalanced so the last tile carries at most n_tiles-1 padded
    columns (a literal tile multiple can waste up to a whole tile of GEMM
    work — 23% at N=10k, tile=4096); a modest unroll amortizes the scan's
    per-step dispatch on CPU.  ``anchors=None`` skips the id-compare
    entirely — callers that maintain a (B, N) selected mask pass that
    instead (O(B·T) per tile vs O(B·T·A)).  ``scales`` (N,), when given, is
    the quantized payload's per-column dequant scale, applied to each tile's
    GEMM output (scale rebalancing is free: scales are per *column*, so the
    scan tile width need not match the payload's quantization tile).
    ``pack=2`` streams packed int4 codes — each step slices tile/2 bytes and
    sign-extends the nibbles in registers (the rebalanced tile is rounded up
    to even so tile boundaries stay byte-aligned)."""
    b, k_q = e_q.shape
    n = r_anc.shape[1] * pack if n_cols is None else n_cols
    n_tiles = -(-n // tile)
    tile = -(-n // n_tiles)
    if pack > 1 and tile % pack:
        tile += pack - tile % pack
    r_anc, noise, mask, scales, n_pad = pad_to_tile(
        tile, r_anc, noise, mask, scales, pack=pack, n=n
    )
    n_tiles = n_pad // tile             # evenness rounding can shrink this
    n_eff = n if n_valid is None else min(n_valid, n)
    e_q32 = e_q.astype(jnp.float32)
    arange_t = jnp.arange(tile, dtype=jnp.int32)

    def step(_, lo):
        r_tile = jax.lax.dynamic_slice(
            r_anc, (0, lo // pack), (k_q, tile // pack)
        )
        if pack == 2:
            r_tile = unpack_int4(r_tile)
        scores = e_q32 @ r_tile.astype(jnp.float32)            # (B, tile)
        if scales is not None:
            scores = scores * jax.lax.dynamic_slice(
                scales, (lo,), (tile,)
            )[None, :]
        if noise is not None:
            scores = scores + jax.lax.dynamic_slice(
                noise, (0, lo), (b, tile)
            ).astype(jnp.float32)
        gids = lo + arange_t
        keep = (gids < n_eff)[None, :]
        if anchors is not None:
            keep = keep & ~(gids[None, :, None] == anchors[:, None, :]).any(axis=2)
        if mask is not None:
            keep = keep & ~jax.lax.dynamic_slice(mask, (0, lo), (b, tile))
        scores = jnp.where(keep, scores, NEG_INF)
        v, i = jax.lax.top_k(scores, k)
        return None, (v, lo + i.astype(jnp.int32))

    _, (vals, idx) = jax.lax.scan(
        step, None, jnp.arange(n_tiles, dtype=jnp.int32) * tile,
        unroll=min(4, n_tiles),
    )
    # (n_tiles, B, k) -> (B, n_tiles, k), matching the kernel layout
    return jnp.swapaxes(vals, 0, 1), jnp.swapaxes(idx, 0, 1)


@partial(
    jax.jit, static_argnames=("k", "tile", "interpret", "n_valid", "impl")
)
def approx_topk_op(
    e_q,
    r_anc,
    anchors,
    k: int,
    *,
    tile: int = 512,
    interpret: bool = True,
    noise=None,
    mask=None,
    n_valid: int | None = None,
    impl: str = "auto",
):
    """Fused  top-k(mask(e_q @ R_anc [+ noise]))  ->  (vals (B,k), idx (B,k)).

    ``r_anc`` is the (k_q, N) score matrix — fp32, bf16, or an int8
    :class:`QuantizedRanc` payload (dequantized tile-by-tile inside the
    kernel; see module docstring).
    ``anchors`` (B, A) are suppressed item ids (pad with -1; None = none);
    ``mask`` (B, N) bool additionally suppresses where True (cheaper than a
    long anchor list when the caller already maintains a selected-mask).
    ``noise`` (B, N), when given, is added to the scores before the top-k —
    passing Gumbel noise makes this an exact sample without replacement from
    softmax(S_hat) (Kool et al. 2019) with S_hat never materialized.
    ``n_valid`` suppresses padded item ids >= n_valid.
    Exact score ties break deterministically by ascending item index.
    """
    if isinstance(r_anc, QuantizedRanc):
        codes, scales = r_anc.codes, r_anc.col_scales()
        pack, n_cols = r_anc.packing, r_anc.shape[1]
    else:
        codes, scales = r_anc, None
        pack, n_cols = 1, None
    if impl == "auto":
        impl = "scan" if interpret else "pallas"
    if impl == "scan":
        vals, idx = _scan_topk_tiles(
            e_q, codes, anchors, k, tile, noise, mask, n_valid,
            scales=scales, pack=pack, n_cols=n_cols,
        )
    elif impl == "pallas":
        if anchors is None:
            anchors = jnp.full((e_q.shape[0], 1), -1, jnp.int32)
        vals, idx = approx_topk_tiles(
            e_q, codes, anchors, k, tile=tile, interpret=interpret,
            noise=noise, mask=mask, n_valid=n_valid, scales=scales,
            pack=pack, n_cols=n_cols,
        )
    else:
        raise ValueError(f"unknown impl '{impl}'")
    b, n_tiles, _ = vals.shape
    # merge: n_tiles*k ≪ N.  Tiles flatten in ascending order and lax.top_k
    # is index-stable, so equal values resolve to the lowest global id.
    flat_v = vals.reshape(b, n_tiles * k)
    flat_i = idx.reshape(b, n_tiles * k)
    top_v, pos = jax.lax.top_k(flat_v, k)
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    return top_v, top_i

"""Public fused approx-score->top-k op: kernel tiles + tiny global merge."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import approx_topk_tiles


@partial(jax.jit, static_argnames=("k", "tile", "interpret"))
def approx_topk_op(e_q, r_anc, anchors, k: int, *, tile: int = 512, interpret: bool = True):
    """Fused  top-k(mask(e_q @ R_anc))  ->  (vals (B,k), idx (B,k)).

    ``anchors`` (B, A) are suppressed item ids (pad with -1).
    """
    vals, idx = approx_topk_tiles(
        e_q, r_anc, anchors, k, tile=tile, interpret=interpret
    )
    b, n_tiles, _ = vals.shape
    flat_v = vals.reshape(b, n_tiles * k)
    flat_i = idx.reshape(b, n_tiles * k)
    top_v, pos = jax.lax.top_k(flat_v, k)                  # merge: n_tiles*k ≪ N
    top_i = jnp.take_along_axis(flat_i, pos, axis=1)
    return top_v, top_i

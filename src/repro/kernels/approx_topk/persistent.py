"""Persistent ADACUR-round kernel: one payload pass per monitored round.

The staged engine runs each round's item-axis work as separate fused ops —
one ``approx_topk_op`` pass for Gumbel-top-k anchor *sampling* and (in the
early-exit monitor and at retrieval) a second pass for the *provisional*
top-k over the same ``e_q @ R_anc`` estimates.  Each pass re-streams the
entire quantized payload from HBM and re-runs the dequant+GEMM front end,
even though both consume the very same (B, T) score tiles.

This module fuses the whole round into ONE payload sweep:

  grid = (n_item_tiles,); each step:
    scores  = e_q @ dequant(codes[:, tile])        (MXU, computed ONCE)
    sample  = running top-k of scores + Gumbel     (anchor/mask suppressed)
    prov    = running top-k of scores              (eligibility-masked)

Round state — ``e_q`` and both running top-k accumulators — stays resident
in VMEM across grid steps (the accumulators are *revisited outputs*: their
block index maps are constant, the flash-attention accumulator pattern, so
Pallas keeps them on-chip and writes HBM once at the end).  Payload tiles
are the only HBM traffic, double-buffered by the Pallas pipeline.  The
exact-arithmetic stages (CE scoring, the incremental pinv via
``cur.block_pinv_extend_static``) have nothing to gain from tiling over
items and everything to lose in precision plumbing — they stay outside, in
plain fp32 XLA.

Two interchangeable backends, mirroring ops.py:

- ``pallas``: the persistent kernel above (``interpret=True`` runs it under
  the Pallas interpreter on CPU);
- ``scan``: a lax.scan over item tiles carrying the running top-k lists —
  the fast CPU path and the executable spec.  When the caller passes a
  ``noise_key`` instead of a materialized (B, N) noise array, the scan
  additionally generates each tile's Gumbel rectangle *inside the loop*
  (``sampling.blocked_gumbel`` is a pure function of (key, global row,
  global item block), so per-tile generation is bit-equal to slicing a
  full-width field — the scan tile is kept NOISE_BLOCK-aligned to make the
  block coordinates line up).  The (B, N) noise matrix then never exists.

**Bitwise contracts** (identical to ops.py, asserted by the parity tests):
per-column fp32 contractions; noise keyed by global (row, item) coords;
exact score ties break by ascending item index.  Both backends merge the
running top-k with each tile by an explicit (max value, min item id)
selection rule over sentinel-initialized accumulators (NEG_INF values,
INT32_MAX ids), which is independent of buffer order and therefore equals
the staged flatten-then-top-k merge bit-for-bit — including fully-masked
degenerate rows, where the lowest masked item ids win just as they do in
the staged path.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .kernel import NEG_INF, pad_to_tile
from .quant import QuantizedRanc, unpack_int4


def _sampling():
    # deferred: core.engine imports this module, and importing
    # repro.core.sampling at module scope would run core/__init__ and
    # close the cycle when a caller imports the kernel package first
    from ...core import sampling

    return sampling

_SENTINEL_ID = jnp.iinfo(jnp.int32).max


def _merge_min_id(cv, ci, tv, ti, k):
    """Merge a carried top-k with a tile's top-k by (max value, min item id).

    The explicit min-id tie rule makes the merge independent of buffer
    order, so sentinel carry entries (NEG_INF value, INT32_MAX id) lose
    every comparison — including against fully-masked tiles, where the
    staged flatten-then-top-k yields the lowest masked item ids.  One
    vectorized lexicographic sort per merge (descending value, ascending
    id) selects the same pairs as the pallas kernel's iterative
    ``_select_min_id`` — ids are globally unique across carry and tile, so
    the lexicographic order is total — but costs O(k log k) vector work
    instead of k sequential selection steps per tile.
    """
    v = jnp.concatenate([cv, tv], axis=1)
    i = jnp.concatenate([ci, ti], axis=1)
    order = jnp.lexsort((i, -v), axis=1)[:, :k]
    return (
        jnp.take_along_axis(v, order, axis=1),
        jnp.take_along_axis(i, order, axis=1),
    )


def _persistent_scan(
    e_q, codes, scales, n, pack, k_sample, k_prov, anchors, mask, prov_mask,
    noise, noise_key, row_offset, col_offset, n_valid, tile,
):
    b, k_q = e_q.shape
    gen_noise = noise is None and noise_key is not None
    n_tiles = -(-n // tile)
    tile = -(-n // n_tiles)
    # keep tile boundaries byte-aligned for packed codes, and NOISE_BLOCK-
    # aligned when generating the Gumbel field per tile (block coords must
    # land on field-block boundaries)
    grain = _sampling().NOISE_BLOCK if gen_noise else pack
    if tile % grain:
        tile += grain - tile % grain
    codes, noise, mask, scales, n_pad = pad_to_tile(
        tile, codes, noise, mask, scales, pack=pack, n=n
    )
    if prov_mask is not None and prov_mask.shape[1] != n_pad:
        prov_mask = jnp.pad(
            prov_mask, ((0, 0), (0, n_pad - prov_mask.shape[1]))
        )
    n_tiles = n_pad // tile
    n_eff = n if n_valid is None else min(n_valid, n)
    e_q32 = e_q.astype(jnp.float32)
    arange_t = jnp.arange(tile, dtype=jnp.int32)

    def tile_lists(lo):
        r_tile = jax.lax.dynamic_slice(
            codes, (0, lo // pack), (k_q, tile // pack)
        )
        if pack == 2:
            r_tile = unpack_int4(r_tile)
        gemm = e_q32 @ r_tile.astype(jnp.float32)              # (B, tile)
        if scales is not None:
            scale_t = jax.lax.dynamic_slice(scales, (lo,), (tile,))[None, :]

        def scaled():
            # each branch re-applies the scale to the shared GEMM output:
            # a single-consumer multiply feeding the branch's noise add, the
            # same dataflow the staged passes compile (XLA contracts it to
            # an FMA — sharing one scaled array across branches would block
            # that and drift the scores an ulp from the staged path)
            return gemm * scale_t if scales is not None else gemm

        gids = lo + arange_t
        base = (gids < n_eff)[None, :]
        out = []
        if k_sample is not None:
            s = scaled()
            if noise is not None:
                s = s + jax.lax.dynamic_slice(
                    noise, (0, lo), (b, tile)
                ).astype(jnp.float32)
            elif gen_noise:
                s = s + _sampling().blocked_gumbel(
                    noise_key, b, tile, row_offset, col_offset + lo
                )
            keep = base
            if anchors is not None:
                keep = keep & ~(
                    gids[None, :, None] == anchors[:, None, :]
                ).any(axis=2)
            if mask is not None:
                keep = keep & ~jax.lax.dynamic_slice(mask, (0, lo), (b, tile))
            s = jnp.where(keep, s, NEG_INF)
            v, i = jax.lax.top_k(s, k_sample)
            out.append((v, lo + i.astype(jnp.int32)))
        if k_prov is not None:
            keep = base
            if prov_mask is not None:
                keep = keep & ~jax.lax.dynamic_slice(
                    prov_mask, (0, lo), (b, tile)
                )
            s = jnp.where(keep, scaled(), NEG_INF)
            v, i = jax.lax.top_k(s, k_prov)
            out.append((v, lo + i.astype(jnp.int32)))
        return tuple(out)

    ks = [k for k in (k_sample, k_prov) if k is not None]
    # one uniform scan over ALL tiles with a sentinel-initialized carry:
    # special-casing tile 0 outside the loop gives CPU XLA a second,
    # differently-fused copy of the score chain whose results drift an ulp
    # from the staged passes.  With every tile flowing through the same
    # compiled body the chain is bit-stable, and the min-id merge makes the
    # sentinel entries (NEG_INF, INT32_MAX) lose every comparison.
    init = tuple(
        (
            jnp.full((b, k), NEG_INF, jnp.float32),
            jnp.full((b, k), _SENTINEL_ID, jnp.int32),
        )
        for k in ks
    )

    def step(carry, lo):
        t = tile_lists(lo)
        merged = tuple(
            _merge_min_id(cv, ci, tv, ti, k)
            for (cv, ci), (tv, ti), k in zip(carry, t, ks)
        )
        return merged, None

    carry, _ = jax.lax.scan(
        step, init,
        jnp.arange(n_tiles, dtype=jnp.int32) * tile,
        unroll=min(4, n_tiles),
    )
    return carry


def _select_min_id(buf_v, buf_i, k):
    """k iterations of (max value, min item id) selection over a buffer.

    Explicitly encodes the ascending-item-id tie rule, so the result is
    independent of buffer order — in particular of where carried entries
    sit relative to the current tile's entries.
    """
    b = buf_v.shape[0]

    def take(i, carry):
        v, idx, bv, bi = carry
        m = jnp.max(bv, axis=1)                                # (B,)
        is_max = bv == m[:, None]
        g = jnp.min(jnp.where(is_max, bi, _SENTINEL_ID), axis=1)
        v = v.at[:, i].set(m)
        idx = idx.at[:, i].set(g)
        sup = is_max & (bi == g[:, None])
        bv = jnp.where(sup, NEG_INF, bv)
        bi = jnp.where(sup, _SENTINEL_ID, bi)
        return v, idx, bv, bi

    v0 = jnp.full((b, k), NEG_INF, jnp.float32)
    i0 = jnp.zeros((b, k), jnp.int32)
    v, idx, _, _ = jax.lax.fori_loop(0, k, take, (v0, i0, buf_v, buf_i))
    return v, idx


def _persistent_kernel(
    e_q_ref, codes_ref, *rest,
    tile, k_sample, k_prov, n_items, pack,
    has_anchors, has_scales, has_noise, has_mask, has_prov_mask,
):
    it = iter(rest)
    anchors_ref = next(it) if has_anchors else None
    scales_ref = next(it) if has_scales else None
    noise_ref = next(it) if has_noise else None
    mask_ref = next(it) if has_mask else None
    prov_mask_ref = next(it) if has_prov_mask else None
    outs = list(it)
    ti = pl.program_id(0)
    e_q = e_q_ref[...].astype(jnp.float32)
    r = codes_ref[...]
    if pack == 2:
        r = unpack_int4(r)
    gemm = jax.lax.dot_general(
        e_q, r.astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                           # (B, T)

    def scaled():
        # per-branch scale multiply — see _persistent_scan for why this is
        # not hoisted (FMA-contraction parity with the staged passes)
        return gemm * scales_ref[...] if scales_ref is not None else gemm

    gids = ti * tile + jax.lax.broadcasted_iota(jnp.int32, gemm.shape, 1)
    base = gids < n_items

    def run(s, v_ref, i_ref, k):
        # revisited-output accumulator: read the running top-k (garbage on
        # the first step — replaced by sentinels that lose every comparison),
        # merge with this tile, write back.  Constant block index keeps the
        # accumulator in VMEM for the whole grid.
        cv = jnp.where(ti == 0, NEG_INF, v_ref[...])
        ci = jnp.where(ti == 0, _SENTINEL_ID, i_ref[...])
        buf_v = jnp.concatenate([cv, s], axis=1)                # (B, k+T)
        buf_i = jnp.concatenate([ci, gids], axis=1)
        v, idx = _select_min_id(buf_v, buf_i, k)
        v_ref[...] = v
        i_ref[...] = idx

    o = iter(outs)
    if k_sample is not None:
        s = scaled()
        if noise_ref is not None:
            s = s + noise_ref[...].astype(jnp.float32)
        keep = base
        if anchors_ref is not None:
            anchors = anchors_ref[...]
            keep = keep & ~(gids[:, :, None] == anchors[:, None, :]).any(axis=2)
        if mask_ref is not None:
            keep = keep & ~mask_ref[...]
        run(jnp.where(keep, s, NEG_INF), next(o), next(o), k_sample)
    if k_prov is not None:
        keep = base
        if prov_mask_ref is not None:
            keep = keep & ~prov_mask_ref[...]
        run(jnp.where(keep, scaled(), NEG_INF), next(o), next(o), k_prov)


def _persistent_pallas(
    e_q, codes, scales, n, pack, k_sample, k_prov, anchors, mask, prov_mask,
    noise, n_valid, tile, interpret,
):
    b, k_q = e_q.shape
    if pack > 1 and tile % pack:
        tile += pack - tile % pack
    codes, noise, mask, scales, n_pad = pad_to_tile(
        tile, codes, noise, mask, scales, pack=pack, n=n
    )
    if prov_mask is not None and prov_mask.shape[1] != n_pad:
        prov_mask = jnp.pad(
            prov_mask, ((0, 0), (0, n_pad - prov_mask.shape[1]))
        )
    n_tiles = n_pad // tile
    kernel = partial(
        _persistent_kernel, tile=tile, k_sample=k_sample, k_prov=k_prov,
        n_items=n if n_valid is None else min(n_valid, n), pack=pack,
        has_anchors=anchors is not None, has_scales=scales is not None,
        has_noise=noise is not None, has_mask=mask is not None,
        has_prov_mask=prov_mask is not None,
    )
    in_specs = [
        pl.BlockSpec((b, k_q), lambda ti: (0, 0)),
        pl.BlockSpec((k_q, tile // pack), lambda ti: (0, ti)),
    ]
    inputs = [e_q, codes]
    if anchors is not None:
        in_specs.append(pl.BlockSpec(anchors.shape, lambda ti: (0, 0)))
        inputs.append(anchors)
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, tile), lambda ti: (0, ti)))
        inputs.append(scales[None, :])
    for extra in (noise, mask, prov_mask):
        if extra is not None:
            in_specs.append(pl.BlockSpec((b, tile), lambda ti: (0, ti)))
            inputs.append(extra)
    out_specs, out_shape = [], []
    for k in (k_sample, k_prov):
        if k is not None:
            out_specs += [
                pl.BlockSpec((b, k), lambda ti: (0, 0)),
                pl.BlockSpec((b, k), lambda ti: (0, 0)),
            ]
            out_shape += [
                jax.ShapeDtypeStruct((b, k), jnp.float32),
                jax.ShapeDtypeStruct((b, k), jnp.int32),
            ]
    outs = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    pairs = [(outs[i], outs[i + 1]) for i in range(0, len(outs), 2)]
    return tuple(pairs)


@partial(
    jax.jit,
    static_argnames=("k_sample", "k_prov", "tile", "interpret", "n_valid", "impl"),
)
def persistent_round_op(
    e_q,
    r_anc,
    *,
    k_sample: int | None = None,
    k_prov: int | None = None,
    anchors=None,
    mask=None,
    prov_mask=None,
    noise=None,
    noise_key=None,
    row_offset=0,
    col_offset=0,
    n_valid: int | None = None,
    tile: int = 512,
    interpret: bool = True,
    impl: str = "auto",
):
    """One fused payload sweep -> sampled top-k and/or provisional top-k.

    Returns ``(sample, prov)`` where each part is a ``(vals (B,k),
    idx (B,k))`` pair or ``None`` if its k was not requested.  Bit-identical
    to the corresponding staged calls:

    - ``sample`` == ``approx_topk_op(e_q, r_anc, anchors, k_sample,
      noise=noise, mask=mask, n_valid=n_valid)``
    - ``prov``   == ``approx_topk_op(e_q, r_anc, None, k_prov,
      mask=prov_mask, n_valid=n_valid)``

    but streams the payload from HBM once instead of twice.  ``noise_key``
    (+ global ``row_offset``/``col_offset``) may replace a materialized
    ``noise`` array: the scan backend then generates each tile's Gumbel
    rectangle inside the loop; the pallas backend materializes the identical
    field up front (TPU noise stays precomputed — in-kernel RNG cannot match
    ``jax.random`` bitwise).
    """
    if k_sample is None and k_prov is None:
        raise ValueError("persistent_round_op needs k_sample and/or k_prov")
    if isinstance(r_anc, QuantizedRanc):
        codes, scales = r_anc.codes, r_anc.col_scales()
        pack, n = r_anc.packing, r_anc.shape[1]
    else:
        codes, scales, pack, n = r_anc, None, 1, r_anc.shape[1]
    if impl == "auto":
        impl = "scan" if interpret else "pallas"
    if impl == "scan":
        pairs = _persistent_scan(
            e_q, codes, scales, n, pack, k_sample, k_prov, anchors, mask,
            prov_mask, noise, noise_key, row_offset, col_offset, n_valid, tile,
        )
    elif impl == "pallas":
        if noise is None and noise_key is not None:
            noise = _sampling().blocked_gumbel(
                noise_key, e_q.shape[0], n, row_offset, col_offset
            )
        pairs = _persistent_pallas(
            e_q, codes, scales, n, pack, k_sample, k_prov, anchors, mask,
            prov_mask, noise, n_valid, tile, interpret,
        )
    else:
        raise ValueError(f"unknown impl '{impl}'")
    pairs = list(pairs)
    sample = pairs.pop(0) if k_sample is not None else None
    prov = pairs.pop(0) if k_prov is not None else None
    return sample, prov

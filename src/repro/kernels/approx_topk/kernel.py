"""Fused approximate-score + top-k Pallas kernel (the paper's hot op).

ADACUR's per-round inner loop (Alg. 2 line 7 + retrieval) is

    S_hat = e_q @ R_anc ;  top-k(S_hat  masked on selected anchors)

with e_q = C_test @ U precomputed (B, k_q) and R_anc (k_q, N).  Naively this
writes the (B, N) score matrix to HBM and reads it back for top-k — 2·B·N·4
bytes of traffic that dominates at N ~ 10^6.  This kernel fuses the GEMM
with a per-tile top-k so scores never leave VMEM:

  grid = (n_item_tiles,); each step:
    scores = e_q @ R_anc[:, tile]                 (MXU, (B, T))
    mask   = tile_ids ∈ anchor set (fused Alg. 3 line 8)
    per-tile top-k via k iterations of (max, argmax, suppress)
  outputs: (B, n_tiles, k) values + global indices.

The tiny (B, n_tiles·k) cross-tile merge happens in ops.py with one
jax.lax.top_k — n_tiles·k ≪ N, so the HBM round-trip shrinks by ~T/k
(e.g. 512/64 = 8x) and the GEMM output never hits HBM at all.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _approx_topk_kernel(
    e_q_ref,        # (B, k_q)
    r_anc_ref,      # (k_q, T)
    anchors_ref,    # (B, A) int32 — already-selected anchor ids (global)
    vals_ref,       # (B, 1, k) out
    idx_ref,        # (B, 1, k) out int32
    *,
    tile: int,
    k: int,
    n_items: int,
):
    ti = pl.program_id(0)
    e_q = e_q_ref[...].astype(jnp.float32)                 # (B, k_q)
    r = r_anc_ref[...].astype(jnp.float32)                 # (k_q, T)
    scores = jax.lax.dot_general(
        e_q, r, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                       # (B, T)
    b = scores.shape[0]
    gids = ti * tile + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = gids < n_items
    # fused anchor masking (Alg. 3 line 8): suppress already-selected items
    anchors = anchors_ref[...]                              # (B, A)
    hit = (gids[:, :, None] == anchors[:, None, :]).any(axis=2)
    scores = jnp.where(valid & ~hit, scores, NEG_INF)

    def take_max(i, carry):
        s, vals, idx = carry
        m = jnp.max(s, axis=1)                              # (B,)
        am = jnp.argmax(s, axis=1).astype(jnp.int32)        # (B,)
        vals = vals.at[:, i].set(m)
        idx = idx.at[:, i].set(ti * tile + am)
        # suppress the winner
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols == am[:, None], NEG_INF, s)
        return s, vals, idx

    vals0 = jnp.full((b, k), NEG_INF, jnp.float32)
    idx0 = jnp.zeros((b, k), jnp.int32)
    _, vals, idx = jax.lax.fori_loop(0, k, take_max, (scores, vals0, idx0))
    vals_ref[:, 0, :] = vals
    idx_ref[:, 0, :] = idx


def approx_topk_tiles(
    e_q: jax.Array,        # (B, k_q) f32
    r_anc: jax.Array,      # (k_q, N)
    anchors: jax.Array,    # (B, A) int32 — global ids to mask (pad with -1)
    k: int,
    *,
    tile: int = 512,
    interpret: bool = False,
):
    """Returns per-tile (vals (B, n_tiles, k), idx (B, n_tiles, k))."""
    b, k_q = e_q.shape
    _, n = r_anc.shape
    n_pad = pl.cdiv(n, tile) * tile
    if n_pad != n:
        r_anc = jnp.pad(r_anc, ((0, 0), (0, n_pad - n)))
    n_tiles = n_pad // tile
    kernel = functools.partial(
        _approx_topk_kernel, tile=tile, k=k, n_items=n
    )
    vals, idx = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((b, k_q), lambda ti: (0, 0)),
            pl.BlockSpec((k_q, tile), lambda ti: (0, ti)),
            pl.BlockSpec(anchors.shape, lambda ti: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, 1, k), lambda ti: (0, ti, 0)),
            pl.BlockSpec((b, 1, k), lambda ti: (0, ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((b, n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(e_q, r_anc, anchors)
    return vals, idx

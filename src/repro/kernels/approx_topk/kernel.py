"""Fused approximate-score + top-k Pallas kernel (the paper's hot op).

ADACUR's per-round inner loop (Alg. 2 line 7 + retrieval) is

    S_hat = e_q @ R_anc ;  top-k(S_hat  masked on selected anchors)

with e_q = C_test @ U precomputed (B, k_q) and R_anc (k_q, N).  Naively this
writes the (B, N) score matrix to HBM and reads it back for top-k — 2·B·N·4
bytes of traffic that dominates at N ~ 10^6.  This kernel fuses the GEMM
with a per-tile top-k so scores never leave VMEM:

  grid = (n_item_tiles,); each step:
    scores = e_q @ R_anc[:, tile]                 (MXU, (B, T))
    scores += noise[:, tile]                      (optional Gumbel input —
                                                   SoftMax sampling w/o
                                                   replacement, Kool 2019)
    mask   = tile_ids ∈ anchor set (fused Alg. 3 line 8) ∧ tile_ids < n_valid
             [∨ mask[:, tile] when a dense bool mask is passed instead]
    per-tile top-k via k iterations of (max, argmax, suppress)
  outputs: (B, n_tiles, k) values + global indices.

The tiny (B, n_tiles·k) cross-tile merge happens in ops.py with one
jax.lax.top_k — n_tiles·k ≪ N, so the HBM round-trip shrinks by ~T/k
(e.g. 512/64 = 8x) and the GEMM output never hits HBM at all.

Masking comes in two flavors: an anchor-id list (B, A) compared per tile
(A ≪ N ids stay resident in VMEM — the right trade on TPU), or a dense
(B, N) bool mask streamed tile-by-tile (O(B·T) per tile — the right trade
for the CPU scan emulation in ops.py, and for engines that already maintain
the ``selected`` mask).  ``n_valid`` suppresses padded item ids >= n_valid
when R_anc's item axis is padded to a shardable multiple (pod meshes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .quant import unpack_int4

NEG_INF = -1e30


def pad_to_tile(tile: int, r_anc, noise=None, mask=None, scales=None,
                pack: int = 1, n: int | None = None):
    """Zero-pad the item axis to a tile multiple (shared by both backends).

    ``scales`` is the optional (N,) per-column dequantization scale vector of
    a quantized payload; padded columns carry scale 1.0 (their codes pad to
    0, so the padded scores are exact zeros and the n_items bound masks
    them).  ``pack`` > 1 means ``r_anc`` holds packed codes (int4: 2 logical
    columns per stored byte) — it is padded in *packed* coordinates, which
    tile-evenness keeps exact; ``n`` is then the logical column count.
    """
    if n is None:
        n = r_anc.shape[1] * pack
    n_pad = pl.cdiv(n, tile) * tile
    m_pad = n_pad // pack
    if r_anc.shape[1] != m_pad:
        r_anc = jnp.pad(r_anc, ((0, 0), (0, m_pad - r_anc.shape[1])))
    if n_pad != n:
        pad = ((0, 0), (0, n_pad - n))
        noise = jnp.pad(noise, pad) if noise is not None else None
        mask = jnp.pad(mask, pad) if mask is not None else None
        if scales is not None:
            scales = jnp.pad(scales, (0, n_pad - n), constant_values=1.0)
    return r_anc, noise, mask, scales, n_pad


def _approx_topk_kernel(
    e_q_ref,        # (B, k_q)
    r_anc_ref,      # (k_q, T) scores / int8 / fp8 — or (k_q, T/2) packed int4
    anchors_ref,    # (B, A) int32 — already-selected anchor ids (global)
    *rest,          # [scales_ref (1,T)] [noise_ref (B,T)] [mask_ref (B,T)]
                    # vals_ref, idx_ref
    tile: int,
    k: int,
    n_items: int,
    pack: int,
    has_scales: bool,
    has_noise: bool,
    has_mask: bool,
):
    it = iter(rest)
    scales_ref = next(it) if has_scales else None
    noise_ref = next(it) if has_noise else None
    mask_ref = next(it) if has_mask else None
    vals_ref, idx_ref = next(it), next(it)
    ti = pl.program_id(0)
    e_q = e_q_ref[...].astype(jnp.float32)                 # (B, k_q)
    # fused dequant front end: an int8/fp8 tile widens in registers (a
    # packed int4 tile additionally sign-extends its nibbles first); the
    # per-column scale factors out of the contraction and multiplies the
    # (B, T) GEMM output, so the fp32 R_anc tile never exists in memory.
    r = r_anc_ref[...]
    if pack == 2:
        r = unpack_int4(r)                                 # (k_q, T) int8
    r = r.astype(jnp.float32)                              # (k_q, T)
    scores = jax.lax.dot_general(
        e_q, r, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                       # (B, T)
    if scales_ref is not None:
        scores = scores * scales_ref[...]                  # (1, T) broadcast
    if noise_ref is not None:
        scores = scores + noise_ref[...].astype(jnp.float32)
    b = scores.shape[0]
    gids = ti * tile + jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    valid = gids < n_items
    # fused anchor masking (Alg. 3 line 8): suppress already-selected items
    anchors = anchors_ref[...]                              # (B, A)
    hit = (gids[:, :, None] == anchors[:, None, :]).any(axis=2)
    if mask_ref is not None:
        hit = hit | mask_ref[...]
    scores = jnp.where(valid & ~hit, scores, NEG_INF)

    def take_max(i, carry):
        s, vals, idx = carry
        m = jnp.max(s, axis=1)                              # (B,)
        am = jnp.argmax(s, axis=1).astype(jnp.int32)        # (B,)
        vals = vals.at[:, i].set(m)
        idx = idx.at[:, i].set(ti * tile + am)
        # suppress the winner
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols == am[:, None], NEG_INF, s)
        return s, vals, idx

    vals0 = jnp.full((b, k), NEG_INF, jnp.float32)
    idx0 = jnp.zeros((b, k), jnp.int32)
    _, vals, idx = jax.lax.fori_loop(0, k, take_max, (scores, vals0, idx0))
    vals_ref[:, 0, :] = vals
    idx_ref[:, 0, :] = idx


def approx_topk_tiles(
    e_q: jax.Array,        # (B, k_q) f32
    r_anc: jax.Array,      # (k_q, N) scores — or quantized codes (pass scales)
    anchors: jax.Array,    # (B, A) int32 — global ids to mask (pad with -1)
    k: int,
    *,
    tile: int = 512,
    interpret: bool = False,
    noise: jax.Array | None = None,   # (B, N) additive noise (Gumbel sampling)
    mask: jax.Array | None = None,    # (B, N) bool — True = suppress
    n_valid: int | None = None,       # real item count when N is padded
    scales: jax.Array | None = None,  # (N,) per-column dequant scales
    pack: int = 1,                    # 2 = r_anc is packed int4 (k_q, N/2)
    n_cols: int | None = None,        # logical N when r_anc is packed
):
    """Returns per-tile (vals (B, n_tiles, k), idx (B, n_tiles, k))."""
    b, k_q = e_q.shape
    n = r_anc.shape[1] * pack if n_cols is None else n_cols
    if pack > 1 and tile % pack:
        tile += pack - tile % pack
    r_anc, noise, mask, scales, n_pad = pad_to_tile(
        tile, r_anc, noise, mask, scales, pack=pack, n=n
    )
    n_tiles = n_pad // tile
    kernel = functools.partial(
        _approx_topk_kernel, tile=tile, k=k,
        n_items=n if n_valid is None else min(n_valid, n),
        pack=pack,
        has_scales=scales is not None,
        has_noise=noise is not None, has_mask=mask is not None,
    )
    in_specs = [
        pl.BlockSpec((b, k_q), lambda ti: (0, 0)),
        pl.BlockSpec((k_q, tile // pack), lambda ti: (0, ti)),
        pl.BlockSpec(anchors.shape, lambda ti: (0, 0)),
    ]
    inputs = [e_q, r_anc, anchors]
    if scales is not None:
        in_specs.append(pl.BlockSpec((1, tile), lambda ti: (0, ti)))
        inputs.append(scales[None, :])
    for extra in (noise, mask):
        if extra is not None:
            in_specs.append(pl.BlockSpec((b, tile), lambda ti: (0, ti)))
            inputs.append(extra)
    vals, idx = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((b, 1, k), lambda ti: (0, ti, 0)),
            pl.BlockSpec((b, 1, k), lambda ti: (0, ti, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n_tiles, k), jnp.float32),
            jax.ShapeDtypeStruct((b, n_tiles, k), jnp.int32),
        ],
        interpret=interpret,
    )(*inputs)
    return vals, idx

"""Quantized anchor-payload subsystem: int8 codes + per-item-tile scales.

At the ROADMAP's "millions of items" scale the offline artifact — the
(k_q, N) anchor score matrix ``R_anc`` — is the memory bottleneck, exactly
the gap arXiv 2405.03651 identifies over ANNCUR: fp32 R_anc at k_q=500,
N=10^6 is 2 GB, and the engine streams all of it over the item axis twice
per round.  This module stores R_anc as

- ``codes``  (k_q, N) int8 — symmetric round-to-nearest quantization, and
- ``scales`` (ceil(N / tile),) fp32 — one scale per *item tile*, shared by
  all k_q rows of that tile (``scale = amax_tile / 127``),

a ~4x payload shrink (codes are 1/4 the bytes; scales add 4 / tile bytes
per item).  Scores dequantize per column:  ``S_hat[:, j] = (e_q @
codes[:, j]) * scales[j // tile]`` — algebraically the scale factors out of
the contraction, so the fused kernel applies it to the (B, T) GEMM *output*
in registers and the fp32 R_anc never exists anywhere.

Tile-local scales make mutation cheap: ``add_items``/``remove_items``
re-quantize only the tiles whose columns changed (see
:func:`update_columns` / :func:`requantize_preserving_prefix`), so
untouched tiles keep bit-identical codes *and* scales across a mutation
round-trip.

Everything here is dtype-polymorphic over the three payload policies
(``AdaCURConfig.payload_dtype``): plain fp32 arrays, bf16 arrays, and
:class:`QuantizedRanc`.  The engine and the fused ``approx_topk`` op call
the dispatchers (:func:`matmul`, :func:`gather_columns`, ...) and never
branch on the payload type themselves.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

PAYLOAD_DTYPES = ("float32", "bfloat16", "int8")
DEFAULT_TILE = 512


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("codes", "scales"),
    meta_fields=("tile",),
)
@dataclass
class QuantizedRanc:
    """int8 anchor payload: per-item-tile symmetric quantization of R_anc.

    ``codes[q, j] * scales[j // tile]`` reconstructs entry (q, j); an
    all-zero tile stores scale 1.0 so dequantization is always exact zeros
    there (padded capacity tails stay exact).  ``tile`` is pytree metadata,
    so payloads with equal tile hash/trace identically under jit.
    """

    codes: jax.Array     # (k_q, N) int8
    scales: jax.Array    # (ceil(N / tile),) float32
    tile: int

    @property
    def shape(self):
        return self.codes.shape

    @property
    def dtype(self):
        """The *compute* dtype — everything dequantizes into fp32."""
        return jnp.dtype(jnp.float32)

    @property
    def nbytes(self) -> int:
        return self.codes.nbytes + self.scales.nbytes

    @property
    def n_tiles(self) -> int:
        return self.scales.shape[0]

    def col_scales(self) -> jax.Array:
        """(N,) per-column fp32 scales (tile scales expanded)."""
        n = self.codes.shape[1]
        full = jnp.repeat(
            self.scales, self.tile, total_repeat_length=self.n_tiles * self.tile
        )
        return full[:n]


def payload_dtype_of(r_anc) -> str:
    """The policy name of a payload operand ("float32"/"bfloat16"/"int8")."""
    if isinstance(r_anc, QuantizedRanc):
        return "int8"
    return str(jnp.asarray(r_anc).dtype)


def quantize_ranc(r_anc: jax.Array, tile: int = DEFAULT_TILE) -> QuantizedRanc:
    """Symmetric per-item-tile int8 quantization (round to nearest).

    Deterministic: re-quantizing a dequantized payload whose tile scale is
    unchanged recovers the codes bit-exactly (|codes| <= 127, so the
    round-trip error is far below the 0.5 rounding radius).
    """
    x = jnp.asarray(r_anc, jnp.float32)
    k_q, n = x.shape
    n_tiles = -(-n // tile)
    n_pad = n_tiles * tile
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    amax = jnp.max(jnp.abs(x.reshape(k_q, n_tiles, tile)), axis=(0, 2))
    scales = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    col = jnp.repeat(scales, tile, total_repeat_length=n_pad)
    codes = jnp.clip(jnp.round(x / col[None, :]), -127, 127).astype(jnp.int8)
    return QuantizedRanc(codes=codes[:, :n], scales=scales, tile=tile)


def dequantize(payload: QuantizedRanc) -> jax.Array:
    """(k_q, N) fp32 reconstruction — offline/debug only, never the hot path."""
    return payload.codes.astype(jnp.float32) * payload.col_scales()[None, :]


def as_payload(r_anc, payload_dtype: str, tile: int = DEFAULT_TILE):
    """Apply the config's payload policy to a raw operand.

    A plain array is converted *up* to the requested payload (bf16 cast or
    int8 quantization — traced, so bare-r_anc retrievers pay the conversion
    per call; index-backed retrievers pre-quantize via
    ``AnchorIndex.quantize`` and skip this).  An operand that is already a
    :class:`QuantizedRanc` is authoritative and passes through unchanged.
    """
    if payload_dtype not in PAYLOAD_DTYPES:
        raise ValueError(
            f"unknown payload_dtype '{payload_dtype}' (one of {PAYLOAD_DTYPES})"
        )
    if isinstance(r_anc, QuantizedRanc) or payload_dtype == "float32":
        return r_anc
    if payload_dtype == "bfloat16":
        return jnp.asarray(r_anc).astype(jnp.bfloat16)
    return quantize_ranc(r_anc, tile)


def matmul(e_q: jax.Array, r_anc) -> jax.Array:
    """Dense ``e_q @ R_anc`` -> (B, N) fp32 for any payload type.

    This is the *dense* engine path (and the oracle the fused kernels are
    tested against); the per-column scale is applied to the GEMM output, the
    same factoring the kernels use, so dense and fused scores agree.
    """
    if isinstance(r_anc, QuantizedRanc):
        s = e_q.astype(jnp.float32) @ r_anc.codes.astype(jnp.float32)
        return s * r_anc.col_scales()[None, :]
    return e_q.astype(jnp.float32) @ jnp.asarray(r_anc).astype(jnp.float32)


def take_columns(r_anc, pos: jax.Array) -> jax.Array:
    """R_anc[:, pos] -> (k_q, k) fp32 for an unbatched position vector."""
    if isinstance(r_anc, QuantizedRanc):
        cols = jnp.take(r_anc.codes, pos, axis=1).astype(jnp.float32)
        return cols * r_anc.scales[pos // r_anc.tile][None, :]
    return jnp.take(jnp.asarray(r_anc), pos, axis=1).astype(jnp.float32)


def gather_columns(r_anc, anchor_idx: jax.Array, via_onehot: bool = False):
    """R_anc[:, I_anc] for a batch of per-query anchor sets -> (B, k_q, k) fp32.

    The payload-aware twin of ``cur.gather_anchor_columns`` — dequantizes
    exactly the gathered columns (k columns, not N).  ``via_onehot``
    expresses the gather as a one-hot matmul for column-sharded payloads
    (see cur.py for why).
    """
    if not isinstance(r_anc, QuantizedRanc):
        r = jnp.asarray(r_anc)
        if via_onehot:
            n = r.shape[1]
            onehot = (
                anchor_idx[:, None, :] == jnp.arange(n)[None, :, None]
            ).astype(jnp.float32)
            return jnp.einsum("qn,bnk->bqk", r.astype(jnp.float32), onehot)
        return jnp.swapaxes(r.T[anchor_idx], 1, 2).astype(jnp.float32)
    scale = r_anc.scales[anchor_idx // r_anc.tile]            # (B, k)
    if via_onehot:
        n = r_anc.codes.shape[1]
        onehot = (
            anchor_idx[:, None, :] == jnp.arange(n)[None, :, None]
        ).astype(jnp.float32)
        cols = jnp.einsum(
            "qn,bnk->bqk", r_anc.codes.astype(jnp.float32), onehot
        )
    else:
        cols = jnp.swapaxes(r_anc.codes.T[anchor_idx], 1, 2).astype(jnp.float32)
    return cols * scale[:, None, :]


def subset_columns(r_anc, pos: jax.Array, valid: jax.Array):
    """Gather columns ``pos`` into a compact sub-payload of the same policy.

    The workhorse of candidate-subset search: ``pos`` (C,) are corpus column
    positions (padded entries may repeat position 0 — ``valid`` (C,) bool
    marks the real ones) and the result is a (k_q, C) payload whose column j
    *dequantizes bit-identically* to column ``pos[j]`` of the full payload.
    For an int8 payload the gathered codes keep their original bytes and
    each column carries its source tile's scale (``tile=1`` — per-column
    scales), so no re-quantization happens and whole-tile alignment of the
    subset is not required.  Invalid columns are exact zeros (codes 0 /
    scale 1.0 / fp32 0), matching the engine's padded-capacity invariant.
    """
    if isinstance(r_anc, QuantizedRanc):
        codes = jnp.take(r_anc.codes, pos, axis=1)
        codes = jnp.where(valid[None, :], codes, jnp.int8(0))
        scales = jnp.where(
            valid, r_anc.scales[pos // r_anc.tile], jnp.float32(1.0)
        )
        return QuantizedRanc(codes=codes, scales=scales, tile=1)
    r = jnp.asarray(r_anc)
    cols = jnp.take(r, pos, axis=1)
    return jnp.where(valid[None, :], cols, jnp.zeros((), r.dtype))


# ---------------------------------------------------------------------------
# Tile-local mutation: re-quantize ONLY the touched tiles.
# ---------------------------------------------------------------------------


def dequantize_slice(payload: QuantizedRanc, lo: int, hi: int) -> jax.Array:
    """fp32 reconstruction of columns [lo, hi) — lo/hi concrete host ints."""
    codes = jax.lax.slice_in_dim(payload.codes, lo, hi, axis=1)
    return codes.astype(jnp.float32) * payload.col_scales()[lo:hi][None, :]


def update_columns(
    payload: QuantizedRanc, cols: jax.Array, start: int
) -> QuantizedRanc:
    """Overwrite columns [start, start + m) with fp32 ``cols``, re-quantizing
    only the tiles that range touches (``add_items``' hot path).  Codes in a
    touched tile whose scale is unchanged by the new columns re-quantize
    bit-identically; tiles outside the range are returned byte-for-byte.
    """
    k_q, m = cols.shape
    tile = payload.tile
    n = payload.codes.shape[1]
    t0 = start // tile
    t1 = -(-(start + m) // tile)                   # exclusive touched-tile end
    lo, hi = t0 * tile, min(t1 * tile, n)
    region = dequantize_slice(payload, lo, hi)
    region = jax.lax.dynamic_update_slice(
        region, jnp.asarray(cols, jnp.float32), (0, start - lo)
    )
    sub = quantize_ranc(region, tile)
    codes = jax.lax.dynamic_update_slice(payload.codes, sub.codes, (0, lo))
    scales = jax.lax.dynamic_update_slice(payload.scales, sub.scales, (t0,))
    return QuantizedRanc(codes=codes, scales=scales, tile=tile)


def requantize_preserving_prefix(
    old: QuantizedRanc, new_f32: jax.Array, first_touched_col: int
) -> QuantizedRanc:
    """Quantize ``new_f32``, then restore the bytes of every tile strictly
    before the first touched column from ``old`` (they are guaranteed
    value-identical, and this guarantees them *bit*-identical — fp scale
    recomputation could otherwise drift an ulp).

    Used by ``remove_items`` (stable compaction leaves the prefix before the
    first removed column in place) and ``with_capacity`` (only the padded
    tail changes).  ``new_f32`` may have a different width than ``old``.
    """
    newp = quantize_ranc(new_f32, old.tile)
    t0 = min(first_touched_col // old.tile, old.n_tiles, newp.n_tiles)
    keep = t0 * old.tile
    if keep == 0:
        return newp
    codes = newp.codes.at[:, :keep].set(old.codes[:, :keep])
    scales = newp.scales.at[:t0].set(old.scales[:t0])
    return QuantizedRanc(codes=codes, scales=scales, tile=old.tile)

"""Quantized anchor-payload subsystem: sub-fp32 codes + per-item-tile scales.

At the ROADMAP's "millions of items" scale the offline artifact — the
(k_q, N) anchor score matrix ``R_anc`` — is the memory bottleneck, exactly
the gap arXiv 2405.03651 identifies over ANNCUR: fp32 R_anc at k_q=500,
N=10^6 is 2 GB, and the engine streams all of it over the item axis twice
per round.  This module stores R_anc as quantized codes plus

- ``scales`` (ceil(N / tile),) fp32 — one scale per *item tile*, shared by
  all k_q rows of that tile (``scale = amax_tile / qmax``),

in one of three code formats (``QuantizedRanc.code_dtype``):

- ``"int8"``  — (k_q, N) int8, qmax 127 (0.25x fp32 bytes);
- ``"int4"``  — (k_q, ceil(N/2)) uint8, two signed nibbles per byte
  (column 2j in the low nibble, 2j+1 in the high nibble), qmax 7
  (0.125x fp32 bytes);
- ``"fp8"``   — (k_q, N) float8_e4m3fn, qmax 448 = e4m3's max finite
  (0.25x fp32 bytes, but ~2 extra bits of dynamic range per tile vs int8).

Scores dequantize per column:  ``S_hat[:, j] = (e_q @ codes[:, j]) *
scales[j // tile]`` — algebraically the scale factors out of the
contraction, so the fused kernel applies it to the (B, T) GEMM *output* in
registers and the fp32 R_anc never exists anywhere.

Tile-local scales make mutation cheap: ``add_items``/``remove_items``
re-quantize only the tiles whose columns changed (see
:func:`update_columns` / :func:`requantize_preserving_prefix`), so
untouched tiles keep bit-identical codes *and* scales across a mutation
round-trip — including packed int4 tiles, because the quantization tile is
required to be even so tile boundaries always fall on byte boundaries.

Everything here is dtype-polymorphic over the payload policies
(``AdaCURConfig.payload_dtype``): plain fp32 arrays, bf16 arrays, and
:class:`QuantizedRanc`.  The engine and the fused ``approx_topk`` op call
the dispatchers (:func:`matmul`, :func:`gather_columns`, ...) and never
branch on the payload type themselves.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

PAYLOAD_DTYPES = ("float32", "bfloat16", "int8", "int4", "fp8")
CODE_DTYPES = ("int8", "int4", "fp8")
DEFAULT_TILE = 512

_QMAX = {"int8": 127.0, "int4": 7.0, "fp8": 448.0}
# real storage bytes per column per k_q row (scales add 4 / tile per column)
CODE_BYTES_PER_COL = {"int8": 1.0, "int4": 0.5, "fp8": 1.0}


def fp8_supported() -> bool:
    """Whether this JAX build carries float8_e4m3fn (all recent builds do)."""
    return hasattr(jnp, "float8_e4m3fn")


def pack_int4(codes: jax.Array) -> jax.Array:
    """(k_q, n) signed nibble values in [-8, 7] -> (k_q, ceil(n/2)) uint8.

    Column 2j lands in the low nibble of byte j, column 2j+1 in the high
    nibble; an odd trailing column packs against a zero phantom nibble.
    """
    c = jnp.asarray(codes, jnp.int32)
    if c.shape[1] % 2:
        c = jnp.pad(c, ((0, 0), (0, 1)))
    c = c & 0xF
    return (c[:, 0::2] | (c[:, 1::2] << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """(k_q, m) uint8 -> (k_q, 2m) int8 signed nibble values.

    Branch-free sign extension in int32 (``v - ((v & 8) << 1)``) and a
    repeat+parity-shift interleave, so the same helper runs unchanged inside
    the Pallas kernel body and in plain XLA — guaranteeing bit-identical
    nibble decode on every backend.
    """
    u = jnp.repeat(packed.astype(jnp.int32), 2, axis=1)          # (k_q, 2m)
    col = jax.lax.broadcasted_iota(jnp.int32, u.shape, 1)
    nib = (u >> jnp.where(col % 2 == 0, 0, 4)) & 0xF
    return (nib - ((nib & 0x8) << 1)).astype(jnp.int8)


def _take_nibbles(packed: jax.Array, pos: jax.Array) -> jax.Array:
    """Gather logical int4 columns ``pos`` -> (k_q, *pos.shape) int8."""
    byte = jnp.take(packed, pos // 2, axis=1).astype(jnp.int32)
    shift = jnp.where(pos % 2 == 0, 0, 4)
    nib = (byte >> shift[None]) & 0xF
    return (nib - ((nib & 0x8) << 1)).astype(jnp.int8)


@partial(
    jax.tree_util.register_dataclass,
    data_fields=("codes", "scales"),
    meta_fields=("tile", "code_dtype", "n_cols"),
)
@dataclass
class QuantizedRanc:
    """Quantized anchor payload: per-item-tile symmetric codes for R_anc.

    ``dequant(codes)[q, j] * scales[j // tile]`` reconstructs entry (q, j);
    an all-zero tile stores scale 1.0 so dequantization is always exact
    zeros there (padded capacity tails stay exact).  ``tile``/``code_dtype``
    are pytree metadata, so payloads with equal meta hash/trace identically
    under jit.  ``n_cols`` only exists for odd-width int4 payloads (the
    packed byte count over-states the logical width by one); it stays -1
    ("2x the packed width") everywhere else — in particular for every
    sharded payload, whose aligned capacity is always even, so shard_map's
    per-shard reconstruction reports the correct local width.
    """

    codes: jax.Array     # int8 (k_q, N) | uint8 (k_q, ceil(N/2)) | fp8 (k_q, N)
    scales: jax.Array    # (ceil(N / tile),) float32
    tile: int
    code_dtype: str = "int8"
    n_cols: int = -1

    @property
    def packing(self) -> int:
        """Logical columns per stored code element (2 for packed int4)."""
        return 2 if self.code_dtype == "int4" else 1

    @property
    def shape(self):
        k_q, m = self.codes.shape
        if self.code_dtype == "int4":
            return (k_q, m * 2 if self.n_cols < 0 else self.n_cols)
        return (k_q, m)

    @property
    def dtype(self):
        """The *compute* dtype — everything dequantizes into fp32."""
        return jnp.dtype(jnp.float32)

    @property
    def nbytes(self) -> int:
        """Real storage bytes (packed int4 counts 0.5 bytes per column)."""
        return self.codes.nbytes + self.scales.nbytes

    @property
    def n_tiles(self) -> int:
        return self.scales.shape[0]

    def col_scales(self) -> jax.Array:
        """(N,) per-column fp32 scales (tile scales expanded)."""
        n = self.shape[1]
        full = jnp.repeat(
            self.scales, self.tile, total_repeat_length=self.n_tiles * self.tile
        )
        return full[:n]


def payload_dtype_of(r_anc) -> str:
    """The policy name of a payload operand ("float32"/"bfloat16"/"int8"/
    "int4"/"fp8")."""
    if isinstance(r_anc, QuantizedRanc):
        return r_anc.code_dtype
    return str(jnp.asarray(r_anc).dtype)


def payload_nbytes(
    payload_dtype: str, k_q: int, n: int, tile: int = DEFAULT_TILE
) -> int:
    """Analytic REAL byte footprint of a (k_q, n) payload under a policy.

    Uses actual storage bytes — a packed int4 column is 0.5 bytes per row
    (two codes per byte), never an element count — plus the 4-byte-per-tile
    fp32 scale vector for the coded dtypes.  Matches ``.nbytes`` of the
    concrete operand (up to int4's odd-width padding byte per row).
    """
    if payload_dtype not in PAYLOAD_DTYPES:
        raise ValueError(
            f"unknown payload_dtype '{payload_dtype}' (one of {PAYLOAD_DTYPES})"
        )
    if payload_dtype == "float32":
        return k_q * n * 4
    if payload_dtype == "bfloat16":
        return k_q * n * 2
    codes = int(math.ceil(k_q * n * CODE_BYTES_PER_COL[payload_dtype]))
    return codes + 4 * (-(-n // tile))


def unpacked_codes(payload: QuantizedRanc) -> jax.Array:
    """Codes at logical width — int4 nibbles widened to int8, others as-is."""
    if payload.code_dtype == "int4":
        return unpack_int4(payload.codes)[:, : payload.shape[1]]
    return payload.codes


def quantize_ranc(
    r_anc: jax.Array, tile: int = DEFAULT_TILE, code_dtype: str = "int8"
) -> QuantizedRanc:
    """Symmetric per-item-tile quantization (round to nearest).

    Deterministic for the integer formats: re-quantizing a dequantized
    payload whose tile scale is unchanged recovers the codes bit-exactly
    (|codes| <= qmax, so the round-trip error is far below the 0.5 rounding
    radius).  fp8 makes no such fixpoint claim (its rounding grid is
    value-dependent) — mutation bit-identity for fp8 tiles comes from the
    byte-splicing in :func:`update_columns` /
    :func:`requantize_preserving_prefix`, not from re-encoding.
    """
    if code_dtype not in CODE_DTYPES:
        raise ValueError(f"unknown code_dtype '{code_dtype}' (one of {CODE_DTYPES})")
    if code_dtype == "int4" and tile % 2:
        raise ValueError(f"int4 payloads need an even tile, got {tile}")
    if code_dtype == "fp8" and not fp8_supported():
        raise ValueError("fp8 payloads need jnp.float8_e4m3fn in this JAX build")
    x = jnp.asarray(r_anc, jnp.float32)
    k_q, n = x.shape
    n_tiles = -(-n // tile)
    n_pad = n_tiles * tile
    if n_pad != n:
        x = jnp.pad(x, ((0, 0), (0, n_pad - n)))
    qmax = _QMAX[code_dtype]
    amax = jnp.max(jnp.abs(x.reshape(k_q, n_tiles, tile)), axis=(0, 2))
    scales = jnp.where(amax > 0, amax / qmax, 1.0).astype(jnp.float32)
    col = jnp.repeat(scales, tile, total_repeat_length=n_pad)
    y = x / col[None, :]
    if code_dtype == "fp8":
        # e4m3fn has no inf: an out-of-range cast is nan, not a saturate —
        # clip first (amax/scale can land an ulp above qmax)
        codes = jnp.clip(y, -qmax, qmax).astype(jnp.float8_e4m3fn)
        return QuantizedRanc(codes[:, :n], scales, tile, "fp8")
    q = jnp.clip(jnp.round(y), -qmax, qmax)
    if code_dtype == "int4":
        packed = pack_int4(q[:, :n].astype(jnp.int32))
        return QuantizedRanc(packed, scales, tile, "int4", n if n % 2 else -1)
    return QuantizedRanc(q.astype(jnp.int8)[:, :n], scales, tile)


def dequantize(payload: QuantizedRanc) -> jax.Array:
    """(k_q, N) fp32 reconstruction — offline/debug only, never the hot path."""
    return unpacked_codes(payload).astype(jnp.float32) * payload.col_scales()[None, :]


def as_payload(r_anc, payload_dtype: str, tile: int = DEFAULT_TILE):
    """Apply the config's payload policy to a raw operand.

    A plain array is converted *up* to the requested payload (bf16 cast or
    int8/int4/fp8 quantization — traced, so bare-r_anc retrievers pay the
    conversion per call; index-backed retrievers pre-quantize via
    ``AnchorIndex.quantize`` and skip this).  An operand that is already a
    :class:`QuantizedRanc` is authoritative and passes through unchanged.
    """
    if payload_dtype not in PAYLOAD_DTYPES:
        raise ValueError(
            f"unknown payload_dtype '{payload_dtype}' (one of {PAYLOAD_DTYPES})"
        )
    if isinstance(r_anc, QuantizedRanc) or payload_dtype == "float32":
        return r_anc
    if payload_dtype == "bfloat16":
        return jnp.asarray(r_anc).astype(jnp.bfloat16)
    return quantize_ranc(r_anc, tile, code_dtype=payload_dtype)


def matmul(e_q: jax.Array, r_anc) -> jax.Array:
    """Dense ``e_q @ R_anc`` -> (B, N) fp32 for any payload type.

    This is the *dense* engine path (and the oracle the fused kernels are
    tested against); the per-column scale is applied to the GEMM output, the
    same factoring the kernels use, so dense and fused scores agree.
    """
    if isinstance(r_anc, QuantizedRanc):
        s = e_q.astype(jnp.float32) @ unpacked_codes(r_anc).astype(jnp.float32)
        return s * r_anc.col_scales()[None, :]
    return e_q.astype(jnp.float32) @ jnp.asarray(r_anc).astype(jnp.float32)


def take_columns(r_anc, pos: jax.Array) -> jax.Array:
    """R_anc[:, pos] -> (k_q, k) fp32 for an unbatched position vector."""
    if isinstance(r_anc, QuantizedRanc):
        if r_anc.code_dtype == "int4":
            cols = _take_nibbles(r_anc.codes, pos).astype(jnp.float32)
        else:
            cols = jnp.take(r_anc.codes, pos, axis=1).astype(jnp.float32)
        return cols * r_anc.scales[pos // r_anc.tile][None, :]
    return jnp.take(jnp.asarray(r_anc), pos, axis=1).astype(jnp.float32)


def gather_columns(r_anc, anchor_idx: jax.Array, via_onehot: bool = False):
    """R_anc[:, I_anc] for a batch of per-query anchor sets -> (B, k_q, k) fp32.

    The payload-aware twin of ``cur.gather_anchor_columns`` — dequantizes
    exactly the gathered columns (k columns, not N; for packed int4 that is
    k *nibble* reads, never a full unpack).  ``via_onehot`` expresses the
    gather as a one-hot matmul for column-sharded payloads (see cur.py for
    why).
    """
    if not isinstance(r_anc, QuantizedRanc):
        r = jnp.asarray(r_anc)
        if via_onehot:
            n = r.shape[1]
            onehot = (
                anchor_idx[:, None, :] == jnp.arange(n)[None, :, None]
            ).astype(jnp.float32)
            return jnp.einsum("qn,bnk->bqk", r.astype(jnp.float32), onehot)
        return jnp.swapaxes(r.T[anchor_idx], 1, 2).astype(jnp.float32)
    scale = r_anc.scales[anchor_idx // r_anc.tile]            # (B, k)
    if via_onehot:
        n = r_anc.shape[1]
        onehot = (
            anchor_idx[:, None, :] == jnp.arange(n)[None, :, None]
        ).astype(jnp.float32)
        cols = jnp.einsum(
            "qn,bnk->bqk", unpacked_codes(r_anc).astype(jnp.float32), onehot
        )
    elif r_anc.code_dtype == "int4":
        # (k_q, B, k) nibble gather -> (B, k_q, k)
        cols = jnp.swapaxes(
            _take_nibbles(r_anc.codes, anchor_idx), 0, 1
        ).astype(jnp.float32)
    else:
        cols = jnp.swapaxes(r_anc.codes.T[anchor_idx], 1, 2).astype(jnp.float32)
    return cols * scale[:, None, :]


def subset_columns(r_anc, pos: jax.Array, valid: jax.Array):
    """Gather columns ``pos`` into a compact sub-payload of the same policy.

    The workhorse of candidate-subset search: ``pos`` (C,) are corpus column
    positions (padded entries may repeat position 0 — ``valid`` (C,) bool
    marks the real ones) and the result is a (k_q, C) payload whose column j
    *dequantizes bit-identically* to column ``pos[j]`` of the full payload.
    For int8/fp8 the gathered codes keep their original bytes and each
    column carries its source tile's scale (``tile=1`` — per-column scales),
    so no re-quantization happens and whole-tile alignment of the subset is
    not required.  Packed int4 nibbles widen to int8 codes (the nibble
    *values* are preserved exactly, so dequantization stays bit-identical;
    only the shortlist-sized subset pays the 2x byte widening — subsets can
    be odd-width and scattered, which packed storage cannot represent).
    Invalid columns are exact zeros (codes 0 / scale 1.0 / fp32 0), matching
    the engine's padded-capacity invariant.
    """
    if isinstance(r_anc, QuantizedRanc):
        scales = jnp.where(
            valid, r_anc.scales[pos // r_anc.tile], jnp.float32(1.0)
        )
        if r_anc.code_dtype == "int4":
            codes = _take_nibbles(r_anc.codes, pos)
            codes = jnp.where(valid[None, :], codes, jnp.int8(0))
            return QuantizedRanc(codes, scales, tile=1, code_dtype="int8")
        codes = jnp.take(r_anc.codes, pos, axis=1)
        codes = jnp.where(valid[None, :], codes, jnp.zeros((), codes.dtype))
        return QuantizedRanc(codes, scales, tile=1, code_dtype=r_anc.code_dtype)
    r = jnp.asarray(r_anc)
    cols = jnp.take(r, pos, axis=1)
    return jnp.where(valid[None, :], cols, jnp.zeros((), r.dtype))


# ---------------------------------------------------------------------------
# Tile-local mutation: re-quantize ONLY the touched tiles.
# ---------------------------------------------------------------------------


def dequantize_slice(payload: QuantizedRanc, lo: int, hi: int) -> jax.Array:
    """fp32 reconstruction of columns [lo, hi) — lo/hi concrete host ints.

    For packed int4, ``lo`` must be even (callers slice at tile boundaries,
    and int4 tiles are even); ``hi`` may be odd (a phantom high nibble is
    decoded and discarded).
    """
    if payload.code_dtype == "int4":
        assert lo % 2 == 0, "int4 slices must start on a byte boundary"
        packed = jax.lax.slice_in_dim(payload.codes, lo // 2, -(-hi // 2), axis=1)
        codes = unpack_int4(packed)[:, : hi - lo]
    else:
        codes = jax.lax.slice_in_dim(payload.codes, lo, hi, axis=1)
    return codes.astype(jnp.float32) * payload.col_scales()[lo:hi][None, :]


def update_columns(
    payload: QuantizedRanc, cols: jax.Array, start: int
) -> QuantizedRanc:
    """Overwrite columns [start, start + m) with fp32 ``cols``, re-quantizing
    only the tiles that range touches (``add_items``' hot path).  Codes in a
    touched tile whose scale is unchanged by the new columns re-quantize
    bit-identically (int8/int4); tiles outside the range are returned
    byte-for-byte — for packed int4 the touched region is spliced at byte
    granularity, which tile-evenness makes exact.
    """
    k_q, m = cols.shape
    tile = payload.tile
    n = payload.shape[1]
    t0 = start // tile
    t1 = -(-(start + m) // tile)                   # exclusive touched-tile end
    lo, hi = t0 * tile, min(t1 * tile, n)
    region = dequantize_slice(payload, lo, hi)
    region = jax.lax.dynamic_update_slice(
        region, jnp.asarray(cols, jnp.float32), (0, start - lo)
    )
    sub = quantize_ranc(region, tile, code_dtype=payload.code_dtype)
    if payload.code_dtype == "int4":
        codes = jax.lax.dynamic_update_slice(payload.codes, sub.codes, (0, lo // 2))
    else:
        codes = jax.lax.dynamic_update_slice(payload.codes, sub.codes, (0, lo))
    scales = jax.lax.dynamic_update_slice(payload.scales, sub.scales, (t0,))
    return QuantizedRanc(
        codes, scales, tile, payload.code_dtype, payload.n_cols
    )


def requantize_preserving_prefix(
    old: QuantizedRanc, new_f32: jax.Array, first_touched_col: int
) -> QuantizedRanc:
    """Quantize ``new_f32``, then restore the bytes of every tile strictly
    before the first touched column from ``old`` (they are guaranteed
    value-identical, and this guarantees them *bit*-identical — fp scale
    recomputation could otherwise drift an ulp; for fp8 the re-encoding
    grid itself can drift, so byte restoration is the only correctness
    story).

    Used by ``remove_items`` (stable compaction leaves the prefix before the
    first removed column in place) and ``with_capacity`` (only the padded
    tail changes).  ``new_f32`` may have a different width than ``old``.
    """
    newp = quantize_ranc(new_f32, old.tile, code_dtype=old.code_dtype)
    t0 = min(first_touched_col // old.tile, old.n_tiles, newp.n_tiles)
    keep = t0 * old.tile
    if keep == 0:
        return newp
    kc = keep // old.packing            # tile evenness: byte-aligned for int4
    codes = newp.codes.at[:, :kc].set(old.codes[:, :kc])
    scales = newp.scales.at[:t0].set(old.scales[:t0])
    return QuantizedRanc(codes, scales, old.tile, old.code_dtype, newp.n_cols)

"""Pure-jnp oracle: materialize S_hat, mask anchors, full top-k.

Accepts the same payload types as the fused op (fp32 / bf16 arrays or an
int8 :class:`QuantizedRanc`), dequantizing with the same per-column scale
factoring the kernels use — so the oracle and the fused paths compute the
same scores and, with the shared ascending-index tie-break of
``lax.top_k``, bit-equal rankings."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant import QuantizedRanc

NEG_INF = -1e30


def approx_topk_reference(
    e_q: jax.Array,       # (B, k_q)
    r_anc: jax.Array,     # (k_q, N) — or an int8 QuantizedRanc payload
    anchors: jax.Array,   # (B, A) global ids to mask (-1 = unused)
    k: int,
    noise: jax.Array | None = None,   # (B, N) additive noise
    mask: jax.Array | None = None,    # (B, N) bool — True = suppress
    n_valid: int | None = None,       # real item count when N is padded
):
    if isinstance(r_anc, QuantizedRanc):
        scores = e_q.astype(jnp.float32) @ r_anc.codes.astype(jnp.float32)
        scores = scores * r_anc.col_scales()[None, :]
    else:
        scores = e_q.astype(jnp.float32) @ r_anc.astype(jnp.float32)  # (B, N)
    if noise is not None:
        scores = scores + noise.astype(jnp.float32)
    n = scores.shape[1]
    ids = jnp.arange(n)
    hit = (ids[None, :, None] == anchors[:, None, :]).any(axis=2)
    if mask is not None:
        hit = hit | mask
    if n_valid is not None:
        hit = hit | (ids >= n_valid)[None, :]
    scores = jnp.where(hit, NEG_INF, scores)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)

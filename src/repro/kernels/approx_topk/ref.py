"""Pure-jnp oracle: materialize S_hat, mask anchors, full top-k."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def approx_topk_reference(
    e_q: jax.Array,       # (B, k_q)
    r_anc: jax.Array,     # (k_q, N)
    anchors: jax.Array,   # (B, A) global ids to mask (-1 = unused)
    k: int,
):
    scores = e_q.astype(jnp.float32) @ r_anc.astype(jnp.float32)   # (B, N)
    n = scores.shape[1]
    ids = jnp.arange(n)
    hit = (ids[None, :, None] == anchors[:, None, :]).any(axis=2)
    scores = jnp.where(hit, NEG_INF, scores)
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)

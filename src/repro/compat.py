"""JAX version-compatibility shims.

The codebase targets current JAX (``jax.shard_map`` with ``check_vma``);
hermetic containers pin older 0.4.x where the API lives at
``jax.experimental.shard_map.shard_map`` and the replication check is
spelled ``check_rep``.  Route every call through here so call sites stay
written against the modern API.
"""

from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None):
    kw = {} if check_vma is None else {_CHECK_KW: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)

"""Data pipeline: deterministic sharded batching with background prefetch.

Host-side pipeline feeding the jit'd steps:

- ``ShardedBatcher``: deterministic per-host slicing of a global batch
  (host h of H takes rows [h·B/H, (h+1)·B/H)) with an epoch-seeded
  permutation — restartable from any step (fault tolerance: the RNG is
  (seed, epoch)-keyed, so a resumed job regenerates the identical stream);
- ``Prefetcher``: a background thread keeps ``depth`` batches ready so host
  data prep overlaps device compute (the standard single-host analogue of
  per-host input pipelines).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class ShardedBatcher:
    def __init__(
        self,
        n_examples: int,
        global_batch: int,
        seed: int = 0,
        host_id: int = 0,
        n_hosts: int = 1,
    ):
        if global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.n = n_examples
        self.gb = global_batch
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.per_host = global_batch // n_hosts

    def epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(self.n)

    def batch_indices(self, step: int) -> np.ndarray:
        """Global step -> this host's example ids (deterministic, resumable)."""
        per_epoch = self.n // self.gb
        epoch, within = divmod(step, max(per_epoch, 1))
        order = self.epoch_order(epoch)
        lo = (within % max(per_epoch, 1)) * self.gb
        rows = order[lo : lo + self.gb]
        return rows[self.host_id * self.per_host : (self.host_id + 1) * self.per_host]


class Prefetcher:
    """Wrap a batch-producing callable; keep ``depth`` batches ready."""

    def __init__(self, make_batch: Callable[[int], object], depth: int = 2,
                 start_step: int = 0):
        self.make_batch = make_batch
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.make_batch(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

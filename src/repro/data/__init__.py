from . import loader, synthetic  # noqa: F401

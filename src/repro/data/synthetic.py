"""Synthetic ZESHEL-like corpora and cross-encoder scorers.

The paper's experiments need (a) a corpus of items, (b) train/test query
splits, and (c) a cross-encoder whose query-item score matrix has the
structure that makes the problem interesting: a smooth, approximately
low-rank background (CUR-friendly) plus sharp query-specific spikes on the
true nearest neighbours (exactly the part random anchors miss — paper
Fig. 1).  Since the ZESHEL text + [EMB]-CE checkpoint are not available
offline, we provide:

- ``SyntheticCE``: a structural scorer — low-rank tanh-mixture background +
  Gaussian-kernel spikes — evaluated in closed form (fast bulk scoring for
  10K-1M item corpora on CPU);
- ``ZeshelLikeDataset``: entity/mention token sequences with controlled
  ambiguity for the trained tiny-transformer CE (examples/).

Claims are validated as relative orderings (ADACUR > ANNCUR > rerank
baselines at matched CE budget), which is what the paper establishes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticCE:
    """Closed-form cross-encoder over a synthetic domain.

    score(q, i) = sum_r w_r · tanh(<A_r e_q, B_r e_i>)        (background)
                + gamma · exp(-||e_q - e_i||² / (2σ²))         (k-NN spikes)

    The tanh mixture is approximately low rank (CUR captures it with modest
    k_i); the Gaussian spike term is effectively high-rank/localized, which
    reproduces the paper's Fig. 1 failure mode of uniform anchors.
    """

    q_emb: jax.Array          # (n_queries, d)
    i_emb: jax.Array          # (n_items, d)
    mix_a: jax.Array          # (R, d, r_low)
    mix_b: jax.Array          # (R, d, r_low)
    mix_w: jax.Array          # (R,)
    gamma: float
    sigma: float

    @property
    def n_queries(self) -> int:
        return self.q_emb.shape[0]

    @property
    def n_items(self) -> int:
        return self.i_emb.shape[0]

    def _background(self, qe: jax.Array, ie: jax.Array) -> jax.Array:
        # qe: (..., d) ; ie: (..., d) broadcast-compatible leading dims
        qa = jnp.einsum("...d,rdk->...rk", qe, self.mix_a)
        ib = jnp.einsum("...d,rdk->...rk", ie, self.mix_b)
        return jnp.einsum("...rk,...rk,r->...", jnp.tanh(qa), jnp.tanh(ib), self.mix_w)

    def _spike(self, qe: jax.Array, ie: jax.Array) -> jax.Array:
        d2 = jnp.sum((qe - ie) ** 2, axis=-1)
        return self.gamma * jnp.exp(-d2 / (2.0 * self.sigma**2))

    def score_pairs(self, query_ids: jax.Array, item_ids: jax.Array) -> jax.Array:
        """Exact CE scores for (B,) query ids x (B, k) item ids -> (B, k)."""
        qe = self.q_emb[query_ids][:, None, :]       # (B, 1, d)
        ie = self.i_emb[item_ids]                    # (B, k, d)
        return self._background(qe, ie) + self._spike(qe, ie)

    def score_block(self, query_ids: jax.Array, item_ids: jax.Array) -> jax.Array:
        """Bulk scores for (Q,) query ids x (N,) item ids -> (Q, N)."""
        qe = self.q_emb[query_ids][:, None, :]       # (Q, 1, d)
        ie = self.i_emb[item_ids][None, :, :]        # (1, N, d)
        return self._background(qe, ie) + self._spike(qe, ie)

    def full_matrix(self, query_ids: jax.Array, chunk: int = 128) -> jax.Array:
        """(Q, N) exact score matrix, computed in row chunks."""
        item_ids = jnp.arange(self.n_items)
        blocks = []
        fn = jax.jit(self.score_block)
        for lo in range(0, int(query_ids.shape[0]), chunk):
            blocks.append(fn(query_ids[lo : lo + chunk], item_ids))
        return jnp.concatenate(blocks, axis=0)

    def score_fn(self):
        """ADACUR-compatible score_fn(query_ids, item_idx)."""

        def fn(query_ids, item_idx):
            return self.score_pairs(query_ids, item_idx)

        return fn


def make_synthetic_ce(
    key: jax.Array,
    n_queries: int = 1000,
    n_items: int = 10000,
    d: int = 16,
    r_low: int = 8,
    n_mix: int = 4,
    gamma: float = 2.5,
    sigma: float = 0.6,
    n_clusters: int = 25,
) -> SyntheticCE:
    """Build a synthetic domain with cluster structure (entities come in
    confusable families, mentions sit near their family's entities)."""
    ks = jax.random.split(key, 6)
    centers = jax.random.normal(ks[0], (n_clusters, d)) / jnp.sqrt(d)
    i_cluster = jax.random.randint(ks[1], (n_items,), 0, n_clusters)
    i_emb = centers[i_cluster] + 0.3 * jax.random.normal(ks[2], (n_items, d)) / jnp.sqrt(d)
    q_cluster = jax.random.randint(ks[3], (n_queries,), 0, n_clusters)
    q_emb = centers[q_cluster] + 0.3 * jax.random.normal(ks[4], (n_queries, d)) / jnp.sqrt(d)
    mk = jax.random.split(ks[5], 3)
    mix_a = jax.random.normal(mk[0], (n_mix, d, r_low)) / jnp.sqrt(d)
    mix_b = jax.random.normal(mk[1], (n_mix, d, r_low)) / jnp.sqrt(d)
    mix_w = jnp.abs(jax.random.normal(mk[2], (n_mix,))) + 0.5
    return SyntheticCE(q_emb, i_emb, mix_a, mix_b, mix_w, gamma, sigma)


def lexical_signatures(
    emb,
    n_terms: int = 8,
    n_planes: int = 64,
    seed: int = 0,
) -> np.ndarray:
    """Signed random-projection "tokens" for an embedding-only corpus.

    The synthetic CE domain has no text, but the BM25 first stage
    (``repro.core.candidates.BM25Candidates``) needs token sequences.
    Project each embedding onto ``n_planes`` shared random hyperplanes and
    keep the ``n_terms`` largest-|projection| planes as that row's terms,
    sign-split (plane p firing positive and negative are different tokens)
    — an LSH vocabulary of ``2 * n_planes`` tokens (+1 reserved pad id 0)
    where cosine-similar rows share terms.  Deterministic in ``seed``, and
    one seed must be shared between corpus and query sides so their
    vocabularies align.
    """
    emb = np.asarray(emb, dtype=np.float32)
    rng = np.random.default_rng(seed)
    planes = rng.standard_normal((emb.shape[1], n_planes)).astype(np.float32)
    proj = emb @ planes                                   # (B, n_planes)
    top = np.argsort(-np.abs(proj), axis=1, kind="stable")[:, :n_terms]
    sign = (np.take_along_axis(proj, top, axis=1) >= 0).astype(np.int32)
    return (2 * top + sign + 1).astype(np.int32)          # 0 stays the pad id


# ---------------------------------------------------------------------------
# ZESHEL-like token datasets for the trained tiny cross-encoder
# ---------------------------------------------------------------------------

PAD, CLS, SEP, MASK = 0, 1, 2, 3
N_SPECIAL = 4


@dataclass
class ZeshelLikeDataset:
    """Token-level entity-linking data: items are 'entity descriptions'
    (random-but-consistent token sequences), queries are 'mentions' (noisy
    crops of their gold entity's description plus context)."""

    item_tokens: np.ndarray     # (n_items, item_len) int32
    query_tokens: np.ndarray    # (n_queries, query_len) int32
    gold: np.ndarray            # (n_queries,) gold item id
    vocab_size: int
    item_len: int
    query_len: int

    def pair_tokens(self, query_ids: np.ndarray, item_ids: np.ndarray) -> np.ndarray:
        """[CLS] query [SEP] item [SEP] concatenation for the CE.

        query_ids: (B,), item_ids: (B, K) -> (B, K, L) token batch.
        """
        q = self.query_tokens[query_ids]                       # (B, Lq)
        it = self.item_tokens[item_ids]                        # (B, K, Li)
        b, k = item_ids.shape
        lq, li = q.shape[1], it.shape[2]
        out = np.zeros((b, k, lq + li + 3), dtype=np.int32)
        out[:, :, 0] = CLS
        out[:, :, 1 : 1 + lq] = q[:, None, :]
        out[:, :, 1 + lq] = SEP
        out[:, :, 2 + lq : 2 + lq + li] = it
        out[:, :, 2 + lq + li] = SEP
        return out


def make_zeshel_like(
    seed: int,
    n_items: int = 2000,
    n_queries: int = 400,
    vocab: int = 256,
    item_len: int = 24,
    query_len: int = 16,
    n_families: int = 40,
    family_overlap: float = 0.6,
) -> ZeshelLikeDataset:
    """Entity families share ``family_overlap`` of their tokens, creating the
    confusable near-neighbour structure zero-shot entity linking has."""
    rng = np.random.default_rng(seed)
    usable = vocab - N_SPECIAL
    fam_proto = rng.integers(0, usable, size=(n_families, item_len)) + N_SPECIAL
    fam_of_item = rng.integers(0, n_families, size=n_items)
    item_tokens = fam_proto[fam_of_item].copy()
    # per-item unique tokens where the family prototype is not kept
    keep = rng.random((n_items, item_len)) < family_overlap
    uniq = rng.integers(0, usable, size=(n_items, item_len)) + N_SPECIAL
    item_tokens = np.where(keep, item_tokens, uniq).astype(np.int32)

    gold = rng.integers(0, n_items, size=n_queries)
    # mention = noisy crop of the gold description + family context tokens
    starts = rng.integers(0, item_len - query_len + 1, size=n_queries)
    query_tokens = np.stack(
        [item_tokens[g, s : s + query_len] for g, s in zip(gold, starts)]
    )
    noise = rng.random((n_queries, query_len)) < 0.15
    rand_tok = rng.integers(0, usable, size=(n_queries, query_len)) + N_SPECIAL
    query_tokens = np.where(noise, rand_tok, query_tokens).astype(np.int32)
    return ZeshelLikeDataset(
        item_tokens, query_tokens, gold.astype(np.int32), vocab, item_len, query_len
    )

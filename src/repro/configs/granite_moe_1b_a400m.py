"""Granite-3.0-1B-A400M  [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 32 experts top-8, tied embeddings.
"""

from .base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="granite-moe-1b-a400m",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    qk_norm=False,
    qkv_bias=False,
    rope_theta=10000.0,
    act="swiglu",
    tie_embeddings=True,
    moe=MoEConfig(n_experts=32, top_k=8, d_expert=512),
)

"""MIND: Multi-Interest Network with Dynamic routing  [arXiv:1904.08030].

embed_dim=64 n_interests=4 capsule_iters=3 — dual-encoder-style
multi-interest retriever.  Not an ADACUR target (scores are max-over-dot);
serves as the first-round anchor retriever (paper's DE_BASE role).
"""

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="mind",
    kind="mind",
    embed_dim=64,
    seq_len=50,
    n_interests=4,
    capsule_iters=3,
    n_items=1_000_000,
    interaction="multi-interest",
)

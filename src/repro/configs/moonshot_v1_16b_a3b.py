"""Moonlight-16B-A3B (moonshot-v1-16b-a3b)  [hf:moonshotai/Moonlight-16B-A3B].

48L d_model=2048 16H (kv=16, i.e. MHA) per-expert d_ff=1408 vocab=163840,
MoE 64 experts top-6, 2 shared experts, first layer dense (d_ff 11264) —
DeepSeek-V3-style layout.
"""

from .base import LMConfig, MoEConfig

CONFIG = LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=163840,
    qk_norm=False,
    qkv_bias=False,
    rope_theta=50000.0,
    act="swiglu",
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared_experts=2,
        first_k_dense=1,
        d_ff_dense=11264,
    ),
)

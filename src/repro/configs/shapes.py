"""Assigned input-shape sets, one per architecture family.

Every (architecture x shape) pair forms one dry-run cell; see
``repro.configs.registry`` for the pairing and ``launch/dryrun.py`` for the
lower+compile pass over all cells.
"""

from __future__ import annotations

from .base import GraphShape, LMShape, RecSysShape

# --- LM-family transformers ------------------------------------------------
# ``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
# cache of seq_len), NOT train_step.  long_500k is decode — O(L) per token —
# served with a sequence-parallel KV cache (see DESIGN.md §4.1).
LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": LMShape("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": LMShape("decode_32k", "decode", seq_len=32768, global_batch=128),
    "long_500k": LMShape("long_500k", "decode", seq_len=524288, global_batch=1),
}

# --- GNN ---------------------------------------------------------------------
GNN_SHAPES = {
    "full_graph_sm": GraphShape(
        "full_graph_sm", "full", n_nodes=2708, n_edges=10556, d_feat=1433
    ),
    "minibatch_lg": GraphShape(
        "minibatch_lg",
        "minibatch",
        n_nodes=232965,
        n_edges=114615892,
        batch_nodes=1024,
        fanout=(15, 10),
    ),
    "ogb_products": GraphShape(
        "ogb_products", "full", n_nodes=2449029, n_edges=61859140, d_feat=100
    ),
    "molecule": GraphShape(
        "molecule", "molecule", n_nodes=30, n_edges=64, batch_graphs=128
    ),
}

# --- RecSys ------------------------------------------------------------------
RECSYS_SHAPES = {
    "train_batch": RecSysShape("train_batch", "train", batch=65536),
    "serve_p99": RecSysShape("serve_p99", "serve", batch=512),
    "serve_bulk": RecSysShape("serve_bulk", "serve", batch=262144),
    "retrieval_cand": RecSysShape(
        "retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000
    ),
}

SHAPES_BY_FAMILY = {
    "lm": LM_SHAPES,
    "gnn": GNN_SHAPES,
    "recsys": RECSYS_SHAPES,
}

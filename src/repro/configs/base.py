"""Config dataclasses for every architecture family in the framework.

All configs are frozen dataclasses so they can be hashed as jit static
arguments and stored in checkpoint manifests.  Each assigned architecture
gets one module under ``repro.configs`` exporting ``CONFIG``; the registry
(``repro.configs.registry``) maps ``--arch`` ids to them.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Language models (dense + MoE)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (GShard-style dense dispatch)."""

    n_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden dim
    n_shared_experts: int = 0        # DeepSeek/Moonlight-style shared experts
    first_k_dense: int = 0           # first K layers use a dense FFN instead
    d_ff_dense: int = 0              # hidden dim of those dense layers
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    capacity_factor: float = 1.25    # GShard per-expert capacity (drop beyond)


@dataclass(frozen=True)
class LMConfig:
    """Decoder (or encoder) transformer LM configuration."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # defaults to d_model // n_heads
    qk_norm: bool = False            # Qwen3-style per-head RMSNorm on q,k
    qkv_bias: bool = False           # Qwen1.5-style bias on QKV projections
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    causal: bool = True              # False => encoder-only (bert4rec-style)
    act: str = "swiglu"              # "swiglu" | "gelu"
    norm: str = "rmsnorm"            # "rmsnorm" | "layernorm" (starcoder2)
    mlp_bias: bool = False           # bias on MLP projections (starcoder2)
    moe: Optional[MoEConfig] = None
    max_seq_len: int = 524288
    dtype: str = "bfloat16"          # activation / param dtype for serving
    remat: bool = True               # activation checkpointing in train_step
    scan_layers: bool = True         # lax.scan over stacked layer params

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def n_params(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS in the roofline)."""
        hd = self.resolved_head_dim
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        attn = self.d_model * hd * (self.n_heads + 2 * self.n_kv_heads)  # qkv
        attn += self.n_heads * hd * self.d_model                          # out
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv_heads)
        per_layer = attn
        if self.moe is None:
            n_ff = 3 if self.act == "swiglu" else 2
            per_layer += n_ff * self.d_model * self.d_ff
            total_ffn = per_layer * self.n_layers
        else:
            n_ff = 3 if self.act == "swiglu" else 2
            moe_ffn = n_ff * self.d_model * self.moe.d_expert * (
                self.moe.n_experts + self.moe.n_shared_experts
            ) + self.d_model * self.moe.n_experts  # router
            dense_ffn = n_ff * self.d_model * (self.moe.d_ff_dense or self.d_ff)
            n_moe = self.n_layers - self.moe.first_k_dense
            total_ffn = attn * self.n_layers + moe_ffn * n_moe + dense_ffn * self.moe.first_k_dense
        norms = self.n_layers * 2 * self.d_model + self.d_model
        return emb + total_ffn + norms

    def n_active_params(self) -> int:
        """Active (per-token) parameter count — used for MoE MODEL_FLOPS."""
        if self.moe is None:
            return self.n_params()
        hd = self.resolved_head_dim
        emb = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        attn = self.d_model * hd * (self.n_heads + 2 * self.n_kv_heads)
        attn += self.n_heads * hd * self.d_model
        n_ff = 3 if self.act == "swiglu" else 2
        active_ffn = n_ff * self.d_model * self.moe.d_expert * (
            self.moe.top_k + self.moe.n_shared_experts
        )
        dense_ffn = n_ff * self.d_model * (self.moe.d_ff_dense or self.d_ff)
        n_moe = self.n_layers - self.moe.first_k_dense
        return (
            emb
            + attn * self.n_layers
            + active_ffn * n_moe
            + dense_ffn * self.moe.first_k_dense
        )


# ---------------------------------------------------------------------------
# GNN (NequIP)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int                    # multiplicity per irrep channel
    l_max: int                       # max spherical-harmonic degree
    n_rbf: int                       # radial basis functions
    cutoff: float                    # radial cutoff (Angstrom)
    d_feat: int = 0                  # raw input node-feature dim (0 => species embed)
    n_species: int = 64
    equivariance: str = "E(3)-tensor-product"
    dtype: str = "float32"

    @property
    def irrep_dim(self) -> int:
        """Total feature dim per channel over l = 0..l_max: sum(2l+1)."""
        return sum(2 * l + 1 for l in range(self.l_max + 1))


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RecSysConfig:
    name: str
    kind: str                        # "bst" | "mind" | "bert4rec" | "dlrm"
    embed_dim: int
    n_items: int = 1_000_000         # item vocabulary (retrieval corpus)
    seq_len: int = 20                # user-history length (sequential models)
    n_heads: int = 8
    n_blocks: int = 1
    mlp_dims: Tuple[int, ...] = ()
    # MIND
    n_interests: int = 4
    capsule_iters: int = 3
    # DLRM
    n_dense: int = 0
    n_sparse: int = 0
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    table_sizes: Tuple[int, ...] = ()
    interaction: str = "dot"
    multihot_per_field: int = 1      # lookups per sparse field (embedding-bag size)
    dtype: str = "float32"


# ---------------------------------------------------------------------------
# Shapes: one named shape set per family (see configs/shapes.py)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LMShape:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


@dataclass(frozen=True)
class GraphShape:
    name: str
    kind: str          # "full" | "minibatch" | "molecule"
    n_nodes: int
    n_edges: int
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: Tuple[int, ...] = ()
    batch_graphs: int = 0


@dataclass(frozen=True)
class RecSysShape:
    name: str
    kind: str          # "train" | "serve" | "retrieval"
    batch: int
    n_candidates: int = 0


# ---------------------------------------------------------------------------
# ADACUR runtime config (the paper's technique)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdaCURConfig:
    """Inference-time configuration for the multi-round adaptive retriever.

    Mirrors Algorithm 1 in the paper: a CE-call budget ``budget_ce`` split
    between ``k_anchor`` anchor items sampled over ``n_rounds`` rounds and
    ``budget_ce - k_anchor`` items re-ranked with exact CE scores.  With
    ``split_budget=False`` this is ADACUR^No-Split.
    """

    k_anchor: int = 100
    n_rounds: int = 5
    budget_ce: int = 200
    strategy: str = "topk"           # "topk" | "softmax" | "random"
    first_round: str = "random"      # "random" | "retriever"
    split_budget: bool = True
    k_retrieve: int = 100            # top-k to return
    softmax_temp: float = 1.0
    # Beyond-paper (motivated by the paper's own §3.2 oracle study, where an
    # ε-fraction of random anchors fixes TopK's diversity problem): mix
    # round_epsilon·k_s uniform-random anchors into every ADAPTIVE round.
    # 0.0 reproduces the paper's algorithm exactly.
    round_epsilon: float = 0.0
    incremental_pinv: bool = True    # beyond-paper: O(k_q k_i k_s) updates
    distributed_gather: bool = False # one-hot-matmul column gather (pod meshes)
    # --- static-shape round engine (core/engine.py) ------------------------
    # "unrolled": python loop over rounds, one trace per (cfg, shapes) — the
    #   seed behavior, works with any (even non-traceable) score_fn.
    # "fori": shape-invariant round body under lax.fori_loop/while_loop; the
    #   round count becomes a *runtime* operand, so changing n_rounds per
    #   call (adaptive round counts, arXiv 2405.03651) does not retrace.
    loop_mode: str = "unrolled"      # "unrolled" | "fori"
    # Route per-round anchor sampling and the final rerank-candidate
    # selection through the fused Pallas score->top-k kernel so the (B, N)
    # approximate score matrix is never materialized in HBM.
    use_fused_topk: bool = False
    fused_tile: int = 6144           # item-axis tile of the fused kernel
    fused_interpret: bool = True     # interpret-mode Pallas (CPU); False on TPU
    # Anytime ADACUR (fori mode only): stop early once the round-over-round
    # provisional top-k_retrieve candidate set overlap reaches 1 - tol.
    # 0.0 always runs the full round budget.
    early_exit_tol: float = 0.0
    # How the per-round item-axis work is staged (requires use_fused_topk):
    # "staged": one fused approx_topk_op pass per consumer — anchor sampling,
    #   and (in monitored/early-exit mode) a second pass for the provisional
    #   top-k — each re-streaming the payload from HBM.
    # "persistent": the whole round runs as ONE payload sweep through
    #   kernels/approx_topk/persistent.py — dequant + estimate GEMM + Gumbel
    #   top-k sampling + provisional top-k fused, with the round state
    #   (e_q, running top-k accumulators) VMEM-resident across item tiles.
    #   The monitored early-exit loop is additionally software-pipelined:
    #   round r+1's anchor sample and round r's provisional monitor share
    #   one sweep, halving payload passes per monitored round.  Rankings are
    #   bit-identical to "staged" in every loop mode (asserted by the parity
    #   and property suites).
    round_kernel: str = "staged"     # "staged" | "persistent"
    # Storage/streaming dtype of the R_anc payload the item-axis hot path
    # reads every round.  "int8" stores per-item-tile symmetric codes + fp32
    # scales (~4x fewer bytes; the fused kernel dequantizes tile-by-tile in
    # registers); "int4" packs two codes per byte (0.125x fp32 bytes) and
    # "fp8" stores float8_e4m3 codes (platform-gated); "bfloat16" halves the
    # payload with no extra state.  An index-backed retriever quantizes its
    # AnchorIndex once at from_index; a bare-r_anc retriever converts the
    # operand inside the trace (per call — prefer the index path at scale).
    # Exact CE scores, the pinv state and the final ranking stay fp32
    # throughout.
    payload_dtype: str = "float32"   # "float32"|"bfloat16"|"int8"|"int4"|"fp8"
    payload_tile: int = 512          # item-axis quantization tile (quantized)
    # Regularized pinv: adaptively-selected anchors are correlated, so the
    # anchor column matrix conditions much worse than a random subset
    # (measured ~13500 vs ~210); truncating tiny singular values keeps the
    # global approximation stable (see EXPERIMENTS.md §Repro).
    pinv_rcond: float = 1e-4

    def __post_init__(self):
        if self.k_anchor % self.n_rounds != 0:
            raise ValueError(
                f"k_anchor={self.k_anchor} must divide evenly into n_rounds={self.n_rounds}"
            )
        if self.split_budget and self.budget_ce < self.k_anchor:
            raise ValueError("budget_ce must cover k_anchor when splitting budget")
        if self.loop_mode not in ("unrolled", "fori"):
            raise ValueError(f"unknown loop_mode '{self.loop_mode}'")
        if self.early_exit_tol > 0.0 and self.loop_mode != "fori":
            raise ValueError("early_exit_tol requires loop_mode='fori'")
        if self.payload_dtype not in (
            "float32", "bfloat16", "int8", "int4", "fp8"
        ):
            raise ValueError(
                f"unknown payload_dtype '{self.payload_dtype}' "
                "(float32|bfloat16|int8|int4|fp8)"
            )
        if self.payload_tile <= 0:
            raise ValueError("payload_tile must be positive")
        if self.payload_dtype == "int4" and self.payload_tile % 2:
            raise ValueError("int4 payloads need an even payload_tile "
                             "(two codes pack per byte)")
        if self.round_kernel not in ("staged", "persistent"):
            raise ValueError(f"unknown round_kernel '{self.round_kernel}'")
        if self.round_kernel == "persistent" and not self.use_fused_topk:
            raise ValueError(
                "round_kernel='persistent' fuses the round into the Pallas "
                "sweep; it requires use_fused_topk=True"
            )


def replace(cfg, **kw):
    """dataclasses.replace that works through our frozen configs."""
    return dataclasses.replace(cfg, **kw)

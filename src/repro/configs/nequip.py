"""NequIP  [arXiv:2101.03164].

n_layers=5 d_hidden=32 l_max=2 n_rbf=8 cutoff=5 — O(3)-equivariant
interatomic potential; irrep tensor-product message passing with
``segment_sum`` scatter (see repro.models.gnn.nequip).
"""

from .base import GNNConfig

CONFIG = GNNConfig(
    name="nequip",
    n_layers=5,
    d_hidden=32,
    l_max=2,
    n_rbf=8,
    cutoff=5.0,
    n_species=64,
)

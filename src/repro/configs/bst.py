"""Behavior Sequence Transformer (Alibaba)  [arXiv:1905.06874].

embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256,
transformer over the user behaviour sequence + target item (joint
query-item scorer => ADACUR-compatible cross-encoder class).
"""

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="bst",
    kind="bst",
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp_dims=(1024, 512, 256),
    n_items=1_000_000,
    interaction="transformer-seq",
)

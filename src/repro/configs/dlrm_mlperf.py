"""DLRM MLPerf benchmark config (Criteo 1TB)  [arXiv:1906.00091].

n_dense=13 n_sparse=26 embed_dim=128 bot_mlp=13-512-256-128
top_mlp=1024-1024-512-256-1, dot interaction.  Table sizes are the
MLPerf/Criteo-Terabyte cardinalities (~880M rows total → row-sharded over
the whole mesh, see DESIGN.md §5).
"""

from .base import RecSysConfig

# MLPerf DLRM (Criteo Terabyte, day_0-23) per-field cardinalities.
CRITEO_TABLE_SIZES = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

CONFIG = RecSysConfig(
    name="dlrm-mlperf",
    kind="dlrm",
    embed_dim=128,
    n_dense=13,
    n_sparse=26,
    bot_mlp=(13, 512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
    table_sizes=CRITEO_TABLE_SIZES,
    n_items=1_000_000,
    interaction="dot",
)

"""Architecture registry: ``--arch <id>`` → (config, family, shape set).

All ten assigned architectures plus the paper's own cross-encoder backbone
(``ce-tiny``, the trained end-to-end example model) are selectable here.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

from . import (
    bert4rec,
    bst,
    dlrm_mlperf,
    granite_moe_1b_a400m,
    mind,
    moonshot_v1_16b_a3b,
    nequip,
    qwen1_5_110b,
    qwen3_8b,
    starcoder2_3b,
)
from .base import LMConfig, replace
from .shapes import SHAPES_BY_FAMILY


@dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str            # "lm" | "gnn" | "recsys"
    config: Any
    adacur_applicable: bool
    notes: str = ""


# The paper's own model: a small cross-encoder backbone trained by the
# end-to-end example (examples/train_cross_encoder.py).
CE_TINY = LMConfig(
    name="ce-tiny",
    n_layers=4,
    d_model=256,
    n_heads=8,
    n_kv_heads=4,
    head_dim=32,
    d_ff=1024,
    vocab_size=512,          # byte-level tokenizer + specials
    qk_norm=True,
    rope_theta=10000.0,
    act="swiglu",
    causal=False,            # cross-encoders read the joint sequence bidirectionally
    max_seq_len=512,
)


REGISTRY: Dict[str, ArchEntry] = {
    "qwen3-8b": ArchEntry("qwen3-8b", "lm", qwen3_8b.CONFIG, True, "primary CE backbone"),
    "qwen1.5-110b": ArchEntry("qwen1.5-110b", "lm", qwen1_5_110b.CONFIG, True),
    "starcoder2-3b": ArchEntry("starcoder2-3b", "lm", starcoder2_3b.CONFIG, True),
    "moonshot-v1-16b-a3b": ArchEntry(
        "moonshot-v1-16b-a3b", "lm", moonshot_v1_16b_a3b.CONFIG, True, "MoE CE backbone"
    ),
    "granite-moe-1b-a400m": ArchEntry(
        "granite-moe-1b-a400m", "lm", granite_moe_1b_a400m.CONFIG, True, "MoE CE backbone"
    ),
    "nequip": ArchEntry(
        "nequip", "gnn", nequip.CONFIG, False,
        "no query/item factorization — ADACUR inapplicable (DESIGN.md §4.1)",
    ),
    "bst": ArchEntry("bst", "recsys", bst.CONFIG, True, "cross-encoder-class scorer"),
    "mind": ArchEntry(
        "mind", "recsys", mind.CONFIG, False,
        "dual-encoder; used as first-round anchor retriever (DESIGN.md §4.1)",
    ),
    "bert4rec": ArchEntry("bert4rec", "recsys", bert4rec.CONFIG, True),
    "dlrm-mlperf": ArchEntry("dlrm-mlperf", "recsys", dlrm_mlperf.CONFIG, True),
    "ce-tiny": ArchEntry("ce-tiny", "lm", CE_TINY, True, "paper repro backbone"),
}


def get(arch_id: str) -> ArchEntry:
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(REGISTRY)}")
    return REGISTRY[arch_id]


def shapes_for(arch_id: str):
    """Assigned shape set for this arch (dict name -> shape dataclass)."""
    return SHAPES_BY_FAMILY[get(arch_id).family]


def cells() -> Tuple[Tuple[str, str], ...]:
    """All assigned (arch x shape) dry-run cells — 40 total."""
    out = []
    for arch_id, entry in REGISTRY.items():
        if arch_id == "ce-tiny":
            continue  # extra, not one of the 40 assigned cells
        for shape_name in SHAPES_BY_FAMILY[entry.family]:
            out.append((arch_id, shape_name))
    return tuple(out)


def smoke_config(arch_id: str):
    """Reduced config of the same family for CPU smoke tests."""
    entry = get(arch_id)
    cfg = entry.config
    if entry.family == "lm":
        moe = cfg.moe
        if moe is not None:
            moe = replace(
                moe, n_experts=4, top_k=2, d_expert=64,
                n_shared_experts=min(moe.n_shared_experts, 1),
                first_k_dense=min(moe.first_k_dense, 1), d_ff_dense=128,
                # generous capacity: smoke tests check decode==encode, which
                # only holds when no batch-dependent capacity drops occur
                capacity_factor=8.0,
            )
        return replace(
            cfg, n_layers=2, d_model=64, n_heads=4,
            n_kv_heads=max(1, 4 * cfg.n_kv_heads // cfg.n_heads),
            head_dim=16, d_ff=128, vocab_size=256, moe=moe,
            max_seq_len=1024, dtype="float32",
        )
    if entry.family == "gnn":
        return replace(cfg, n_layers=2, d_hidden=4, n_rbf=4, n_species=8)
    # recsys
    kw = dict(embed_dim=16, n_items=1000, seq_len=min(cfg.seq_len, 8))
    if cfg.kind == "dlrm":
        kw.update(
            bot_mlp=(13, 32, 16), top_mlp=(64, 32, 1),
            table_sizes=tuple(min(s, 100) for s in cfg.table_sizes),
        )
    if cfg.kind in ("bst", "bert4rec"):
        kw.update(mlp_dims=(32, 16) if cfg.kind == "bst" else (32,))
    return replace(cfg, **kw)

"""Qwen1.5-110B  [hf:Qwen/Qwen1.5-110B; config family per hf:Qwen/Qwen1.5-0.5B].

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064 — QKV bias,
SwiGLU, RoPE theta 1e6.
"""

from .base import LMConfig

CONFIG = LMConfig(
    name="qwen1.5-110b",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab_size=152064,
    qk_norm=False,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="swiglu",
)

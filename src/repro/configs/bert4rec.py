"""BERT4Rec  [arXiv:1904.06690].

embed_dim=64 n_blocks=2 n_heads=2 seq_len=200 — bidirectional transformer
over the interaction sequence; joint (sequence, item) scorer.
"""

from .base import RecSysConfig

CONFIG = RecSysConfig(
    name="bert4rec",
    kind="bert4rec",
    embed_dim=64,
    seq_len=200,
    n_blocks=2,
    n_heads=2,
    mlp_dims=(256,),
    n_items=1_000_000,
    interaction="bidir-seq",
)

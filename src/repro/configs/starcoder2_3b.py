"""StarCoder2-3B  [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 — GQA, RoPE,
GELU MLP (non-gated), tied embeddings, learned bias on projections.
"""

from .base import LMConfig

CONFIG = LMConfig(
    name="starcoder2-3b",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    qk_norm=False,
    qkv_bias=True,
    rope_theta=999999.4,
    act="gelu",
    norm="layernorm",
    mlp_bias=True,
    tie_embeddings=True,
)

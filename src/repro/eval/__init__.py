"""Retrieval quality evaluation: metrics + the budget-matched IR harness.

- ``metrics``  Top-k-Recall (paper §3) and qrels-based recall@k / MRR@k /
               NDCG@k — the single implementation ``repro.core.retrieval``
               re-exports
- ``harness``  InformationRetrievalEvaluator-style driver over the unified
               Retriever API; ``quality_matrix`` is the one-command
               ADACUR / ANNCUR / rerank / hybrid comparison CI gates on
"""

from . import metrics  # noqa: F401
from .metrics import (  # noqa: F401
    RecallReport,
    evaluate_result,
    exact_topk,
    ir_metrics,
    qrels_from_exact,
    qrels_from_gold,
    topk_recall,
)

from . import harness  # noqa: F401
from .harness import MethodReport, evaluate_retriever, quality_matrix  # noqa: F401

"""Retrieval quality metrics: the paper's Top-k-Recall plus standard IR
measures (recall@k, MRR@k, NDCG@k) over explicit relevance judgments.

This module is the single implementation — ``repro.core.retrieval``
re-exports :func:`topk_recall` / :class:`RecallReport` /
:func:`evaluate_result` for backward compatibility.  It deliberately
imports nothing from ``repro`` (pure jax/numpy), so any layer can depend
on it without cycles.

Two complementary views of quality:

- **Top-k-Recall** (paper §3): fraction of the cross-encoder's exact top-k
  found in the method's returned set — ground truth derived from the exact
  score matrix, no external labels.
- **qrels metrics** (InformationRetrievalEvaluator-style): recall@k /
  MRR@k / NDCG@k against explicit per-query relevance judgments
  (``qrels``) — gold entity labels, CE-top-k pseudo-labels
  (:func:`qrels_from_exact`), or graded gains.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Mapping, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

# per-query relevance: {item_id: gain} (graded) or a set of ids (binary)
Qrels = Sequence[Union[Mapping[int, float], frozenset, set]]


def exact_topk(exact_scores: jax.Array, k: int):
    """Ground-truth top-k under the cross-encoder (for recall eval)."""
    return jax.lax.top_k(exact_scores, k)


def topk_recall(retrieved_idx: jax.Array, gt_idx: jax.Array, k: int) -> jax.Array:
    """Top-k-Recall: |retrieved ∩ gt_topk| / k, averaged over the batch.

    ``retrieved_idx`` may contain more than k entries (paper convention:
    recall of the ground-truth top-k within the method's returned set).
    """
    hits = (retrieved_idx[:, :, None] == gt_idx[:, None, :k]).any(axis=1)
    return hits.mean()


@dataclass
class RecallReport:
    method: str
    budget_ce: int
    recall: dict  # k -> float


def evaluate_result(
    method: str,
    result,
    exact_scores: jax.Array,
    ks=(1, 10, 100),
) -> RecallReport:
    """Paper-protocol report for an engine result (``.topk_idx`` /
    ``.ce_calls``).  The ground-truth ranking is computed ONCE at
    ``max(ks)`` — prefixes of one descending top-k are the smaller top-ks
    (ascending-id tie-break is shared), not k separate sorts."""
    k_max = max(ks)
    _, gt = exact_topk(exact_scores, k_max)
    out = {k: float(topk_recall(result.topk_idx, gt, k)) for k in ks}
    return RecallReport(method, result.ce_calls, out)


# ---------------------------------------------------------------------------
# qrels-based IR metrics
# ---------------------------------------------------------------------------


def qrels_from_exact(exact_scores, k: int = 1) -> Qrels:
    """Pseudo-qrels from the CE's exact top-k: the judgment set every
    budget-limited method is trying to recover.  ``k=1`` gives gold-style
    single-relevant judgments (recall@k == accuracy@k, MRR = 1/rank of the
    CE argmax)."""
    _, gt = exact_topk(jnp.asarray(exact_scores), k)
    gt = np.asarray(gt)
    return [frozenset(int(i) for i in row) for row in gt]


def qrels_from_gold(gold) -> Qrels:
    """Qrels from a (B,) gold item-id vector (entity-linking labels)."""
    return [frozenset((int(g),)) for g in np.asarray(gold)]


def _gains(rel) -> Dict[int, float]:
    if isinstance(rel, (set, frozenset)):
        return {int(i): 1.0 for i in rel}
    return {int(i): float(g) for i, g in rel.items()}


def ir_metrics(
    ranked, qrels: Qrels, ks: Sequence[int] = (1, 10, 100)
) -> Dict[str, float]:
    """recall@k / MRR@k / NDCG@k of a ranked retrieval, batch-averaged.

    ``ranked``: (B, R) item ids in descending relevance order (an engine
    result's ``topk_idx``).  ``qrels``: per-query judgments (binary sets or
    graded ``{id: gain}``).  Queries with empty judgments are skipped.
    Duplicate ids in a row (the engine pads under-filled rankings by
    repeating the row-best) count once, at their first position.
    """
    ranked = np.asarray(ranked)
    if ranked.ndim != 2 or len(qrels) != ranked.shape[0]:
        raise ValueError(
            f"ranked {ranked.shape} does not match {len(qrels)} qrels rows"
        )
    sums = {f"{m}@{k}": 0.0 for k in ks for m in ("recall", "mrr", "ndcg")}
    n_eval = 0
    for row, rel in zip(ranked, qrels):
        gains = _gains(rel)
        if not gains:
            continue
        n_eval += 1
        seen = set()
        hits = []                       # (position, gain) of first occurrences
        for pos, item in enumerate(row):
            item = int(item)
            if item in seen:
                continue
            seen.add(item)
            if item in gains:
                hits.append((pos, gains[item]))
        ideal = sorted(gains.values(), reverse=True)
        for k in ks:
            in_k = [(p, g) for p, g in hits if p < k]
            sums[f"recall@{k}"] += len(in_k) / len(gains)
            sums[f"mrr@{k}"] += 1.0 / (in_k[0][0] + 1) if in_k else 0.0
            dcg = sum(g / math.log2(p + 2) for p, g in in_k)
            idcg = sum(
                g / math.log2(i + 2) for i, g in enumerate(ideal[:k])
            )
            sums[f"ndcg@{k}"] += dcg / idcg if idcg > 0 else 0.0
    if n_eval == 0:
        raise ValueError("every qrels row is empty — nothing to evaluate")
    return {name: v / n_eval for name, v in sums.items()}

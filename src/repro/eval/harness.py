"""IR evaluation harness: budget-matched quality matrices over retrievers.

``InformationRetrievalEvaluator``-style driver on top of the engine's
unified Retriever API: run a method over a test query split, collect the
ranked ids, score them against relevance judgments
(:func:`repro.eval.metrics.ir_metrics`) AND the paper's Top-k-Recall
protocol, and cross-check the *measured* CE spend against the engine's
plan (:func:`repro.core.engine.ce_call_plan`).

:func:`quality_matrix` is the one-command comparison the benchmarks and CI
gate consume: ADACUR vs ANNCUR vs retrieve-and-rerank vs multi-stage
hybrid (first-stage candidates -> candidate-restricted ADACUR), every
method at the SAME exact-CE-call budget, every method's spend measured by
its own :class:`~repro.core.scorer.TabulatedScorer`.
"""

from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import AdaCURConfig
from ..core.candidates import (
    BM25Candidates,
    DualEncoderCandidates,
    HybridRetriever,
)
from ..core.engine import (
    AdaCURRetriever,
    ANNCURRetriever,
    RerankRetriever,
)
from ..core.scorer import TabulatedScorer, scorer_stats
from .metrics import evaluate_result, ir_metrics, qrels_from_exact
from .metrics import Qrels


@dataclass
class MethodReport:
    """One method's row in a budget-matched quality matrix."""

    method: str
    planned_ce: int                      # engine plan, per query
    measured_ce: Optional[int] = None    # scorer-measured, per query
    budget_matched: Optional[bool] = None  # measured == planned
    topk_recall: Dict[int, float] = field(default_factory=dict)
    ir: Dict[str, float] = field(default_factory=dict)
    wall_us_per_query: float = 0.0

    def to_json(self) -> dict:
        d = asdict(self)
        d["topk_recall"] = {str(k): v for k, v in self.topk_recall.items()}
        return d


def evaluate_retriever(
    name: str,
    retriever,
    qids,
    key,
    *,
    exact=None,
    qrels: Optional[Qrels] = None,
    ks: Sequence[int] = (1, 10, 100),
    search_kw: Optional[dict] = None,
) -> MethodReport:
    """Run one retriever over the test split and score the ranking.

    ``exact`` (B, N) enables the paper's Top-k-Recall; ``qrels`` enables
    recall@k/MRR@k/NDCG@k.  When the retriever's ``score_fn`` is a
    :class:`~repro.core.scorer.Scorer`, the CE spend of this evaluation
    window is measured and compared to the retriever's plan.
    """
    qids = jnp.asarray(qids)
    b = int(qids.shape[0])
    stats = scorer_stats(getattr(retriever, "score_fn", None))
    if stats is not None:
        jax.effects_barrier()
        before = stats.copy()
    t0 = time.perf_counter()
    res = retriever.search(qids, key, **(search_kw or {}))
    res = jax.block_until_ready(res)
    wall_us = (time.perf_counter() - t0) / b * 1e6
    rep = MethodReport(
        method=name,
        planned_ce=int(res.ce_calls),
        wall_us_per_query=wall_us,
    )
    if stats is not None:
        jax.effects_barrier()
        delta = stats - before
        rep.measured_ce = delta.ce_calls // b
        rep.budget_matched = delta.ce_calls == rep.planned_ce * b
    if exact is not None:
        rep.topk_recall = evaluate_result(name, res, exact, ks=ks).recall
    if qrels is not None:
        rep.ir = ir_metrics(np.asarray(res.topk_idx), qrels, ks=ks)
    return rep


def quality_matrix(
    ce,
    index,
    test_q,
    matrix,
    *,
    budget: int = 200,
    n_rounds: int = 5,
    ks: Sequence[int] = (1, 10, 100),
    shortlist_k: Optional[int] = None,
    qrels_k: int = 1,
    corpus_tokens=None,
    query_tokens=None,
    seed: int = 0,
    use_fused_topk: bool = False,
) -> List[MethodReport]:
    """Budget-matched quality matrix: every retrieval strategy this repo
    implements, at the same CE-call budget, over one synthetic CE domain.

    - ``adacur``       multi-round adaptive anchors (the paper's method)
    - ``anncur``       fixed anchors, one round (Yadav et al. 2022)
    - ``rerank_de``    dual-encoder retrieve-and-rerank (whole budget reranks)
    - ``hybrid_de``    DE shortlist -> candidate-restricted ADACUR
    - ``hybrid_bm25``  BM25 shortlist -> candidate-restricted ADACUR
      (only when token data is supplied)

    ``matrix`` is the (n_queries, N) exact score table (rows indexed by
    global query id) — each method gets its own TabulatedScorer over it, so
    the spend measurement windows cannot bleed into each other.  ``qrels``
    are the CE's exact top-``qrels_k`` pseudo-labels.
    """
    matrix = np.asarray(matrix, dtype=np.float32)
    test_q = jnp.asarray(test_q)
    exact = jnp.asarray(matrix[np.asarray(test_q)])
    qrels = qrels_from_exact(exact, k=qrels_k)
    if shortlist_k is None:
        shortlist_k = min(4 * budget, index.n_items)
    if shortlist_k < budget:
        raise ValueError(f"shortlist_k={shortlist_k} < budget={budget}")
    key = jax.random.PRNGKey(seed)
    k_anchor = max(n_rounds, (budget // 2) // n_rounds * n_rounds)
    cfg = AdaCURConfig(
        k_anchor=k_anchor, n_rounds=n_rounds, budget_ce=budget,
        strategy="topk", k_retrieve=max(ks), loop_mode="fori",
        use_fused_topk=use_fused_topk,
    )
    de = DualEncoderCandidates(ce.q_emb, ce.i_emb, n_valid=index.n_items)
    ev = lambda name, ret, **kw: evaluate_retriever(
        name, ret, test_q, key, exact=exact, qrels=qrels, ks=ks, **kw
    )

    reports = [
        ev("adacur", AdaCURRetriever.from_index(
            index, TabulatedScorer(matrix), cfg)),
        ev("anncur", ANNCURRetriever.from_index(
            index.with_anchors(k_anchor=cfg.k_anchor,
                               key=jax.random.PRNGKey(seed + 1)),
            TabulatedScorer(matrix), budget, k_retrieve=cfg.k_retrieve)),
        ev("rerank_de", RerankRetriever.from_index(
            index, TabulatedScorer(matrix), budget,
            k_retrieve=cfg.k_retrieve),
            search_kw=dict(candidate_idx=de(test_q, budget))),
        ev("hybrid_de", HybridRetriever(
            score_fn=TabulatedScorer(matrix), generator=de, cfg=cfg,
            index=index, shortlist_k=shortlist_k, mode="mask")),
    ]
    if corpus_tokens is not None and query_tokens is not None:
        bm = BM25Candidates(corpus_tokens, query_tokens,
                            n_valid=index.n_items)
        reports.append(ev("hybrid_bm25", HybridRetriever(
            score_fn=TabulatedScorer(matrix), generator=bm, cfg=cfg,
            index=index, shortlist_k=shortlist_k, mode="mask")))
    return reports

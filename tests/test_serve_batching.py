"""AdaCURService continuous micro-batching edges: empty flush, deadline
stragglers padded into static batch buckets, bucket-padding parity with an
exact-size batch, swap_index racing queued requests, and measured cache-hit
accounting across requests sharing (query, item) pairs."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig
from repro.core.engine import AdaCURRetriever
from repro.core.index import AnchorIndex
from repro.core.scorer import CachingScorer, TabulatedScorer
from repro.data.synthetic import make_synthetic_ce
from repro.launch.serve import AdaCURService, RetrievalRequest

N_Q, N_ITEMS = 60, 100
CFG = AdaCURConfig(
    k_anchor=8, n_rounds=2, budget_ce=16, k_retrieve=5, loop_mode="fori"
)


@pytest.fixture(scope="module")
def m():
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=N_Q, n_items=N_ITEMS)
    return np.asarray(ce.full_matrix(jnp.arange(N_Q)))


def _service(m, *, item_offset=0, deterministic=False, max_batch=4,
             batch_buckets=None, max_wait_s=60.0, cache=True):
    """Index-backed service; ``item_offset`` shifts the external item ids
    (the scorer's matrix is widened to keep external ids addressable)."""
    wide = np.zeros((N_Q, item_offset + N_ITEMS), dtype=np.float32)
    wide[:, item_offset:] = m
    scorer = TabulatedScorer(wide)
    score_fn = CachingScorer(scorer) if cache else scorer
    index = AnchorIndex.from_r_anc(
        jnp.asarray(m[:40]),
        item_ids=jnp.arange(item_offset, item_offset + N_ITEMS),
    )
    retriever = AdaCURRetriever.from_index(index, score_fn, CFG)
    return AdaCURService(
        retriever=retriever, max_batch=max_batch, max_wait_s=max_wait_s,
        batch_buckets=batch_buckets, deterministic=deterministic,
    )


class TestFlushEdges:
    def test_empty_flush_and_poll(self, m):
        svc = _service(m)
        assert svc.flush() == []
        assert svc.poll() == []

    def test_deadline_straggler_partial_bucket(self, m):
        """A lone queued request is flushed by the event loop's poll after
        the deadline, padded up to a static bucket; the padding never
        reaches the response."""
        svc = _service(m, max_wait_s=0.01, batch_buckets=[2, 4])
        assert svc.submit(RetrievalRequest(query_id=45)) is None
        assert svc.poll() == []                 # not overdue yet
        time.sleep(0.02)
        out = svc.poll()
        assert [r.query_id for r in out] == [45]
        assert len(out[0].item_ids) == CFG.k_retrieve
        assert (out[0].item_ids < N_ITEMS).all()
        assert svc.flush() == []                # queue fully drained

    def test_padded_flush_is_valid_and_reproducible(self, m):
        """A padded partial bucket serves exactly its real requests with
        exact CE scores, and (deterministic mode) the same batch composition
        replays bit-identically — the compiled bucket executable is reused,
        not retraced into a new shape."""
        svc = _service(m, deterministic=True, max_batch=4,
                       batch_buckets=[4], cache=False)
        svc.submit(RetrievalRequest(query_id=41))
        svc.submit(RetrievalRequest(query_id=53))
        a = svc.flush()                        # 2 real rows padded to 4
        assert [r.query_id for r in a] == [41, 53]
        for r in a:
            assert (0 <= r.item_ids).all() and (r.item_ids < N_ITEMS).all()
            # returned scores are the exact CE scores of the returned ids
            np.testing.assert_allclose(
                r.scores, m[r.query_id][r.item_ids], atol=1e-5, rtol=1e-5
            )
        svc.submit(RetrievalRequest(query_id=41))
        svc.submit(RetrievalRequest(query_id=53))
        b = svc.flush()
        for ra, rb in zip(a, b):
            np.testing.assert_array_equal(ra.item_ids, rb.item_ids)
            np.testing.assert_array_equal(ra.scores, rb.scores)


class TestSwapIndexRacing:
    def test_queued_requests_drain_against_admitting_index(self, m):
        """Requests queued before swap_index are served by the index they
        were admitted under; the swap only affects later requests.  The two
        indices expose disjoint external id ranges, so mixing would show."""
        svc = _service(m, item_offset=1000, deterministic=True)
        old_index = svc.index
        # re-key the same corpus under a different external id range
        new_index = AnchorIndex.from_r_anc(
            jnp.asarray(m[:40]), item_ids=jnp.arange(2000, 2000 + N_ITEMS)
        )
        # widen the scorer's matrix so both id ranges stay addressable
        wide = np.zeros((N_Q, 2000 + N_ITEMS), dtype=np.float32)
        wide[:, 1000:1000 + N_ITEMS] = m
        wide[:, 2000:] = m
        svc._scorer.inner.matrix = wide

        svc.submit(RetrievalRequest(query_id=44))
        svc.submit(RetrievalRequest(query_id=47))
        drained = svc.swap_index(new_index)
        assert [r.query_id for r in drained] == [44, 47]
        for r in drained:
            assert (r.item_ids >= 1000).all() and (r.item_ids < 2000).all()
        assert svc.index is new_index and svc.retriever.index is new_index

        # same batch composition after the swap: deterministic mode + the
        # same bucket shape replay the identical trajectories, so only the
        # id namespace may differ
        svc.submit(RetrievalRequest(query_id=44))
        svc.submit(RetrievalRequest(query_id=47))
        after = svc.flush()
        for r_new, r_old in zip(after, drained):
            assert (r_new.item_ids >= 2000).all()
            np.testing.assert_array_equal(r_new.item_ids - 1000, r_old.item_ids)
            np.testing.assert_array_equal(r_new.scores, r_old.scores)

    def test_swap_requires_index_backed_retriever(self, m):
        scorer = TabulatedScorer(m)
        retr = AdaCURRetriever(scorer, jnp.asarray(m[:40]), CFG)
        svc = AdaCURService(retriever=retr, max_batch=2)
        with pytest.raises(ValueError, match="index-backed"):
            svc.swap_index(AnchorIndex.from_r_anc(jnp.asarray(m[:40])))


class TestMeasuredAccounting:
    def test_cache_hits_across_requests_sharing_pairs(self, m):
        """Two identical requests: the second is served entirely from the
        score cache (deterministic mode pins the trajectory), and the
        response-level measured accounting shows it."""
        svc = _service(m, deterministic=True, batch_buckets=[1, 2, 4])
        assert svc.submit(RetrievalRequest(query_id=50)) is None
        (r1,) = svc.flush()
        assert r1.measured_ce_calls == CFG.budget_ce
        assert r1.cache_hits == 0
        assert svc.submit(RetrievalRequest(query_id=50)) is None
        (r2,) = svc.flush()
        assert r2.measured_ce_calls == 0
        assert r2.cache_hits == CFG.budget_ce
        np.testing.assert_array_equal(r1.item_ids, r2.item_ids)
        np.testing.assert_array_equal(r1.scores, r2.scores)
        # planned budget is still reported alongside the measured cost
        assert r1.ce_calls == CFG.budget_ce

    def test_partial_sharing_between_queries(self, m):
        """The cache is pair-keyed: a different query touching the same
        items shares no (q, i) pairs, so it cannot be served from another
        query's cached scores — measured calls stay at the full budget."""
        svc = _service(m, deterministic=True, batch_buckets=[1, 2, 4])
        svc.submit(RetrievalRequest(query_id=50))
        (r1,) = svc.flush()
        svc.submit(RetrievalRequest(query_id=51))
        (r2,) = svc.flush()
        # pair-keyed cache: a fresh query can never hit another query's pairs
        assert r2.measured_ce_calls == CFG.budget_ce
        assert r2.cache_hits == 0

    def test_bare_score_fn_reports_no_measured_stats(self, m):
        svc = _service(m, cache=False)
        svc.submit(RetrievalRequest(query_id=42))
        (r,) = svc.flush()
        # TabulatedScorer is a Scorer: measured stats present even uncached
        assert r.measured_ce_calls == CFG.budget_ce
        assert svc.scorer_stats is not None

        def bare(q, idx):
            return jnp.zeros(idx.shape, jnp.float32)

        index = AnchorIndex.from_r_anc(jnp.asarray(m[:40]))
        retr = AdaCURRetriever.from_index(index, bare, CFG)
        svc2 = AdaCURService(retriever=retr, max_batch=2)
        svc2.submit(RetrievalRequest(query_id=42))
        (r2,) = svc2.flush()
        assert r2.measured_ce_calls is None and svc2.scorer_stats is None

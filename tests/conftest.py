"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the single real CPU device; only
``launch/dryrun.py`` installs the 512-device placeholder mesh."""

import os

import jax
import jax.numpy as jnp
import pytest

jax.config.update("jax_enable_x64", False)

# On a single-core host the async CPU client has one execute thread: a host
# callback that launches a nested jit (CrossEncoderScorer's CE forward)
# blocks that thread waiting for work that needs the same thread — the
# single-device twin of the SPMD-mesh deadlock DeviceCEScorer exists to fix.
# Synchronous dispatch runs callbacks inline on the caller, so the nested
# launch cannot self-block; pipelining is worthless on one core anyway.
# Must run at import time, before any test instantiates the CPU client.
if len(os.sched_getaffinity(0)) < 2:
    jax.config.update("jax_cpu_enable_async_dispatch", False)


@pytest.fixture(scope="session")
def small_domain():
    """A small synthetic CE domain shared across core tests."""
    from repro.data.synthetic import make_synthetic_ce

    key = jax.random.PRNGKey(0)
    ce = make_synthetic_ce(key, n_queries=260, n_items=2000)
    m = ce.full_matrix(jnp.arange(260))
    return {
        "ce": ce,
        "r_anc": m[:200],
        "test_q": jnp.arange(200, 260),
        "exact": m[200:],
    }

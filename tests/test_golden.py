"""Golden regression: the engine's top-k ids + exact scores for a
fixed-seed synthetic corpus are pinned in ``tests/golden/`` and compared
with tolerance — silent numeric drift across refactors fails CI instead of
shipping.

Regenerate intentionally (after an *accepted* behavior change) with:

    GOLDEN_REGEN=1 PYTHONPATH=src python -m pytest tests/test_golden.py
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig
from repro.core import engine
from repro.core.scorer import TabulatedScorer
from repro.data.synthetic import make_synthetic_ce

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "engine_topk.json")

# near-ties may swap ranking positions under BLAS/version drift; scores
# themselves must stay put much more tightly than this
SCORE_ATOL = 1e-3
MIN_ID_OVERLAP = 0.9

CASES = {
    # name -> engine configuration over the same fixed-seed domain
    "fori_dense": AdaCURConfig(
        k_anchor=24, n_rounds=4, budget_ce=48, k_retrieve=10, loop_mode="fori"
    ),
    "fori_fused": AdaCURConfig(
        k_anchor=24, n_rounds=4, budget_ce=48, k_retrieve=10, loop_mode="fori",
        use_fused_topk=True, fused_tile=128,
    ),
    "unrolled_no_split": AdaCURConfig(
        k_anchor=48, n_rounds=4, budget_ce=48, split_budget=False,
        k_retrieve=10, loop_mode="unrolled",
    ),
    # the persistent round kernel under the software-pipelined monitored
    # loop (early-exit monitor + next round's sample share one payload
    # sweep) — pins the riskiest fusion path; staged vs persistent parity
    # itself is asserted bitwise in test_engine_properties
    "fori_persistent_monitored": AdaCURConfig(
        k_anchor=24, n_rounds=4, budget_ce=48, k_retrieve=10, loop_mode="fori",
        use_fused_topk=True, fused_tile=128, round_kernel="persistent",
        early_exit_tol=0.4,
    ),
}


@pytest.fixture(scope="module")
def dom():
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=60, n_items=400)
    m = np.asarray(ce.full_matrix(jnp.arange(60)))
    return {"m": m, "r_anc": jnp.asarray(m[:40]), "test_q": jnp.arange(40, 60)}


def _run_case(dom, cfg: AdaCURConfig):
    run = engine.make_engine(TabulatedScorer(dom["m"]), cfg)
    res = run(dom["r_anc"], dom["test_q"], jax.random.PRNGKey(11))
    return (
        np.asarray(res.topk_idx, dtype=np.int64),
        np.asarray(res.topk_scores, dtype=np.float64),
    )


def test_engine_topk_matches_golden(dom):
    if os.environ.get("GOLDEN_REGEN"):
        snap = {}
        for name, cfg in CASES.items():
            idx, scores = _run_case(dom, cfg)
            snap[name] = {"topk_idx": idx.tolist(),
                          "topk_scores": np.round(scores, 6).tolist()}
        os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
        with open(GOLDEN_PATH, "w") as f:
            json.dump(snap, f, indent=1)
        pytest.skip(f"regenerated {GOLDEN_PATH}")

    assert os.path.exists(GOLDEN_PATH), (
        f"missing golden snapshot {GOLDEN_PATH}; run with GOLDEN_REGEN=1"
    )
    with open(GOLDEN_PATH) as f:
        snap = json.load(f)
    assert set(snap) == set(CASES), "golden cases out of sync with CASES"

    for name, cfg in CASES.items():
        idx, scores = _run_case(dom, cfg)
        g_idx = np.asarray(snap[name]["topk_idx"])
        g_scores = np.asarray(snap[name]["topk_scores"])
        # scores drift-bounded elementwise: a near-tie id swap keeps the
        # score trajectory within tolerance, real drift does not
        np.testing.assert_allclose(
            scores, g_scores, atol=SCORE_ATOL, rtol=0,
            err_msg=f"[{name}] top-k scores drifted past {SCORE_ATOL}",
        )
        same = (idx[:, :, None] == g_idx[:, None, :]).any(-1).mean()
        assert same >= MIN_ID_OVERLAP, (
            f"[{name}] top-k id overlap {same:.3f} < {MIN_ID_OVERLAP}"
        )
        # retrieved scores must remain the exact CE scores of their ids
        np.testing.assert_allclose(
            scores,
            dom["m"][40:][np.arange(20)[:, None], idx],
            atol=1e-4, rtol=1e-4,
            err_msg=f"[{name}] returned scores are not the exact CE scores",
        )

"""Scorer subsystem: provider parity, measured accounting, (query, item)
score caching, length-bucketed micro-batching with zero retraces, and the
real-CE end-to-end search parity vs the exact tabulated matrix."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import AdaCURConfig, replace
from repro.core import engine
from repro.core.scorer import (
    CachingScorer,
    CrossEncoderScorer,
    DeviceCEScorer,
    Scorer,
    SyntheticScorer,
    TabulatedScorer,
    scorer_stats,
)
from repro.data.synthetic import make_synthetic_ce, make_zeshel_like
from repro.models import cross_encoder


@pytest.fixture(scope="module")
def ce_setup():
    """Tiny transformer CE + its exact score matrix (the parity oracle)."""
    ds = make_zeshel_like(0, n_items=80, n_queries=24, item_len=12, query_len=8)
    cfg_lm = replace(
        registry.CE_TINY, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=ds.vocab_size, dtype="float32",
        remat=False,
    )
    params, _ = cross_encoder.init_cross_encoder(jax.random.PRNGKey(0), cfg_lm)
    scorer = CrossEncoderScorer(
        params, cfg_lm, ds.pair_tokens, micro_batch=16, flash_block=(16, 16),
        len_buckets=(32, 64),
    )
    matrix = np.asarray(
        scorer._host(np.arange(24), np.tile(np.arange(80), (24, 1)))
    )
    scorer.reset_stats()
    return {"ds": ds, "lm": (params, cfg_lm), "scorer": scorer, "m": matrix}


class TestProviders:
    def test_protocol(self, ce_setup):
        ce = make_synthetic_ce(jax.random.PRNGKey(1), n_queries=8, n_items=50)
        assert isinstance(SyntheticScorer(ce), Scorer)
        assert isinstance(TabulatedScorer(np.zeros((4, 5))), Scorer)
        assert isinstance(ce_setup["scorer"], Scorer)
        assert scorer_stats(lambda q, i: i) is None

    def test_synthetic_matches_ce(self):
        ce = make_synthetic_ce(jax.random.PRNGKey(1), n_queries=8, n_items=50)
        s = SyntheticScorer(ce)
        q = jnp.arange(4)
        idx = jnp.arange(12).reshape(4, 3)
        np.testing.assert_array_equal(
            np.asarray(s(q, idx)), np.asarray(ce.score_pairs(q, idx))
        )

    def test_tabulated_counts_inside_jit(self):
        m = np.arange(20, dtype=np.float32).reshape(4, 5)
        tab = TabulatedScorer(m, record_pairs=True)

        @jax.jit
        def f(q, idx):
            return tab(q, idx)

        q = jnp.array([0, 2])
        idx = jnp.array([[1, 3], [0, 4]])
        out = np.asarray(f(q, idx))
        np.testing.assert_array_equal(out, m[np.array([0, 2])[:, None], np.asarray(idx)])
        assert tab.stats.ce_calls == 4 and tab.stats.requests == 1
        out2 = np.asarray(f(q, idx))       # compiled path still counts
        np.testing.assert_array_equal(out, out2)
        assert tab.stats.ce_calls == 8 and len(tab.call_log) == 2


class TestCachingScorer:
    def test_hits_and_accounting(self):
        m = np.random.default_rng(0).normal(size=(6, 30)).astype(np.float32)
        cache = CachingScorer(TabulatedScorer(m))
        q = jnp.array([1, 2])
        idx = jnp.array([[0, 1, 2], [3, 4, 5]])
        a = np.asarray(cache(q, idx))
        np.testing.assert_array_equal(a, m[np.array([1, 2])[:, None], np.asarray(idx)])
        assert cache.stats.ce_calls == 6 and cache.stats.cache_hits == 0
        b = np.asarray(cache(q, idx))
        np.testing.assert_array_equal(a, b)
        assert cache.stats.ce_calls == 6 and cache.stats.cache_hits == 6
        # partial overlap: only fresh pairs reach the inner scorer
        idx2 = jnp.array([[0, 1, 7], [3, 8, 9]])
        np.asarray(cache(q, idx2))
        assert cache.stats.ce_calls == 9 and cache.stats.cache_hits == 9

    def test_within_call_dedup(self):
        m = np.random.default_rng(1).normal(size=(4, 10)).astype(np.float32)
        inner = TabulatedScorer(m)
        cache = CachingScorer(inner)
        q = jnp.array([0, 0])                 # two rows, same query
        idx = jnp.array([[1, 2, 1], [2, 3, 3]])   # duplicates inside the call
        out = np.asarray(cache(q, idx))
        np.testing.assert_array_equal(out, m[0][np.asarray(idx)])
        assert cache.stats.ce_calls == 3       # {1, 2, 3} scored once
        assert inner.stats.ce_calls == 3

    def test_lru_capacity(self):
        m = np.zeros((1, 100), dtype=np.float32)
        cache = CachingScorer(TabulatedScorer(m), capacity=4)
        cache(jnp.array([0]), jnp.arange(6)[None, :])
        assert cache.stats.cache_size == 4
        # the two oldest pairs were evicted and must be re-scored
        cache(jnp.array([0]), jnp.arange(2)[None, :])
        assert cache.stats.ce_calls == 8

    def test_rejects_pure_traced_inner(self):
        ce = make_synthetic_ce(jax.random.PRNGKey(1), n_queries=8, n_items=50)
        with pytest.raises(TypeError):
            CachingScorer(SyntheticScorer(ce))


class TestCrossEncoderScorer:
    def test_matches_direct_score_pairs(self, ce_setup):
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        sc = ce_setup["scorer"]
        q = np.arange(5)
        idx = (np.arange(20).reshape(5, 4) * 3) % 80
        toks = jnp.asarray(ds.pair_tokens(q, idx))
        ref = np.asarray(cross_encoder.score_pairs(params, toks, cfg_lm))
        out = np.asarray(sc(jnp.asarray(q), jnp.asarray(idx)))
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)

    def test_bucketing_never_retraces(self, ce_setup):
        sc = ce_setup["scorer"]
        sc._host(np.arange(3), np.arange(6).reshape(3, 2))
        n0 = sc.n_traces
        # new (batch, k) shapes, same token bucket -> zero retraces
        for b, k in [(1, 1), (7, 5), (2, 16), (5, 3)]:
            sc._host(np.arange(b), (np.arange(b * k).reshape(b, k) * 7) % 80)
        assert sc.n_traces == n0
        assert sc.stats.batch_pad > 0          # partial chunks were padded

    def test_bucket_overflow_raises_at_construction(self, ce_setup):
        """Satellite: the pair-length probe fails eagerly, with an actionable
        message — not an opaque XLA error from inside jax.pure_callback."""
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        with pytest.raises(ValueError, match="len_buckets"):
            CrossEncoderScorer(params, cfg_lm, ds.pair_tokens, len_buckets=(8,))

    def test_bucket_overflow_raises_per_call(self, ce_setup):
        """With the probe disabled (pair_fn that rejects dummy ids), the host
        enqueue path still raises the same actionable ValueError."""
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        sc = CrossEncoderScorer(
            params, cfg_lm, ds.pair_tokens, len_buckets=(8,),
            probe_pair_len=False,
        )
        with pytest.raises(ValueError, match="len_buckets"):
            sc._host(np.arange(2), np.arange(4).reshape(2, 2))

    def test_flash_varlen_matches_ref_attention(self, ce_setup):
        """One padded bucket, mixed true lengths: the flash path's SMEM
        valid-length masking equals the (B, L) kv_mask reference."""
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        toks = ds.pair_tokens(np.arange(4), np.arange(12).reshape(4, 3))
        b, k, length = toks.shape
        padded = np.zeros((b, k, 48), np.int32)
        padded[:, :, :length] = toks
        ref = cross_encoder.score_pairs(params, jnp.asarray(padded), cfg_lm)
        flash = cross_encoder.score_pairs(
            params, jnp.asarray(padded), cfg_lm, attn_impl="flash",
            flash_block=(16, 16),
        )
        np.testing.assert_allclose(
            np.asarray(flash), np.asarray(ref), atol=1e-5, rtol=1e-5
        )


class TestEndToEndParity:
    @pytest.mark.parametrize("loop_mode", ["unrolled", "fori"])
    def test_real_ce_search_matches_tabulated(self, ce_setup, loop_mode):
        """The acceptance bar: an engine search scored by the REAL
        cross-encoder retrieves exactly what the tabulated exact matrix
        retrieves — tokenization, bucketing, micro-batching and the flash
        path introduce no drift."""
        m = ce_setup["m"]
        cfg = AdaCURConfig(
            k_anchor=12, n_rounds=3, budget_ce=24, k_retrieve=10,
            loop_mode=loop_mode,
        )
        r_anc = jnp.asarray(m[:16])
        q = jnp.arange(16, 24)
        res_ce = jax.block_until_ready(
            engine.make_engine(ce_setup["scorer"], cfg)(
                r_anc, q, jax.random.PRNGKey(5)
            )
        )
        res_tab = jax.block_until_ready(
            engine.make_engine(TabulatedScorer(m), cfg)(
                r_anc, q, jax.random.PRNGKey(5)
            )
        )
        np.testing.assert_array_equal(
            np.asarray(res_ce.topk_idx), np.asarray(res_tab.topk_idx)
        )
        np.testing.assert_allclose(
            np.asarray(res_ce.topk_scores), np.asarray(res_tab.topk_scores),
            atol=1e-4, rtol=1e-4,
        )

    def test_cached_ce_search(self, ce_setup):
        """CachingScorer over the real CE: a repeated search re-scores
        nothing and returns identical results."""
        m = ce_setup["m"]
        cache = CachingScorer(ce_setup["scorer"])
        cfg = AdaCURConfig(
            k_anchor=12, n_rounds=3, budget_ce=24, k_retrieve=10,
            loop_mode="fori",
        )
        run = engine.make_engine(cache, cfg)
        r_anc = jnp.asarray(m[:16])
        q = jnp.arange(16, 24)
        r1 = jax.block_until_ready(run(r_anc, q, jax.random.PRNGKey(5)))
        cold = cache.stats.ce_calls
        assert cold > 0
        r2 = jax.block_until_ready(run(r_anc, q, jax.random.PRNGKey(5)))
        assert cache.stats.ce_calls == cold          # zero new CE calls
        np.testing.assert_array_equal(
            np.asarray(r1.topk_idx), np.asarray(r2.topk_idx)
        )

    def test_microbatch_pad_rows_never_leak(self, ce_setup):
        """Satellite audit: a batch size that forces micro-batch padding
        (B=5, k_s=4 -> 20 pairs padded to 32; rerank 60 -> 64) keeps
        measured == planned — pad rows are scored for shape stability but
        never reach ``stats.ce_calls`` or the cache."""
        m = ce_setup["m"]
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        sc = CrossEncoderScorer(
            params, cfg_lm, ds.pair_tokens, micro_batch=16,
            flash_block=(16, 16), len_buckets=(32, 64),
        )
        cfg = AdaCURConfig(
            k_anchor=12, n_rounds=3, budget_ce=24, k_retrieve=10,
            loop_mode="fori",
        )
        r_anc = jnp.asarray(m[:16])
        q = jnp.arange(16, 21)                       # B=5: every chunk pads
        jax.block_until_ready(
            engine.make_engine(sc, cfg)(r_anc, q, jax.random.PRNGKey(5))
        )
        assert sc.stats.ce_calls == engine.ce_call_plan(cfg) * 5
        assert sc.stats.batch_pad > 0                # padding really happened
        # through the cache: every miss keys exactly one real pair, so no
        # pad-derived phantom entries can appear
        inner = CrossEncoderScorer(
            params, cfg_lm, ds.pair_tokens, micro_batch=16,
            flash_block=(16, 16), len_buckets=(32, 64),
        )
        cache = CachingScorer(inner)
        jax.block_until_ready(
            engine.make_engine(cache, cfg)(r_anc, q, jax.random.PRNGKey(5))
        )
        assert cache.stats.cache_size == cache.stats.ce_calls
        assert inner.stats.ce_calls == cache.stats.ce_calls
        assert inner.stats.batch_pad > 0


class TestDeviceCEScorer:
    """The device-resident CE provider: in-trace pair assembly + forward,
    exact parity with the host-callback scorer, and measured == planned
    accounting fired from inside the compiled program."""

    @pytest.fixture()
    def device_scorer(self, ce_setup):
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        return DeviceCEScorer(
            params, cfg_lm,
            query_token_fn=lambda q: np.asarray(ds.query_tokens)[q],
            item_tokens=ds.item_tokens,
            len_buckets=(32, 64), flash_block=(16, 16),
        )

    def test_matches_host_scorer(self, ce_setup, device_scorer):
        q = jnp.arange(5)
        idx = jnp.asarray((np.arange(20).reshape(5, 4) * 3) % 80)
        q_tok = device_scorer.tokenize_queries(q)
        out = np.asarray(device_scorer(q_tok, idx))
        ref = np.asarray(ce_setup["scorer"](q, idx))
        np.testing.assert_allclose(out, ref, atol=1e-4, rtol=1e-4)
        assert device_scorer.stats.ce_calls == 20
        assert device_scorer.stats.requests == 1

    def test_engine_search_matches_tabulated(self, ce_setup, device_scorer):
        """Device-resident CE retrieves exactly what the tabulated exact
        matrix retrieves, with zero retraces across run-shape variations."""
        m = ce_setup["m"]
        cfg = AdaCURConfig(
            k_anchor=12, n_rounds=3, budget_ce=24, k_retrieve=10,
            loop_mode="fori",
        )
        r_anc = jnp.asarray(m[:16])
        q = jnp.arange(16, 24)
        q_tok = device_scorer.tokenize_queries(q)
        run = engine.make_engine(device_scorer, cfg)
        res = jax.block_until_ready(run(r_anc, q_tok, jax.random.PRNGKey(5)))
        res_tab = jax.block_until_ready(
            engine.make_engine(TabulatedScorer(m), cfg)(
                r_anc, q, jax.random.PRNGKey(5)
            )
        )
        np.testing.assert_array_equal(
            np.asarray(res.topk_idx), np.asarray(res_tab.topk_idx)
        )
        np.testing.assert_allclose(
            np.asarray(res.topk_scores), np.asarray(res_tab.topk_scores),
            atol=1e-4, rtol=1e-4,
        )
        assert device_scorer.stats.ce_calls == engine.ce_call_plan(cfg) * 8
        n0 = device_scorer.n_traces
        for n_rounds in (1, 3, 2):
            jax.block_until_ready(
                run(r_anc, q_tok, jax.random.PRNGKey(5), n_rounds=n_rounds)
            )
        assert device_scorer.n_traces == n0          # bucketed: no retraces

    def test_bucket_overflow_raises_eagerly(self, ce_setup):
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        sc = DeviceCEScorer(
            params, cfg_lm,
            query_token_fn=lambda q: np.asarray(ds.query_tokens)[q],
            item_tokens=ds.item_tokens, len_buckets=(8,),
        )
        with pytest.raises(ValueError, match="len_buckets"):
            sc.tokenize_queries(jnp.arange(2))

    def test_requires_token_table(self, ce_setup):
        ds, (params, cfg_lm) = ce_setup["ds"], ce_setup["lm"]
        sc = DeviceCEScorer(
            params, cfg_lm,
            query_token_fn=lambda q: np.asarray(ds.query_tokens)[q],
        )
        with pytest.raises(ValueError, match="token table"):
            sc(jnp.zeros((1, 8), jnp.int32), jnp.zeros((1, 2), jnp.int32))

"""Unit + property tests for the CUR / pseudo-inverse substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # hermetic container: deterministic fallback
    from _hypothesis_fallback import given, settings, st
from numpy.testing import assert_allclose

from repro.core import cur


class TestPinv:
    def test_pinv_identity(self):
        a = jnp.eye(5)
        assert_allclose(np.asarray(cur.pinv(a)), np.eye(5), atol=1e-5)

    def test_pinv_moore_penrose_conditions(self):
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (12, 5))
        p = cur.pinv(a)
        assert_allclose(np.asarray(a @ p @ a), np.asarray(a), atol=1e-4)
        assert_allclose(np.asarray(p @ a @ p), np.asarray(p), atol=1e-4)


class TestBlockPinvExtend:
    @pytest.mark.parametrize("m,n,s", [(50, 20, 10), (64, 1, 1), (40, 30, 5), (500, 90, 10)])
    def test_matches_full_pinv(self, m, n, s):
        k1, k2 = jax.random.split(jax.random.PRNGKey(m + n + s))
        a = jax.random.normal(k1, (m, n))
        b = jax.random.normal(k2, (m, s))
        p = cur.pinv(a)
        ext = cur.block_pinv_extend(a, p, b)
        ref = cur.pinv(jnp.concatenate([a, b], axis=1))
        assert_allclose(np.asarray(ext), np.asarray(ref), atol=2e-4)

    def test_rank_deficient_new_columns(self):
        """New columns inside span(A) hit the Greville fallback branch."""
        k = jax.random.PRNGKey(3)
        a = jax.random.normal(k, (30, 10))
        b = a[:, :3] @ jnp.array([[1.0, 0.5, 0.0], [0.0, 1.0, 2.0], [1.0, 0.0, 1.0]])
        p = cur.pinv(a)
        ext = cur.block_pinv_extend(a, p, b)
        m_full = jnp.concatenate([a, b], axis=1)
        # Moore-Penrose condition M M+ M = M still holds for the blended update
        assert_allclose(np.asarray(m_full @ ext @ m_full), np.asarray(m_full), atol=1e-3)

    def test_duplicate_new_columns_stay_finite(self):
        """Two IDENTICAL new columns (exact collisions happen under coarse
        payload grids — int4 especially) make the residual gram exactly
        singular while every column keeps a healthy norm, so neither the
        ridge (which underflows against the fp32 diagonal add) nor the
        norm-based Greville blend catches it.  The update must stay finite
        and bounded; the engine regression was an all-NaN e_q that silently
        disabled rerank suppression (items CE-scored twice — caught by the
        int4 cases of the engine property suite).  The Greville fallback is
        deliberately NOT the exact pinv in this corner (that would need the
        SVD the incremental path exists to avoid), so exact M M+ M = M is
        not asserted — only that the update stays usable."""
        k = jax.random.PRNGKey(4)
        a = jax.random.normal(k, (30, 8))
        col = jax.random.normal(jax.random.fold_in(k, 1), (30, 1))
        b = jnp.concatenate([col, col, col + a[:, :1]], axis=1)
        p = cur.pinv(a)
        ext = cur.block_pinv_extend(a, p, b)
        ext_np = np.asarray(ext)
        assert np.isfinite(ext_np).all(), "bordered update went non-finite"
        # no runaway amplification: entries stay on the order of pinv(A)'s
        assert np.abs(ext_np).max() <= 10.0 * np.abs(np.asarray(p)).max()
        # the healthy third column (outside span, no collision) still
        # reconstructs to within the blended update's usual tolerance
        m_full = jnp.concatenate([a, b], axis=1)
        rec = np.asarray(m_full @ ext @ m_full)
        assert np.isfinite(rec).all()

    @settings(max_examples=20, deadline=None)
    @given(
        # the bordering update is specified for TALL anchor matrices
        # (k_q anchor queries >> k_i anchor items, see cur.block_pinv_extend):
        # keep m >= n + s so [A | B] never goes wide
        m=st.integers(24, 80),
        n=st.integers(1, 15),
        s=st.integers(1, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_incremental_equals_full(self, m, n, s, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        a = jax.random.normal(k1, (m, n))
        b = jax.random.normal(k2, (m, s))
        ext = cur.block_pinv_extend(a, cur.pinv(a), b)
        ref = cur.pinv(jnp.concatenate([a, b], axis=1))
        assert_allclose(np.asarray(ext), np.asarray(ref), atol=5e-4)


class TestApproxScores:
    def test_interpolative_on_anchors(self, small_domain):
        """CUR reconstruction is (near-)exact on the anchor columns themselves
        — the paper's Fig. 7 observation that anchor items have ~zero error."""
        r_anc = small_domain["r_anc"]
        exact = small_domain["exact"]
        key = jax.random.PRNGKey(1)
        anchor = jax.random.choice(key, r_anc.shape[1], (4, 64), replace=False)
        c_test = jnp.take_along_axis(exact[:4], anchor, axis=1)
        s_hat = cur.approx_scores(r_anc, c_test, anchor)
        on_anchor = jnp.take_along_axis(s_hat, anchor, axis=1)
        err = jnp.abs(on_anchor - c_test).max()
        assert float(err) < 0.15  # rcond-regularized, not exactly interpolative

    def test_low_rank_matrix_exact(self):
        """For an exactly low-rank matrix with enough anchors, CUR is exact."""
        key = jax.random.PRNGKey(0)
        k1, k2 = jax.random.split(key)
        u = jax.random.normal(k1, (60, 8))
        v = jax.random.normal(k2, (8, 500))
        m = u @ v                               # rank 8
        r_anc, test_rows = m[:50], m[50:]
        anchor = jnp.tile(jnp.arange(0, 400, 20)[None, :], (10, 1))  # 20 anchors
        c_test = jnp.take_along_axis(test_rows, anchor, axis=1)
        # rcond must sit above float32 noise: the rank-8 matrix's singular
        # values 9..20 are numerical noise (~1e-7 relative) that an overly
        # small rcond would invert into garbage.
        s_hat = cur.approx_scores(r_anc, c_test, anchor, rcond=1e-5)
        assert_allclose(np.asarray(s_hat), np.asarray(test_rows), rtol=1e-3, atol=1e-3)

    def test_query_embedding_factoring_matches(self, small_domain):
        """e_q = C_test @ U then e_q @ R_anc  ==  C_test @ U @ R_anc."""
        r_anc = small_domain["r_anc"]
        exact = small_domain["exact"]
        anchor = jnp.tile(jnp.arange(0, 2000, 40)[None, :], (6, 1))
        c_test = jnp.take_along_axis(exact[:6], anchor, axis=1)
        direct = cur.approx_scores(r_anc, c_test, anchor)
        cols = cur.gather_anchor_columns(r_anc, anchor)
        u = cur.pinv(cols, 1e-6)
        two_gemm = jnp.einsum("bk,bkq,qn->bn", c_test, u, r_anc)
        assert_allclose(np.asarray(direct), np.asarray(two_gemm), rtol=2e-3, atol=2e-3)

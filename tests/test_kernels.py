"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp ref.py oracles, in interpret mode (CPU executes
the kernel bodies; Mosaic lowering is the TPU target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # hermetic container: deterministic fallback
    from _hypothesis_fallback import given, settings, st
from numpy.testing import assert_allclose

from repro.kernels.approx_topk import quant
from repro.kernels.approx_topk.ops import approx_topk_op
from repro.kernels.approx_topk.persistent import persistent_round_op
from repro.kernels.approx_topk.ref import approx_topk_reference
from repro.core.sampling import blocked_gumbel
from repro.kernels.embedding_bag.ops import embedding_bag_op
from repro.kernels.embedding_bag.ref import embedding_bag_reference
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_reference


class TestFlashAttention:
    @pytest.mark.parametrize(
        "b,lq,lk,h,kv,hd,causal",
        [
            (2, 128, 128, 4, 2, 32, True),     # GQA 2:1
            (1, 256, 256, 4, 4, 64, True),     # MHA
            (2, 100, 100, 2, 1, 16, False),    # MQA, bidir, ragged tail
            (1, 64, 192, 2, 2, 32, True),      # decode-chunk (Lk > Lq)
            (1, 128, 128, 8, 2, 128, True),    # GQA 4:1, MXU-width head
        ],
    )
    def test_matches_reference(self, b, lq, lk, h, kv, hd, causal):
        ks = jax.random.split(jax.random.PRNGKey(b * lq + lk), 3)
        q = jax.random.normal(ks[0], (b, lq, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, lk, kv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, lk, kv, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
        ref = attention_reference(q, k, v, causal=causal)
        assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(dtype)
        out = flash_attention(q, k, v, causal=True, interpret=True)
        ref = attention_reference(q, k, v, causal=True)
        tol = 2e-5 if dtype == jnp.float32 else 2e-2
        assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
        )

    @settings(max_examples=8, deadline=None)
    @given(
        lq=st.integers(16, 130),
        h=st.sampled_from([2, 4]),
        kv=st.sampled_from([1, 2]),
        hd=st.sampled_from([16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 1000),
    )
    def test_property_random_shapes(self, lq, h, kv, hd, causal, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, lq, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (1, lq, kv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (1, lq, kv, hd), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32, interpret=True)
        ref = attention_reference(q, k, v, causal=causal)
        assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5, rtol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(
        l=st.integers(17, 96),
        h=st.sampled_from([2, 4]),
        causal=st.booleans(),
        seed=st.integers(0, 1000),
    )
    def test_varlen_kv_lens_matches_masked_reference(self, l, h, causal, seed):
        """Per-example SMEM valid lengths (the CE bucket-padding path): the
        kernel must equal dense attention over each row's valid prefix, at
        every valid query position."""
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        b, hd = 3, 16
        q = jax.random.normal(ks[0], (b, l, h, hd), jnp.float32)
        k = jax.random.normal(ks[1], (b, l, h, hd), jnp.float32)
        v = jax.random.normal(ks[2], (b, l, h, hd), jnp.float32)
        lens = jax.random.randint(ks[3], (b,), 1, l + 1)
        out = flash_attention(
            q, k, v, causal=causal, block_q=32, block_k=32, interpret=True,
            kv_lens=lens,
        )
        for i in range(b):
            n = int(lens[i])
            ref = attention_reference(
                q[i : i + 1, :n], k[i : i + 1, :n], v[i : i + 1, :n],
                causal=causal,
            )
            assert_allclose(
                np.asarray(out[i, :n]), np.asarray(ref[0]), atol=3e-5, rtol=3e-5
            )


class TestApproxTopK:
    @pytest.mark.parametrize("impl", ["pallas", "scan"])
    @pytest.mark.parametrize(
        "b,kq,n,a,k,tile",
        [(4, 64, 2048, 16, 32, 256), (2, 100, 999, 8, 10, 128), (1, 32, 5000, 4, 64, 512)],
    )
    def test_matches_reference(self, b, kq, n, a, k, tile, impl):
        ks = jax.random.split(jax.random.PRNGKey(n + k), 3)
        e_q = jax.random.normal(ks[0], (b, kq))
        r = jax.random.normal(ks[1], (kq, n))
        anchors = jax.random.randint(ks[2], (b, a), 0, n)
        v1, i1 = approx_topk_op(e_q, r, anchors, k, tile=tile, interpret=True, impl=impl)
        v2, i2 = approx_topk_reference(e_q, r, anchors, k)
        assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4, rtol=1e-4)
        # fused and dense rankings are BIT-equal, not merely set-equal:
        # per-column dots agree bitwise and ties break by ascending index
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        # anchor masking property: no returned id may be a masked anchor
        hits = (np.asarray(i1)[:, :, None] == np.asarray(anchors)[:, None, :]).any()
        assert not hits

    @pytest.mark.parametrize("impl", ["pallas", "scan"])
    def test_gumbel_noise_input(self, impl):
        """SoftMax sampling path: scores + Gumbel noise, S_hat never formed."""
        ks = jax.random.split(jax.random.PRNGKey(5), 4)
        e_q = jax.random.normal(ks[0], (3, 40))
        r = jax.random.normal(ks[1], (40, 1200))
        anchors = jax.random.randint(ks[2], (3, 8), 0, 1200)
        g = jax.random.gumbel(ks[3], (3, 1200), dtype=jnp.float32)
        v1, i1 = approx_topk_op(e_q, r, anchors, 16, tile=256, interpret=True,
                                noise=g, impl=impl)
        v2, i2 = approx_topk_reference(e_q, r, anchors, 16, noise=g)
        assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4, rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    @pytest.mark.parametrize("impl", ["pallas", "scan"])
    def test_dense_mask_and_n_valid(self, impl):
        ks = jax.random.split(jax.random.PRNGKey(6), 4)
        e_q = jax.random.normal(ks[0], (2, 32))
        r = jax.random.normal(ks[1], (32, 900))
        anchors = jnp.full((2, 1), -1, jnp.int32)
        mask = jax.random.bernoulli(ks[2], 0.2, (2, 900))
        v1, i1 = approx_topk_op(e_q, r, anchors, 12, tile=128, interpret=True,
                                mask=mask, n_valid=800, impl=impl)
        v2, i2 = approx_topk_reference(e_q, r, anchors, 12, mask=mask, n_valid=800)
        assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4, rtol=1e-4)
        assert (np.asarray(i1) < 800).all()
        assert not np.asarray(jnp.take_along_axis(mask, i1, axis=1)).any()

    @pytest.mark.parametrize("impl", ["pallas", "scan"])
    def test_exact_tie_break_by_ascending_index(self, impl):
        """Exact score ties (integer-valued inputs: the GEMM is exact) must
        resolve deterministically by ascending item index, bit-equal to the
        dense reference — within a tile, across tiles, and in the merge."""
        kq, n, k = 8, 777, 40
        e_q = jnp.ones((2, kq), jnp.float32)
        # scores cycle through 4 exact levels -> ~194 exact ties per level
        levels = jnp.arange(n, dtype=jnp.float32) % 4
        r = jnp.broadcast_to(levels[None, :], (kq, n))
        v1, i1 = approx_topk_op(e_q, r, None, k, tile=128, interpret=True,
                                impl=impl)
        v2, i2 = approx_topk_reference(
            e_q, r, jnp.full((2, 1), -1, jnp.int32), k
        )
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
        # the contract itself: equal-valued winners appear in id order
        i1, v1 = np.asarray(i1), np.asarray(v1)
        for row_v, row_i in zip(v1, i1):
            for lvl in np.unique(row_v):
                ids = row_i[row_v == lvl]
                assert (np.diff(ids) > 0).all(), (lvl, ids)

    @pytest.mark.parametrize("impl", ["pallas", "scan"])
    def test_quantized_payload_matches_reference(self, impl):
        """int8 payload: fused dequant-matmul == dequantized dense oracle,
        bit-equal rankings, and the payload really is ~4x smaller."""
        ks = jax.random.split(jax.random.PRNGKey(11), 3)
        e_q = jax.random.normal(ks[0], (3, 48))
        r = jax.random.normal(ks[1], (48, 1333))
        p = quant.quantize_ranc(r, tile=96)
        assert p.nbytes < 0.3 * r.nbytes
        # quantization error bound: half an lsb per entry
        err = jnp.abs(quant.dequantize(p) - r)
        assert float(err.max()) <= float(p.col_scales().max()) * 0.5 + 1e-6
        anchors = jax.random.randint(ks[2], (3, 6), 0, 1333)
        v1, i1 = approx_topk_op(e_q, p, anchors, 16, tile=256, interpret=True,
                                impl=impl)
        v2, i2 = approx_topk_reference(e_q, p, anchors, 16)
        assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))

    @pytest.mark.parametrize("impl", ["pallas", "scan"])
    def test_quantized_payload_with_noise_mask_n_valid(self, impl):
        """The full input surface (Gumbel noise + dense mask + n_valid)
        composes with the quantized payload identically to the oracle."""
        ks = jax.random.split(jax.random.PRNGKey(12), 4)
        e_q = jax.random.normal(ks[0], (2, 32))
        p = quant.quantize_ranc(jax.random.normal(ks[1], (32, 900)), tile=128)
        mask = jax.random.bernoulli(ks[2], 0.2, (2, 900))
        g = jax.random.gumbel(ks[3], (2, 900), dtype=jnp.float32)
        v1, i1 = approx_topk_op(e_q, p, None, 12, tile=128, interpret=True,
                                noise=g, mask=mask, n_valid=800, impl=impl)
        v2, i2 = approx_topk_reference(
            e_q, p, jnp.full((2, 1), -1, jnp.int32), 12,
            noise=g, mask=mask, n_valid=800,
        )
        assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-5, rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        assert (np.asarray(i1) < 800).all()

    def test_descending_and_unique(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        e_q = jax.random.normal(ks[0], (3, 48))
        r = jax.random.normal(ks[1], (48, 1500))
        anchors = jnp.full((3, 4), -1, jnp.int32)
        v, i = approx_topk_op(e_q, r, anchors, 20, tile=256, interpret=True)
        v = np.asarray(v)
        assert (np.diff(v, axis=1) <= 1e-6).all()
        for row in np.asarray(i):
            assert len(np.unique(row)) == len(row)

    @settings(max_examples=8, deadline=None)
    @given(
        n=st.integers(100, 3000),
        k=st.sampled_from([8, 16, 33]),
        seed=st.integers(0, 1000),
    )
    def test_property_matches_reference(self, n, k, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        e_q = jax.random.normal(ks[0], (2, 32))
        r = jax.random.normal(ks[1], (32, n))
        anchors = jax.random.randint(ks[2], (2, 6), 0, n)
        v1, _ = approx_topk_op(e_q, r, anchors, k, tile=256, interpret=True)
        v2, _ = approx_topk_reference(e_q, r, anchors, k)
        assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-4, rtol=1e-4)


def _persistent_dtypes():
    return ["float32", "int8", "int4"] + (["fp8"] if quant.fp8_supported() else [])


class TestPersistentRound:
    """The persistent round kernel streams each payload tile ONCE and
    produces both per-round top-ks (Gumbel sample + provisional monitor).
    Its contract is bitwise: both outputs equal the staged two-pass
    approx_topk_op calls exactly, for every payload dtype and backend."""

    B, KQ, N = 5, 12, 900

    @pytest.fixture(scope="class")
    def dom(self):
        key = jax.random.PRNGKey(0)
        nkey = jax.random.fold_in(key, 6)
        return {
            "e_q": jax.random.normal(jax.random.fold_in(key, 1), (self.B, self.KQ)),
            "r": jax.random.normal(jax.random.fold_in(key, 2), (self.KQ, self.N)),
            "anchors": jax.random.randint(
                jax.random.fold_in(key, 3), (self.B, 7), 0, self.N
            ).astype(jnp.int32),
            "mask": jax.random.bernoulli(
                jax.random.fold_in(key, 4), 0.1, (self.B, self.N)
            ),
            "prov_mask": jax.random.bernoulli(
                jax.random.fold_in(key, 5), 0.2, (self.B, self.N)
            ),
            "nkey": nkey,
            "noise": blocked_gumbel(nkey, self.B, self.N),
        }

    def _payload(self, dom, dt):
        if dt == "float32":
            return dom["r"]
        return quant.quantize_ranc(dom["r"], tile=128, code_dtype=dt)

    @staticmethod
    def _bitwise(got, want):
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))

    @pytest.mark.parametrize("impl", ["scan", "pallas"])
    @pytest.mark.parametrize("dtype", _persistent_dtypes())
    @pytest.mark.parametrize("n_valid", [None, 700])
    def test_dual_output_bitwise_vs_staged(self, dom, dtype, impl, n_valid):
        p = self._payload(dom, dtype)
        ref_s = approx_topk_op(dom["e_q"], p, dom["anchors"], 20, tile=256,
                               noise=dom["noise"], mask=dom["mask"],
                               n_valid=n_valid)
        ref_p = approx_topk_op(dom["e_q"], p, None, 15, tile=256,
                               mask=dom["prov_mask"], n_valid=n_valid)
        s, prov = persistent_round_op(
            dom["e_q"], p, k_sample=20, k_prov=15, anchors=dom["anchors"],
            mask=dom["mask"], prov_mask=dom["prov_mask"], noise=dom["noise"],
            n_valid=n_valid, tile=256, interpret=True, impl=impl,
        )
        self._bitwise(s, ref_s)
        self._bitwise(prov, ref_p)

    @pytest.mark.parametrize("impl", ["scan", "pallas"])
    @pytest.mark.parametrize("dtype", _persistent_dtypes())
    def test_in_kernel_noise_generation_bitwise(self, dom, dtype, impl):
        """noise_key path: the kernel regenerates blocked_gumbel per tile
        from global coordinates — identical to passing the full field."""
        p = self._payload(dom, dtype)
        ref_s = approx_topk_op(dom["e_q"], p, None, 20, tile=256,
                               noise=dom["noise"], mask=dom["mask"])
        ref_p = approx_topk_op(dom["e_q"], p, None, 15, tile=256,
                               mask=dom["prov_mask"])
        s, prov = persistent_round_op(
            dom["e_q"], p, k_sample=20, k_prov=15, mask=dom["mask"],
            prov_mask=dom["prov_mask"], noise_key=dom["nkey"], tile=256,
            interpret=True, impl=impl,
        )
        self._bitwise(s, ref_s)
        self._bitwise(prov, ref_p)

    @pytest.mark.parametrize("impl", ["scan", "pallas"])
    def test_prov_only_and_fully_masked(self, dom, impl):
        ref_p = approx_topk_op(dom["e_q"], dom["r"], None, 15, tile=256,
                               mask=dom["prov_mask"])
        _, prov = persistent_round_op(
            dom["e_q"], dom["r"], k_prov=15, prov_mask=dom["prov_mask"],
            tile=256, interpret=True, impl=impl,
        )
        self._bitwise(prov, ref_p)
        # degenerate: every item masked — sentinel fill must match staged
        full = jnp.ones((self.B, self.N), bool)
        ref = approx_topk_op(dom["e_q"], dom["r"], None, 10, tile=256, mask=full)
        s, _ = persistent_round_op(dom["e_q"], dom["r"], k_sample=10, mask=full,
                                   tile=256, interpret=True, impl=impl)
        self._bitwise(s, ref)

    @pytest.mark.parametrize("impl", ["scan", "pallas"])
    @pytest.mark.parametrize("tile", [1024, 64])
    def test_degenerate_tile_sizes(self, dom, impl, tile):
        """Single-tile (tile >= N) and tiny-tile sweeps.  Compared against
        the SAME staged backend: at tile > N the two staged backends
        themselves drift an ulp on quantized payloads (a pre-existing
        scan-vs-pallas FMA fusion corner), so cross-impl comparison would
        test the staged kernels, not the persistent one."""
        p = self._payload(dom, "int4")
        ref_s = approx_topk_op(dom["e_q"], p, dom["anchors"], 20, tile=tile,
                               noise=dom["noise"], impl=impl, interpret=True)
        s, _ = persistent_round_op(dom["e_q"], p, k_sample=20,
                                   anchors=dom["anchors"], noise=dom["noise"],
                                   tile=tile, interpret=True, impl=impl)
        self._bitwise(s, ref_s)

    @pytest.mark.parametrize("impl", ["scan", "pallas"])
    def test_shard_offsets_noise_parity(self, dom, impl):
        """Sharded-style (row_offset, col_offset) in-kernel noise equals a
        slice of the globally-keyed field — the property that makes the
        sharded persistent engine bit-identical to single-device."""
        ro, co = 3, 256
        big = blocked_gumbel(dom["nkey"], self.B + ro, self.N + co)
        ref = approx_topk_op(dom["e_q"], dom["r"], dom["anchors"], 20,
                             tile=256, noise=big[ro:, co:])
        s, _ = persistent_round_op(
            dom["e_q"], dom["r"], k_sample=20, anchors=dom["anchors"],
            noise_key=dom["nkey"], row_offset=ro, col_offset=co,
            tile=256, interpret=True, impl=impl,
        )
        self._bitwise(s, ref)


class TestEmbeddingBag:
    @pytest.mark.parametrize(
        "rows,dim,b,h,mode",
        [(1000, 128, 8, 4, "sum"), (500, 64, 16, 7, "mean"), (100, 256, 3, 1, "sum")],
    )
    def test_matches_reference(self, rows, dim, b, h, mode):
        k1, k2 = jax.random.split(jax.random.PRNGKey(rows))
        table = jax.random.normal(k1, (rows, dim))
        ids = jax.random.randint(k2, (b, h), 0, rows)
        out = embedding_bag_op(table, ids, mode=mode, interpret=True)
        ref = embedding_bag_reference(table, ids, mode)
        assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5, rtol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        table = jax.random.normal(k1, (200, 64)).astype(dtype)
        ids = jax.random.randint(k2, (4, 5), 0, 200)
        out = embedding_bag_op(table, ids, interpret=True)
        ref = embedding_bag_reference(table, ids)
        assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=3e-2
        )

    @settings(max_examples=10, deadline=None)
    @given(
        rows=st.integers(10, 500),
        h=st.integers(1, 9),
        seed=st.integers(0, 1000),
    )
    def test_property_duplicate_ids_ok(self, rows, h, seed):
        """Bags with repeated ids must sum the row multiple times."""
        k1 = jax.random.PRNGKey(seed)
        table = jax.random.normal(k1, (rows, 32))
        ids = jnp.zeros((2, h), jnp.int32)  # all duplicates of row 0
        out = embedding_bag_op(table, ids, interpret=True)
        ref = table[0] * h
        assert_allclose(np.asarray(out[0]), np.asarray(ref), atol=1e-4, rtol=1e-4)

"""Static-shape round engine: parity with the seed (growing-shape) search,
no-retrace round-count overrides, early exit, fused-kernel sampling, the
unified Retriever API, and the static incremental-pinv update."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig, replace
from repro.core import adacur, cur, engine, retrieval
from repro.core.index import AnchorIndex
from repro.core.engine import (
    AdaCURRetriever,
    ANNCURRetriever,
    RerankRetriever,
    Retriever,
)


def _overlap(a, b):
    """Mean fraction of ids in `a` also present in the same row of `b`."""
    hits = (np.asarray(a)[:, :, None] == np.asarray(b)[:, None, :]).any(-1)
    return float(hits.mean())


def _seed_search(dom, cfg, key=3, first=None, n_valid=None, r_anc=None):
    return adacur.adacur_search(
        dom["ce"].score_fn(), dom["r_anc"] if r_anc is None else r_anc,
        dom["test_q"], cfg, jax.random.PRNGKey(key), first_anchors=first,
        n_valid_items=n_valid,
    )


def _engine_search(dom, cfg, key=3, first=None, n_valid=None, r_anc=None, **kw):
    return engine.engine_search(
        dom["ce"].score_fn(), dom["r_anc"] if r_anc is None else r_anc,
        dom["test_q"], cfg, jax.random.PRNGKey(key), first_anchors=first,
        n_valid_items=n_valid, **kw,
    )


BASE = dict(k_anchor=40, n_rounds=4, budget_ce=80, k_retrieve=30)


class TestSeedParity:
    """Engine variants retrieve the seed search's top-k (same RNG stream)."""

    def test_unrolled_dense_is_exact(self, small_domain):
        cfg = AdaCURConfig(**BASE)
        ref = _seed_search(small_domain, cfg)
        res = _engine_search(small_domain, cfg)
        np.testing.assert_array_equal(
            np.asarray(res.anchor_idx), np.asarray(ref.anchor_idx)
        )
        np.testing.assert_array_equal(
            np.asarray(res.topk_idx), np.asarray(ref.topk_idx)
        )
        np.testing.assert_allclose(
            np.asarray(res.approx_scores), np.asarray(ref.approx_scores),
            rtol=1e-4, atol=1e-4,
        )

    @pytest.mark.parametrize("loop_mode", ["unrolled", "fori"])
    @pytest.mark.parametrize("fused", [False, True])
    def test_mode_matrix(self, small_domain, loop_mode, fused):
        cfg = AdaCURConfig(**BASE)
        ref = _seed_search(small_domain, cfg)
        res = _engine_search(
            small_domain,
            replace(cfg, loop_mode=loop_mode, use_fused_topk=fused, fused_tile=256),
        )
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.99

    @pytest.mark.parametrize("fused", [False, True])
    def test_softmax_strategy(self, small_domain, fused):
        cfg = AdaCURConfig(strategy="softmax", **BASE)
        ref = _seed_search(small_domain, cfg)
        res = _engine_search(
            small_domain,
            replace(cfg, loop_mode="fori", use_fused_topk=fused, fused_tile=256),
        )
        # identical keys -> identical Gumbel draws -> identical trajectories
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.97

    def test_no_split_budget(self, small_domain):
        cfg = AdaCURConfig(
            k_anchor=40, n_rounds=4, budget_ce=80, split_budget=False,
            k_retrieve=30, loop_mode="fori", use_fused_topk=True, fused_tile=256,
        )
        ref = _seed_search(small_domain, replace(cfg, loop_mode="unrolled",
                                                 use_fused_topk=False))
        res = _engine_search(small_domain, cfg)
        assert res.anchor_idx.shape == (60, 80)  # k_i = budget
        assert res.ce_calls == 80
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.99

    def test_first_anchors(self, small_domain):
        exact = small_domain["exact"]
        noisy = exact + 2.0 * jax.random.normal(jax.random.PRNGKey(0), exact.shape)
        _, first = jax.lax.top_k(noisy, 10)
        cfg = AdaCURConfig(first_round="retriever", **BASE)
        ref = _seed_search(small_domain, cfg, first=first)
        res = _engine_search(
            small_domain,
            replace(cfg, loop_mode="fori", use_fused_topk=True, fused_tile=256),
            first=first,
        )
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.99

    def test_round_epsilon(self, small_domain):
        cfg = AdaCURConfig(round_epsilon=0.3, **BASE)
        ref = _seed_search(small_domain, cfg)
        res = _engine_search(
            small_domain,
            replace(cfg, loop_mode="fori", use_fused_topk=True, fused_tile=256),
        )
        # same keys drive both the adaptive picks and the ε-random fill
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.97

    def test_full_pinv_mode(self, small_domain):
        cfg = AdaCURConfig(incremental_pinv=False, **BASE)
        ref = _seed_search(small_domain, cfg)
        res = _engine_search(small_domain, replace(cfg, loop_mode="fori"))
        # pinv of the zero-padded buffer == padded pinv; tiny SVD fp noise
        # may flip near-ties, hence set overlap rather than equality
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.95

    @pytest.mark.parametrize("fused", [False, True])
    def test_n_valid_items_padding(self, small_domain, fused):
        """Padded item columns carry poison scores; none may be retrieved."""
        r_anc = small_domain["r_anc"]
        n = r_anc.shape[1]
        padded = jnp.concatenate([r_anc, 50.0 * jnp.ones((r_anc.shape[0], 48))], 1)
        cfg = AdaCURConfig(**BASE)
        ref = _seed_search(small_domain, cfg, n_valid=n, r_anc=padded)
        res = _engine_search(
            small_domain,
            replace(cfg, loop_mode="fori", use_fused_topk=fused, fused_tile=256),
            n_valid=n, r_anc=padded,
        )
        assert (np.asarray(res.topk_idx) < n).all()
        assert (np.asarray(res.anchor_idx) < n).all()
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.99


class TestStaticShapes:
    def test_fori_no_retrace_on_n_rounds(self, small_domain):
        """One compiled executable serves every runtime round count."""
        traces = []
        score_fn = small_domain["ce"].score_fn()

        def counting_score_fn(q, idx):
            traces.append(1)   # trace-time side effect: counts (re)traces
            return score_fn(q, idx)

        cfg = AdaCURConfig(loop_mode="fori", **BASE)
        run = engine.make_engine(counting_score_fn, cfg)
        key = jax.random.PRNGKey(3)
        r2 = run(small_domain["r_anc"], small_domain["test_q"], key, n_rounds=2)
        n_first = len(traces)
        assert n_first > 0
        r4 = run(small_domain["r_anc"], small_domain["test_q"], key, n_rounds=4)
        r1 = run(small_domain["r_anc"], small_domain["test_q"], key, n_rounds=1)
        assert len(traces) == n_first, "changing n_rounds retraced the engine"
        assert int(r2.rounds_done) == 2 and int(r4.rounds_done) == 4
        assert int(r1.rounds_done) == 1
        # unexecuted slabs stay empty and are masked out of the ranking
        filled = (np.asarray(r2.anchor_idx) >= 0).sum(1)
        assert (filled == 2 * (BASE["k_anchor"] // BASE["n_rounds"])).all()
        assert (np.asarray(r2.topk_idx) >= 0).all()

    def test_underfilled_ranking_never_leaks_sentinels(self, small_domain):
        """No-split + runtime n_rounds smaller than k_retrieve's need: the
        ranking pads by repeating the row-best candidate instead of serving
        the -1 id / NEG_INF score padding."""
        cfg = AdaCURConfig(
            k_anchor=40, n_rounds=4, budget_ce=40, split_budget=False,
            k_retrieve=30, loop_mode="fori",
        )
        run = engine.make_engine(small_domain["ce"].score_fn(), cfg)
        res = run(small_domain["r_anc"], small_domain["test_q"],
                  jax.random.PRNGKey(3), n_rounds=1)   # 10 filled < 30 wanted
        idx = np.asarray(res.topk_idx)
        scores = np.asarray(res.topk_scores)
        assert (idx >= 0).all()
        assert (scores > -1e29).all()
        ref = jnp.take_along_axis(small_domain["exact"], res.topk_idx, axis=1)
        np.testing.assert_allclose(scores, np.asarray(ref), rtol=1e-5, atol=1e-5)

    def test_unrolled_rejects_runtime_n_rounds(self, small_domain):
        cfg = AdaCURConfig(**BASE)
        with pytest.raises(ValueError):
            _engine_search(small_domain, cfg, n_rounds=2)

    def test_fused_round_has_no_bn_float_intermediates(self, small_domain):
        score_fn = small_domain["ce"].score_fn()
        dense = AdaCURConfig(**BASE)
        fused = replace(dense, use_fused_topk=True, fused_tile=256)
        n_dense = engine.round_body_bn_intermediates(
            score_fn, small_domain["r_anc"], small_domain["test_q"], dense
        )
        n_fused = engine.round_body_bn_intermediates(
            score_fn, small_domain["r_anc"], small_domain["test_q"], fused
        )
        assert n_dense >= 1      # dense scores every item each round
        assert n_fused == 0      # S_hat never materialized

    def test_early_exit_stops_and_reports(self, small_domain):
        cfg = AdaCURConfig(
            k_anchor=80, n_rounds=8, budget_ce=120, k_retrieve=30,
            loop_mode="fori", early_exit_tol=0.5,
        )
        res = _engine_search(small_domain, cfg)
        done = int(res.rounds_done)
        assert 1 <= done <= 8
        assert (np.asarray(res.topk_idx) >= 0).all()
        # exact top-k scores still hold for the returned set
        ref = jnp.take_along_axis(small_domain["exact"], res.topk_idx, axis=1)
        np.testing.assert_allclose(
            np.asarray(res.topk_scores), np.asarray(ref), rtol=1e-5, atol=1e-5
        )

    def test_early_exit_requires_fori(self):
        with pytest.raises(ValueError):
            AdaCURConfig(early_exit_tol=0.1, loop_mode="unrolled", **BASE)


class TestRetrieverAPI:
    def test_protocol(self, small_domain):
        sf = small_domain["ce"].score_fn()
        r_anc = small_domain["r_anc"]
        assert isinstance(AdaCURRetriever(sf, r_anc, AdaCURConfig(**BASE)), Retriever)
        assert isinstance(RerankRetriever(sf, r_anc, 40, 20), Retriever)

    def test_anncur_as_engine_config(self, small_domain):
        """ANNCUR over the first-class index == the bare-array engine
        configuration with the same fixed anchors."""
        sf = small_domain["ce"].score_fn()
        idx = AnchorIndex.from_r_anc(small_domain["r_anc"]).with_latents(
            k_anchor=30, key=jax.random.PRNGKey(7)
        )
        ref = ANNCURRetriever.from_index(idx, sf, budget_ce=60, k_retrieve=30).search(
            small_domain["test_q"]
        )
        ret = ANNCURRetriever(sf, small_domain["r_anc"], idx.anchor_item_pos, 60, 30)
        res = ret.search(small_domain["test_q"])
        assert _overlap(res.topk_idx, ref.topk_idx) >= 0.99

    def test_rerank_as_engine_config(self, small_domain):
        sf = small_domain["ce"].score_fn()
        exact = small_domain["exact"]
        noisy = exact + 1.5 * jax.random.normal(jax.random.PRNGKey(9), exact.shape)
        _, order = jax.lax.top_k(noisy, exact.shape[1])
        ref = retrieval.rerank_baseline(sf, order, small_domain["test_q"], 60, 30)
        ret = RerankRetriever(sf, small_domain["r_anc"], 60, 30)
        res = ret.search(small_domain["test_q"], candidate_idx=order)
        np.testing.assert_array_equal(
            np.asarray(res.topk_idx), np.asarray(ref.topk_idx)
        )

    def test_adacur_beats_anncur_via_retrievers(self, small_domain):
        """The paper's headline ordering survives the engine migration."""
        sf = small_domain["ce"].score_fn()
        cfg = AdaCURConfig(
            k_anchor=50, n_rounds=5, budget_ce=100, k_retrieve=100,
            loop_mode="fori", use_fused_topk=True, fused_tile=256,
        )
        res = AdaCURRetriever(sf, small_domain["r_anc"], cfg).search(
            small_domain["test_q"], jax.random.PRNGKey(3)
        )
        rep = retrieval.evaluate_result("adacur", res, small_domain["exact"])
        idx = AnchorIndex.from_r_anc(small_domain["r_anc"]).with_anchors(
            k_anchor=50, key=jax.random.PRNGKey(7)
        )
        res2 = ANNCURRetriever(
            sf, small_domain["r_anc"], idx.anchor_item_pos, 100, 100
        ).search(small_domain["test_q"])
        rep2 = retrieval.evaluate_result("anncur", res2, small_domain["exact"])
        assert rep.recall[100] > rep2.recall[100]


class TestStaticPinvUpdate:
    def test_static_extend_matches_growing(self):
        """The padded-buffer bordering update equals the concatenate one."""
        k1, k2 = jax.random.split(jax.random.PRNGKey(0))
        a = jax.random.normal(k1, (50, 12))
        b = jax.random.normal(k2, (50, 6))
        p = cur.pinv(a)
        ref = cur.block_pinv_extend(a, p, b)                    # (18, 50)
        a_full = jnp.zeros((50, 24)).at[:, :12].set(a)
        p_full = jnp.zeros((24, 50)).at[:12, :].set(p)
        ext = cur.block_pinv_extend_static(a_full, p_full, b, 12)
        np.testing.assert_allclose(
            np.asarray(ext[:18]), np.asarray(ref), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_array_equal(np.asarray(ext[18:]), np.zeros((6, 50)))

    def test_rank_deficient_duplicated_anchor_columns(self):
        """Duplicated anchor columns make the residual C exactly zero, which
        must route through the Greville fallback branch — the blended update
        still satisfies the Moore-Penrose condition M M+ M = M."""
        a = jax.random.normal(jax.random.PRNGKey(3), (40, 8))
        b = jnp.concatenate([a[:, 2:3], a[:, 5:6]], axis=1)     # exact dupes
        p = cur.pinv(a)
        ext = cur.block_pinv_extend(a, p, b)
        m = jnp.concatenate([a, b], axis=1)
        np.testing.assert_allclose(
            np.asarray(m @ ext @ m), np.asarray(m), atol=1e-3
        )
        # the static variant hits the same branch through the padded buffers
        a_full = jnp.zeros((40, 10)).at[:, :8].set(a)
        p_full = jnp.zeros((10, 40)).at[:8, :].set(p)
        ext_s = cur.block_pinv_extend_static(a_full, p_full, b, 8)
        np.testing.assert_allclose(
            np.asarray(ext_s), np.asarray(ext), atol=1e-5, rtol=1e-5
        )

    def test_engine_with_duplicate_prone_first_round(self, small_domain):
        """A retriever first round of near-duplicate columns exercises the
        rank-deficient branch inside the engine without blowing up."""
        b = small_domain["test_q"].shape[0]
        # anchors 0..4 repeated: later rounds must extend past a singular
        # first-block pinv and still produce finite, valid retrievals
        first = jnp.tile(jnp.arange(5)[None, :], (b, 2))        # (B, 10)
        cfg = AdaCURConfig(
            k_anchor=40, n_rounds=4, budget_ce=80, k_retrieve=20,
            first_round="retriever", loop_mode="fori",
        )
        res = _engine_search(small_domain, cfg, first=first)
        assert np.isfinite(np.asarray(res.topk_scores)).all()
        assert (np.asarray(res.topk_idx) >= 0).all()

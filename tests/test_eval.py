"""IR metrics + harness (repro.eval): hand-computed values, the
single-ground-truth evaluate_result contract, and the compat re-export."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.eval import metrics
from repro.eval.harness import MethodReport


class TestIRMetrics:
    def test_hand_computed_example(self):
        # q0: rel {2, 5}; ranked [2, 9, 5] -> r@1=.5, r@3=1, mrr=1,
        #     ndcg@3 = (1 + 1/log2(4)) / (1 + 1/log2(3)) = 1.5/1.6309...
        # q1: rel {7};    ranked [0, 1, 7] -> r@1=0, r@3=1, mrr=1/3,
        #     ndcg@3 = (1/log2(4)) / 1 = .5
        ranked = np.array([[2, 9, 5], [0, 1, 7]])
        qrels = [{2, 5}, {7}]
        out = metrics.ir_metrics(ranked, qrels, ks=(1, 3))
        assert out["recall@1"] == pytest.approx(0.25)
        assert out["recall@3"] == pytest.approx(1.0)
        assert out["mrr@1"] == pytest.approx(0.5)
        assert out["mrr@3"] == pytest.approx((1.0 + 1.0 / 3.0) / 2.0)
        ndcg0 = (1.0 + 1.0 / np.log2(4.0)) / (1.0 + 1.0 / np.log2(3.0))
        assert out["ndcg@3"] == pytest.approx((ndcg0 + 0.5) / 2.0)

    def test_graded_gains_and_duplicates(self):
        # graded qrels: gain 3 for doc 1, gain 1 for doc 0; a duplicate of
        # doc 1 later in the row must not count twice
        qrels = [{1: 3.0, 0: 1.0}]
        ranked = np.array([[1, 1, 0]])
        out = metrics.ir_metrics(ranked, qrels, ks=(3,))
        ideal = 3.0 + 1.0 / np.log2(3.0)
        got = 3.0 + 1.0 / np.log2(4.0)          # doc 0 at position 3
        assert out["ndcg@3"] == pytest.approx(got / ideal)
        assert out["recall@3"] == pytest.approx(1.0)

    def test_rows_with_empty_qrels_are_skipped(self):
        ranked = np.array([[0, 1], [1, 0]])
        out = metrics.ir_metrics(ranked, [set(), {1}], ks=(1,))
        assert out["recall@1"] == pytest.approx(1.0)
        with pytest.raises(ValueError):
            metrics.ir_metrics(ranked, [set(), set()], ks=(1,))

    def test_qrels_builders(self):
        exact = jnp.asarray([[0.1, 0.9, 0.2], [0.8, 0.0, 0.3]])
        assert metrics.qrels_from_exact(exact, k=1) == [
            frozenset({1}), frozenset({0})
        ]
        assert metrics.qrels_from_gold([2, 0]) == [
            frozenset({2}), frozenset({0})
        ]


class TestEvaluateResult:
    def test_single_ground_truth_matches_per_k(self):
        """evaluate_result computes ground truth ONCE at max(ks); every
        recall@k must still equal the direct per-k computation."""
        key = jax.random.PRNGKey(3)
        exact = jax.random.normal(key, (7, 120))
        retrieved = jax.lax.top_k(
            exact + 0.5 * jax.random.normal(jax.random.PRNGKey(4), exact.shape),
            32,
        )[1]

        class _Res:
            topk_idx = retrieved
            ce_calls = 32

        rep = metrics.evaluate_result("m", _Res(), exact, ks=(1, 5, 32))
        for k in (1, 5, 32):
            _, gt_k = metrics.exact_topk(exact, k)
            assert rep.recall[k] == pytest.approx(
                float(metrics.topk_recall(retrieved, gt_k, k))
            )

    def test_core_retrieval_reexports_same_objects(self):
        from repro.core import retrieval

        assert retrieval.topk_recall is metrics.topk_recall
        assert retrieval.evaluate_result is metrics.evaluate_result
        assert retrieval.exact_topk is metrics.exact_topk
        assert retrieval.RecallReport is metrics.RecallReport


def test_method_report_json_roundtrips():
    rep = MethodReport(
        method="m", planned_ce=10, measured_ce=10, budget_matched=True,
        topk_recall={1: 0.5, 10: 0.9}, ir={"recall@1": 0.5},
        wall_us_per_query=12.0,
    )
    import json

    d = json.loads(json.dumps(rep.to_json()))
    assert d["topk_recall"]["10"] == 0.9 and d["budget_matched"] is True

"""Integration tests for ADACUR / ANNCUR — the paper's central claims on a
small synthetic domain (relative orderings, budget accounting, variants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig
from repro.core import adacur, retrieval
from repro.core.engine import ANNCURRetriever
from repro.core.index import AnchorIndex


def _anncur_search(dom, k_anchor, budget, k_retrieve, key=7):
    """ANNCUR through its first-class home: AnchorIndex latents + the
    engine's ANNCURRetriever (the deprecated shim module is gone)."""
    index = AnchorIndex.from_r_anc(dom["r_anc"]).with_latents(
        k_anchor=k_anchor, key=jax.random.PRNGKey(key)
    )
    return ANNCURRetriever.from_index(
        index, dom["ce"].score_fn(), budget_ce=budget, k_retrieve=k_retrieve
    ).search(dom["test_q"])


def _run_adacur(dom, cfg, seed=3, first=None):
    score_fn = dom["ce"].score_fn()
    return adacur.adacur_search(
        score_fn, dom["r_anc"], dom["test_q"], cfg, jax.random.PRNGKey(seed),
        first_anchors=first,
    )


class TestBudgetAccounting:
    def test_split_budget_ce_calls(self, small_domain):
        cfg = AdaCURConfig(k_anchor=40, n_rounds=4, budget_ce=80, k_retrieve=50)
        res = _run_adacur(small_domain, cfg)
        assert res.ce_calls == 80
        assert res.anchor_idx.shape == (60, 40)

    def test_no_split_uses_all_budget_on_anchors(self, small_domain):
        cfg = AdaCURConfig(
            k_anchor=40, n_rounds=4, budget_ce=80, split_budget=False, k_retrieve=50
        )
        res = _run_adacur(small_domain, cfg)
        assert res.anchor_idx.shape == (60, 80)  # k_i = budget
        assert res.ce_calls == 80

    def test_anchor_sets_have_no_duplicates(self, small_domain):
        cfg = AdaCURConfig(k_anchor=50, n_rounds=5, budget_ce=100)
        res = _run_adacur(small_domain, cfg)
        idx = np.asarray(res.anchor_idx)
        for row in idx:
            assert len(np.unique(row)) == len(row)

    def test_anchor_scores_are_exact(self, small_domain):
        cfg = AdaCURConfig(k_anchor=30, n_rounds=3, budget_ce=60)
        res = _run_adacur(small_domain, cfg)
        ref = jnp.take_along_axis(small_domain["exact"], res.anchor_idx, axis=1)
        np.testing.assert_allclose(
            np.asarray(res.anchor_scores), np.asarray(ref), rtol=1e-5, atol=1e-5
        )

    def test_returned_topk_scores_are_exact(self, small_domain):
        cfg = AdaCURConfig(k_anchor=40, n_rounds=4, budget_ce=100, k_retrieve=20)
        res = _run_adacur(small_domain, cfg)
        ref = jnp.take_along_axis(small_domain["exact"], res.topk_idx, axis=1)
        np.testing.assert_allclose(
            np.asarray(res.topk_scores), np.asarray(ref), rtol=1e-5, atol=1e-5
        )


class TestPaperClaims:
    """Relative orderings the paper establishes (Fig. 2, 3)."""

    def test_adacur_beats_anncur_at_equal_budget(self, small_domain):
        budget = 100
        cfg = AdaCURConfig(
            k_anchor=50, n_rounds=5, budget_ce=budget, strategy="topk", k_retrieve=100
        )
        res = _run_adacur(small_domain, cfg)
        rep = retrieval.evaluate_result("adacur", res, small_domain["exact"])
        res2 = _anncur_search(small_domain, 50, budget, 100)
        rep2 = retrieval.evaluate_result("anncur", res2, small_domain["exact"])
        assert rep.recall[100] > rep2.recall[100]
        assert rep.recall[10] >= rep2.recall[10] - 0.02

    def test_more_rounds_helps_then_saturates(self, small_domain):
        recalls = {}
        for nr in (1, 5):
            cfg = AdaCURConfig(
                k_anchor=60, n_rounds=nr, budget_ce=120, strategy="topk", k_retrieve=100
            )
            res = _run_adacur(small_domain, cfg)
            recalls[nr] = retrieval.evaluate_result("a", res, small_domain["exact"]).recall[100]
        assert recalls[5] > recalls[1]

    def test_incremental_pinv_matches_full(self, small_domain):
        """Incremental pinv is numerically equivalent (same recall; identical
        scores given the same anchors).  Anchor *trajectories* may diverge on
        near-ties, so equality is asserted on the deterministic pieces."""
        from repro.core import cur

        # deterministic piece: given one anchor set, both pinv paths agree
        r_anc, exact = small_domain["r_anc"], small_domain["exact"]
        anchor = jnp.tile(jnp.arange(0, 2000, 25)[None, :], (4, 1))  # 80 anchors
        c_test = jnp.take_along_axis(exact[:4], anchor, axis=1)
        cols = cur.gather_anchor_columns(r_anc, anchor)
        p_full = cur.pinv(cols, 1e-6)
        p_inc = cur.incremental_pinv_init(cols[..., :40], 1e-6)
        p_inc = jax.vmap(cur.block_pinv_extend)(cols[..., :40], p_inc, cols[..., 40:])
        s_full = jnp.einsum("bk,bkq,qn->bn", c_test, p_full, r_anc)
        s_inc = jnp.einsum("bk,bkq,qn->bn", c_test, p_inc, r_anc)
        np.testing.assert_allclose(
            np.asarray(s_inc), np.asarray(s_full), rtol=2e-2, atol=2e-2
        )

        # end-to-end piece: recall parity within noise
        base = dict(k_anchor=50, n_rounds=5, budget_ce=100, strategy="topk", k_retrieve=100)
        res_inc = _run_adacur(small_domain, AdaCURConfig(**base, incremental_pinv=True))
        res_full = _run_adacur(small_domain, AdaCURConfig(**base, incremental_pinv=False))
        r_i = retrieval.evaluate_result("inc", res_inc, exact).recall[100]
        r_f = retrieval.evaluate_result("full", res_full, exact).recall[100]
        assert abs(r_i - r_f) < 0.05

    def test_retriever_first_round(self, small_domain):
        """ADACUR seeded by a first-stage retriever (paper's DE_BASE variant)."""
        exact = small_domain["exact"]
        # crude 'retriever': noisy exact scores
        noisy = exact + 2.0 * jax.random.normal(jax.random.PRNGKey(0), exact.shape)
        _, first = jax.lax.top_k(noisy, 10)
        cfg = AdaCURConfig(
            k_anchor=50, n_rounds=5, budget_ce=100, first_round="retriever", k_retrieve=100
        )
        res = _run_adacur(small_domain, cfg, first=first)
        rep = retrieval.evaluate_result("adacur-ret", res, exact)
        assert rep.recall[10] > 0.5

    def test_jitted_matches_eager(self, small_domain):
        cfg = AdaCURConfig(k_anchor=30, n_rounds=3, budget_ce=60, k_retrieve=30)
        score_fn = small_domain["ce"].score_fn()
        run = adacur.make_jitted_search(score_fn, cfg)
        res_j = run(small_domain["r_anc"], small_domain["test_q"], jax.random.PRNGKey(3))
        res_e = _run_adacur(small_domain, cfg)
        np.testing.assert_array_equal(
            np.asarray(res_j.topk_idx), np.asarray(res_e.topk_idx)
        )


class TestANNCUR:
    def test_anncur_beats_pure_random_rerank(self, small_domain):
        """ANNCUR's approximate retrieval must beat re-ranking random items."""
        budget = 100
        exact = small_domain["exact"]
        res = _anncur_search(small_domain, 50, budget, 100)
        rep = retrieval.evaluate_result("anncur", res, exact)
        rand_cand = jnp.tile(
            jax.random.permutation(jax.random.PRNGKey(8), exact.shape[1])[None, :budget],
            (exact.shape[0], 1),
        )
        res_r = retrieval.rerank_baseline(
            small_domain["ce"].score_fn(), rand_cand, small_domain["test_q"], budget, 100
        )
        rep_r = retrieval.evaluate_result("random", res_r, exact)
        assert rep.recall[10] > rep_r.recall[10]

    def test_budget_below_anchors_raises(self, small_domain):
        with pytest.raises(ValueError):
            _anncur_search(small_domain, 50, 40, 10)

"""Property-based engine invariants (hypothesis; the deterministic fallback
shim runs the same strategies offline):

(a) recall@k is non-decreasing in the executed round count for a fixed
    seed (no-split ranking: anchor pools are nested and exactly scored, so
    this holds as a theorem, not a tendency);
(b) no (query, item) pair is CE-scored twice within one search — the
    dedup/suppression invariant, reconstructed from a recording
    TabulatedScorer's call log;
(c) total measured CE calls per query equal ``ce_call_plan(cfg, rounds)``
    exactly, under every engine mode (unrolled / fori with runtime round
    overrides / early-exit) — the budget is measured, not assumed;
(d) (b) and (c) hold verbatim under a first-stage candidate restriction
    (HybridRetriever subset/mask), and nothing outside the candidate set
    is ever CE-scored or retrieved.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import HealthCheck, given, settings, strategies as st

    def _settings(**kw):
        kw.setdefault("deadline", None)
        kw.setdefault(
            "suppress_health_check",
            [HealthCheck.too_slow, HealthCheck.data_too_large],
        )
        return settings(**kw)
except ImportError:          # hermetic container: deterministic shim
    from _hypothesis_fallback import given, settings, st

    def _settings(**kw):
        kw.pop("deadline", None)
        kw.pop("suppress_health_check", None)
        return settings(**kw)

from repro.configs.base import AdaCURConfig
from repro.core import engine, retrieval
from repro.core.engine import ce_call_plan
from repro.core.scorer import TabulatedScorer
from repro.data.synthetic import make_synthetic_ce

N_ANCHOR_Q, N_TEST_Q, N_ITEMS = 30, 10, 250


@pytest.fixture(scope="module")
def dom():
    ce = make_synthetic_ce(
        jax.random.PRNGKey(0), n_queries=N_ANCHOR_Q + N_TEST_Q, n_items=N_ITEMS
    )
    m = np.asarray(ce.full_matrix(jnp.arange(N_ANCHOR_Q + N_TEST_Q)))
    return {
        "m": m,
        "r_anc": jnp.asarray(m[:N_ANCHOR_Q]),
        "test_q": jnp.arange(N_ANCHOR_Q, N_ANCHOR_Q + N_TEST_Q),
        "exact": jnp.asarray(m[N_ANCHOR_Q:]),
    }


def _pair_sets_per_row(call_log):
    """row -> list of (qid, item) pairs scored for that batch row."""
    rows = {}
    for qids, idx in call_log:
        for r in range(idx.shape[0]):
            rows.setdefault(r, []).extend(
                (int(qids[r]), int(i)) for i in idx[r]
            )
    return rows


class TestRecallMonotoneInRounds:
    @_settings(max_examples=5)
    @given(
        k_s=st.sampled_from([4, 8]),
        r_max=st.sampled_from([2, 3, 4]),
        strategy=st.sampled_from(["topk", "softmax", "random"]),
        k_retrieve=st.sampled_from([5, 10]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_no_split_recall_non_decreasing(self, dom, k_s, r_max, strategy,
                                            k_retrieve, seed):
        """More rounds => nested, exactly-scored anchor pools => recall@k
        (k = k_retrieve) cannot drop.  One compiled fori engine serves every
        runtime round count."""
        cfg = AdaCURConfig(
            k_anchor=k_s * r_max, n_rounds=r_max, budget_ce=k_s * r_max,
            split_budget=False, strategy=strategy, k_retrieve=k_retrieve,
            loop_mode="fori",
        )
        scorer = TabulatedScorer(dom["m"])
        run = engine.make_engine(scorer, cfg)
        key = jax.random.PRNGKey(seed)
        _, gt = retrieval.exact_topk(dom["exact"], k_retrieve)
        recalls = []
        for r in range(1, r_max + 1):
            res = run(dom["r_anc"], dom["test_q"], key, n_rounds=r)
            recalls.append(
                float(retrieval.topk_recall(res.topk_idx, gt, k_retrieve))
            )
        for lo, hi in zip(recalls, recalls[1:]):
            assert hi >= lo - 1e-9, f"recall dropped across rounds: {recalls}"

    def test_split_budget_recall_trend(self, dom):
        """Split-budget recall is not a theorem (the rerank pool is chosen
        by a changing approximation), but over the full round range it must
        trend up for a fixed seed — the paper's Fig. 3."""
        cfg = AdaCURConfig(
            k_anchor=24, n_rounds=4, budget_ce=48, k_retrieve=10,
            loop_mode="fori",
        )
        run = engine.make_engine(TabulatedScorer(dom["m"]), cfg)
        key = jax.random.PRNGKey(7)
        _, gt = retrieval.exact_topk(dom["exact"], 10)
        recalls = [
            float(retrieval.topk_recall(
                run(dom["r_anc"], dom["test_q"], key, n_rounds=r).topk_idx,
                gt, 10,
            ))
            for r in (1, 4)
        ]
        assert recalls[-1] >= recalls[0] - 0.05


class TestScoredPairInvariants:
    @_settings(max_examples=6)
    @given(
        mode=st.sampled_from(["unrolled", "fori", "early"]),
        split=st.booleans(),
        strategy=st.sampled_from(["topk", "softmax"]),
        epsilon=st.sampled_from([0.0, 0.25]),
        payload=st.sampled_from(["float32", "int8", "int4"]),
        round_kernel=st.sampled_from(["staged", "persistent"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_dedup_and_exact_call_count(self, dom, mode, split, strategy,
                                        epsilon, payload, round_kernel, seed):
        """(b) + (c) in one engine run: every scored (query, item) pair is
        unique within its search row, and the measured total equals the
        plan for the rounds actually executed.  Holds unchanged under the
        quantized payloads: quantization perturbs *which* items the
        approximation proposes, never the dedup/suppression bookkeeping or
        the budget accounting.  Likewise under the persistent round kernel,
        which changes how the payload is swept, not what gets scored."""
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32 if split else 16,
            split_budget=split, strategy=strategy, round_epsilon=epsilon,
            k_retrieve=8, payload_dtype=payload, payload_tile=64,
            loop_mode="unrolled" if mode == "unrolled" else "fori",
            early_exit_tol=0.4 if mode == "early" else 0.0,
            use_fused_topk=round_kernel == "persistent",
            round_kernel=round_kernel, fused_tile=128,
        )
        scorer = TabulatedScorer(dom["m"], record_pairs=True)
        run = engine.make_engine(scorer, cfg)
        res = jax.block_until_ready(
            run(dom["r_anc"], dom["test_q"], jax.random.PRNGKey(seed))
        )

        rows = _pair_sets_per_row(scorer.call_log)
        assert len(rows) == N_TEST_Q
        for r, pairs in rows.items():
            assert len(pairs) == len(set(pairs)), (
                f"row {r}: {len(pairs) - len(set(pairs))} pairs CE-scored twice"
            )

        rounds_done = int(res.rounds_done)
        planned = ce_call_plan(cfg, rounds_done) * N_TEST_Q
        assert scorer.stats.ce_calls == planned, (
            f"measured {scorer.stats.ce_calls} != planned {planned} "
            f"(mode={mode}, rounds_done={rounds_done})"
        )
        # the planned budget the result reports stays an upper bound
        assert ce_call_plan(cfg, rounds_done) <= res.ce_calls

    @pytest.mark.parametrize("mode", ["unrolled", "fori", "early"])
    def test_int8_payload_invariants_every_loop_mode(self, dom, mode):
        """Deterministic coverage of the acceptance property: measured ==
        planned CE calls and no-pair-scored-twice hold under
        ``payload_dtype=int8`` in every loop mode (hypothesis sampling above
        may or may not draw each combination)."""
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, split_budget=True,
            k_retrieve=8, payload_dtype="int8", payload_tile=64,
            loop_mode="unrolled" if mode == "unrolled" else "fori",
            early_exit_tol=0.4 if mode == "early" else 0.0,
        )
        scorer = TabulatedScorer(dom["m"], record_pairs=True)
        run = engine.make_engine(scorer, cfg)
        res = jax.block_until_ready(
            run(dom["r_anc"], dom["test_q"], jax.random.PRNGKey(123))
        )
        for r, pairs in _pair_sets_per_row(scorer.call_log).items():
            assert len(pairs) == len(set(pairs)), f"row {r}: pair scored twice"
        planned = ce_call_plan(cfg, int(res.rounds_done)) * N_TEST_Q
        assert scorer.stats.ce_calls == planned

    @pytest.mark.parametrize("payload", ["float32", "int4"])
    @pytest.mark.parametrize("mode", ["unrolled", "fori", "early"])
    def test_persistent_kernel_invariants_every_loop_mode(self, dom, mode,
                                                          payload):
        """Deterministic coverage of the persistent-round acceptance
        property: measured == planned CE calls and no-pair-scored-twice
        hold under ``round_kernel='persistent'`` in every loop mode —
        including 'early', where the software-pipelined monitored loop
        fuses the monitor sweep with the next round's sample."""
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, split_budget=True,
            k_retrieve=8, payload_dtype=payload, payload_tile=64,
            loop_mode="unrolled" if mode == "unrolled" else "fori",
            early_exit_tol=0.4 if mode == "early" else 0.0,
            use_fused_topk=True, round_kernel="persistent", fused_tile=128,
        )
        scorer = TabulatedScorer(dom["m"], record_pairs=True)
        run = engine.make_engine(scorer, cfg)
        res = jax.block_until_ready(
            run(dom["r_anc"], dom["test_q"], jax.random.PRNGKey(123))
        )
        for r, pairs in _pair_sets_per_row(scorer.call_log).items():
            assert len(pairs) == len(set(pairs)), f"row {r}: pair scored twice"
        planned = ce_call_plan(cfg, int(res.rounds_done)) * N_TEST_Q
        assert scorer.stats.ce_calls == planned

    @pytest.mark.parametrize("mode", ["unrolled", "fori", "early"])
    def test_persistent_equals_staged_bitwise(self, dom, mode):
        """The engine-level bitwise contract: identical results (ids,
        scores, rounds_done) from the staged and persistent round kernels
        on the same key, per loop mode."""
        base = dict(
            k_anchor=16, n_rounds=4, budget_ce=32, split_budget=True,
            k_retrieve=8, payload_dtype="int8", payload_tile=64,
            loop_mode="unrolled" if mode == "unrolled" else "fori",
            early_exit_tol=0.4 if mode == "early" else 0.0,
            use_fused_topk=True, fused_tile=128,
        )
        key = jax.random.PRNGKey(9)
        out = {}
        for rk in ("staged", "persistent"):
            cfg = AdaCURConfig(round_kernel=rk, **base)
            run = engine.make_engine(TabulatedScorer(dom["m"]), cfg)
            out[rk] = jax.block_until_ready(
                run(dom["r_anc"], dom["test_q"], key)
            )
        np.testing.assert_array_equal(
            np.asarray(out["staged"].topk_idx),
            np.asarray(out["persistent"].topk_idx),
        )
        np.testing.assert_array_equal(
            np.asarray(out["staged"].topk_scores),
            np.asarray(out["persistent"].topk_scores),
        )
        assert int(out["staged"].rounds_done) == int(
            out["persistent"].rounds_done
        )

    @_settings(max_examples=6)
    @given(
        hyb_mode=st.sampled_from(["subset", "mask"]),
        loop=st.sampled_from(["unrolled", "fori"]),
        payload=st.sampled_from(["float32", "int8"]),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_first_stage_preserves_engine_invariants(self, dom, hyb_mode,
                                                     loop, payload, seed):
        """(b) + (c) survive a first stage: restricting the engine to a
        candidate shortlist (gathered subset or eligible mask) changes
        *which* items get scored, never the dedup bookkeeping or the
        budget — measured == planned verbatim, no pair scored twice, and
        nothing outside the candidates is ever retrieved."""
        from repro.core.candidates import HybridRetriever, OracleCandidates

        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=8,
            payload_dtype=payload, payload_tile=64, loop_mode=loop,
        )
        scorer = TabulatedScorer(dom["m"], record_pairs=True)
        orc = OracleCandidates(jnp.asarray(dom["m"]))
        hyb = HybridRetriever(
            score_fn=scorer, generator=orc, cfg=cfg, r_anc=dom["r_anc"],
            shortlist_k=64, mode=hyb_mode,
        )
        res = jax.block_until_ready(
            hyb.search(dom["test_q"], jax.random.PRNGKey(seed))
        )
        jax.effects_barrier()

        rows = _pair_sets_per_row(scorer.call_log)
        assert len(rows) == N_TEST_Q
        for r, pairs in rows.items():
            assert len(pairs) == len(set(pairs)), f"row {r}: pair scored twice"
        planned = ce_call_plan(cfg, int(res.rounds_done)) * N_TEST_Q
        assert scorer.stats.ce_calls == planned, (
            f"measured {scorer.stats.ce_calls} != planned {planned} under "
            f"first stage (mode={hyb_mode})"
        )
        # every CE-scored item and every retrieved item is a candidate
        cand = np.asarray(orc(dom["test_q"], 64))
        union = set(cand.ravel().tolist())
        for r, pairs in rows.items():
            allowed = union if hyb_mode == "subset" else set(cand[r].tolist())
            scored = {i for _, i in pairs}
            assert scored <= allowed, f"row {r}: CE scored a non-candidate"
            retrieved = set(int(i) for i in np.asarray(res.topk_idx)[r])
            assert retrieved <= allowed, f"row {r}: retrieved a non-candidate"

    @_settings(max_examples=4)
    @given(
        n_rounds=st.integers(min_value=1, max_value=4),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_runtime_round_override_call_count(self, dom, n_rounds, seed):
        """(c) under fori runtime round overrides: one executable, exact
        measured calls at every round count."""
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=8,
            loop_mode="fori",
        )
        scorer = TabulatedScorer(dom["m"])
        run = engine.make_engine(scorer, cfg)
        jax.block_until_ready(
            run(dom["r_anc"], dom["test_q"], jax.random.PRNGKey(seed),
                n_rounds=n_rounds)
        )
        assert scorer.stats.ce_calls == ce_call_plan(cfg, n_rounds) * N_TEST_Q
        scorer.reset_stats()

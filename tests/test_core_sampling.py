"""Tests for anchor sampling strategies (Algorithm 3 + §3.2 oracles)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # hermetic container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.core import sampling


def _no_dups(idx):
    idx = np.asarray(idx)
    return all(len(np.unique(row)) == len(row) for row in idx)


class TestStrategies:
    def test_topk_picks_highest_unselected(self):
        scores = jnp.array([[5.0, 4.0, 3.0, 2.0, 1.0]])
        selected = jnp.array([[True, False, False, False, False]])
        idx = sampling.sample_topk(scores, selected, 2)
        assert set(np.asarray(idx[0]).tolist()) == {1, 2}

    def test_softmax_no_replacement_and_mask(self):
        key = jax.random.PRNGKey(0)
        scores = jax.random.normal(key, (8, 100))
        selected = jnp.zeros((8, 100), dtype=bool).at[:, :10].set(True)
        idx = sampling.sample_softmax(key, scores, selected, 20)
        assert _no_dups(idx)
        assert np.asarray(idx).min() >= 10

    def test_softmax_distribution(self):
        """Gumbel-top-1 frequencies match softmax probabilities."""
        key = jax.random.PRNGKey(1)
        logits = jnp.array([2.0, 1.0, 0.0, -1.0])
        scores = jnp.tile(logits[None, :], (4000, 1))
        selected = jnp.zeros_like(scores, dtype=bool)
        idx = sampling.sample_softmax(key, scores, selected, 1)
        freq = np.bincount(np.asarray(idx).ravel(), minlength=4) / 4000
        probs = np.asarray(jax.nn.softmax(logits))
        np.testing.assert_allclose(freq, probs, atol=0.03)

    def test_random_uniform_over_unselected(self):
        key = jax.random.PRNGKey(2)
        selected = jnp.zeros((2000, 10), dtype=bool).at[:, 0].set(True)
        idx = sampling.sample_random(key, selected, 3)
        flat = np.asarray(idx).ravel()
        assert flat.min() >= 1 and _no_dups(idx)
        freq = np.bincount(flat, minlength=10)[1:] / flat.size
        np.testing.assert_allclose(freq, np.full(9, 1 / 9), atol=0.02)

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(16, 256),
        k=st.integers(1, 15),
        n_sel=st.integers(0, 10),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_all_strategies_respect_mask(self, n, k, n_sel, seed):
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        scores = jax.random.normal(k1, (3, n))
        sel_idx = jax.random.choice(k2, n, (n_sel,), replace=False) if n_sel else jnp.array([], dtype=jnp.int32)
        selected = jnp.zeros((3, n), dtype=bool).at[:, sel_idx].set(True)
        k_eff = min(k, n - n_sel)
        for strat in ("topk", "softmax", "random"):
            idx = sampling.sample(strat, key, scores, selected, k_eff)
            chosen_mask = np.asarray(jnp.take_along_axis(selected, idx, axis=1))
            assert not chosen_mask.any(), strat
            assert _no_dups(idx), strat


class TestOracles:
    def test_oracle_topk_masks_top_km(self):
        key = jax.random.PRNGKey(0)
        scores = jnp.tile(jnp.arange(50, dtype=jnp.float32)[None, ::-1], (2, 1))
        idx = sampling.oracle_topk(key, scores, k_i=10, k_m=5, eps=0.0)
        assert np.asarray(idx).min() >= 5  # items ranked 0-4 masked out

    def test_oracle_eps_fraction_random(self):
        key = jax.random.PRNGKey(0)
        scores = jnp.tile(jnp.arange(200, dtype=jnp.float32)[None, ::-1], (4, 1))
        idx = sampling.oracle_topk(key, scores, k_i=40, k_m=0, eps=0.5)
        greedy = np.asarray(idx[:, :20])
        assert (greedy < 20).all()          # greedy half = true top-20
        assert _no_dups(idx)

    @pytest.mark.parametrize("eps", [0.0, 0.25, 0.75])
    def test_oracle_softmax_sizes(self, eps):
        key = jax.random.PRNGKey(1)
        scores = jax.random.normal(key, (3, 300))
        idx = sampling.oracle_softmax(key, scores, k_i=40, k_m=10, eps=eps)
        assert idx.shape == (3, 40)
        assert _no_dups(idx)

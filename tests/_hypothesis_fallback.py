"""Deterministic stand-in for the tiny hypothesis subset the tests use.

The real ``hypothesis`` is declared in requirements.txt and is used when
installed.  Hermetic environments without it (the kernel-toolchain
container) import this module instead, so property tests still *collect and
run* — each ``@given`` test executes ``max_examples`` deterministic draws
seeded from the test's qualified name, rather than being skipped.

Only the strategies the suite actually uses are implemented:
``st.integers``, ``st.sampled_from``, ``st.booleans``, ``st.floats``.
No shrinking, no database — failures report the drawn kwargs verbatim.
"""

from __future__ import annotations

import functools
import inspect
import random
import zlib


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng: random.Random):
        return self._draw(rng)


class st:  # noqa: N801 — mirrors `hypothesis.strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def settings(max_examples: int = 10, **_kw):
    """Records max_examples on the wrapped test (deadline etc. ignored)."""

    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            n = getattr(wrapper, "_fallback_max_examples", 10)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {name: s.draw(rng) for name, s in strategies.items()}
                fn(*args, **drawn, **kw)

        # hide the strategy-bound parameters from pytest's fixture
        # resolution (it introspects the signature of collected tests)
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items() if name not in strategies]
        wrapper.__signature__ = sig.replace(parameters=keep)
        return wrapper

    return deco

"""Substrate tests: checkpointing (atomic/async/elastic/recovery), data
pipeline (determinism/resume/prefetch), fault tolerance, optimizer, MoE
dispatch correctness, gnn equivariance properties."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # hermetic container: deterministic fallback
    from _hypothesis_fallback import given, settings, st

from repro.checkpoint import CheckpointManager, Checkpointer
from repro.configs.base import MoEConfig
from repro.configs import registry
from repro.data.loader import Prefetcher, ShardedBatcher
from repro.distributed import compression, fault_tolerance
from repro.models import moe as moe_lib
from repro.models.gnn import nequip, sampler
from repro.training import optimizer


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


class TestCheckpoint:
    def _state(self, key=0):
        k = jax.random.PRNGKey(key)
        return {
            "w": jax.random.normal(k, (8, 4)),
            "nested": {"b": jnp.arange(5, dtype=jnp.float32)},
            "stack": [jnp.ones((2, 2)), jnp.zeros((3,))],
        }

    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = self._state()
        ck.save(7, state)
        out = ck.restore(7, state)
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
            state, out,
        )

    def test_async_save_then_wait(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        state = self._state()
        ck.save(1, state)
        ck.wait()
        assert ck.available_steps() == [1]

    def test_atomic_no_tmp_left(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=False)
        ck.save(3, self._state())
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))

    def test_manager_keep_policy(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=1, keep=2, async_save=False)
        state = self._state()
        for s in range(1, 6):
            mgr.maybe_save(s, state)
        assert mgr.ckpt.available_steps() == [4, 5]

    def test_resume_cold_and_warm(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), save_every=1, keep=3, async_save=False)
        state = self._state()
        step, out = mgr.resume(state)
        assert step == 0
        mgr.maybe_save(2, jax.tree.map(lambda x: x + 1, state))
        step, out = mgr.resume(state)
        assert step == 2
        np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(state["w"]) + 1)

    def test_run_with_recovery_simulated_node_failure(self, tmp_path):
        """A step that dies mid-run resumes from the last checkpoint and the
        final state matches an uninterrupted run exactly."""
        mgr = CheckpointManager(str(tmp_path), save_every=1, keep=5, async_save=False)
        crashed = {"done": False}

        def step_fn(step, state):
            if step == 3 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("host 17 vanished")
            return jax.tree.map(lambda x: x + 1.0, state)

        state0 = {"x": jnp.zeros(3)}
        out = mgr.run_with_recovery(step_fn, state0, n_steps=5)
        np.testing.assert_allclose(np.asarray(out["x"]), np.full(3, 5.0))

    def test_elastic_restore_respecs(self, tmp_path):
        """State saved with specs restores onto a (1,1) mesh (elastic down)."""
        from jax.sharding import PartitionSpec as P

        mesh = jax.make_mesh((1, 1), ("data", "model"))
        ck = Checkpointer(str(tmp_path), async_save=False)
        state = self._state()
        specs = {
            "w": P("data", "model"),
            "nested": {"b": P()},
            "stack": [P(None, "model"), P()],
        }
        ck.save(1, state, specs)
        out = ck.restore(1, state, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(state["w"]))
        # restored leaf carries a NamedSharding on the new mesh
        assert out["w"].sharding.mesh.shape == {"data": 1, "model": 1}


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


class TestLoader:
    def test_deterministic_and_disjoint_across_hosts(self):
        b0 = ShardedBatcher(1000, 64, seed=1, host_id=0, n_hosts=4)
        b1 = ShardedBatcher(1000, 64, seed=1, host_id=1, n_hosts=4)
        i0a, i0b = b0.batch_indices(5), b0.batch_indices(5)
        np.testing.assert_array_equal(i0a, i0b)          # deterministic
        assert not set(i0a.tolist()) & set(b1.batch_indices(5).tolist())

    def test_resume_mid_epoch(self):
        b = ShardedBatcher(1000, 50, seed=3)
        ref = [b.batch_indices(s) for s in range(30)]
        again = [b.batch_indices(s) for s in range(30)]
        for a, c in zip(ref, again):
            np.testing.assert_array_equal(a, c)

    def test_epoch_reshuffles(self):
        b = ShardedBatcher(100, 50, seed=0)
        assert not np.array_equal(b.epoch_order(0), b.epoch_order(1))

    def test_prefetcher_streams_in_order(self):
        pf = Prefetcher(lambda step: step * 10, depth=3, start_step=2)
        got = [next(pf) for _ in range(4)]
        pf.close()
        assert got == [(2, 20), (3, 30), (4, 40), (5, 50)]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


class TestFaultTolerance:
    def test_watchdog_flags_persistent_straggler(self):
        fired = []
        wd = fault_tolerance.StragglerWatchdog(
            threshold=2.0, patience=2, on_straggler=fired.append
        )
        for s in range(10):
            wd.observe(s, 1.0)
        wd.observe(10, 5.0)
        wd.observe(11, 5.0)
        assert fired and fired[0].straggler

    def test_watchdog_ignores_single_blip(self):
        fired = []
        wd = fault_tolerance.StragglerWatchdog(patience=2, on_straggler=fired.append)
        for s in range(10):
            wd.observe(s, 1.0)
        wd.observe(10, 9.0)
        wd.observe(11, 1.0)
        assert not fired

    def test_heartbeat_dead_hosts(self):
        hb = fault_tolerance.HeartbeatMonitor(timeout=10.0)
        hb.beat("a", now=0.0)
        hb.beat("b", now=5.0)
        assert hb.dead_hosts(now=12.0) == ["a"]
        assert hb.healthy_count(now=12.0) == 1

    def test_elastic_plan_picks_largest_fit(self):
        assert fault_tolerance.elastic_plan(512) == (2, 16, 16)
        assert fault_tolerance.elastic_plan(300) == (16, 16)
        assert fault_tolerance.elastic_plan(100) == (8, 8)
        assert fault_tolerance.elastic_plan(1) == (1, 1)


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = optimizer.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
        params = {"x": jnp.array([5.0, -3.0])}
        opt = optimizer.init_adamw(params)
        loss = lambda p: jnp.sum(p["x"] ** 2)
        for _ in range(50):
            grads = jax.grad(loss)(params)
            params, opt, _ = optimizer.adamw_update(cfg, params, grads, opt)
        assert float(loss(params)) < 1.0

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full(4, 10.0)}
        clipped, norm = optimizer.clip_by_global_norm(g, 1.0)
        assert float(norm) == pytest.approx(20.0)
        assert float(optimizer.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)

    def test_grad_accumulation_matches_full_batch(self):
        params = {"w": jnp.array([[1.0, 2.0], [3.0, 4.0]])}
        data = jax.random.normal(jax.random.PRNGKey(0), (8, 2))

        def loss_fn(p, x):
            return jnp.mean((x @ p["w"]) ** 2)

        full = jax.grad(lambda p: loss_fn(p, data))(params)
        micro = data.reshape(4, 2, 2)
        acc, _ = optimizer.accumulate_grads(loss_fn, params, micro, 4)
        np.testing.assert_allclose(np.asarray(acc["w"]), np.asarray(full["w"]), rtol=1e-5)

    def test_schedule_warmup_and_decay(self):
        cfg = optimizer.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
        assert float(optimizer.cosine_schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(optimizer.cosine_schedule(cfg, jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)


class TestCompression:
    def test_int8_roundtrip_error_feedback_converges(self):
        """With error feedback the accumulated compressed sum tracks the true
        sum (compression error does not accumulate)."""
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
        err = compression.init_error_feedback(g)
        total_true = jnp.zeros(64)
        total_comp = jnp.zeros(64)
        for _ in range(20):
            deq, err = compression.int8_roundtrip_with_feedback(g, err)
            total_true += g["w"]
            total_comp += deq["w"]
        rel = float(jnp.abs(total_comp - total_true).max() / jnp.abs(total_true).max())
        assert rel < 0.02

    def test_topk_sparsify_keeps_largest(self):
        g = {"w": jnp.arange(100, dtype=jnp.float32)}
        err = compression.init_error_feedback(g)
        kept, err = compression.topk_sparsify_with_feedback(g, err, frac=0.1)
        nz = np.asarray(kept["w"]) != 0
        assert nz.sum() == 10 and nz[-10:].all()

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10000))
    def test_property_int8_bounded_error(self, seed):
        g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10
        q, s = compression.int8_quantize(g)
        deq = compression.int8_dequantize(q, s)
        assert float(jnp.abs(deq - g).max()) <= float(s) * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# MoE dispatch correctness (sort-based capacity dispatch vs dense reference)
# ---------------------------------------------------------------------------


class TestMoE:
    def _dense_reference(self, params, x, cfg):
        """Route every token through its top-k experts with no capacity."""
        logits = x.astype(jnp.float32) @ params["router"]
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, cfg.top_k)
        top_p = top_p / top_p.sum(-1, keepdims=True)
        y = jnp.zeros_like(x)
        for j in range(cfg.top_k):
            for e in range(cfg.n_experts):
                m = (top_e[:, j] == e).astype(x.dtype)[:, None]
                g = jax.nn.silu(x @ params["wg"][e]) * (x @ params["wu"][e])
                y += m * top_p[:, j : j + 1].astype(x.dtype) * (g @ params["wd"][e])
        return y

    def test_matches_dense_reference_with_big_capacity(self):
        from repro.models import layers

        cfg = MoEConfig(n_experts=4, top_k=2, d_expert=16)
        key = jax.random.PRNGKey(0)
        params, _ = layers.split_tree(moe_lib.moe_init(key, 8, cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (32, 8))
        y, aux = moe_lib.moe_apply_local(params, x, cfg, capacity_factor=4.0)
        ref = self._dense_reference(params, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-4, rtol=1e-4)

    def test_capacity_drop_is_graceful(self):
        from repro.models import layers

        cfg = MoEConfig(n_experts=2, top_k=1, d_expert=8)
        params, _ = layers.split_tree(moe_lib.moe_init(jax.random.PRNGKey(0), 4, cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 4))
        y, _ = moe_lib.moe_apply_local(params, x, cfg, capacity_factor=0.25)
        assert jnp.isfinite(y).all()

    def test_shared_experts_added(self):
        from repro.models import layers

        cfg = MoEConfig(n_experts=2, top_k=1, d_expert=8, n_shared_experts=1)
        params, _ = layers.split_tree(moe_lib.moe_init(jax.random.PRNGKey(0), 4, cfg))
        x = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
        y, _ = moe_lib.moe_apply_local(params, x, cfg, capacity_factor=4.0)
        no_shared = dict(params)
        no_shared.pop("shared")
        y2, _ = moe_lib.moe_apply_local(no_shared, x, cfg, capacity_factor=4.0)
        assert float(jnp.abs(y - y2).max()) > 1e-5


# ---------------------------------------------------------------------------
# GNN equivariance properties + sampler
# ---------------------------------------------------------------------------


class TestNequIPProperties:
    def _setup(self, seed=0):
        cfg = registry.smoke_config("nequip")
        params, _ = nequip.init_nequip(jax.random.PRNGKey(0), cfg)
        k = jax.random.PRNGKey(seed)
        pos = jax.random.normal(k, (16, 3)) * 2
        sp = jax.random.randint(k, (16,), 0, cfg.n_species)
        s = jax.random.randint(jax.random.PRNGKey(seed + 1), (50,), 0, 16)
        r = jax.random.randint(jax.random.PRNGKey(seed + 2), (50,), 0, 16)
        return cfg, params, pos, sp, s, r

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_property_rotation_invariant_energy(self, seed):
        cfg, params, pos, sp, s, r = self._setup(seed)
        q, _ = np.linalg.qr(np.random.default_rng(seed).normal(size=(3, 3)))
        q = jnp.asarray(q, jnp.float32)
        e1 = nequip.forward(params, cfg, pos, sp, s, r)
        e2 = nequip.forward(params, cfg, pos @ q.T, sp, s, r)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=3e-5, rtol=1e-4)

    def test_translation_invariant(self):
        cfg, params, pos, sp, s, r = self._setup()
        e1 = nequip.forward(params, cfg, pos, sp, s, r)
        e2 = nequip.forward(params, cfg, pos + 7.5, sp, s, r)
        np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=3e-5, rtol=1e-4)

    def test_forces_rotate_covariantly(self):
        cfg, params, pos, sp, s, r = self._setup()
        q, _ = np.linalg.qr(np.random.default_rng(7).normal(size=(3, 3)))
        q = jnp.asarray(q, jnp.float32)
        _, f1 = nequip.energy_and_forces(params, cfg, pos, sp, s, r)
        _, f2 = nequip.energy_and_forces(params, cfg, pos @ q.T, sp, s, r)
        np.testing.assert_allclose(
            np.asarray(f1 @ q.T), np.asarray(f2), atol=5e-4, rtol=5e-3
        )

    def test_sampler_respects_fanout_and_padding(self):
        sd, rc = sampler.random_graph(2000, 16000, 1)
        g = sampler.CSRGraph.from_edge_index(sd, rc, 2000)
        rng = np.random.default_rng(0)
        sub = sampler.sample_subgraph(g, np.arange(32), (5, 3), 4000, 4000, rng)
        assert sub.node_mask.sum() <= 4000 and sub.edge_mask.sum() <= 4000
        assert sub.seed_mask.sum() == 32
        # edges reference in-range local ids
        assert sub.senders.max() < 4000 and sub.receivers.max() < 4000

"""Multi-device numerical equivalence checks, run as a SUBPROCESS with 8
forced host devices (jax locks device count at init, so the main pytest
process cannot do this).  Asserts that every distributed execution path
produces the same numbers as its single-device reference:

- sequence-parallel decode attention (LSE combine) == local decode core
- expert-parallel MoE (shard_map)                  == local MoE
- channel-TP receiver-partitioned GNN interact     == local interact
- pipeline_forward (GPipe over an axis)            == plain stage chain
- AnchorIndex.shard(mesh) search (shard_map fused per-shard top-k with a
  cross-shard merge, AND the full engine under jit auto-SPMD) == the
  unsharded index

Exit code 0 = all equivalences hold.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import MoEConfig
from repro.configs import registry
from repro.distributed import decode_attention, pipeline
from repro.models import moe as moe_lib, transformer
from repro.models.gnn import nequip

TOL = dict(rtol=2e-4, atol=2e-4)


def check_decode_attention(mesh):
    b, s, kv, h, hd = 4, 64, 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q = jax.random.normal(ks[0], (b, h, hd))
    k_new = jax.random.normal(ks[1], (b, kv, hd))
    v_new = jax.random.normal(ks[2], (b, kv, hd))
    ck = jax.random.normal(ks[3], (b, s, kv, hd))
    cv = jax.random.normal(ks[4], (b, s, kv, hd))
    pos = jnp.int32(37)

    ref_o, ref_ck, ref_cv = transformer._local_decode_core(q, k_new, v_new, ck, cv, pos)
    core = decode_attention.make_decode_core(mesh, ("data",), ("model",), s)
    with mesh:
        o, ck2, cv2 = jax.jit(core)(q, k_new, v_new, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref_o), **TOL)
    np.testing.assert_allclose(np.asarray(ck2), np.asarray(ref_ck), **TOL)
    np.testing.assert_allclose(np.asarray(cv2), np.asarray(ref_cv), **TOL)

    # seq sharded over BOTH axes (the long_500k layout), batch unsharded
    core2 = decode_attention.make_decode_core(mesh, (), ("data", "model"), s)
    with mesh:
        o2, _, _ = jax.jit(core2)(q, k_new, v_new, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(ref_o), **TOL)
    print("decode_attention: OK")


def check_moe(mesh):
    from repro.models import layers

    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16)
    params, _ = layers.split_tree(moe_lib.moe_init(jax.random.PRNGKey(0), 12, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 12))
    y_ref, _ = moe_lib.moe_apply_local(params, x, cfg, capacity_factor=8.0)
    # EP computes the aux loss per data GROUP (GShard's per-group definition):
    # the reference is the mean of per-shard auxes, not the global aux.
    n_dp = mesh.shape["data"]
    aux_ref = np.mean([
        float(moe_lib.moe_apply_local(params, xs, cfg, capacity_factor=8.0)[1])
        for xs in jnp.split(x, n_dp)
    ])
    moe_fn = moe_lib.make_moe_fn(mesh, cfg, ("data",), "model", capacity_factor=8.0)
    with mesh:
        y, aux = jax.jit(moe_fn)(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)
    np.testing.assert_allclose(float(aux), aux_ref, rtol=1e-4)
    print("moe_ep: OK")


def check_gnn_interact(mesh):
    cfg = registry.smoke_config("nequip")
    h = 8   # divisible by model axis (4)
    import dataclasses
    cfg = dataclasses.replace(cfg, d_hidden=h)
    params, _ = nequip.init_nequip(jax.random.PRNGKey(0), cfg)
    n_per, n_shards = 8, mesh.shape["data"]
    n = n_per * n_shards
    e_per = 16
    e = e_per * n_shards
    key = jax.random.PRNGKey(3)
    pos = jax.random.normal(key, (n, 3)) * 2
    # receiver-partitioned edges: shard i's receivers live in its node range
    recv = jnp.concatenate([
        jax.random.randint(jax.random.PRNGKey(10 + i), (e_per,), i * n_per, (i + 1) * n_per)
        for i in range(n_shards)
    ])
    send = jax.random.randint(jax.random.PRNGKey(4), (e,), 0, n)
    feats = {
        "s": jax.random.normal(jax.random.PRNGKey(5), (n, h)),
        "v": jax.random.normal(jax.random.PRNGKey(6), (n, h, 3)) * 0.1,
        "t": jax.random.normal(jax.random.PRNGKey(7), (n, h, 3, 3)) * 0.1,
    }
    feats["t"] = jax.tree.map(lambda x: x, feats)["t"]
    rhat, y2, rbf = nequip._edge_geometry(pos, send, recv, cfg)
    lp = params["layers"][0]
    ref = nequip._interact(lp, feats, send, recv, rhat, y2, rbf, n, h)
    interact = nequip.make_sharded_interact(mesh, "data", "model")
    with mesh:
        out = jax.jit(
            lambda *a: interact(*a)
        )(lp, feats, send, recv, rhat, y2, rbf, n, h)
    for k in ("s", "v", "t"):
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]), **TOL)
    print("gnn_sharded_interact: OK")


def check_pipeline(mesh):
    n_stages = mesh.shape["data"]
    d = 6
    keys = jax.random.split(jax.random.PRNGKey(0), n_stages)
    ws = jnp.stack([jax.random.normal(k, (d, d)) / jnp.sqrt(d) for k in keys])

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
    ref = x
    for i in range(n_stages):
        ref = stage_fn(ws[i], ref)
    piped = pipeline.pipeline_forward(mesh, stage_fn, "data", n_microbatches=4)
    with mesh:
        out = jax.jit(piped)(ws, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)
    print("pipeline_forward: OK")


def check_cross_pod_reduce():
    """int8 hierarchical cross-pod grad reduce: mean parity + error-feedback
    convergence over repeated steps (multi-pod mesh (2, 2, 2))."""
    from repro.distributed import compression, cross_pod

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    specs = {"w": P("data", "model")}
    reduce_fn = cross_pod.make_hierarchical_grad_reduce(mesh, specs)

    # per-pod partial grads: same sharded layout, different value per pod
    g_pod = [
        {"w": jax.random.normal(jax.random.PRNGKey(i), (8, 8))} for i in range(2)
    ]
    true_mean = {"w": (g_pod[0]["w"] + g_pod[1]["w"]) / 2}
    # lay out a per-pod-varying global value: pod p holds g_pod[p]
    full = {"w": jnp.stack([g_pod[0]["w"], g_pod[1]["w"]])}   # (2, 8, 8)

    def driver(full_g, err):
        def body(gp, e):
            g = {"w": gp["w"][0]}          # this pod's partial
            out, new_e = cross_pod_body(g, e)
            return out, new_e
        return shard_map(
            body, mesh=mesh,
            in_specs=({"w": P("pod", "data", "model")}, {"w": P("data", "model")}),
            out_specs=({"w": P("data", "model")}, {"w": P("data", "model")}),
            check_vma=False,
        )(full_g, err)

    # shared-scale int8 reduce (mirrors cross_pod.make_hierarchical_grad_reduce)
    def cross_pod_body(g, e):
        def one(gl, el):
            g32 = gl.astype(jnp.float32) + el
            scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), "pod") / 127.0 + 1e-12
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
            deq = q_sum.astype(jnp.float32) * scale / 2
            return deq, g32 - q.astype(jnp.float32) * scale
        pairs = jax.tree.map(one, g, e)
        return (
            jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)),
        )

    err = {"w": jnp.zeros((8, 8))}
    total_true = jnp.zeros((8, 8))
    total_comp = jnp.zeros((8, 8))
    with mesh:
        for _ in range(10):
            out, err = jax.jit(driver)(full, err)
            total_true += true_mean["w"]
            total_comp += out["w"]
    rel = float(jnp.abs(total_comp - total_true).max() / jnp.abs(total_true).max())
    assert rel < 0.05, rel
    print(f"cross_pod_reduce: OK (accumulated rel err {rel:.4f})")


def check_anchor_index_shard(mesh):
    """shard(mesh) parity: the sharded index must produce the identical
    top-k — through the shard_map fused-topk + cross-shard merge path AND
    through the full engine run on the column-sharded R_anc (auto-SPMD)."""
    from repro.configs.base import AdaCURConfig
    from repro.core.engine import AdaCURRetriever
    from repro.core.index import AnchorIndex

    r = jax.random.normal(jax.random.PRNGKey(0), (24, 1000))
    index = AnchorIndex.from_r_anc(r, capacity=1024)   # padded, n_valid=1000
    sharded = index.shard(mesh)
    det_mesh, det_axes = sharded._item_sharding()
    assert det_axes == ("data", "model"), det_axes
    assert det_mesh is not None

    # the placement must survive mutation (it lives in the NamedSharding)
    mutated = sharded.add_items(jnp.arange(1000, 1010),
                                cols=jnp.zeros((24, 10)))
    assert mutated._item_sharding()[1] == ("data", "model")

    # (a) latent top-k: per-shard fused approx_topk + all-gather merge
    e_q = jax.random.normal(jax.random.PRNGKey(1), (5, 24))
    v0, i0 = index.topk(e_q, 10, tile=128)
    v1, i1 = sharded.topk(e_q, 10, tile=128)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), **TOL)

    # (b) the full multi-round engine over the sharded index
    def score_fn(q, idx):
        return jnp.take(r, idx, axis=1).mean(axis=0) + 0.01 * q[:, None]

    cfg = AdaCURConfig(k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=10,
                       loop_mode="fori")
    q = jnp.arange(5, dtype=jnp.float32)
    res_h = AdaCURRetriever.from_index(index, score_fn, cfg).search(
        q, jax.random.PRNGKey(2)
    )
    res_s = AdaCURRetriever.from_index(sharded, score_fn, cfg).search(
        q, jax.random.PRNGKey(2)
    )
    np.testing.assert_array_equal(
        np.asarray(res_h.topk_idx), np.asarray(res_s.topk_idx)
    )
    np.testing.assert_allclose(
        np.asarray(res_h.topk_scores), np.asarray(res_s.topk_scores), **TOL
    )
    print("anchor_index_shard: OK")


def check_quantized_index_shard(mesh):
    """shard(mesh) on an int8 payload: codes and scales must land co-sharded
    on the item axis (whole quantization tiles per shard), and the sharded
    fused-dequant top-k must match the unsharded quantized index exactly."""
    from repro.core.index import AnchorIndex
    from repro.kernels.approx_topk.quant import QuantizedRanc

    tile = 16
    r = jax.random.normal(jax.random.PRNGKey(0), (24, 1000))
    index = AnchorIndex.from_r_anc(r, capacity=1024).quantize("int8", tile=tile)
    sharded = index.shard(mesh)
    assert isinstance(sharded.r_anc, QuantizedRanc)
    assert sharded._item_sharding()[1] == ("data", "model"), (
        sharded._item_sharding()
    )
    # co-sharding: each shard owns whole tiles and exactly their scales
    n_shards = mesh.size
    assert sharded.capacity % (n_shards * tile) == 0
    codes_spec = sharded.r_anc.codes.sharding.spec
    scales_spec = sharded.r_anc.scales.sharding.spec
    assert tuple(codes_spec[1]) == ("data", "model"), codes_spec
    assert tuple(scales_spec[0]) == ("data", "model"), scales_spec

    e_q = jax.random.normal(jax.random.PRNGKey(1), (5, 24))
    v0, i0 = index.topk(e_q, 10, tile=128)
    v1, i1 = sharded.topk(e_q, 10, tile=128)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), **TOL)

    # mutation keeps the co-sharded placement
    mutated = sharded.add_items(jnp.arange(1000, 1010),
                                cols=jnp.zeros((24, 10)))
    assert mutated._item_sharding()[1] == ("data", "model")
    print("quantized_index_shard: OK")


if __name__ == "__main__":
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    check_decode_attention(mesh)
    check_moe(mesh)
    check_gnn_interact(mesh)
    check_pipeline(mesh)
    check_cross_pod_reduce()
    check_anchor_index_shard(mesh)
    check_quantized_index_shard(mesh)
    print("ALL MULTIDEVICE CHECKS PASSED")

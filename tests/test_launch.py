"""Launcher-layer tests: step builders compile on a 1x1 mesh for every
cell family, sharding rules resolve sensibly, roofline parsing works."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding
from repro.launch import roofline
from repro.launch.dryrun import collective_bytes


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestShardingRules:
    def test_basic_translation(self, mesh11):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        spec = sharding.spec_for(mesh, ("embed", "heads", "head_dim"), (64, 4, 16))
        assert spec == P("data", "model")

    def test_divisibility_fallback(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        # kv_heads=3 does not divide model=1? it does; use a fake big mesh via
        # axis sizes on the 1-device mesh: model=1 always divides -> sharded
        spec = sharding.spec_for(mesh, ("kv_heads", "head_dim"), (3, 16))
        assert spec == P("model")

    def test_no_duplicate_mesh_axis(self, mesh11):
        # (expert, embed, mlp): expert takes model, mlp must NOT reuse it
        spec = sharding.spec_for(mesh11, ("expert", "embed", "mlp"), (4, 8, 16))
        assert spec == P("model", "data")

    def test_batch_axes(self, mesh11):
        assert sharding.batch_axes(mesh11) == ("data",)
        mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
        assert sharding.batch_axes(mesh3) == ("pod", "data")


class TestCollectiveParser:
    def test_parses_shapes_and_kinds(self):
        hlo = """
  %ag = bf16[8,128]{1,0} all-gather(%x), replica_groups={{0,1}}
  %ar.1 = f32[256]{0} all-reduce(%y), to_apply=%add
  %cp = f32[4,4]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %not_a_collective = f32[9] add(%a, %b)
"""
        out = collective_bytes(hlo)
        assert out["all-gather"]["bytes"] == 8 * 128 * 2
        assert out["all-reduce"]["bytes"] == 256 * 4
        assert out["collective-permute"]["count"] == 1
        assert "add" not in out

    def test_roofline_terms(self):
        rec = {
            "cell": "x:y", "mesh": "16x16", "n_chips": 256,
            "cost": {"flops": 1e12, "bytes accessed": 1e9},
            "collectives": {"all-reduce": {"count": 1, "bytes": 5e8}},
            "model_flops": 2.56e15,   # 1e13/chip > hlo 1e12 -> analytic wins
            "memory": {"temp_size_in_bytes": 123},
        }
        r = roofline.analyze_record(rec)
        assert r.t_compute_model == pytest.approx(1e13 / 197e12)
        assert r.t_memory == pytest.approx(1e9 / 819e9)
        assert r.t_collective == pytest.approx(2 * 5e8 / 50e9)
        assert r.bottleneck == "compute"


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("granite-moe-1b-a400m", "train_4k"),
        ("qwen3-8b", "decode_32k"),
        ("nequip", "molecule"),
        ("bst", "retrieval_cand"),
        ("mind", "serve_p99"),
        ("dlrm-mlperf", "serve_p99"),
    ],
)
def test_cell_builders_construct(arch, shape, mesh11):
    """Every family's builder produces a coherent StepBundle on a tiny mesh
    (full lowering is exercised by launch/dryrun.py with 512 devices)."""
    from repro.launch import steps

    bundle = steps.build_cell(arch, shape, mesh11)
    assert bundle.name == f"{arch}:{shape}"
    assert bundle.model_flops > 0
    flat_args = jax.tree.leaves(bundle.abstract_args)
    flat_shardings = jax.tree.leaves(
        bundle.in_shardings, is_leaf=lambda x: hasattr(x, "spec")
    )
    assert len(flat_args) > 0 and len(flat_shardings) > 0

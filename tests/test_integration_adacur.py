"""Integration: ADACUR end-to-end with REAL scorers (the trained-CE path
and the recsys joint scorers), plus the fused kernel consistency with the
engine's own computation and the serving loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import AdaCURConfig, replace
from repro.core import adacur, cur, index as index_lib, retrieval
from repro.data.synthetic import make_zeshel_like
from repro.kernels.approx_topk.ops import approx_topk_op
from repro.launch.serve import AdaCURService, RetrievalRequest
from repro.models import cross_encoder
from repro.models.recsys import bst


@pytest.fixture(scope="module")
def ce_domain():
    """Tiny untrained transformer CE over a ZESHEL-like corpus."""
    ds = make_zeshel_like(0, n_items=200, n_queries=50, item_len=12, query_len=8)
    cfg = replace(
        registry.CE_TINY, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=ds.vocab_size, dtype="float32",
        remat=False,
    )
    params, _ = cross_encoder.init_cross_encoder(jax.random.PRNGKey(0), cfg)

    def score_fn(q_ids, item_idx):
        toks = jnp.asarray(ds.pair_tokens(np.asarray(q_ids), np.asarray(item_idx)))
        return cross_encoder.score_pairs(params, toks, cfg)

    def bulk(q_ids, item_ids):
        toks = jnp.asarray(
            ds.pair_tokens(np.asarray(q_ids), np.tile(np.asarray(item_ids), (len(q_ids), 1)))
        )
        return cross_encoder.score_pairs(params, toks, cfg)

    return ds, score_fn, bulk


class TestTransformerCEPipeline:
    def test_index_then_search(self, ce_domain, tmp_path):
        ds, score_fn, bulk = ce_domain
        r_anc = index_lib.build_r_anc(
            bulk, jnp.arange(30), jnp.arange(200), block_rows=16,
            checkpoint_dir=str(tmp_path),
        )
        assert r_anc.shape == (30, 200)
        # resume path: second call loads from the manifest (no rescoring)
        r_anc2 = index_lib.build_r_anc(
            bulk, jnp.arange(30), jnp.arange(200), block_rows=16,
            checkpoint_dir=str(tmp_path),
        )
        np.testing.assert_allclose(np.asarray(r_anc), np.asarray(r_anc2), rtol=1e-6)

        test_q = np.arange(30, 40)
        exact = bulk(test_q, np.arange(200))
        cfg = AdaCURConfig(k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=20)
        res = adacur.adacur_search(score_fn, r_anc, test_q, cfg, jax.random.PRNGKey(1))
        rep = retrieval.evaluate_result("adacur-ce", res, exact, ks=(1, 10))
        assert rep.recall[1] > 0.5  # finds the CE's own argmax most of the time

    def test_anchor_scores_match_direct_ce(self, ce_domain):
        ds, score_fn, bulk = ce_domain
        r_anc = bulk(np.arange(20), np.arange(200))
        test_q = np.arange(20, 26)
        cfg = AdaCURConfig(k_anchor=12, n_rounds=3, budget_ce=24, k_retrieve=10)
        res = adacur.adacur_search(score_fn, r_anc, test_q, cfg, jax.random.PRNGKey(0))
        direct = score_fn(test_q, res.anchor_idx)
        np.testing.assert_allclose(
            np.asarray(res.anchor_scores), np.asarray(direct), rtol=1e-4, atol=1e-4
        )


class TestFusedKernelConsistency:
    def test_kernel_matches_engine_round(self, small_domain):
        """The fused approx_topk kernel reproduces the engine's round-2
        candidate selection exactly (same e_q, same masking)."""
        r_anc = small_domain["r_anc"]
        exact = small_domain["exact"]
        anchor = jnp.tile(jnp.arange(0, 2000, 50)[None, :], (4, 1))  # 40 anchors
        c_test = jnp.take_along_axis(exact[:4], anchor, axis=1)
        cols = cur.gather_anchor_columns(r_anc, anchor)
        e_q = cur.query_embedding(cols, c_test, rcond=1e-4)

        # engine path: full scores -> mask -> top-k
        s_hat = e_q @ r_anc
        rows = jnp.arange(4)[:, None]
        sel = jnp.zeros((4, 2000), bool).at[rows, anchor].set(True)
        ref_v, ref_i = jax.lax.top_k(jnp.where(sel, -1e30, s_hat), 16)

        v, i = approx_topk_op(e_q, r_anc, anchor, 16, tile=256, interpret=True)
        np.testing.assert_allclose(np.asarray(v), np.asarray(ref_v), rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(np.asarray(i), np.asarray(ref_i))


class TestRecsysADACUR:
    def test_bst_joint_scorer_with_adacur(self):
        """The paper's technique over the BST cross-encoder-class scorer."""
        cfg = registry.smoke_config("bst")
        params, _ = bst.init_bst(jax.random.PRNGKey(0), cfg)
        n_items = 500
        hist = jax.random.randint(jax.random.PRNGKey(1), (6, cfg.seq_len), 0, n_items)

        def score_fn(h, idx):
            return bst.score_candidates(params, h, idx, cfg)

        # offline: 40 anchor "queries" (user histories) x all items
        anchor_hists = jax.random.randint(
            jax.random.PRNGKey(2), (40, cfg.seq_len), 0, n_items
        )
        all_items = jnp.tile(jnp.arange(n_items)[None], (40, 1))
        r_anc = bst.score_candidates(params, anchor_hists, all_items, cfg)
        exact = bst.score_candidates(
            params, hist, jnp.tile(jnp.arange(n_items)[None], (6, 1)), cfg
        )
        acfg = AdaCURConfig(k_anchor=24, n_rounds=4, budget_ce=60, k_retrieve=50)
        res = adacur.adacur_search(score_fn, r_anc, hist, acfg, jax.random.PRNGKey(3))
        rep = retrieval.evaluate_result("bst-adacur", res, exact, ks=(1, 10))
        # with an untrained scorer, structure is weak; sanity: valid results
        assert res.topk_idx.shape == (6, 50)
        assert rep.recall[10] >= 0.0
        ref = bst.score_candidates(params, hist, res.topk_idx, cfg)
        np.testing.assert_allclose(
            np.asarray(res.topk_scores), np.asarray(ref), rtol=1e-4, atol=1e-4
        )


class TestServing:
    def test_straggler_flushed_by_poll_after_deadline(self, small_domain):
        """Regression: a lone queued request past max_wait_s used to sit
        until ANOTHER request arrived; poll() must serve it."""
        import time

        cfg = AdaCURConfig(k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=20)
        svc = AdaCURService(
            small_domain["ce"].score_fn(), small_domain["r_anc"], cfg,
            max_batch=4, max_wait_s=0.02,
        )
        assert svc.submit(RetrievalRequest(query_id=205)) is None
        assert svc.poll() == []          # deadline not reached yet
        time.sleep(0.03)
        out = svc.poll()                 # no second request ever arrives
        assert len(out) == 1 and out[0].query_id == 205
        assert out[0].item_ids.shape == (20,)
        assert svc.poll() == []          # queue drained

    def test_service_accepts_custom_retriever(self, small_domain):
        from repro.core.engine import AdaCURRetriever

        cfg = AdaCURConfig(
            k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=20,
            loop_mode="fori", use_fused_topk=True, fused_tile=256,
        )
        ret = AdaCURRetriever(small_domain["ce"].score_fn(), small_domain["r_anc"], cfg)
        svc = AdaCURService(retriever=ret, max_batch=2, max_wait_s=10.0)
        out = []
        for qid in (201, 202):
            got = svc.submit(RetrievalRequest(query_id=qid))
            out += got or []
        assert len(out) == 2
        assert all(r.item_ids.shape == (20,) for r in out)

    def test_service_batches_and_answers(self, small_domain):
        cfg = AdaCURConfig(k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=20)
        svc = AdaCURService(
            small_domain["ce"].score_fn(), small_domain["r_anc"], cfg,
            max_batch=4, max_wait_s=10.0,
        )
        responses = []
        for qid in range(200, 208):
            out = svc.submit(RetrievalRequest(query_id=qid))
            if out:
                responses += out
        responses += svc.flush()
        assert len(responses) == 8
        for r in responses:
            assert r.item_ids.shape == (20,)
            assert r.ce_calls == 40

"""AnchorIndex lifecycle: build -> interrupt -> resume bit-parity, stale
manifest invalidation (the block_rows regression), save -> load -> search
round-trip parity, add_items/remove_items parity vs a from-scratch rebuild
(and no-retrace), external item ids, and the index-first service."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig
from repro.core.engine import AdaCURRetriever, ANNCURRetriever, RerankRetriever
from repro.core.index import AnchorIndex, build_r_anc
from repro.data.synthetic import make_synthetic_ce

CFG = AdaCURConfig(
    k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=10, loop_mode="fori"
)


@pytest.fixture(scope="module")
def dom():
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=60, n_items=300)
    m = ce.full_matrix(jnp.arange(60))
    return {
        "ce": ce,
        "m": m,                      # (60, 300) full score matrix
        "q_ids": jnp.arange(40),     # anchor queries
        "test_q": jnp.arange(40, 60),
    }


class TestBuildResume:
    def test_interrupt_then_resume_bit_parity(self, dom, tmp_path):
        """A preempted build resumes from its block checkpoints and produces
        the exact bytes an uninterrupted build produces, rescoring only the
        missing blocks."""
        ce, q_ids = dom["ce"], dom["q_ids"]
        item_ids = jnp.arange(300)
        d = str(tmp_path / "ck")

        calls = {"n": 0}

        def flaky(q, i):
            if calls["n"] >= 2:
                raise RuntimeError("preempted")
            calls["n"] += 1
            return ce.score_block(q, i)

        with pytest.raises(RuntimeError):
            build_r_anc(flaky, q_ids, item_ids, block_rows=8, checkpoint_dir=d)

        count = {"n": 0}

        def counting(q, i):
            count["n"] += 1
            return ce.score_block(q, i)

        resumed = build_r_anc(counting, q_ids, item_ids, block_rows=8,
                              checkpoint_dir=d)
        assert count["n"] == 3          # 5 blocks total, 2 were checkpointed
        fresh = build_r_anc(ce.score_block, q_ids, item_ids, block_rows=8)
        np.testing.assert_array_equal(np.asarray(resumed), np.asarray(fresh))

    def test_stale_block_rows_invalidates_manifest(self, dom, tmp_path):
        """Regression: the manifest used to validate only k_q/n_items, so
        resuming with a different block_rows silently reused blocks whose
        row ranges no longer matched.  It must be invalidated instead."""
        ce, q_ids = dom["ce"], dom["q_ids"]
        item_ids = jnp.arange(300)
        d = str(tmp_path / "ck")
        first = build_r_anc(ce.score_block, q_ids, item_ids, block_rows=16,
                            checkpoint_dir=d)
        # same dir, different block geometry: all blocks must be rescored
        second = build_r_anc(ce.score_block, q_ids, item_ids, block_rows=8,
                             checkpoint_dir=d)
        assert second.shape == (40, 300)
        np.testing.assert_array_equal(np.asarray(second), np.asarray(first))
        with open(os.path.join(d, "manifest.json")) as f:
            meta = json.load(f)
        assert meta["block_rows"] == 8
        assert len(meta["done_blocks"]) == 5

    def test_stale_id_content_invalidates_manifest(self, dom, tmp_path):
        """Same shapes/block geometry but DIFFERENT anchor-query ids must
        not reuse blocks: the manifest fingerprints the id content."""
        ce = dom["ce"]
        item_ids = jnp.arange(300)
        d = str(tmp_path / "ck")
        build_r_anc(ce.score_block, jnp.arange(40), item_ids, block_rows=16,
                    checkpoint_dir=d)
        other_q = jnp.arange(10, 50)       # same k_q, different queries
        got = build_r_anc(ce.score_block, other_q, item_ids, block_rows=16,
                          checkpoint_dir=d)
        fresh = build_r_anc(ce.score_block, other_q, item_ids, block_rows=16)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(fresh))

    def test_resume_skips_all_blocks(self, dom, tmp_path):
        ce, q_ids = dom["ce"], dom["q_ids"]
        d = str(tmp_path / "ck")
        idx = AnchorIndex.build(ce.score_block, q_ids, jnp.arange(300),
                                block_rows=16, checkpoint_dir=d)

        def exploding(q, i):
            raise AssertionError("resume must not rescore finished blocks")

        idx2 = AnchorIndex.build(exploding, q_ids, jnp.arange(300),
                                 block_rows=16, checkpoint_dir=d)
        np.testing.assert_array_equal(np.asarray(idx.r_anc), np.asarray(idx2.r_anc))


class TestSaveLoad:
    def test_save_load_search_round_trip(self, dom, tmp_path):
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        index = AnchorIndex.from_r_anc(m[:40], capacity=320).with_latents(
            k_anchor=10, key=jax.random.PRNGKey(5)
        )
        path = str(tmp_path / "index")
        index.save(path)
        loaded = AnchorIndex.load(path)
        for name in ("r_anc", "item_ids", "n_valid", "anchor_item_pos",
                     "u", "item_embeddings"):
            np.testing.assert_array_equal(
                np.asarray(getattr(index, name)), np.asarray(getattr(loaded, name))
            )
        key = jax.random.PRNGKey(1)
        res_m = AdaCURRetriever.from_index(index, sf, CFG).search(dom["test_q"], key)
        res_l = AdaCURRetriever.from_index(loaded, sf, CFG).search(dom["test_q"], key)
        np.testing.assert_array_equal(
            np.asarray(res_m.topk_idx), np.asarray(res_l.topk_idx)
        )
        np.testing.assert_array_equal(
            np.asarray(res_m.topk_scores), np.asarray(res_l.topk_scores)
        )

    def test_version_check(self, dom, tmp_path):
        index = AnchorIndex.from_r_anc(dom["m"][:40])
        path = str(tmp_path / "index")
        index.save(path)
        meta_path = os.path.join(path, "index_meta.json")
        with open(meta_path) as f:
            meta = json.load(f)
        meta["format_version"] = 999
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        with pytest.raises(ValueError, match="format version"):
            AnchorIndex.load(path)

    def test_load_missing_dir_raises(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            AnchorIndex.load(str(tmp_path / "nope"))


class TestMutation:
    def test_add_items_parity_vs_rebuild(self, dom):
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        key = jax.random.PRNGKey(1)
        index = AnchorIndex.from_r_anc(
            m[:40, :250], item_ids=jnp.arange(250), capacity=300
        )
        grown = index.add_items(jnp.arange(250, 300), cols=m[:40, 250:300])
        rebuild = AnchorIndex.from_r_anc(m[:40], capacity=300)
        np.testing.assert_array_equal(
            np.asarray(grown.r_anc), np.asarray(rebuild.r_anc)
        )
        res_g = AdaCURRetriever.from_index(grown, sf, CFG).search(dom["test_q"], key)
        res_r = AdaCURRetriever.from_index(rebuild, sf, CFG).search(dom["test_q"], key)
        np.testing.assert_array_equal(
            np.asarray(res_g.topk_idx), np.asarray(res_r.topk_idx)
        )
        np.testing.assert_array_equal(
            np.asarray(res_g.topk_scores), np.asarray(res_r.topk_scores)
        )

    def test_remove_items_parity_vs_rebuild(self, dom):
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        key = jax.random.PRNGKey(2)
        full = AnchorIndex.from_r_anc(m[:40], capacity=300)
        rm = jnp.arange(100, 150)
        shrunk = full.remove_items(rm)
        surv = np.setdiff1d(np.arange(300), np.asarray(rm))
        rebuild = AnchorIndex.from_r_anc(
            m[:40][:, surv], item_ids=jnp.asarray(surv), capacity=300
        )
        np.testing.assert_array_equal(
            np.asarray(shrunk.r_anc), np.asarray(rebuild.r_anc)
        )
        np.testing.assert_array_equal(
            np.asarray(shrunk.item_ids), np.asarray(rebuild.item_ids)
        )
        res_s = AdaCURRetriever.from_index(shrunk, sf, CFG).search(dom["test_q"], key)
        res_r = AdaCURRetriever.from_index(rebuild, sf, CFG).search(dom["test_q"], key)
        np.testing.assert_array_equal(
            np.asarray(res_s.topk_idx), np.asarray(res_r.topk_idx)
        )
        # removed external ids never appear in the results
        got_ids = np.asarray(shrunk.gather_item_ids(res_s.topk_idx))
        assert not np.isin(got_ids, np.asarray(rm)).any()

    def test_mutation_never_retraces(self, dom):
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        traces = []

        def counting_sf(q, i):
            traces.append(1)
            return sf(q, i)

        index = AnchorIndex.from_r_anc(
            m[:40, :250], item_ids=jnp.arange(250), capacity=300
        )
        ret = AdaCURRetriever.from_index(index, counting_sf, CFG)
        ret.search(dom["test_q"], jax.random.PRNGKey(1))
        n_traces = len(traces)
        assert n_traces > 0
        ret.index = index.add_items(jnp.arange(250, 300), cols=m[:40, 250:300])
        ret.search(dom["test_q"], jax.random.PRNGKey(1))
        ret.index = ret.index.remove_items(jnp.arange(10, 40))
        ret.search(dom["test_q"], jax.random.PRNGKey(2))
        assert len(traces) == n_traces, "index mutation retraced the engine"

    def test_mutation_guards(self, dom):
        m = dom["m"]
        index = AnchorIndex.from_r_anc(m[:40, :250], capacity=260)
        with pytest.raises(ValueError, match="overflows capacity"):
            index.add_items(jnp.arange(250, 300), cols=m[:40, 250:300])
        with pytest.raises(ValueError, match="already in the index"):
            index.add_items(jnp.arange(5), cols=m[:40, :5])
        with pytest.raises(ValueError, match="duplicate item ids"):
            index.add_items(jnp.asarray([250, 250]), cols=m[:40, :2])
        with pytest.raises(ValueError, match="padding sentinel"):
            index.add_items(jnp.asarray([-1]), cols=m[:40, :1])
        latent = index.with_latents(k_anchor=8, key=jax.random.PRNGKey(0))
        anchor_id = int(latent.gather_item_ids(latent.anchor_item_pos)[0])
        with pytest.raises(ValueError, match="anchor item"):
            latent.remove_items(jnp.asarray([anchor_id]))

    def test_remove_items_remaps_anchor_positions(self, dom):
        """Compaction shifts anchor positions; the latents must track them."""
        m = dom["m"]
        index = AnchorIndex.from_r_anc(m[:40]).with_latents(
            anchor_pos=jnp.asarray([200, 250, 299])
        )
        shrunk = index.remove_items(jnp.arange(0, 50))   # non-anchor prefix
        np.testing.assert_array_equal(
            np.asarray(shrunk.anchor_item_pos), np.asarray([150, 200, 249])
        )
        np.testing.assert_array_equal(
            np.asarray(shrunk.gather_item_ids(shrunk.anchor_item_pos)),
            np.asarray([200, 250, 299]),
        )


class TestStaticVsDynamicValidPath:
    def test_unpadded_index_keeps_static_engine_path(self, dom):
        """An unpadded index must not force the runtime n_valid bound (which
        routes the fused TPU kernel through a (B, N) mask) and must match
        the classic bare-r_anc retriever exactly."""
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        ret = AdaCURRetriever.from_index(AnchorIndex.from_r_anc(m[:40]), sf, CFG)
        _, kw = ret._search_operands()
        assert "n_valid" not in kw
        res = ret.search(dom["test_q"], jax.random.PRNGKey(1))
        ref = AdaCURRetriever(sf, m[:40], CFG).search(dom["test_q"], jax.random.PRNGKey(1))
        np.testing.assert_array_equal(
            np.asarray(res.topk_idx), np.asarray(ref.topk_idx)
        )

    def test_padded_index_uses_dynamic_bound(self, dom):
        ret = AdaCURRetriever.from_index(
            AnchorIndex.from_r_anc(dom["m"][:40], capacity=350),
            dom["ce"].score_fn(), CFG,
        )
        _, kw = ret._search_operands()
        assert "n_valid" in kw

    def test_remove_on_unpadded_index_stays_correct(self, dom):
        """Removing from an initially-unpadded index flips it to the dynamic
        path (one retrace) — freed slots must still never be retrieved."""
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        ret = AdaCURRetriever.from_index(AnchorIndex.from_r_anc(m[:40]), sf, CFG)
        ret.search(dom["test_q"], jax.random.PRNGKey(1))
        rm = jnp.arange(0, 50)
        ret.index = ret.index.remove_items(rm)
        res = ret.search(dom["test_q"], jax.random.PRNGKey(2))
        got = np.asarray(ret.index.gather_item_ids(res.topk_idx))
        assert not np.isin(got, np.asarray(rm)).any()
        assert (got >= 0).all()


class TestExternalItemIds:
    def test_engine_maps_positions_to_ids(self, dom):
        """With non-identity item_ids, score_fn sees external ids and the
        returned exact scores match a direct CE call on those ids."""
        ce, m = dom["ce"], dom["m"]
        sf = ce.score_fn()
        ids = jnp.arange(100, 300)        # items 100..299 only, positions 0..199
        index = AnchorIndex.from_r_anc(m[:40, 100:300], item_ids=ids, capacity=220)
        res = AdaCURRetriever.from_index(index, sf, CFG).search(
            dom["test_q"], jax.random.PRNGKey(3)
        )
        ext = index.gather_item_ids(res.topk_idx)
        assert (np.asarray(ext) >= 100).all()
        direct = sf(dom["test_q"], ext)
        np.testing.assert_allclose(
            np.asarray(res.topk_scores), np.asarray(direct), rtol=1e-5, atol=1e-5
        )


class TestShardedTopk:
    def test_single_device_shard_parity(self, dom):
        index = AnchorIndex.from_r_anc(dom["m"][:40], capacity=320)
        mesh = jax.make_mesh((1,), ("data",))
        sharded = index.shard(mesh)
        e_q = jax.random.normal(jax.random.PRNGKey(3), (5, 40))
        v0, i0 = index.topk(e_q, 8, tile=64)
        v1, i1 = sharded.topk(e_q, 8, tile=64)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=1e-5)


class TestANNCURLivesInTheIndex:
    """The deprecated ``core.anncur`` shim module is gone: its offline
    product is ``with_latents`` and its search is ``ANNCURRetriever``."""

    def test_shim_module_removed(self):
        with pytest.raises(ImportError):
            from repro.core import anncur  # noqa: F401

    def test_latents_index_drives_the_engine(self, dom):
        sf = dom["ce"].score_fn()
        index = AnchorIndex.from_r_anc(dom["m"][:40]).with_latents(
            k_anchor=10, key=jax.random.PRNGKey(7)
        )
        assert index.has_latents
        res = ANNCURRetriever.from_index(
            index, sf, budget_ce=20, k_retrieve=10
        ).search(dom["test_q"])
        # retrieved scores are the exact CE scores of the retrieved ids
        ref = jnp.take_along_axis(dom["m"][40:], res.topk_idx, axis=1)
        np.testing.assert_allclose(
            np.asarray(res.topk_scores), np.asarray(ref), rtol=1e-5, atol=1e-5
        )


class TestServiceOverIndex:
    def test_service_from_index_path_and_swap(self, dom, tmp_path):
        from repro.launch.serve import AdaCURService, RetrievalRequest

        ce, m = dom["ce"], dom["m"]
        index = AnchorIndex.from_r_anc(m[:40, :250], capacity=300)
        path = str(tmp_path / "index")
        index.save(path)
        svc = AdaCURService(
            score_fn=ce.score_fn(), cfg=CFG, index=path,
            max_batch=2, max_wait_s=10.0,
        )
        out = []
        for qid in (41, 42):
            out += svc.submit(RetrievalRequest(query_id=qid)) or []
        assert len(out) == 2
        assert all((r.item_ids < 250).all() for r in out)
        # grow the corpus in place: no rebuild, served from the next batch
        svc.swap_index(svc.index.add_items(jnp.arange(250, 300),
                                           cols=m[:40, 250:300]))
        out2 = []
        for qid in (43, 44):
            out2 += svc.submit(RetrievalRequest(query_id=qid)) or []
        assert len(out2) == 2

    def test_default_retriever_ignores_candidate_fn(self, dom):
        """A service built with the default AdaCUR retriever plus a
        candidate_fn must not crash at flush (regression: search() rejected
        the candidate_idx kwarg)."""
        from repro.launch.serve import AdaCURService, RetrievalRequest

        svc = AdaCURService(
            score_fn=dom["ce"].score_fn(), cfg=CFG,
            index=AnchorIndex.from_r_anc(dom["m"][:40]),
            max_batch=1, candidate_fn=lambda qids: jnp.zeros(
                (qids.shape[0], CFG.budget_ce), jnp.int32
            ),
        )
        out = svc.submit(RetrievalRequest(query_id=45))
        assert out and out[0].item_ids.shape == (10,)

    def test_swap_index_requires_index_backed_retriever(self, dom):
        from repro.launch.serve import AdaCURService

        sf = dom["ce"].score_fn()
        svc = AdaCURService(score_fn=sf, r_anc=dom["m"][:40], cfg=CFG,
                            retriever=AdaCURRetriever(sf, dom["m"][:40], CFG))
        with pytest.raises(ValueError, match="index-backed"):
            svc.swap_index(AnchorIndex.from_r_anc(dom["m"][:40]))

    def test_make_retriever_kinds(self, dom):
        from repro.launch.serve import make_retriever

        sf = dom["ce"].score_fn()
        index = AnchorIndex.from_r_anc(dom["m"][:40])
        for kind, cls in (("adacur", AdaCURRetriever),
                          ("anncur", ANNCURRetriever),
                          ("rerank", RerankRetriever)):
            ret = make_retriever(kind, index, sf, CFG)
            assert isinstance(ret, cls)
        with pytest.raises(ValueError, match="unknown retriever"):
            make_retriever("bm25", index, sf, CFG)


class TestTokenTable:
    """The corpus token table (device-resident CE): positional lockstep with
    the payload through every mutation, v3 save/load round-trip, and the
    lowest-version stamping that keeps older readers working."""

    def _tokens(self, n, item_len=6):
        return (np.arange(n * item_len, dtype=np.int32).reshape(n, item_len)
                % 97) + 3

    def test_attach_pads_to_capacity(self, dom):
        index = AnchorIndex.from_r_anc(dom["m"][:40, :250],
                                       item_ids=jnp.arange(250), capacity=300)
        tok = self._tokens(250)
        with_tok = index.with_item_tokens(tok)
        assert with_tok.item_tokens.shape == (300, 6)
        np.testing.assert_array_equal(np.asarray(with_tok.item_tokens[:250]), tok)
        np.testing.assert_array_equal(
            np.asarray(with_tok.item_tokens[250:]), np.zeros((50, 6), np.int32)
        )
        # full-capacity tables attach as-is; other row counts are rejected
        assert index.with_item_tokens(
            self._tokens(300)
        ).item_tokens.shape == (300, 6)
        with pytest.raises(ValueError, match="rows"):
            index.with_item_tokens(self._tokens(123))

    def test_mutation_keeps_positional_lockstep(self, dom):
        m = dom["m"]
        tok = self._tokens(250)
        index = AnchorIndex.from_r_anc(
            m[:40, :250], item_ids=jnp.arange(250), capacity=300
        ).with_item_tokens(tok)

        # add_items without tokens must fail loudly, with them it appends
        with pytest.raises(ValueError, match="new_tokens"):
            index.add_items(jnp.arange(250, 260), cols=m[:40, 250:260])
        new_tok = self._tokens(10) + 1
        grown = index.add_items(jnp.arange(250, 260), cols=m[:40, 250:260],
                                new_tokens=new_tok)
        np.testing.assert_array_equal(np.asarray(grown.item_tokens[:250]), tok)
        np.testing.assert_array_equal(np.asarray(grown.item_tokens[250:260]),
                                      new_tok)

        # remove_items compacts the table with the same stable permutation
        # as the payload: position j still tokenizes the item at position j
        shrunk = grown.remove_items(jnp.arange(100, 150))
        ids = np.asarray(shrunk.item_ids)
        table = np.asarray(shrunk.item_tokens)
        full = np.concatenate([tok, new_tok], axis=0)
        for pos in range(int(shrunk.n_items)):
            np.testing.assert_array_equal(table[pos], full[ids[pos]])

        # tokenless index rejects stray new_tokens
        bare = AnchorIndex.from_r_anc(m[:40, :250], capacity=300)
        with pytest.raises(ValueError, match="no token table"):
            bare.add_items(jnp.arange(250, 260), cols=m[:40, 250:260],
                           new_tokens=new_tok)

    def test_save_load_round_trip_and_version(self, dom, tmp_path):
        m = dom["m"]
        base = AnchorIndex.from_r_anc(m[:40, :250], capacity=300)

        # a token-carrying index stamps v3 and round-trips the table
        path3 = str(tmp_path / "v3")
        base.with_item_tokens(self._tokens(250)).save(path3)
        with open(os.path.join(path3, "index_meta.json")) as f:
            assert json.load(f)["format_version"] == 3
        loaded = AnchorIndex.load(path3)
        np.testing.assert_array_equal(
            np.asarray(loaded.item_tokens),
            np.asarray(base.with_item_tokens(self._tokens(250)).item_tokens),
        )

        # feature-gated stamping: plain fp32 stays v1, quantized-only v2
        path1 = str(tmp_path / "v1")
        base.save(path1)
        with open(os.path.join(path1, "index_meta.json")) as f:
            assert json.load(f)["format_version"] == 1
        assert AnchorIndex.load(path1).item_tokens is None
        path2 = str(tmp_path / "v2")
        base.quantize("int8", tile=50).save(path2)
        with open(os.path.join(path2, "index_meta.json")) as f:
            assert json.load(f)["format_version"] == 2

    def test_capacity_repad_preserves_table(self, dom):
        index = AnchorIndex.from_r_anc(
            dom["m"][:40, :250], capacity=300
        ).with_item_tokens(self._tokens(250))
        wide = index.with_capacity(384)
        assert wide.item_tokens.shape == (384, 6)
        np.testing.assert_array_equal(
            np.asarray(wide.item_tokens[:250]), self._tokens(250)
        )
        np.testing.assert_array_equal(
            np.asarray(wide.item_tokens[250:]), 0
        )

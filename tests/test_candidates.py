"""Multi-stage hybrid retrieval: first-stage generators + candidate-subset
engine search (repro.core.candidates).

The load-bearing contract is **subset == masked, bitwise**: searching a
gathered sub-index under ``pos_map`` must produce exactly the numbers the
full-corpus engine produces under the candidate-union ``eligible`` mask —
same noise realization (the blocked noise field is evaluated at the
original corpus coordinates), same tie-breaks (the sorted-ascending
position map preserves ascending-id order), same dequantization (int8
subset columns keep their codes and carry per-column source-tile scales).
Plus: varying candidate sets never retrace, first-stage spend is measured,
and the engine's CE accounting is untouched by candidate restriction.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig
from repro.core.candidates import (
    BM25Candidates,
    CandidateGenerator,
    DualEncoderCandidates,
    HybridRetriever,
    OracleCandidates,
    candidate_eligibility,
    union_candidates,
)
from repro.core.engine import engine_search
from repro.core.index import AnchorIndex
from repro.core.scorer import TabulatedScorer
from repro.data.synthetic import (
    lexical_signatures,
    make_synthetic_ce,
    make_zeshel_like,
)
from repro.kernels.approx_topk import quant

N_ANCHOR_Q, N_TEST_Q, N_ITEMS = 48, 6, 384  # k_q=48 >= every k_anchor here


@pytest.fixture(scope="module")
def dom():
    ce = make_synthetic_ce(
        jax.random.PRNGKey(0), n_queries=N_ANCHOR_Q + N_TEST_Q,
        n_items=N_ITEMS,
    )
    m = np.asarray(ce.full_matrix(jnp.arange(N_ANCHOR_Q + N_TEST_Q)))
    noisy = jnp.asarray(m) + 1.2 * jax.random.normal(
        jax.random.PRNGKey(9), m.shape
    )
    return {
        "ce": ce,
        "m": m,
        "r_anc": jnp.asarray(m[:N_ANCHOR_Q]),
        "test_q": jnp.arange(N_ANCHOR_Q, N_ANCHOR_Q + N_TEST_Q),
        "exact": jnp.asarray(m[N_ANCHOR_Q:]),
        # imperfect first stage: noisy-exact candidate ordering per query
        "cand_order": jax.lax.top_k(noisy, N_ITEMS)[1],
    }


class TestGenerators:
    def test_dual_encoder_matches_exact_dot_topk(self, dom):
        ce = dom["ce"]
        de = DualEncoderCandidates(ce.q_emb, ce.i_emb, tile=128)
        assert isinstance(de, CandidateGenerator)
        got = de(dom["test_q"], 16)
        ref = jax.lax.top_k(ce.q_emb[dom["test_q"]] @ ce.i_emb.T, 16)[1]
        assert np.array_equal(np.asarray(got), np.asarray(ref))
        assert de.stats.requests == 1
        assert de.stats.candidates == N_TEST_Q * 16

    def test_oracle_is_exact_topk(self, dom):
        orc = OracleCandidates(dom["exact"])
        got = orc(jnp.arange(N_TEST_Q), 8)
        ref = jax.lax.top_k(dom["exact"], 8)[1]
        assert np.array_equal(np.asarray(got), np.asarray(ref))

    def test_bm25_deterministic_counted_and_finds_gold(self):
        ds = make_zeshel_like(0, n_items=256, n_queries=24)
        bm = BM25Candidates(ds.item_tokens, ds.query_tokens)
        q = jnp.arange(12)
        a = np.asarray(bm(q, 10))
        # runtime-counted through jit, like TabulatedScorer
        b = np.asarray(jax.jit(lambda qq: bm(qq, 10))(q))
        jax.effects_barrier()
        assert np.array_equal(a, b)
        assert bm.stats.requests == 2 and bm.stats.candidates == 240
        # token overlap with the gold description must rank it highly
        hit = np.mean([ds.gold[i] in a[i] for i in range(12)])
        assert hit >= 0.75, f"BM25 gold-in-10 rate {hit}"

    def test_bm25_over_lexicalized_embeddings(self, dom):
        ce = dom["ce"]
        bm = BM25Candidates(
            lexical_signatures(ce.i_emb, seed=3),
            lexical_signatures(ce.q_emb, seed=3),
        )
        cand = np.asarray(bm(dom["test_q"], 32))
        # LSH tokens: cosine-similar rows share terms, so the DE top-1 item
        # should usually appear in the BM25 shortlist
        de_top = np.asarray(
            jax.lax.top_k(ce.q_emb[dom["test_q"]] @ ce.i_emb.T, 1)[1]
        )[:, 0]
        hit = np.mean([de_top[i] in cand[i] for i in range(N_TEST_Q)])
        assert hit >= 0.5, f"lexicalized BM25 missed DE-top1 too often ({hit})"


class TestUnionAndSubset:
    def test_union_sorted_padded_deduped(self):
        cand = jnp.array([[3, 1, 1, 100], [7, 3, 2, 200]])
        pos, valid, n_sub = union_candidates(cand, 8, 256)
        assert list(np.asarray(pos)) == [1, 2, 3, 7, 100, 200, 0, 0]
        assert int(n_sub) == 6
        assert list(np.asarray(valid)) == [True] * 6 + [False] * 2

    def test_union_drops_out_of_corpus_positions(self):
        cand = jnp.array([[3, 1, 256, 300]])
        pos, valid, n_sub = union_candidates(cand, 4, 256)
        assert int(n_sub) == 2
        assert list(np.asarray(pos))[:2] == [1, 3]

    def test_eligibility_scatter(self):
        cand = jnp.array([[3, 1], [7, 300]])
        el = candidate_eligibility(cand, 256, per_query=True)
        assert el.shape == (2, 256)
        assert bool(el[0, 1]) and bool(el[0, 3]) and not bool(el[0, 7])
        assert bool(el[1, 7]) and int(el.sum()) == 3  # 300 dropped
        un = candidate_eligibility(cand, 256, per_query=False)
        assert int(un.sum()) == 3

    def test_subset_columns_int8_bitwise_dequant(self, dom):
        """Gathered int8 columns keep their codes and source-tile scales:
        dequantizing the subset payload reproduces the full payload's
        dequantization at those columns EXACTLY."""
        payload = quant.as_payload(dom["r_anc"], "int8", tile=64)
        pos = jnp.array([0, 5, 63, 64, 130, 383], jnp.int32)
        valid = jnp.array([True] * 5 + [False])
        sub = quant.subset_columns(payload, pos, valid)
        assert sub.tile == 1 and sub.codes.shape == (N_ANCHOR_Q, 6)
        full_deq = np.asarray(quant.dequantize(payload))
        sub_deq = np.asarray(quant.dequantize(sub))
        assert np.array_equal(sub_deq[:, :5], full_deq[:, np.asarray(pos)[:5]])
        assert np.all(sub_deq[:, 5] == 0.0)  # padded column exactly zero

    def test_subset_columns_fp32(self, dom):
        pos = jnp.array([2, 9, 100], jnp.int32)
        valid = jnp.array([True, True, False])
        sub = np.asarray(quant.subset_columns(dom["r_anc"], pos, valid))
        assert np.array_equal(sub[:, :2], np.asarray(dom["r_anc"])[:, [2, 9]])
        assert np.all(sub[:, 2] == 0.0)


SUBSET_CONFIGS = [
    ("unrolled", "topk", "float32", False),
    ("fori", "topk", "int8", False),
    ("fori", "softmax", "float32", True),
    ("early", "random", "int8", True),
    ("early", "topk", "float32", True),
    ("fori", "random", "float32", False),
]


class TestSubsetVsMaskedBitParity:
    @pytest.mark.parametrize("mode,strat,payload,fused", SUBSET_CONFIGS)
    def test_subset_equals_masked(self, dom, mode, strat, payload, fused):
        """engine_search over the gathered sub-index (pos_map) is bitwise
        equal to the full-corpus search under the candidate-union eligible
        mask — same top-k ids/scores, same anchors, same rounds."""
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=40, k_retrieve=10,
            strategy=strat, payload_dtype=payload, payload_tile=64,
            use_fused_topk=fused, fused_tile=128,
            loop_mode="unrolled" if mode == "unrolled" else "fori",
            early_exit_tol=0.4 if mode == "early" else 0.0,
        )
        payload_op = quant.as_payload(dom["r_anc"], payload, 64)
        cand = dom["cand_order"][N_ANCHOR_Q:, :64]
        capacity = 256
        pos, valid, n_sub = union_candidates(cand, capacity, N_ITEMS)
        sub = quant.subset_columns(payload_op, pos, valid)
        sub_ids = jnp.where(valid, pos, -1)
        key = jax.random.PRNGKey(21)
        kw = {} if mode == "unrolled" else dict(
            n_rounds=jnp.asarray(cfg.n_rounds, jnp.int32)
        )
        rs = engine_search(
            TabulatedScorer(dom["m"]), sub, dom["test_q"], cfg, key,
            n_valid_items=n_sub, item_ids=sub_ids, pos_map=pos,
            return_scores=False, **kw,
        )
        elig = candidate_eligibility(cand, N_ITEMS, per_query=False)
        rm = engine_search(
            TabulatedScorer(dom["m"]), payload_op, dom["test_q"], cfg, key,
            eligible=elig, return_scores=False, **kw,
        )
        pos_np = np.asarray(pos)
        assert np.array_equal(pos_np[np.asarray(rs.topk_idx)],
                              np.asarray(rm.topk_idx))
        assert np.array_equal(np.asarray(rs.topk_scores),
                              np.asarray(rm.topk_scores))
        a_s, a_m = np.asarray(rs.anchor_idx), np.asarray(rm.anchor_idx)
        assert np.array_equal(np.where(a_s >= 0, pos_np[a_s], -1),
                              np.where(a_m >= 0, a_m, -1))
        assert np.array_equal(np.asarray(rs.anchor_scores),
                              np.asarray(rm.anchor_scores))
        assert int(rs.rounds_done) == int(rm.rounds_done)


class TestHybridRetriever:
    def _cfg(self, **kw):
        base = dict(k_anchor=16, n_rounds=4, budget_ce=40, k_retrieve=10,
                    strategy="topk", loop_mode="fori")
        base.update(kw)
        return AdaCURConfig(**base)

    def test_validation(self, dom):
        orc = OracleCandidates(jnp.asarray(dom["m"]))
        with pytest.raises(ValueError, match="shortlist_k"):
            HybridRetriever(score_fn=TabulatedScorer(dom["m"]), generator=orc,
                            cfg=self._cfg(), r_anc=dom["r_anc"],
                            shortlist_k=8)
        with pytest.raises(ValueError, match="unknown mode"):
            HybridRetriever(score_fn=TabulatedScorer(dom["m"]), generator=orc,
                            cfg=self._cfg(), r_anc=dom["r_anc"],
                            shortlist_k=64, mode="nope")

    @pytest.mark.parametrize("mode", ["subset", "mask"])
    def test_retrieved_subset_of_candidates(self, dom, mode):
        orc = OracleCandidates(jnp.asarray(dom["m"]))
        hyb = HybridRetriever(
            score_fn=TabulatedScorer(dom["m"]), generator=orc,
            cfg=self._cfg(), r_anc=dom["r_anc"], shortlist_k=64, mode=mode,
        )
        res = hyb.search(dom["test_q"], jax.random.PRNGKey(5))
        cand = np.asarray(orc(dom["test_q"], 64))
        union = set(cand.ravel().tolist())
        for r, row in enumerate(np.asarray(res.topk_idx)):
            allowed = union if mode == "subset" else set(cand[r].tolist())
            assert set(int(i) for i in row) <= allowed, f"row {r} leaked"

    def test_zero_retrace_across_candidate_sets(self, dom):
        """Different query batches propose different candidate sets; the
        union/gather/search pipeline stays ONE compiled executable."""
        orc = OracleCandidates(jnp.asarray(dom["m"]))
        hyb = HybridRetriever(
            score_fn=TabulatedScorer(dom["m"]), generator=orc,
            cfg=self._cfg(), r_anc=dom["r_anc"], shortlist_k=64,
        )
        hyb.search(jnp.arange(N_TEST_Q), jax.random.PRNGKey(0))
        sizes = [hyb._run._cache_size()]
        for lo in (6, 17, 30):
            hyb.search(jnp.arange(lo, lo + N_TEST_Q), jax.random.PRNGKey(lo))
            sizes.append(hyb._run._cache_size())
        assert sizes == [1, 1, 1, 1], f"retraced: {sizes}"

    def test_measured_equals_planned_and_first_stage_is_free(self, dom):
        scorer = TabulatedScorer(dom["m"])
        orc = OracleCandidates(jnp.asarray(dom["m"]))
        hyb = HybridRetriever(
            score_fn=scorer, generator=orc, cfg=self._cfg(),
            r_anc=dom["r_anc"], shortlist_k=64,
        )
        jax.block_until_ready(hyb.search(dom["test_q"], jax.random.PRNGKey(2)))
        jax.effects_barrier()
        assert scorer.stats.ce_calls == hyb.ce_call_plan() * N_TEST_Q
        assert orc.stats.candidates == N_TEST_Q * 64  # generator spend: 0 CE

    def test_no_pair_scored_twice_under_first_stage(self, dom):
        scorer = TabulatedScorer(dom["m"], record_pairs=True)
        orc = OracleCandidates(jnp.asarray(dom["m"]))
        hyb = HybridRetriever(
            score_fn=scorer, generator=orc, cfg=self._cfg(),
            r_anc=dom["r_anc"], shortlist_k=64, mode="mask",
        )
        jax.block_until_ready(hyb.search(dom["test_q"], jax.random.PRNGKey(4)))
        jax.effects_barrier()
        rows = {}
        for qids, idx in scorer.call_log:
            for r in range(idx.shape[0]):
                rows.setdefault(r, []).extend(
                    (int(qids[r]), int(i)) for i in idx[r]
                )
        for r, pairs in rows.items():
            assert len(pairs) == len(set(pairs)), f"row {r}: pair scored twice"

    def test_index_backed_subset_maps_item_ids(self, dom):
        """Over a padded AnchorIndex, subset results come back in corpus
        positions whose external ids the index resolves — identical to the
        masked index-backed search."""
        index = AnchorIndex.from_r_anc(
            dom["m"][:N_ANCHOR_Q], capacity=N_ITEMS + 128
        )
        orc = OracleCandidates(jnp.asarray(dom["m"]), n_valid=N_ITEMS)
        cfg = self._cfg()
        hyb = HybridRetriever(
            score_fn=TabulatedScorer(dom["m"]), generator=orc, cfg=cfg,
            index=index, shortlist_k=64,
        )
        res = hyb.search(dom["test_q"], jax.random.PRNGKey(6))
        ids = np.asarray(index.gather_item_ids(res.topk_idx))
        assert (ids >= 0).all() and (ids < N_ITEMS).all()
        # parity with the masked search over the same index
        hyb_m = HybridRetriever(
            score_fn=TabulatedScorer(dom["m"]), generator=orc, cfg=cfg,
            index=index, shortlist_k=64, mode="mask",
        )
        cand = orc(dom["test_q"], 64)
        elig = candidate_eligibility(cand, index.capacity, per_query=False)
        ref = hyb_m._run(
            index.r_anc, dom["test_q"], jax.random.PRNGKey(6),
            eligible=elig, item_ids=index.item_ids, n_valid=index.n_valid,
        )
        assert np.array_equal(np.asarray(res.topk_idx), np.asarray(ref.topk_idx))
        assert np.array_equal(
            np.asarray(res.topk_scores), np.asarray(ref.topk_scores)
        )

    def test_sharded_engine_rejects_pos_map(self, dom):
        from repro.core.engine import make_sharded_engine

        mesh = jax.make_mesh((1,), ("items",))
        srun = make_sharded_engine(
            TabulatedScorer(dom["m"]), self._cfg(), mesh
        )
        with pytest.raises(ValueError, match="single-shard"):
            srun(dom["r_anc"], dom["test_q"], jax.random.PRNGKey(0),
                 pos_map=jnp.arange(N_ITEMS))

"""Multi-device integration test: spawns a subprocess with 8 forced host
devices (jax locks the device count at init) and asserts all distributed
execution paths match their single-device references numerically — see
tests/multidevice_check.py for the checks."""

import os
import subprocess
import sys

import pytest


@pytest.mark.timeout(600)
def test_multidevice_equivalences():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "tests", "multidevice_check.py")],
        env=env, capture_output=True, text=True, timeout=560,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "ALL MULTIDEVICE CHECKS PASSED" in proc.stdout

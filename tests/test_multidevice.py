"""Multi-device numerical equivalence checks — TIER-1, parametrized.

Every distributed execution path must produce the same numbers as its
single-device reference.  jax locks the device count at backend init, so
the main pytest process (which must see the one real CPU device — see
conftest.py) cannot host these: each parametrized case re-executes THIS
FILE as a subprocess whose environment — built by the ``forced_devices``
fixture — pins ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Checks (the ``CHECKS`` registry; run one directly with
``XLA_FLAGS=--xla_force_host_platform_device_count=8 python
tests/test_multidevice.py <name>``):

- sequence-parallel decode attention (LSE combine) == local decode core
- expert-parallel MoE (shard_map)                  == local MoE
- channel-TP receiver-partitioned GNN interact     == local interact
- pipeline_forward (GPipe over an axis)            == plain stage chain
- int8 hierarchical cross-pod grad reduce: mean parity + error feedback
- AnchorIndex.shard(mesh) top-k (fused per-shard + cross-shard merge)
  == the unsharded index, fp32 and int8 co-sharded payloads
- the FULL SPMD engine (engine.make_sharded_engine) on a (data x items)
  mesh: bit-identical top-k vs the single-device engine across loop modes
  x payload dtypes x a mutated padded-capacity index; the persistent
  round kernel + int4/fp8 payloads on a 2x2 mesh (bit-equal to BOTH the
  single-device persistent engine and the sharded staged engine,
  including the software-pipelined monitored loop); the property-suite
  invariants (no pair CE-scored twice, measured == planned calls) under a
  2x2 mesh; zero retraces across runtime n_rounds; first-stage candidate
  restriction (a per-query ``eligible`` mask sharded over the mesh ==
  the single-device masked engine, bit-identical); and a golden snapshot
  (tests/golden/engine_sharded.json, regenerate with GOLDEN_REGEN=1).
"""

import json
import os
import subprocess
import sys

import pytest

_THIS = os.path.abspath(__file__)
_ROOT = os.path.dirname(os.path.dirname(_THIS))
GOLDEN_SHARDED = os.path.join(os.path.dirname(_THIS), "golden", "engine_sharded.json")

TOL = dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# check implementations — run ONLY in the 8-device subprocess
# ---------------------------------------------------------------------------


def check_decode_attention():
    import jax, jax.numpy as jnp, numpy as np

    from repro.distributed import decode_attention
    from repro.models import transformer

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    b, s, kv, h, hd = 4, 64, 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    q = jax.random.normal(ks[0], (b, h, hd))
    k_new = jax.random.normal(ks[1], (b, kv, hd))
    v_new = jax.random.normal(ks[2], (b, kv, hd))
    ck = jax.random.normal(ks[3], (b, s, kv, hd))
    cv = jax.random.normal(ks[4], (b, s, kv, hd))
    pos = jnp.int32(37)

    ref_o, ref_ck, ref_cv = transformer._local_decode_core(q, k_new, v_new, ck, cv, pos)
    core = decode_attention.make_decode_core(mesh, ("data",), ("model",), s)
    with mesh:
        o, ck2, cv2 = jax.jit(core)(q, k_new, v_new, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(o), np.asarray(ref_o), **TOL)
    np.testing.assert_allclose(np.asarray(ck2), np.asarray(ref_ck), **TOL)
    np.testing.assert_allclose(np.asarray(cv2), np.asarray(ref_cv), **TOL)

    # seq sharded over BOTH axes (the long_500k layout), batch unsharded
    core2 = decode_attention.make_decode_core(mesh, (), ("data", "model"), s)
    with mesh:
        o2, _, _ = jax.jit(core2)(q, k_new, v_new, ck, cv, pos)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(ref_o), **TOL)


def check_moe_ep():
    import jax, jax.numpy as jnp, numpy as np

    from repro.configs.base import MoEConfig
    from repro.models import layers, moe as moe_lib

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=16)
    params, _ = layers.split_tree(moe_lib.moe_init(jax.random.PRNGKey(0), 12, cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 12))
    y_ref, _ = moe_lib.moe_apply_local(params, x, cfg, capacity_factor=8.0)
    # EP computes the aux loss per data GROUP (GShard's per-group definition):
    # the reference is the mean of per-shard auxes, not the global aux.
    n_dp = mesh.shape["data"]
    aux_ref = np.mean([
        float(moe_lib.moe_apply_local(params, xs, cfg, capacity_factor=8.0)[1])
        for xs in jnp.split(x, n_dp)
    ])
    moe_fn = moe_lib.make_moe_fn(mesh, cfg, ("data",), "model", capacity_factor=8.0)
    with mesh:
        y, aux = jax.jit(moe_fn)(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), **TOL)
    np.testing.assert_allclose(float(aux), aux_ref, rtol=1e-4)


def check_gnn_interact():
    import dataclasses

    import jax, jax.numpy as jnp, numpy as np

    from repro.configs import registry
    from repro.models.gnn import nequip

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(registry.smoke_config("nequip"), d_hidden=8)
    params, _ = nequip.init_nequip(jax.random.PRNGKey(0), cfg)
    h = 8
    n_per, n_shards = 8, mesh.shape["data"]
    n = n_per * n_shards
    e_per = 16
    e = e_per * n_shards
    pos = jax.random.normal(jax.random.PRNGKey(3), (n, 3)) * 2
    # receiver-partitioned edges: shard i's receivers live in its node range
    recv = jnp.concatenate([
        jax.random.randint(jax.random.PRNGKey(10 + i), (e_per,), i * n_per, (i + 1) * n_per)
        for i in range(n_shards)
    ])
    send = jax.random.randint(jax.random.PRNGKey(4), (e,), 0, n)
    feats = {
        "s": jax.random.normal(jax.random.PRNGKey(5), (n, h)),
        "v": jax.random.normal(jax.random.PRNGKey(6), (n, h, 3)) * 0.1,
        "t": jax.random.normal(jax.random.PRNGKey(7), (n, h, 3, 3)) * 0.1,
    }
    rhat, y2, rbf = nequip._edge_geometry(pos, send, recv, cfg)
    lp = params["layers"][0]
    ref = nequip._interact(lp, feats, send, recv, rhat, y2, rbf, n, h)
    interact = nequip.make_sharded_interact(mesh, "data", "model")
    with mesh:
        out = jax.jit(
            lambda *a: interact(*a)
        )(lp, feats, send, recv, rhat, y2, rbf, n, h)
    for k in ("s", "v", "t"):
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]), **TOL)


def check_pipeline():
    import jax, jax.numpy as jnp, numpy as np

    from repro.distributed import pipeline

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    n_stages = mesh.shape["data"]
    d = 6
    keys = jax.random.split(jax.random.PRNGKey(0), n_stages)
    ws = jnp.stack([jax.random.normal(k, (d, d)) / jnp.sqrt(d) for k in keys])

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    x = jax.random.normal(jax.random.PRNGKey(1), (8, d))
    ref = x
    for i in range(n_stages):
        ref = stage_fn(ws[i], ref)
    piped = pipeline.pipeline_forward(mesh, stage_fn, "data", n_microbatches=4)
    with mesh:
        out = jax.jit(piped)(ws, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), **TOL)


def check_cross_pod_reduce():
    """int8 hierarchical cross-pod grad reduce: mean parity + error-feedback
    convergence over repeated steps (multi-pod mesh (2, 2, 2))."""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    g_pod = [
        {"w": jax.random.normal(jax.random.PRNGKey(i), (8, 8))} for i in range(2)
    ]
    true_mean = {"w": (g_pod[0]["w"] + g_pod[1]["w"]) / 2}
    full = {"w": jnp.stack([g_pod[0]["w"], g_pod[1]["w"]])}   # (2, 8, 8)

    # shared-scale int8 reduce (mirrors cross_pod.make_hierarchical_grad_reduce)
    def cross_pod_body(g, e):
        def one(gl, el):
            g32 = gl.astype(jnp.float32) + el
            scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), "pod") / 127.0 + 1e-12
            q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
            q_sum = jax.lax.psum(q.astype(jnp.int32), "pod")
            deq = q_sum.astype(jnp.float32) * scale / 2
            return deq, g32 - q.astype(jnp.float32) * scale
        pairs = jax.tree.map(one, g, e)
        return (
            jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple)),
            jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple)),
        )

    def driver(full_g, err):
        def body(gp, e):
            g = {"w": gp["w"][0]}          # this pod's partial
            out, new_e = cross_pod_body(g, e)
            return out, new_e
        return shard_map(
            body, mesh=mesh,
            in_specs=({"w": P("pod", "data", "model")}, {"w": P("data", "model")}),
            out_specs=({"w": P("data", "model")}, {"w": P("data", "model")}),
            check_vma=False,
        )(full_g, err)

    err = {"w": jnp.zeros((8, 8))}
    total_true = jnp.zeros((8, 8))
    total_comp = jnp.zeros((8, 8))
    with mesh:
        for _ in range(10):
            out, err = jax.jit(driver)(full, err)
            total_true += true_mean["w"]
            total_comp += out["w"]
    rel = float(jnp.abs(total_comp - total_true).max() / jnp.abs(total_true).max())
    assert rel < 0.05, rel


def check_anchor_index_shard():
    """shard(mesh) parity on a legacy ("data", "model") training mesh: the
    fused per-shard top-k + cross-shard merge AND the full engine (which
    auto-binds the SPMD engine with items over BOTH axes, batch replicated)
    must equal the unsharded index."""
    import jax, jax.numpy as jnp, numpy as np

    from repro.configs.base import AdaCURConfig
    from repro.core.engine import AdaCURRetriever
    from repro.core.index import AnchorIndex

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    r = jax.random.normal(jax.random.PRNGKey(0), (24, 1000))
    index = AnchorIndex.from_r_anc(r, capacity=1024)   # padded, n_valid=1000
    sharded = index.shard(mesh)
    det_mesh, det_axes = sharded._item_sharding()
    assert det_axes == ("data", "model"), det_axes
    assert det_mesh is not None

    # the placement must survive mutation (it lives in the NamedSharding)
    mutated = sharded.add_items(jnp.arange(1000, 1010),
                                cols=jnp.zeros((24, 10)))
    assert mutated._item_sharding()[1] == ("data", "model")

    # (a) latent top-k: per-shard fused approx_topk + all-gather merge
    e_q = jax.random.normal(jax.random.PRNGKey(1), (5, 24))
    v0, i0 = index.topk(e_q, 10, tile=128)
    v1, i1 = sharded.topk(e_q, 10, tile=128)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), **TOL)

    # (b) the full multi-round engine over the sharded index (shard_map SPMD)
    def score_fn(q, idx):
        return jnp.take(r, idx, axis=1).mean(axis=0) + 0.01 * q[:, None]

    cfg = AdaCURConfig(k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=10,
                       loop_mode="fori")
    q = jnp.arange(5, dtype=jnp.float32)
    res_h = AdaCURRetriever.from_index(index, score_fn, cfg).search(
        q, jax.random.PRNGKey(2)
    )
    ret_s = AdaCURRetriever.from_index(sharded, score_fn, cfg)
    assert ret_s._sharded
    res_s = ret_s.search(q, jax.random.PRNGKey(2))
    np.testing.assert_array_equal(
        np.asarray(res_h.topk_idx), np.asarray(res_s.topk_idx)
    )
    np.testing.assert_array_equal(
        np.asarray(res_h.topk_scores), np.asarray(res_s.topk_scores)
    )


def check_quantized_index_shard():
    """shard(mesh) on an int8 payload: codes and scales must land co-sharded
    on the item axis (whole quantization tiles per shard), and the sharded
    fused-dequant top-k must match the unsharded quantized index exactly."""
    import jax, jax.numpy as jnp, numpy as np

    from repro.core.index import AnchorIndex
    from repro.kernels.approx_topk.quant import QuantizedRanc

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    tile = 16
    r = jax.random.normal(jax.random.PRNGKey(0), (24, 1000))
    index = AnchorIndex.from_r_anc(r, capacity=1024).quantize("int8", tile=tile)
    sharded = index.shard(mesh)
    assert isinstance(sharded.r_anc, QuantizedRanc)
    assert sharded._item_sharding()[1] == ("data", "model"), (
        sharded._item_sharding()
    )
    # co-sharding: each shard owns whole tiles and exactly their scales
    n_shards = mesh.size
    assert sharded.capacity % (n_shards * tile) == 0
    codes_spec = sharded.r_anc.codes.sharding.spec
    scales_spec = sharded.r_anc.scales.sharding.spec
    assert tuple(codes_spec[1]) == ("data", "model"), codes_spec
    assert tuple(scales_spec[0]) == ("data", "model"), scales_spec

    e_q = jax.random.normal(jax.random.PRNGKey(1), (5, 24))
    v0, i0 = index.topk(e_q, 10, tile=128)
    v1, i1 = sharded.topk(e_q, 10, tile=128)
    np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
    np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), **TOL)

    # mutation keeps the co-sharded placement
    mutated = sharded.add_items(jnp.arange(1000, 1010),
                                cols=jnp.zeros((24, 10)))
    assert mutated._item_sharding()[1] == ("data", "model")


# ---------------------------------------------------------------------------
# the SPMD engine checks (the PR-5 acceptance surface)
# ---------------------------------------------------------------------------


def _engine_domain():
    import jax, jax.numpy as jnp, numpy as np

    from repro.data.synthetic import make_synthetic_ce

    n_aq, n_tq, n = 24, 8, 1024
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=n_aq + n_tq, n_items=n)
    m = np.asarray(ce.full_matrix(jnp.arange(n_aq + n_tq)))
    return m, jnp.asarray(m[:n_aq]), jnp.arange(n_aq, n_aq + n_tq)


def check_engine_spmd_parity():
    """Full engine_search under shard_map on a (data x items) mesh is
    BIT-IDENTICAL to the single-device engine: all three loop modes x
    fp32/int8 payloads, with measured CE calls equal to the plan."""
    import jax, numpy as np

    from repro.configs.base import AdaCURConfig
    from repro.core.engine import ce_call_plan, make_engine, make_sharded_engine
    from repro.core.scorer import TabulatedScorer

    m, r_anc, test_q = _engine_domain()
    mesh = jax.make_mesh((2, 4), ("data", "items"))
    key = jax.random.PRNGKey(11)
    n_tq = test_q.shape[0]
    for mode, strat, payload in [
        ("fori", "topk", "float32"),
        ("fori", "softmax", "float32"),
        ("fori", "random", "int8"),
        ("unrolled", "topk", "int8"),
        ("early", "topk", "float32"),
        ("early", "softmax", "int8"),
    ]:
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=10,
            strategy=strat, use_fused_topk=True, fused_tile=128,
            payload_dtype=payload, payload_tile=128,
            loop_mode="unrolled" if mode == "unrolled" else "fori",
            early_exit_tol=0.3 if mode == "early" else 0.0,
        )
        scorer = TabulatedScorer(m)
        r1 = make_engine(TabulatedScorer(m), cfg)(r_anc, test_q, key)
        r2 = jax.block_until_ready(
            make_sharded_engine(scorer, cfg, mesh)(r_anc, test_q, key)
        )
        label = f"{mode}/{strat}/{payload}"
        np.testing.assert_array_equal(
            np.asarray(r1.topk_idx), np.asarray(r2.topk_idx), err_msg=label
        )
        np.testing.assert_array_equal(
            np.asarray(r1.topk_scores), np.asarray(r2.topk_scores), err_msg=label
        )
        np.testing.assert_array_equal(
            np.asarray(r1.anchor_idx), np.asarray(r2.anchor_idx), err_msg=label
        )
        rounds = int(r2.rounds_done)
        assert rounds == int(r1.rounds_done), label
        assert scorer.stats.ce_calls == ce_call_plan(cfg, rounds) * n_tq, label


def check_engine_spmd_persistent():
    """The persistent round kernel + sub-int8 payloads under the SPMD
    engine on a 2x2 (data x items) mesh: bit-identical to the SAME config
    single-device, and bit-identical to the STAGED sharded engine — the
    fused sweep changes how each shard reads its payload slab, never the
    numbers.  Covers the software-pipelined monitored loop ('early') and
    the packed int4 / fp8 payload tiles."""
    import jax, numpy as np

    from repro.configs.base import AdaCURConfig
    from repro.core.engine import make_engine, make_sharded_engine
    from repro.core.scorer import TabulatedScorer
    from repro.kernels.approx_topk import quant

    m, r_anc, test_q = _engine_domain()
    mesh = jax.make_mesh((2, 2), ("data", "items"))
    key = jax.random.PRNGKey(11)
    cases = [("fori", "int4"), ("early", "int4"), ("early", "float32")]
    if quant.fp8_supported():
        cases.append(("fori", "fp8"))
    for mode, payload in cases:
        base = dict(
            k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=10,
            use_fused_topk=True, fused_tile=128,
            payload_dtype=payload, payload_tile=128, loop_mode="fori",
            early_exit_tol=0.3 if mode == "early" else 0.0,
        )
        cfg = AdaCURConfig(round_kernel="persistent", **base)
        label = f"{mode}/{payload}"
        r1 = make_engine(TabulatedScorer(m), cfg)(r_anc, test_q, key)
        r2 = jax.block_until_ready(
            make_sharded_engine(TabulatedScorer(m), cfg, mesh)(
                r_anc, test_q, key
            )
        )
        r3 = jax.block_until_ready(
            make_sharded_engine(
                TabulatedScorer(m), AdaCURConfig(round_kernel="staged", **base),
                mesh,
            )(r_anc, test_q, key)
        )
        for ref, tag in ((r1, "single-device"), (r3, "sharded-staged")):
            np.testing.assert_array_equal(
                np.asarray(r2.topk_idx), np.asarray(ref.topk_idx),
                err_msg=f"{label} vs {tag}",
            )
            np.testing.assert_array_equal(
                np.asarray(r2.topk_scores), np.asarray(ref.topk_scores),
                err_msg=f"{label} vs {tag}",
            )
            assert int(r2.rounds_done) == int(ref.rounds_done), (label, tag)


def check_engine_spmd_mutated_index():
    """Sharded parity survives the index lifecycle: a padded-capacity index
    mutated by remove_items + add_items serves bit-identical results (and
    identical EXTERNAL ids) through the SPMD engine, fp32 and int8."""
    import jax, jax.numpy as jnp, numpy as np

    from repro.configs.base import AdaCURConfig
    from repro.core.engine import AdaCURRetriever
    from repro.core.index import AnchorIndex
    from repro.core.scorer import TabulatedScorer

    m, r_anc, test_q = _engine_domain()
    mesh = jax.make_mesh((2, 4), ("data", "items"))

    class WrapScorer(TabulatedScorer):
        # external ids >= 5000 map back onto matrix columns
        def _host(self, qids, idx):
            self.stats.ce_calls += int(idx.size)
            return self.matrix[qids[:, None], np.where(idx >= 5000, idx - 5000, idx)]

    for payload in ("float32", "int8"):
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=10,
            use_fused_topk=True, fused_tile=128, loop_mode="fori",
            payload_dtype=payload, payload_tile=128,
        )
        base = AnchorIndex.from_r_anc(r_anc[:, :1000], capacity=1024)
        if payload == "int8":
            base = base.quantize("int8", tile=128)
        cols = jnp.asarray(m[:24, :6])

        def mutate(ix):
            return ix.remove_items(jnp.arange(30, 40)).add_items(
                jnp.arange(5000, 5006), cols=cols
            )

        mut_ref = mutate(base)
        mut_sh = mutate(base.shard(mesh))
        key = jax.random.PRNGKey(3)
        a = AdaCURRetriever.from_index(mut_ref, WrapScorer(m), cfg).search(test_q, key)
        b = AdaCURRetriever.from_index(mut_sh, WrapScorer(m), cfg).search(test_q, key)
        np.testing.assert_array_equal(
            np.asarray(a.topk_idx), np.asarray(b.topk_idx), err_msg=payload
        )
        np.testing.assert_array_equal(
            np.asarray(mut_ref.gather_item_ids(a.topk_idx)),
            np.asarray(mut_sh.gather_item_ids(b.topk_idx)), err_msg=payload,
        )
        np.testing.assert_array_equal(
            np.asarray(a.topk_scores), np.asarray(b.topk_scores), err_msg=payload
        )


def check_engine_spmd_invariants():
    """The property suite's invariants hold under a 2x2 (data x items) mesh:
    no (query, item) pair is CE-scored twice within a search row, measured
    calls equal ce_call_plan exactly (the cond-gated scorer fires once per
    round system-wide), and runtime n_rounds overrides never retrace."""
    import jax

    from repro.configs.base import AdaCURConfig
    from repro.core.engine import ce_call_plan, make_sharded_engine
    from repro.core.scorer import TabulatedScorer

    m, r_anc, test_q = _engine_domain()
    mesh = jax.make_mesh((2, 2), ("data", "items"))
    n_tq = test_q.shape[0]
    for split, strat in [(True, "topk"), (True, "softmax"), (False, "softmax")]:
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32 if split else 16,
            split_budget=split, strategy=strat, round_epsilon=0.25,
            k_retrieve=8, use_fused_topk=True, fused_tile=128, loop_mode="fori",
        )
        scorer = TabulatedScorer(m, record_pairs=True)
        run = make_sharded_engine(scorer, cfg, mesh)
        res = jax.block_until_ready(run(r_anc, test_q, jax.random.PRNGKey(5)))
        rows = {}
        for qids, idx in scorer.call_log:
            for r in range(idx.shape[0]):
                rows.setdefault(int(qids[r]), []).extend(
                    (int(qids[r]), int(i)) for i in idx[r]
                )
        assert len(rows) == n_tq, (split, strat, sorted(rows))
        for qid, pairs in rows.items():
            assert len(pairs) == len(set(pairs)), (
                f"qid {qid}: {len(pairs) - len(set(pairs))} pairs scored twice "
                f"(split={split}, strat={strat})"
            )
        planned = ce_call_plan(cfg, int(res.rounds_done)) * n_tq
        assert scorer.stats.ce_calls == planned, (
            scorer.stats.ce_calls, planned, split, strat
        )

    # zero retraces across runtime n_rounds on the compiled SPMD program
    traces = []
    import jax.numpy as jnp

    def counting(q, idx):
        traces.append(1)
        return jnp.take(jnp.asarray(m), idx, axis=1).mean(0) + 0.01 * q[:, None].astype(jnp.float32)

    cfg = AdaCURConfig(k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=8,
                       use_fused_topk=True, fused_tile=128, loop_mode="fori")
    run = make_sharded_engine(counting, cfg, mesh)
    jax.block_until_ready(run(r_anc, test_q, jax.random.PRNGKey(5), n_rounds=2))
    n0 = len(traces)
    for r in (4, 1, 3):
        jax.block_until_ready(run(r_anc, test_q, jax.random.PRNGKey(5), n_rounds=r))
    assert len(traces) == n0, f"runtime n_rounds retraced: {len(traces)} vs {n0}"


def check_engine_spmd_eligible():
    """First-stage candidate restriction under the SPMD engine: a per-query
    ``eligible`` mask sharded over the (data x items) mesh produces BIT-
    IDENTICAL results to the single-device masked engine, every returned
    item is a candidate, and measured CE calls still equal the plan."""
    import jax, jax.numpy as jnp, numpy as np

    from repro.configs.base import AdaCURConfig
    from repro.core.candidates import candidate_eligibility
    from repro.core.engine import ce_call_plan, make_engine, make_sharded_engine
    from repro.core.scorer import TabulatedScorer

    m, r_anc, test_q = _engine_domain()
    n_items = r_anc.shape[1]
    n_tq = int(test_q.shape[0])
    mesh = jax.make_mesh((2, 2), ("data", "items"))
    key = jax.random.PRNGKey(13)

    # imperfect first stage: noisy exact top-96 per query
    noisy = jnp.asarray(m)[test_q] + 1.5 * jax.random.normal(
        jax.random.PRNGKey(3), (n_tq, n_items)
    )
    cand = jax.lax.top_k(noisy, 96)[1]
    eligible = candidate_eligibility(cand, n_items, per_query=True)

    for strat, payload in [("topk", "float32"), ("random", "int8")]:
        cfg = AdaCURConfig(
            k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=8,
            strategy=strat, use_fused_topk=True, fused_tile=128,
            loop_mode="fori", payload_dtype=payload, payload_tile=128,
        )
        ref = make_engine(TabulatedScorer(m), cfg)(
            r_anc, test_q, key, eligible=eligible
        )
        scorer = TabulatedScorer(m)
        run = make_sharded_engine(scorer, cfg, mesh)
        res = jax.block_until_ready(
            run(r_anc, test_q, key, eligible=eligible)
        )
        for f in ("topk_idx", "topk_scores", "anchor_idx", "anchor_scores"):
            assert np.array_equal(
                np.asarray(getattr(res, f)), np.asarray(getattr(ref, f))
            ), (strat, payload, f)
        cand_sets = [set(int(i) for i in row) for row in np.asarray(cand)]
        for r, row in enumerate(np.asarray(res.topk_idx)):
            assert set(int(i) for i in row) <= cand_sets[r], (
                f"row {r} returned non-candidates ({strat}, {payload})"
            )
        planned = ce_call_plan(cfg, int(res.rounds_done)) * n_tq
        assert scorer.stats.ce_calls == planned, (
            scorer.stats.ce_calls, planned, strat, payload
        )

    # a (N,) batch-union mask shards over the items axis only
    union = candidate_eligibility(cand, n_items, per_query=False)
    cfg = AdaCURConfig(
        k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=8,
        use_fused_topk=True, fused_tile=128, loop_mode="fori",
    )
    ref = make_engine(TabulatedScorer(m), cfg)(r_anc, test_q, key, eligible=union)
    res = make_sharded_engine(TabulatedScorer(m), cfg, mesh)(
        r_anc, test_q, key, eligible=union
    )
    assert np.array_equal(np.asarray(res.topk_idx), np.asarray(ref.topk_idx))
    assert np.array_equal(np.asarray(res.topk_scores), np.asarray(ref.topk_scores))


def check_engine_spmd_golden():
    """Golden regression for one sharded engine config: catches cross-shard
    merge-order / collective regressions by tolerance compare against a
    pinned snapshot.  GOLDEN_REGEN=1 regenerates (sharded == single-device
    bit parity means the snapshot is mesh-independent, but it is always
    RECORDED through the 2x4 sharded program)."""
    import jax, numpy as np

    from repro.configs.base import AdaCURConfig
    from repro.core.engine import make_sharded_engine
    from repro.core.scorer import TabulatedScorer

    m, r_anc, test_q = _engine_domain()
    mesh = jax.make_mesh((2, 4), ("data", "items"))
    cfg = AdaCURConfig(
        k_anchor=16, n_rounds=4, budget_ce=32, k_retrieve=10,
        use_fused_topk=True, fused_tile=128, loop_mode="fori",
        payload_dtype="int8", payload_tile=128,
    )
    res = make_sharded_engine(TabulatedScorer(m), cfg, mesh)(
        r_anc, test_q, jax.random.PRNGKey(11)
    )
    idx = np.asarray(res.topk_idx, dtype=np.int64)
    scores = np.asarray(res.topk_scores, dtype=np.float64)

    if os.environ.get("GOLDEN_REGEN"):
        os.makedirs(os.path.dirname(GOLDEN_SHARDED), exist_ok=True)
        with open(GOLDEN_SHARDED, "w") as f:
            json.dump(
                {"mesh": "2x4", "topk_idx": idx.tolist(),
                 "topk_scores": np.round(scores, 6).tolist()}, f, indent=1,
            )
        print(f"regenerated {GOLDEN_SHARDED}")
        return
    assert os.path.exists(GOLDEN_SHARDED), (
        f"missing golden snapshot {GOLDEN_SHARDED}; run this check with "
        "GOLDEN_REGEN=1"
    )
    with open(GOLDEN_SHARDED) as f:
        snap = json.load(f)
    g_idx = np.asarray(snap["topk_idx"])
    g_scores = np.asarray(snap["topk_scores"])
    np.testing.assert_allclose(scores, g_scores, atol=1e-3, rtol=0)
    same = (idx[:, :, None] == g_idx[:, None, :]).any(-1).mean()
    assert same >= 0.9, f"sharded top-k id overlap {same:.3f} < 0.9"


def check_engine_device_ce():
    """The tentpole acceptance surface: the REAL transformer cross-encoder
    as a device-resident stage of the one shard_map program (DeviceCEScorer)
    on a 2x2 (data x items) mesh — no host callback, no nested launch, no
    psum-rendezvous deadlock.  Exact top-k parity vs the single-device
    exact-matrix search AND the single-device device-resident engine;
    exactly-once system-wide CE accounting with item-shard pad rows
    excluded; zero retraces across runtime n_rounds / n_valid."""
    import jax, jax.numpy as jnp, numpy as np

    from repro.configs import registry
    from repro.configs.base import AdaCURConfig, replace
    from repro.core.engine import ce_call_plan, make_engine, make_sharded_engine
    from repro.core.scorer import (
        CrossEncoderScorer, DeviceCEScorer, TabulatedScorer,
    )
    from repro.data.synthetic import make_zeshel_like
    from repro.models import cross_encoder

    # capacity 256 = 2 item shards x NOISE_BLOCK(128): shardable unpadded
    ds = make_zeshel_like(0, n_items=256, n_queries=24, item_len=12, query_len=8)
    cfg_lm = replace(
        registry.CE_TINY, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=ds.vocab_size, dtype="float32",
        remat=False,
    )
    params, _ = cross_encoder.init_cross_encoder(jax.random.PRNGKey(0), cfg_lm)
    host = CrossEncoderScorer(
        params, cfg_lm, ds.pair_tokens, micro_batch=16, flash_block=(16, 16),
        len_buckets=(32, 64),
    )
    m = np.asarray(host._host(np.arange(24), np.tile(np.arange(256), (24, 1))))

    def device_scorer():
        return DeviceCEScorer(
            params, cfg_lm,
            query_token_fn=lambda q: np.asarray(ds.query_tokens)[q],
            item_tokens=ds.item_tokens, len_buckets=(32, 64),
            flash_block=(16, 16),
        )

    cfg = AdaCURConfig(k_anchor=12, n_rounds=4, budget_ce=24, k_retrieve=10,
                       loop_mode="fori")
    r_anc = jnp.asarray(m[:16])
    q = jnp.arange(16, 22)          # 6 rows -> b_local=3 per data shard
    key = jax.random.PRNGKey(7)

    mesh = jax.make_mesh((2, 2), ("data", "items"))
    sc = device_scorer()
    run = make_sharded_engine(sc, cfg, mesh)
    q_tok = sc.tokenize_queries(q)
    res = jax.block_until_ready(run(r_anc, q_tok, key))

    # (a) exact parity vs the single-device exact-matrix search...
    ref = jax.block_until_ready(
        make_engine(TabulatedScorer(m), cfg)(r_anc, q, key)
    )
    np.testing.assert_array_equal(
        np.asarray(res.topk_idx), np.asarray(ref.topk_idx)
    )
    np.testing.assert_allclose(
        np.asarray(res.topk_scores), np.asarray(ref.topk_scores), **TOL
    )
    # ...and vs the single-device device-resident engine
    sc1 = device_scorer()
    res1 = jax.block_until_ready(
        make_engine(sc1, cfg)(r_anc, sc1.tokenize_queries(q), key)
    )
    np.testing.assert_array_equal(
        np.asarray(res.topk_idx), np.asarray(res1.topk_idx)
    )

    # (b) exactly-once accounting under the mesh: each round's 3x3=9 local
    # pair rows pad to 10 over 2 item shards (batch_pad counts them), yet
    # measured CE calls equal the plan with pad rows excluded
    rounds = int(res.rounds_done)
    planned = ce_call_plan(cfg, rounds) * int(q.shape[0])
    assert sc.stats.ce_calls == planned, (sc.stats.ce_calls, planned)
    assert sc.stats.batch_pad > 0, "expected item-shard pad rows"
    assert sc1.stats.ce_calls == planned, (sc1.stats.ce_calls, planned)

    # (c) zero retraces across runtime n_rounds and corpus n_valid
    n0 = sc.n_traces
    for r in (1, 4, 2):
        jax.block_until_ready(run(r_anc, q_tok, key, n_rounds=r))
    jax.block_until_ready(run(r_anc, q_tok, key, n_valid=192))
    assert sc.n_traces == n0, (sc.n_traces, n0)


CHECKS = {
    "decode_attention": check_decode_attention,
    "moe_ep": check_moe_ep,
    "gnn_interact": check_gnn_interact,
    "pipeline": check_pipeline,
    "cross_pod_reduce": check_cross_pod_reduce,
    "anchor_index_shard": check_anchor_index_shard,
    "quantized_index_shard": check_quantized_index_shard,
    "engine_spmd_parity": check_engine_spmd_parity,
    "engine_spmd_persistent": check_engine_spmd_persistent,
    "engine_spmd_mutated_index": check_engine_spmd_mutated_index,
    "engine_spmd_invariants": check_engine_spmd_invariants,
    "engine_spmd_eligible": check_engine_spmd_eligible,
    "engine_spmd_golden": check_engine_spmd_golden,
    "engine_device_ce": check_engine_device_ce,
}


# ---------------------------------------------------------------------------
# pytest driver (runs in the normal 1-device process)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def forced_devices():
    """Environment for the check subprocesses: 8 forced host devices (the
    flag must be set before jax's backend initializes, hence a fresh
    process), src on PYTHONPATH, GOLDEN_REGEN passed through."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    return env


@pytest.mark.timeout(600)
@pytest.mark.parametrize("check", sorted(CHECKS))
def test_multidevice(check, forced_devices):
    proc = subprocess.run(
        [sys.executable, _THIS, check],
        env=forced_devices, capture_output=True, text=True, timeout=560,
    )
    assert proc.returncode == 0, (
        f"[{check}] failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert f"OK {check}" in proc.stdout


@pytest.mark.timeout(600)
def test_serve_real_ce_mesh():
    """The config this PR un-rejects: ``--scorer real-ce --mesh 2x2`` must
    serve end-to-end through the CLI — index built by the bulk CE path,
    token table sharded with the payload, DeviceCEScorer inside the SPMD
    program — with measured-accounting output and no deadlock."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve",
         "--scorer", "real-ce", "--mesh", "2x2", "--n-items", "128",
         "--requests", "8", "--batch", "8", "--budget", "16", "--rounds", "2"],
        env=env, capture_output=True, text=True, timeout=560, cwd=_ROOT,
    )
    assert proc.returncode == 0, (
        f"serve --scorer real-ce --mesh 2x2 failed\n"
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    )
    assert "served 8 requests" in proc.stdout, proc.stdout
    assert "device-resident CE" in proc.stdout, proc.stdout


if __name__ == "__main__":
    import faulthandler

    name = sys.argv[1] if len(sys.argv) > 1 else None
    names = [name] if name else sorted(CHECKS)
    watchdog_s = float(os.environ.get("MULTIDEVICE_WATCHDOG_S", "480"))
    for n in names:
        # deadlock watchdog: a future collective/callback hang dumps every
        # thread's stack and exits nonzero instead of sitting silent until
        # the outer subprocess timeout kills it with no diagnostics
        faulthandler.dump_traceback_later(watchdog_s, exit=True)
        CHECKS[n]()
        faulthandler.cancel_dump_traceback_later()
        print(f"OK {n}")

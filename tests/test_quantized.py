"""Quantized anchor-payload lifecycle: quantize/dequantize invariants, the
payload-policy wiring (config -> from_index -> engine), save -> load ->
search parity, shard(mesh) codes+scales co-sharding parity, and the
mutation round-trip guarantee (remove_items -> add_items keeps untouched
tiles bit-identical)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig, replace
from repro.core.engine import AdaCURRetriever, ANNCURRetriever, RerankRetriever
from repro.core.index import AnchorIndex
from repro.data.synthetic import make_synthetic_ce
from repro.kernels.approx_topk import quant
from repro.kernels.approx_topk.quant import QuantizedRanc

TILE = 64
CFG = AdaCURConfig(
    k_anchor=20, n_rounds=4, budget_ce=40, k_retrieve=10, loop_mode="fori",
    payload_dtype="int8", payload_tile=TILE,
)


@pytest.fixture(scope="module")
def dom():
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=60, n_items=300)
    m = ce.full_matrix(jnp.arange(60))
    return {
        "ce": ce,
        "m": m,                      # (60, 300) full score matrix
        "q_ids": jnp.arange(40),
        "test_q": jnp.arange(40, 60),
    }


def _codes_scales(idx):
    assert isinstance(idx.r_anc, QuantizedRanc)
    return np.asarray(idx.r_anc.codes), np.asarray(idx.r_anc.scales)


class TestQuantizePrimitives:
    def test_round_trip_error_bound_and_zero_tiles(self):
        r = jax.random.normal(jax.random.PRNGKey(1), (24, 500))
        r = r.at[:, 448:].set(0.0)               # an exactly-zero tail tile
        p = quant.quantize_ranc(r, tile=64)
        deq = quant.dequantize(p)
        # half-lsb error bound, exact zeros stay exact
        assert float(jnp.abs(deq - r).max()) <= float(p.scales.max()) * 0.5 + 1e-7
        np.testing.assert_array_equal(np.asarray(deq[:, 448:]), 0.0)
        assert float(p.scales[-1]) == 1.0        # zero tile stores scale 1.0
        # deterministic: re-quantizing the dequantized payload is a fixpoint
        p2 = quant.quantize_ranc(deq, tile=64)
        np.testing.assert_array_equal(np.asarray(p.codes), np.asarray(p2.codes))

    def test_payload_is_quarter_size(self):
        r = jnp.ones((128, 4096))
        p = quant.quantize_ranc(r, tile=512)
        assert p.nbytes / r.nbytes <= 0.3

    def test_int4_pack_unpack_round_trip(self):
        """Packed-nibble codes: two columns per byte, exact code recovery,
        and the dequantized payload reconstructs within half an int4 lsb."""
        r = jax.random.normal(jax.random.PRNGKey(2), (16, 384))
        p = quant.quantize_ranc(r, tile=64, code_dtype="int4")
        assert p.codes.dtype == jnp.uint8
        assert p.codes.shape == (16, 192)            # two codes per byte
        assert p.shape == (16, 384)
        assert p.nbytes / r.nbytes <= 0.15
        deq = quant.dequantize(p)
        assert float(jnp.abs(deq - r).max()) <= float(p.scales.max()) * 0.5 + 1e-6
        # re-quantizing the reconstruction is a fixpoint of the code grid
        p2 = quant.quantize_ranc(deq, tile=64, code_dtype="int4")
        np.testing.assert_array_equal(np.asarray(p.codes), np.asarray(p2.codes))

    def test_int4_requires_even_tile(self):
        r = jnp.ones((4, 128))
        with pytest.raises(ValueError, match="even tile"):
            quant.quantize_ranc(r, tile=63, code_dtype="int4")

    @pytest.mark.skipif(not quant.fp8_supported(), reason="no float8 in build")
    def test_fp8_round_trip_and_bytes(self):
        r = 3.0 * jax.random.normal(jax.random.PRNGKey(3), (16, 384))
        p = quant.quantize_ranc(r, tile=64, code_dtype="fp8")
        assert p.codes.dtype == jnp.float8_e4m3fn
        assert p.nbytes / r.nbytes <= 0.3
        deq = quant.dequantize(p)
        # fp8-e4m3 carries a 3-bit mantissa: error <= |x| * 2^-4 everywhere
        # the code is normal, plus one subnormal ulp (scale * 2^-9) near 0
        bound = jnp.abs(r) * 2.0 ** -4 + float(p.scales.max()) * 2.0 ** -9 + 1e-7
        assert bool((jnp.abs(deq - r) <= bound).all())

    def test_index_quantize_policy(self, dom):
        idx = AnchorIndex.from_r_anc(dom["m"][:40])
        q = idx.quantize("int8", tile=TILE)
        assert q.payload_dtype == "int8"
        assert q.payload_nbytes < 0.3 * idx.payload_nbytes
        assert q.quantize("int8", tile=TILE) is q        # idempotent
        b = idx.quantize("bfloat16")
        assert b.payload_dtype == "bfloat16"
        back = q.quantize("float32")
        assert back.payload_dtype == "float32"
        np.testing.assert_allclose(
            np.asarray(back.r_anc), np.asarray(quant.dequantize(q.r_anc))
        )


class TestPayloadPolicyWiring:
    def test_from_index_quantizes_once(self, dom):
        sf = dom["ce"].score_fn()
        idx = AnchorIndex.from_r_anc(dom["m"][:40])
        ret = AdaCURRetriever.from_index(idx, sf, CFG)
        assert ret.index.payload_dtype == "int8"
        # an already-quantized index is authoritative (no re-encode)
        ret2 = AdaCURRetriever.from_index(ret.index, sf, CFG)
        assert ret2.index is ret.index

    def test_quantized_index_is_authoritative(self, dom):
        """A policy mismatch never dequantizes an int8 artifact — the
        payload converts UP only, mirroring quant.as_payload."""
        sf = dom["ce"].score_fn()
        idx8 = AnchorIndex.from_r_anc(dom["m"][:40]).quantize("int8", tile=TILE)
        ret = AdaCURRetriever.from_index(
            idx8, sf, replace(CFG, payload_dtype="bfloat16")
        )
        assert ret.index is idx8

    def test_bare_r_anc_matches_prequantized_index(self, dom):
        """In-trace as_payload conversion == offline index quantization."""
        sf = dom["ce"].score_fn()
        key = jax.random.PRNGKey(3)
        res_bare = AdaCURRetriever(sf, dom["m"][:40], CFG).search(dom["test_q"], key)
        res_idx = AdaCURRetriever.from_index(
            AnchorIndex.from_r_anc(dom["m"][:40]), sf, CFG
        ).search(dom["test_q"], key)
        np.testing.assert_array_equal(
            np.asarray(res_bare.topk_idx), np.asarray(res_idx.topk_idx)
        )

    @pytest.mark.parametrize("fused", [False, True])
    def test_fused_vs_dense_under_int8(self, dom, fused):
        """Same payload -> same scores: fused and dense engines agree."""
        sf = dom["ce"].score_fn()
        cfg = replace(CFG, use_fused_topk=fused, fused_tile=128)
        res = AdaCURRetriever.from_index(
            AnchorIndex.from_r_anc(dom["m"][:40]), sf, cfg
        ).search(dom["test_q"], jax.random.PRNGKey(5))
        ref = AdaCURRetriever.from_index(
            AnchorIndex.from_r_anc(dom["m"][:40]), sf,
            replace(cfg, use_fused_topk=not fused),
        ).search(dom["test_q"], jax.random.PRNGKey(5))
        hits = (
            np.asarray(res.topk_idx)[:, :, None]
            == np.asarray(ref.topk_idx)[:, None, :]
        ).any(-1)
        assert hits.mean() >= 0.99

    def test_anncur_and_rerank_over_quantized_index(self, dom):
        sf = dom["ce"].score_fn()
        base = replace(CFG, use_fused_topk=True, fused_tile=128)
        idx = AnchorIndex.from_r_anc(dom["m"][:40]).with_anchors(
            k_anchor=10, key=jax.random.PRNGKey(7)
        )
        res = ANNCURRetriever.from_index(
            idx, sf, budget_ce=20, k_retrieve=10, base_cfg=base
        ).search(dom["test_q"])
        assert (np.asarray(res.topk_idx) >= 0).all()
        order = jnp.tile(jnp.arange(300)[None, :], (20, 1))
        res2 = RerankRetriever.from_index(
            idx, sf, budget_ce=20, k_retrieve=10, base_cfg=base
        ).search(dom["test_q"], candidate_idx=order)
        assert (np.asarray(res2.topk_idx) >= 0).all()

    def test_recall_parity_with_fp32(self, dom):
        """The headline acceptance property at test scale: int8 retrieval
        recall@10 tracks fp32 on the same seeds.  This 20-query domain has
        ~0.05 seed-to-seed recall noise, so the assertion averages three
        seeds with a matching tolerance; the bench asserts the tight 0.005
        bound at N=100k where the query sample is large."""
        from repro.core import retrieval

        sf = dom["ce"].score_fn()
        exact = dom["m"][40:]
        _, gt = retrieval.exact_topk(exact, 10)
        recalls = {"float32": [], "int8": []}
        for dtype, acc in recalls.items():
            cfg = replace(CFG, payload_dtype=dtype)
            ret = AdaCURRetriever.from_index(
                AnchorIndex.from_r_anc(dom["m"][:40]), sf, cfg
            )
            for seed in (11, 12, 13):
                res = ret.search(dom["test_q"], jax.random.PRNGKey(seed))
                acc.append(float(retrieval.topk_recall(res.topk_idx, gt, 10)))
        gap = abs(np.mean(recalls["int8"]) - np.mean(recalls["float32"]))
        assert gap <= 0.05, recalls


class TestQuantizedPersistence:
    def test_save_load_search_parity(self, dom, tmp_path):
        sf = dom["ce"].score_fn()
        index = AnchorIndex.from_r_anc(dom["m"][:40], capacity=320).quantize(
            "int8", tile=TILE
        )
        path = str(tmp_path / "qindex")
        index.save(path)
        meta = json.load(open(os.path.join(path, "index_meta.json")))
        assert meta["format_version"] == 2       # int8 keeps the v2 layout
        assert meta["payload"] == {
            "dtype": "int8", "tile": TILE, "code_dtype": "int8", "n_cols": -1,
        }
        loaded = AnchorIndex.load(path)
        assert loaded.payload_dtype == "int8"
        c0, s0 = _codes_scales(index)
        c1, s1 = _codes_scales(loaded)
        np.testing.assert_array_equal(c0, c1)
        np.testing.assert_array_equal(s0, s1)
        key = jax.random.PRNGKey(1)
        res_m = AdaCURRetriever.from_index(index, sf, CFG).search(dom["test_q"], key)
        res_l = AdaCURRetriever.from_index(loaded, sf, CFG).search(dom["test_q"], key)
        np.testing.assert_array_equal(
            np.asarray(res_m.topk_idx), np.asarray(res_l.topk_idx)
        )
        np.testing.assert_array_equal(
            np.asarray(res_m.topk_scores), np.asarray(res_l.topk_scores)
        )

    @pytest.mark.parametrize("dtype", ["int4", "fp8"])
    def test_v4_sub_int8_save_load_round_trip(self, dom, tmp_path, dtype):
        """Sub-int8 payloads stamp format v4, record code_dtype/n_cols in
        the meta, and round-trip codes+scales and search results exactly."""
        if dtype == "fp8" and not quant.fp8_supported():
            pytest.skip("no float8 in build")
        sf = dom["ce"].score_fn()
        index = AnchorIndex.from_r_anc(dom["m"][:40], capacity=320).quantize(
            dtype, tile=TILE
        )
        path = str(tmp_path / f"{dtype}index")
        index.save(path)
        meta = json.load(open(os.path.join(path, "index_meta.json")))
        assert meta["format_version"] == 4
        assert meta["payload"]["code_dtype"] == dtype
        assert meta["payload"]["tile"] == TILE
        loaded = AnchorIndex.load(path)
        assert loaded.payload_dtype == dtype
        c0, s0 = _codes_scales(index)
        c1, s1 = _codes_scales(loaded)
        # all code dtypes here are 1-byte; compare raw bits (fp8 included)
        np.testing.assert_array_equal(c0.view(np.uint8), c1.view(np.uint8))
        np.testing.assert_array_equal(s0, s1)
        key = jax.random.PRNGKey(1)
        cfg = replace(CFG, payload_dtype=dtype)
        res_m = AdaCURRetriever.from_index(index, sf, cfg).search(dom["test_q"], key)
        res_l = AdaCURRetriever.from_index(loaded, sf, cfg).search(dom["test_q"], key)
        np.testing.assert_array_equal(
            np.asarray(res_m.topk_idx), np.asarray(res_l.topk_idx)
        )
        np.testing.assert_array_equal(
            np.asarray(res_m.topk_scores), np.asarray(res_l.topk_scores)
        )

    def test_v1_artifacts_still_load(self, dom, tmp_path):
        index = AnchorIndex.from_r_anc(dom["m"][:40])
        path = str(tmp_path / "v1index")
        index.save(path)
        meta_path = os.path.join(path, "index_meta.json")
        meta = json.load(open(meta_path))
        meta["format_version"] = 1
        del meta["payload"]
        with open(meta_path, "w") as f:
            json.dump(meta, f)
        loaded = AnchorIndex.load(path)
        np.testing.assert_array_equal(
            np.asarray(loaded.r_anc), np.asarray(index.r_anc)
        )

    def test_quantized_latents_save_load(self, dom, tmp_path):
        index = (
            AnchorIndex.from_r_anc(dom["m"][:40])
            .quantize("int8", tile=TILE)
            .with_latents(k_anchor=8, key=jax.random.PRNGKey(2))
        )
        path = str(tmp_path / "qlat")
        index.save(path)
        loaded = AnchorIndex.load(path)
        np.testing.assert_array_equal(np.asarray(index.u), np.asarray(loaded.u))
        np.testing.assert_array_equal(
            np.asarray(index.item_embeddings), np.asarray(loaded.item_embeddings)
        )


class TestQuantizedSharding:
    def test_codes_scales_cosharded_topk_parity(self, dom):
        index = AnchorIndex.from_r_anc(dom["m"][:40], capacity=320).quantize(
            "int8", tile=TILE
        )
        mesh = jax.make_mesh((1,), ("data",))
        sharded = index.shard(mesh)
        assert isinstance(sharded.r_anc, QuantizedRanc)
        # codes and scales carry matching item-axis placements (a 1-device
        # mesh reads back as unsharded; the real multi-shard co-sharding
        # parity runs in tests/multidevice_check.py with 8 host devices)
        codes_spec = sharded.r_anc.codes.sharding.spec
        scales_spec = sharded.r_anc.scales.sharding.spec
        assert codes_spec[0] is None and tuple(codes_spec[1]) == ("data",)
        assert tuple(scales_spec[0]) == ("data",)
        e_q = jax.random.normal(jax.random.PRNGKey(3), (5, 40))
        v0, i0 = index.topk(e_q, 8, tile=TILE)
        v1, i1 = sharded.topk(e_q, 8, tile=TILE)
        np.testing.assert_array_equal(np.asarray(i0), np.asarray(i1))
        np.testing.assert_allclose(np.asarray(v0), np.asarray(v1), rtol=1e-5)

    def test_shard_aligns_capacity_to_whole_tiles(self, dom):
        index = AnchorIndex.from_r_anc(dom["m"][:40]).quantize("int8", tile=TILE)
        mesh = jax.make_mesh((1,), ("data",))
        sharded = index.shard(mesh)                 # 300 -> 320 (5 tiles)
        assert sharded.capacity % TILE == 0
        assert sharded.r_anc.scales.shape[0] == sharded.capacity // TILE
        assert sharded.n_items == 300


class TestQuantizedMutation:
    def test_remove_add_round_trip_untouched_tiles_bit_identical(self, dom):
        m = dom["m"]
        index = AnchorIndex.from_r_anc(m[:40], capacity=320).quantize(
            "int8", tile=TILE
        )
        c0, s0 = _codes_scales(index)
        # remove the last 40 valid items (touched tiles start at col 260)
        shrunk = index.remove_items(jnp.arange(260, 300))
        c1, s1 = _codes_scales(shrunk)
        t0 = 260 // TILE                       # first touched tile
        np.testing.assert_array_equal(c1[:, : t0 * TILE], c0[:, : t0 * TILE])
        np.testing.assert_array_equal(s1[:t0], s0[:t0])
        # add them back: prefix tiles stay bit-identical through the cycle
        grown = shrunk.add_items(jnp.arange(260, 300), cols=m[:40, 260:300])
        c2, s2 = _codes_scales(grown)
        np.testing.assert_array_equal(c2[:, : t0 * TILE], c0[:, : t0 * TILE])
        np.testing.assert_array_equal(s2[:t0], s0[:t0])
        assert grown.n_items == 300
        np.testing.assert_array_equal(
            np.asarray(grown.item_ids), np.asarray(index.item_ids)
        )

    @pytest.mark.parametrize("dtype", ["int4", "fp8"])
    def test_sub_int8_remove_add_round_trip_bit_identical(self, dom, dtype):
        """The tile-local requantization contract holds below int8: a
        remove -> add cycle leaves every untouched tile's packed codes and
        scales bit-identical, and restores the touched region within the
        code grid's error bound."""
        if dtype == "fp8" and not quant.fp8_supported():
            pytest.skip("no float8 in build")
        m = dom["m"]
        index = AnchorIndex.from_r_anc(m[:40], capacity=320).quantize(
            dtype, tile=TILE
        )
        c0, s0 = _codes_scales(index)
        shrunk = index.remove_items(jnp.arange(260, 300))
        grown = shrunk.add_items(jnp.arange(260, 300), cols=m[:40, 260:300])
        c2, s2 = _codes_scales(grown)
        t0 = 260 // TILE                         # first touched tile
        kc = t0 * TILE // index.r_anc.packing    # prefix width in code cols
        np.testing.assert_array_equal(
            c2.view(np.uint8)[:, :kc], c0.view(np.uint8)[:, :kc]
        )
        np.testing.assert_array_equal(s2[:t0], s0[:t0])
        assert grown.n_items == 300
        np.testing.assert_array_equal(
            np.asarray(grown.item_ids), np.asarray(index.item_ids)
        )
        # restored columns reconstruct within half an ulp of their tile's
        # grid (int4: uniform lsb; fp8: ulp(448) = 32 code units at amax)
        deq = np.asarray(quant.dequantize(grown.r_anc))[:, 260:300]
        err = np.abs(deq - np.asarray(m[:40, 260:300]))
        s_max = float(np.asarray(s2[t0:]).max())
        assert err.max() <= s_max * (0.5 if dtype == "int4" else 16.0) + 1e-5

    def test_add_items_requantizes_only_touched_tiles(self, dom):
        m = dom["m"]
        index = AnchorIndex.from_r_anc(
            m[:40, :256], item_ids=jnp.arange(256), capacity=320
        ).quantize("int8", tile=TILE)
        c0, s0 = _codes_scales(index)
        grown = index.add_items(jnp.arange(256, 300), cols=m[:40, 256:300])
        c1, s1 = _codes_scales(grown)
        # valid prefix occupies tiles 0..3 exactly; only tile 4 changes
        np.testing.assert_array_equal(c1[:, :256], c0[:, :256])
        np.testing.assert_array_equal(s1[:4], s0[:4])
        # new columns reconstruct within the quantization error bound
        deq = np.asarray(quant.dequantize(grown.r_anc))[:, 256:300]
        err = np.abs(deq - np.asarray(m[:40, 256:300]))
        assert err.max() <= float(grown.r_anc.scales[4]) * 0.5 + 1e-6

    def test_mutation_never_retraces_quantized(self, dom):
        m = dom["m"]
        sf = dom["ce"].score_fn()
        traces = []

        def counting_sf(q, i):
            traces.append(1)
            return sf(q, i)

        index = AnchorIndex.from_r_anc(
            m[:40, :250], item_ids=jnp.arange(250), capacity=320
        ).quantize("int8", tile=TILE)
        ret = AdaCURRetriever.from_index(index, counting_sf, CFG)
        ret.search(dom["test_q"], jax.random.PRNGKey(1))
        n_traces = len(traces)
        assert n_traces > 0
        ret.index = index.add_items(jnp.arange(250, 300), cols=m[:40, 250:300])
        ret.search(dom["test_q"], jax.random.PRNGKey(1))
        ret.index = ret.index.remove_items(jnp.arange(10, 40))
        ret.search(dom["test_q"], jax.random.PRNGKey(2))
        assert len(traces) == n_traces, "quantized mutation retraced the engine"

    def test_removed_items_never_retrieved(self, dom):
        m = dom["m"]
        sf = dom["ce"].score_fn()
        index = AnchorIndex.from_r_anc(m[:40], capacity=320).quantize(
            "int8", tile=TILE
        )
        rm = jnp.arange(0, 50)
        shrunk = index.remove_items(rm)
        res = AdaCURRetriever.from_index(shrunk, sf, CFG).search(
            dom["test_q"], jax.random.PRNGKey(2)
        )
        got = np.asarray(shrunk.gather_item_ids(res.topk_idx))
        assert not np.isin(got, np.asarray(rm)).any()
        assert (got >= 0).all()


class TestQuantizedBuildAndService:
    def test_build_emits_quantized_payload(self, dom, tmp_path):
        ce = dom["ce"]
        idx = AnchorIndex.build(
            ce.score_block, dom["q_ids"], jnp.arange(300), block_rows=16,
            checkpoint_dir=str(tmp_path / "ck"),
            payload_dtype="int8", payload_tile=TILE,
        )
        assert idx.payload_dtype == "int8"
        ref = AnchorIndex.build(
            ce.score_block, dom["q_ids"], jnp.arange(300), block_rows=16
        ).quantize("int8", tile=TILE)
        c0, s0 = _codes_scales(idx)
        c1, s1 = _codes_scales(ref)
        np.testing.assert_array_equal(c0, c1)
        np.testing.assert_array_equal(s0, s1)

    def test_service_over_quantized_index_with_swap(self, dom):
        from repro.launch.serve import AdaCURService, RetrievalRequest

        m = dom["m"]
        index = AnchorIndex.from_r_anc(m[:40, :250], capacity=320).quantize(
            "int8", tile=TILE
        )
        svc = AdaCURService(
            score_fn=dom["ce"].score_fn(), cfg=CFG, index=index,
            max_batch=2, max_wait_s=10.0,
        )
        out = []
        for qid in (41, 42):
            out += svc.submit(RetrievalRequest(query_id=qid)) or []
        assert len(out) == 2
        assert all((r.item_ids < 250).all() for r in out)
        svc.swap_index(svc.index.add_items(jnp.arange(250, 300),
                                           cols=m[:40, 250:300]))
        out2 = []
        for qid in (43, 44):
            out2 += svc.submit(RetrievalRequest(query_id=qid)) or []
        assert len(out2) == 2

"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED config of the same family and runs one forward /
train step on CPU, asserting output shapes + no NaNs.  The FULL configs are
exercised via the dry-run (launch/dryrun.py) only."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.models.gnn import nequip
from repro.models.recsys import bert4rec, bst, dlrm, mind
from repro.training import optimizer

LM_ARCHS = [
    "qwen3-8b", "qwen1.5-110b", "starcoder2-3b",
    "moonshot-v1-16b-a3b", "granite-moe-1b-a400m",
]


def _finite(x):
    return bool(jnp.isfinite(x).all())


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestLMArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = registry.smoke_config(arch)
        params, _ = transformer.init_lm(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
        h, aux = transformer.encode(params, tokens, cfg)
        logits = transformer.lm_logits(params, h, cfg)
        assert h.shape == (2, 16, cfg.d_model)
        assert logits.shape[:2] == (2, 16) and logits.shape[2] >= cfg.vocab_size
        assert _finite(h) and _finite(aux)
        assert _finite(logits[..., : cfg.vocab_size])

    def test_one_train_step(self, arch):
        cfg = registry.smoke_config(arch)
        params, _ = transformer.init_lm(jax.random.PRNGKey(0), cfg)
        opt_cfg = optimizer.AdamWConfig(lr=1e-3)
        opt = optimizer.init_adamw(params)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)

        def loss_fn(p):
            h, aux = transformer.encode(p, tokens, cfg)
            logits = transformer.lm_logits(p, h[:, :-1], cfg)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            return -jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new_params, _, metrics = optimizer.adamw_update(opt_cfg, params, grads, opt)
        assert _finite(loss) and _finite(metrics["grad_norm"])
        # params actually moved
        delta = jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).sum()), params, new_params),
        )
        assert delta > 0

    def test_decode_matches_encode(self, arch):
        """Prefill-free decode from scratch == encode at every position."""
        cfg = registry.smoke_config(arch)
        if not cfg.causal:
            pytest.skip("encoder-only")
        params, _ = transformer.init_lm(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, cfg.vocab_size)
        h, _ = transformer.encode(params, tokens, cfg)
        ref_logits = transformer.lm_logits(params, h, cfg)

        cache = transformer.init_cache(cfg, 2, 8)
        outs = []
        for t in range(8):
            lg, cache = transformer.decode_step(
                params, cache, tokens[:, t], jnp.int32(t), cfg
            )
            outs.append(lg)
        dec = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(dec[..., : cfg.vocab_size], np.float32),
            np.asarray(ref_logits[..., : cfg.vocab_size], np.float32),
            atol=2e-2, rtol=2e-2,
        )


class TestGNNArchSmoke:
    def test_forward_and_train_step(self):
        cfg = registry.smoke_config("nequip")
        params, _ = nequip.init_nequip(jax.random.PRNGKey(0), cfg)
        n, e = 24, 80
        batch = {
            "positions": jax.random.normal(jax.random.PRNGKey(1), (n, 3)) * 2,
            "node_attr": jax.random.randint(jax.random.PRNGKey(2), (n,), 0, cfg.n_species),
            "senders": jax.random.randint(jax.random.PRNGKey(3), (e,), 0, n),
            "receivers": jax.random.randint(jax.random.PRNGKey(4), (e,), 0, n),
            "energy": jnp.ones((1,)),
        }
        loss, grads = jax.value_and_grad(nequip.energy_mse_loss)(params, cfg, batch)
        assert _finite(loss)
        gnorm = optimizer.global_norm(grads)
        assert _finite(gnorm) and float(gnorm) > 0


RECSYS = {
    "dlrm-mlperf": dlrm,
    "bst": bst,
    "bert4rec": bert4rec,
    "mind": mind,
}


@pytest.mark.parametrize("arch", sorted(RECSYS))
class TestRecSysArchSmoke:
    def _init(self, arch, cfg):
        key = jax.random.PRNGKey(0)
        mod = RECSYS[arch]
        init = {
            "dlrm-mlperf": mod.init_dlrm if arch == "dlrm-mlperf" else None,
        }
        if arch == "dlrm-mlperf":
            return dlrm.init_dlrm(key, cfg)
        if arch == "bst":
            return bst.init_bst(key, cfg)
        if arch == "bert4rec":
            return bert4rec.init_bert4rec(key, cfg)
        return mind.init_mind(key, cfg)

    def test_forward_and_loss(self, arch):
        cfg = registry.smoke_config(arch)
        params, _ = self._init(arch, cfg)
        key = jax.random.PRNGKey(1)
        b = 4
        if arch == "dlrm-mlperf":
            dense = jax.random.normal(key, (b, cfg.n_dense))
            sparse = jax.random.randint(key, (b, cfg.n_sparse), 0, 10**6)
            out = dlrm.forward(params, dense, sparse, cfg)
            loss = dlrm.bce_loss(params, dense, sparse, jnp.ones(b), cfg)
        elif arch == "bst":
            hist = jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items)
            tgt = jax.random.randint(key, (b,), 0, cfg.n_items)
            out = bst.forward(params, hist, tgt, cfg)
            loss = bst.bce_loss(params, hist, tgt, jnp.zeros(b), cfg)
        elif arch == "bert4rec":
            hist = jax.random.randint(key, (b, cfg.seq_len), 1, cfg.n_items)
            out = bert4rec.score_candidates(
                params, hist, jax.random.randint(key, (b, 3), 0, cfg.n_items - 1), cfg
            )
            loss = bert4rec.mlm_loss(params, hist, jnp.arange(b), cfg)
        else:
            hist = jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items)
            out = mind.score_all_items(params, hist, cfg)[:, : cfg.n_items]
            loss = mind.sampled_softmax_loss(
                params, hist, jnp.arange(b),
                jax.random.randint(key, (b, 8), 0, cfg.n_items), cfg,
            )
        assert _finite(out) and _finite(loss)

    def test_grad_step(self, arch):
        cfg = registry.smoke_config(arch)
        params, _ = self._init(arch, cfg)
        key = jax.random.PRNGKey(2)
        b = 4
        if arch == "dlrm-mlperf":
            fn = lambda p: dlrm.bce_loss(
                p, jax.random.normal(key, (b, cfg.n_dense)),
                jax.random.randint(key, (b, cfg.n_sparse), 0, 10**6),
                jnp.ones(b), cfg)
        elif arch == "bst":
            fn = lambda p: bst.bce_loss(
                p, jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items),
                jax.random.randint(key, (b,), 0, cfg.n_items), jnp.ones(b), cfg)
        elif arch == "bert4rec":
            fn = lambda p: bert4rec.mlm_loss(
                p, jax.random.randint(key, (b, cfg.seq_len), 1, cfg.n_items),
                jnp.arange(b), cfg)
        else:
            fn = lambda p: mind.sampled_softmax_loss(
                p, jax.random.randint(key, (b, cfg.seq_len), 0, cfg.n_items),
                jnp.arange(b), jax.random.randint(key, (b, 8), 0, cfg.n_items), cfg)
        grads = jax.grad(fn)(params)
        assert _finite(optimizer.global_norm(grads))


def test_registry_covers_40_cells():
    cells = registry.cells()
    assert len(cells) == 40
    assert len({a for a, _ in cells}) == 10

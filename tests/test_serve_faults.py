"""Serve-tier chaos suite: every injected failure mode must end in exactly
one terminal response per request — results, degraded results, error, or
rejection — with the replica loop, the router, and the index all still
live afterwards.

Failure modes covered (all deterministic, via ``launch.faults.FaultPlan``):
deadline-degraded anytime answers (+ prefix consistency against an explicit
shorter run), scorer exceptions contained at the flush boundary, index swap
racing live submissions from other threads, hedged duplicate suppression,
error-driven and straggler-driven quarantine with queue drain, and
admission-control rejection ordering.

The whole module runs under a faulthandler watchdog (SERVE_WATCHDOG_S, like
the multidevice suite): a deadlocked router/replica thread dumps all stacks
and kills the run instead of hanging CI.
"""

import logging
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AdaCURConfig
from repro.core.engine import AdaCURRetriever, ce_call_plan
from repro.core.index import AnchorIndex
from repro.core.scorer import TabulatedScorer
from repro.launch.faults import (
    FaultInjectedError,
    FaultPlan,
    FaultyScorer,
    ScorerFault,
    SleepFault,
    SwapFault,
)
from repro.launch.router import Router
from repro.launch.serve import AdaCURService, RetrievalRequest

N_Q, N_ITEMS = 60, 100
CFG = AdaCURConfig(
    k_anchor=4, n_rounds=4, budget_ce=12, k_retrieve=8, loop_mode="fori"
)


@pytest.fixture(autouse=True, scope="module")
def _watchdog():
    import faulthandler

    watchdog_s = float(os.environ.get("SERVE_WATCHDOG_S", "480"))
    faulthandler.dump_traceback_later(watchdog_s, exit=True)
    # injected scorer faults log loudly from inside the callback machinery;
    # they are the *point* of this suite, not noise worth printing
    logging.getLogger("jax._src.callback").setLevel(logging.CRITICAL)
    yield
    faulthandler.cancel_dump_traceback_later()


@pytest.fixture(scope="module")
def m():
    rng = np.random.default_rng(0)
    return rng.standard_normal((N_Q, N_ITEMS)).astype(np.float32)


def _service(m, *, plan=None, replica=None, item_offset=0, deterministic=False,
             max_batch=None, batch_buckets=None, record_pairs=False):
    if max_batch is None:
        max_batch = max(batch_buckets) if batch_buckets else 4
    wide = np.zeros((N_Q, item_offset + N_ITEMS), dtype=np.float32)
    wide[:, item_offset:] = m
    scorer = TabulatedScorer(wide, record_pairs=record_pairs)
    if plan is not None:
        scorer = FaultyScorer(scorer, plan, replica=replica)
    index = AnchorIndex.from_r_anc(
        jnp.asarray(m[:40]),
        item_ids=jnp.arange(item_offset, item_offset + N_ITEMS),
    )
    retriever = AdaCURRetriever.from_index(index, scorer, CFG, anytime=True)
    return AdaCURService(
        retriever=retriever, max_batch=max_batch, max_wait_s=60.0,
        batch_buckets=batch_buckets, deterministic=deterministic,
    )


class TestAnytimeDeadline:
    def test_degraded_response_is_prefix_consistent(self, m):
        """An expired budget returns the provisional top-k of the rounds
        completed — and that answer is *exactly* the answer an explicit
        ``n_rounds=rounds_completed`` run produces (same key, same batch
        shape): degradation truncates the trajectory, it never invents a
        different one."""
        svc = _service(m, deterministic=True, batch_buckets=[1])
        (r,) = svc.submit(RetrievalRequest(
            query_id=45, deadline_t=time.monotonic() - 1.0)) or svc.flush()
        assert r.status == "ok" and r.degraded
        assert r.rounds_completed == 1          # round 0 always completes
        assert r.measured_ce_calls == ce_call_plan(CFG, 1)
        ref = svc.retriever.search(
            jnp.asarray([45]), svc._key, n_rounds=r.rounds_completed
        )
        ref_ids = np.asarray(svc.index.gather_item_ids(ref.topk_idx))[0]
        np.testing.assert_array_equal(r.item_ids, ref_ids)
        np.testing.assert_array_equal(r.scores, np.asarray(ref.topk_scores[0]))

    def test_generous_deadline_serves_full_search(self, m):
        svc = _service(m, deterministic=True, batch_buckets=[1])
        (r,) = svc.submit(RetrievalRequest(
            query_id=45, deadline_t=time.monotonic() + 60.0)) or svc.flush()
        assert not r.degraded and r.rounds_completed == CFG.n_rounds
        assert r.measured_ce_calls == ce_call_plan(CFG)

    def test_deadline_requires_anytime_retriever(self, m):
        scorer = TabulatedScorer(m)
        index = AnchorIndex.from_r_anc(jnp.asarray(m[:40]))
        retr = AdaCURRetriever.from_index(index, scorer, CFG)  # not anytime
        with pytest.raises(ValueError, match="anytime"):
            retr.search(jnp.asarray([3]), deadline_t=time.monotonic())


class TestFlushErrorBoundary:
    def test_scorer_exception_fails_batch_not_loop(self, m):
        """A scorer raising on call k fails exactly the in-flight batch
        (per-request error responses); the queue and the compiled engine
        stay serviceable for the next batch."""
        plan = FaultPlan(scorer_faults=[ScorerFault(call_k=1)])
        svc = _service(m, plan=plan, batch_buckets=[1, 2, 4])
        svc.submit(RetrievalRequest(query_id=3))
        svc.submit(RetrievalRequest(query_id=7))
        out = svc.flush()
        assert [r.query_id for r in out] == [3, 7]
        assert all(r.status == "error" for r in out)
        assert all("FaultInjectedError" in r.error for r in out)
        assert all(r.item_ids is None for r in out)
        # the very next batch (call counter past the fault) serves cleanly
        svc.submit(RetrievalRequest(query_id=3))
        (ok,) = svc.flush()
        assert ok.status == "ok" and ok.error is None
        assert (0 <= ok.item_ids).all() and (ok.item_ids < N_ITEMS).all()

    def test_fault_raises_at_exact_call(self, m):
        plan = FaultPlan(scorer_faults=[ScorerFault(call_k=3)])
        scorer = FaultyScorer(TabulatedScorer(m), plan)
        scorer._host_entry(np.asarray([0]), np.asarray([[1, 2]]))
        scorer._host_entry(np.asarray([0]), np.asarray([[1, 2]]))
        with pytest.raises(FaultInjectedError):
            scorer._host_entry(np.asarray([0]), np.asarray([[1, 2]]))
        # stats stayed on the inner scorer and counted only served calls
        assert scorer.stats.ce_calls == 4


class TestSwapUnderLiveSubmissions:
    def test_concurrent_swap_and_submit(self, m):
        """submit()/flush() from worker threads racing swap_index() from
        the main thread: every response's ids come wholly from one index's
        namespace (never a mix), and responses drained *by* the swap are
        answered against the admitting (old) index."""
        svc = _service(m, item_offset=1000, max_batch=2,
                       batch_buckets=[1, 2])
        # widen the scorer so both namespaces stay addressable
        wide = np.zeros((N_Q, 2000 + N_ITEMS), dtype=np.float32)
        wide[:, 1000:1000 + N_ITEMS] = m
        wide[:, 2000:] = m
        svc._scorer.matrix = wide
        new_index = AnchorIndex.from_r_anc(
            jnp.asarray(m[:40]), item_ids=jnp.arange(2000, 2000 + N_ITEMS)
        )
        svc.retriever.search(jnp.asarray([0, 1]))   # warm the compile

        responses, stop = [], threading.Event()
        out_lock = threading.Lock()

        def submitter(seed):
            rng = np.random.default_rng(seed)
            while not stop.is_set():
                got = svc.submit(
                    RetrievalRequest(query_id=int(rng.integers(0, N_Q)))
                ) or []
                got += svc.flush()
                with out_lock:
                    responses.extend(got)

        threads = [threading.Thread(target=submitter, args=(s,))
                   for s in range(3)]
        for t in threads:
            t.start()
        time.sleep(0.1)
        drained = svc.swap_index(new_index)
        time.sleep(0.1)
        stop.set()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive()
        responses.extend(svc.flush())

        for r in drained:
            # swap-drained responses answer against their admitting index
            assert (r.item_ids >= 1000).all() and (r.item_ids < 2000).all()
        assert responses, "submitter threads served nothing"
        for r in responses:
            assert r.status == "ok"
            old = (r.item_ids >= 1000) & (r.item_ids < 2000)
            new = r.item_ids >= 2000
            assert old.all() or new.all(), "mixed-namespace response"
        # traffic after the swap point lands on the new index
        svc.submit(RetrievalRequest(query_id=5))
        (after,) = svc.flush()
        assert (after.item_ids >= 2000).all()


def _router(m, n_replicas=2, plan=None, record_pairs=False, **kw):
    services = [
        _service(m, plan=plan, replica=rid, batch_buckets=[1, 2, 4],
                 record_pairs=record_pairs)
        for rid in range(n_replicas)
    ]
    return Router(services, plan=plan, **kw)


def _warm(router, m):
    """Compile every replica's engine batch buckets before timing-sensitive
    phases (a cold jit compile dwarfs any injected stall otherwise)."""
    for rep in router.replicas:
        for b in rep.service.batch_buckets:
            rep.service.retriever.search(jnp.arange(b))


class TestRouterChaos:
    def test_hedged_pair_yields_exactly_one_response(self, m):
        """Replica 0 stalls every batch; hedging re-dispatches to replica 1.
        Each ticket resolves exactly once (CAS), and the winning attempt
        scored each of its CE pairs at most once."""
        plan = FaultPlan(sleep_faults=[SleepFault(replica=0, seconds=0.6)])
        router = _router(m, plan=plan, queue_limit=64, hedge_after_s=0.05,
                         record_pairs=True)
        try:
            _warm(router, m)
            qids = list(range(10, 18))           # distinct per ticket
            tickets = [router.submit(q) for q in qids]
            outs = [router.result(t, timeout=120) for t in tickets]
            assert all(o is not None for o in outs), "lost request"
            assert all(o.status == "ok" for o in outs)
            assert router.stats["hedges"] >= 1
            for t, o in zip(tickets, outs):
                # one terminal outcome; a replica never serves the same
                # ticket twice (hedge/retry dispatch excludes replicas
                # already tried), so with the engine's per-search
                # exactly-once pair invariant, no attempt double-scores
                assert o.attempts <= 2           # original + at most 1 hedge
                assert len(t.replicas_tried) == len(set(t.replicas_tried))
            # and within every scorer callback, a request's pair rows are
            # duplicate-free on both replicas
            for rep in router.replicas:
                for qarr, iarr in rep.service._scorer.call_log:
                    for qr, row in zip(np.asarray(qarr), np.asarray(iarr)):
                        assert len(row) == len(set(row.tolist())), (
                            "duplicate pair inside one scorer call"
                        )
        finally:
            router.close()

    def test_error_quarantine_drains_to_peers(self, m):
        """A replica whose every batch errors is quarantined after
        ``max_consecutive_errors`` and its queue drained: all requests
        still end OK via the healthy peer — zero lost."""
        plan = FaultPlan(scorer_faults=[
            ScorerFault(call_k=k, replica=0) for k in range(1, 500)
        ])
        router = _router(m, plan=plan, queue_limit=64, max_retries=2,
                         max_consecutive_errors=2)
        try:
            tickets = [router.submit(i % N_Q) for i in range(16)]
            outs = [router.result(t, timeout=120) for t in tickets]
            assert all(o is not None for o in outs), "lost request"
            assert all(o.status == "ok" for o in outs)
            assert router.quarantined == [0]
            assert not router.replicas[0].healthy
            assert router.replicas[1].healthy
            # post-quarantine traffic routes around the dead replica
            t = router.submit(9)
            out = router.result(t, timeout=120)
            assert out.status == "ok" and out.replica == 1
        finally:
            router.close()

    def test_straggler_watchdog_quarantines_slow_replica(self, m):
        """The StragglerWatchdog is the router's health signal: with the
        fleet baseline warmed by the healthy peer, a persistently slow
        replica is flagged against the *shared* median and quarantined
        after ``patience`` straggler batches."""
        # patience=1: hedging steals the stalled replica's repeat traffic,
        # so it only observes a batch or two before traffic routes away
        # margins: warmed CPU batches run well under ~0.4s even with GIL
        # noise, the flag level is 8 x 0.2s = 1.6s, and the injected stall
        # is 2s+ — healthy noise cannot flag, the stall cannot miss
        plan = FaultPlan(sleep_faults=[SleepFault(replica=0, seconds=2.0)])
        router = _router(m, plan=plan, queue_limit=64, hedge_after_s=0.05,
                         watchdog_threshold=8.0, watchdog_patience=1)
        try:
            _warm(router, m)
            # fleet-wide baseline (the shared deque means replica 0 is
            # judged against its peers' median, not its own stalled history)
            router.replicas[1].watchdog.window.extend([0.2] * 8)
            tickets = [router.submit(i % N_Q) for i in range(12)]
            outs = [router.result(t, timeout=120) for t in tickets]
            assert all(o is not None and o.status == "ok" for o in outs)
            # hedging answers long before the stalled replica's batch even
            # completes — wait for that batch to land and be flagged
            t_end = time.monotonic() + 30.0
            while 0 not in router.quarantined and time.monotonic() < t_end:
                time.sleep(0.05)
            assert 0 in router.quarantined
            # shared-baseline invariant: both watchdogs see one deque
            assert (router.replicas[0].watchdog.window
                    is router.replicas[1].watchdog.window)
        finally:
            router.close()

    def test_admission_rejection_ordering(self, m):
        """Load shedding is immediate and explicit: once ``queue_limit``
        tickets are in flight, the next submit resolves REJECTED before
        any in-flight ticket completes — never queued, never lost."""
        plan = FaultPlan(sleep_faults=[SleepFault(replica=0, seconds=0.5)])
        router = _router(m, n_replicas=1, plan=plan, queue_limit=2)
        try:
            _warm(router, m)
            admitted = [router.submit(i) for i in range(2)]
            shed = [router.submit(i) for i in range(2, 5)]
            # rejections are terminal at submit-return time, while the
            # admitted tickets are still in flight behind the stall
            for t in shed:
                assert t.resolved and t.outcome.status == "rejected"
                assert t.outcome.attempts == 0
            assert not any(t.resolved for t in admitted)
            outs = [router.result(t, timeout=120) for t in admitted]
            assert all(o is not None and o.status == "ok" for o in outs)
            assert router.stats["rejected"] == 3
            assert router.stats["admitted"] == 2
        finally:
            router.close()

    def test_midflight_swap_preserves_namespace_consistency(self, m):
        """A FaultPlan-scheduled swap at admission n: every response's ids
        are wholly from one index namespace and nothing is lost."""
        new_index = AnchorIndex.from_r_anc(
            jnp.asarray(m[:40]), item_ids=jnp.arange(2000, 2000 + N_ITEMS)
        )
        plan = FaultPlan(swap_faults=[SwapFault(at_seq=6)])
        services = []
        for rid in range(2):
            wide = np.zeros((N_Q, 2000 + N_ITEMS), dtype=np.float32)
            wide[:, 1000:1000 + N_ITEMS] = m
            wide[:, 2000:] = m
            scorer = TabulatedScorer(wide)
            index = AnchorIndex.from_r_anc(
                jnp.asarray(m[:40]),
                item_ids=jnp.arange(1000, 1000 + N_ITEMS),
            )
            retriever = AdaCURRetriever.from_index(
                index, scorer, CFG, anytime=True
            )
            services.append(AdaCURService(
                retriever=retriever, max_batch=4, max_wait_s=60.0,
                batch_buckets=[1, 2, 4],
            ))
        router = Router(services, plan=plan, queue_limit=64,
                        swap_index_fn=lambda: new_index)
        try:
            tickets = [router.submit(i % N_Q) for i in range(12)]
            outs = [router.result(t, timeout=120) for t in tickets]
            assert all(o is not None for o in outs), "lost request"
            assert all(o.status == "ok" for o in outs)
            assert router.stats["swaps"] == 1
            seen_new = False
            for o in outs:
                ids = o.response.item_ids
                old = ((ids >= 1000) & (ids < 2000)).all()
                new = (ids >= 2000).all()
                assert old or new, "mixed-namespace response"
                seen_new = seen_new or new
            assert seen_new, "swap never took effect"
        finally:
            router.close()

    def test_close_resolves_stragglers(self, m):
        """Shutdown with tickets still in flight: close() resolves them as
        errors — even teardown cannot lose a request."""
        plan = FaultPlan(sleep_faults=[SleepFault(replica=0, seconds=2.0)])
        router = _router(m, n_replicas=1, plan=plan, queue_limit=8)
        _warm(router, m)
        tickets = [router.submit(i) for i in range(3)]
        router.close(timeout=0.2)
        for t in tickets:
            out = router.result(t, timeout=120)
            assert out is not None
            assert out.status in ("ok", "error")

"""Quickstart: ADACUR vs ANNCUR on a synthetic cross-encoder domain.

    PYTHONPATH=src python examples/quickstart.py [--payload-dtype int8] \
        [--first-stage {de,bm25}]

Builds a 10K-item domain, wraps the offline scores in the one
:class:`AnchorIndex` artifact (build/save/load/shard/mutate lives there),
then runs budget-matched retrieval with the paper's method and the
fixed-anchor baseline — both as configurations of the unified Retriever
engine — and prints Top-k-Recall.  ``--payload-dtype int8`` (or ``int4`` /
``fp8``) demonstrates the quantized payload end to end: the index stores
per-tile codes + fp32 scales (int8/fp8 ~4x smaller, packed int4 ~8x) and
the fused kernel dequantizes tile-by-tile in registers.
``--round-kernel persistent`` fuses each round's estimate, Gumbel top-k
and early-exit monitor into one payload sweep."""

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import AdaCURConfig
from repro.core import retrieval
from repro.core.engine import AdaCURRetriever, ANNCURRetriever
from repro.core.index import AnchorIndex
from repro.core.scorer import SyntheticScorer
from repro.data.synthetic import make_synthetic_ce


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--payload-dtype",
                    choices=("float32", "bfloat16", "int8", "int4", "fp8"),
                    default="float32",
                    help="storage/streaming dtype of the R_anc payload "
                         "(int8/fp8 ~4x smaller, packed int4 ~8x)")
    ap.add_argument("--round-kernel", choices=("staged", "persistent"),
                    default="staged",
                    help="persistent: one fused payload sweep per round "
                         "(bit-identical rankings to staged)")
    ap.add_argument("--first-stage", choices=("none", "de", "bm25"),
                    default="none",
                    help="add a multi-stage hybrid row: first-stage "
                         "shortlist -> candidate-restricted ADACUR")
    args = ap.parse_args()

    print("building synthetic CE domain: 10,000 items, 500 anchor queries...")
    ce = make_synthetic_ce(jax.random.PRNGKey(0), n_queries=600, n_items=10000)
    m = ce.full_matrix(jnp.arange(600))
    test_q, exact = jnp.arange(500, 600), m[500:]
    # every provider (synthetic / tabulated / real CE) is a Scorer; see
    # examples/real_ce_search.py for the transformer-CE stack
    score_fn = SyntheticScorer(ce)

    # the offline artifact: anchor-query scores + ids; at scale this is
    # AnchorIndex.build(...) (resumable) + .save()/.load() + .shard(mesh)
    index = AnchorIndex.from_r_anc(m[:500], anchor_query_ids=jnp.arange(500))
    fp32_bytes = index.payload_nbytes
    if args.payload_dtype != "float32":
        index = index.quantize(args.payload_dtype)
        print(f"payload {args.payload_dtype}: {index.payload_nbytes / 1e6:.1f} MB "
              f"(fp32: {fp32_bytes / 1e6:.1f} MB, "
              f"{index.payload_nbytes / fp32_bytes:.2f}x)")

    budget = 200  # exact CE calls per query at test time
    print(f"\nCE-call budget per query: {budget}  (brute force would need 10,000)\n")

    cfg = AdaCURConfig(k_anchor=100, n_rounds=5, budget_ce=budget,
                       strategy="topk", k_retrieve=100, loop_mode="fori",
                       use_fused_topk=True, payload_dtype=args.payload_dtype,
                       round_kernel=args.round_kernel)
    ret = AdaCURRetriever.from_index(index, score_fn, cfg)
    res = ret.search(test_q, jax.random.PRNGKey(1))
    rep = retrieval.evaluate_result("ADACUR(TopK,5 rounds)", res, exact)

    idx = index.with_anchors(k_anchor=100, key=jax.random.PRNGKey(2))
    ret2 = ANNCURRetriever.from_index(idx, score_fn, budget, 100)
    res2 = ret2.search(test_q)
    rep2 = retrieval.evaluate_result("ANNCUR(random anchors)", res2, exact)

    reports = [rep, rep2]
    if args.first_stage != "none":
        from repro.core.candidates import (
            BM25Candidates, DualEncoderCandidates, HybridRetriever,
        )

        if args.first_stage == "de":
            gen = DualEncoderCandidates(ce.q_emb, ce.i_emb)
        else:
            from repro.data.synthetic import lexical_signatures

            gen = BM25Candidates(lexical_signatures(ce.i_emb, seed=3),
                                 lexical_signatures(ce.q_emb, seed=3))
        hyb = HybridRetriever(score_fn=score_fn, generator=gen, cfg=cfg,
                              index=index, shortlist_k=4 * budget,
                              mode="mask")
        res3 = hyb.search(test_q, jax.random.PRNGKey(3))
        reports.append(retrieval.evaluate_result(
            f"HYBRID({args.first_stage}->ADACUR)", res3, exact))

    print(f"{'method':<28} {'R@1':>6} {'R@10':>6} {'R@100':>6}")
    for rep_i in reports:
        print(f"{rep_i.method:<28} {rep_i.recall[1]:>6.3f} "
              f"{rep_i.recall[10]:>6.3f} {rep_i.recall[100]:>6.3f}")
    assert rep.recall[100] > rep2.recall[100], "ADACUR should beat ANNCUR@100"
    print("\nADACUR > ANNCUR at equal budget — the paper's headline result.")


if __name__ == "__main__":
    main()
